//! Table 2 — the ablation grid across quantization configurations
//! (quick-effort variant; `osp repro table2 --full` for the full rows).
//! Requires trained runs (`cargo run --release --example train_osp --
//! --ablation`).

use osp::repro::{self, Effort};
use osp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::var("OSP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    let runs = std::path::PathBuf::from(
        std::env::var("OSP_RUNS").unwrap_or_else(|_| "runs".into()));
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP table2: no artifacts");
        return Ok(());
    }
    let engine = Engine::open(&dir)?;
    // Quick variant over the three headline configs; the full grid is
    // `osp repro table2 --full`.
    match repro::table2_tags(&engine, &runs, Effort::QUICK,
                             &["adam", "muon", "osp"]) {
        Ok(t) => t.print(),
        Err(e) => eprintln!("SKIP table2: {e}"),
    }
    Ok(())
}
