//! §Perf — training-step dispatch study: single-step fused executable vs
//! the K-step scan artifact (train8_*), measuring how much of the step is
//! host<->device parameter traffic vs compute, plus evalq dispatch cost.
//!
//!   cargo bench --bench perf_steps

use osp::bench::{bench, Table};
use osp::runtime::{Engine, HostValue};
use osp::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::var("OSP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP perf_steps: no artifacts");
        return Ok(());
    }
    let engine = Engine::open(&dir)?;
    let m = engine.manifest();
    let (b, s) = (m.batch_train, m.model.seq_len);
    let mut table = Table::new(
        "§Perf — step dispatch: 1-step vs 8-step artifacts",
        &["config", "artifact", "ms/step", "tok/s", "speedup"]);

    for (opt, arch) in [("adam", "rmsnorm_plain"),
                        ("muon", "ssnorm_embproj")] {
        let init = engine.load(&format!("init_{arch}"))?;
        let params: Vec<HostValue> = init
            .run(&[HostValue::tokens(&[1], vec![3])])?
            .into_iter()
            .map(|t| HostValue::F32(t.into_f32().unwrap()))
            .collect();
        let opt_state: Vec<HostValue> =
            osp::runtime::init_opt_state(m.opt_leaves(arch, opt)?)
                .into_iter()
                .map(HostValue::F32)
                .collect();
        let mut rng = Pcg::new(5, 0);
        let mut toks = |n: usize| -> Vec<i32> {
            (0..n).map(|_| rng.below(m.model.vocab_size as u64) as i32)
                .collect()
        };

        // Single-step.
        let exe1 = engine.load(&format!("train_{opt}_{arch}"))?;
        let mut in1: Vec<HostValue> = params.clone();
        in1.extend(opt_state.iter().cloned());
        in1.push(HostValue::tokens(&[b, s], toks(b * s)));
        in1.push(HostValue::scalar(1e-3));
        let t1 = bench(1, 5, || {
            exe1.run(&in1).expect("step");
        });
        let tok1 = (b * s) as f64 / t1.mean_secs;
        table.row(vec![format!("{opt}@{arch}"), "train (1-step)".into(),
                       format!("{:.1}", 1e3 * t1.mean_secs),
                       format!("{tok1:.0}"), "1.00x".into()]);

        // 8-step scan (if built).
        let name8 = format!("train8_{opt}_{arch}");
        if engine.manifest().artifact(&name8).is_ok() {
            let exe8 = engine.load(&name8)?;
            let k = 8usize;
            let mut in8: Vec<HostValue> = params.clone();
            in8.extend(opt_state.iter().cloned());
            in8.push(HostValue::tokens(&[k, b, s], toks(k * b * s)));
            in8.push(HostValue::F32(osp::tensor::Tensor::new(
                vec![k], vec![1e-3; k])));
            let t8 = bench(1, 3, || {
                exe8.run(&in8).expect("step8");
            });
            let per_step = t8.mean_secs / k as f64;
            table.row(vec![
                format!("{opt}@{arch}"), "train8 (scan)".into(),
                format!("{:.1}", 1e3 * per_step),
                format!("{:.0}", (b * s) as f64 / per_step),
                format!("{:.2}x", t1.mean_secs / per_step),
            ]);
        }
    }

    // Dispatch overhead floor: the cheapest executable (ns_*).
    if let Some(ns) = engine.manifest().artifacts.keys()
        .find(|n| n.starts_with("ns_")).cloned()
    {
        let exe = engine.load(&ns)?;
        let shape = exe.spec.inputs[0].shape.clone();
        let mut g = osp::tensor::Tensor::zeros(&shape);
        Pcg::new(1, 1).fill_normal(g.data_mut(), 1.0);
        let inp = [HostValue::F32(g)];
        let t = bench(2, 10, || {
            exe.run(&inp).expect("ns");
        });
        table.row(vec!["dispatch floor".into(), ns,
                       format!("{:.2}", 1e3 * t.mean_secs),
                       "-".into(), "-".into()]);
    }
    table.print();
    Ok(())
}
