//! Table 4 — PTQ method composition (RTN / FFN-Had / GPTQ / QuaRot-lite /
//! SpinQuant-lite) at W4-A4-KV4, Adam vs OSP checkpoints.

use osp::repro::{self, Effort};
use osp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::var("OSP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    let runs = std::path::PathBuf::from(
        std::env::var("OSP_RUNS").unwrap_or_else(|_| "runs".into()));
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP table4: no artifacts");
        return Ok(());
    }
    let engine = Engine::open(&dir)?;
    match repro::table4(&engine, &runs, Effort::QUICK) {
        Ok(t) => t.print(),
        Err(e) => eprintln!("SKIP table4: {e}"),
    }
    Ok(())
}
