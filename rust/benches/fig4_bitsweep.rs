//! Figure 4 — WikiText-2-analog perplexity across weight x activation
//! bit-widths for Adam / Muon / OSP (RTN). Also prints the Figure 3/7
//! training-dynamics summary from telemetry.

use osp::repro::{self, Effort};
use osp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::var("OSP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    let runs = std::path::PathBuf::from(
        std::env::var("OSP_RUNS").unwrap_or_else(|_| "runs".into()));
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP fig4: no artifacts");
        return Ok(());
    }
    let engine = Engine::open(&dir)?;
    // Quick variant: the two headline configs; `osp repro fig4` adds muon.
    match repro::fig4(&engine, &runs, &["adam", "osp"], Effort::QUICK) {
        Ok(t) => t.print(),
        Err(e) => eprintln!("SKIP fig4: {e}"),
    }
    match repro::fig3(&runs, &repro::ablation_tags()) {
        Ok(s) => println!("{s}"),
        Err(e) => eprintln!("SKIP fig3: {e}"),
    }
    Ok(())
}
