//! Table 1 — optimizer cost comparison: training throughput (TPS,
//! relative to Adam), optimizer-state memory, and build (compile) time
//! per optimizer, all through the fused train_* executables.
//!
//!   cargo bench --bench table1_optimizers

use std::time::Instant;

use osp::bench::{bench, Table};
use osp::runtime::{Engine, HostValue};
use osp::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::var("OSP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP table1: no artifacts (run `make artifacts`)");
        return Ok(());
    }
    let engine = Engine::open(&dir)?;
    let m = engine.manifest();
    let arch = "rmsnorm_plain";
    let (b, s) = (m.batch_train, m.model.seq_len);
    let tokens_per_step = (b * s) as f64;
    let param_elems = m.param_count(arch)? as f64;

    let init = engine.load(&format!("init_{arch}"))?;
    let params: Vec<HostValue> = init
        .run(&[HostValue::tokens(&[1], vec![3])])?
        .into_iter()
        .map(|t| HostValue::F32(t.into_f32().unwrap()))
        .collect();
    let mut rng = Pcg::new(5, 0);
    let toks: Vec<i32> = (0..b * s)
        .map(|_| rng.below(m.model.vocab_size as u64) as i32)
        .collect();
    let tokens = HostValue::tokens(&[b, s], toks);

    let mut table = Table::new(
        "Table 1 — optimizer cost (paper: Adam 100%, Muon 97.9%, \
         Shampoo 75.5%, SOAP worse; mem 36/24/~113/~101 LD^2)",
        &["Optimizer", "TPS", "Relative", "OptState/Params", "Build (s)",
          "Step (ms)"]);

    let mut adam_tps = None;
    for opt in ["adam", "muon", "muon_noadam", "shampoo", "soap"] {
        let name = format!("train_{opt}_{arch}");
        if engine.manifest().artifact(&name).is_err() {
            continue;
        }
        // Build time = parse + XLA compile (what the paper's "Build Time"
        // column measures on its TPU toolchain).
        let t0 = Instant::now();
        let exe = engine.load(&name)?;
        let build_secs = t0.elapsed().as_secs_f64();

        let opt_state: Vec<HostValue> =
            osp::runtime::init_opt_state(m.opt_leaves(arch, opt)?)
                .into_iter()
                .map(HostValue::F32)
                .collect();
        let state_elems = m.opt_state_count(arch, opt)? as f64;

        let mut inputs: Vec<HostValue> = params.clone();
        inputs.extend(opt_state.iter().cloned());
        inputs.push(tokens.clone());
        inputs.push(HostValue::scalar(1e-3));

        let timing = bench(1, 5, || {
            exe.run(&inputs).expect("train step");
        });
        let tps = tokens_per_step / timing.mean_secs;
        let rel = adam_tps.map(|a: f64| tps / a).unwrap_or(1.0);
        if opt == "adam" {
            adam_tps = Some(tps);
        }
        table.row(vec![
            opt.to_string(),
            format!("{tps:.0}"),
            format!("{:.1}%", 100.0 * rel),
            format!("{:.2}x", state_elems / param_elems),
            format!("{build_secs:.2}"),
            format!("{:.1}", 1000.0 * timing.mean_secs),
        ]);
        eprintln!("  measured {opt}");
    }
    table.print();
    Ok(())
}
