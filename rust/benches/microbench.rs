//! Microbenchmarks of the L3 substrates (§Perf): host linalg (the
//! disaggregated-Muon outer loop), quantization kernels, ring all-reduce,
//! the data pipeline, and raw executable dispatch overhead.

use osp::bench::{bench, Table};
use osp::coordinator::dp::ring_all_reduce;
use osp::data::{Split, TokenStream};
use osp::quant::rtn;
use osp::tensor::linalg;
use osp::tensor::Tensor;
use osp::util::rng::Pcg;

fn randn(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Pcg::new(seed, 8);
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(
        "L3 microbenchmarks",
        &["op", "size", "mean (ms)", "throughput"]);

    let a = randn(&[256, 256], 1);
    let b = randn(&[256, 256], 2);
    let t = bench(2, 10, || {
        std::hint::black_box(linalg::matmul(&a, &b));
    });
    table.row(vec!["matmul".into(), "256x256".into(),
                   format!("{:.2}", t.mean_secs * 1e3),
                   format!("{:.2} GFLOP/s",
                           2.0 * 256f64.powi(3) / t.mean_secs / 1e9)]);

    let g = randn(&[256, 256], 3);
    let t = bench(1, 5, || {
        std::hint::black_box(linalg::ns_orthogonalize(&g, 5));
    });
    table.row(vec!["newton_schulz(5)".into(), "256x256".into(),
                   format!("{:.2}", t.mean_secs * 1e3),
                   format!("{:.0} mat/s", t.per_sec())]);

    let w = randn(&[512, 512], 4);
    let t = bench(1, 10, || {
        std::hint::black_box(rtn::quantize_per_channel(&w, 4));
    });
    table.row(vec!["rtn_per_channel".into(), "512x512".into(),
                   format!("{:.2}", t.mean_secs * 1e3),
                   format!("{:.1} Melem/s",
                           w.len() as f64 / t.mean_secs / 1e6)]);

    let x = randn(&[512, 512], 5);
    let t = bench(1, 10, || {
        std::hint::black_box(linalg::hadamard_rows(&x));
    });
    table.row(vec!["hadamard_rows".into(), "512x512".into(),
                   format!("{:.2}", t.mean_secs * 1e3),
                   format!("{:.1} Melem/s",
                           x.len() as f64 / t.mean_secs / 1e6)]);

    for k in [2usize, 4, 8] {
        let n = 1 << 18;
        let t = bench(1, 5, || {
            let parts: Vec<Vec<f32>> =
                (0..k).map(|i| vec![i as f32; n]).collect();
            std::hint::black_box(ring_all_reduce(parts));
        });
        table.row(vec![format!("ring_all_reduce(k={k})"),
                       format!("{n} f32"),
                       format!("{:.2}", t.mean_secs * 1e3),
                       format!("{:.1} MB/s",
                               (k * n * 4) as f64 / t.mean_secs / 1e6)]);
    }

    let t = bench(1, 5, || {
        let mut s = TokenStream::new(512, 1, Split::Train, 0, 1);
        for i in 0..20 {
            std::hint::black_box(s.next_batch(8, 128, i));
        }
    });
    table.row(vec!["data 20 batches".into(), "8x128".into(),
                   format!("{:.2}", t.mean_secs * 1e3),
                   format!("{:.2} Mtok/s",
                           20.0 * 8.0 * 128.0 / t.mean_secs / 1e6)]);

    table.print();
    Ok(())
}
