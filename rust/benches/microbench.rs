//! Microbenchmarks of the L3 substrates (§Perf): host linalg (the
//! disaggregated-Muon outer loop), quantization kernels, ring all-reduce,
//! the data pipeline, and raw executable dispatch overhead.
//!
//! The `serial` rows pin the single-thread baseline; the `par(N)` rows
//! run the same kernel on the shared pool (`N` = `OSP_THREADS`, or the
//! host's available parallelism capped at 16 when unset). Compare
//! `OSP_THREADS=1` vs `OSP_THREADS=4` runs to see the speedup the
//! parallel kernel layer (DESIGN.md §6) buys.
//!
//! `--json` runs only the quantization + decode sections and writes
//! `BENCH_quant.json` (packed-vs-dense matvec ns/op + bytes/param,
//! LUT-vs-legacy `scalar_ns_op` kernel rows for the §10 microkernels,
//! `int_ns_op`/`int_scalar_ns_op` rows for the §11 integer rhs kernels,
//! and packed-vs-dense decode tokens/sec at batch 8) for CI's perf
//! trajectory; `osp serve-bench --json` covers the full batch/bit-config
//! grid in `BENCH_infer.json`, and `osp bench-diff OLD NEW` trends any
//! two of these artifacts against each other.

use osp::bench::{bench, Table};
use osp::coordinator::dp::ring_all_reduce;
use osp::data::grammar::{Grammar, LANGUAGE_SEED};
use osp::data::{Split, TokenStream};
use osp::eval::tasks;
use osp::infer::{engine, DecodeParams, InferConfig, InferModel};
use osp::model::ops;
use osp::quant::rtn;
use osp::tensor::intkern;
use osp::tensor::linalg;
use osp::tensor::par;
use osp::tensor::Tensor;
use osp::util::json::Json;
use osp::util::rng::Pcg;

fn randn(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Pcg::new(seed, 8);
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

fn gflops(n: usize, secs: f64) -> String {
    format!("{:.2} GFLOP/s", 2.0 * (n as f64).powi(3) / secs / 1e9)
}

/// Packed-vs-dense matvec at the weight shapes PTQ actually emits, plus
/// LUT-vs-legacy kernel rows (the tiled microkernels of DESIGN.md §10
/// against the pre-LUT per-element `decode()` kernels kept as
/// `qmatvec_scalar`/`qmatmul_scalar`): table rows + one JSON record per
/// (op, size, bits) for `osp bench-diff` trending.
fn bench_quant(table: &mut Table, nw: usize) -> Vec<Json> {
    let mut records = Vec::new();
    for n in [512usize, 1024] {
        let w = randn(&[n, n], 6);
        let x: Vec<f32> = randn(&[n], 7).into_data();
        let iters = if n >= 1024 { 20 } else { 50 };
        for bits in [4u32, 8] {
            let q = rtn::quantize_per_channel_q(&w, bits);
            let dq = q.dequantize();
            let td = bench(2, iters, || {
                std::hint::black_box(par::matvec_with(None, &dq, &x));
            });
            let tq = bench(2, iters, || {
                std::hint::black_box(q.qmatvec_with(None, &x));
            });
            let tqp = bench(2, iters, || {
                std::hint::black_box(q.qmatvec_with(par::shared_pool(), &x));
            });
            let ts = bench(2, iters, || {
                std::hint::black_box(q.qmatvec_scalar(&x));
            });
            let dense_bpp = 4.0;
            let packed_bpp = q.packed_bytes() as f64 / q.numel() as f64;
            table.row(vec!["matvec dense f32".into(), format!("{n}x{n}"),
                           format!("{:.3}", td.mean_secs * 1e3),
                           format!("{dense_bpp:.2} B/param")]);
            table.row(vec![format!("qmatvec w{bits} lut"),
                           format!("{n}x{n}"),
                           format!("{:.3}", tq.mean_secs * 1e3),
                           format!("{packed_bpp:.2} B/param")]);
            table.row(vec![format!("qmatvec w{bits} scalar(legacy)"),
                           format!("{n}x{n}"),
                           format!("{:.3}", ts.mean_secs * 1e3),
                           format!("{:.2}x vs lut",
                                   ts.mean_secs / tq.mean_secs.max(1e-12))]);
            table.row(vec![format!("qmatvec w{bits} par({nw})"),
                           format!("{n}x{n}"),
                           format!("{:.3}", tqp.mean_secs * 1e3),
                           format!("{packed_bpp:.2} B/param")]);
            records.push(Json::obj(vec![
                ("op", Json::str("matvec")),
                ("size", Json::num(n as f64)),
                ("w_bits", Json::num(bits as f64)),
                ("dense_ns_op", Json::num(td.mean_secs * 1e9)),
                ("packed_ns_op", Json::num(tq.mean_secs * 1e9)),
                ("packed_par_ns_op", Json::num(tqp.mean_secs * 1e9)),
                ("scalar_ns_op", Json::num(ts.mean_secs * 1e9)),
                ("dense_bytes_per_param", Json::num(dense_bpp)),
                ("packed_bytes_per_param", Json::num(packed_bpp)),
            ]));

            // qmatmul at a decode-ish [n, n] @ [n, 32] shape: the tiled
            // LUT kernel vs the legacy per-element kernel.
            let b = randn(&[n, 32], 9 + n as u64);
            let miters = if n >= 1024 { 5 } else { 20 };
            let tml = bench(1, miters, || {
                std::hint::black_box(q.qmatmul_with(None, &b));
            });
            let tms = bench(1, miters, || {
                std::hint::black_box(q.qmatmul_scalar(&b));
            });
            table.row(vec![format!("qmatmul w{bits} lut"),
                           format!("{n}x{n}x32"),
                           format!("{:.3}", tml.mean_secs * 1e3),
                           format!("{packed_bpp:.2} B/param")]);
            table.row(vec![format!("qmatmul w{bits} scalar(legacy)"),
                           format!("{n}x{n}x32"),
                           format!("{:.3}", tms.mean_secs * 1e3),
                           format!("{:.2}x vs lut",
                                   tms.mean_secs
                                   / tml.mean_secs.max(1e-12))]);
            records.push(Json::obj(vec![
                ("op", Json::str("matmul")),
                ("size", Json::num(n as f64)),
                ("w_bits", Json::num(bits as f64)),
                ("packed_ns_op", Json::num(tml.mean_secs * 1e9)),
                ("scalar_ns_op", Json::num(tms.mean_secs * 1e9)),
            ]));

            // rhs-orientation integer kernels (DESIGN.md §11): the A4
            // activation tap emits i8 codes + one scale per row, and
            // the packed linear accumulates i8*i8 -> i32 instead of
            // dequantizing weights to f32. `int_ns_op` is the detected
            // SIMD backend, `int_scalar_ns_op` the scalar integer
            // oracle, both against the f32 LUT kernel consuming the
            // tap's bit-identical write-back.
            let be = intkern::active();
            for (op, m) in [("matvec_rhs", 1usize), ("matmul_rhs", 8)] {
                let mut a = randn(&[m, n], 11 + (m * n) as u64);
                let acts = ops::quant_rows_i8(a.data_mut(), n, 7.0);
                let riters = if m > 1 { iters / 2 } else { iters }.max(3);
                let tf = bench(1, riters, || {
                    std::hint::black_box(q.qmatmul_rhs_with(None, &a));
                });
                let ti = bench(1, riters, || {
                    std::hint::black_box(
                        q.qmatmul_rhs_int_with(None, &acts, be));
                });
                let tis = bench(1, riters, || {
                    std::hint::black_box(q.qmatmul_rhs_int_with(
                        None, &acts, intkern::Backend::Scalar));
                });
                let shape = format!("{m}x{n}x{n}");
                table.row(vec![format!("{op} w{bits} f32 lut"),
                               shape.clone(),
                               format!("{:.3}", tf.mean_secs * 1e3),
                               format!("{packed_bpp:.2} B/param")]);
                table.row(vec![format!("{op} w{bits} int {}",
                                       be.label()),
                               shape.clone(),
                               format!("{:.3}", ti.mean_secs * 1e3),
                               format!("{:.2}x vs f32",
                                       tf.mean_secs
                                       / ti.mean_secs.max(1e-12))]);
                table.row(vec![format!("{op} w{bits} int scalar"),
                               shape,
                               format!("{:.3}", tis.mean_secs * 1e3),
                               format!("{:.2}x vs f32",
                                       tf.mean_secs
                                       / tis.mean_secs.max(1e-12))]);
                records.push(Json::obj(vec![
                    ("op", Json::str(op)),
                    ("size", Json::num(n as f64)),
                    ("w_bits", Json::num(bits as f64)),
                    ("a_bits", Json::num(4.0)),
                    ("batch", Json::num(m as f64)),
                    ("kernel", Json::str(be.label())),
                    ("packed_ns_op", Json::num(tf.mean_secs * 1e9)),
                    ("int_ns_op", Json::num(ti.mean_secs * 1e9)),
                    ("int_scalar_ns_op",
                     Json::num(tis.mean_secs * 1e9)),
                ]));
            }
        }
    }
    records
}

/// Decode throughput on a small synthetic model: dense-f32 weights vs
/// packed W4 (KV4), batch 8, on the shared pool. The packed row should
/// trend >= dense at this batch size — column-stripe decode amortizes
/// the in-register dequant across the batch while reading 1/8th the
/// weight bytes.
fn bench_decode(table: &mut Table, nw: usize) -> Vec<Json> {
    let cfg = InferConfig { vocab_size: 512, d_model: 128, n_layers: 2,
                            n_heads: 4, d_ff: 352, rope_theta: 10000.0,
                            norm_ss: true, embproj: false };
    let dense = InferModel::synthetic(&cfg, 17);
    let g = Grammar::new(cfg.vocab_size, LANGUAGE_SEED);
    let (batch, prompt_len, max_new) = (8usize, 4usize, 12usize);
    let prompts = tasks::grammar_prompts(&g, batch, prompt_len, 1);
    let pool = par::shared_pool();
    let tokens = (batch * (prompt_len + max_new - 1)) as f64;
    let mut records = Vec::new();
    for (label, w_bits, a, kv) in [("dense f32", 16u32, 16u32, 16u32),
                                   ("packed w4/kv4", 4, 4, 4)] {
        let model = dense.quantized(w_bits);
        let params = DecodeParams::greedy(a, kv, batch);
        let t = bench(1, 3, || {
            std::hint::black_box(
                engine::generate(&model, &prompts, max_new, params, pool)
                    .expect("decode"));
        });
        let tps = tokens / t.mean_secs;
        table.row(vec![format!("decode {label}"),
                       format!("b{batch} d{} L{}", cfg.d_model,
                               cfg.n_layers),
                       format!("{:.2}", t.mean_secs * 1e3),
                       format!("{tps:.0} tok/s par({nw})")]);
        records.push(Json::obj(vec![
            ("op", Json::str("decode")),
            ("w_bits", Json::num(w_bits as f64)),
            ("kv_bits", Json::num(kv as f64)),
            ("batch", Json::num(batch as f64)),
            ("tokens_per_sec", Json::num(tps)),
            ("weight_bytes", Json::num(model.weight_bytes() as f64)),
        ]));
    }
    records
}

fn main() -> anyhow::Result<()> {
    let json_mode = std::env::args().any(|a| a == "--json");
    let nw = par::configured_threads();
    let mut table = Table::new(
        &format!("L3 microbenchmarks (OSP_THREADS={nw})"),
        &["op", "size", "mean (ms)", "throughput"]);

    if json_mode {
        // CI path: the quant section plus decode-throughput rows,
        // serialized for trending.
        let mut records = bench_quant(&mut table, nw);
        records.extend(bench_decode(&mut table, nw));
        let doc = Json::obj(vec![
            ("bench", Json::str("quant")),
            ("threads", Json::num(nw as f64)),
            ("rows", Json::Arr(records)),
        ]);
        std::fs::write("BENCH_quant.json", doc.dump())?;
        table.print();
        println!("wrote BENCH_quant.json");
        return Ok(());
    }

    // Matmul: serial baseline vs shared-pool dispatch at the sizes the
    // Muon outer loop and rotations actually see.
    for n in [256usize, 512, 1024] {
        let a = randn(&[n, n], 1);
        let b = randn(&[n, n], 2);
        let iters = if n >= 1024 { 3 } else { 10 };
        let t = bench(1, iters, || {
            std::hint::black_box(par::matmul_with(None, &a, &b));
        });
        table.row(vec!["matmul serial".into(), format!("{n}x{n}"),
                       format!("{:.2}", t.mean_secs * 1e3),
                       gflops(n, t.mean_secs)]);
        let t = bench(1, iters, || {
            std::hint::black_box(
                par::matmul_with(par::shared_pool(), &a, &b));
        });
        table.row(vec![format!("matmul par({nw})"), format!("{n}x{n}"),
                       format!("{:.2}", t.mean_secs * 1e3),
                       gflops(n, t.mean_secs)]);
    }

    // Newton-Schulz: the disaggregated-Muon hot loop. The public entry
    // point dispatches through the shared pool, so OSP_THREADS governs
    // it directly (run with OSP_THREADS=1 for the serial baseline).
    for (n, steps, iters) in [(256usize, 5usize, 5usize), (512, 5, 3),
                              (1024, 2, 1)] {
        let g = randn(&[n, n], 3);
        let label = format!("newton_schulz({steps})");
        let t = bench(if iters > 1 { 1 } else { 0 }, iters, || {
            std::hint::black_box(linalg::ns_orthogonalize(&g, steps));
        });
        table.row(vec![label, format!("{n}x{n} par({nw})"),
                       format!("{:.2}", t.mean_secs * 1e3),
                       format!("{:.1} mat/s", t.per_sec())]);
    }

    let w = randn(&[512, 512], 4);
    let t = bench(1, 10, || {
        std::hint::black_box(rtn::quantize_per_channel(&w, 4));
    });
    table.row(vec!["rtn_per_channel".into(), "512x512".into(),
                   format!("{:.2}", t.mean_secs * 1e3),
                   format!("{:.1} Melem/s",
                           w.len() as f64 / t.mean_secs / 1e6)]);

    let t = bench(1, 10, || {
        std::hint::black_box(rtn::quantize_per_channel_q(&w, 4));
    });
    table.row(vec!["rtn_emit_codes".into(), "512x512".into(),
                   format!("{:.2}", t.mean_secs * 1e3),
                   format!("{:.1} Melem/s",
                           w.len() as f64 / t.mean_secs / 1e6)]);

    bench_quant(&mut table, nw);
    bench_decode(&mut table, nw);

    let x = randn(&[512, 512], 5);
    let t = bench(1, 10, || {
        std::hint::black_box(linalg::hadamard_rows(&x));
    });
    table.row(vec!["hadamard_rows".into(), "512x512".into(),
                   format!("{:.2}", t.mean_secs * 1e3),
                   format!("{:.1} Melem/s",
                           x.len() as f64 / t.mean_secs / 1e6)]);

    for k in [2usize, 4, 8] {
        let n = 1 << 18;
        let t = bench(1, 5, || {
            let parts: Vec<Vec<f32>> =
                (0..k).map(|i| vec![i as f32; n]).collect();
            std::hint::black_box(ring_all_reduce(parts));
        });
        table.row(vec![format!("ring_all_reduce(k={k})"),
                       format!("{n} f32"),
                       format!("{:.2}", t.mean_secs * 1e3),
                       format!("{:.1} MB/s",
                               (k * n * 4) as f64 / t.mean_secs / 1e6)]);
    }

    let t = bench(1, 5, || {
        let mut s = TokenStream::new(512, 1, Split::Train, 0, 1);
        for i in 0..20 {
            std::hint::black_box(s.next_batch(8, 128, i));
        }
    });
    table.row(vec!["data 20 batches".into(), "8x128".into(),
                   format!("{:.2}", t.mean_secs * 1e3),
                   format!("{:.2} Mtok/s",
                           20.0 * 8.0 * 128.0 / t.mean_secs / 1e6)]);

    table.print();
    Ok(())
}
