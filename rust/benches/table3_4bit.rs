//! Table 3 — per-task benchmark scores at 4-4-4 across the trained
//! configurations (the paper's open-source-comparator table; our ablation
//! runs stand in for the Adam-lineage models — DESIGN.md §2).

use osp::repro::{self, Effort};
use osp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::var("OSP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    let runs = std::path::PathBuf::from(
        std::env::var("OSP_RUNS").unwrap_or_else(|_| "runs".into()));
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP table3: no artifacts");
        return Ok(());
    }
    let engine = Engine::open(&dir)?;
    match repro::table3(&engine, &runs, Effort::QUICK) {
        Ok(t) => t.print(),
        Err(e) => eprintln!("SKIP table3: {e}"),
    }
    match repro::table5(&engine, &runs, Effort::QUICK) {
        Ok(t) => t.print(),
        Err(e) => eprintln!("SKIP table5: {e}"),
    }
    Ok(())
}
