//! Figure 1 — fp16 vs 4-bit score per saved checkpoint: Adam checkpoints
//! collapse off the diagonal; OSP checkpoints stay near it.

use osp::repro::{self, Effort};
use osp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::var("OSP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    let runs = std::path::PathBuf::from(
        std::env::var("OSP_RUNS").unwrap_or_else(|_| "runs".into()));
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP fig1: no artifacts");
        return Ok(());
    }
    let engine = Engine::open(&dir)?;
    match repro::fig1(&engine, &runs, Effort::QUICK) {
        Ok(t) => t.print(),
        Err(e) => eprintln!("SKIP fig1: {e}"),
    }
    Ok(())
}
