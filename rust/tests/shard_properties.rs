//! Row-parallel sharded serving contract (DESIGN.md §14, ISSUE 9
//! acceptance): a sharded decode — trunk matmuls split across worker
//! shards, col stripes concatenated and row partials summed in i32 on
//! the coordinator — produces token streams bit-identical to the
//! single-process integer path for any shard count. Pinned here as a
//! property over the {1, 2, 4} shards x W{4,8} x KV{4,16} matrix via
//! in-process [`LocalShards`], and end-to-end over HTTP with real
//! worker processes that fetch their artifacts (checksummed, chunked)
//! from the coordinator's `/shards` endpoints.
//!
//! All servers bind 127.0.0.1:0 (ephemeral ports), so the suite can
//! run in parallel with itself and with CI neighbors.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use osp::coordinator::shard::write_shards;
use osp::infer::{engine as decode, DecodeParams, InferConfig,
                 InferModel};
use osp::model::remote::LocalShards;
use osp::serve::http::ClientConn;
use osp::serve::load;
use osp::serve::worker::{ShardSource, WorkerOpts, WorkerServer};
use osp::serve::{ServeOpts, Server};
use osp::tensor::intkern::{Backend, IntMode};
use osp::tensor::par;
use osp::util::json::Json;

fn tiny_cfg() -> InferConfig {
    InferConfig { vocab_size: 96, d_model: 32, n_layers: 2, n_heads: 2,
                  d_ff: 40, rope_theta: 10000.0, norm_ss: true,
                  embproj: false }
}

/// One well-behaved streamed /generate exchange: returns the token
/// stream when the request completes.
fn gen_stream(addr: &str, prompt: &[i32], max_new: usize)
              -> Result<(u16, Vec<i64>, Option<String>), String> {
    let stream =
        TcpStream::connect(addr).map_err(|e| e.to_string())?;
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .map_err(|e| e.to_string())?;
    stream.set_nodelay(true).ok();
    let mut conn = ClientConn::new(stream);
    let body = format!(
        "{{\"prompt\":{prompt:?},\"max_new\":{max_new},\
         \"timeout_ms\":30000}}");
    conn.send_request("POST", "/generate", &body)
        .map_err(|e| e.to_string())?;
    let (status, _headers) =
        conn.read_head().map_err(|e| e.to_string())?;
    let mut tokens = Vec::new();
    let mut terminal = None;
    if status != 200 {
        return Ok((status, tokens, terminal));
    }
    loop {
        let Some(line) =
            conn.next_chunk().map_err(|e| e.to_string())?
        else {
            return Ok((status, tokens, terminal));
        };
        let ev = Json::parse(line.trim()).map_err(|e| {
            format!("bad event '{line}': {e}")
        })?;
        if let Some(t) = ev.get("token").and_then(|v| v.as_f64()) {
            tokens.push(t as i64);
        } else if ev.get("done").is_some() {
            terminal = Some("done".into());
        } else if let Some(e) =
            ev.get("error").and_then(|v| v.as_str())
        {
            terminal = Some(e.to_string());
        }
    }
}

/// The standing invariant, as a matrix: sharded decode streams are
/// bit-identical to the single-process scalar-integer streams for
/// shard counts {1, 2, 4} at W{4,8} x KV{4,16} (A4 throughout — the
/// sharded path requires the integer kernels, DESIGN.md §14).
#[test]
fn sharded_streams_bit_identical_across_matrix() {
    let cfg = tiny_cfg();
    let dense = InferModel::synthetic(&cfg, 29);
    let prompts: Vec<Vec<i32>> =
        (0..3).map(|i| vec![2 + i, 5, 7 + i, 11]).collect();
    let pool = par::shared_pool();
    for &w in &[4u32, 8] {
        for &kv in &[4u32, 16] {
            let params = DecodeParams::greedy(4, kv, prompts.len());
            let mut local = dense.quantized(w);
            local.set_int_mode(IntMode::Scalar);
            let want = decode::generate(&local, &prompts, 10, params,
                                        pool)
                .expect("local decode");
            for &s in &[1usize, 2, 4] {
                let mut m = dense.quantized(w);
                m.set_int_mode(IntMode::Scalar);
                let sets = m.extract_shard_sets(s)
                    .expect("extract shard sets");
                m.shard_remote(Arc::new(LocalShards::new(
                    sets, Backend::Scalar)))
                    .expect("shard_remote");
                assert_eq!(m.remote_workers(), s);
                let got = decode::generate(&m, &prompts, 10, params,
                                           pool)
                    .expect("sharded decode");
                assert_eq!(
                    got, want,
                    "streams diverged at shards={s} W{w} KV{kv}");
            }
        }
    }
}

/// End-to-end over HTTP: `osp shard` artifacts on disk, two worker
/// servers that fetch them (checksummed, chunked, resumable) from the
/// coordinator's `/shards` endpoints, a coordinator routing trunk
/// matmuls to the fleet — token streams bit-identical to a
/// single-process server over the same model, per-worker gauges live
/// on `/status`, rpc counters conserved, and a coordinator drain
/// propagates to the fleet with zero stripes in flight.
#[test]
fn http_sharded_serve_streams_match_single_process() {
    let cfg = tiny_cfg();
    let dir = std::env::temp_dir().join("osp_shard_props_e2e");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir");

    let published = InferModel::synthetic(&cfg, 53).quantized(4);
    write_shards(&published, 2, "ssnorm_plain", &dir)
        .expect("write shards");

    // Reserve two ephemeral worker ports, then release them: the
    // coordinator needs the fleet's addresses at spawn, while the
    // workers need the coordinator's address to fetch from. (Both
    // listeners are held until the addresses are read so the two
    // reservations cannot collide.)
    let l0 = TcpListener::bind("127.0.0.1:0").expect("reserve 0");
    let l1 = TcpListener::bind("127.0.0.1:0").expect("reserve 1");
    let wa0 = l0.local_addr().expect("addr 0").to_string();
    let wa1 = l1.local_addr().expect("addr 1").to_string();
    drop(l0);
    drop(l1);

    let mut cm = InferModel::synthetic(&cfg, 53).quantized(4);
    cm.set_int_mode(IntMode::Scalar);
    let server = Server::spawn(cm, ServeOpts {
        addr: "127.0.0.1:0".into(),
        workers: vec![wa0.clone(), wa1.clone()],
        shard_dir: dir.to_string_lossy().into_owned(),
        ..ServeOpts::default()
    })
    .expect("spawn coordinator");
    let addr = server.addr().to_string();

    let spawn_worker = |shard: usize, waddr: &str| {
        WorkerServer::spawn(WorkerOpts {
            addr: waddr.into(),
            n_shards: 2,
            int_mode: IntMode::Scalar,
            ..WorkerOpts::new("", shard, ShardSource::Fetch {
                coordinator: addr.clone(),
                spool: dir.join(format!("spool_{shard}.part")),
                byte_budget: None,
            })
        })
        .expect("spawn worker")
    };
    let w0 = spawn_worker(0, &wa0);
    let w1 = spawn_worker(1, &wa1);

    // The coordinator's /healthz flips ready once every worker has
    // fetched, verified, and published its shard.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (st, h) =
            load::http_get(&addr, "/healthz").expect("healthz");
        assert_eq!(st, 200);
        if h.get("ready").and_then(|v| v.as_bool()) == Some(true) {
            break;
        }
        assert!(Instant::now() < deadline,
                "fleet never became ready: {} (w0 err {:?}, w1 err \
                 {:?})",
                h.dump(), w0.load_error(), w1.load_error());
        thread::sleep(Duration::from_millis(50));
    }

    let probes: Vec<Vec<i32>> =
        (0..4).map(|i| vec![1 + i, 2 + i, 3, 5]).collect();

    // Single-process baseline over the identical model, on the same
    // scalar-integer path the sharded trunk recombines bitwise.
    let baseline: Vec<Vec<i64>> = {
        let mut bm = InferModel::synthetic(&cfg, 53).quantized(4);
        bm.set_int_mode(IntMode::Scalar);
        let bs = Server::spawn(bm, ServeOpts {
            addr: "127.0.0.1:0".into(),
            ..ServeOpts::default()
        })
        .expect("baseline server");
        let baddr = bs.addr().to_string();
        let streams = probes
            .iter()
            .map(|p| {
                let (st, tokens, term) =
                    gen_stream(&baddr, p, 8).expect("probe");
                assert_eq!(st, 200);
                assert_eq!(term.as_deref(), Some("done"));
                tokens
            })
            .collect();
        bs.drain();
        bs.join();
        streams
    };

    let got: Vec<Vec<i64>> = probes
        .iter()
        .map(|p| {
            let (st, tokens, term) =
                gen_stream(&addr, p, 8).expect("sharded probe");
            assert_eq!(st, 200);
            assert_eq!(term.as_deref(), Some("done"));
            tokens
        })
        .collect();
    assert_eq!(got, baseline,
               "sharded streams diverged from single-process");

    // Per-worker gauges on /status, the ISSUE 9 memory contract, and
    // rpc conservation: every pool-side success was served by exactly
    // one worker.
    let (st, status) =
        load::http_get(&addr, "/status").expect("status");
    assert_eq!(st, 200);
    let f = |k: &str| status.get(k).and_then(|v| v.as_f64());
    assert_eq!(f("workers"), Some(2.0), "{}", status.dump());
    assert_eq!(f("shards"), Some(2.0), "{}", status.dump());
    let full = f("weight_bytes_full").expect("weight_bytes_full");
    assert_eq!(full, published.weight_bytes() as f64);
    let coord = f("weight_bytes_coord").expect("weight_bytes_coord");
    assert!(coord < full,
            "sharding freed no coordinator weight bytes: {coord} vs \
             {full}");
    let ws = status
        .get("worker_status")
        .and_then(|v| v.as_arr())
        .expect("worker_status")
        .clone();
    assert_eq!(ws.len(), 2);
    let mut served_sum = 0.0;
    let mut max_wb: f64 = 0.0;
    for w in &ws {
        let wf = |k: &str| {
            w.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
        };
        assert_eq!(w.get("ready").and_then(|v| v.as_bool()),
                   Some(true), "{}", w.dump());
        assert!(wf("bytes_fetched") > 0.0,
                "worker fetched nothing: {}", w.dump());
        assert_eq!(wf("chunks_done"), wf("chunks_total"), "{}",
                   w.dump());
        served_sum += wf("rpcs_served");
        max_wb = max_wb.max(wf("weight_bytes"));
    }
    // Each worker holds at most ~55% of the full model's weight
    // bytes at 2 shards (the trunk halves; dense embed/norms stay
    // coordinator-side and are not duplicated onto workers).
    assert!(max_wb > 0.0 && max_wb <= 0.55 * full,
            "per-worker peak {max_wb} vs full model {full}");
    let pool_ok = status
        .get("shard_pool")
        .and_then(|p| p.get("rpcs_ok"))
        .and_then(|v| v.as_f64())
        .expect("shard_pool.rpcs_ok");
    assert!(pool_ok > 0.0, "{}", status.dump());
    assert_eq!(pool_ok, served_sum,
               "rpc conservation violated: {}", status.dump());

    // Drain the coordinator; it propagates the drain to the fleet.
    let (st, _) =
        load::http_post(&addr, "/admin/drain", "").expect("drain");
    assert_eq!(st, 200);
    server.join();
    let wait_done = |w: &WorkerServer, tag: &str| {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !w.is_done() {
            assert!(Instant::now() < deadline,
                    "{tag} never saw the propagated drain");
            thread::sleep(Duration::from_millis(20));
        }
    };
    wait_done(&w0, "worker 0");
    wait_done(&w1, "worker 1");
    assert_eq!(w0.load_error(), None);
    assert_eq!(w1.load_error(), None);
    w0.join();
    w1.join();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Spawn-time validation: a fleet whose size disagrees with the shard
/// cut is rejected, and so is the f32 path (partial f32 sums cannot
/// recombine bit-exactly — the invariant demands integer kernels).
#[test]
fn coordinator_spawn_validates_fleet_and_kernel_path() {
    let cfg = tiny_cfg();
    let dir = std::env::temp_dir().join("osp_shard_props_reject");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir");
    let model = InferModel::synthetic(&cfg, 7).quantized(4);
    write_shards(&model, 2, "ssnorm_plain", &dir).expect("shards");
    let sopts = |workers: Vec<String>| ServeOpts {
        addr: "127.0.0.1:0".into(),
        workers,
        shard_dir: dir.to_string_lossy().into_owned(),
        ..ServeOpts::default()
    };

    // Fleet size must match what the shard dir was cut for.
    let mut m = InferModel::synthetic(&cfg, 7).quantized(4);
    m.set_int_mode(IntMode::Scalar);
    let err = Server::spawn(m, sopts(vec!["127.0.0.1:1".into()]))
        .err()
        .expect("mismatched fleet accepted");
    assert!(format!("{err:#}").contains("workers"), "{err:#}");

    // Integer kernels are mandatory for sharded serving.
    let mut m = InferModel::synthetic(&cfg, 7).quantized(4);
    m.set_int_mode(IntMode::Off);
    let err = Server::spawn(
        m, sopts(vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()]))
        .err()
        .expect("f32 sharded serve accepted");
    assert!(format!("{err:#}").contains("integer"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}
