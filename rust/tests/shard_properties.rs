//! Row-parallel sharded serving contract (DESIGN.md §14, ISSUE 9
//! acceptance): a sharded decode — trunk matmuls split across worker
//! shards, col stripes concatenated and row partials summed in i32 on
//! the coordinator — produces token streams bit-identical to the
//! single-process integer path for any shard count. Pinned here as a
//! property over the {1, 2, 4} shards x W{4,8} x KV{4,16} matrix via
//! in-process [`LocalShards`], and end-to-end over HTTP with real
//! worker processes that fetch their artifacts (checksummed, chunked)
//! from the coordinator's `/shards` endpoints.
//!
//! The fault-tolerance half (DESIGN.md §15): with `--replicas 2`,
//! killing one worker mid-decode reroutes its stripes to the live
//! replica with the surviving stream byte-identical (integer partials
//! are replica-invariant), losing every replica of a shard degrades
//! to retryable 503s, and a restarted worker rejoins through the
//! resumable fetch path without a coordinator restart.
//!
//! All servers bind 127.0.0.1:0 (ephemeral ports), so the suite can
//! run in parallel with itself and with CI neighbors.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use osp::coordinator::shard::write_shards;
use osp::infer::{engine as decode, DecodeParams, InferConfig,
                 InferModel};
use osp::model::remote::LocalShards;
use osp::serve::http::ClientConn;
use osp::serve::load;
use osp::serve::worker::{ShardSource, WorkerOpts, WorkerServer};
use osp::serve::{ServeOpts, Server};
use osp::tensor::intkern::{Backend, IntMode};
use osp::tensor::par;
use osp::util::json::Json;

fn tiny_cfg() -> InferConfig {
    InferConfig { vocab_size: 96, d_model: 32, n_layers: 2, n_heads: 2,
                  d_ff: 40, rope_theta: 10000.0, norm_ss: true,
                  embproj: false }
}

/// One well-behaved streamed /generate exchange: returns the token
/// stream when the request completes.
fn gen_stream(addr: &str, prompt: &[i32], max_new: usize)
              -> Result<(u16, Vec<i64>, Option<String>), String> {
    let stream =
        TcpStream::connect(addr).map_err(|e| e.to_string())?;
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .map_err(|e| e.to_string())?;
    stream.set_nodelay(true).ok();
    let mut conn = ClientConn::new(stream);
    let body = format!(
        "{{\"prompt\":{prompt:?},\"max_new\":{max_new},\
         \"timeout_ms\":30000}}");
    conn.send_request("POST", "/generate", &body)
        .map_err(|e| e.to_string())?;
    let (status, _headers) =
        conn.read_head().map_err(|e| e.to_string())?;
    let mut tokens = Vec::new();
    let mut terminal = None;
    if status != 200 {
        return Ok((status, tokens, terminal));
    }
    loop {
        let Some(line) =
            conn.next_chunk().map_err(|e| e.to_string())?
        else {
            return Ok((status, tokens, terminal));
        };
        let ev = Json::parse(line.trim()).map_err(|e| {
            format!("bad event '{line}': {e}")
        })?;
        if let Some(t) = ev.get("token").and_then(|v| v.as_f64()) {
            tokens.push(t as i64);
        } else if ev.get("done").is_some() {
            terminal = Some("done".into());
        } else if let Some(e) =
            ev.get("error").and_then(|v| v.as_str())
        {
            terminal = Some(e.to_string());
        }
    }
}

/// The standing invariant, as a matrix: sharded decode streams are
/// bit-identical to the single-process scalar-integer streams for
/// shard counts {1, 2, 4} at W{4,8} x KV{4,16} (A4 throughout — the
/// sharded path requires the integer kernels, DESIGN.md §14).
#[test]
fn sharded_streams_bit_identical_across_matrix() {
    let cfg = tiny_cfg();
    let dense = InferModel::synthetic(&cfg, 29);
    let prompts: Vec<Vec<i32>> =
        (0..3).map(|i| vec![2 + i, 5, 7 + i, 11]).collect();
    let pool = par::shared_pool();
    for &w in &[4u32, 8] {
        for &kv in &[4u32, 16] {
            let params = DecodeParams::greedy(4, kv, prompts.len());
            let mut local = dense.quantized(w);
            local.set_int_mode(IntMode::Scalar);
            let want = decode::generate(&local, &prompts, 10, params,
                                        pool)
                .expect("local decode");
            for &s in &[1usize, 2, 4] {
                let mut m = dense.quantized(w);
                m.set_int_mode(IntMode::Scalar);
                let sets = m.extract_shard_sets(s)
                    .expect("extract shard sets");
                m.shard_remote(Arc::new(LocalShards::new(
                    sets, Backend::Scalar)))
                    .expect("shard_remote");
                assert_eq!(m.remote_workers(), s);
                let got = decode::generate(&m, &prompts, 10, params,
                                           pool)
                    .expect("sharded decode");
                assert_eq!(
                    got, want,
                    "streams diverged at shards={s} W{w} KV{kv}");
            }
        }
    }
}

/// End-to-end over HTTP: `osp shard` artifacts on disk, two worker
/// servers that fetch them (checksummed, chunked, resumable) from the
/// coordinator's `/shards` endpoints, a coordinator routing trunk
/// matmuls to the fleet — token streams bit-identical to a
/// single-process server over the same model, per-worker gauges live
/// on `/status`, rpc counters conserved, and a coordinator drain
/// propagates to the fleet with zero stripes in flight.
#[test]
fn http_sharded_serve_streams_match_single_process() {
    let cfg = tiny_cfg();
    let dir = std::env::temp_dir().join("osp_shard_props_e2e");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir");

    let published = InferModel::synthetic(&cfg, 53).quantized(4);
    write_shards(&published, 2, "ssnorm_plain", &dir)
        .expect("write shards");

    // Reserve two ephemeral worker ports, then release them: the
    // coordinator needs the fleet's addresses at spawn, while the
    // workers need the coordinator's address to fetch from. (Both
    // listeners are held until the addresses are read so the two
    // reservations cannot collide.)
    let l0 = TcpListener::bind("127.0.0.1:0").expect("reserve 0");
    let l1 = TcpListener::bind("127.0.0.1:0").expect("reserve 1");
    let wa0 = l0.local_addr().expect("addr 0").to_string();
    let wa1 = l1.local_addr().expect("addr 1").to_string();
    drop(l0);
    drop(l1);

    let mut cm = InferModel::synthetic(&cfg, 53).quantized(4);
    cm.set_int_mode(IntMode::Scalar);
    let server = Server::spawn(cm, ServeOpts {
        addr: "127.0.0.1:0".into(),
        workers: vec![wa0.clone(), wa1.clone()],
        shard_dir: dir.to_string_lossy().into_owned(),
        ..ServeOpts::default()
    })
    .expect("spawn coordinator");
    let addr = server.addr().to_string();

    let spawn_worker = |shard: usize, waddr: &str| {
        WorkerServer::spawn(WorkerOpts {
            addr: waddr.into(),
            n_shards: 2,
            int_mode: IntMode::Scalar,
            ..WorkerOpts::new("", shard, ShardSource::Fetch {
                coordinator: addr.clone(),
                spool: dir.join(format!("spool_{shard}.part")),
                byte_budget: None,
            })
        })
        .expect("spawn worker")
    };
    let w0 = spawn_worker(0, &wa0);
    let w1 = spawn_worker(1, &wa1);

    // The coordinator's /healthz flips ready once every worker has
    // fetched, verified, and published its shard.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (st, h) =
            load::http_get(&addr, "/healthz").expect("healthz");
        assert_eq!(st, 200);
        if h.get("ready").and_then(|v| v.as_bool()) == Some(true) {
            break;
        }
        assert!(Instant::now() < deadline,
                "fleet never became ready: {} (w0 err {:?}, w1 err \
                 {:?})",
                h.dump(), w0.load_error(), w1.load_error());
        thread::sleep(Duration::from_millis(50));
    }

    let probes: Vec<Vec<i32>> =
        (0..4).map(|i| vec![1 + i, 2 + i, 3, 5]).collect();

    // Single-process baseline over the identical model, on the same
    // scalar-integer path the sharded trunk recombines bitwise.
    let baseline: Vec<Vec<i64>> = {
        let mut bm = InferModel::synthetic(&cfg, 53).quantized(4);
        bm.set_int_mode(IntMode::Scalar);
        let bs = Server::spawn(bm, ServeOpts {
            addr: "127.0.0.1:0".into(),
            ..ServeOpts::default()
        })
        .expect("baseline server");
        let baddr = bs.addr().to_string();
        let streams = probes
            .iter()
            .map(|p| {
                let (st, tokens, term) =
                    gen_stream(&baddr, p, 8).expect("probe");
                assert_eq!(st, 200);
                assert_eq!(term.as_deref(), Some("done"));
                tokens
            })
            .collect();
        bs.drain();
        bs.join();
        streams
    };

    let got: Vec<Vec<i64>> = probes
        .iter()
        .map(|p| {
            let (st, tokens, term) =
                gen_stream(&addr, p, 8).expect("sharded probe");
            assert_eq!(st, 200);
            assert_eq!(term.as_deref(), Some("done"));
            tokens
        })
        .collect();
    assert_eq!(got, baseline,
               "sharded streams diverged from single-process");

    // Per-worker gauges on /status, the ISSUE 9 memory contract, and
    // rpc conservation: every pool-side success was served by exactly
    // one worker.
    let (st, status) =
        load::http_get(&addr, "/status").expect("status");
    assert_eq!(st, 200);
    let f = |k: &str| status.get(k).and_then(|v| v.as_f64());
    assert_eq!(f("workers"), Some(2.0), "{}", status.dump());
    assert_eq!(f("shards"), Some(2.0), "{}", status.dump());
    let full = f("weight_bytes_full").expect("weight_bytes_full");
    assert_eq!(full, published.weight_bytes() as f64);
    let coord = f("weight_bytes_coord").expect("weight_bytes_coord");
    assert!(coord < full,
            "sharding freed no coordinator weight bytes: {coord} vs \
             {full}");
    let ws = status
        .get("worker_status")
        .and_then(|v| v.as_arr())
        .expect("worker_status")
        .clone();
    assert_eq!(ws.len(), 2);
    let mut served_sum = 0.0;
    let mut max_wb: f64 = 0.0;
    for w in &ws {
        let wf = |k: &str| {
            w.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
        };
        assert_eq!(w.get("ready").and_then(|v| v.as_bool()),
                   Some(true), "{}", w.dump());
        assert!(wf("bytes_fetched") > 0.0,
                "worker fetched nothing: {}", w.dump());
        assert_eq!(wf("chunks_done"), wf("chunks_total"), "{}",
                   w.dump());
        served_sum += wf("rpcs_served");
        max_wb = max_wb.max(wf("weight_bytes"));
    }
    // Each worker holds at most ~55% of the full model's weight
    // bytes at 2 shards (the trunk halves; dense embed/norms stay
    // coordinator-side and are not duplicated onto workers).
    assert!(max_wb > 0.0 && max_wb <= 0.55 * full,
            "per-worker peak {max_wb} vs full model {full}");
    let pool_ok = status
        .get("shard_pool")
        .and_then(|p| p.get("rpcs_ok"))
        .and_then(|v| v.as_f64())
        .expect("shard_pool.rpcs_ok");
    assert!(pool_ok > 0.0, "{}", status.dump());
    assert_eq!(pool_ok, served_sum,
               "rpc conservation violated: {}", status.dump());

    // Drain the coordinator; it propagates the drain to the fleet.
    let (st, _) =
        load::http_post(&addr, "/admin/drain", "").expect("drain");
    assert_eq!(st, 200);
    server.join();
    let wait_done = |w: &WorkerServer, tag: &str| {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !w.is_done() {
            assert!(Instant::now() < deadline,
                    "{tag} never saw the propagated drain");
            thread::sleep(Duration::from_millis(20));
        }
    };
    wait_done(&w0, "worker 0");
    wait_done(&w1, "worker 1");
    assert_eq!(w0.load_error(), None);
    assert_eq!(w1.load_error(), None);
    w0.join();
    w1.join();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The §15 fault-tolerance contract, end to end: a three-worker fleet
/// at `--replicas 2` (worker w serves shard w % 2, so shard 0 has two
/// replicas) survives losing shard 0's primary mid-decode with the
/// survivor's stream byte-identical to a single-process run; losing
/// the last shard-0 replica degrades to retryable 503s naming the
/// shard; and restarting the dead primary on its old port rejoins
/// through the resumable fetch path and reopens the gate — all
/// without touching the coordinator. RPC conservation is checked
/// across the whole incident via pre/post-kill counter snapshots.
#[test]
fn failover_rejoin_and_degradation_with_replicas() {
    let cfg = tiny_cfg();
    let dir = std::env::temp_dir().join("osp_shard_props_failover");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir");

    let published = InferModel::synthetic(&cfg, 61).quantized(4);
    write_shards(&published, 2, "ssnorm_plain", &dir)
        .expect("write shards");

    // Reserve three worker ports (the same bind-then-drop dance as
    // above); worker 2 is shard 0's replica.
    let ls: Vec<TcpListener> = (0..3)
        .map(|i| {
            TcpListener::bind("127.0.0.1:0")
                .unwrap_or_else(|e| panic!("reserve {i}: {e}"))
        })
        .collect();
    let was: Vec<String> = ls
        .iter()
        .map(|l| l.local_addr().expect("reserved addr").to_string())
        .collect();
    drop(ls);

    let mut cm = InferModel::synthetic(&cfg, 61).quantized(4);
    cm.set_int_mode(IntMode::Scalar);
    let server = Server::spawn(cm, ServeOpts {
        addr: "127.0.0.1:0".into(),
        workers: was.clone(),
        shard_dir: dir.to_string_lossy().into_owned(),
        replicas: 2,
        probe_interval_ms: 40,
        down_after: 2,
        ..ServeOpts::default()
    })
    .expect("spawn coordinator");
    let addr = server.addr().to_string();

    let spawn_worker = |w: usize| {
        WorkerServer::spawn(WorkerOpts {
            addr: was[w].clone(),
            n_shards: 2,
            int_mode: IntMode::Scalar,
            ..WorkerOpts::new("", w % 2, ShardSource::Fetch {
                coordinator: addr.clone(),
                // Spools are keyed by *worker*, not shard: workers 0
                // and 2 fetch shard 0 concurrently.
                spool: dir.join(format!("spool_w{w}.part")),
                byte_budget: None,
            })
        })
        .unwrap_or_else(|e| panic!("spawn worker {w}: {e:#}"))
    };
    let w0 = spawn_worker(0);
    let w1 = spawn_worker(1);
    let w2 = spawn_worker(2);

    let wait_ready = |want: bool, tag: &str| {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let (st, h) =
                load::http_get(&addr, "/healthz").expect("healthz");
            assert_eq!(st, 200);
            if h.get("ready").and_then(|v| v.as_bool()) == Some(want)
            {
                break;
            }
            assert!(Instant::now() < deadline,
                    "{tag}: ready never became {want}: {}", h.dump());
            thread::sleep(Duration::from_millis(25));
        }
    };
    wait_ready(true, "boot");

    // Fleet-health and per-worker rpc counters off /metrics (the
    // scrape-free document; /status adds the worker scrape).
    let fleet = |k: &str| -> f64 {
        let (st, s) =
            load::http_get(&addr, "/metrics").expect("metrics");
        assert_eq!(st, 200);
        s.get("fleet_health")
            .and_then(|f| f.get(k))
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| panic!("no fleet_health.{k}: {}",
                                      s.dump()))
    };
    let state_of = |w: usize| -> String {
        let (_, s) =
            load::http_get(&addr, "/metrics").expect("metrics");
        s.get("fleet_health")
            .and_then(|f| f.get("states"))
            .and_then(|v| v.as_arr())
            .and_then(|a| a.get(w))
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .unwrap_or_default()
    };
    let per_ok = || -> Vec<f64> {
        let (_, s) =
            load::http_get(&addr, "/metrics").expect("metrics");
        s.get("shard_pool")
            .and_then(|p| p.get("per_worker_rpcs_ok"))
            .and_then(|v| v.as_arr())
            .map(|a| {
                a.iter().map(|v| v.as_f64().unwrap_or(0.0)).collect()
            })
            .expect("per_worker_rpcs_ok")
    };

    let probes: Vec<Vec<i32>> =
        (0..3).map(|i| vec![3 + i, 1, 4 + i, 2]).collect();
    let max_news = [8usize, 48, 8];

    // Single-process baseline: the unperturbed streams every phase
    // below must reproduce bit-for-bit.
    let baseline: Vec<Vec<i64>> = {
        let mut bm = InferModel::synthetic(&cfg, 61).quantized(4);
        bm.set_int_mode(IntMode::Scalar);
        let bs = Server::spawn(bm, ServeOpts {
            addr: "127.0.0.1:0".into(),
            ..ServeOpts::default()
        })
        .expect("baseline server");
        let baddr = bs.addr().to_string();
        let streams = probes
            .iter()
            .zip(&max_news)
            .map(|(p, &n)| {
                let (st, tokens, term) =
                    gen_stream(&baddr, p, n).expect("baseline probe");
                assert_eq!(st, 200);
                assert_eq!(term.as_deref(), Some("done"));
                tokens
            })
            .collect();
        bs.drain();
        bs.join();
        streams
    };

    // Phase A — healthy fleet streams match the baseline.
    let (st, tokens, term) =
        gen_stream(&addr, &probes[0], max_news[0]).expect("healthy");
    assert_eq!((st, term.as_deref()), (200, Some("done")));
    assert_eq!(tokens, baseline[0], "healthy fleet diverged");
    let rejoins_before = fleet("rejoins");

    // Phase B — kill shard 0's primary mid-decode. The stream is
    // held open manually: the kill lands after the first token, with
    // dozens of shard-0 stripe RPCs still ahead of the sequence, so
    // the reroute to worker 2 is exercised while decoding.
    let stream =
        TcpStream::connect(&addr).expect("connect for failover");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .expect("read timeout");
    stream.set_nodelay(true).ok();
    let mut conn = ClientConn::new(stream);
    let body = format!(
        "{{\"prompt\":{:?},\"max_new\":{},\"timeout_ms\":30000}}",
        probes[1], max_news[1]);
    conn.send_request("POST", "/generate", &body).expect("send");
    let (st, _headers) = conn.read_head().expect("head");
    assert_eq!(st, 200);
    let first = conn
        .next_chunk()
        .expect("first chunk")
        .expect("stream closed before the first token");
    let ev = Json::parse(first.trim()).expect("first event");
    let mut tokens = vec![ev
        .get("token")
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("first event not a token: {first}"))
        as i64];
    // In-process SIGKILL stand-in: drain stops the accept loop and
    // `join` guarantees the listener is gone, so the next shard-0
    // RPC sees the refused connection a killed process would cause.
    w0.drain();
    w0.join();
    let mut term = None;
    loop {
        let Some(line) = conn.next_chunk().expect("chunk") else {
            break;
        };
        let ev = Json::parse(line.trim()).expect("event");
        if let Some(t) = ev.get("token").and_then(|v| v.as_f64()) {
            tokens.push(t as i64);
        } else if ev.get("done").is_some() {
            term = Some("done".to_string());
        } else if let Some(e) =
            ev.get("error").and_then(|v| v.as_str())
        {
            term = Some(e.to_string());
        }
    }
    assert_eq!(term.as_deref(), Some("done"),
               "stream did not survive the primary's death");
    assert_eq!(tokens, baseline[1],
               "failover perturbed the surviving stream");
    assert!(fleet("failovers") >= 1.0, "no failover recorded");
    let ok_dead0 = per_ok()[0];

    // The prober's breaker opens on the dead worker.
    let deadline = Instant::now() + Duration::from_secs(10);
    while state_of(0) != "down" {
        assert!(Instant::now() < deadline,
                "worker 0 never marked down (state {})", state_of(0));
        thread::sleep(Duration::from_millis(25));
    }
    assert!(fleet("breaker_trips") >= 1.0);

    // Phase C — lose the last shard-0 replica: the fleet degrades to
    // retryable 503s that name the uncovered shard, never panics,
    // never emits wrong tokens.
    w2.drain();
    w2.join();
    let ok_dead2 = per_ok()[2];
    wait_ready(false, "outage");
    let gen_body =
        format!("{{\"prompt\":{:?},\"max_new\":4}}", probes[0]);
    let (st, doc) = load::http_post(&addr, "/generate", &gen_body)
        .expect("degraded post");
    assert_eq!(st, 503, "{}", doc.dump());
    let msg = doc.get("error").and_then(|v| v.as_str()).unwrap_or("");
    assert!(msg.contains("uncovered"), "{}", doc.dump());
    let (_, mdoc) =
        load::http_get(&addr, "/metrics").expect("metrics");
    let m = |k: &str| {
        mdoc.get("metrics")
            .and_then(|m| m.get(k))
            .and_then(|v| v.as_f64())
            .unwrap_or(-1.0)
    };
    assert!(m("uncovered_503s") >= 1.0, "{}", mdoc.dump());

    // Phase D — the primary restarts on its old port, re-fetches via
    // the resumable spool, passes the readiness gate, and the fleet
    // recovers without a coordinator restart.
    let w0b = spawn_worker(0);
    wait_ready(true, "rejoin");
    assert!(fleet("rejoins") >= rejoins_before + 1.0,
            "rejoin not recorded");
    assert_eq!(state_of(2), "down", "dead replica resurrected?");
    let (st, tokens, term) = gen_stream(&addr, &probes[2], max_news[2])
        .expect("post-rejoin");
    assert_eq!((st, term.as_deref()), (200, Some("done")));
    assert_eq!(tokens, baseline[2], "post-rejoin stream diverged");

    // Conservation across the incident: the pool's successes split
    // exactly into each incarnation's serves — dead worker 2's count
    // froze at its snapshot, worker 0's post-restart serves sit on
    // top of its pre-kill snapshot, and worker 1 never lost an rpc.
    let (st, status) =
        load::http_get(&addr, "/status").expect("status");
    assert_eq!(st, 200);
    let per = per_ok();
    let pool_ok = status
        .get("shard_pool")
        .and_then(|p| p.get("rpcs_ok"))
        .and_then(|v| v.as_f64())
        .expect("shard_pool.rpcs_ok");
    assert_eq!(pool_ok, per.iter().sum::<f64>(),
               "pool rpc conservation violated: {}", status.dump());
    let ws = status
        .get("worker_status")
        .and_then(|v| v.as_arr())
        .expect("worker_status")
        .clone();
    assert_eq!(ws.len(), 3);
    let wf = |w: usize, k: &str| {
        ws[w].get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0)
    };
    assert_eq!(wf(0, "rpcs_served"), per[0] - ok_dead0,
               "worker 0 incarnations do not reconcile: {}",
               status.dump());
    assert_eq!(wf(1, "rpcs_served"), per[1],
               "worker 1 serves drifted from pool successes: {}",
               status.dump());
    assert_eq!(per[2], ok_dead2,
               "successes recorded against a dead worker: {}",
               status.dump());
    assert!(ws[2].get("error").is_some(), "{}", ws[2].dump());
    assert_eq!(wf(0, "rpc_in_flight"), 0.0);
    assert_eq!(wf(1, "rpc_in_flight"), 0.0);
    // Zero failed requests end to end (the uncovered 503 was shed at
    // the gate, pre-admission).
    let sm = |k: &str| {
        status.get("metrics")
            .and_then(|m| m.get(k))
            .and_then(|v| v.as_f64())
            .unwrap_or(-1.0)
    };
    assert_eq!(sm("admitted"), 3.0, "{}", status.dump());
    assert_eq!(sm("completed"), 3.0, "{}", status.dump());
    assert_eq!(sm("failed"), 0.0, "{}", status.dump());

    // Clean drain: the propagated drain reaches the live workers
    // (the dead one is skipped best-effort).
    let (st, _) =
        load::http_post(&addr, "/admin/drain", "").expect("drain");
    assert_eq!(st, 200);
    server.join();
    let wait_done = |w: &WorkerServer, tag: &str| {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !w.is_done() {
            assert!(Instant::now() < deadline,
                    "{tag} never saw the propagated drain");
            thread::sleep(Duration::from_millis(20));
        }
    };
    wait_done(&w1, "worker 1");
    wait_done(&w0b, "worker 0 (rejoined)");
    assert_eq!(w1.load_error(), None);
    assert_eq!(w0b.load_error(), None);
    w1.join();
    w0b.join();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Spawn-time validation: a fleet whose size disagrees with the shard
/// cut is rejected, and so is the f32 path (partial f32 sums cannot
/// recombine bit-exactly — the invariant demands integer kernels).
#[test]
fn coordinator_spawn_validates_fleet_and_kernel_path() {
    let cfg = tiny_cfg();
    let dir = std::env::temp_dir().join("osp_shard_props_reject");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir");
    let model = InferModel::synthetic(&cfg, 7).quantized(4);
    write_shards(&model, 2, "ssnorm_plain", &dir).expect("shards");
    let sopts = |workers: Vec<String>| ServeOpts {
        addr: "127.0.0.1:0".into(),
        workers,
        shard_dir: dir.to_string_lossy().into_owned(),
        ..ServeOpts::default()
    };

    // Fleet size must match what the shard dir was cut for.
    let mut m = InferModel::synthetic(&cfg, 7).quantized(4);
    m.set_int_mode(IntMode::Scalar);
    let err = Server::spawn(m, sopts(vec!["127.0.0.1:1".into()]))
        .err()
        .expect("mismatched fleet accepted");
    assert!(format!("{err:#}").contains("workers"), "{err:#}");

    // A fleet larger than n_shards * replicas is rejected too: at
    // the default --replicas 1 a third worker could never be routed
    // a stripe.
    let mut m = InferModel::synthetic(&cfg, 7).quantized(4);
    m.set_int_mode(IntMode::Scalar);
    let err = Server::spawn(
        m,
        sopts(vec!["127.0.0.1:1".into(), "127.0.0.1:2".into(),
                   "127.0.0.1:3".into()]))
        .err()
        .expect("overfull fleet accepted at replicas=1");
    assert!(format!("{err:#}").contains("workers"), "{err:#}");

    // Integer kernels are mandatory for sharded serving.
    let mut m = InferModel::synthetic(&cfg, 7).quantized(4);
    m.set_int_mode(IntMode::Off);
    let err = Server::spawn(
        m, sopts(vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()]))
        .err()
        .expect("f32 sharded serve accepted");
    assert!(format!("{err:#}").contains("integer"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}
