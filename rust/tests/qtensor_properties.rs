//! Packed-tensor parity properties (DESIGN.md §7, §10): pack/unpack
//! roundtrips, `dequantize()` pinned bit-exactly against in-test copies
//! of the seed's f32 RTN/GPTQ quantize-dequantize paths, fused
//! qmatvec/qmatmul kernels pinned against the dense kernels on the
//! dequantized tensor, the tiled LUT microkernels pinned against
//! per-element `decode()` oracles, and the §11 integer rhs kernels
//! (scalar + detected SIMD backend) pinned against a plain nested-loop
//! i32 oracle — across odd shapes, bits {2, 3, 4, 5, 8, 16}, and
//! worker counts 1/2/8.

use osp::quant::{gptq, rtn};
use osp::tensor::intkern::{self, Backend, QuantActs};
use osp::tensor::linalg;
use osp::tensor::par;
use osp::tensor::qtensor::QTensor;
use osp::tensor::Tensor;
use osp::util::prop;
use osp::util::rng::Pcg;
use osp::util::threadpool::ThreadPool;

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];
const BITS: [u32; 4] = [2, 4, 8, 16];
/// Every bit-width with a packed storage layout (3-bit codes ride 4-bit
/// fields, 5-bit codes ride bytes) — the LUT decode paths.
const LUT_BITS: [u32; 5] = [2, 3, 4, 5, 8];

fn randn(shape: &[usize], rng: &mut Pcg) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

/// Odd dims that stress byte-packing edges: odd columns (padded rows),
/// single rows/cols, and sizes off any block multiple.
fn odd_dims(rng: &mut Pcg) -> (usize, usize) {
    let pick = |rng: &mut Pcg| -> usize {
        match rng.below(6) {
            0 => 1,
            1 => 3,
            2 => 5,
            3 => 17,
            4 => 33,
            _ => 65,
        }
    };
    (pick(rng), pick(rng))
}

// ---- seed reference implementations (the f32 round-trip paths this PR
// ---- replaced with code-emitting variants; copied verbatim to pin the
// ---- bit-exact parity contract against an independent oracle) --------------

fn rtn_ref(w: &Tensor, bits: u32) -> Tensor {
    let Some(lv) = rtn::levels(bits) else {
        return w.clone();
    };
    let (rows, cols) = (w.shape()[0], w.shape()[1]);
    let mut absmax = vec![0.0f32; cols];
    for i in 0..rows {
        for (j, m) in absmax.iter_mut().enumerate() {
            *m = m.max(w.at2(i, j).abs());
        }
    }
    let scales: Vec<f32> = absmax.iter().map(|m| m / lv).collect();
    let mut out = w.clone();
    for i in 0..rows {
        for j in 0..cols {
            let (v, s) = (w.at2(i, j), scales[j]);
            let q = if s <= 0.0 {
                0.0
            } else {
                (v / s).round().clamp(-lv - 1.0, lv) * s
            };
            out.set2(i, j, q);
        }
    }
    out
}

fn inverse_cholesky_ref(h: &Tensor, damp_frac: f64) -> Tensor {
    let n = h.shape()[0];
    let mut hd = h.clone();
    let mean_diag: f64 =
        (0..n).map(|i| hd.at2(i, i) as f64).sum::<f64>() / n as f64;
    let damp = (damp_frac * mean_diag.max(1e-8)) as f32;
    for i in 0..n {
        let d = hd.at2(i, i);
        let v = if d <= 0.0 { 1.0 } else { d + damp };
        hd.set2(i, i, v);
    }
    let hinv = linalg::spd_inverse(&hd).unwrap();
    linalg::transpose(&linalg::cholesky(&hinv).unwrap())
}

fn gptq_ref(w: &Tensor, h: &Tensor, bits: u32) -> Tensor {
    let Some(lv) = rtn::levels(bits) else {
        return w.clone();
    };
    let (rows, cols) = (w.shape()[0], w.shape()[1]);
    let u = inverse_cholesky_ref(h, 0.01);
    let mut scales = vec![0.0f32; cols];
    for i in 0..rows {
        for (j, s) in scales.iter_mut().enumerate() {
            *s = s.max(w.at2(i, j).abs());
        }
    }
    for s in scales.iter_mut() {
        *s /= lv;
    }
    let mut work = w.clone();
    let mut out = Tensor::zeros(&[rows, cols]);
    for i in 0..rows {
        let uii = u.at2(i, i).max(1e-12);
        let mut err = vec![0.0f32; cols];
        for j in 0..cols {
            let v = work.at2(i, j);
            let s = scales[j];
            let q = if s <= 0.0 {
                0.0
            } else {
                (v / s).round().clamp(-lv - 1.0, lv) * s
            };
            out.set2(i, j, q);
            err[j] = (v - q) / uii;
        }
        for r in i + 1..rows {
            let uir = u.at2(i, r);
            if uir == 0.0 {
                continue;
            }
            for j in 0..cols {
                let v = work.at2(r, j) - uir * err[j];
                work.set2(r, j, v);
            }
        }
    }
    out
}

// ---- properties ------------------------------------------------------------

#[test]
fn pack_unpack_roundtrip_odd_shapes() {
    prop::check("pack/unpack roundtrip", 40, 0x51, |rng| {
        let (rows, cols) = odd_dims(rng);
        let bits = [2u32, 4, 8][rng.below_usize(3)];
        let span = 1u64 << bits;
        let codes: Vec<i32> = (0..rows * cols)
            .map(|_| (rng.below(span) as i64 - (span / 2) as i64) as i32)
            .collect();
        let scales: Vec<f32> =
            (0..cols).map(|_| rng.range_f32(0.01, 2.0)).collect();
        (rows, cols, bits, codes, scales)
    }, |(rows, cols, bits, codes, scales)| {
        let q = QTensor::pack(&[*rows, *cols], *bits, codes,
                              scales.clone());
        if q.unpack_codes() != *codes {
            return Err(format!("roundtrip broke at {rows}x{cols} {bits}b"));
        }
        // Padded trailing nibbles must not leak into values.
        let deq = q.dequantize();
        for i in 0..*rows {
            for j in 0..*cols {
                let want = codes[i * cols + j] as f32 * scales[j];
                if deq.at2(i, j) != want {
                    return Err(format!("deq ({i},{j}) {} != {want}",
                                       deq.at2(i, j)));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn rtn_codes_dequantize_bit_identical_to_seed_path() {
    prop::check("rtn code path == seed f32 path", 40, 0x52, |rng| {
        let (rows, cols) = odd_dims(rng);
        let bits = BITS[rng.below_usize(BITS.len())];
        (randn(&[rows, cols], rng), bits)
    }, |(w, bits)| {
        let want = rtn_ref(w, *bits);
        let got_q = rtn::quantize_per_channel_q(w, *bits).dequantize();
        let got_f = rtn::quantize_per_channel(w, *bits);
        if got_q.data() != want.data() {
            return Err(format!("codes path diverged at {:?} {bits}b",
                               w.shape()));
        }
        if got_f.data() != want.data() {
            return Err(format!("f32 wrapper diverged at {:?} {bits}b",
                               w.shape()));
        }
        Ok(())
    });
}

#[test]
fn rtn_zero_column_and_outlier_edge_cases() {
    // Dead columns (scale 0) and huge-dynamic-range columns hit the
    // clamp and the scale<=0 guard.
    let mut w = Tensor::zeros(&[9, 5]);
    let mut rng = Pcg::new(0x53, 0);
    rng.fill_normal(w.data_mut(), 1.0);
    for i in 0..9 {
        w.set2(i, 2, 0.0); // dead column
        let v = w.at2(i, 4) * 1e6; // outlier column
        w.set2(i, 4, v);
    }
    for bits in BITS {
        let want = rtn_ref(&w, bits);
        let got = rtn::quantize_per_channel_q(&w, bits).dequantize();
        assert_eq!(got.data(), want.data(), "{bits}-bit");
    }
}

#[test]
fn gptq_codes_dequantize_bit_identical_to_seed_path() {
    prop::check("gptq code path == seed f32 path", 12, 0x54, |rng| {
        let rows = 4 + rng.below_usize(20);
        let cols = 1 + rng.below_usize(12);
        let samples = rows + rng.below_usize(16);
        let w = randn(&[rows, cols], rng);
        let x = randn(&[samples, rows], rng);
        let h = linalg::matmul(&linalg::transpose(&x), &x);
        let bits = BITS[rng.below_usize(BITS.len())];
        (w, h, bits)
    }, |(w, h, bits)| {
        let want = gptq_ref(w, h, *bits);
        let got = gptq::gptq_quantize_q(w, h, *bits)
            .map_err(|e| e.to_string())?
            .dequantize();
        if got.data() != want.data() {
            return Err(format!("gptq diverged at {:?} {bits}b", w.shape()));
        }
        let got_f = gptq::gptq_quantize(w, h, *bits)
            .map_err(|e| e.to_string())?;
        if got_f.data() != want.data() {
            return Err(format!("gptq f32 wrapper diverged at {:?} {bits}b",
                               w.shape()));
        }
        Ok(())
    });
}

#[test]
fn qmatvec_parity_workers_and_bits() {
    for &nw in &WORKER_COUNTS {
        let pool = ThreadPool::new(nw, 4 * nw.max(4));
        prop::check("qmatvec parity", 20, 0x55 + nw as u64, |rng| {
            let (rows, cols) = odd_dims(rng);
            let bits = BITS[rng.below_usize(BITS.len())];
            let w = randn(&[rows, cols], rng);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            (rtn::quantize_per_channel_q(&w, bits), x)
        }, |(q, x)| {
            let dense = par::matvec_with(None, &q.dequantize(), x);
            let serial = q.qmatvec_with(None, x);
            let parallel = q.qmatvec_with(Some(&pool), x);
            if serial != dense {
                return Err(format!("fused != dense at {:?} {}b",
                                   q.shape(), q.bits()));
            }
            if parallel != serial {
                return Err(format!("par != serial at {:?} ({nw} workers)",
                                   q.shape()));
            }
            Ok(())
        });
    }
}

#[test]
fn qmatmul_parity_workers_and_bits() {
    for &nw in &WORKER_COUNTS {
        let pool = ThreadPool::new(nw, 4 * nw.max(4));
        prop::check("qmatmul parity", 16, 0x56 + nw as u64, |rng| {
            let (m, k) = odd_dims(rng);
            let n = 1 + rng.below_usize(17);
            let bits = BITS[rng.below_usize(BITS.len())];
            let w = randn(&[m, k], rng);
            (rtn::quantize_per_channel_q(&w, bits), randn(&[k, n], rng))
        }, |(q, b)| {
            let dense = par::matmul_with(None, &q.dequantize(), b);
            let serial = q.qmatmul_with(None, b);
            let parallel = q.qmatmul_with(Some(&pool), b);
            if serial.data() != dense.data() {
                return Err(format!("fused != dense at {:?} {}b",
                                   q.shape(), q.bits()));
            }
            if parallel.data() != serial.data() {
                return Err(format!("par != serial at {:?} ({nw} workers)",
                                   q.shape()));
            }
            Ok(())
        });
    }
}

/// Random codes spanning the full two's-complement range of `bits`.
fn random_codes(rng: &mut Pcg, n: usize, bits: u32) -> Vec<i32> {
    let span = 1i64 << bits;
    (0..n)
        .map(|_| (rng.below(span as u64) as i64 - span / 2) as i32)
        .collect()
}

/// The LUT dequant paths (`dequantize`, `dequant_fields`) are bitwise
/// the per-element `decode()` oracle (`code_at(i, j) * scales[j]`) for
/// every packed bit-width, odd shape, and unaligned field window —
/// including the mid-byte stripe starts `qmatmul_rhs` takes.
#[test]
fn lut_dequant_matches_per_element_decode() {
    prop::check("lut dequant == decode", 40, 0x60, |rng| {
        let (rows, cols) = odd_dims(rng);
        let bits = LUT_BITS[rng.below_usize(LUT_BITS.len())];
        let codes = random_codes(rng, rows * cols, bits);
        let scales: Vec<f32> =
            (0..cols).map(|_| rng.range_f32(0.01, 2.0)).collect();
        let j0 = rng.below_usize(cols);
        let j1 = j0 + rng.below_usize(cols - j0 + 1);
        (rows, cols, bits, codes, scales, j0, j1)
    }, |(rows, cols, bits, codes, scales, j0, j1)| {
        let q = QTensor::pack(&[*rows, *cols], *bits, codes,
                              scales.clone());
        let deq = q.dequantize();
        for i in 0..*rows {
            for j in 0..*cols {
                let want = q.code_at(i, j) as f32 * scales[j];
                if deq.at2(i, j) != want {
                    return Err(format!(
                        "dequantize ({i},{j}) {} != {want} at \
                         {rows}x{cols} {bits}b", deq.at2(i, j)));
                }
            }
            let mut window = vec![0.0f32; j1 - j0];
            q.dequant_fields(i, *j0, *j1, &mut window);
            for (t, j) in (*j0..*j1).enumerate() {
                let want = q.code_at(i, j) as f32 * scales[j];
                if window[t] != want {
                    return Err(format!(
                        "dequant_fields row {i} [{j0},{j1}) @{j}: {} != \
                         {want} ({bits}b)", window[t]));
                }
            }
        }
        Ok(())
    });
}

/// The tiled LUT qmatvec/qmatmul kernels are bitwise the pre-LUT
/// per-element kernels (`qmatvec_scalar`/`qmatmul_scalar`) and
/// serial == parallel for worker counts 1/2/8, across every packed
/// bit-width and odd shape.
#[test]
fn lut_kernels_match_scalar_oracle_workers_and_bits() {
    for &nw in &WORKER_COUNTS {
        let pool = ThreadPool::new(nw, 4 * nw.max(4));
        prop::check("lut kernels == scalar", 16, 0x61 + nw as u64, |rng| {
            let (m, k) = odd_dims(rng);
            let n = 1 + rng.below_usize(9);
            let bits = LUT_BITS[rng.below_usize(LUT_BITS.len())];
            let codes = random_codes(rng, m * k, bits);
            let scales: Vec<f32> =
                (0..k).map(|_| rng.range_f32(0.01, 2.0)).collect();
            let q = QTensor::pack(&[m, k], bits, &codes, scales);
            let x: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
            (q, x, randn(&[k, n], rng))
        }, |(q, x, b)| {
            let want = q.qmatvec_scalar(x);
            if q.qmatvec_with(None, x) != want {
                return Err(format!("qmatvec lut != scalar at {:?} {}b",
                                   q.shape(), q.bits()));
            }
            if q.qmatvec_with(Some(&pool), x) != want {
                return Err(format!("qmatvec par != scalar at {:?} \
                                    ({nw} workers)", q.shape()));
            }
            let wantm = q.qmatmul_scalar(b);
            if q.qmatmul_with(None, b).data() != wantm.data() {
                return Err(format!("qmatmul lut != scalar at {:?} {}b",
                                   q.shape(), q.bits()));
            }
            if q.qmatmul_with(Some(&pool), b).data() != wantm.data() {
                return Err(format!("qmatmul par != scalar at {:?} \
                                    ({nw} workers)", q.shape()));
            }
            Ok(())
        });
    }
}

/// Random activation codes spanning the full i8 range (including
/// -128) plus positive per-row scales.
fn random_acts(rng: &mut Pcg, m: usize, k: usize) -> QuantActs {
    let codes: Vec<i8> =
        (0..m * k).map(|_| rng.below(256) as u8 as i8).collect();
    let scales: Vec<f32> =
        (0..m).map(|_| rng.range_f32(0.001, 1.0)).collect();
    QuantActs::from_parts(codes, scales, m, k)
}

/// Plain nested-loop oracle for the integer rhs matmul (DESIGN.md
/// §11): exact i32 accumulation over the full contraction, then ONE
/// f32 rescale `sum * (act_scale * col_scale)` per output element.
fn int_rhs_ref(q: &QTensor, acts: &QuantActs) -> Vec<f32> {
    let (m, k) = (acts.m(), acts.k());
    let n = q.shape()[1];
    let mut out = vec![0.0f32; m * n];
    for r in 0..m {
        let ca = acts.row_codes(r);
        for j in 0..n {
            let mut s = 0i32;
            for (kk, &c) in ca.iter().enumerate().take(k) {
                s += c as i32 * q.code_at(kk, j);
            }
            out[r * n + j] = s as f32 * (acts.scale(r) * q.scales()[j]);
        }
    }
    out
}

/// The integer rhs kernels (`qmatmul_rhs_int_with`) are bitwise the
/// plain nested-loop oracle for every packed bit-width and odd shape;
/// the detected SIMD backend is bitwise the scalar integer backend;
/// and serial == parallel for worker counts 1/2/8 (mid-byte column
/// stripes included — narrow stripes at 8 workers start mid-nibble
/// for the 2/4-bit layouts).
#[test]
fn int_rhs_kernels_match_plain_oracle_workers_and_bits() {
    let simd = intkern::active();
    for &nw in &WORKER_COUNTS {
        let pool = ThreadPool::new(nw, 4 * nw.max(4));
        prop::check("int rhs kernels == oracle", 16, 0x71 + nw as u64,
                    |rng| {
            let (k, n) = odd_dims(rng);
            let m = 1 + rng.below_usize(9);
            let bits = LUT_BITS[rng.below_usize(LUT_BITS.len())];
            let codes = random_codes(rng, k * n, bits);
            let scales: Vec<f32> =
                (0..n).map(|_| rng.range_f32(0.01, 2.0)).collect();
            let q = QTensor::pack(&[k, n], bits, &codes, scales);
            let acts = random_acts(rng, m, k);
            (q, acts)
        }, |(q, acts)| {
            let want = int_rhs_ref(q, acts);
            let serial =
                q.qmatmul_rhs_int_with(None, acts, Backend::Scalar);
            if serial.data() != want.as_slice() {
                return Err(format!("scalar int != oracle at {:?} {}b",
                                   q.shape(), q.bits()));
            }
            let parallel =
                q.qmatmul_rhs_int_with(Some(&pool), acts,
                                       Backend::Scalar);
            if parallel.data() != serial.data() {
                return Err(format!("int par != serial at {:?} \
                                    ({nw} workers)", q.shape()));
            }
            if simd != Backend::Scalar {
                let sv = q.qmatmul_rhs_int_with(None, acts, simd);
                if sv.data() != serial.data() {
                    return Err(format!("{} int != scalar int at {:?} \
                                        {}b", simd.label(), q.shape(),
                                       q.bits()));
                }
                let svp =
                    q.qmatmul_rhs_int_with(Some(&pool), acts, simd);
                if svp.data() != serial.data() {
                    return Err(format!("{} int par != scalar int at \
                                        {:?} ({nw} workers)",
                                       simd.label(), q.shape()));
                }
            }
            Ok(())
        });
    }
}

/// A contraction dim crossing the f32 path's KTILE (256) and shapes
/// off every RBLOCK multiple: the int kernels accumulate straight
/// through tile boundaries without splitting the i32 sum.
#[test]
fn int_rhs_kernels_cross_ktile_boundaries() {
    let mut rng = Pcg::new(0x72, 0);
    let simd = intkern::active();
    for (m, k, n) in [(3usize, 300usize, 20usize), (5, 257, 7),
                      (1, 512, 33)] {
        for bits in [4u32, 8] {
            let codes = random_codes(&mut rng, k * n, bits);
            let scales: Vec<f32> =
                (0..n).map(|_| rng.range_f32(0.01, 2.0)).collect();
            let q = QTensor::pack(&[k, n], bits, &codes, scales);
            let acts = random_acts(&mut rng, m, k);
            let want = int_rhs_ref(&q, &acts);
            let got = q.qmatmul_rhs_int_with(None, &acts,
                                             Backend::Scalar);
            assert_eq!(got.data(), want.as_slice(),
                       "scalar {m}x{k}x{n} {bits}b");
            if simd != Backend::Scalar {
                let gs = q.qmatmul_rhs_int_with(None, &acts, simd);
                assert_eq!(gs.data(), got.data(),
                           "{} {m}x{k}x{n} {bits}b", simd.label());
            }
        }
    }
}

#[test]
fn quant_mse_matches_materialized_diff() {
    prop::check("streaming mse == materialized mse", 30, 0x57, |rng| {
        let (rows, cols) = odd_dims(rng);
        let bits = BITS[rng.below_usize(BITS.len())];
        (randn(&[rows, cols], rng), bits)
    }, |(w, bits)| {
        let q = rtn_ref(w, *bits);
        let mut s = 0.0f64;
        for (a, b) in w.data().iter().zip(q.data()) {
            let d = (a - b) as f64;
            s += d * d;
        }
        let want = s / w.len() as f64;
        let got = rtn::quant_mse(w, *bits);
        if got != want {
            return Err(format!("mse {got} != {want} at {:?} {bits}b",
                               w.shape()));
        }
        Ok(())
    });
}

#[test]
fn packed_bytes_well_under_dense_at_w4() {
    let mut rng = Pcg::new(0x58, 0);
    let w = randn(&[96, 64], &mut rng);
    let q = rtn::quantize_per_channel_q(&w, 4);
    let ratio = q.packed_bytes() as f64 / q.dense_bytes() as f64;
    assert!(ratio <= 0.3, "W4 packed/dense ratio {ratio}");
    let q8 = rtn::quantize_per_channel_q(&w, 8);
    assert!(q8.packed_bytes() < q8.dense_bytes() / 3,
            "W8 should still be ~4x smaller");
}
