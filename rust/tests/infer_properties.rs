//! Decode-engine parity properties (DESIGN.md §8): greedy token streams
//! from packed-W4/KV4 models pinned bit-exactly against their dense-f32
//! twins on grammar-corpus prompts, serial vs pool-parallel decode
//! pinned bit-identical across worker counts, and scheduler/batching
//! invariance.

use osp::data::grammar::Grammar;
use osp::eval::tasks;
use osp::infer::engine::generate;
use osp::infer::{DecodeParams, InferConfig, InferModel};
use osp::tensor::intkern::IntMode;
use osp::util::prop;
use osp::util::rng::Pcg;
use osp::util::threadpool::ThreadPool;

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];

fn cfg_case(rng: &mut Pcg) -> InferConfig {
    let d_model = [16usize, 32, 48][rng.below(3) as usize];
    let n_heads = [2usize, 4][rng.below(2) as usize];
    InferConfig {
        vocab_size: [64usize, 96, 128][rng.below(3) as usize],
        d_model,
        n_layers: 1 + rng.below(2) as usize,
        n_heads,
        d_ff: [24usize, 40, 56][rng.below(3) as usize],
        rope_theta: 10000.0,
        norm_ss: rng.below(2) == 0,
        embproj: false,
    }
}

#[derive(Debug)]
struct Case {
    seed: u64,
    vocab: usize,
    prompts: Vec<Vec<i32>>,
}

fn case(rng: &mut Pcg) -> (InferConfig, Case) {
    let cfg = cfg_case(rng);
    let g = Grammar::new(cfg.vocab_size, 42);
    let n = 1 + rng.below(3) as usize;
    let plen = 2 + rng.below(6) as usize;
    let prompts = tasks::grammar_prompts(&g, n, plen, rng.next_u64());
    (cfg.clone(), Case { seed: rng.next_u64(), vocab: cfg.vocab_size,
                         prompts })
}

/// Packed-W4/KV4 greedy decode is bit-identical to the dense-f32 twin
/// on grammar-corpus prompts — across random shapes and >= 3 seeds.
#[test]
fn packed_kv4_matches_dense_decode() {
    prop::check("packed_kv4_matches_dense", 6, 0xD5C0DE, case, |(cfg, c)| {
        let dense = InferModel::synthetic(cfg, c.seed);
        let packed = dense.quantized(4);
        let params = DecodeParams::greedy(4, 4, c.prompts.len());
        let a = generate(&packed, &c.prompts, 8, params, None).unwrap();
        let b = generate(&packed.dequantized(), &c.prompts, 8, params,
                         None)
            .unwrap();
        if a != b {
            return Err(format!("packed {a:?} != dense {b:?}"));
        }
        for stream in &a {
            if stream.len() != 8 {
                return Err(format!("stream len {}", stream.len()));
            }
            if stream.iter().any(|&t| t < 0 || t as usize >= c.vocab) {
                return Err(format!("out-of-vocab token in {stream:?}"));
            }
        }
        Ok(())
    });
}

/// Serial decode and pool-parallel decode (workers 1/2/8) produce
/// bit-identical streams — on packed and dense models alike.
#[test]
fn serial_vs_parallel_decode_bit_identical() {
    prop::check("serial_vs_parallel_decode", 4, 0xBA7C4, case, |(cfg, c)| {
        let packed = InferModel::synthetic(cfg, c.seed).quantized(4);
        let params = DecodeParams::greedy(4, 4, c.prompts.len());
        let serial = generate(&packed, &c.prompts, 6, params, None)
            .unwrap();
        for nw in WORKER_COUNTS {
            let pool = ThreadPool::new(nw, 8 * nw.max(4));
            let par = generate(&packed, &c.prompts, 6, params,
                               Some(&pool))
                .unwrap();
            if par != serial {
                return Err(format!(
                    "{nw} workers: {par:?} != serial {serial:?}"));
            }
        }
        Ok(())
    });
}

/// The eval-layer consistency check reports zero mismatches for every
/// Table-2 runtime bit config on a packed-W4 model.
#[test]
fn generation_consistency_across_table2_configs() {
    let cfg = InferConfig { vocab_size: 96, d_model: 32, n_layers: 2,
                            n_heads: 2, d_ff: 40, rope_theta: 10000.0,
                            norm_ss: true, embproj: false };
    let g = Grammar::new(96, 42);
    for seed in [1u64, 2, 3] {
        let packed = InferModel::synthetic(&cfg, seed).quantized(4);
        for bc in osp::eval::BitConfig::table2_columns() {
            let rep = tasks::generation_consistency(
                &packed, &g, 3, 5, 6, bc.a, bc.kv, seed, None);
            assert_eq!(rep.mismatches, 0,
                       "seed {seed} config {}: agreement {}", bc.label(),
                       rep.agreement());
            assert_eq!(rep.tokens, 3 * 6);
        }
    }
}

/// End-to-end integer-kernel parity (DESIGN.md §11): with the integer
/// activation path enabled, `IntMode::Auto` (detected SIMD) and
/// `IntMode::Scalar` (integer oracle) decode bit-identical greedy
/// streams — serially and across worker counts 1/2/8.
/// `InferModel::synthetic(..).quantized(4)` is deterministic, so two
/// builds from one seed are the same model.
#[test]
fn int_simd_and_scalar_decode_bit_identical() {
    prop::check("int_simd_vs_scalar_decode", 4, 0x147C0DE, case,
                |(cfg, c)| {
        let build = |mode: IntMode| {
            InferModel::synthetic(cfg, c.seed)
                .quantized(4)
                .with_int_mode(mode)
        };
        let scalar_m = build(IntMode::Scalar);
        let auto_m = build(IntMode::Auto);
        let params = DecodeParams::greedy(4, 4, c.prompts.len());
        let want = generate(&scalar_m, &c.prompts, 6, params, None)
            .unwrap();
        let got = generate(&auto_m, &c.prompts, 6, params, None)
            .unwrap();
        if got != want {
            return Err(format!("auto {got:?} != scalar int {want:?}"));
        }
        for nw in WORKER_COUNTS {
            let pool = ThreadPool::new(nw, 8 * nw.max(4));
            let par = generate(&auto_m, &c.prompts, 6, params,
                               Some(&pool))
                .unwrap();
            if par != want {
                return Err(format!(
                    "{nw} workers: auto {par:?} != scalar int serial"));
            }
            let spar = generate(&scalar_m, &c.prompts, 6, params,
                                Some(&pool))
                .unwrap();
            if spar != want {
                return Err(format!(
                    "{nw} workers: scalar int par != serial"));
            }
        }
        Ok(())
    });
}

/// Eviction mid-decode (DESIGN.md §12): cancelling one sequence — via
/// `DecodeEngine::cancel`, the serve layer's deadline/disconnect path —
/// leaves every surviving batchmate's token stream bit-identical to a
/// run where the cancelled request was never admitted, and returns the
/// victim's batch slot. Exercised with nonzero temperature so the
/// per-request RNG path is covered too, and across random victim
/// choices (queued and active alike).
#[test]
fn cancel_mid_decode_leaves_survivors_bit_identical() {
    prop::check("cancel_mid_decode_invariant", 6, 0xCA7CE1,
                |rng: &mut Pcg| {
        let cfg = cfg_case(rng);
        let g = Grammar::new(cfg.vocab_size, 42);
        let n = 3 + rng.below(3) as usize;
        let plen = 2 + rng.below(5) as usize;
        let prompts = tasks::grammar_prompts(&g, n, plen,
                                             rng.next_u64());
        let victim = rng.below_usize(n);
        let steps_before = 1 + rng.below_usize(3);
        (cfg, prompts, victim, steps_before, rng.next_u64())
    }, |(cfg, prompts, victim, steps_before, seed)| {
        use osp::infer::{DecodeEngine, GenRequest};
        let model = InferModel::synthetic(cfg, *seed).quantized(4);
        let mut params = DecodeParams::greedy(4, 4, 2);
        params.temperature = 0.9;
        params.seed = 0x5EED ^ *seed;
        let max_new = 8usize;
        // Run A: admit everyone, step a little, cancel the victim,
        // finish. steps_before <= 3 < max_new, so an active victim
        // cannot have finished before the cancel.
        let mut eng = DecodeEngine::new(&model, params, None);
        for (i, p) in prompts.iter().enumerate() {
            eng.submit(GenRequest { id: i, prompt: p.clone(), max_new })
                .unwrap();
        }
        for _ in 0..*steps_before {
            eng.step().map_err(|e| format!("step: {e}"))?;
        }
        if !eng.cancel(*victim) {
            return Err(format!("victim {victim} not cancellable"));
        }
        if eng.cancel(*victim) {
            return Err("double-cancel succeeded".into());
        }
        let mut got = eng.run().map_err(|e| format!("run: {e}"))?;
        if eng.n_active() != 0 || eng.n_queued() != 0 {
            return Err(format!("leaked slots: {} active {} queued",
                               eng.n_active(), eng.n_queued()));
        }
        if eng.stats.cancelled != 1 {
            return Err(format!("stats.cancelled = {}",
                               eng.stats.cancelled));
        }
        got.sort_by_key(|r| r.id);
        if got.iter().any(|r| r.id == *victim) {
            return Err("cancelled request still finished".into());
        }
        if got.len() != prompts.len() - 1 {
            return Err(format!("{} survivors of {}", got.len(),
                               prompts.len() - 1));
        }
        // Run B: the victim is never admitted; same ids, so each
        // survivor keeps its sampling RNG stream.
        let mut base = DecodeEngine::new(&model, params, None);
        for (i, p) in prompts.iter().enumerate() {
            if i == *victim {
                continue;
            }
            base.submit(GenRequest { id: i, prompt: p.clone(),
                                     max_new })
                .unwrap();
        }
        let mut want = base.run().map_err(|e| format!("run: {e}"))?;
        want.sort_by_key(|r| r.id);
        for (g, w) in got.iter().zip(&want) {
            if g.id != w.id || g.generated != w.generated {
                return Err(format!(
                    "survivor {} diverged after cancel of {victim}: \
                     {:?} != {:?}",
                    g.id, g.generated, w.generated));
            }
        }
        Ok(())
    });
}

/// Streams are independent of scheduler batch composition: decoding
/// sequences together (any max_batch) equals decoding them alone.
#[test]
fn continuous_batching_is_stream_invariant() {
    let cfg = InferConfig { vocab_size: 64, d_model: 16, n_layers: 2,
                            n_heads: 2, d_ff: 24, rope_theta: 10000.0,
                            norm_ss: false, embproj: false };
    let model = InferModel::synthetic(&cfg, 5).quantized(4);
    let g = Grammar::new(64, 42);
    let prompts = tasks::grammar_prompts(&g, 5, 4, 9);
    let solo: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| generate(&model, std::slice::from_ref(p), 7,
                          DecodeParams::greedy(4, 4, 1), None)
             .unwrap()
             .remove(0))
        .collect();
    let pool = ThreadPool::new(4, 32);
    for max_batch in [1usize, 2, 5] {
        let together = generate(&model, &prompts, 7,
                                DecodeParams::greedy(4, 4, max_batch),
                                Some(&pool))
            .unwrap();
        assert_eq!(together, solo, "max_batch={max_batch}");
    }
}

/// Prefix sharing (DESIGN.md §13) is invisible in the token streams:
/// prompts that agree on every token but the last decode bit-identical
/// greedy streams with `share_prefix` on and off, across batch sizes
/// (serial admission adopts registered pages; simultaneous admission
/// mostly doesn't — both must match the unshared baseline). A serial
/// shared run actually shares pages, an unshared run never does, and
/// the pool balances to zero once the registry is cleared.
#[test]
fn prefix_sharing_is_stream_invariant() {
    prop::check("prefix_sharing_invariant", 5, 0x5A4ED, |rng: &mut Pcg| {
        let cfg = cfg_case(rng);
        // Token-aligned pages, 1-3 tokens each; prompts share all but
        // the final token, so each request's shareable region (whole
        // pages over the first plen-1 tokens) lies inside the common
        // run and the first finisher's registration is adoptable.
        let tpp = 1 + rng.below_usize(3);
        let page_rows = cfg.n_heads * tpp;
        let plen = 2 * tpp + 1;
        let common: Vec<i32> = (0..plen - 1)
            .map(|_| rng.below(cfg.vocab_size as u64) as i32)
            .collect();
        let n = 2 + rng.below_usize(3);
        let prompts: Vec<Vec<i32>> = (0..n)
            .map(|i| {
                let mut p = common.clone();
                p.push((i % cfg.vocab_size) as i32);
                p
            })
            .collect();
        (cfg, prompts, page_rows, rng.next_u64())
    }, |(cfg, prompts, page_rows, seed)| {
        use osp::infer::{DecodeEngine, GenRequest};
        let model = InferModel::synthetic(cfg, *seed).quantized(4);
        let run = |share: bool, max_batch: usize|
                  -> Result<(Vec<Vec<i32>>, usize), String> {
            let mut params = DecodeParams::greedy(4, 4, max_batch);
            params.kv_page_rows = *page_rows;
            params.share_prefix = share;
            let mut eng = DecodeEngine::new(&model, params, None);
            for (i, p) in prompts.iter().enumerate() {
                eng.submit(GenRequest { id: i, prompt: p.clone(),
                                        max_new: 6 })
                    .map_err(|e| format!("submit {i}: {e}"))?;
            }
            let mut out = eng.run().map_err(|e| format!("run: {e}"))?;
            let shared = eng.stats.kv_pages_shared;
            eng.clear_prefix_cache();
            let g = eng.pool_gauges();
            if (g.refs_live, g.pages_live) != (0, 0) {
                return Err(format!(
                    "share={share} mb={max_batch}: pool holds {} refs \
                     / {} pages after drain", g.refs_live,
                    g.pages_live));
            }
            out.sort_by_key(|r| r.id);
            Ok((out.into_iter().map(|r| r.generated).collect(), shared))
        };
        let (base, s_off) = run(false, prompts.len())?;
        if s_off != 0 {
            return Err(format!("sharing off but {s_off} pages shared"));
        }
        for mb in [1usize, 2, prompts.len()] {
            let (got, _) = run(true, mb)?;
            if got != base {
                return Err(format!(
                    "share on, max_batch {mb}: {got:?} != unshared \
                     {base:?}"));
            }
        }
        let (_, s_serial) = run(true, 1)?;
        if s_serial == 0 {
            return Err("serial shared run shared no pages".into());
        }
        Ok(())
    });
}
