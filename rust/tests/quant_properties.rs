//! Property tests (seeded, reproducible via the printed case_seed) on the
//! quantization and linalg substrates — the proptest-style suite.

use osp::quant::{gptq, rtn};
use osp::tensor::linalg;
use osp::tensor::stats;
use osp::tensor::Tensor;
use osp::util::prop::{all_close, check};
use osp::util::rng::Pcg;

fn randn(rng: &mut Pcg, shape: &[usize], std: f32) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), std);
    t
}

#[test]
fn prop_rtn_error_bound() {
    check(
        "rtn |x - q(x)| <= scale/2",
        40,
        0xA1,
        |rng| {
            let rows = 1 + rng.below_usize(40);
            let cols = 1 + rng.below_usize(24);
            let bits = 2 + rng.below(7) as u32;
            (randn(rng, &[rows, cols], 2.0), bits)
        },
        |(w, bits)| {
            let q = rtn::quantize_per_channel(w, *bits);
            let lv = ((1u32 << (bits - 1)) - 1) as f32;
            let (rows, cols) = (w.shape()[0], w.shape()[1]);
            for j in 0..cols {
                let absmax = (0..rows)
                    .map(|i| w.at2(i, j).abs())
                    .fold(0.0f32, f32::max);
                let half = absmax / lv / 2.0 + 1e-6;
                for i in 0..rows {
                    let err = (w.at2(i, j) - q.at2(i, j)).abs();
                    if err > half {
                        return Err(format!(
                            "err {err} > half-scale {half} at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_hadamard_involution_and_isometry() {
    check(
        "hadamard: H(Hx) == x and ||Hx|| == ||x||",
        30,
        0xB2,
        |rng| {
            let rows = 1 + rng.below_usize(12);
            let n = [16, 32, 48, 80, 176, 352][rng.below_usize(6)];
            randn(rng, &[rows, n], 1.5)
        },
        |x| {
            let y = linalg::hadamard_rows(x);
            let back = linalg::hadamard_rows(&y);
            all_close(back.data(), x.data(), 1e-4)?;
            for r in 0..x.rows() {
                let nx: f32 =
                    x.row(r).iter().map(|v| v * v).sum::<f32>().sqrt();
                let ny: f32 =
                    y.row(r).iter().map(|v| v * v).sum::<f32>().sqrt();
                if (nx - ny).abs() > 1e-3 * (1.0 + nx) {
                    return Err(format!("row {r}: norm {nx} -> {ny}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gptq_not_worse_than_rtn() {
    check(
        "gptq hessian-error <= rtn hessian-error",
        15,
        0xC3,
        |rng| {
            let n = 8 + rng.below_usize(24);
            let cols = 2 + rng.below_usize(12);
            let samples = n + rng.below_usize(32);
            let w = randn(rng, &[n, cols], 1.0);
            let x = randn(rng, &[samples, n], 1.0);
            let h = linalg::matmul(&linalg::transpose(&x), &x);
            (w, h)
        },
        |(w, h)| {
            // GPTQ is greedy: per-instance it may occasionally tie or
            // slip a few percent behind RTN on tiny ill-conditioned
            // problems; bound the slip per case and require strict
            // dominance in aggregate (below).
            let q = gptq::gptq_quantize(w, h, 4)
                .map_err(|e| e.to_string())?;
            let r = rtn::quantize_per_channel(w, 4);
            let eg = gptq::hessian_error(w, &q, h);
            let er = gptq::hessian_error(w, &r, h);
            if eg > er * 1.15 {
                return Err(format!("gptq {eg} > 1.15 * rtn {er}"));
            }
            Ok(())
        },
    );

    // Aggregate: GPTQ must dominate RTN summed over many problems.
    let mut rng = Pcg::new(0xC3C3, 9);
    let (mut sum_g, mut sum_r) = (0.0f64, 0.0f64);
    for _ in 0..20 {
        let n = 8 + rng.below_usize(24);
        let cols = 2 + rng.below_usize(12);
        let samples = n + rng.below_usize(32);
        let w = randn(&mut rng, &[n, cols], 1.0);
        let x = randn(&mut rng, &[samples, n], 1.0);
        let h = linalg::matmul(&linalg::transpose(&x), &x);
        let q = gptq::gptq_quantize(&w, &h, 4).unwrap();
        let r = rtn::quantize_per_channel(&w, 4);
        sum_g += gptq::hessian_error(&w, &q, &h);
        sum_r += gptq::hessian_error(&w, &r, &h);
    }
    assert!(sum_g < sum_r, "aggregate gptq {sum_g} >= rtn {sum_r}");
}

#[test]
fn prop_qr_orthogonal_reconstructs() {
    check(
        "qr: Q^T Q == I and QR == A",
        25,
        0xD4,
        |rng| {
            let n = 2 + rng.below_usize(14);
            let m = n + rng.below_usize(10);
            randn(rng, &[m, n], 1.0)
        },
        |a| {
            let (q, r) = linalg::qr(a);
            let n = a.shape()[1];
            let qtq = linalg::matmul(&linalg::transpose(&q), &q);
            all_close(qtq.data(), Tensor::eye(n).data(), 5e-3)?;
            let rec = linalg::matmul(&q, &r);
            all_close(rec.data(), a.data(), 5e-3)
        },
    );
}

#[test]
fn prop_random_rotation_reduces_planted_outlier_kurtosis() {
    check(
        "rotation flattens planted outlier channels",
        10,
        0xE5,
        |rng| {
            let d = 16 + 8 * rng.below_usize(3);
            let mut w = randn(rng, &[d, d], 1.0);
            // plant 1-2 outlier input channels
            for _ in 0..1 + rng.below_usize(2) {
                let c = rng.below_usize(d);
                for j in 0..d {
                    let v = w.at2(c, j) * 40.0;
                    w.set2(c, j, v);
                }
            }
            let q = linalg::random_orthogonal(d, rng);
            (w, q)
        },
        |(w, q)| {
            let rotated = linalg::matmul(&linalg::transpose(q), w);
            let k_before = stats::excess_kurtosis(w.data());
            let k_after = stats::excess_kurtosis(rotated.data());
            if k_after >= k_before {
                return Err(format!(
                    "kurtosis not reduced: {k_before} -> {k_after}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ring_all_reduce_is_average() {
    check(
        "ring all-reduce == average, any k/n",
        20,
        0xF6,
        |rng| {
            let k = 1 + rng.below_usize(8);
            let n = 1 + rng.below_usize(300);
            let parts: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.normal()).collect())
                .collect();
            parts
        },
        |parts| {
            let k = parts.len() as f32;
            let n = parts[0].len();
            let want: Vec<f32> = (0..n)
                .map(|i| parts.iter().map(|p| p[i]).sum::<f32>() / k)
                .collect();
            let got = osp::coordinator::dp::ring_all_reduce(parts.clone());
            for r in got {
                all_close(&r, &want, 1e-4)?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_host_muon_descends_quadratic() {
    // On f(W) = 0.5||W - T||^2 the Muon host optimizer must descend.
    check(
        "host muon descends",
        10,
        0x17,
        |rng| {
            let d = 8 + rng.below_usize(8);
            (randn(rng, &[d, d], 1.0), randn(rng, &[d, d], 1.0))
        },
        |(w0, target)| {
            use osp::coordinator::opt::HostOpt;
            use osp::runtime::manifest::ParamSpec;
            let specs = [ParamSpec {
                name: "w".into(),
                shape: w0.shape().to_vec(),
                init: "normal".into(),
                kind: "matrix".into(),
            }];
            let mut opt = HostOpt::new("muon", &specs);
            let mut params = vec![w0.clone()];
            let loss = |p: &Tensor| -> f64 {
                p.sub(target).frobenius_norm() as f64
            };
            let l0 = loss(&params[0]);
            for _ in 0..10 {
                let g = params[0].sub(target);
                opt.apply(&mut params, &[g], 0.02).map_err(|e| e.to_string())?;
            }
            let l1 = loss(&params[0]);
            if l1 >= l0 {
                return Err(format!("no descent: {l0} -> {l1}"));
            }
            Ok(())
        },
    );
}
