//! Coordinator integration: the three execution modes agree, the
//! disaggregated optimizer-parallel path is step-equivalent to the host
//! path, and EmbProj absorption is computationally invariant through the
//! real executables.

mod common;

use common::{engine_or_skip, init_params, tokens_for};

use osp::coordinator::opt::HostOpt;
use osp::coordinator::{install_disaggregated_ns, levels_for_bits};
use osp::quant::absorb;
use osp::runtime::HostValue;
use osp::tensor::Tensor;
use osp::util::threadpool::ThreadPool;

fn run_grad(eng: &osp::runtime::Engine, arch: &str, params: &[Tensor],
            toks: &HostValue) -> Vec<Tensor> {
    let grad = eng.load(&format!("grad_{arch}")).unwrap();
    let mut inputs: Vec<HostValue> =
        params.iter().cloned().map(HostValue::F32).collect();
    inputs.push(toks.clone());
    let out = grad.run(&inputs).unwrap();
    out[..params.len()]
        .iter()
        .map(|v| v.as_f32().unwrap().clone())
        .collect()
}

#[test]
fn fused_and_host_muon_steps_agree() {
    let Some(eng) = engine_or_skip() else { return };
    let arch = "ssnorm_embproj";
    let m = eng.manifest();
    let toks = tokens_for(&eng, m.batch_train, 77);
    let lr = 1e-3f32;

    // Fused step through the train artifact.
    let train = eng.load(&format!("train_muon_{arch}")).unwrap();
    let params0 = init_params(&eng, arch, 5);
    let opt_state = osp::runtime::init_opt_state(
        m.opt_leaves(arch, "muon").unwrap());
    let n_p = params0.len();
    let mut inputs: Vec<HostValue> =
        params0.iter().cloned().map(HostValue::F32).collect();
    inputs.extend(opt_state.iter().cloned().map(HostValue::F32));
    inputs.push(toks.clone());
    inputs.push(HostValue::scalar(lr));
    let fused_out = train.run(&inputs).unwrap();
    let fused_params: Vec<Tensor> = fused_out[..n_p]
        .iter()
        .map(|v| v.as_f32().unwrap().clone())
        .collect();

    // Host step: grad artifact + HostOpt (rust-side Muon).
    let mut host_params = params0.clone();
    let grads = run_grad(&eng, arch, &host_params, &toks);
    let mut host_opt = HostOpt::new("muon", m.params(arch).unwrap());
    host_opt.apply(&mut host_params, &grads, lr).unwrap();

    // Same math on both sides of the PJRT boundary.
    let specs = m.params(arch).unwrap();
    for ((spec, f), h) in specs.iter().zip(&fused_params).zip(&host_params)
    {
        let scale = f.abs_max().max(1e-3);
        let max_diff = f
            .data()
            .iter()
            .zip(h.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 2e-2 * scale,
                "param {} diverges: {max_diff} (scale {scale})", spec.name);
    }
}

#[test]
fn disaggregated_ns_matches_host_ns() {
    let Some(eng) = engine_or_skip() else { return };
    let arch = "ssnorm_embproj";
    let m = eng.manifest();
    let toks = tokens_for(&eng, m.batch_train, 31);
    let lr = 1e-3f32;

    let params0 = init_params(&eng, arch, 9);
    let grads = run_grad(&eng, arch, &params0, &toks);

    // Host NS path.
    let mut p_host = params0.clone();
    let mut opt_host = HostOpt::new("muon", m.params(arch).unwrap());
    opt_host.apply(&mut p_host, &grads, lr).unwrap();

    // Disaggregated path: ns_* executables sharded over a pool (the
    // paper's optimizer-parallel design).
    let mut p_dis = params0.clone();
    let mut opt_dis = HostOpt::new("muon", m.params(arch).unwrap());
    let pool = std::sync::Arc::new(ThreadPool::new(4, 64));
    install_disaggregated_ns(&eng, &mut opt_dis, pool, 4).unwrap();
    opt_dis.apply(&mut p_dis, &grads, lr).unwrap();

    for (h, d) in p_host.iter().zip(&p_dis) {
        let max_diff = h
            .data()
            .iter()
            .zip(d.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-3, "disagg vs host diff {max_diff}");
    }
}

#[test]
fn embproj_absorption_invariant_through_executables() {
    let Some(eng) = engine_or_skip() else { return };
    let m = eng.manifest();
    let arch = "ssnorm_embproj";
    let params = init_params(&eng, arch, 21);
    let toks = tokens_for(&eng, m.batch_eval, 13);
    let off = levels_for_bits(16);

    let eval = |arch: &str, params: &[Tensor]| -> f32 {
        let exe = eng.load(&format!("evalq_{arch}")).unwrap();
        let mut inputs: Vec<HostValue> =
            params.iter().cloned().map(HostValue::F32).collect();
        inputs.push(toks.clone());
        inputs.push(HostValue::scalar(off));
        inputs.push(HostValue::scalar(off));
        inputs.push(HostValue::scalar(0.0));
        let out = exe.run(&inputs).unwrap();
        out[0].as_f32().unwrap().data()[0]
    };

    let nll_embproj = eval(arch, &params);
    let absorbed = absorb::absorb_embproj(m.params(arch).unwrap(), &params)
        .unwrap();
    let nll_plain = eval("ssnorm_plain", &absorbed);
    // Section 3.3: absorption maintains computational invariance.
    let rel = (nll_embproj - nll_plain).abs() / nll_embproj.abs();
    assert!(rel < 1e-3, "absorption changed nll: {nll_embproj} vs \
                         {nll_plain}");
}

#[test]
fn ffn_had_weight_prerotation_invariant_at_fp() {
    let Some(eng) = engine_or_skip() else { return };
    let m = eng.manifest();
    let arch = "rmsnorm_plain";
    let params = init_params(&eng, arch, 2);
    let toks = tokens_for(&eng, m.batch_eval, 17);
    let exe = eng.load(&format!("evalq_{arch}")).unwrap();
    let off = levels_for_bits(16);

    let run = |params: &[Tensor], had: f32| -> f32 {
        let mut inputs: Vec<HostValue> =
            params.iter().cloned().map(HostValue::F32).collect();
        inputs.push(toks.clone());
        inputs.push(HostValue::scalar(off));
        inputs.push(HostValue::scalar(off));
        inputs.push(HostValue::scalar(had));
        exe.run(&inputs).unwrap()[0].as_f32().unwrap().data()[0]
    };

    let base = run(&params, 0.0);
    // Pre-rotate w_down in rust, enable online Hadamard in the graph: at
    // fp precision the composition must be exact (H orthogonal).
    let mut rotated = params.clone();
    osp::quant::rotate::prerotate_w_down_hadamard(
        m.params(arch).unwrap(), &mut rotated);
    let had = run(&rotated, 1.0);
    let rel = (base - had).abs() / base.abs();
    assert!(rel < 1e-3, "FFN-Had not invariant: {base} vs {had}");
}

#[test]
fn residual_rotation_invariant_through_executables() {
    let Some(eng) = engine_or_skip() else { return };
    let m = eng.manifest();
    // SSNorm arch: scalar gamma commutes with rotations natively (§3.2
    // payoff) — no scale folding needed.
    let arch = "ssnorm_plain";
    let params = init_params(&eng, arch, 8);
    let toks = tokens_for(&eng, m.batch_eval, 19);
    let exe = eng.load(&format!("evalq_{arch}")).unwrap();
    let off = levels_for_bits(16);

    let run = |params: &[Tensor]| -> f32 {
        let mut inputs: Vec<HostValue> =
            params.iter().cloned().map(HostValue::F32).collect();
        inputs.push(toks.clone());
        inputs.push(HostValue::scalar(off));
        inputs.push(HostValue::scalar(off));
        inputs.push(HostValue::scalar(0.0));
        exe.run(&inputs).unwrap()[0].as_f32().unwrap().data()[0]
    };

    let base = run(&params);
    let mut rotated = params.clone();
    let mut rng = osp::util::rng::Pcg::new(33, 0);
    let q = osp::tensor::linalg::random_orthogonal(m.model.d_model,
                                                   &mut rng);
    osp::quant::rotate::apply_residual_rotation(
        m.params(arch).unwrap(), &mut rotated, &q).unwrap();
    let rot = run(&rotated);
    let rel = (base - rot).abs() / base.abs();
    assert!(rel < 2e-3, "rotation not invariant: {base} vs {rot}");
}
