//! Shared helpers for integration tests: engine bootstrap (skipping
//! gracefully when `make artifacts` has not run) and manifest-driven
//! parameter/token construction.

use std::path::PathBuf;
use std::sync::OnceLock;

use osp::runtime::{Engine, HostValue};
use osp::tensor::Tensor;
use osp::util::rng::Pcg;

static ENGINE: OnceLock<Option<Engine>> = OnceLock::new();

/// Artifact directory: $OSP_ARTIFACTS or <repo>/artifacts.
pub fn artifact_dir() -> PathBuf {
    std::env::var("OSP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

/// Open the engine (shared across all tests in the binary — compiled
/// executables are cached once), or skip when `make artifacts` hasn't
/// run.
pub fn engine_or_skip() -> Option<Engine> {
    ENGINE
        .get_or_init(|| {
            let dir = artifact_dir();
            if !dir.join("manifest.json").exists() {
                eprintln!("SKIP: no artifacts at {dir:?}; run `make artifacts`");
                return None;
            }
            Some(Engine::open(&dir).expect("engine open"))
        })
        .clone()
}

/// Run the init_<arch> artifact to get flat params.
pub fn init_params(eng: &Engine, arch: &str, seed: i32) -> Vec<Tensor> {
    let init = eng.load(&format!("init_{arch}")).unwrap();
    let out = init
        .run(&[HostValue::tokens(&[1], vec![seed])])
        .expect("init run");
    out.into_iter().map(|v| v.into_f32().unwrap()).collect()
}

/// Random token batch with the manifest's seq_len.
pub fn tokens_for(eng: &Engine, batch: usize, seed: u64) -> HostValue {
    let m = eng.manifest();
    let mut rng = Pcg::new(seed, 5);
    let n = batch * m.model.seq_len;
    let data: Vec<i32> = (0..n)
        .map(|_| rng.below(m.model.vocab_size as u64) as i32)
        .collect();
    HostValue::tokens(&[batch, m.model.seq_len], data)
}
