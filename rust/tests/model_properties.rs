//! Host model layer properties (DESIGN.md §9): the block forward pinned
//! bit-exactly against an independent no-KV-cache reference
//! implementation across the W{4,8} x A{4,16} x KV{4,16} grid,
//! prefill-chunk invariance (bit-identical logits *and* KV cache
//! contents for chunk 1 vs 64), chunk-invariant host perplexity, and
//! the fail-safe rejection paths of the model/scheduler stack.

use std::sync::Arc;

use osp::coordinator::levels_for_bits;
use osp::data::{Split, TokenStream};
use osp::eval::host::{perplexity_host, HostEvalOpts, VALID_STREAM_SEED};
use osp::model::kv::{PagePool, PageRef, QRows, SeqKv};
use osp::model::ops::{fake_quant_row, norm_row, rope_in_place, silu,
                      softmax_in_place};
use osp::model::{InferConfig, InferModel, LogitsMode, SeqBlock};
use osp::quant::rtn::quantize_per_channel_q;
use osp::tensor::intkern::{self, Backend, IntMode};
use osp::tensor::{par, Tensor};
use osp::util::rng::Pcg;
use osp::util::threadpool::ThreadPool;

// ---- independent reference implementation ---------------------------------
//
// A teacher-forced forward with *no KV cache and no batching*: every
// sequence runs alone, K/V are stored as plain fake-quantized f32 rows,
// and attention walks the full causal prefix per position. It shares
// only the per-row primitives (`model::ops`) and the dense matmul with
// the production path — the cache, packing, chunking, and batching
// machinery under test is completely absent.

struct RefLayer {
    attn_norm: Tensor,
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
    ffn_norm: Tensor,
    w_gate: Tensor,
    w_up: Tensor,
    w_down: Tensor,
}

struct RefModel {
    d: usize,
    nh: usize,
    f: usize,
    embed: Tensor,
    layers: Vec<RefLayer>,
    final_norm: Tensor,
    unembed: Tensor,
    inv_freq: Vec<f32>,
}

/// Random dense leaves in manifest order for `ssnorm_plain`.
fn make_params(v: usize, d: usize, l: usize, f: usize, seed: u64)
               -> Vec<Tensor> {
    let mut rng = Pcg::new(seed, 3);
    let mut randn = |shape: &[usize], s: f32| {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), s);
        t
    };
    let mut params = vec![randn(&[v, d], 0.05)];
    for _ in 0..l {
        params.push(Tensor::full(&[1], (d as f32).sqrt())); // attn_norm
        params.push(randn(&[d, d], 0.05)); // wq
        params.push(randn(&[d, d], 0.05)); // wk
        params.push(randn(&[d, d], 0.05)); // wv
        params.push(randn(&[d, d], 0.03)); // wo
        params.push(Tensor::full(&[1], (d as f32).sqrt())); // ffn_norm
        params.push(randn(&[d, f], 0.05)); // w_gate
        params.push(randn(&[d, f], 0.05)); // w_up
        params.push(randn(&[f, d], 0.03)); // w_down
    }
    params.push(Tensor::full(&[1], (d as f32).sqrt())); // final_norm
    params.push(randn(&[d, v], 0.05)); // unembed
    params
}

/// W-quantize a 2-D leaf exactly like `InferModel::quantized` does
/// (RTN per-channel packed codes, dequantized back to the snapped f32
/// values the fused kernels serve).
fn wq_deq(t: &Tensor, w_bits: u32) -> Tensor {
    quantize_per_channel_q(t, w_bits).dequantize()
}

fn ref_model(params: &[Tensor], nh: usize, rope_theta: f32, w_bits: u32)
             -> RefModel {
    let d = params[0].shape()[1];
    let l = (params.len() - 3) / 9;
    let f = params[7].shape()[1];
    let layers = (0..l)
        .map(|li| {
            let b = 1 + li * 9;
            RefLayer {
                attn_norm: params[b].clone(),
                wq: wq_deq(&params[b + 1], w_bits),
                wk: wq_deq(&params[b + 2], w_bits),
                wv: wq_deq(&params[b + 3], w_bits),
                wo: wq_deq(&params[b + 4], w_bits),
                ffn_norm: params[b + 5].clone(),
                w_gate: wq_deq(&params[b + 6], w_bits),
                w_up: wq_deq(&params[b + 7], w_bits),
                w_down: wq_deq(&params[b + 8], w_bits),
            }
        })
        .collect();
    let half = (d / nh) / 2;
    RefModel {
        d,
        nh,
        f,
        embed: wq_deq(&params[0], w_bits),
        layers,
        final_norm: params[params.len() - 2].clone(),
        unembed: wq_deq(&params[params.len() - 1], w_bits),
        inv_freq: (0..half)
            .map(|j| rope_theta.powf(-(j as f32) / half as f32))
            .collect(),
    }
}

/// Teacher-forced logits `[s, vocab]` for one sequence.
fn ref_logits(p: &RefModel, tokens: &[i32], a_bits: u32, kv_bits: u32)
              -> Tensor {
    let (d, nh, f) = (p.d, p.nh, p.f);
    let hd = d / nh;
    let a_lv = levels_for_bits(a_bits);
    let kv_lv = levels_for_bits(kv_bits);
    let s = tokens.len();
    let mut x = Tensor::zeros(&[s, d]);
    for (t, &tok) in tokens.iter().enumerate() {
        x.row_mut(t).copy_from_slice(p.embed.row(tok as usize));
    }
    for lw in &p.layers {
        // ---- MHSA ----
        let mut h = x.clone();
        for row in h.data_mut().chunks_mut(d) {
            norm_row(row, &lw.attn_norm, true);
            fake_quant_row(row, a_lv);
        }
        let q = par::matmul_with(None, &h, &lw.wq);
        let k = par::matmul_with(None, &h, &lw.wk);
        let v = par::matmul_with(None, &h, &lw.wv);
        // The KV tap: rope'd K rows and raw V rows per (pos, head),
        // fake-quantized like the cache stores them.
        let mut kst = vec![vec![0.0f32; hd]; s * nh];
        let mut vst = vec![vec![0.0f32; hd]; s * nh];
        for pos in 0..s {
            for hh in 0..nh {
                let mut kh = k.row(pos)[hh * hd..(hh + 1) * hd].to_vec();
                rope_in_place(&mut kh, pos, &p.inv_freq);
                fake_quant_row(&mut kh, kv_lv);
                kst[pos * nh + hh] = kh;
                let mut vh = v.row(pos)[hh * hd..(hh + 1) * hd].to_vec();
                fake_quant_row(&mut vh, kv_lv);
                vst[pos * nh + hh] = vh;
            }
        }
        let mut attn = Tensor::zeros(&[s, d]);
        let shd = (hd as f32).sqrt();
        for pos in 0..s {
            for hh in 0..nh {
                let mut qh = q.row(pos)[hh * hd..(hh + 1) * hd].to_vec();
                rope_in_place(&mut qh, pos, &p.inv_freq);
                let mut w = vec![0.0f32; pos + 1];
                for (t, wv) in w.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    for (kv, qv) in kst[t * nh + hh].iter().zip(&qh) {
                        acc += kv * qv;
                    }
                    *wv = acc / shd;
                }
                softmax_in_place(&mut w);
                let out_h = &mut attn.row_mut(pos)[hh * hd..(hh + 1) * hd];
                for (t, &wv) in w.iter().enumerate() {
                    for (o, &vv) in out_h.iter_mut().zip(&vst[t * nh + hh])
                    {
                        *o += wv * vv;
                    }
                }
            }
        }
        for row in attn.data_mut().chunks_mut(d) {
            fake_quant_row(row, a_lv);
        }
        x = x.add(&par::matmul_with(None, &attn, &lw.wo));

        // ---- FFN ----
        let mut h = x.clone();
        for row in h.data_mut().chunks_mut(d) {
            norm_row(row, &lw.ffn_norm, true);
            fake_quant_row(row, a_lv);
        }
        let gate = par::matmul_with(None, &h, &lw.w_gate);
        let mut g = par::matmul_with(None, &h, &lw.w_up);
        for (gv, xv) in g.data_mut().iter_mut().zip(gate.data()) {
            *gv *= silu(*xv);
        }
        for row in g.data_mut().chunks_mut(f) {
            fake_quant_row(row, a_lv);
        }
        x = x.add(&par::matmul_with(None, &g, &lw.w_down));
    }
    let mut hfin = x;
    for row in hfin.data_mut().chunks_mut(d) {
        norm_row(row, &p.final_norm, true);
    }
    for row in hfin.data_mut().chunks_mut(d) {
        fake_quant_row(row, a_lv);
    }
    par::matmul_with(None, &hfin, &p.unembed)
}

// ---- helpers --------------------------------------------------------------

const V: usize = 64;
const D: usize = 16;
const L: usize = 2;
const NH: usize = 2;
const F: usize = 24;
const S: usize = 12;
const THETA: f32 = 10000.0;

fn build_models(seed: u64, w_bits: u32)
                -> (Vec<Tensor>, InferModel, RefModel) {
    let params = make_params(V, D, L, F, seed);
    let model = InferModel::from_dense_params("ssnorm_plain", &params, NH,
                                              THETA)
        .unwrap()
        .quantized(w_bits);
    let rm = ref_model(&params, NH, THETA, w_bits);
    (params, model, rm)
}

fn random_tokens(rng: &mut Pcg, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(V as u64) as i32).collect()
}

/// Feed `tokens` through `forward_block` in blocks of `chunk`, stacking
/// all-position logits.
fn chunked_logits(model: &InferModel, tokens: &[i32], cache: &mut SeqKv,
                  a_bits: u32, chunk: usize) -> Tensor {
    let vocab = model.cfg.vocab_size;
    let mut out = Tensor::zeros(&[tokens.len(), vocab]);
    let mut c0 = 0usize;
    while c0 < tokens.len() {
        let c1 = (c0 + chunk).min(tokens.len());
        let mut blocks = vec![SeqBlock { tokens: &tokens[c0..c1],
                                         cache: &mut *cache }];
        let logits = model
            .forward_block(None, &mut blocks, a_bits, LogitsMode::All,
                           None)
            .unwrap()
            .unwrap();
        out.data_mut()[c0 * vocab..c1 * vocab]
            .copy_from_slice(logits.data());
        c0 = c1;
    }
    out
}

/// `chunked_logits` with a worker pool: the A4 forward in blocks of 5,
/// stacking all-position logits.
fn pooled_logits(model: &InferModel, tokens: &[i32], cache: &mut SeqKv,
                 tp: &ThreadPool) -> Tensor {
    let vocab = model.cfg.vocab_size;
    let mut out = Tensor::zeros(&[tokens.len(), vocab]);
    let mut c0 = 0usize;
    while c0 < tokens.len() {
        let c1 = (c0 + 5).min(tokens.len());
        let mut blocks = vec![SeqBlock { tokens: &tokens[c0..c1],
                                         cache: &mut *cache }];
        let logits = model
            .forward_block(Some(tp), &mut blocks, 4, LogitsMode::All,
                           None)
            .unwrap()
            .unwrap();
        out.data_mut()[c0 * vocab..c1 * vocab]
            .copy_from_slice(logits.data());
        c0 = c1;
    }
    out
}

fn assert_caches_equal(a: &SeqKv, b: &SeqKv, what: &str) {
    assert_eq!(a.n_tokens(), b.n_tokens(), "{what}: n_tokens");
    for li in 0..a.n_layers() {
        let (la, lb) = (a.layer(li), b.layer(li));
        assert_eq!(la.k.len(), lb.k.len(), "{what}: L{li} K rows");
        assert_eq!(la.v.len(), lb.v.len(), "{what}: L{li} V rows");
        for i in 0..la.k.len() {
            for j in 0..la.k.dim() {
                assert_eq!(la.k.at(i, j), lb.k.at(i, j),
                           "{what}: L{li} K[{i}][{j}]");
                assert_eq!(la.v.at(i, j), lb.v.at(i, j),
                           "{what}: L{li} V[{i}][{j}]");
            }
        }
    }
}

/// Independent next-token NLL over one sequence's reference logits:
/// positions `0..s-1` predict `tokens[1..]` (the evalq `nll` rule).
fn ref_nll_per_token(rm: &RefModel, rows: &[&[i32]], a_bits: u32,
                     kv_bits: u32) -> f64 {
    let mut total = 0.0f64;
    let mut count = 0.0f64;
    for row in rows {
        let logits = ref_logits(rm, row, a_bits, kv_bits);
        let mut snll = 0.0f64;
        for pos in 0..row.len() - 1 {
            let lr = logits.row(pos);
            let m = lr.iter().cloned().fold(f32::MIN, f32::max);
            let mut z = 0.0f32;
            for &v in lr {
                z += (v - m).exp();
            }
            snll += (z as f64).ln() - (lr[row[pos + 1] as usize] - m) as f64;
        }
        total += snll;
        count += (row.len() - 1) as f64;
    }
    total / count
}

// ---- properties -----------------------------------------------------------

/// The packed block forward is bit-identical to the independent
/// reference across the whole W x A x KV grid — single sequences and
/// batched sequences alike.
#[test]
fn forward_block_matches_reference_across_bit_grid() {
    let mut rng = Pcg::new(0xB10C, 1);
    let t0 = random_tokens(&mut rng, S);
    let t1 = random_tokens(&mut rng, S);
    for w_bits in [4u32, 8] {
        let (_params, model, rm) = build_models(77, w_bits);
        for a_bits in [4u32, 16] {
            for kv_bits in [4u32, 16] {
                let tag = format!("W{w_bits}-A{a_bits}-KV{kv_bits}");
                let want0 = ref_logits(&rm, &t0, a_bits, kv_bits);
                let want1 = ref_logits(&rm, &t1, a_bits, kv_bits);
                // Single sequence, whole block.
                let mut c = model.new_cache(kv_bits);
                let got = chunked_logits(&model, &t0, &mut c, a_bits, S);
                assert_eq!(got.data(), want0.data(), "{tag}: solo seq");
                // Two sequences in one batched block call.
                let mut c0 = model.new_cache(kv_bits);
                let mut c1 = model.new_cache(kv_bits);
                let mut blocks =
                    vec![SeqBlock { tokens: &t0, cache: &mut c0 },
                         SeqBlock { tokens: &t1, cache: &mut c1 }];
                let both = model
                    .forward_block(None, &mut blocks, a_bits,
                                   LogitsMode::All, None)
                    .unwrap()
                    .unwrap();
                assert_eq!(&both.data()[..S * V], want0.data(),
                           "{tag}: batched seq 0");
                assert_eq!(&both.data()[S * V..], want1.data(),
                           "{tag}: batched seq 1");
            }
        }
    }
}

/// Chunk 1 vs 64 (and ragged sizes in between): bit-identical logits
/// and bit-identical KV cache contents — the prefill-chunk invariance
/// the scheduler's `--prefill-chunk` knob relies on.
#[test]
fn prefill_chunk_invariance_logits_and_kv() {
    let mut rng = Pcg::new(0xC407, 2);
    let tokens = random_tokens(&mut rng, S);
    let (_params, model, _rm) = build_models(31, 4);
    for kv_bits in [4u32, 16] {
        let mut base_cache = model.new_cache(kv_bits);
        let base = chunked_logits(&model, &tokens, &mut base_cache, 4, 1);
        for chunk in [2usize, 5, 64] {
            let mut cache = model.new_cache(kv_bits);
            let got = chunked_logits(&model, &tokens, &mut cache, 4, chunk);
            assert_eq!(got.data(), base.data(),
                       "kv{kv_bits} chunk {chunk}: logits");
            assert_caches_equal(&cache, &base_cache,
                                &format!("kv{kv_bits} chunk {chunk}"));
        }
    }
}

/// Host perplexity agrees with reference values computed by the
/// independent forward on the same held-out batch — pinning the NLL
/// target alignment (`tokens[pos+1]`) and the token count, not just the
/// logits — across W{4,8} x A{4,16} x KV{4,16}.
#[test]
fn host_perplexity_matches_reference_values() {
    let mut stream = TokenStream::new(V, VALID_STREAM_SEED, Split::Valid,
                                      0, 1);
    let batch = stream.next_batch(2, S, 0);
    let rows: Vec<&[i32]> = (0..2)
        .map(|r| &batch.tokens[r * S..(r + 1) * S])
        .collect();
    for w_bits in [4u32, 8] {
        let (_params, model, rm) = build_models(55, w_bits);
        for a_bits in [4u32, 16] {
            for kv_bits in [4u32, 16] {
                let tag = format!("W{w_bits}-A{a_bits}-KV{kv_bits}");
                let want = ref_nll_per_token(&rm, &rows, a_bits, kv_bits);
                let opts = HostEvalOpts { a_bits, kv_bits, batch: 2,
                                          seq_len: S, n_batches: 1,
                                          chunk: 5 };
                let got = perplexity_host(&model, &opts, None).unwrap();
                let tol = 1e-9 * (1.0 + want.abs());
                assert!((got.nll_per_token - want).abs() <= tol,
                        "{tag}: host nll/tok {} vs reference {}",
                        got.nll_per_token, want);
                let want_ppl = want.min(60.0).exp();
                assert!((got.ppl - want_ppl).abs() <= 1e-6 * want_ppl,
                        "{tag}: host ppl {} vs reference {want_ppl}",
                        got.ppl);
            }
        }
    }
}

/// Host perplexity is invariant to the teacher-forcing chunk size and
/// to packing (packed model == dense twin), and reads the same held-out
/// stream the engine path reads.
#[test]
fn host_perplexity_chunk_and_packing_invariance() {
    let cfg = InferConfig { vocab_size: 96, d_model: 32, n_layers: 2,
                            n_heads: 2, d_ff: 40, rope_theta: 10000.0,
                            norm_ss: true, embproj: false };
    let packed = InferModel::synthetic(&cfg, 5).quantized(4);
    let mut opts = HostEvalOpts::new(4, 4);
    opts.batch = 2;
    opts.seq_len = 24;
    opts.n_batches = 1;
    opts.chunk = 1;
    let base = perplexity_host(&packed, &opts, None).unwrap();
    for chunk in [3usize, 24, 64] {
        let got = perplexity_host(&packed,
                                  &HostEvalOpts { chunk, ..opts }, None)
            .unwrap();
        assert_eq!(got.nll_per_token, base.nll_per_token,
                   "chunk {chunk} nll");
        assert_eq!(got.ppl, base.ppl, "chunk {chunk} ppl");
    }
    let dense = packed.dequantized();
    let got = perplexity_host(&dense, &HostEvalOpts { chunk: 64, ..opts },
                              None)
        .unwrap();
    assert_eq!(got.nll_per_token, base.nll_per_token, "dense twin");
    // The held-out stream is the engine path's: same seed, Valid split.
    let mut s = TokenStream::new(96, VALID_STREAM_SEED, Split::Valid, 0, 1);
    let b = s.next_batch(2, 24, 0);
    assert!(b.tokens.iter().all(|&t| (0..96).contains(&t)));
}

/// The block-dequant attention kernels (DESIGN.md §10) are bit-exact
/// against the element-wise KV reference: a scratch tile filled by
/// `QRows::dequant_block_into` and swept with plain dense loops yields
/// the same scores and value mixes as per-(query, row) `QRows::dot` /
/// `QRows::axpy_into` decoding — across every packed KV width, the f32
/// passthrough, and interior block ranges. This is the equivalence the
/// attention rewrite in `InferModel::attend_block` relies on.
#[test]
fn block_dequant_attention_matches_elementwise_reference() {
    let mut rng = Pcg::new(0xA77E, 4);
    let dim = 10;
    let n_rows = 13;
    for bits in [2u32, 3, 4, 5, 8, 16] {
        let mut kstore = QRows::new(dim, bits);
        let mut vstore = QRows::new(dim, bits);
        for _ in 0..n_rows {
            let kr: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
            let vr: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
            kstore.push(&kr);
            vstore.push(&vr);
        }
        // Dequantize the whole store once (the per-block scratch tile).
        let mut ktile = vec![0.0f32; n_rows * dim];
        let mut vtile = vec![0.0f32; n_rows * dim];
        kstore.dequant_block_into(0, n_rows, &mut ktile);
        vstore.dequant_block_into(0, n_rows, &mut vtile);
        // Interior ranges agree with the full-range tile bitwise.
        let (i0, i1) = (3usize, 9usize);
        let mut part = vec![0.0f32; (i1 - i0) * dim];
        kstore.dequant_block_into(i0, i1, &mut part);
        assert_eq!(&part[..], &ktile[i0 * dim..i1 * dim], "{bits}b range");
        for q in 0..4 {
            let query: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
            // Scores: dense tile dot vs element-wise QRows::dot.
            let mut weights = Vec::with_capacity(n_rows);
            for t in 0..n_rows {
                let krow = &ktile[t * dim..(t + 1) * dim];
                let mut acc = 0.0f32;
                for (kv, qv) in krow.iter().zip(&query) {
                    acc += kv * qv;
                }
                assert_eq!(acc, kstore.dot(t, &query),
                           "{bits}b q{q} score row {t}");
                weights.push(acc);
            }
            softmax_in_place(&mut weights);
            // Value mix: dense tile sweep vs element-wise axpy_into.
            let mut dense_mix = vec![0.0f32; dim];
            let mut ref_mix = vec![0.0f32; dim];
            for (t, &wv) in weights.iter().enumerate() {
                let vrow = &vtile[t * dim..(t + 1) * dim];
                for (o, &vv) in dense_mix.iter_mut().zip(vrow) {
                    *o += wv * vv;
                }
                vstore.axpy_into(t, wv, &mut ref_mix);
            }
            assert_eq!(dense_mix, ref_mix, "{bits}b q{q} value mix");
        }
    }
}

/// The integer activation path (DESIGN.md §11): `IntMode::Auto` (the
/// detected SIMD backend) and `IntMode::Scalar` (the integer oracle)
/// produce bit-identical logits and KV caches through the full block
/// forward, the int forward is prefill-chunk invariant, and with
/// `a_bits = 16` the int path disengages (no i8 grid), matching the
/// default `Off` model bitwise.
#[test]
fn int_mode_auto_matches_scalar_and_stays_chunk_invariant() {
    let mut rng = Pcg::new(0x1417, 6);
    let tokens = random_tokens(&mut rng, S);
    // build_models(seed, ..) is deterministic: three calls give three
    // identical models (InferModel is not Clone).
    let build = |mode: IntMode| {
        let (_p, model, _rm) = build_models(77, 4);
        model.with_int_mode(mode)
    };
    let m_scalar = build(IntMode::Scalar);
    assert_eq!(m_scalar.int_kernel(4), Some(Backend::Scalar));
    assert_eq!(m_scalar.int_kernel(16), None, "A16 has no i8 grid");
    let mut c_scalar = m_scalar.new_cache(4);
    let base = chunked_logits(&m_scalar, &tokens, &mut c_scalar, 4, S);
    // Auto (whatever backend this host detects) == scalar, bitwise —
    // logits and cache contents.
    let m_auto = build(IntMode::Auto);
    assert_eq!(m_auto.int_kernel(4), Some(intkern::active()));
    let mut c_auto = m_auto.new_cache(4);
    let got = chunked_logits(&m_auto, &tokens, &mut c_auto, 4, S);
    assert_eq!(got.data(), base.data(),
               "auto ({}) != scalar int logits",
               intkern::active().label());
    assert_caches_equal(&c_auto, &c_scalar, "auto vs scalar int");
    // Prefill-chunk invariance holds on the int path too.
    for chunk in [1usize, 5, 64] {
        let mut c = m_scalar.new_cache(4);
        let got = chunked_logits(&m_scalar, &tokens, &mut c, 4, chunk);
        assert_eq!(got.data(), base.data(), "int chunk {chunk}: logits");
        assert_caches_equal(&c, &c_scalar,
                            &format!("int chunk {chunk}"));
    }
    // At A16 the grid is not i8-representable: the int-mode model must
    // take the plain f32 path and match the Off model exactly.
    let m_off = build(IntMode::Off);
    let mut c16_int = m_scalar.new_cache(16);
    let a16_int = chunked_logits(&m_scalar, &tokens, &mut c16_int, 16, S);
    let mut c16_off = m_off.new_cache(16);
    let a16_off = chunked_logits(&m_off, &tokens, &mut c16_off, 16, S);
    assert_eq!(a16_int.data(), a16_off.data(), "A16 int == off");
    assert_caches_equal(&c16_int, &c16_off, "A16 int vs off");
}

/// Rejection paths: malformed inputs surface as `Err` at every level of
/// the stack (block forward, step API) and never panic.
#[test]
fn rejection_paths_return_err() {
    let cfg = InferConfig { vocab_size: 32, d_model: 16, n_layers: 1,
                            n_heads: 2, d_ff: 24, rope_theta: 10000.0,
                            norm_ss: false, embproj: false };
    let model = InferModel::synthetic(&cfg, 9);
    // Empty batch through the step API.
    let mut none: Vec<&mut SeqKv> = Vec::new();
    assert!(model.decode_step(None, &[], &mut none, 4, true).is_err());
    // Out-of-vocab token through the step API leaves the cache intact.
    let mut c = model.new_cache(4);
    {
        let mut refs = vec![&mut c];
        assert!(model
            .decode_step(None, &[99], &mut refs, 4, true)
            .is_err());
    }
    assert_eq!(c.n_tokens(), 0);
    // A valid step afterwards still works (the model is unpoisoned).
    let mut refs = vec![&mut c];
    let logits = model
        .forward_step_refs(None, &[1], &mut refs, 4)
        .unwrap();
    assert_eq!(logits.shape(), &[1, 32]);
}

/// DESIGN.md §13 parity contract, sharing off: a paged cache drawn
/// from a shared `PagePool` yields bit-identical logits *and* KV
/// contents to the default private-pool cache for any page size (one
/// row per page up to one giant page) and any worker count, and every
/// page returns to the pool when the cache drops.
#[test]
fn paged_cache_is_bitwise_invariant_to_page_size_and_workers() {
    let mut rng = Pcg::new(0x9A6E, 8);
    let tokens = random_tokens(&mut rng, S);
    let (_params, model, _rm) = build_models(77, 4);
    let hd = D / NH;
    for kv_bits in [4u32, 16] {
        let mut base_cache = model.new_cache(kv_bits);
        let base = chunked_logits(&model, &tokens, &mut base_cache, 4, 5);
        for prows in [1usize, 3, 64, 1024] {
            let pool = PagePool::new(hd, kv_bits, prows, 0);
            {
                let mut cache = model.new_cache_in(kv_bits, &pool);
                let got =
                    chunked_logits(&model, &tokens, &mut cache, 4, 5);
                assert_eq!(got.data(), base.data(),
                           "kv{kv_bits} page_rows {prows}: logits");
                assert_caches_equal(&cache, &base_cache,
                                    &format!("kv{kv_bits} R{prows}"));
            }
            let g = pool.gauges();
            assert_eq!((g.refs_live, g.pages_live), (0, 0),
                       "kv{kv_bits} page_rows {prows}: pages leaked \
                        after cache drop");
        }
        // Worker count is orthogonal to paging: a pooled forward over
        // an awkward page size still matches the serial baseline.
        for nw in [2usize, 8] {
            let tp = ThreadPool::new(nw, 8 * nw);
            let pool = PagePool::new(hd, kv_bits, 3, 0);
            let mut cache = model.new_cache_in(kv_bits, &pool);
            let got = pooled_logits(&model, &tokens, &mut cache, &tp);
            assert_eq!(got.data(), base.data(),
                       "kv{kv_bits} {nw} workers: logits");
            assert_caches_equal(&cache, &base_cache,
                                &format!("kv{kv_bits} {nw} workers"));
        }
    }
}

/// `PagePool` bookkeeping under a seeded random op soup: pushes into
/// several stores, snapshot-shares of random pages, releases, and
/// whole-store drops. Invariants checked per op (refs >= live pages,
/// peak >= live) and at the end (shared snapshots still decode to
/// their captured bytes — copy-on-write never mutated a shared page —
/// and the drained pool balances to zero with `free == peak`, i.e. no
/// double-free and no leak).
#[test]
fn page_pool_invariants_under_random_ops() {
    const PROWS: usize = 4;
    const DIM: usize = 8;
    let pool = PagePool::new(DIM, 4, PROWS, 0);
    // Decode all PROWS rows of one raw page through a throwaway
    // adopter table — the only window onto page bytes from out here.
    let read_page = |pr: &PageRef| -> Vec<f32> {
        let mut t = QRows::with_pool(Arc::clone(&pool));
        t.adopt_page(pool.retain(pr));
        let mut out = vec![0.0f32; PROWS * DIM];
        t.dequant_block_into(0, PROWS, &mut out);
        out
    };
    let mut rng = Pcg::new(0xF001 ^ 0x9E37, 13);
    let mut stores: Vec<QRows> = (0..3)
        .map(|_| QRows::with_pool(Arc::clone(&pool)))
        .collect();
    let mut held: Vec<(PageRef, Vec<f32>)> = Vec::new();
    for op in 0..400 {
        match rng.below(5) {
            // Biased toward growth so pages actually turn over.
            0 | 1 | 2 => {
                let s = rng.below_usize(stores.len());
                let row: Vec<f32> =
                    (0..DIM).map(|_| rng.normal()).collect();
                stores[s].push(&row);
            }
            3 => {
                // Snapshot-share a random page of a random store.
                let s = rng.below_usize(stores.len());
                if stores[s].n_pages() > 0 {
                    let p = rng.below_usize(stores[s].n_pages());
                    let pr = stores[s].page_ref(p);
                    let bytes = read_page(&pr);
                    held.push((pr, bytes));
                } else if !held.is_empty() {
                    let h = held.swap_remove(
                        rng.below_usize(held.len()));
                    pool.release(h.0);
                }
            }
            _ => {
                // Drop-and-replace a whole store: its table releases
                // every page it references.
                let s = rng.below_usize(stores.len());
                stores[s] = QRows::with_pool(Arc::clone(&pool));
            }
        }
        let g = pool.gauges();
        assert!(g.refs_live >= g.pages_live,
                "op {op}: refs {} < live pages {}", g.refs_live,
                g.pages_live);
        assert!(g.pages_peak >= g.pages_live,
                "op {op}: peak below live");
        assert_eq!(g.pages_shared, g.refs_live - g.pages_live,
                   "op {op}: shared gauge out of step");
    }
    // Copy-on-write proof: despite every push and drop above, each
    // held snapshot still decodes to the exact bytes captured when
    // the share was taken.
    for (i, (pr, bytes)) in held.iter().enumerate() {
        assert_eq!(&read_page(pr), bytes,
                   "held snapshot {i} mutated in place");
    }
    for (pr, _) in held.drain(..) {
        pool.release(pr);
    }
    stores.clear();
    let g = pool.gauges();
    assert_eq!((g.refs_live, g.pages_live), (0, 0),
               "drained pool still holds refs/pages");
    assert_eq!(g.free_pages, g.pages_peak,
               "every buffer ever created is parked on the free list");
}
