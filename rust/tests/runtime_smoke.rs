//! Integration: load real artifacts through the manifest-driven runtime,
//! execute them on the PJRT CPU client, and check the numerics end to
//! end (python AOT -> HLO text -> rust compile -> execute).
//!
//! All shapes come from the manifest, so these tests pass under any
//! preset (`make artifacts OSP_PRESET=tiny|small|e2e`).

mod common;

use common::{engine_or_skip, init_params, tokens_for};

use osp::runtime::HostValue;
use osp::tensor::linalg;
use osp::tensor::stats::excess_kurtosis;

#[test]
fn ns_artifact_matches_rust_ns() {
    let Some(eng) = engine_or_skip() else { return };
    // Pick any ns_* artifact and compare against the in-tree NS.
    let name = eng
        .manifest()
        .artifacts
        .keys()
        .find(|n| n.starts_with("ns_"))
        .expect("no ns_* artifact")
        .clone();
    let exe = eng.load(&name).unwrap();
    let shape = exe.spec.inputs[0].shape.clone();
    let mut rng = osp::util::rng::Pcg::new(42, 0);
    let mut g = osp::tensor::Tensor::zeros(&shape);
    rng.fill_normal(g.data_mut(), 1.0);

    let out = exe.run(&[HostValue::F32(g.clone())]).unwrap();
    let got = out[0].as_f32().unwrap();
    let want = linalg::ns_orthogonalize(&g, 5);
    osp::util::prop::all_close(got.data(), want.data(), 5e-3)
        .expect("ns artifact vs rust ns");
}

#[test]
fn init_evalq_roundtrip_and_quant_degrades() {
    let Some(eng) = engine_or_skip() else { return };
    let arch = "rmsnorm_plain";
    let params = init_params(&eng, arch, 7);
    let m = eng.manifest();

    let evalq = eng.load(&format!("evalq_{arch}")).unwrap();
    let toks = tokens_for(&eng, m.batch_eval, 123);

    let mut run = |a_lv: f32, kv_lv: f32, had: f32| -> (f32, f32) {
        let mut inputs: Vec<HostValue> =
            params.iter().cloned().map(HostValue::F32).collect();
        inputs.push(toks.clone());
        inputs.push(HostValue::scalar(a_lv));
        inputs.push(HostValue::scalar(kv_lv));
        inputs.push(HostValue::scalar(had));
        let out = evalq.run(&inputs).unwrap();
        let nll = out[0].as_f32().unwrap().data()[0];
        let count = out[1].as_f32().unwrap().data()[0];
        (nll, count)
    };

    let off = (1u32 << 20) as f32;
    let (nll_fp, count) = run(off, off, 0.0);
    assert!(count > 0.0);
    let ppl_fp = (nll_fp / count).exp();
    // Random init: perplexity near vocab size.
    let v = m.model.vocab_size as f32;
    assert!(ppl_fp > v * 0.3 && ppl_fp < v * 3.0, "ppl {ppl_fp} vocab {v}");

    // 4-bit activations must not *improve* the loss.
    let (nll_q, _) = run(7.0, 7.0, 0.0);
    assert!(nll_q >= nll_fp * 0.99, "quant improved nll?! {nll_q} {nll_fp}");
}

#[test]
fn train_step_reduces_loss_and_reports_kurtosis() {
    let Some(eng) = engine_or_skip() else { return };
    let arch = "rmsnorm_plain";
    let m = eng.manifest();
    let opt = "adam";
    let train = eng.load(&format!("train_{opt}_{arch}")).unwrap();

    let mut params = init_params(&eng, arch, 3);
    let mut opt_state = osp::runtime::init_opt_state(
        m.opt_leaves(arch, opt).unwrap());

    let n_p = params.len();
    let n_o = opt_state.len();
    let toks = tokens_for(&eng, m.batch_train, 55);

    let mut losses = Vec::new();
    for _ in 0..3 {
        let mut inputs: Vec<HostValue> =
            params.iter().cloned().map(HostValue::F32).collect();
        inputs.extend(opt_state.iter().cloned().map(HostValue::F32));
        inputs.push(toks.clone());
        inputs.push(HostValue::scalar(1e-3));
        let out = train.run(&inputs).unwrap();
        params = out[..n_p]
            .iter()
            .map(|v| v.as_f32().unwrap().clone())
            .collect();
        opt_state = out[n_p..n_p + n_o]
            .iter()
            .map(|v| v.as_f32().unwrap().clone())
            .collect();
        let loss = out[n_p + n_o].as_f32().unwrap().data()[0];
        let kurt = out[n_p + n_o + 1].as_f32().unwrap();
        assert_eq!(kurt.len(), 2 * m.model.n_layers);
        assert!(loss.is_finite());
        losses.push(loss);
    }
    // Same batch re-fed: loss must drop monotonically-ish.
    assert!(losses[2] < losses[0],
            "loss did not decrease: {losses:?}");
    // Step counter advanced.
    let step_idx = m
        .opt_leaves(arch, opt)
        .unwrap()
        .iter()
        .position(|l| l.name == "step")
        .unwrap();
    assert_eq!(opt_state[step_idx].data()[0], 3.0);
}

#[test]
fn probe_artifact_emits_activation_tensors() {
    let Some(eng) = engine_or_skip() else { return };
    let arch = "ssnorm_embproj";
    let m = eng.manifest();
    let probe = eng.load(&format!("probe_{arch}")).unwrap();
    let params = init_params(&eng, arch, 11);
    let mut inputs: Vec<HostValue> =
        params.into_iter().map(HostValue::F32).collect();
    inputs.push(tokens_for(&eng, m.batch_probe, 9));
    let out = probe.run(&inputs).unwrap();
    // kurt, mhsa_in, ffn_in, q_mag, k_mag, attn_logits
    assert_eq!(out.len(), 6);
    let mhsa_in = out[1].as_f32().unwrap();
    assert_eq!(mhsa_in.shape()[0], m.probe_layers.len());
    // At random init the residual stream is approximately gaussian.
    let k = excess_kurtosis(mhsa_in.data());
    assert!(k.abs() < 30.0, "init kurtosis implausible: {k}");
    let logits = out[5].as_f32().unwrap();
    assert_eq!(logits.shape().len(), 5);
}

#[test]
fn grad_artifact_matches_train_direction() {
    let Some(eng) = engine_or_skip() else { return };
    let arch = "rmsnorm_plain";
    let m = eng.manifest();
    let grad = eng.load(&format!("grad_{arch}")).unwrap();
    let params = init_params(&eng, arch, 3);
    let mut inputs: Vec<HostValue> =
        params.iter().cloned().map(HostValue::F32).collect();
    let toks = tokens_for(&eng, m.batch_train, 55);
    inputs.push(toks);
    let out = grad.run(&inputs).unwrap();
    let n_p = params.len();
    assert_eq!(out.len(), n_p + 2);
    // Gradients finite and not all-zero.
    let gnorm: f32 = out[..n_p]
        .iter()
        .map(|g| g.as_f32().unwrap().frobenius_norm())
        .sum();
    assert!(gnorm.is_finite() && gnorm > 1e-4, "grad norm {gnorm}");
}
