//! Serve front-end robustness contract (DESIGN.md §12, ISSUE 7
//! acceptance): under seeded chaos — client aborts, malformed and
//! oversized requests, slow-loris headers, tiny deadlines, queue-full
//! floods — the server never panics or leaks batch slots, every
//! rejection is a well-formed HTTP response, `/metrics` reconciles
//! with client-observed outcomes, surviving streams are bit-identical
//! to an unperturbed run, and `/admin/drain` terminates cleanly.
//!
//! All servers bind 127.0.0.1:0 (ephemeral ports), so the suite can
//! run in parallel with itself and with CI neighbors.

use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use osp::infer::{InferConfig, InferModel};
use osp::serve::chaos::ChaosSpec;
use osp::serve::http::{header, ClientConn};
use osp::serve::load::{self, LoadOpts};
use osp::serve::{ServeOpts, Server};
use osp::util::json::Json;

fn tiny_cfg() -> InferConfig {
    InferConfig { vocab_size: 96, d_model: 32, n_layers: 2, n_heads: 2,
                  d_ff: 40, rope_theta: 10000.0, norm_ss: true,
                  embproj: false }
}

/// Synthetic quantized model + server on an ephemeral port. The model
/// is deterministic from (cfg, seed): two spawns with the same inputs
/// serve bit-identical engines, which is what the parity tests lean on.
fn spawn_server(cfg: &InferConfig, model_seed: u64,
                tweak: impl FnOnce(&mut ServeOpts)) -> Server {
    let model = InferModel::synthetic(cfg, model_seed).quantized(4);
    let mut opts = ServeOpts {
        addr: "127.0.0.1:0".into(),
        header_timeout_ms: 400,
        write_timeout_ms: 2_000,
        ..ServeOpts::default()
    };
    tweak(&mut opts);
    Server::spawn(model, opts).expect("spawn server")
}

#[derive(Debug)]
struct GenOutcome {
    status: u16,
    retry_after: bool,
    tokens: Vec<i64>,
    /// `"done"`, `"deadline"`, another error string, or None if the
    /// stream ended without a terminal event.
    terminal: Option<String>,
}

/// One well-behaved streamed /generate exchange.
fn gen_stream(addr: &str, prompt: &[i32], max_new: usize,
              timeout_ms: u64) -> Result<GenOutcome, String> {
    let stream =
        TcpStream::connect(addr).map_err(|e| e.to_string())?;
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .map_err(|e| e.to_string())?;
    stream.set_nodelay(true).ok();
    let mut conn = ClientConn::new(stream);
    let body = format!(
        "{{\"prompt\":{prompt:?},\"max_new\":{max_new},\
         \"timeout_ms\":{timeout_ms}}}");
    conn.send_request("POST", "/generate", &body)
        .map_err(|e| e.to_string())?;
    let (status, headers) = conn.read_head().map_err(|e| {
        e.to_string()
    })?;
    let retry_after = header(&headers, "retry-after").is_some();
    let mut out = GenOutcome { status, retry_after, tokens: Vec::new(),
                               terminal: None };
    if status != 200 {
        return Ok(out);
    }
    loop {
        let Some(line) =
            conn.next_chunk().map_err(|e| e.to_string())?
        else {
            return Ok(out);
        };
        let ev = Json::parse(line.trim()).map_err(|e| {
            format!("bad event '{line}': {e}")
        })?;
        if let Some(t) = ev.get("token").and_then(|v| v.as_f64()) {
            out.tokens.push(t as i64);
        } else if ev.get("done").is_some() {
            out.terminal = Some("done".into());
        } else if let Some(e) =
            ev.get("error").and_then(|v| v.as_str())
        {
            out.terminal = Some(e.to_string());
        }
    }
}

fn metric(doc: &Json, key: &str) -> f64 {
    doc.get("metrics")
        .and_then(|m| m.get(key))
        .and_then(|v| v.as_f64())
        .unwrap_or(f64::NAN)
}

/// Poll /metrics until nothing is in flight (aborted sequences are
/// cancelled lazily, on their next emission attempt).
fn settle(addr: &str) -> Json {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (st, doc) =
            load::http_get(addr, "/metrics").expect("GET /metrics");
        assert_eq!(st, 200);
        if metric(&doc, "in_flight") == 0.0
            && metric(&doc, "queue_depth") == 0.0
        {
            return doc;
        }
        assert!(Instant::now() < deadline,
                "in-flight work never drained: {}", doc.dump());
        thread::sleep(Duration::from_millis(50));
    }
}

/// The chaos matrix: seeded faults of every class against one server.
/// Afterwards the server is still live, counters obey conservation
/// (admitted == completed + timed_out + cancelled + failed, in-flight
/// 0 — i.e. no leaked batch slots), server and client tallies
/// reconcile, and drain exits cleanly.
#[test]
fn chaos_matrix_server_survives_and_metrics_reconcile() {
    let cfg = tiny_cfg();
    let server = spawn_server(&cfg, 11, |o| {
        o.max_batch = 4;
        o.queue_cap = 4;
    });
    let addr = server.addr().to_string();
    let (st, health) =
        load::http_get(&addr, "/healthz").expect("healthz");
    assert_eq!(st, 200);
    assert_eq!(health.get("ok").and_then(|v| v.as_bool()), Some(true));

    let chaos = ChaosSpec::parse(
        "seed=5,abort=0.25,malformed=0.15,oversize=0.1,slowloris=0.1,\
         tiny_deadline=0.15,hold_ms=900")
        .expect("chaos spec");
    let opts = LoadOpts { addr: addr.clone(), clients: 6, requests: 6,
                          prompt_len: 6, max_new: 8,
                          timeout_ms: 10_000, chaos,
                          chaos_label: "matrix".into(), seed: 3 };
    let doc = load::run_load(&opts).expect("run_load");
    let row = doc.get("rows").and_then(|r| r.as_arr()).unwrap()[0]
        .clone();
    let client = |k: &str| {
        row.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
    };
    assert_eq!(client("requests"), 36.0, "{}", row.dump());
    assert!(client("completed") > 0.0,
            "chaos drowned every request: {}", row.dump());
    assert_eq!(client("errors"), 0.0,
               "ill-formed server responses: {}", row.dump());

    // Server still live and every slot returned.
    let after = settle(&addr);
    let g = |k: &str| metric(&after, k);
    assert_eq!(g("admitted"),
               g("completed") + g("timed_out") + g("cancelled")
                   + g("failed"),
               "conservation violated: {}", after.dump());
    assert_eq!(g("failed"), 0.0, "{}", after.dump());
    assert_eq!(g("active_seqs"), 0.0, "{}", after.dump());

    // Client/server reconciliation. Client-side aborts may still
    // complete server-side (the stream fit the event buffer), so the
    // relations are one-sided where the race allows it.
    let server_rejected = g("rejected_full") + g("rejected_bad")
        + g("rejected_oversize")
        + g("rejected_slow")
        + g("rejected_draining");
    assert_eq!(server_rejected, client("rejected"),
               "rejections disagree: client row {} server {}",
               row.dump(), after.dump());
    assert!(g("completed") >= client("completed"),
            "client saw more completions than the server recorded");
    assert!(g("timed_out") + g("cancelled")
                >= client("deadline"),
            "client deadlines unaccounted: {}", after.dump());
    assert!(g("cancelled") + g("completed") + g("timed_out")
                >= client("aborted"),
            "aborted requests unaccounted: {}", after.dump());

    // Drain terminates cleanly.
    let (st, drain) =
        load::http_post(&addr, "/admin/drain", "").expect("drain");
    assert_eq!(st, 200);
    assert_eq!(drain.get("draining").and_then(|v| v.as_bool()),
               Some(true));
    server.join();
}

/// Acceptance bit-parity: streams served while chaos clients abort,
/// flood, and time out around them are bit-identical to the same
/// requests against an unperturbed server over the same model.
#[test]
fn surviving_streams_bit_identical_under_chaos() {
    let cfg = tiny_cfg();
    let probes: Vec<Vec<i32>> = (0..4)
        .map(|i| vec![1 + i, 2 + i, 3, 5])
        .collect();

    // Unperturbed run.
    let baseline: Vec<Vec<i64>> = {
        let server = spawn_server(&cfg, 23, |o| {
            o.max_batch = 4;
            o.queue_cap = 8;
        });
        let addr = server.addr().to_string();
        let streams = probes
            .iter()
            .map(|p| {
                let out =
                    gen_stream(&addr, p, 8, 20_000).expect("probe");
                assert_eq!(out.status, 200, "{out:?}");
                assert_eq!(out.terminal.as_deref(), Some("done"),
                           "{out:?}");
                out.tokens
            })
            .collect();
        server.drain();
        server.join();
        streams
    };

    // Same model, same probes — now with a chaos load alongside.
    let server = spawn_server(&cfg, 23, |o| {
        o.max_batch = 4;
        o.queue_cap = 8;
    });
    let addr = server.addr().to_string();
    let chaos_addr = addr.clone();
    let chaos_thread = thread::spawn(move || {
        let chaos = ChaosSpec::parse(
            "seed=9,abort=0.4,malformed=0.2,tiny_deadline=0.2")
            .expect("chaos spec");
        let opts = LoadOpts { addr: chaos_addr, clients: 4,
                              requests: 5, prompt_len: 5, max_new: 6,
                              timeout_ms: 8_000, chaos,
                              chaos_label: "parity".into(), seed: 4 };
        load::run_load(&opts).expect("chaos load")
    });
    let got: Vec<Vec<i64>> = probes
        .iter()
        .map(|p| loop {
            let out = gen_stream(&addr, p, 8, 20_000).expect("probe");
            if out.status == 200
                && out.terminal.as_deref() == Some("done")
            {
                break out.tokens;
            }
            // Under flood a probe may catch a full queue; anything
            // else well-formed would be a deadline, which the long
            // timeout rules out.
            assert_eq!(out.status, 503, "unexpected probe outcome \
                                         {out:?}");
            thread::sleep(Duration::from_millis(30));
        })
        .collect();
    chaos_thread.join().expect("chaos thread");
    assert_eq!(got, baseline,
               "chaos perturbed surviving token streams");
    server.drain();
    server.join();
}

/// A 10-way simultaneous flood against max_batch 1 / queue_cap 1:
/// every response is well-formed, the overflow gets 503s with a
/// Retry-After header, and nothing wedges or panics.
#[test]
fn queue_full_flood_gets_well_formed_503s() {
    let cfg = tiny_cfg();
    let server = spawn_server(&cfg, 31, |o| {
        o.max_batch = 1;
        o.queue_cap = 1;
        o.max_new_cap = 512;
    });
    let addr = server.addr().to_string();
    let outcomes: Vec<GenOutcome> = thread::scope(|s| {
        let handles: Vec<_> = (0..10)
            .map(|i| {
                let addr = addr.clone();
                s.spawn(move || {
                    gen_stream(&addr, &[1, 2, 3, (i % 7) as i32], 128,
                               30_000)
                        .expect("flood request")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let full: usize =
        outcomes.iter().filter(|o| o.status == 503).count();
    let done = outcomes
        .iter()
        .filter(|o| {
            o.status == 200 && o.terminal.as_deref() == Some("done")
        })
        .count();
    assert_eq!(full + done, outcomes.len(),
               "unexpected outcomes: {outcomes:?}");
    assert!(full >= 1,
            "10-way flood against a 2-slot server produced no 503s");
    for o in outcomes.iter().filter(|o| o.status == 503) {
        assert!(o.retry_after, "503 without Retry-After: {o:?}");
    }
    let after = settle(&addr);
    assert_eq!(metric(&after, "rejected_full"), full as f64, "{}",
               after.dump());
    server.drain();
    server.join();
}

/// Deadline expiry evicts a sequence mid-decode (504 or a mid-stream
/// deadline event), counts as timed_out, and leaves a concurrent
/// batchmate's stream bit-identical to an unperturbed run.
#[test]
fn deadline_evicts_mid_decode_without_disturbing_batchmates() {
    let cfg = InferConfig { vocab_size: 128, d_model: 96, n_layers: 3,
                            n_heads: 4, d_ff: 128,
                            rope_theta: 10000.0, norm_ss: true,
                            embproj: false };
    let mate_prompt = vec![7, 8, 9, 10];

    let baseline = {
        let server = spawn_server(&cfg, 47, |o| o.max_batch = 4);
        let addr = server.addr().to_string();
        let out = gen_stream(&addr, &mate_prompt, 12, 30_000)
            .expect("baseline");
        assert_eq!(out.terminal.as_deref(), Some("done"), "{out:?}");
        server.drain();
        server.join();
        out.tokens
    };

    let server = spawn_server(&cfg, 47, |o| {
        o.max_batch = 4;
        o.max_new_cap = 10_000;
    });
    let addr = server.addr().to_string();
    let victim_addr = addr.clone();
    // An 8000-token request under a 25 ms deadline cannot finish: it
    // must be evicted mid-decode.
    let victim = thread::spawn(move || {
        gen_stream(&victim_addr, &[1, 2, 3], 8000, 25)
            .expect("victim request")
    });
    thread::sleep(Duration::from_millis(5));
    let mate = gen_stream(&addr, &mate_prompt, 12, 30_000)
        .expect("batchmate");
    let vout = victim.join().expect("victim thread");
    let deadline_seen = vout.status == 504
        || vout.terminal.as_deref() == Some("deadline");
    assert!(deadline_seen, "victim was not evicted: {vout:?}");
    assert_eq!(mate.terminal.as_deref(), Some("done"), "{mate:?}");
    assert_eq!(mate.tokens, baseline,
               "deadline eviction disturbed a batchmate's stream");
    let after = settle(&addr);
    assert_eq!(metric(&after, "timed_out"), 1.0, "{}", after.dump());
    server.drain();
    server.join();
}

/// Malformed inputs of several shapes: every one gets a well-formed
/// 4xx and the server keeps answering afterwards.
#[test]
fn malformed_requests_get_400s_never_panics() {
    let cfg = tiny_cfg();
    let server = spawn_server(&cfg, 13, |o| o.max_batch = 2);
    let addr = server.addr().to_string();
    let cases: &[(&str, u16)] = &[
        ("{not json", 400),
        ("{\"max_new\":4}", 400),                   // missing prompt
        ("{\"prompt\":[1,2],\"max_new\":0}", 400),  // zero max_new
        ("{\"prompt\":[99999]}", 400),              // out of vocab
        ("{\"prompt\":[-1]}", 400),                 // negative token
        ("{\"prompt\":[1.5]}", 400),                // non-integer
        ("{\"prompt\":\"hi\"}", 400),               // wrong type
    ];
    for (body, want) in cases {
        let (st, err) = load::http_post(&addr, "/generate", body)
            .expect("post");
        assert_eq!(st, *want, "body {body}: {}", err.dump());
        assert!(err.get("error").is_some(), "{}", err.dump());
    }
    let (st, _) =
        load::http_post(&addr, "/nope", "{}").expect("post 404");
    assert_eq!(st, 404);
    // Still serving real work afterwards.
    let out = gen_stream(&addr, &[1, 2, 3], 4, 10_000).expect("gen");
    assert_eq!(out.terminal.as_deref(), Some("done"), "{out:?}");
    assert_eq!(out.tokens.len(), 4, "{out:?}");
    let after = settle(&addr);
    assert_eq!(metric(&after, "rejected_bad"),
               cases.len() as f64 + 1.0, "{}", after.dump());
    server.drain();
    server.join();
}

/// Draining rejects new work with a 503 while finishing nothing is
/// in flight, and join() returns promptly.
#[test]
fn drain_rejects_new_work_then_exits() {
    let cfg = tiny_cfg();
    let server = spawn_server(&cfg, 17, |o| o.max_batch = 2);
    let addr = server.addr().to_string();
    let out = gen_stream(&addr, &[3, 1, 4], 4, 10_000).expect("gen");
    assert_eq!(out.terminal.as_deref(), Some("done"));
    let (st, _) =
        load::http_post(&addr, "/admin/drain", "").expect("drain");
    assert_eq!(st, 200);
    // The acceptor may already have exited; if it still answers, the
    // answer must be a draining 503.
    if let Ok(after) = gen_stream(&addr, &[3, 1, 4], 4, 10_000) {
        assert_eq!(after.status, 503, "{after:?}");
    }
    server.join();
}
