//! Serial/parallel parity properties for the kernel layer (DESIGN.md
//! §6): for every kernel, every odd shape, and worker counts 1/2/8, the
//! parallel result must be *bit-identical* to the serial one — the
//! partitioning contract says each output row / reduction block is
//! computed by exactly one job with the same arithmetic as the serial
//! path.

use osp::tensor::linalg;
use osp::tensor::par;
use osp::tensor::stats;
use osp::tensor::Tensor;
use osp::util::prop;
use osp::util::rng::Pcg;
use osp::util::threadpool::ThreadPool;

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];

fn randn(shape: &[usize], rng: &mut Pcg) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

/// Shapes that stress partition edges: degenerate dims, sizes far from
/// any block multiple, and one comfortably large case.
fn odd_dims(rng: &mut Pcg) -> (usize, usize, usize) {
    let pick = |rng: &mut Pcg| -> usize {
        match rng.below(6) {
            0 => 1,
            1 => 2,
            2 => 3,
            3 => 17,
            4 => 33,
            _ => 65,
        }
    };
    (pick(rng), pick(rng), pick(rng))
}

#[test]
fn matmul_parity_odd_shapes_and_workers() {
    for &nw in &WORKER_COUNTS {
        let pool = ThreadPool::new(nw, 4 * nw.max(4));
        prop::check("matmul parity", 24, 0xA1 + nw as u64, |rng| {
            let (m, k, n) = odd_dims(rng);
            (randn(&[m, k], rng), randn(&[k, n], rng))
        }, |(a, b)| {
            let serial = par::matmul_with(None, a, b);
            let parallel = par::matmul_with(Some(&pool), a, b);
            if serial.data() != parallel.data() {
                return Err(format!(
                    "matmul parity broke at {:?} @ {:?} ({nw} workers)",
                    a.shape(), b.shape()));
            }
            Ok(())
        });
    }
}

#[test]
fn matmul_transb_parity_and_equivalence() {
    for &nw in &WORKER_COUNTS {
        let pool = ThreadPool::new(nw, 4 * nw.max(4));
        prop::check("matmul_transb parity", 24, 0xB2 + nw as u64, |rng| {
            let (m, k, n) = odd_dims(rng);
            (randn(&[m, k], rng), randn(&[n, k], rng))
        }, |(a, b)| {
            let serial = par::matmul_transb_with(None, a, b);
            let parallel = par::matmul_transb_with(Some(&pool), a, b);
            if serial.data() != parallel.data() {
                return Err(format!("transb parity broke ({nw} workers)"));
            }
            // And the algebraic identity vs an explicit transpose —
            // same accumulation order, so bit-exact too.
            let explicit =
                par::matmul_with(None, a, &linalg::transpose(b));
            if explicit.data() != serial.data() {
                return Err("transb != matmul(a, b^T)".to_string());
            }
            Ok(())
        });
    }
}

#[test]
fn matvec_parity() {
    for &nw in &WORKER_COUNTS {
        let pool = ThreadPool::new(nw, 4 * nw.max(4));
        prop::check("matvec parity", 24, 0xC3 + nw as u64, |rng| {
            let (m, n, _) = odd_dims(rng);
            let a = randn(&[m, n], rng);
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            (a, x)
        }, |(a, x)| {
            if par::matvec_with(None, a, x)
                != par::matvec_with(Some(&pool), a, x)
            {
                return Err(format!("matvec parity broke ({nw} workers)"));
            }
            Ok(())
        });
    }
}

#[test]
fn hadamard_parity_including_non_pow2() {
    for &nw in &WORKER_COUNTS {
        let pool = ThreadPool::new(nw, 4 * nw.max(4));
        // 1xN, Nx1, and blocked (non-power-of-two cols) shapes.
        for shape in [[1usize, 48], [7, 1], [5, 176], [33, 64]] {
            let mut rng = Pcg::new(0xD4 + nw as u64, shape[1] as u64);
            let x = randn(&shape, &mut rng);
            let serial = par::hadamard_rows_with(None, &x);
            let parallel = par::hadamard_rows_with(Some(&pool), &x);
            assert_eq!(serial.data(), parallel.data(),
                       "hadamard parity {shape:?} ({nw} workers)");
        }
    }
}

#[test]
fn moments_parity_across_workers() {
    for &nw in &WORKER_COUNTS {
        let pool = ThreadPool::new(nw, 4 * nw.max(4));
        // Sizes straddling the 4096-element reduction block boundary.
        for n in [1usize, 5, 4095, 4096, 4097, 20_000] {
            let mut rng = Pcg::new(0xE5, n as u64);
            let data: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let serial = stats::moments_with(None, &data);
            let parallel = stats::moments_with(Some(&pool), &data);
            // f64 partials combined in block order: exact equality.
            assert_eq!(serial.mean.to_bits(), parallel.mean.to_bits(),
                       "mean n={n} ({nw} workers)");
            assert_eq!(serial.var.to_bits(), parallel.var.to_bits(),
                       "var n={n} ({nw} workers)");
            assert_eq!(serial.m3.to_bits(), parallel.m3.to_bits(),
                       "m3 n={n} ({nw} workers)");
            assert_eq!(serial.m4.to_bits(), parallel.m4.to_bits(),
                       "m4 n={n} ({nw} workers)");
            assert_eq!(serial.min, parallel.min);
            assert_eq!(serial.max, parallel.max);
            assert_eq!(serial.n, parallel.n);
        }
    }
}

#[test]
fn dispatching_entry_points_match_serial_kernels() {
    // The public linalg API (auto-dispatch over the shared pool) must
    // agree bitwise with the explicit serial path, whatever OSP_THREADS
    // happens to be in this environment.
    let mut rng = Pcg::new(0xF6, 1);
    let a = randn(&[96, 80], &mut rng);
    let b = randn(&[80, 96], &mut rng);
    assert_eq!(linalg::matmul(&a, &b).data(),
               par::matmul_with(None, &a, &b).data());
    let g = randn(&[64, 48], &mut rng);
    assert_eq!(linalg::matmul_transb(&g, &g).data(),
               par::matmul_transb_with(None, &g, &g).data());
    let x = randn(&[65, 176], &mut rng);
    assert_eq!(linalg::hadamard_rows(&x).data(),
               par::hadamard_rows_with(None, &x).data());
    let data: Vec<f32> = (0..300_000).map(|_| rng.normal()).collect();
    let auto = stats::moments(&data);
    let serial = stats::moments_with(None, &data);
    assert_eq!(auto.m4.to_bits(), serial.m4.to_bits());
    assert_eq!(auto.var.to_bits(), serial.var.to_bits());
}

#[test]
fn newton_schulz_unchanged_by_parallel_dispatch() {
    // ns_orthogonalize now runs on matmul_transb + pool dispatch; its
    // output must stay within the spectrum band the seed pinned.
    let mut rng = Pcg::new(0x17, 9);
    let g = randn(&[24, 16], &mut rng);
    let x = linalg::ns_orthogonalize(&g, 5);
    let gram = linalg::matmul(&linalg::transpose(&x), &x);
    for i in 0..16 {
        let d = gram.at2(i, i);
        assert!((0.3..2.0).contains(&d), "sigma^2 {d}");
    }
}
