//! The parallel kernel layer (DESIGN.md §6): tiled kernels over a
//! process-wide shared [`ThreadPool`].
//!
//! Every hot path of the reproduction — the disaggregated Muon
//! Newton-Schulz outer loop, QuaRot/SpinQuant-lite rotations, GPTQ's
//! Hessian pipeline, and kurtosis telemetry — bottoms out in dense,
//! embarrassingly parallel loops. This module gives them one substrate:
//!
//! * a lazily-initialized shared pool sized by the `OSP_THREADS`
//!   environment variable (default: available parallelism, capped at
//!   [`MAX_DEFAULT_THREADS`]); `OSP_THREADS=1` disables parallelism,
//! * row-block partitioned kernels ([`matmul_with`], [`matmul_transb_with`],
//!   [`matvec_with`], [`hadamard_rows_with`]) plus generic scatter maps
//!   ([`par_map`], [`par_map_mut`]) and element-wise helpers,
//! * a worker-thread guard: kernels invoked from inside a pool job fall
//!   back to serial automatically, so nested parallelism can never starve
//!   the queue (see [`threadpool::on_worker_thread`]).
//!
//! Determinism / parity contract: each output row (or element) is
//! computed by exactly one job with the *same* per-row arithmetic as the
//! serial path, and partitioning never reorders accumulation within a
//! row. Serial and parallel results are therefore bit-identical for any
//! worker count — `rust/tests/par_properties.rs` pins this property.

use std::sync::OnceLock;

use crate::util::threadpool::{self, ThreadPool};

use super::linalg;
use super::Tensor;

/// Default cap on the shared pool size when `OSP_THREADS` is unset: the
/// host kernels saturate memory bandwidth well before high core counts,
/// and the coordinator's own rank pools want headroom.
pub const MAX_DEFAULT_THREADS: usize = 16;

/// Below this many scalar operations a kernel stays serial: pool
/// dispatch costs tens of microseconds, which only amortizes on blocks
/// of ~10^5 operations and up.
pub const PAR_MIN_OPS: usize = 1 << 17;

static SHARED: OnceLock<Option<ThreadPool>> = OnceLock::new();

/// Worker count the shared pool is (or would be) built with:
/// `OSP_THREADS` if set to a positive integer, otherwise the host's
/// available parallelism capped at [`MAX_DEFAULT_THREADS`].
pub fn configured_threads() -> usize {
    match std::env::var("OSP_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
    {
        Some(n) if n > 0 => n,
        _ => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_DEFAULT_THREADS),
    }
}

/// The process-wide shared pool, lazily initialized on first use.
/// `None` when parallelism is disabled (`OSP_THREADS=1` or a
/// single-core host).
pub fn shared_pool() -> Option<&'static ThreadPool> {
    SHARED
        .get_or_init(|| {
            let n = configured_threads();
            (n > 1).then(|| ThreadPool::new(n, 4 * n.max(4)))
        })
        .as_ref()
}

/// The pool a kernel should use right now: the shared pool, unless the
/// caller already runs *on* a pool worker (nested scatters would starve
/// the queue once every worker blocks on sub-jobs).
pub fn active_pool() -> Option<&'static ThreadPool> {
    if threadpool::on_worker_thread() {
        return None;
    }
    shared_pool()
}

/// Dispatch helper: the active pool when the job is worth parallelizing
/// (`ops` scalar operations ≥ [`PAR_MIN_OPS`]), else `None` (serial).
pub fn pool_for_ops(ops: usize) -> Option<&'static ThreadPool> {
    if ops < PAR_MIN_OPS {
        None
    } else {
        active_pool()
    }
}

/// Rows per scatter block: ~4 blocks per worker balances load without
/// drowning the queue in tiny jobs. Deterministic in (rows, workers)
/// only; parity is unaffected because rows are independent. The single
/// blocking policy for every row-partitioned kernel (here, qtensor's
/// fused kernels, GPTQ's tail update).
pub(crate) fn rows_per_block(rows: usize, n_workers: usize) -> usize {
    rows.div_ceil(n_workers.max(1) * 4).max(1)
}

// ---- tiled kernels --------------------------------------------------------

/// C = A @ B, row-block partitioned over `pool` (serial when `None`).
/// Bit-identical to the serial path for any worker count.
pub fn matmul_with(pool: Option<&ThreadPool>, a: &Tensor, b: &Tensor)
                   -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul {:?} @ {:?}", a.shape(), b.shape());
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    match pool {
        Some(p) if m > 1 && n > 0 => {
            let rpb = rows_per_block(m, p.n_workers());
            p.scatter_chunks(c.data_mut(), rpb * n, |ci, chunk| {
                let r0 = ci * rpb;
                for (ri, crow) in chunk.chunks_mut(n).enumerate() {
                    let i = r0 + ri;
                    linalg::matmul_row(&ad[i * k..(i + 1) * k], bd, n, crow);
                }
            });
        }
        _ => {
            let cd = c.data_mut();
            for i in 0..m {
                linalg::matmul_row(&ad[i * k..(i + 1) * k], bd, n,
                                   &mut cd[i * n..(i + 1) * n]);
            }
        }
    }
    c
}

/// C = A @ B^T for A [m, k], B [n, k] — the Gram/polar workhorse; reads
/// both operands row-major with no transpose allocation.
pub fn matmul_transb_with(pool: Option<&ThreadPool>, a: &Tensor, b: &Tensor)
                          -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_transb {:?} @ {:?}^T", a.shape(), b.shape());
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    match pool {
        Some(p) if m > 1 && n > 0 => {
            let rpb = rows_per_block(m, p.n_workers());
            p.scatter_chunks(c.data_mut(), rpb * n, |ci, chunk| {
                let r0 = ci * rpb;
                for (ri, crow) in chunk.chunks_mut(n).enumerate() {
                    let i = r0 + ri;
                    linalg::matmul_transb_row(&ad[i * k..(i + 1) * k], bd, k,
                                              crow);
                }
            });
        }
        _ => {
            let cd = c.data_mut();
            for i in 0..m {
                linalg::matmul_transb_row(&ad[i * k..(i + 1) * k], bd, k,
                                          &mut cd[i * n..(i + 1) * n]);
            }
        }
    }
    c
}

/// y = A @ x, row-partitioned.
pub fn matvec_with(pool: Option<&ThreadPool>, a: &Tensor, x: &[f32])
                   -> Vec<f32> {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert_eq!(n, x.len());
    let mut y = vec![0.0f32; m];
    let ad = a.data();
    let dot = |i: usize| -> f32 {
        ad[i * n..(i + 1) * n].iter().zip(x).map(|(p, q)| p * q).sum()
    };
    match pool {
        Some(p) if m > 1 => {
            let rpb = rows_per_block(m, p.n_workers());
            p.scatter_chunks(&mut y, rpb, |ci, chunk| {
                let r0 = ci * rpb;
                for (ri, out) in chunk.iter_mut().enumerate() {
                    *out = dot(r0 + ri);
                }
            });
        }
        _ => {
            for (i, out) in y.iter_mut().enumerate() {
                *out = dot(i);
            }
        }
    }
    y
}

/// Blocked fast Walsh-Hadamard transform along the last axis,
/// row-partitioned (rows are independent: bit-exact parity).
pub fn hadamard_rows_with(pool: Option<&ThreadPool>, x: &Tensor) -> Tensor {
    let n = x.cols();
    let rows = x.rows();
    let blk = linalg::pow2_block(n);
    let scale = 1.0 / (blk as f32).sqrt();
    let mut out = x.clone();
    if n == 0 || rows == 0 {
        return out;
    }
    match pool {
        Some(p) if rows > 1 => {
            let rpb = rows_per_block(rows, p.n_workers());
            p.scatter_chunks(out.data_mut(), rpb * n, |_ci, chunk| {
                for row in chunk.chunks_mut(n) {
                    linalg::hadamard_row(row, blk, scale);
                }
            });
        }
        _ => {
            for row in out.data_mut().chunks_mut(n) {
                linalg::hadamard_row(row, blk, scale);
            }
        }
    }
    out
}

// ---- generic scatter maps -------------------------------------------------

/// Map `f` over `items` on `pool` (serial when `None`), collecting
/// results in input order. Borrow-friendly: `f` and `items` may
/// reference the caller's stack, unlike [`ThreadPool::scatter`].
pub fn par_map<T, R, F>(pool: Option<&ThreadPool>, items: &[T], f: F)
                        -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    match pool {
        Some(p) if items.len() > 1 => {
            let mut out: Vec<Option<R>> =
                (0..items.len()).map(|_| None).collect();
            p.scatter_chunks(&mut out, 1, |i, slot| {
                slot[0] = Some(f(i, &items[i]));
            });
            out.into_iter()
                .map(|r| r.expect("missing par_map result"))
                .collect()
        }
        _ => items.iter().enumerate().map(|(i, t)| f(i, t)).collect(),
    }
}

/// Apply `f` to each item in place, one pool job per item (serial when
/// `pool` is `None`). The workhorse for "quantize / rotate independent
/// 2-D params" scatters in the quant and optimizer layers.
pub fn par_map_mut<T, F>(pool: Option<&ThreadPool>, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    match pool {
        Some(p) if items.len() > 1 => {
            p.scatter_chunks(items, 1, |i, slot| f(i, &mut slot[0]));
        }
        _ => {
            for (i, t) in items.iter_mut().enumerate() {
                f(i, t);
            }
        }
    }
}

// ---- element-wise helpers -------------------------------------------------

/// dst += src, element-wise; partition-independent, so bit-exact for any
/// worker count. Used by the ring all-reduce accumulate hop.
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    match pool_for_ops(dst.len()) {
        Some(p) => {
            let cl = dst.len().div_ceil(p.n_workers().max(1) * 4).max(1);
            p.scatter_chunks(dst, cl, |ci, chunk| {
                let s0 = ci * cl;
                for (d, s) in chunk.iter_mut()
                    .zip(&src[s0..s0 + chunk.len()])
                {
                    *d += s;
                }
            });
        }
        None => {
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
    }
}

/// data *= s, element-wise (the all-reduce averaging hop).
pub fn scale_in_place(data: &mut [f32], s: f32) {
    match pool_for_ops(data.len()) {
        Some(p) => {
            let cl = data.len().div_ceil(p.n_workers().max(1) * 4).max(1);
            p.scatter_chunks(data, cl, |_ci, chunk| {
                for v in chunk.iter_mut() {
                    *v *= s;
                }
            });
        }
        None => {
            for v in data.iter_mut() {
                *v *= s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed, 3);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn matmul_parity_small_pool() {
        let pool = ThreadPool::new(3, 32);
        for (m, k, n) in [(1, 5, 4), (7, 1, 3), (5, 4, 1), (13, 9, 11)] {
            let a = randn(&[m, k], (m * 100 + k) as u64);
            let b = randn(&[k, n], (k * 100 + n) as u64);
            let serial = matmul_with(None, &a, &b);
            let par = matmul_with(Some(&pool), &a, &b);
            assert_eq!(serial.data(), par.data(), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_transb_matches_explicit_transpose() {
        let a = randn(&[6, 9], 1);
        let b = randn(&[5, 9], 2);
        let want = matmul_with(None, &a, &linalg::transpose(&b));
        let got = matmul_transb_with(None, &a, &b);
        assert_eq!(want.data(), got.data());
    }

    #[test]
    fn matvec_parity() {
        let pool = ThreadPool::new(2, 16);
        let a = randn(&[17, 13], 3);
        let x: Vec<f32> = (0..13).map(|i| i as f32 * 0.25 - 1.0).collect();
        assert_eq!(matvec_with(None, &a, &x),
                   matvec_with(Some(&pool), &a, &x));
    }

    #[test]
    fn hadamard_parity() {
        let pool = ThreadPool::new(4, 16);
        let x = randn(&[9, 176], 4);
        let serial = hadamard_rows_with(None, &x);
        let par = hadamard_rows_with(Some(&pool), &x);
        assert_eq!(serial.data(), par.data());
    }

    #[test]
    fn par_map_preserves_order_and_borrows() {
        let pool = ThreadPool::new(4, 16);
        let base = 7usize; // borrowed by the kernel
        let items: Vec<usize> = (0..37).collect();
        let out = par_map(Some(&pool), &items, |i, &x| x * base + i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * base + i);
        }
    }

    #[test]
    fn par_map_mut_touches_every_item_once() {
        let pool = ThreadPool::new(3, 16);
        let mut items: Vec<u32> = (0..29).collect();
        par_map_mut(Some(&pool), &mut items, |i, v| {
            *v += 1000 * i as u32;
        });
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1000 * i as u32);
        }
    }

    #[test]
    fn elementwise_helpers() {
        let mut d: Vec<f32> = (0..300_000).map(|i| i as f32).collect();
        let s: Vec<f32> = (0..300_000).map(|i| (i % 7) as f32).collect();
        let mut want = d.clone();
        for (a, b) in want.iter_mut().zip(&s) {
            *a += b;
        }
        add_assign(&mut d, &s); // large enough to hit the pool path
        assert_eq!(d, want);
        scale_in_place(&mut d, 0.5);
        for (a, b) in d.iter().zip(&want) {
            assert_eq!(*a, b * 0.5);
        }
    }

    #[test]
    fn nested_kernels_fall_back_to_serial() {
        // A kernel launched from a pool worker must not scatter again.
        let pool = ThreadPool::new(2, 8);
        let flags = par_map(Some(&pool), &[(), ()], |_i, ()| {
            active_pool().is_none()
        });
        assert!(flags.iter().all(|&f| f));
    }

    #[test]
    fn configured_threads_is_positive() {
        assert!(configured_threads() >= 1);
    }
}
