//! Dense f32 tensor library: the host-side math substrate.
//!
//! Everything the coordinator does to weights outside the XLA executables
//! lives here — quantization, rotations, GPTQ's Cholesky solves, the
//! disaggregated Muon outer loop, and statistics. Row-major layout,
//! shape-checked operations, no external dependencies. [`qtensor`] adds
//! the packed low-bit storage + fused dequant kernels the PTQ pipeline
//! deploys (DESIGN.md §7).

pub mod intkern;
pub mod linalg;
pub mod lut;
pub mod par;
pub mod qtensor;
pub mod stats;

use std::fmt;

/// Row-major dense f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} != data len {}", data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(),
                 data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(),
                 data: vec![v; shape.iter().product()] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(&mut f).collect() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows when viewed as a matrix (product of leading dims).
    pub fn rows(&self) -> usize {
        assert!(!self.shape.is_empty());
        self.shape[..self.shape.len() - 1].iter().product()
    }

    /// Last-dimension size.
    pub fn cols(&self) -> usize {
        *self.shape.last().expect("rank-0 tensor has no cols")
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    // ---- elementwise ------------------------------------------------------

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
        self
    }

    pub fn scale(self, s: f32) -> Tensor {
        self.map(|v| v * s)
    }

    /// self += alpha * other (the optimizer outer-loop workhorse).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        let mut out = self.clone();
        out.axpy(1.0, other);
        out
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        let mut out = self.clone();
        out.axpy(-1.0, other);
        out
    }

    pub fn hadamard_product(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    // ---- reductions --------------------------------------------------------

    pub fn dot(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.sum() / self.data.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 5]);
    }

    #[test]
    fn eye_and_reshape() {
        let e = Tensor::eye(3);
        assert_eq!(e.at2(1, 1), 1.0);
        assert_eq!(e.at2(0, 1), 0.0);
        let r = e.reshape(&[9]);
        assert_eq!(r.shape(), &[9]);
    }

    #[test]
    fn axpy_and_arith() {
        let a = Tensor::new(vec![3], vec![1., 2., 3.]);
        let b = Tensor::new(vec![3], vec![10., 20., 30.]);
        let mut c = a.clone();
        c.axpy(0.5, &b);
        assert_eq!(c.data(), &[6., 12., 18.]);
        assert_eq!(a.add(&b).data(), &[11., 22., 33.]);
        assert_eq!(b.sub(&a).data(), &[9., 18., 27.]);
        assert_eq!(a.hadamard_product(&b).data(), &[10., 40., 90.]);
        assert_eq!(a.dot(&b), 140.0);
    }

    #[test]
    fn norms() {
        let t = Tensor::new(vec![2, 2], vec![3., 4., 0., 0.]);
        assert!((t.frobenius_norm() - 5.0).abs() < 1e-6);
        assert_eq!(t.abs_max(), 4.0);
        assert_eq!(t.mean(), 1.75);
    }

    #[test]
    fn rows_for_3d() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.rows(), 6);
        assert_eq!(t.cols(), 4);
    }
}
