//! Byte-granular decode lookup tables (DESIGN.md §10): the microkernel
//! substrate that replaces per-element shift/mask/sign-extend decoding
//! of packed codes with one table lookup per storage *byte*.
//!
//! A 256-entry table maps each packed byte directly to its sign-extended
//! codes: [`LUT4`] yields the 2 nibble codes of a 4-bit-field byte (also
//! used by 3-bit grids, which pack into 4-bit fields), [`LUT2`] the 4
//! crumb codes of a 2-bit-field byte. 8-bit fields need no table — a
//! plain `as i8` cast loop sign-extends them. Entries are `i8` so a
//! whole table is 512 B / 1 KiB and stays L1-resident.
//!
//! Parity contract: for every byte and field position the table entry
//! equals [`super::qtensor::decode`]'s sign-extended code (pinned by the
//! tests below for all 256 bytes), and the dequant helpers multiply
//! `code as f32 * scale` exactly like the per-element path — so every
//! kernel built on these tables is bit-identical to its pre-LUT
//! predecessor. The helpers walk `[j0, j1)` windows byte-granularly:
//! scalar head until the window is byte-aligned, whole-byte body, scalar
//! tail — required because `QTensor::qmatmul_rhs` stripes start
//! mid-byte.

/// Sign-extend the low `sbits` of `field` (const-evaluable twin of the
/// shift pair inside `qtensor::decode`).
const fn sext(field: u8, sbits: u32) -> i8 {
    let sh = 8 - sbits;
    ((field << sh) as i8) >> sh
}

const fn build_lut2() -> [[i8; 4]; 256] {
    let mut t = [[0i8; 4]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut j = 0usize;
        while j < 4 {
            t[b][j] = sext(((b as u8) >> (2 * j as u32)) & 0x3, 2);
            j += 1;
        }
        b += 1;
    }
    t
}

const fn build_lut4() -> [[i8; 2]; 256] {
    let mut t = [[0i8; 2]; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b][0] = sext((b as u8) & 0xF, 4);
        t[b][1] = sext((b as u8) >> 4, 4);
        b += 1;
    }
    t
}

/// byte -> 4 sign-extended 2-bit codes, low crumb first.
pub static LUT2: [[i8; 4]; 256] = build_lut2();

/// byte -> 2 sign-extended 4-bit codes, low nibble first.
pub static LUT4: [[i8; 2]; 256] = build_lut4();

/// Core tiled dequant: fields `[j0, j1)` of a packed row into `out`
/// (`out.len() == j1 - j0`), each value `code as f32 * scale(j)`.
/// Monomorphized per scale source so the per-column (weights) and
/// uniform-scale (KV rows) variants both inline the lookup body.
#[inline]
fn dequant_with<S: Fn(usize) -> f32>(row: &[u8], sbits: u32, j0: usize,
                                     j1: usize, out: &mut [f32], scale: S) {
    debug_assert_eq!(out.len(), j1 - j0);
    match sbits {
        8 => {
            for (o, j) in out.iter_mut().zip(j0..j1) {
                *o = (row[j] as i8) as f32 * scale(j);
            }
        }
        4 => {
            let mut j = j0;
            let mut o = 0usize;
            if j < j1 && (j & 1) == 1 {
                out[o] = LUT4[row[j >> 1] as usize][1] as f32 * scale(j);
                j += 1;
                o += 1;
            }
            while j + 2 <= j1 {
                let c = &LUT4[row[j >> 1] as usize];
                out[o] = c[0] as f32 * scale(j);
                out[o + 1] = c[1] as f32 * scale(j + 1);
                j += 2;
                o += 2;
            }
            if j < j1 {
                out[o] = LUT4[row[j >> 1] as usize][0] as f32 * scale(j);
            }
        }
        2 => {
            let mut j = j0;
            let mut o = 0usize;
            while j < j1 && (j & 3) != 0 {
                out[o] = LUT2[row[j >> 2] as usize][j & 3] as f32 * scale(j);
                j += 1;
                o += 1;
            }
            while j + 4 <= j1 {
                let c = &LUT2[row[j >> 2] as usize];
                out[o] = c[0] as f32 * scale(j);
                out[o + 1] = c[1] as f32 * scale(j + 1);
                out[o + 2] = c[2] as f32 * scale(j + 2);
                out[o + 3] = c[3] as f32 * scale(j + 3);
                j += 4;
                o += 4;
            }
            while j < j1 {
                out[o] = LUT2[row[j >> 2] as usize][j & 3] as f32 * scale(j);
                j += 1;
                o += 1;
            }
        }
        _ => unreachable!("no LUT layout for {sbits}-bit storage"),
    }
}

/// Decode fields `[j0, j1)` of one packed row into raw sign-extended i8
/// codes (no scale) — the integer-kernel decode
/// ([`super::intkern::accumulate_stripe`]). Same byte-granular
/// head/body/tail walk as [`dequant_cols`]; exact by construction.
pub fn decode_cols_i8(row: &[u8], sbits: u32, j0: usize, j1: usize,
                      out: &mut [i8]) {
    debug_assert_eq!(out.len(), j1 - j0);
    match sbits {
        8 => {
            for (o, j) in out.iter_mut().zip(j0..j1) {
                *o = row[j] as i8;
            }
        }
        4 => {
            let mut j = j0;
            let mut o = 0usize;
            if j < j1 && (j & 1) == 1 {
                out[o] = LUT4[row[j >> 1] as usize][1];
                j += 1;
                o += 1;
            }
            while j + 2 <= j1 {
                let c = &LUT4[row[j >> 1] as usize];
                out[o] = c[0];
                out[o + 1] = c[1];
                j += 2;
                o += 2;
            }
            if j < j1 {
                out[o] = LUT4[row[j >> 1] as usize][0];
            }
        }
        2 => {
            let mut j = j0;
            let mut o = 0usize;
            while j < j1 {
                out[o] = LUT2[row[j >> 2] as usize][j & 3];
                j += 1;
                o += 1;
            }
        }
        _ => unreachable!("no LUT layout for {sbits}-bit storage"),
    }
}

/// Dequantize fields `[j0, j1)` of one packed row with per-column
/// scales (`out[t] = code(j0 + t) as f32 * scales[j0 + t]`) — the
/// weight-tensor variant ([`super::qtensor::QTensor`] kernels). When
/// the active SIMD backend has a body for this storage width, codes are
/// decoded vector-wide and scaled in a second pass — bitwise identical
/// (same integer codes, same single f32 multiply per element).
#[inline]
pub fn dequant_cols(row: &[u8], sbits: u32, scales: &[f32], j0: usize,
                    j1: usize, out: &mut [f32]) {
    if super::intkern::simd_decode_codes_f32(row, sbits, j0, j1, out) {
        for (o, &s) in out.iter_mut().zip(&scales[j0..j1]) {
            *o *= s;
        }
        return;
    }
    dequant_with(row, sbits, j0, j1, out, |j| scales[j]);
}

/// Dequantize fields `[j0, j1)` of one packed row with a single row
/// scale — the quantized-KV-cache variant (`model::kv::QRows`).
#[inline]
pub fn dequant_uniform(row: &[u8], sbits: u32, scale: f32, j0: usize,
                       j1: usize, out: &mut [f32]) {
    if super::intkern::simd_decode_codes_f32(row, sbits, j0, j1, out) {
        for o in out.iter_mut() {
            *o *= scale;
        }
        return;
    }
    dequant_with(row, sbits, j0, j1, out, |_| scale);
}

#[cfg(test)]
mod tests {
    use super::super::qtensor::decode;
    use super::*;

    #[test]
    fn luts_match_decode_for_every_byte() {
        for b in 0u16..256 {
            let row = [b as u8];
            for j in 0..4 {
                assert_eq!(LUT2[b as usize][j] as i32, decode(&row, 2, j),
                           "LUT2 byte {b} field {j}");
            }
            for j in 0..2 {
                assert_eq!(LUT4[b as usize][j] as i32, decode(&row, 4, j),
                           "LUT4 byte {b} field {j}");
            }
            assert_eq!((b as u8 as i8) as i32, decode(&row, 8, 0),
                       "8-bit byte {b}");
        }
    }

    #[test]
    fn decode_i8_windows_match_per_element_decode() {
        let bytes: Vec<u8> = (0..23).map(|i| (41 * i + 7) as u8).collect();
        for sbits in [2u32, 4, 8] {
            let cpb = (8 / sbits) as usize;
            let cols = bytes.len() * cpb;
            for j0 in 0..cols {
                for j1 in j0..=cols {
                    let mut out = vec![0i8; j1 - j0];
                    decode_cols_i8(&bytes, sbits, j0, j1, &mut out);
                    for (t, j) in (j0..j1).enumerate() {
                        assert_eq!(out[t] as i32, decode(&bytes, sbits, j),
                                   "{sbits}b [{j0},{j1}) @{j}");
                    }
                }
            }
        }
    }

    #[test]
    fn dequant_windows_match_per_element_decode() {
        // A 23-field row at every storage width, every [j0, j1) window:
        // heads, bodies, and tails all agree with decode().
        let bytes: Vec<u8> = (0..23).map(|i| (37 * i + 11) as u8).collect();
        for sbits in [2u32, 4, 8] {
            let cpb = (8 / sbits) as usize;
            let cols = bytes.len() * cpb;
            let scales: Vec<f32> =
                (0..cols).map(|j| 0.25 + 0.5 * j as f32).collect();
            for j0 in 0..cols {
                for j1 in j0..=cols {
                    let mut out = vec![0.0f32; j1 - j0];
                    dequant_cols(&bytes, sbits, &scales, j0, j1, &mut out);
                    for (t, j) in (j0..j1).enumerate() {
                        let want =
                            decode(&bytes, sbits, j) as f32 * scales[j];
                        assert_eq!(out[t], want, "{sbits}b [{j0},{j1}) @{j}");
                    }
                    let mut uni = vec![0.0f32; j1 - j0];
                    dequant_uniform(&bytes, sbits, 0.625, j0, j1, &mut uni);
                    for (t, j) in (j0..j1).enumerate() {
                        let want = decode(&bytes, sbits, j) as f32 * 0.625;
                        assert_eq!(uni[t], want,
                                   "{sbits}b uniform [{j0},{j1}) @{j}");
                    }
                }
            }
        }
    }
}
