//! Matrix algebra on [`Tensor`]: blocked matmul, transpose, Cholesky,
//! Householder QR, random orthogonal matrices, Newton-Schulz polar
//! factorization, and the blocked Walsh-Hadamard transform.
//!
//! These back GPTQ (Cholesky of the damped Hessian), QuaRot-lite /
//! SpinQuant-lite (orthogonal rotations), EmbProj absorption, and the
//! disaggregated Muon outer loop.
//!
//! The public entry points dispatch serial-vs-parallel by size: above
//! [`par::PAR_MIN_OPS`] scalar operations they run row-block partitioned
//! on the shared pool (see [`super::par`]), with bit-exact parity to the
//! serial path for any worker count.

use super::{par, Tensor};
use crate::util::rng::Pcg;

/// One output row of C = A @ B: crow += arow @ B, in i-k-j order
/// (streams B rows, accumulates into the C row — cache friendly for
/// row-major without an explicit transpose). Branch-free over the values
/// of A so throughput is independent of sparsity; shared by the serial
/// and parallel paths, which is what makes them bit-identical.
#[inline]
pub(crate) fn matmul_row(arow: &[f32], bd: &[f32], n: usize,
                         crow: &mut [f32]) {
    for (kk, &aik) in arow.iter().enumerate() {
        let brow = &bd[kk * n..(kk + 1) * n];
        for (cv, bv) in crow.iter_mut().zip(brow) {
            *cv += aik * bv;
        }
    }
}

/// One output row of C = A @ B^T: crow[j] = arow · B[j, :], with B
/// row-major [n, k]. Accumulation order over k matches [`matmul_row`]'s,
/// so `matmul_transb(a, b)` is bit-identical to
/// `matmul(a, &transpose(b))`.
#[inline]
pub(crate) fn matmul_transb_row(arow: &[f32], bd: &[f32], k: usize,
                                crow: &mut [f32]) {
    for (j, cv) in crow.iter_mut().enumerate() {
        let brow = &bd[j * k..(j + 1) * k];
        *cv = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
    }
}

/// In-place normalized blocked FWHT of one row (block size `blk`, a
/// power of two; `scale` = blk^-1/2).
#[inline]
pub(crate) fn hadamard_row(row: &mut [f32], blk: usize, scale: f32) {
    for chunk in row.chunks_mut(blk) {
        let mut h = 1;
        while h < blk {
            let mut i = 0;
            while i < blk {
                for j in i..i + h {
                    let a = chunk[j];
                    let b = chunk[j + h];
                    chunk[j] = a + b;
                    chunk[j + h] = a - b;
                }
                i += 2 * h;
            }
            h *= 2;
        }
        for v in chunk.iter_mut() {
            *v *= scale;
        }
    }
}

/// Blocked matmul C = A @ B. Panics on shape mismatch. Row-block
/// parallel on the shared pool above the size threshold.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let ops = a.shape()[0] * a.shape()[1] * b.shape()[1];
    par::matmul_with(par::pool_for_ops(ops), a, b)
}

/// C = A @ B^T for A [m, k], B [n, k]: the Gram-matrix form used by the
/// Newton-Schulz iterations; avoids materializing the transpose.
pub fn matmul_transb(a: &Tensor, b: &Tensor) -> Tensor {
    let ops = a.shape()[0] * a.shape()[1] * b.shape()[0];
    par::matmul_transb_with(par::pool_for_ops(ops), a, b)
}

/// Cache-blocked transpose: 32x32 tiles over raw row slices. The naive
/// per-element `at2`/`set2` walk pays a bounds check per element and
/// strides the destination by a full row on every write; tiling keeps
/// both source and destination lines resident for a whole tile.
/// Element-for-element identical to the naive walk (pure data movement
/// — pinned by `transpose_matches_naive`).
pub fn transpose(a: &Tensor) -> Tensor {
    const TILE: usize = 32;
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut t = Tensor::zeros(&[n, m]);
    let ad = a.data();
    let td = t.data_mut();
    for i0 in (0..m).step_by(TILE) {
        let i1 = (i0 + TILE).min(m);
        for j0 in (0..n).step_by(TILE) {
            let j1 = (j0 + TILE).min(n);
            for i in i0..i1 {
                let arow = &ad[i * n..i * n + j1];
                for j in j0..j1 {
                    td[j * m + i] = arow[j];
                }
            }
        }
    }
    t
}

/// y = A @ x for a vector x (row-parallel above the size threshold).
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let ops = a.shape()[0] * a.shape()[1];
    par::matvec_with(par::pool_for_ops(ops), a, x)
}

/// Cholesky factorization A = L L^T for symmetric positive definite A.
/// Returns the lower-triangular L; errors if A is not SPD (non-positive
/// pivot), which GPTQ handles by increasing damping.
pub fn cholesky(a: &Tensor) -> Result<Tensor, String> {
    let n = a.shape()[0];
    assert_eq!(a.shape(), &[n, n]);
    let mut l = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at2(i, j);
            for k in 0..j {
                s -= l.at2(i, k) * l.at2(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    return Err(format!(
                        "cholesky: non-positive pivot {s} at {i}"));
                }
                l.set2(i, j, s.sqrt());
            } else {
                l.set2(i, j, s / l.at2(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve L y = b with lower-triangular L (forward substitution).
pub fn solve_lower(l: &Tensor, b: &[f32]) -> Vec<f32> {
    let n = l.shape()[0];
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.at2(i, k) * y[k];
        }
        y[i] = s / l.at2(i, i);
    }
    y
}

/// Solve L^T x = y with lower-triangular L (back substitution).
pub fn solve_lower_t(l: &Tensor, y: &[f32]) -> Vec<f32> {
    let n = l.shape()[0];
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l.at2(k, i) * x[k];
        }
        x[i] = s / l.at2(i, i);
    }
    x
}

/// Inverse of an SPD matrix via Cholesky (used by GPTQ's Hessian inverse).
pub fn spd_inverse(a: &Tensor) -> Result<Tensor, String> {
    let n = a.shape()[0];
    let l = cholesky(a)?;
    let mut inv = Tensor::zeros(&[n, n]);
    let mut e = vec![0.0f32; n];
    for j in 0..n {
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for i in 0..n {
            inv.set2(i, j, x[i]);
        }
        e[j] = 0.0;
    }
    Ok(inv)
}

/// Householder QR: A (m x n, m >= n) -> (Q m x n thin, R n x n upper).
pub fn qr(a: &Tensor) -> (Tensor, Tensor) {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert!(m >= n, "qr expects m >= n, got {m}x{n}");
    let mut r = a.clone();
    // Accumulate the Householder vectors, then form thin Q by applying
    // the reflections to the first n columns of I.
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the reflector for column k below the diagonal.
        let mut v = vec![0.0f32; m];
        let mut norm2 = 0.0f32;
        for i in k..m {
            let x = r.at2(i, k);
            v[i] = x;
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        if norm < 1e-30 {
            vs.push(v);
            continue;
        }
        let sign = if v[k] >= 0.0 { 1.0 } else { -1.0 };
        v[k] += sign * norm;
        let vnorm2: f32 = v[k..].iter().map(|x| x * x).sum();
        if vnorm2 < 1e-30 {
            vs.push(v);
            continue;
        }
        // Apply (I - 2 v v^T / v^T v) to R.
        for j in k..n {
            let mut dot = 0.0f32;
            for i in k..m {
                dot += v[i] * r.at2(i, j);
            }
            let c = 2.0 * dot / vnorm2;
            for i in k..m {
                let val = r.at2(i, j) - c * v[i];
                r.set2(i, j, val);
            }
        }
        vs.push(v);
    }
    // Thin Q: apply reflections in reverse to I(m x n).
    let mut q = Tensor::zeros(&[m, n]);
    for j in 0..n {
        q.set2(j, j, 1.0);
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f32 = v[k..].iter().map(|x| x * x).sum();
        if vnorm2 < 1e-30 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0f32;
            for i in k..m {
                dot += v[i] * q.at2(i, j);
            }
            let c = 2.0 * dot / vnorm2;
            for i in k..m {
                let val = q.at2(i, j) - c * v[i];
                q.set2(i, j, val);
            }
        }
    }
    // Zero R's subdiagonal and truncate to n x n.
    let mut rr = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in i..n {
            rr.set2(i, j, r.at2(i, j));
        }
    }
    (q, rr)
}

/// Haar-ish random orthogonal matrix: QR of a Gaussian with the R-diagonal
/// sign fix (used by QuaRot-lite rotations and SpinQuant-lite starts).
pub fn random_orthogonal(n: usize, rng: &mut Pcg) -> Tensor {
    let mut g = Tensor::zeros(&[n, n]);
    rng.fill_normal(g.data_mut(), 1.0);
    let (mut q, r) = qr(&g);
    for j in 0..n {
        if r.at2(j, j) < 0.0 {
            for i in 0..n {
                let v = -q.at2(i, j);
                q.set2(i, j, v);
            }
        }
    }
    q
}

/// Cubic Newton-Schulz polar factor (matches ref.polar_ref in python):
/// X <- 1.5 X - 0.5 X X^T X after Frobenius normalization.
pub fn polar(g: &Tensor, steps: usize) -> Tensor {
    let transposed = g.shape()[0] > g.shape()[1];
    let mut x = if transposed { transpose(g) } else { g.clone() };
    let norm = x.frobenius_norm() + 1e-7;
    x = x.scale(1.0 / norm);
    for _ in 0..steps {
        let xxt = matmul_transb(&x, &x);
        let correction = matmul(&xxt, &x);
        let mut next = x.clone().scale(1.5);
        next.axpy(-0.5, &correction);
        x = next;
    }
    if transposed {
        transpose(&x)
    } else {
        x
    }
}

/// Quintic Newton-Schulz orthogonalization — the Muon update map
/// (paper Eq. 2). Numerically identical to the python oracle
/// `ref.ns_orthogonalize_ref`; the disaggregated-vs-fused equivalence
/// test pins it against the `ns_*` XLA artifacts.
pub fn ns_orthogonalize(g: &Tensor, steps: usize) -> Tensor {
    const A: f32 = 3.4445;
    const B: f32 = -4.7750;
    const C: f32 = 2.0315;
    let transposed = g.shape()[0] > g.shape()[1];
    let mut x = if transposed { transpose(g) } else { g.clone() };
    let norm = x.frobenius_norm() + 1e-7;
    x = x.scale(1.0 / norm);
    for _ in 0..steps {
        let gram = matmul_transb(&x, &x);
        let gram2 = matmul(&gram, &gram);
        let mut poly = gram.scale(B);
        poly.axpy(C, &gram2);
        let mut next = x.clone().scale(A);
        next.axpy(1.0, &matmul(&poly, &x));
        x = next;
    }
    if transposed {
        transpose(&x)
    } else {
        x
    }
}

/// Largest power of two dividing n (Hadamard block size; matches
/// ref.pow2_block in python).
pub fn pow2_block(n: usize) -> usize {
    n & n.wrapping_neg()
}

/// Normalized blocked fast Walsh-Hadamard transform along the last axis
/// of a [rows, n] tensor; the involution used for online FFN rotation and
/// QuaRot-lite weight pre-rotation. Matches `ref.hadamard_ref`.
/// Row-parallel on the shared pool above the size threshold.
pub fn hadamard_rows(x: &Tensor) -> Tensor {
    let ops = x.rows() * x.cols();
    par::hadamard_rows_with(par::pool_for_ops(ops), x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed, 3);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn matmul_small_known() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![5., 6., 7., 8.]);
        assert_eq!(matmul(&a, &b).data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_identity() {
        let a = randn(&[7, 5], 1);
        let i = Tensor::eye(5);
        let c = matmul(&a, &i);
        crate::util::prop::all_close(c.data(), a.data(), 1e-6).unwrap();
    }

    #[test]
    fn matmul_transb_is_matmul_with_transpose() {
        let a = randn(&[6, 10], 21);
        let b = randn(&[4, 10], 22);
        let want = matmul(&a, &transpose(&b));
        let got = matmul_transb(&a, &b);
        // Same accumulation order per element: bit-exact, not just close.
        assert_eq!(want.data(), got.data());
    }

    #[test]
    fn matmul_zero_rows_are_exact() {
        // The dense inner loop is branch-free over A's values; zeros in
        // A must still produce exact zero contributions.
        let mut a = randn(&[5, 7], 23);
        for v in a.row_mut(2) {
            *v = 0.0;
        }
        let b = randn(&[7, 3], 24);
        let c = matmul(&a, &b);
        assert_eq!(c.row(2), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = randn(&[4, 9], 2);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn transpose_matches_naive() {
        // The untiled reference walk the blocked version replaced.
        let naive = |a: &Tensor| -> Tensor {
            let (m, n) = (a.shape()[0], a.shape()[1]);
            let mut t = Tensor::zeros(&[n, m]);
            for i in 0..m {
                for j in 0..n {
                    let v = a.at2(i, j);
                    t.set2(j, i, v);
                }
            }
            t
        };
        // Shapes around and across the 32-tile boundary, plus degenerate.
        for (m, n) in [(1, 1), (1, 7), (5, 1), (31, 33), (32, 32),
                       (33, 31), (64, 65), (100, 3), (3, 100)] {
            let a = randn(&[m, n], (m * 1000 + n) as u64);
            assert_eq!(transpose(&a), naive(&a), "{m}x{n}");
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let g = randn(&[6, 6], 3);
        let mut a = matmul(&g, &transpose(&g));
        for i in 0..6 {
            let v = a.at2(i, i) + 0.5;
            a.set2(i, i, v);
        }
        let l = cholesky(&a).unwrap();
        let rec = matmul(&l, &transpose(&l));
        crate::util::prop::all_close(rec.data(), a.data(), 1e-4).unwrap();
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 2., 1.]);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn triangular_solves() {
        let g = randn(&[5, 5], 4);
        let mut a = matmul(&g, &transpose(&g));
        for i in 0..5 {
            let v = a.at2(i, i) + 1.0;
            a.set2(i, i, v);
        }
        let l = cholesky(&a).unwrap();
        let b = vec![1., -2., 0.5, 3., -1.];
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // A x should equal b
        let ax = matvec(&a, &x);
        crate::util::prop::all_close(&ax, &b, 1e-3).unwrap();
    }

    #[test]
    fn spd_inverse_works() {
        let g = randn(&[4, 4], 5);
        let mut a = matmul(&g, &transpose(&g));
        for i in 0..4 {
            let v = a.at2(i, i) + 1.0;
            a.set2(i, i, v);
        }
        let inv = spd_inverse(&a).unwrap();
        let prod = matmul(&a, &inv);
        crate::util::prop::all_close(prod.data(), Tensor::eye(4).data(),
                                     1e-3).unwrap();
    }

    #[test]
    fn qr_orthogonal_and_reconstructs() {
        let a = randn(&[8, 5], 6);
        let (q, r) = qr(&a);
        let qtq = matmul(&transpose(&q), &q);
        crate::util::prop::all_close(qtq.data(), Tensor::eye(5).data(),
                                     1e-4).unwrap();
        let rec = matmul(&q, &r);
        crate::util::prop::all_close(rec.data(), a.data(), 1e-4).unwrap();
    }

    #[test]
    fn random_orthogonal_is_orthogonal() {
        let mut rng = Pcg::new(7, 0);
        let q = random_orthogonal(16, &mut rng);
        let qtq = matmul(&transpose(&q), &q);
        crate::util::prop::all_close(qtq.data(), Tensor::eye(16).data(),
                                     1e-4).unwrap();
    }

    #[test]
    fn polar_orthogonalizes() {
        let g = randn(&[12, 12], 8);
        let p = polar(&g, 40);
        let ptp = matmul(&transpose(&p), &p);
        crate::util::prop::all_close(ptp.data(), Tensor::eye(12).data(),
                                     5e-3).unwrap();
    }

    #[test]
    fn ns_orthogonalize_spectrum_in_band() {
        let g = randn(&[24, 16], 9);
        let x = ns_orthogonalize(&g, 5);
        // singular values in ~[0.7, 1.3] => x^T x diagonal in [0.45, 1.8]
        let gram = matmul(&transpose(&x), &x);
        for i in 0..16 {
            let d = gram.at2(i, i);
            assert!((0.3..2.0).contains(&d), "sigma^2 {d}");
        }
    }

    #[test]
    fn hadamard_involution_and_norm() {
        let x = randn(&[3, 176], 10); // 176 = 16 * 11: blocked path
        let y = hadamard_rows(&x);
        let back = hadamard_rows(&y);
        crate::util::prop::all_close(back.data(), x.data(), 1e-4).unwrap();
        // Norm preservation per row
        for r in 0..3 {
            let nx: f32 = x.row(r).iter().map(|v| v * v).sum::<f32>().sqrt();
            let ny: f32 = y.row(r).iter().map(|v| v * v).sum::<f32>().sqrt();
            crate::util::prop::close(nx, ny, 1e-4).unwrap();
        }
    }

    #[test]
    fn pow2_block_values() {
        assert_eq!(pow2_block(176), 16);
        assert_eq!(pow2_block(256), 256);
        assert_eq!(pow2_block(352), 32);
        assert_eq!(pow2_block(1), 1);
    }
}
