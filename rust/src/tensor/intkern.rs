//! Integer i8×i8→i32 microkernels for the packed serving path
//! (DESIGN.md §11).
//!
//! The runtime activation tap snaps each row to `code × scale` with
//! codes on a grid of at most 255 points — i8-representable for every
//! A≤8 config (`quant::rtn::quantize_row_i8`). This module keeps those
//! codes as integers: it accumulates exact i32 dot products against the
//! packed weight codes and applies `act_scale × weight_scale` once per
//! output element, instead of dequantizing every weight code to f32
//! first. The inner loops have explicit SIMD bodies (`core::arch` AVX2
//! and NEON) behind runtime feature detection plus an `OSP_SIMD=off`
//! override, and a plain-scalar oracle.
//!
//! Parity contract: integer accumulation in ascending-k order is
//! exactly associative, and every backend computes the same i32 sums
//! before a single shared scalar finalize — so the SIMD kernels are
//! bit-identical to the scalar oracle for any worker count, window
//! alignment, or chunking (pinned in `qtensor_properties.rs`). The
//! integer path differs from the f32 LUT path only in last-ulp
//! rounding: f32 rounds once per accumulation step, the integer path
//! rounds once at the end (see DESIGN.md §11 for why that is the
//! *better*-rounded answer).

use std::sync::OnceLock;

use super::lut;

/// Kernel backend for the integer path and the SIMD f32 decode tiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Plain-Rust loops: the oracle every SIMD body must match bitwise.
    Scalar,
    /// x86-64 AVX2: `vpmaddwd` against interleaved weight row pairs.
    Avx2,
    /// AArch64 NEON: `smull` widening multiplies per weight row.
    Neon,
}

impl Backend {
    pub fn label(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }
}

/// `OSP_SIMD=off|0|false` forces [`Backend::Scalar`] everywhere.
/// Read once per process; tests that need both paths in one process
/// force a backend programmatically instead of racing the env.
pub fn simd_disabled() -> bool {
    static OFF: OnceLock<bool> = OnceLock::new();
    *OFF.get_or_init(|| {
        std::env::var("OSP_SIMD").is_ok_and(|v| {
            matches!(v.to_ascii_lowercase().as_str(), "off" | "0" | "false")
        })
    })
}

/// Best backend the host supports, cached per process: AVX2 / NEON when
/// detected at runtime, otherwise scalar. `OSP_SIMD=off` pins scalar.
pub fn active() -> Backend {
    static ACTIVE: OnceLock<Backend> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        if simd_disabled() {
            Backend::Scalar
        } else {
            detect()
        }
    })
}

fn detect() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Backend::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Backend::Neon;
        }
    }
    Backend::Scalar
}

/// One-line CPU feature summary for `osp simd-info` and the CI log.
pub fn describe() -> String {
    let mut feats: Vec<&str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    for (name, on) in [
        ("sse2", is_x86_feature_detected!("sse2")),
        ("sse4.1", is_x86_feature_detected!("sse4.1")),
        ("avx", is_x86_feature_detected!("avx")),
        ("avx2", is_x86_feature_detected!("avx2")),
        ("avx512f", is_x86_feature_detected!("avx512f")),
    ] {
        if on {
            feats.push(name);
        }
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        feats.push("neon");
    }
    format!("arch={} features=[{}] backend={}{}",
            std::env::consts::ARCH,
            feats.join(","),
            active().label(),
            if simd_disabled() { " (OSP_SIMD=off)" } else { "" })
}

/// Where the model-level dispatch sends A≤8-bit linears. The library
/// default is [`IntMode::Off`] so every existing packed-vs-dense parity
/// contract is untouched; the CLI opts into `Auto` (see `osp generate
/// --int`, env `OSP_INT`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum IntMode {
    /// Legacy f32 LUT path only.
    #[default]
    Off,
    /// Integer path pinned to the scalar oracle (parity baselines).
    Scalar,
    /// Integer path on the best detected backend.
    Auto,
}

impl IntMode {
    pub fn parse(s: &str) -> Option<IntMode> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "f32" => Some(IntMode::Off),
            "scalar" => Some(IntMode::Scalar),
            "auto" | "on" | "simd" | "int" => Some(IntMode::Auto),
            _ => None,
        }
    }

    /// Kernel backend this mode resolves to (None = integer path off).
    pub fn backend(self) -> Option<Backend> {
        match self {
            IntMode::Off => None,
            IntMode::Scalar => Some(Backend::Scalar),
            IntMode::Auto => Some(active()),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            IntMode::Off => "off",
            IntMode::Scalar => "scalar",
            IntMode::Auto => "auto",
        }
    }
}

/// A batch of activation rows quantized exactly once: i8 codes
/// (row-major `[m, k]`) plus one f32 scale per row.
/// `codes[r][c] as f32 * scales[r]` is bitwise the fake-quant value the
/// f32 path sees for the same row (`quant::rtn::quantize_row_i8`).
#[derive(Clone, Debug)]
pub struct QuantActs {
    codes: Vec<i8>,
    scales: Vec<f32>,
    m: usize,
    k: usize,
}

impl QuantActs {
    pub fn from_parts(codes: Vec<i8>, scales: Vec<f32>, m: usize,
                      k: usize) -> QuantActs {
        assert_eq!(codes.len(), m * k, "codes len vs [{m}, {k}]");
        assert_eq!(scales.len(), m, "one scale per row");
        QuantActs { codes, scales, m, k }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn row_codes(&self, r: usize) -> &[i8] {
        &self.codes[r * self.k..(r + 1) * self.k]
    }

    pub fn scale(&self, r: usize) -> f32 {
        self.scales[r]
    }
}

/// Largest contraction depth with a static no-overflow guarantee:
/// |code| <= 128 on both sides bounds each term by 2^14, so k < 2^17
/// keeps every running i32 sum under 2^31.
pub const MAX_INT_K: usize = 1 << 17;

/// Integer stripe accumulator: for every activation row `r` and column
/// `t` in the window `[j0, j1)` of a packed `[k, n]` weight block,
/// `acc[r * (j1-j0) + t] += Σ_kk act_codes[r][kk] * w_code[kk][j0+t]`.
/// `bytes`/`stride`/`sbits` describe the packed storage (one packed row
/// per contraction index). All backends produce bit-identical `acc`:
/// exact i32 sums, ascending-k order.
pub fn accumulate_stripe(bytes: &[u8], stride: usize, sbits: u32, k: usize,
                         j0: usize, j1: usize, acts: &QuantActs,
                         backend: Backend, acc: &mut [i32]) {
    let jw = j1 - j0;
    debug_assert_eq!(acts.k, k);
    debug_assert_eq!(acc.len(), acts.m * jw);
    assert!(k < MAX_INT_K, "contraction depth {k} risks i32 overflow");
    if jw == 0 || acts.m == 0 {
        return;
    }
    match backend {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => {
            debug_assert!(is_x86_feature_detected!("avx2"));
            let mut w0 = vec![0i8; jw];
            let mut w1 = vec![0i8; jw];
            let mut kk = 0usize;
            while kk + 2 <= k {
                let row0 = &bytes[kk * stride..(kk + 1) * stride];
                let row1 = &bytes[(kk + 1) * stride..(kk + 2) * stride];
                decode_window_i8(row0, sbits, j0, j1, backend, &mut w0);
                decode_window_i8(row1, sbits, j0, j1, backend, &mut w1);
                for r in 0..acts.m {
                    let ca0 = acts.codes[r * k + kk] as i16;
                    let ca1 = acts.codes[r * k + kk + 1] as i16;
                    if ca0 == 0 && ca1 == 0 {
                        continue;
                    }
                    let arow = &mut acc[r * jw..(r + 1) * jw];
                    // SAFETY: this arm only runs with AVX2 detected
                    // (asserted above); the pointers cover jw valid
                    // elements and madd_pair touches at most the first
                    // 16-aligned prefix it reports back.
                    let done = unsafe {
                        avx2::madd_pair(w0.as_ptr(), w1.as_ptr(), ca0, ca1,
                                        arow.as_mut_ptr(), jw)
                    };
                    for t in done..jw {
                        arow[t] += ca0 as i32 * w0[t] as i32
                            + ca1 as i32 * w1[t] as i32;
                    }
                }
                kk += 2;
            }
            if kk < k {
                let row = &bytes[kk * stride..(kk + 1) * stride];
                decode_window_i8(row, sbits, j0, j1, backend, &mut w0);
                scalar_k_row(&w0, acts, kk, acc);
            }
        }
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => {
            let mut wrow = vec![0i8; jw];
            for kk in 0..k {
                let row = &bytes[kk * stride..(kk + 1) * stride];
                decode_window_i8(row, sbits, j0, j1, backend, &mut wrow);
                for r in 0..acts.m {
                    let ca = acts.codes[r * k + kk];
                    if ca == 0 {
                        continue;
                    }
                    let arow = &mut acc[r * jw..(r + 1) * jw];
                    // SAFETY: NEON is baseline on aarch64; pointers
                    // cover jw valid elements.
                    let done = unsafe {
                        neon::madd_row(wrow.as_ptr(), ca, arow.as_mut_ptr(),
                                       jw)
                    };
                    for t in done..jw {
                        arow[t] += ca as i32 * wrow[t] as i32;
                    }
                }
            }
        }
        _ => {
            let mut wrow = vec![0i8; jw];
            for kk in 0..k {
                let row = &bytes[kk * stride..(kk + 1) * stride];
                lut::decode_cols_i8(row, sbits, j0, j1, &mut wrow);
                scalar_k_row(&wrow, acts, kk, acc);
            }
        }
    }
}

/// Scalar oracle for one contraction row: `acc[r][·] += ca_r * wrow`.
/// Skipping `ca == 0` rows is a pure shortcut (adds of zero), so it
/// cannot perturb parity with the SIMD arms.
fn scalar_k_row(wrow: &[i8], acts: &QuantActs, kk: usize, acc: &mut [i32]) {
    let (k, jw) = (acts.k, wrow.len());
    for r in 0..acts.m {
        let ca = acts.codes[r * k + kk] as i32;
        if ca == 0 {
            continue;
        }
        let arow = &mut acc[r * jw..(r + 1) * jw];
        for (a, &wc) in arow.iter_mut().zip(wrow) {
            *a += ca * wc as i32;
        }
    }
}

/// Decode one packed-row window to i8 codes, with a SIMD body for the
/// 4-bit layout (the W4 hot path). Exact: integer decode is the same
/// bits on every backend (pinned against `lut::decode_cols_i8`).
fn decode_window_i8(row: &[u8], sbits: u32, j0: usize, j1: usize,
                    backend: Backend, out: &mut [i8]) {
    #[cfg(target_arch = "x86_64")]
    if backend == Backend::Avx2 && sbits == 4 {
        let mut j = j0;
        let mut o = 0usize;
        if j < j1 && (j & 1) == 1 {
            out[o] = lut::LUT4[row[j >> 1] as usize][1];
            j += 1;
            o += 1;
        }
        while j + 16 <= j1 {
            // SAFETY: AVX2 detected by the caller; 8 source bytes and
            // 16 destination slots are in bounds (j + 16 <= j1 and the
            // row holds ceil(j1 / 2) packed bytes).
            unsafe {
                avx2::codes16_4bit_i8(row.as_ptr().add(j >> 1),
                                      out.as_mut_ptr().add(o));
            }
            j += 16;
            o += 16;
        }
        while j < j1 {
            out[o] = lut::LUT4[row[j >> 1] as usize][j & 1];
            j += 1;
            o += 1;
        }
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if backend == Backend::Neon && sbits == 4 {
        let mut j = j0;
        let mut o = 0usize;
        if j < j1 && (j & 1) == 1 {
            out[o] = lut::LUT4[row[j >> 1] as usize][1];
            j += 1;
            o += 1;
        }
        while j + 16 <= j1 {
            // SAFETY: NEON is baseline on aarch64; 8 source bytes and
            // 16 destination slots are in bounds.
            unsafe {
                neon::codes16_4bit_i8(row.as_ptr().add(j >> 1),
                                      out.as_mut_ptr().add(o));
            }
            j += 16;
            o += 16;
        }
        while j < j1 {
            out[o] = lut::LUT4[row[j >> 1] as usize][j & 1];
            j += 1;
            o += 1;
        }
        return;
    }
    let _ = backend;
    lut::decode_cols_i8(row, sbits, j0, j1, out);
}

/// Decode the `[j0, j1)` window of one packed row into *unscaled* f32
/// codes using the active SIMD backend. Returns false when no SIMD body
/// applies (scalar backend, 2-bit storage) and the caller should keep
/// its scalar LUT walk. Exact: each output is one int→f32 convert, so
/// the caller's per-element scale multiply is bitwise the fused scalar
/// path.
pub(crate) fn simd_decode_codes_f32(row: &[u8], sbits: u32, j0: usize,
                                    j1: usize, out: &mut [f32]) -> bool {
    debug_assert_eq!(out.len(), j1 - j0);
    #[cfg(target_arch = "x86_64")]
    if active() == Backend::Avx2 && (sbits == 4 || sbits == 8) {
        let mut j = j0;
        let mut o = 0usize;
        if sbits == 4 && j < j1 && (j & 1) == 1 {
            out[o] = lut::LUT4[row[j >> 1] as usize][1] as f32;
            j += 1;
            o += 1;
        }
        while j + 16 <= j1 {
            // SAFETY: AVX2 active; source bytes (8 packed / 16 dense)
            // and 16 output slots are in bounds.
            unsafe {
                if sbits == 4 {
                    avx2::codes16_4bit_f32(row.as_ptr().add(j >> 1),
                                           out.as_mut_ptr().add(o));
                } else {
                    avx2::codes16_8bit_f32(row.as_ptr().add(j),
                                           out.as_mut_ptr().add(o));
                }
            }
            j += 16;
            o += 16;
        }
        while j < j1 {
            out[o] = if sbits == 4 {
                lut::LUT4[row[j >> 1] as usize][j & 1] as f32
            } else {
                (row[j] as i8) as f32
            };
            j += 1;
            o += 1;
        }
        return true;
    }
    #[cfg(target_arch = "aarch64")]
    if active() == Backend::Neon && (sbits == 4 || sbits == 8) {
        let mut j = j0;
        let mut o = 0usize;
        if sbits == 4 && j < j1 && (j & 1) == 1 {
            out[o] = lut::LUT4[row[j >> 1] as usize][1] as f32;
            j += 1;
            o += 1;
        }
        while j + 16 <= j1 {
            // SAFETY: NEON is baseline on aarch64; source bytes and 16
            // output slots are in bounds.
            unsafe {
                if sbits == 4 {
                    neon::codes16_4bit_f32(row.as_ptr().add(j >> 1),
                                           out.as_mut_ptr().add(o));
                } else {
                    neon::codes16_8bit_f32(row.as_ptr().add(j),
                                           out.as_mut_ptr().add(o));
                }
            }
            j += 16;
            o += 16;
        }
        while j < j1 {
            out[o] = if sbits == 4 {
                lut::LUT4[row[j >> 1] as usize][j & 1] as f32
            } else {
                (row[j] as i8) as f32
            };
            j += 1;
            o += 1;
        }
        return true;
    }
    let _ = (row, sbits, j0, j1, out);
    false
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Decode 8 packed bytes into 16 sign-extended 4-bit codes in field
    /// order (low nibble first): mask the two nibble planes, interleave
    /// them byte-wise, then sign-extend via `(x ^ 8) - 8`.
    ///
    /// # Safety
    /// Requires AVX2; `src` must have 8 readable bytes, `dst` 16
    /// writable lanes.
    #[target_feature(enable = "avx2")]
    unsafe fn decode16_4bit(src: *const u8) -> __m128i {
        unsafe {
            let v = _mm_loadl_epi64(src as *const __m128i);
            let mask = _mm_set1_epi8(0x0F);
            let lo = _mm_and_si128(v, mask);
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(v), mask);
            let codes = _mm_unpacklo_epi8(lo, hi);
            let k8 = _mm_set1_epi8(8);
            _mm_sub_epi8(_mm_xor_si128(codes, k8), k8)
        }
    }

    /// # Safety
    /// Requires AVX2; 8 readable source bytes, 16 writable i8 lanes.
    #[target_feature(enable = "avx2")]
    pub unsafe fn codes16_4bit_i8(src: *const u8, dst: *mut i8) {
        unsafe {
            let s = decode16_4bit(src);
            _mm_storeu_si128(dst as *mut __m128i, s);
        }
    }

    /// # Safety
    /// Requires AVX2; 8 readable source bytes, 16 writable f32 lanes.
    #[target_feature(enable = "avx2")]
    pub unsafe fn codes16_4bit_f32(src: *const u8, dst: *mut f32) {
        unsafe {
            let s = decode16_4bit(src);
            let f0 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(s));
            let s_hi = _mm_srli_si128::<8>(s);
            let f1 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(s_hi));
            _mm256_storeu_ps(dst, f0);
            _mm256_storeu_ps(dst.add(8), f1);
        }
    }

    /// # Safety
    /// Requires AVX2; 16 readable source bytes, 16 writable f32 lanes.
    #[target_feature(enable = "avx2")]
    pub unsafe fn codes16_8bit_f32(src: *const u8, dst: *mut f32) {
        unsafe {
            let v = _mm_loadu_si128(src as *const __m128i);
            let f0 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(v));
            let v_hi = _mm_srli_si128::<8>(v);
            let f1 = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(v_hi));
            _mm256_storeu_ps(dst, f0);
            _mm256_storeu_ps(dst.add(8), f1);
        }
    }

    /// `acc[t] += ca0 * w0[t] + ca1 * w1[t]` for the 16-aligned column
    /// prefix; returns how many columns were handled (the caller
    /// finishes the tail in scalar). Interleaves the two weight rows
    /// byte-wise so one `vpmaddwd` against the broadcast [ca0, ca1]
    /// pair yields both products summed per column — exact in i16/i32
    /// (|code| <= 128 bounds each product by 2^14, the pair sum by
    /// 2^15).
    ///
    /// # Safety
    /// Requires AVX2; `w0`/`w1`/`acc` must each have `jw` valid lanes.
    #[target_feature(enable = "avx2")]
    pub unsafe fn madd_pair(w0: *const i8, w1: *const i8, ca0: i16,
                            ca1: i16, acc: *mut i32, jw: usize) -> usize {
        unsafe {
            let pair_bits =
                ((ca1 as u16 as u32) << 16) | (ca0 as u16 as u32);
            let pair = _mm256_set1_epi32(pair_bits as i32);
            let mut j = 0usize;
            while j + 16 <= jw {
                let a = _mm_loadu_si128(w0.add(j) as *const __m128i);
                let b = _mm_loadu_si128(w1.add(j) as *const __m128i);
                let lo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(a, b));
                let hi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(a, b));
                let s0 = _mm256_madd_epi16(lo, pair);
                let s1 = _mm256_madd_epi16(hi, pair);
                let p0 = acc.add(j) as *mut __m256i;
                let p1 = acc.add(j + 8) as *mut __m256i;
                _mm256_storeu_si256(
                    p0, _mm256_add_epi32(_mm256_loadu_si256(p0), s0));
                _mm256_storeu_si256(
                    p1, _mm256_add_epi32(_mm256_loadu_si256(p1), s1));
                j += 16;
            }
            j
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// Decode 8 packed bytes into 16 sign-extended 4-bit codes in field
    /// order (low nibble first).
    ///
    /// # Safety
    /// `src` must have 8 readable bytes.
    #[target_feature(enable = "neon")]
    unsafe fn decode16_4bit(src: *const u8) -> int8x16_t {
        unsafe {
            let v = vld1_u8(src);
            let lo = vand_u8(v, vdup_n_u8(0x0F));
            let hi = vshr_n_u8::<4>(v);
            let z0 = vzip1_u8(lo, hi);
            let z1 = vzip2_u8(lo, hi);
            let codes = vreinterpretq_s8_u8(vcombine_u8(z0, z1));
            let k8 = vdupq_n_s8(8);
            vsubq_s8(veorq_s8(codes, k8), k8)
        }
    }

    /// # Safety
    /// 8 readable source bytes, 16 writable i8 lanes.
    #[target_feature(enable = "neon")]
    pub unsafe fn codes16_4bit_i8(src: *const u8, dst: *mut i8) {
        unsafe {
            vst1q_s8(dst, decode16_4bit(src));
        }
    }

    /// # Safety
    /// 8 readable source bytes, 16 writable f32 lanes.
    #[target_feature(enable = "neon")]
    pub unsafe fn codes16_4bit_f32(src: *const u8, dst: *mut f32) {
        unsafe {
            let s = decode16_4bit(src);
            store16_f32(s, dst);
        }
    }

    /// # Safety
    /// 16 readable source bytes, 16 writable f32 lanes.
    #[target_feature(enable = "neon")]
    pub unsafe fn codes16_8bit_f32(src: *const u8, dst: *mut f32) {
        unsafe {
            let v = vld1q_s8(src as *const i8);
            store16_f32(v, dst);
        }
    }

    /// # Safety
    /// `dst` must have 16 writable f32 lanes.
    #[target_feature(enable = "neon")]
    unsafe fn store16_f32(codes: int8x16_t, dst: *mut f32) {
        unsafe {
            let w0 = vmovl_s8(vget_low_s8(codes));
            let w1 = vmovl_s8(vget_high_s8(codes));
            vst1q_f32(dst, vcvtq_f32_s32(vmovl_s16(vget_low_s16(w0))));
            vst1q_f32(dst.add(4),
                      vcvtq_f32_s32(vmovl_s16(vget_high_s16(w0))));
            vst1q_f32(dst.add(8),
                      vcvtq_f32_s32(vmovl_s16(vget_low_s16(w1))));
            vst1q_f32(dst.add(12),
                      vcvtq_f32_s32(vmovl_s16(vget_high_s16(w1))));
        }
    }

    /// `acc[t] += ca * w[t]` for the 16-aligned column prefix; returns
    /// how many columns were handled. `smull` keeps every single
    /// product exact in i16 (|product| <= 2^14), then widening adds
    /// accumulate in i32.
    ///
    /// # Safety
    /// `w`/`acc` must each have `jw` valid lanes.
    #[target_feature(enable = "neon")]
    pub unsafe fn madd_row(w: *const i8, ca: i8, acc: *mut i32,
                           jw: usize) -> usize {
        unsafe {
            let cav = vdup_n_s8(ca);
            let mut j = 0usize;
            while j + 16 <= jw {
                let v = vld1q_s8(w.add(j));
                let p0 = vmull_s8(vget_low_s8(v), cav);
                let p1 = vmull_s8(vget_high_s8(v), cav);
                let a0 = vaddw_s16(vld1q_s32(acc.add(j)),
                                   vget_low_s16(p0));
                let a1 = vaddw_s16(vld1q_s32(acc.add(j + 4)),
                                   vget_high_s16(p0));
                let a2 = vaddw_s16(vld1q_s32(acc.add(j + 8)),
                                   vget_low_s16(p1));
                let a3 = vaddw_s16(vld1q_s32(acc.add(j + 12)),
                                   vget_high_s16(p1));
                vst1q_s32(acc.add(j), a0);
                vst1q_s32(acc.add(j + 4), a1);
                vst1q_s32(acc.add(j + 8), a2);
                vst1q_s32(acc.add(j + 12), a3);
                j += 16;
            }
            j
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::qtensor::{encode, row_stride, storage_bits};
    use super::*;
    use crate::util::rng::Pcg;

    fn pack_rows(codes: &[Vec<i32>], bits: u32) -> (Vec<u8>, usize, u32) {
        let sbits = storage_bits(bits).unwrap();
        let cols = codes[0].len();
        let stride = row_stride(cols, bits);
        let mut bytes = vec![0u8; codes.len() * stride];
        for (kk, row) in codes.iter().enumerate() {
            let out = &mut bytes[kk * stride..(kk + 1) * stride];
            for (j, &c) in row.iter().enumerate() {
                encode(out, sbits, j, c);
            }
        }
        (bytes, stride, sbits)
    }

    fn random_codes(rng: &mut Pcg, n: usize, bits: u32) -> Vec<i32> {
        let lv = (1i32 << (bits - 1)) - 1;
        (0..n).map(|_| rng.below((2 * lv + 2) as u64) as i32 - lv - 1)
            .collect()
    }

    fn random_acts(rng: &mut Pcg, m: usize, k: usize) -> QuantActs {
        // Full i8 range including -128 to stress the SIMD bodies harder
        // than the runtime tap (which never emits below -(levels+1)).
        let codes: Vec<i8> =
            (0..m * k).map(|_| rng.below(256) as i32 as u8 as i8).collect();
        let scales: Vec<f32> =
            (0..m).map(|r| 0.01 + 0.1 * r as f32).collect();
        QuantActs::from_parts(codes, scales, m, k)
    }

    /// Plain nested-loop reference, no LUTs, no stripe walk.
    fn reference(wcodes: &[Vec<i32>], acts: &QuantActs, j0: usize,
                 j1: usize) -> Vec<i32> {
        let jw = j1 - j0;
        let mut acc = vec![0i32; acts.m() * jw];
        for r in 0..acts.m() {
            for (kk, wrow) in wcodes.iter().enumerate() {
                let ca = acts.row_codes(r)[kk] as i32;
                for t in 0..jw {
                    acc[r * jw + t] += ca * wrow[j0 + t];
                }
            }
        }
        acc
    }

    #[test]
    fn scalar_stripe_matches_reference_across_windows() {
        let mut rng = Pcg::new(11, 3);
        for bits in [2u32, 3, 4, 5, 8] {
            for (m, k, n) in [(1usize, 7usize, 33usize), (3, 8, 19),
                              (4, 5, 48)] {
                let wcodes: Vec<Vec<i32>> =
                    (0..k).map(|_| random_codes(&mut rng, n, bits))
                    .collect();
                let (bytes, stride, sbits) = pack_rows(&wcodes, bits);
                let acts = random_acts(&mut rng, m, k);
                for (j0, j1) in [(0, n), (1, n), (0, n - 1), (3, n / 2 + 3),
                                 (n - 1, n)] {
                    let mut acc = vec![0i32; m * (j1 - j0)];
                    accumulate_stripe(&bytes, stride, sbits, k, j0, j1,
                                      &acts, Backend::Scalar, &mut acc);
                    assert_eq!(acc, reference(&wcodes, &acts, j0, j1),
                               "bits {bits} m {m} k {k} [{j0},{j1})");
                }
            }
        }
    }

    #[test]
    fn simd_stripe_is_bitwise_scalar() {
        let be = detect();
        if be == Backend::Scalar {
            eprintln!("no SIMD backend on this host; skipping");
            return;
        }
        let mut rng = Pcg::new(29, 3);
        for bits in [2u32, 4, 8] {
            // Shapes chosen to hit the 16-wide body, the column tail,
            // odd k (AVX2 pair remainder), and mid-byte windows.
            for (m, k, n) in [(1usize, 1usize, 16usize), (1, 9, 61),
                              (2, 16, 40), (5, 7, 17), (3, 31, 129)] {
                let wcodes: Vec<Vec<i32>> =
                    (0..k).map(|_| random_codes(&mut rng, n, bits))
                    .collect();
                let (bytes, stride, sbits) = pack_rows(&wcodes, bits);
                let acts = random_acts(&mut rng, m, k);
                for (j0, j1) in [(0, n), (1, n), (0, n - 1),
                                 (n / 3, n / 3 + 16.min(n - n / 3))] {
                    let jw = j1 - j0;
                    let mut a = vec![0i32; m * jw];
                    let mut b = vec![0i32; m * jw];
                    accumulate_stripe(&bytes, stride, sbits, k, j0, j1,
                                      &acts, Backend::Scalar, &mut a);
                    accumulate_stripe(&bytes, stride, sbits, k, j0, j1,
                                      &acts, be, &mut b);
                    assert_eq!(a, b,
                               "bits {bits} m {m} k {k} [{j0},{j1})");
                }
            }
        }
    }

    #[test]
    fn simd_stripe_survives_extreme_codes() {
        let be = detect();
        if be == Backend::Scalar {
            return;
        }
        // All-(-128) against all-(-128): the worst-case magnitude for
        // the i16 intermediates in both SIMD schemes.
        let k = 33usize;
        let n = 37usize;
        let wcodes: Vec<Vec<i32>> = (0..k).map(|_| vec![-128i32; n])
            .collect();
        let (bytes, stride, sbits) = pack_rows(&wcodes, 8);
        let acts = QuantActs::from_parts(vec![-128i8; 2 * k],
                                         vec![1.0, 1.0], 2, k);
        let mut a = vec![0i32; 2 * n];
        let mut b = vec![0i32; 2 * n];
        accumulate_stripe(&bytes, stride, sbits, k, 0, n, &acts,
                          Backend::Scalar, &mut a);
        accumulate_stripe(&bytes, stride, sbits, k, 0, n, &acts, be,
                          &mut b);
        assert_eq!(a, b);
        assert!(a.iter().all(|&v| v == 128 * 128 * k as i32));
    }

    #[test]
    fn decode_window_i8_matches_lut_on_every_backend() {
        let bytes: Vec<u8> = (0..40).map(|i| (29 * i + 3) as u8).collect();
        for sbits in [2u32, 4, 8] {
            let cols = bytes.len() * (8 / sbits as usize);
            for be in [Backend::Scalar, detect()] {
                for (j0, j1) in [(0, cols), (1, cols), (5, cols - 2),
                                 (0, 15), (17, 33)] {
                    let mut want = vec![0i8; j1 - j0];
                    lut::decode_cols_i8(&bytes, sbits, j0, j1, &mut want);
                    let mut got = vec![0i8; j1 - j0];
                    decode_window_i8(&bytes, sbits, j0, j1, be, &mut got);
                    assert_eq!(got, want,
                               "{sbits}b {be:?} [{j0},{j1})");
                }
            }
        }
    }

    #[test]
    fn mode_parsing_and_labels() {
        assert_eq!(IntMode::parse("off"), Some(IntMode::Off));
        assert_eq!(IntMode::parse("Scalar"), Some(IntMode::Scalar));
        assert_eq!(IntMode::parse("AUTO"), Some(IntMode::Auto));
        assert_eq!(IntMode::parse("on"), Some(IntMode::Auto));
        assert_eq!(IntMode::parse("bogus"), None);
        assert_eq!(IntMode::default(), IntMode::Off);
        assert_eq!(IntMode::Off.backend(), None);
        assert_eq!(IntMode::Scalar.backend(), Some(Backend::Scalar));
        assert!(IntMode::Auto.backend().is_some());
        assert_eq!(Backend::Scalar.label(), "scalar");
    }

    #[test]
    fn describe_names_the_active_backend() {
        let d = describe();
        assert!(d.contains("backend="), "{d}");
        assert!(d.contains(std::env::consts::ARCH), "{d}");
    }
}
