//! Packed quantized tensors (DESIGN.md §7): integer codes at 2/4/8 bits,
//! nibble/byte-packed row-major, plus per-output-channel f32 scales.
//!
//! This is the storage the PTQ pipeline actually deploys: RTN and GPTQ
//! emit codes directly (`quant::rtn::quantize_per_channel_q`,
//! `quant::gptq::gptq_quantize_q`), checkpoints persist them
//! (`checkpoint::save_packed`), and the fused kernels here consume them
//! without ever materializing a dense f32 copy. A 4-bit weight costs
//! 0.5 bytes/param plus one f32 scale per column — ~8x below dense f32.
//!
//! Parity contract (pinned by `rust/tests/qtensor_properties.rs`):
//!
//! * [`QTensor::dequantize`] is bit-identical to the f32
//!   quantize-dequantize path it replaces: codes are the exact
//!   `(v/scale).round().clamp(..)` integers the f32 path multiplied back,
//!   and `code as f32 * scale` is the same single multiplication.
//! * [`QTensor::qmatvec`] / [`QTensor::qmatmul`] dequantize in-register
//!   with the same accumulation order as the dense kernels, so their
//!   results are bit-identical to running [`linalg::matmul_row`]-shaped
//!   loops over `self.dequantize()`.
//! * Serial and pool-parallel kernel paths share one per-row body
//!   (row-block partitioning on the `OSP_THREADS` pool, DESIGN.md §6),
//!   so they are bit-identical for any worker count.
//!
//! Kernel structure (DESIGN.md §10): the fused kernels decode through
//! the byte-granular lookup tables in [`super::lut`] — one table hit
//! per packed *byte* instead of a shift/mask/sign-extend per element —
//! into [`KTILE`]-column dequant tiles swept [`RBLOCK`] rows at a time,
//! so every streamed B row (or x window) is reused across the register
//! block. Accumulation per output element stays single-accumulator
//! ascending-k, which is what keeps the LUT kernels bit-identical to
//! both the dense kernels and the pre-LUT per-element kernels
//! ([`QTensor::qmatvec_scalar`] / [`QTensor::qmatmul_scalar`], kept as
//! the independent oracle and the `microbench` baseline).

use std::fmt;

use crate::util::threadpool::ThreadPool;

use super::{intkern, linalg, lut, par, Tensor};

/// Columns per dequant scratch tile: 256 f32 = 1 KiB per row keeps an
/// [`RBLOCK`]-row tile sweep (4 KiB of dequantized codes plus the B/x
/// window) L1-resident.
pub const KTILE: usize = 256;

/// Rows per register block: each K-tile sweep carries `RBLOCK`
/// accumulator rows so a streamed B row (or x tile) loads once per
/// block instead of once per output row.
pub const RBLOCK: usize = 4;

/// Code payload of a [`QTensor`].
#[derive(Clone, Debug, PartialEq)]
pub enum QStorage {
    /// Row-major signed codes, [`codes_per_byte`] codes per byte, each
    /// row starting on a byte boundary (pad bits are zero).
    Packed(Vec<u8>),
    /// Quantization off (bits >= 16): dense f32 passthrough.
    Dense(Vec<f32>),
}

/// Storage field width (2/4/8 bits, byte-divisible) for a logical
/// quantization bit-width: the next packable size up. 3-bit codes live
/// in 4-bit fields, 5/6/7-bit codes in bytes. `None` when no packed
/// layout exists (bits 9-15 fall back to [`QStorage::Dense`]).
pub fn storage_bits(bits: u32) -> Option<u32> {
    match bits {
        2 => Some(2),
        3 | 4 => Some(4),
        5..=8 => Some(8),
        _ => None,
    }
}

/// Codes per storage byte for a logical bit-width with a packed layout
/// (4 at 2-bit, 2 at 3/4-bit, 1 at 5..8-bit).
pub fn codes_per_byte(bits: u32) -> usize {
    let sbits = storage_bits(bits)
        .unwrap_or_else(|| panic!("no packed layout for {bits}-bit"));
    (8 / sbits) as usize
}

/// Sign-extended code `j` of a packed row (`sbits`-wide fields).
/// Shared with the quantized KV cache (`infer::kv`), which stores its
/// rows in this exact field layout.
#[inline(always)]
pub(crate) fn decode(row: &[u8], sbits: u32, j: usize) -> i32 {
    let cpb = (8 / sbits) as usize;
    let byte = row[j / cpb];
    let sh = 8 - sbits;
    let field = (byte >> ((j % cpb) as u32 * sbits)) << sh;
    ((field as i8) >> sh) as i32
}

/// OR code `j` into a zeroed packed row (two's complement, masked).
/// Hard-asserts the range: masking an out-of-range code would silently
/// store a *different* value (e.g. 8 at 4-bit decodes as -8), which is
/// worse than a panic for a deployment storage format.
#[inline(always)]
pub(crate) fn encode(row: &mut [u8], sbits: u32, j: usize, code: i32) {
    assert!(
        (-(1i64 << (sbits - 1))..(1i64 << (sbits - 1)))
            .contains(&(code as i64)),
        "code {code} out of range for {sbits}-bit storage");
    let cpb = (8 / sbits) as usize;
    let mask = ((1u16 << sbits) - 1) as u8;
    row[j / cpb] |= ((code as u8) & mask) << ((j % cpb) as u32 * sbits);
}

/// A 2-D quantized tensor: packed integer codes plus one symmetric f32
/// scale per output channel (= column of the `[in, out]` weight layout).
/// `dequantize()[i][j] == code(i, j) as f32 * scales[j]`.
#[derive(Clone, PartialEq)]
pub struct QTensor {
    shape: Vec<usize>,
    bits: u32,
    /// Per-column scales (empty for the dense passthrough).
    scales: Vec<f32>,
    storage: QStorage,
}

impl fmt::Debug for QTensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "QTensor{:?}[{}b, {} bytes]", self.shape, self.bits,
               self.storage_bytes())
    }
}

impl QTensor {
    /// Pack signed codes (row-major, one per element) with per-column
    /// scales. Codes must fit `bits` (two's complement); requires a
    /// packed layout ([`storage_bits`] is Some).
    pub fn pack(shape: &[usize], bits: u32, codes: &[i32], scales: Vec<f32>)
                -> QTensor {
        assert_eq!(shape.len(), 2, "QTensor::pack wants a 2-D shape");
        let sbits = storage_bits(bits)
            .unwrap_or_else(|| panic!("no packed layout for {bits}-bit"));
        let (rows, cols) = (shape[0], shape[1]);
        assert_eq!(codes.len(), rows * cols, "codes len != rows*cols");
        assert_eq!(scales.len(), cols, "one scale per output channel");
        let stride = row_stride(cols, bits);
        let mut bytes = vec![0u8; rows * stride];
        for i in 0..rows {
            let row = &mut bytes[i * stride..(i + 1) * stride];
            for j in 0..cols {
                encode(row, sbits, j, codes[i * cols + j]);
            }
        }
        QTensor { shape: shape.to_vec(), bits, scales,
                  storage: QStorage::Packed(bytes) }
    }

    /// Build from producer codes at any bit-width below 16: packs when a
    /// packed layout exists (bits <= 8), otherwise materializes
    /// `code * scale` into a dense passthrough (9..15-bit grids, only
    /// reachable via a user-chosen `osp quantize --w-bits N` — same
    /// values, no compression).
    pub fn from_codes(shape: &[usize], bits: u32, codes: &[i32],
                      scales: Vec<f32>) -> QTensor {
        if storage_bits(bits).is_some() {
            return QTensor::pack(shape, bits, codes, scales);
        }
        assert_eq!(shape.len(), 2, "QTensor::from_codes wants a 2-D shape");
        let cols = shape[1];
        assert_eq!(scales.len(), cols, "one scale per output channel");
        assert_eq!(codes.len(), shape[0] * cols, "codes len != rows*cols");
        let data: Vec<f32> = codes
            .iter()
            .enumerate()
            .map(|(e, &c)| c as f32 * scales[e % cols.max(1)])
            .collect();
        QTensor { shape: shape.to_vec(), bits, scales,
                  storage: QStorage::Dense(data) }
    }

    /// Dense passthrough for bits >= 16 ("quantization off") — keeps the
    /// 16-bit identity semantics of the f32 paths, any shape.
    pub fn from_dense(t: &Tensor) -> QTensor {
        QTensor { shape: t.shape().to_vec(), bits: 16, scales: Vec::new(),
                  storage: QStorage::Dense(t.data().to_vec()) }
    }

    /// Storage field width of the packed payload.
    fn sbits(&self) -> u32 {
        storage_bits(self.bits).expect("dense storage has no field width")
    }

    /// Rebuild from serialized parts (checkpoint load). Validates the
    /// invariants `pack`/`from_dense` establish.
    pub fn from_parts(shape: Vec<usize>, bits: u32, scales: Vec<f32>,
                      storage: QStorage) -> Result<QTensor, String> {
        let numel: usize = shape.iter().product();
        match &storage {
            QStorage::Dense(d) => {
                if d.len() != numel {
                    return Err(format!("dense payload {} != numel {numel}",
                                       d.len()));
                }
            }
            QStorage::Packed(bytes) => {
                if shape.len() != 2 {
                    return Err("packed storage needs a 2-D shape".into());
                }
                if storage_bits(bits).is_none() {
                    return Err(format!("unpackable bit-width {bits}"));
                }
                let want = shape[0] * row_stride(shape[1], bits);
                if bytes.len() != want {
                    return Err(format!("packed payload {} != {want} bytes",
                                       bytes.len()));
                }
                if scales.len() != shape[1] {
                    return Err(format!("{} scales for {} columns",
                                       scales.len(), shape[1]));
                }
                // Pad bits must be zero: `pack` emits that canonical
                // form and PartialEq/unpack assume it.
                let cpb = codes_per_byte(bits);
                let used = shape[1] % cpb;
                if used != 0 {
                    let stride = row_stride(shape[1], bits);
                    let keep = used as u32 * storage_bits(bits).unwrap();
                    for r in 0..shape[0] {
                        if bytes[(r + 1) * stride - 1] >> keep != 0 {
                            return Err(format!(
                                "row {r}: nonzero pad bits"));
                        }
                    }
                }
            }
        }
        Ok(QTensor { shape, bits, scales, storage })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rows(&self) -> usize {
        self.shape[..self.shape.len() - 1].iter().product()
    }

    pub fn cols(&self) -> usize {
        *self.shape.last().expect("rank-0 QTensor")
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    pub fn storage(&self) -> &QStorage {
        &self.storage
    }

    pub fn is_packed(&self) -> bool {
        matches!(self.storage, QStorage::Packed(_))
    }

    /// Bytes of the code payload (packed codes, or dense f32 fallback).
    pub fn storage_bytes(&self) -> usize {
        match &self.storage {
            QStorage::Packed(b) => b.len(),
            QStorage::Dense(d) => 4 * d.len(),
        }
    }

    /// Total serialized weight bytes: codes + scales.
    pub fn packed_bytes(&self) -> usize {
        self.storage_bytes() + 4 * self.scales.len()
    }

    /// What the same tensor costs dense (f32).
    pub fn dense_bytes(&self) -> usize {
        4 * self.numel()
    }

    /// The signed integer code at (i, j); dense passthroughs have no
    /// codes.
    pub fn code_at(&self, i: usize, j: usize) -> i32 {
        match &self.storage {
            QStorage::Packed(bytes) => {
                let stride = row_stride(self.cols(), self.bits);
                decode(&bytes[i * stride..(i + 1) * stride], self.sbits(), j)
            }
            QStorage::Dense(_) => panic!("code_at on a dense passthrough"),
        }
    }

    /// Unpack every code row-major (test/diagnostic helper).
    pub fn unpack_codes(&self) -> Vec<i32> {
        let (rows, cols) = (self.rows(), self.cols());
        let mut out = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                out.push(self.code_at(i, j));
            }
        }
        out
    }

    /// Materialize the dense f32 tensor. Bit-identical to the f32
    /// quantize-dequantize output the codes were derived from.
    pub fn dequantize(&self) -> Tensor {
        match &self.storage {
            QStorage::Dense(d) => Tensor::new(self.shape.clone(), d.clone()),
            QStorage::Packed(bytes) => {
                let (rows, cols) = (self.rows(), self.cols());
                let (stride, sbits) = (row_stride(cols, self.bits),
                                       self.sbits());
                let mut data = vec![0.0f32; rows * cols];
                for i in 0..rows {
                    let row = &bytes[i * stride..(i + 1) * stride];
                    let out = &mut data[i * cols..(i + 1) * cols];
                    lut::dequant_cols(row, sbits, &self.scales, 0, cols,
                                      out);
                }
                Tensor::new(self.shape.clone(), data)
            }
        }
    }

    // ---- fused dequant kernels --------------------------------------------

    /// C rows `[i0, i0 + rows)` of C = deq(self) @ B into `cblock`
    /// (`[rows, n]` row-major): the tiled LUT microkernel. Packed rows
    /// dequantize [`KTILE`] columns at a time into a stack tile shared
    /// by an [`RBLOCK`]-row register block, then every B row in the
    /// tile streams once across the whole block. Per output element the
    /// accumulation is single-accumulator ascending-k — bit-identical
    /// to [`linalg::matmul_row`] on `self.dequantize()` and to the
    /// pre-LUT per-element kernel, for any row partitioning (so serial
    /// and pool-parallel paths agree bitwise).
    fn matmul_rows_into(&self, i0: usize, bd: &[f32], n: usize,
                        cblock: &mut [f32]) {
        if n == 0 {
            return;
        }
        let k = self.cols();
        let rows = cblock.len() / n;
        match &self.storage {
            QStorage::Dense(d) => {
                for (ri, crow) in cblock.chunks_mut(n).enumerate() {
                    let i = i0 + ri;
                    linalg::matmul_row(&d[i * k..(i + 1) * k], bd, n, crow);
                }
            }
            QStorage::Packed(bytes) => {
                let (stride, sbits) = (row_stride(k, self.bits),
                                       self.sbits());
                let mut wtile = [0.0f32; RBLOCK * KTILE];
                let mut r0 = 0usize;
                while r0 < rows {
                    let rb = RBLOCK.min(rows - r0);
                    let mut k0 = 0usize;
                    while k0 < k {
                        let kt = KTILE.min(k - k0);
                        for r in 0..rb {
                            let i = i0 + r0 + r;
                            let row = &bytes[i * stride..(i + 1) * stride];
                            lut::dequant_cols(
                                row, sbits, &self.scales, k0, k0 + kt,
                                &mut wtile[r * KTILE..r * KTILE + kt]);
                        }
                        for t in 0..kt {
                            let brow = &bd[(k0 + t) * n..(k0 + t + 1) * n];
                            for r in 0..rb {
                                let aik = wtile[r * KTILE + t];
                                let crow = &mut cblock
                                    [(r0 + r) * n..(r0 + r + 1) * n];
                                for (cv, bv) in crow.iter_mut().zip(brow) {
                                    *cv += aik * bv;
                                }
                            }
                        }
                        k0 += kt;
                    }
                    r0 += rb;
                }
            }
        }
    }

    /// y rows `[i0, i0 + out.len())` of y = deq(self) @ x: the matvec
    /// twin of [`QTensor::matmul_rows_into`] — [`RBLOCK`] accumulators
    /// sweep shared [`KTILE`]-wide dequant tiles against the matching x
    /// window. Each accumulator runs ascending-k, so the result is
    /// bit-identical to the dense dot and the per-element kernel.
    fn dot_rows_into(&self, i0: usize, x: &[f32], out: &mut [f32]) {
        let k = self.cols();
        match &self.storage {
            QStorage::Dense(d) => {
                for (ri, o) in out.iter_mut().enumerate() {
                    let i = i0 + ri;
                    *o = d[i * k..(i + 1) * k]
                        .iter()
                        .zip(x)
                        .map(|(p, q)| p * q)
                        .sum();
                }
            }
            QStorage::Packed(bytes) => {
                let (stride, sbits) = (row_stride(k, self.bits),
                                       self.sbits());
                let rows = out.len();
                let mut wtile = [0.0f32; RBLOCK * KTILE];
                let mut r0 = 0usize;
                while r0 < rows {
                    let rb = RBLOCK.min(rows - r0);
                    let mut acc = [0.0f32; RBLOCK];
                    let mut k0 = 0usize;
                    while k0 < k {
                        let kt = KTILE.min(k - k0);
                        for r in 0..rb {
                            let i = i0 + r0 + r;
                            let row = &bytes[i * stride..(i + 1) * stride];
                            lut::dequant_cols(
                                row, sbits, &self.scales, k0, k0 + kt,
                                &mut wtile[r * KTILE..r * KTILE + kt]);
                        }
                        let xt = &x[k0..k0 + kt];
                        for (r, a) in acc.iter_mut().enumerate().take(rb) {
                            let wt = &wtile[r * KTILE..r * KTILE + kt];
                            let mut s = *a;
                            for (wv, xv) in wt.iter().zip(xt) {
                                s += wv * xv;
                            }
                            *a = s;
                        }
                        k0 += kt;
                    }
                    out[r0..r0 + rb].copy_from_slice(&acc[..rb]);
                    r0 += rb;
                }
            }
        }
    }

    /// C = deq(self) @ B without materializing deq(self); row-block
    /// parallel on `pool` (serial when `None`), bit-identical either way.
    pub fn qmatmul_with(&self, pool: Option<&ThreadPool>, b: &Tensor)
                        -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (b.shape()[0], b.shape()[1]);
        assert_eq!(k, k2, "qmatmul {:?} @ {:?}", self.shape, b.shape());
        let mut c = Tensor::zeros(&[m, n]);
        let bd = b.data();
        match pool {
            Some(p) if m > 1 && n > 0 => {
                let rpb = par::rows_per_block(m, p.n_workers());
                p.scatter_chunks(c.data_mut(), rpb * n, |ci, chunk| {
                    self.matmul_rows_into(ci * rpb, bd, n, chunk);
                });
            }
            _ => {
                self.matmul_rows_into(0, bd, n, c.data_mut());
            }
        }
        c
    }

    /// C = deq(self) @ B on the shared pool above the size threshold.
    pub fn qmatmul(&self, b: &Tensor) -> Tensor {
        let ops = self.rows() * self.cols() * b.shape()[1];
        self.qmatmul_with(par::pool_for_ops(ops), b)
    }

    /// y = deq(self) @ x, row-partitioned, without materializing
    /// deq(self). Bit-identical to `par::matvec_with` on the dequantized
    /// tensor, for any worker count.
    pub fn qmatvec_with(&self, pool: Option<&ThreadPool>, x: &[f32])
                        -> Vec<f32> {
        let m = self.rows();
        assert_eq!(self.cols(), x.len(), "qmatvec {:?} @ [{}]", self.shape,
                   x.len());
        let mut y = vec![0.0f32; m];
        match pool {
            Some(p) if m > 1 => {
                let rpb = par::rows_per_block(m, p.n_workers());
                p.scatter_chunks(&mut y, rpb, |ci, chunk| {
                    self.dot_rows_into(ci * rpb, x, chunk);
                });
            }
            _ => {
                self.dot_rows_into(0, x, &mut y);
            }
        }
        y
    }

    /// y = deq(self) @ x with the pre-LUT per-element `decode()` kernel
    /// (serial). Kept as the independent bit-parity oracle for the
    /// property tests and the `scalar` baseline of the microbench's
    /// LUT-vs-legacy rows — not a production path.
    pub fn qmatvec_scalar(&self, x: &[f32]) -> Vec<f32> {
        let (m, k) = (self.rows(), self.cols());
        assert_eq!(k, x.len(), "qmatvec_scalar {:?} @ [{}]", self.shape,
                   x.len());
        let mut y = vec![0.0f32; m];
        match &self.storage {
            QStorage::Dense(d) => {
                for (i, out) in y.iter_mut().enumerate() {
                    *out = d[i * k..(i + 1) * k]
                        .iter()
                        .zip(x)
                        .map(|(p, q)| p * q)
                        .sum();
                }
            }
            QStorage::Packed(bytes) => {
                let (stride, sbits) = (row_stride(k, self.bits),
                                       self.sbits());
                for (i, out) in y.iter_mut().enumerate() {
                    let row = &bytes[i * stride..(i + 1) * stride];
                    let mut acc = 0.0f32;
                    for (j, &xv) in x.iter().enumerate() {
                        acc += decode(row, sbits, j) as f32
                            * self.scales[j]
                            * xv;
                    }
                    *out = acc;
                }
            }
        }
        y
    }

    /// C = deq(self) @ B with the pre-LUT per-element `decode()` kernel
    /// (serial); see [`QTensor::qmatvec_scalar`].
    pub fn qmatmul_scalar(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (b.shape()[0], b.shape()[1]);
        assert_eq!(k, k2, "qmatmul_scalar {:?} @ {:?}", self.shape,
                   b.shape());
        let mut c = Tensor::zeros(&[m, n]);
        let bd = b.data();
        let cd = c.data_mut();
        match &self.storage {
            QStorage::Dense(d) => {
                for i in 0..m {
                    linalg::matmul_row(&d[i * k..(i + 1) * k], bd, n,
                                       &mut cd[i * n..(i + 1) * n]);
                }
            }
            QStorage::Packed(bytes) => {
                let (stride, sbits) = (row_stride(k, self.bits),
                                       self.sbits());
                for i in 0..m {
                    let row = &bytes[i * stride..(i + 1) * stride];
                    let crow = &mut cd[i * n..(i + 1) * n];
                    for kk in 0..k {
                        let aik = decode(row, sbits, kk) as f32
                            * self.scales[kk];
                        let brow = &bd[kk * n..(kk + 1) * n];
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
        c
    }

    /// y = deq(self) @ x on the shared pool above the size threshold.
    pub fn qmatvec(&self, x: &[f32]) -> Vec<f32> {
        self.qmatvec_with(par::pool_for_ops(self.numel()), x)
    }

    /// Dequantize fields `[j0, j1)` of row `i` into `out` (one f32 per
    /// field, `out.len() == j1 - j0`). The values are bitwise the slice
    /// `dequantize()[i][j0..j1]` — `code as f32 * scale` is the same
    /// single multiplication, now decoded through the byte LUTs
    /// ([`super::lut::dequant_cols`]; mid-byte `j0` stripes take a
    /// scalar head, whole bytes after that).
    pub fn dequant_fields(&self, i: usize, j0: usize, j1: usize,
                          out: &mut [f32]) {
        debug_assert_eq!(out.len(), j1 - j0);
        let cols = self.cols();
        match &self.storage {
            QStorage::Dense(d) => {
                out.copy_from_slice(&d[i * cols + j0..i * cols + j1]);
            }
            QStorage::Packed(bytes) => {
                let (stride, sbits) = (row_stride(cols, self.bits),
                                       self.sbits());
                let row = &bytes[i * stride..(i + 1) * stride];
                lut::dequant_cols(row, sbits, &self.scales, j0, j1, out);
            }
        }
    }

    /// Dequantize one full row into `out` (`out.len() == cols`). The
    /// decode engine's embedding-lookup path.
    pub fn dequant_row_into(&self, i: usize, out: &mut [f32]) {
        self.dequant_fields(i, 0, self.cols(), out);
    }

    /// C = A @ deq(self) without materializing deq(self): the decode
    /// engine's x-@-W orientation, where `self` is a `[in, out]` weight
    /// and A carries one activation row per batch element.
    ///
    /// Partitioning is by *output-column* stripes (not batch rows): each
    /// stripe decodes every weight row exactly once and amortizes it
    /// across all batch rows, so the per-element decode cost shrinks by
    /// the batch size — the reason packed decode overtakes dense f32 at
    /// batch >= 8. Per output element the accumulation is in ascending-k
    /// order with `code as f32 * scale` values, identical to
    /// [`par::matmul_with`] over `(a, self.dequantize())` for any pool on
    /// either side — bit-exact dense/fused and serial/parallel parity.
    pub fn qmatmul_rhs_with(&self, pool: Option<&ThreadPool>, a: &Tensor)
                            -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let (k2, n) = (self.rows(), self.cols());
        assert_eq!(k, k2, "qmatmul_rhs {:?} @ {:?}", a.shape(), self.shape);
        let ad = a.data();
        // One job per column stripe; each job owns a contiguous
        // [m, stripe] buffer merged into C afterwards (column stripes of
        // a row-major C are not contiguous, so scatter_chunks does not
        // apply).
        let stripe_kernel = |j0: usize, j1: usize, c: &mut [f32]| {
            let jw = j1 - j0;
            let mut wrow = vec![0.0f32; jw];
            for kk in 0..k {
                self.dequant_fields(kk, j0, j1, &mut wrow);
                for r in 0..m {
                    let ark = ad[r * k + kk];
                    let crow = &mut c[r * jw..(r + 1) * jw];
                    for (cv, wv) in crow.iter_mut().zip(&wrow) {
                        *cv += ark * wv;
                    }
                }
            }
        };
        let stripes: Vec<(usize, usize)> = match pool {
            Some(p) if n > 1 => {
                let sw = n.div_ceil(p.n_workers().max(1) * 4).max(1);
                (0..n.div_ceil(sw))
                    .map(|si| (si * sw, ((si + 1) * sw).min(n)))
                    .collect()
            }
            _ => vec![(0, n)],
        };
        let parts: Vec<Vec<f32>> = par::par_map(
            if stripes.len() > 1 { pool } else { None }, &stripes,
            |_si, &(j0, j1)| {
                let mut c = vec![0.0f32; m * (j1 - j0)];
                stripe_kernel(j0, j1, &mut c);
                c
            });
        let mut c = Tensor::zeros(&[m, n]);
        let cd = c.data_mut();
        for (&(j0, j1), part) in stripes.iter().zip(&parts) {
            let jw = j1 - j0;
            for r in 0..m {
                cd[r * n + j0..r * n + j1]
                    .copy_from_slice(&part[r * jw..(r + 1) * jw]);
            }
        }
        c
    }

    /// C = A @ deq(self) on the shared pool above the size threshold.
    pub fn qmatmul_rhs(&self, a: &Tensor) -> Tensor {
        let ops = a.shape()[0] * self.numel();
        self.qmatmul_rhs_with(par::pool_for_ops(ops), a)
    }

    /// Integer twin of [`Self::qmatmul_rhs_with`]: C = A @ deq(self)
    /// where A arrives as pre-quantized i8 codes + per-row scales
    /// ([`intkern::QuantActs`], emitted once per activation tap). Each
    /// output element is one exact i8×i8→i32 dot product rescaled once
    /// by `act_scale × weight_scale` — no per-element weight dequant.
    /// Same column-stripe partitioning as the f32 kernel, and the i32
    /// sums are backend- and stripe-exact, so results are bit-identical
    /// across Scalar/AVX2/NEON, worker counts, and stripe boundaries
    /// (DESIGN.md §11). Only defined for packed storage.
    pub fn qmatmul_rhs_int_with(&self, pool: Option<&ThreadPool>,
                                acts: &intkern::QuantActs,
                                backend: intkern::Backend) -> Tensor {
        let (m, k) = (acts.m(), acts.k());
        let (k2, n) = (self.rows(), self.cols());
        assert_eq!(k, k2, "qmatmul_rhs_int [{m}, {k}] @ {:?}", self.shape);
        let QStorage::Packed(bytes) = &self.storage else {
            panic!("qmatmul_rhs_int needs packed storage");
        };
        let (stride, sbits) = (row_stride(n, self.bits), self.sbits());
        let stripe_kernel = |j0: usize, j1: usize, c: &mut [f32]| {
            let jw = j1 - j0;
            let mut acc = vec![0i32; m * jw];
            intkern::accumulate_stripe(bytes, stride, sbits, k, j0, j1,
                                       acts, backend, &mut acc);
            for r in 0..m {
                let sa = acts.scale(r);
                let arow = &acc[r * jw..(r + 1) * jw];
                let crow = &mut c[r * jw..(r + 1) * jw];
                for ((cv, &av), &sw) in crow.iter_mut().zip(arow)
                    .zip(&self.scales[j0..j1])
                {
                    *cv = av as f32 * (sa * sw);
                }
            }
        };
        let stripes: Vec<(usize, usize)> = match pool {
            Some(p) if n > 1 => {
                let sw = n.div_ceil(p.n_workers().max(1) * 4).max(1);
                (0..n.div_ceil(sw))
                    .map(|si| (si * sw, ((si + 1) * sw).min(n)))
                    .collect()
            }
            _ => vec![(0, n)],
        };
        let parts: Vec<Vec<f32>> = par::par_map(
            if stripes.len() > 1 { pool } else { None }, &stripes,
            |_si, &(j0, j1)| {
                let mut c = vec![0.0f32; m * (j1 - j0)];
                stripe_kernel(j0, j1, &mut c);
                c
            });
        let mut c = Tensor::zeros(&[m, n]);
        let cd = c.data_mut();
        for (&(j0, j1), part) in stripes.iter().zip(&parts) {
            let jw = j1 - j0;
            for r in 0..m {
                cd[r * n + j0..r * n + j1]
                    .copy_from_slice(&part[r * jw..(r + 1) * jw]);
            }
        }
        c
    }

    // ---- row-parallel shard views (DESIGN.md §14) -------------------------

    /// Output-column shard `[j0, j1)` of a packed `[in, out]` weight:
    /// the codes of those columns repacked into a self-contained
    /// QTensor whose per-column scales are exactly `scales[j0..j1]`.
    /// Running [`Self::qmatmul_rhs_int_with`] on every shard and
    /// concatenating the column stripes in ascending `j0` order is
    /// bit-identical to running it on the full tensor — the kernel
    /// already partitions by column stripe internally, so a shard
    /// boundary is just a stripe boundary that happens to live in
    /// another process.
    pub fn shard_cols(&self, j0: usize, j1: usize) -> QTensor {
        assert!(self.is_packed(), "shard_cols needs packed storage");
        assert!(j0 < j1 && j1 <= self.cols(),
                "shard_cols [{j0}, {j1}) of {} columns", self.cols());
        let (rows, jw) = (self.rows(), j1 - j0);
        let mut codes = Vec::with_capacity(rows * jw);
        for i in 0..rows {
            for j in j0..j1 {
                codes.push(self.code_at(i, j));
            }
        }
        QTensor::pack(&[rows, jw], self.bits, &codes,
                      self.scales[j0..j1].to_vec())
    }

    /// Contraction-row shard `[k0, k1)` of a packed `[in, out]` weight:
    /// those input rows repacked with the *full* per-output-column
    /// scale vector, so the shard stays self-describing. Summing the
    /// exact i32 partials of [`Self::accumulate_int`] over all shards
    /// (any order — integer addition is associative) and rescaling the
    /// total once by `act_scale * scales[j]` is bit-identical to the
    /// unsharded [`Self::qmatmul_rhs_int_with`], which is why the
    /// reduction weights (wo / w_down) can split across workers without
    /// breaking stream parity (DESIGN.md §14).
    pub fn shard_rows(&self, k0: usize, k1: usize) -> QTensor {
        assert!(self.is_packed(), "shard_rows needs packed storage");
        assert!(k0 < k1 && k1 <= self.rows(),
                "shard_rows [{k0}, {k1}) of {} rows", self.rows());
        let (cols, kw) = (self.cols(), k1 - k0);
        let mut codes = Vec::with_capacity(kw * cols);
        for i in k0..k1 {
            for j in 0..cols {
                codes.push(self.code_at(i, j));
            }
        }
        QTensor::pack(&[kw, cols], self.bits, &codes, self.scales.clone())
    }

    /// Full-width exact i32 accumulation: `acc[r][j] += Σ_k
    /// act_code[r][k] * weight_code[k][j]` over every output column.
    /// This is the worker-side partial of the row-parallel reduction —
    /// no scales are applied, so partials from different shards can be
    /// summed exactly before the single rescale. `acc` is `[m, n]`
    /// row-major and is accumulated into, not overwritten. Packed
    /// storage only.
    pub fn accumulate_int(&self, acts: &intkern::QuantActs,
                          backend: intkern::Backend, acc: &mut [i32]) {
        let (m, k) = (acts.m(), acts.k());
        let (k2, n) = (self.rows(), self.cols());
        assert_eq!(k, k2, "accumulate_int [{m}, {k}] @ {:?}", self.shape);
        assert_eq!(acc.len(), m * n, "acc len vs [{m}, {n}]");
        let QStorage::Packed(bytes) = &self.storage else {
            panic!("accumulate_int needs packed storage");
        };
        let (stride, sbits) = (row_stride(n, self.bits), self.sbits());
        intkern::accumulate_stripe(bytes, stride, sbits, k, 0, n, acts,
                                   backend, acc);
    }
}

/// Bytes per packed row: columns padded up to a whole byte so every row
/// starts byte-aligned (what makes row-block partitioning trivial).
pub fn row_stride(cols: usize, bits: u32) -> usize {
    cols.div_ceil(codes_per_byte(bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed, 11);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    fn random_codes(rng: &mut Pcg, n: usize, bits: u32) -> Vec<i32> {
        let span = 1i64 << bits;
        (0..n)
            .map(|_| (rng.below(span as u64) as i64 - (span / 2)) as i32)
            .collect()
    }

    #[test]
    fn pack_roundtrip_all_bits() {
        let mut rng = Pcg::new(1, 0);
        for bits in [2u32, 3, 4, 5, 6, 8] {
            for (rows, cols) in [(1, 1), (3, 5), (7, 16), (4, 33)] {
                let codes = random_codes(&mut rng, rows * cols, bits);
                let scales = vec![1.0f32; cols];
                let q = QTensor::pack(&[rows, cols], bits, &codes, scales);
                assert_eq!(q.unpack_codes(), codes, "{bits}b {rows}x{cols}");
            }
        }
    }

    #[test]
    fn from_codes_without_packed_layout_is_dense() {
        // 10-bit: codes don't fit a byte — dense passthrough, same values.
        let codes = vec![-512, 511, 100, -7, 0, 3];
        let q = QTensor::from_codes(&[2, 3], 10, &codes,
                                    vec![0.5, 1.0, 2.0]);
        assert!(!q.is_packed());
        assert_eq!(q.dequantize().data(),
                   &[-256.0, 511.0, 200.0, -3.5, 0.0, 6.0]);
    }

    #[test]
    fn packed_sizes() {
        assert_eq!(row_stride(5, 4), 3);
        assert_eq!(row_stride(5, 3), 3); // 3-bit codes in 4-bit fields
        assert_eq!(row_stride(4, 4), 2);
        assert_eq!(row_stride(5, 2), 2);
        assert_eq!(row_stride(5, 8), 5);
        assert_eq!(row_stride(5, 6), 5); // 6-bit codes in bytes
        let q = QTensor::pack(&[16, 5], 4, &vec![0; 80], vec![0.5; 5]);
        assert_eq!(q.storage_bytes(), 16 * 3);
        assert_eq!(q.packed_bytes(), 16 * 3 + 4 * 5);
        assert_eq!(q.dense_bytes(), 4 * 80);
    }

    #[test]
    fn dequantize_is_code_times_scale() {
        let codes = vec![-8, 7, 0, 1, -1, 3];
        let q = QTensor::pack(&[2, 3], 4, &codes, vec![0.5, 2.0, 1.5]);
        assert_eq!(q.dequantize().data(),
                   &[-4.0, 14.0, 0.0, 0.5, -2.0, 4.5]);
        assert_eq!(q.code_at(0, 0), -8);
        assert_eq!(q.code_at(1, 2), 3);
    }

    #[test]
    fn dense_passthrough_identity() {
        let t = randn(&[4, 6], 2);
        let q = QTensor::from_dense(&t);
        assert_eq!(q.bits(), 16);
        assert!(!q.is_packed());
        assert_eq!(q.dequantize(), t);
    }

    #[test]
    fn qmatmul_matches_dense_kernel_bitwise() {
        let mut rng = Pcg::new(3, 0);
        for bits in [2u32, 4, 8] {
            let (m, k, n) = (7, 13, 5);
            let codes = random_codes(&mut rng, m * k, bits);
            let scales: Vec<f32> =
                (0..k).map(|j| 0.1 + 0.05 * j as f32).collect();
            let q = QTensor::pack(&[m, k], bits, &codes, scales);
            let b = randn(&[k, n], 40 + bits as u64);
            let want = par::matmul_with(None, &q.dequantize(), &b);
            let got = q.qmatmul_with(None, &b);
            assert_eq!(want.data(), got.data(), "{bits}-bit");
        }
    }

    #[test]
    fn qmatvec_matches_dense_kernel_bitwise() {
        let mut rng = Pcg::new(4, 0);
        let (m, k) = (9, 17);
        let codes = random_codes(&mut rng, m * k, 4);
        let scales: Vec<f32> = (0..k).map(|j| 0.3 + 0.01 * j as f32).collect();
        let q = QTensor::pack(&[m, k], 4, &codes, scales);
        let x: Vec<f32> = (0..k).map(|i| i as f32 * 0.25 - 2.0).collect();
        let want = par::matvec_with(None, &q.dequantize(), &x);
        assert_eq!(want, q.qmatvec_with(None, &x));
    }

    #[test]
    fn qmatmul_rhs_matches_dense_kernel_bitwise() {
        let mut rng = Pcg::new(5, 0);
        for bits in [2u32, 4, 8] {
            let (m, k, n) = (6, 11, 9);
            let codes = random_codes(&mut rng, k * n, bits);
            let scales: Vec<f32> =
                (0..n).map(|j| 0.2 + 0.03 * j as f32).collect();
            let q = QTensor::pack(&[k, n], bits, &codes, scales);
            let a = randn(&[m, k], 70 + bits as u64);
            let want = par::matmul_with(None, &a, &q.dequantize());
            let got = q.qmatmul_rhs_with(None, &a);
            assert_eq!(want.data(), got.data(), "{bits}-bit serial");
            let pool = ThreadPool::new(3, 32);
            let got_par = q.qmatmul_rhs_with(Some(&pool), &a);
            assert_eq!(want.data(), got_par.data(), "{bits}-bit par");
        }
        // Dense passthrough storage takes the same path.
        let t = randn(&[7, 5], 80);
        let q = QTensor::from_dense(&t);
        let a = randn(&[3, 7], 81);
        assert_eq!(par::matmul_with(None, &a, &t).data(),
                   q.qmatmul_rhs_with(None, &a).data());
    }

    #[test]
    fn dequant_row_matches_dequantize() {
        let mut rng = Pcg::new(6, 0);
        let (rows, cols) = (5, 13);
        let codes = random_codes(&mut rng, rows * cols, 4);
        let scales: Vec<f32> = (0..cols).map(|j| 0.1 + 0.2 * j as f32)
            .collect();
        let q = QTensor::pack(&[rows, cols], 4, &codes, scales);
        let dq = q.dequantize();
        let mut row = vec![0.0f32; cols];
        for i in 0..rows {
            q.dequant_row_into(i, &mut row);
            assert_eq!(&row[..], dq.row(i), "row {i}");
        }
        let mut mid = vec![0.0f32; 6];
        q.dequant_fields(2, 3, 9, &mut mid);
        assert_eq!(&mid[..], &dq.row(2)[3..9]);
    }

    #[test]
    fn lut_kernels_match_scalar_kernels_across_tile_edges() {
        // Shapes that cross both microkernel boundaries: rows off the
        // RBLOCK multiple and cols past KTILE, at every storage width
        // (3- and 5-bit ride the 4- and 8-bit field layouts).
        let mut rng = Pcg::new(7, 0);
        for bits in [2u32, 3, 4, 5, 8] {
            for (m, k) in [(1, 1), (RBLOCK + 1, KTILE + 7),
                           (2 * RBLOCK + 3, 2 * KTILE + 1), (9, 300)] {
                let codes = random_codes(&mut rng, m * k, bits);
                let scales: Vec<f32> =
                    (0..k).map(|j| 0.05 + 0.01 * j as f32).collect();
                let q = QTensor::pack(&[m, k], bits, &codes, scales);
                let x: Vec<f32> =
                    (0..k).map(|i| (i as f32).sin()).collect();
                assert_eq!(q.qmatvec_with(None, &x), q.qmatvec_scalar(&x),
                           "{bits}b {m}x{k} matvec");
                let b = randn(&[k, 3], 90 + bits as u64);
                assert_eq!(q.qmatmul_with(None, &b).data(),
                           q.qmatmul_scalar(&b).data(),
                           "{bits}b {m}x{k} matmul");
            }
        }
    }

    fn random_acts(rng: &mut Pcg, m: usize, k: usize)
                   -> intkern::QuantActs {
        let codes: Vec<i8> = (0..m * k)
            .map(|_| (rng.below(16) as i64 - 8) as i8)
            .collect();
        let scales: Vec<f32> =
            (0..m).map(|r| 0.02 + 0.01 * r as f32).collect();
        intkern::QuantActs::from_parts(codes, scales, m, k)
    }

    /// Column shards recombine bitwise: concatenating the int-kernel
    /// output stripes of `shard_cols` pieces (ascending j0) equals the
    /// unsharded kernel exactly, for any shard count (DESIGN.md §14).
    #[test]
    fn col_shards_concat_bitwise_to_full_int_matmul() {
        let mut rng = Pcg::new(21, 0);
        for bits in [4u32, 8] {
            let (m, k, n) = (3, 19, 23);
            let codes = random_codes(&mut rng, k * n, bits);
            let scales: Vec<f32> =
                (0..n).map(|j| 0.1 + 0.02 * j as f32).collect();
            let q = QTensor::pack(&[k, n], bits, &codes, scales);
            let acts = random_acts(&mut rng, m, k);
            let be = intkern::Backend::Scalar;
            let full = q.qmatmul_rhs_int_with(None, &acts, be);
            for shards in [1usize, 2, 4] {
                let mut got = Tensor::zeros(&[m, n]);
                for s in 0..shards {
                    let (j0, j1) =
                        ((n * s) / shards, (n * (s + 1)) / shards);
                    let jw = j1 - j0;
                    let part = q.shard_cols(j0, j1)
                        .qmatmul_rhs_int_with(None, &acts, be);
                    for r in 0..m {
                        got.data_mut()[r * n + j0..r * n + j1]
                            .copy_from_slice(
                                &part.data()[r * jw..(r + 1) * jw]);
                    }
                }
                assert_eq!(full.data(), got.data(),
                           "{bits}b x{shards} shards");
            }
        }
    }

    /// Row shards recombine bitwise: exact i32 partials from
    /// `accumulate_int` over `shard_rows` pieces sum (any shard count)
    /// to the full-contraction accumulator, and one rescale of that
    /// total reproduces the unsharded kernel output exactly — the §14
    /// reduction-weight invariant.
    #[test]
    fn row_shard_partials_sum_bitwise_to_full_int_matmul() {
        let mut rng = Pcg::new(22, 0);
        let (m, k, n) = (2, 24, 9);
        let codes = random_codes(&mut rng, k * n, 4);
        let scales: Vec<f32> =
            (0..n).map(|j| 0.2 + 0.05 * j as f32).collect();
        let q = QTensor::pack(&[k, n], 4, &codes, scales);
        let acts = random_acts(&mut rng, m, k);
        let be = intkern::Backend::Scalar;
        let full = q.qmatmul_rhs_int_with(None, &acts, be);
        for shards in [1usize, 2, 3] {
            let mut acc = vec![0i32; m * n];
            for s in 0..shards {
                let (k0, k1) = ((k * s) / shards, (k * (s + 1)) / shards);
                let shard = q.shard_rows(k0, k1);
                let mut sc = Vec::with_capacity(m * (k1 - k0));
                for r in 0..m {
                    sc.extend_from_slice(&acts.row_codes(r)[k0..k1]);
                }
                let sacts = intkern::QuantActs::from_parts(
                    sc, (0..m).map(|r| acts.scale(r)).collect(), m,
                    k1 - k0);
                let mut part = vec![0i32; m * n];
                shard.accumulate_int(&sacts, be, &mut part);
                for (a, p) in acc.iter_mut().zip(&part) {
                    *a += p;
                }
            }
            let mut got = vec![0.0f32; m * n];
            for r in 0..m {
                let sa = acts.scale(r);
                for j in 0..n {
                    got[r * n + j] =
                        acc[r * n + j] as f32 * (sa * q.scales()[j]);
                }
            }
            assert_eq!(full.data(), &got[..], "x{shards} shards");
        }
    }

    #[test]
    fn shard_views_carry_their_scales() {
        let mut rng = Pcg::new(23, 0);
        let (k, n) = (6, 10);
        let codes = random_codes(&mut rng, k * n, 4);
        let scales: Vec<f32> =
            (0..n).map(|j| 1.0 + j as f32).collect();
        let q = QTensor::pack(&[k, n], 4, &codes, scales.clone());
        let c = q.shard_cols(3, 7);
        assert_eq!(c.shape(), &[k, 4]);
        assert_eq!(c.scales(), &scales[3..7]);
        for i in 0..k {
            for j in 0..4 {
                assert_eq!(c.code_at(i, j), q.code_at(i, 3 + j));
            }
        }
        let r = q.shard_rows(2, 5);
        assert_eq!(r.shape(), &[3, n]);
        assert_eq!(r.scales(), &scales[..]);
        for i in 0..3 {
            for j in 0..n {
                assert_eq!(r.code_at(i, j), q.code_at(2 + i, j));
            }
        }
    }

    #[test]
    fn from_parts_validates() {
        assert!(QTensor::from_parts(vec![2, 3], 4, vec![1.0; 3],
                                    QStorage::Packed(vec![0u8; 4]))
                .is_ok());
        // wrong payload size
        assert!(QTensor::from_parts(vec![2, 3], 4, vec![1.0; 3],
                                    QStorage::Packed(vec![0u8; 5]))
                .is_err());
        // wrong scale count
        assert!(QTensor::from_parts(vec![2, 3], 4, vec![1.0; 2],
                                    QStorage::Packed(vec![0u8; 4]))
                .is_err());
        // unpackable bits (9-bit codes have no packed layout)
        assert!(QTensor::from_parts(vec![2, 3], 9, vec![1.0; 3],
                                    QStorage::Packed(vec![0u8; 4]))
                .is_err());
        // dense numel mismatch
        assert!(QTensor::from_parts(vec![2, 3], 16, vec![],
                                    QStorage::Dense(vec![0.0; 5]))
                .is_err());
    }
}
