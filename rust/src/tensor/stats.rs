//! Statistics on tensors: moments, excess kurtosis (the paper's outlier
//! metric, Eq. 4), and histograms (Figures 2, 8-11).
//!
//! The moment reduction is *blocked*: data is reduced per fixed
//! [`MOMENT_BLOCK`]-element block and block partials are combined in
//! block order. Serial and parallel paths share the exact same block
//! structure, so kurtosis telemetry is bit-identical for any worker
//! count (pinned by `rust/tests/par_properties.rs`).

use super::{par, Tensor};
use crate::util::threadpool::ThreadPool;

/// First four central moments in two blocked passes (numerically stable
/// enough in f64 accumulation for activation-scale data).
#[derive(Clone, Copy, Debug, Default)]
pub struct Moments {
    pub n: usize,
    pub mean: f64,
    pub var: f64,
    pub m3: f64,
    pub m4: f64,
    pub min: f32,
    pub max: f32,
}

/// Fixed reduction block size (elements). Partials are always computed
/// per block and combined in block order — independent of worker count —
/// which is what makes the parallel reduction deterministic.
pub const MOMENT_BLOCK: usize = 4096;

/// Per-block central-moment partial (pass 2).
#[derive(Clone, Copy, Debug)]
struct BlockMoments {
    m2: f64,
    m3: f64,
    m4: f64,
    lo: f32,
    hi: f32,
}

/// Reduce each fixed-size block of `data` with `f`, writing partials in
/// block order; block `i` covers `data[i*MOMENT_BLOCK ..]`. Dispatches
/// the blocks over `pool` when available.
fn per_block<R, F>(pool: Option<&ThreadPool>, data: &[f32], out: &mut [R],
                   f: F)
where
    R: Send,
    F: Fn(&[f32]) -> R + Sync,
{
    let block = |bi: usize| {
        let s0 = bi * MOMENT_BLOCK;
        let s1 = (s0 + MOMENT_BLOCK).min(data.len());
        &data[s0..s1]
    };
    match pool {
        Some(p) if out.len() > 1 => {
            p.scatter_chunks(out, 1, |bi, slot| slot[0] = f(block(bi)));
        }
        _ => {
            for (bi, slot) in out.iter_mut().enumerate() {
                *slot = f(block(bi));
            }
        }
    }
}

/// Blocked moment reduction over an explicit pool (`None` = serial).
/// Bit-identical across worker counts; see module docs.
pub fn moments_with(pool: Option<&ThreadPool>, data: &[f32]) -> Moments {
    let n = data.len();
    if n == 0 {
        return Moments::default();
    }
    let nb = n.div_ceil(MOMENT_BLOCK);

    // Pass 1: block sums -> mean (combined in block order).
    let mut sums = vec![0.0f64; nb];
    per_block(pool, data, &mut sums,
              |block| block.iter().map(|&v| v as f64).sum::<f64>());
    let mean = sums.iter().sum::<f64>() / n as f64;

    // Pass 2: central moments per block, combined in block order.
    let mut parts = vec![BlockMoments { m2: 0.0, m3: 0.0, m4: 0.0,
                                        lo: f32::INFINITY,
                                        hi: f32::NEG_INFINITY }; nb];
    per_block(pool, data, &mut parts, |block| {
        let (mut m2, mut m3, mut m4) = (0.0f64, 0.0f64, 0.0f64);
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in block {
            let d = v as f64 - mean;
            let d2 = d * d;
            m2 += d2;
            m3 += d2 * d;
            m4 += d2 * d2;
            lo = lo.min(v);
            hi = hi.max(v);
        }
        BlockMoments { m2, m3, m4, lo, hi }
    });
    let (mut m2, mut m3, mut m4) = (0.0f64, 0.0f64, 0.0f64);
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for p in &parts {
        m2 += p.m2;
        m3 += p.m3;
        m4 += p.m4;
        lo = lo.min(p.lo);
        hi = hi.max(p.hi);
    }
    Moments {
        n,
        mean,
        var: m2 / n as f64,
        m3: m3 / n as f64,
        m4: m4 / n as f64,
        min: lo,
        max: hi,
    }
}

/// Moments over the shared pool when the slice is large enough (the
/// kurtosis-telemetry hot path), serial otherwise.
pub fn moments(data: &[f32]) -> Moments {
    moments_with(par::pool_for_ops(data.len()), data)
}

/// Excess kurtosis E[((x-mu)/sigma)^4] - 3 (paper Eq. 4). Near 0 for a
/// Gaussian, huge for outlier-bearing activations (Adam: ~1818 in the
/// paper; OSP: 0.04).
pub fn excess_kurtosis(data: &[f32]) -> f64 {
    let m = moments(data);
    if m.var <= 1e-24 {
        return 0.0;
    }
    m.m4 / (m.var * m.var) - 3.0
}

pub fn tensor_kurtosis(t: &Tensor) -> f64 {
    excess_kurtosis(t.data())
}

/// Fixed-bin histogram over [lo, hi] with out-of-range clamping; the
/// figure renderers print these as the paper's activation histograms.
/// Non-finite values are never binned (NaN used to saturate into bin 0
/// via the `as i64` cast, silently skewing the left tail); they are
/// counted in `nonfinite` instead, so `counts` sums to
/// `total - nonfinite`.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u64>,
    /// All input values, finite or not.
    pub total: u64,
    /// NaN/inf inputs skipped during binning.
    pub nonfinite: u64,
}

impl Histogram {
    pub fn build(data: &[f32], lo: f32, hi: f32, bins: usize) -> Histogram {
        assert!(bins > 0 && hi > lo);
        let mut counts = vec![0u64; bins];
        let mut nonfinite = 0u64;
        let w = (hi - lo) / bins as f32;
        for &v in data {
            if !v.is_finite() {
                nonfinite += 1;
                continue;
            }
            let idx = (((v - lo) / w) as i64).clamp(0, bins as i64 - 1);
            counts[idx as usize] += 1;
        }
        Histogram { lo, hi, counts, total: data.len() as u64, nonfinite }
    }

    /// Symmetric histogram sized from the data's *finite* absolute
    /// maximum (an inf bound used to produce NaN bin widths; NaN inputs
    /// already fell out of the fold and then tripped `build`'s
    /// `hi > lo` assert on all-NaN data — now both degrade gracefully).
    pub fn auto(data: &[f32], bins: usize) -> Histogram {
        let m = data
            .iter()
            .filter(|v| v.is_finite())
            .fold(1e-6f32, |m, v| m.max(v.abs()));
        Histogram::build(data, -m, m, bins)
    }

    pub fn bin_center(&self, i: usize) -> f32 {
        let w = (self.hi - self.lo) / self.counts.len() as f32;
        self.lo + (i as f32 + 0.5) * w
    }

    /// Fraction of mass beyond `k` standard deviations (the Bondarenko
    /// et al. 6-sigma outlier criterion used in §5.2). Blocked parallel
    /// count; integer combination, so exact for any worker count.
    pub fn outlier_fraction(data: &[f32], k: f32) -> f64 {
        let m = moments(data);
        let sd = m.var.sqrt() as f32;
        if sd <= 0.0 || data.is_empty() {
            return 0.0;
        }
        let nb = data.len().div_ceil(MOMENT_BLOCK);
        let mut counts = vec![0usize; nb];
        per_block(par::pool_for_ops(data.len()), data, &mut counts,
                  |block| {
                      block
                          .iter()
                          .filter(|&&v| {
                              ((v as f64 - m.mean).abs() as f32) > k * sd
                          })
                          .count()
                  });
        counts.iter().sum::<usize>() as f64 / data.len() as f64
    }

    /// Render as a compact ASCII sparkline (for terminal reports).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = *self.counts.iter().max().unwrap_or(&1) as f64;
        self.counts
            .iter()
            .map(|&c| {
                if c == 0 {
                    ' '
                } else {
                    // log scale: outlier tails are invisible linearly
                    let f = ((c as f64).ln_1p() / max.ln_1p() * 7.0) as usize;
                    GLYPHS[f.min(7)]
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn gaussian_kurtosis_near_zero() {
        let mut rng = Pcg::new(0, 0);
        let data: Vec<f32> = (0..200_000).map(|_| rng.normal()).collect();
        let k = excess_kurtosis(&data);
        assert!(k.abs() < 0.1, "{k}");
    }

    #[test]
    fn outliers_blow_up_kurtosis() {
        let mut rng = Pcg::new(1, 0);
        let mut data: Vec<f32> = (0..100_000).map(|_| rng.normal()).collect();
        for v in data.iter_mut().take(40) {
            *v *= 300.0;
        }
        assert!(excess_kurtosis(&data) > 1000.0);
    }

    #[test]
    fn uniform_kurtosis_negative() {
        let mut rng = Pcg::new(2, 0);
        let data: Vec<f32> =
            (0..100_000).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let k = excess_kurtosis(&data);
        assert!((-1.4..-1.0).contains(&k), "{k}");
    }

    #[test]
    fn moments_known_values() {
        let m = moments(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m.mean - 2.5).abs() < 1e-9);
        assert!((m.var - 1.25).abs() < 1e-9);
        assert_eq!(m.min, 1.0);
        assert_eq!(m.max, 4.0);
    }

    #[test]
    fn histogram_total_and_bins() {
        let data = [-1.0f32, -0.5, 0.0, 0.5, 0.99, 5.0];
        let h = Histogram::build(&data, -1.0, 1.0, 4);
        assert_eq!(h.total, 6);
        assert_eq!(h.counts.iter().sum::<u64>(), 6);
        // 5.0 clamps into the last bin
        assert!(h.counts[3] >= 2);
        assert!((h.bin_center(0) + 0.75).abs() < 1e-6);
    }

    /// Regression: NaN used to be counted into bin 0 (`as i64`
    /// saturates to 0) and an inf absmax gave `auto` NaN bin widths.
    #[test]
    fn histogram_skips_nonfinite() {
        let data = [f32::NAN, -0.5, 0.5, f32::INFINITY, f32::NEG_INFINITY];
        let h = Histogram::build(&data, -1.0, 1.0, 2);
        assert_eq!(h.total, 5);
        assert_eq!(h.nonfinite, 3);
        assert_eq!(h.counts, vec![1, 1]); // NaN no longer inflates bin 0
        assert_eq!(h.counts.iter().sum::<u64>(), h.total - h.nonfinite);

        // auto ignores non-finite values when sizing bounds.
        let h = Histogram::auto(&data, 4);
        assert!(h.hi.is_finite() && h.hi >= 0.5);
        assert_eq!(h.nonfinite, 3);

        // All-NaN data neither panics nor bins anything.
        let h = Histogram::auto(&[f32::NAN, f32::NAN], 4);
        assert_eq!(h.nonfinite, 2);
        assert_eq!(h.counts.iter().sum::<u64>(), 0);
        assert!(!h.sparkline().is_empty());
    }

    #[test]
    fn outlier_fraction_sane() {
        let mut rng = Pcg::new(3, 0);
        let data: Vec<f32> = (0..100_000).map(|_| rng.normal()).collect();
        let f6 = Histogram::outlier_fraction(&data, 6.0);
        assert!(f6 < 1e-4, "{f6}"); // gaussian: essentially none
        let f1 = Histogram::outlier_fraction(&data, 1.0);
        assert!((f1 - 0.317).abs() < 0.02, "{f1}");
    }

    #[test]
    fn sparkline_renders() {
        let h = Histogram::build(&[0.0, 0.1, 0.2, 0.9], 0.0, 1.0, 8);
        let s = h.sparkline();
        assert_eq!(s.chars().count(), 8);
    }
}
