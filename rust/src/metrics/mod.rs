//! Telemetry: per-step scalar series, JSONL emission, and the kurtosis
//! tracker behind Figures 3 and 7.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One telemetry record (a step, an eval, a probe...).
#[derive(Clone, Debug, Default)]
pub struct Record {
    pub step: u64,
    pub fields: BTreeMap<String, f64>,
    pub tags: BTreeMap<String, String>,
}

impl Record {
    pub fn new(step: u64) -> Record {
        Record { step, ..Default::default() }
    }

    pub fn field(mut self, key: &str, v: f64) -> Record {
        self.fields.insert(key.to_string(), v);
        self
    }

    pub fn tag(mut self, key: &str, v: &str) -> Record {
        self.tags.insert(key.to_string(), v.to_string());
        self
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("step".to_string(), Json::num(self.step as f64));
        for (k, v) in &self.fields {
            obj.insert(k.clone(), Json::num(*v));
        }
        for (k, v) in &self.tags {
            obj.insert(k.clone(), Json::str(v.clone()));
        }
        Json::Obj(obj)
    }

    pub fn from_json(j: &Json) -> Option<Record> {
        let obj = j.as_obj()?;
        let mut r = Record::new(obj.get("step")?.as_f64()? as u64);
        for (k, v) in obj {
            if k == "step" {
                continue;
            }
            match v {
                Json::Num(n) => {
                    r.fields.insert(k.clone(), *n);
                }
                Json::Str(s) => {
                    r.tags.insert(k.clone(), s.clone());
                }
                _ => {}
            }
        }
        Some(r)
    }
}

/// Append-only JSONL telemetry writer (one per run).
pub struct TelemetryWriter {
    file: std::fs::File,
}

impl TelemetryWriter {
    pub fn create(path: &Path) -> Result<TelemetryWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?;
        Ok(TelemetryWriter { file })
    }

    pub fn write(&mut self, rec: &Record) -> Result<()> {
        writeln!(self.file, "{}", rec.to_json().dump())?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// Read a telemetry file back (the `repro fig3/fig7` renderers).
pub fn read_telemetry(path: &Path) -> Result<Vec<Record>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path:?}"))?;
    Ok(text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .filter_map(|l| Json::parse(l).ok())
        .filter_map(|j| Record::from_json(&j))
        .collect())
}

/// A scalar series (loss curve, kurtosis curve).
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub values: Vec<(u64, f64)>,
}

impl Series {
    pub fn push(&mut self, step: u64, v: f64) {
        self.values.push((step, v));
    }

    pub fn last(&self) -> Option<f64> {
        self.values.last().map(|&(_, v)| v)
    }

    /// Mean of the final `k` values (smoothed endpoint).
    pub fn tail_mean(&self, k: usize) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let n = self.values.len();
        let s = &self.values[n.saturating_sub(k)..];
        s.iter().map(|&(_, v)| v).sum::<f64>() / s.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.values.iter().map(|&(_, v)| v).fold(f64::NEG_INFINITY, f64::max)
    }

    /// Downsample to ~k points for terminal plotting.
    pub fn downsample(&self, k: usize) -> Vec<(u64, f64)> {
        if self.values.len() <= k {
            return self.values.clone();
        }
        let stride = self.values.len() as f64 / k as f64;
        (0..k)
            .map(|i| self.values[(i as f64 * stride) as usize])
            .collect()
    }
}

/// Wall-clock phase profiler for the coordinator's hot loop (§Perf):
/// accumulates named spans, reports a breakdown.
#[derive(Default)]
pub struct PhaseProfiler {
    totals: BTreeMap<String, (u64, f64)>,
}

pub struct PhaseGuard<'a> {
    profiler: &'a mut PhaseProfiler,
    name: String,
    start: Instant,
}

impl PhaseProfiler {
    pub fn span(&mut self, name: &str) -> PhaseGuard<'_> {
        PhaseGuard { profiler: self, name: name.to_string(),
                     start: Instant::now() }
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        let e = self.totals.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += secs;
    }

    pub fn report(&self) -> Vec<(String, u64, f64)> {
        self.totals
            .iter()
            .map(|(k, &(n, t))| (k.clone(), n, t))
            .collect()
    }

    pub fn total(&self, name: &str) -> f64 {
        self.totals.get(name).map(|&(_, t)| t).unwrap_or(0.0)
    }
}

impl Drop for PhaseGuard<'_> {
    fn drop(&mut self) {
        let dt = self.start.elapsed().as_secs_f64();
        self.profiler.add(&self.name, dt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_json_roundtrip() {
        let r = Record::new(17)
            .field("loss", 3.25)
            .field("kurt_max", 12.5)
            .tag("config", "osp");
        let j = r.to_json();
        let back = Record::from_json(&j).unwrap();
        assert_eq!(back.step, 17);
        assert_eq!(back.fields["loss"], 3.25);
        assert_eq!(back.tags["config"], "osp");
    }

    #[test]
    fn telemetry_write_read() {
        let dir = std::env::temp_dir().join("osp_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("telemetry.jsonl");
        {
            let mut w = TelemetryWriter::create(&path).unwrap();
            for i in 0..5 {
                w.write(&Record::new(i).field("loss", 5.0 - i as f64))
                    .unwrap();
            }
            w.flush().unwrap();
        }
        let recs = read_telemetry(&path).unwrap();
        assert_eq!(recs.len(), 5);
        assert_eq!(recs[4].fields["loss"], 1.0);
    }

    #[test]
    fn series_aggregates() {
        let mut s = Series::default();
        for i in 0..10 {
            s.push(i, i as f64);
        }
        assert_eq!(s.last(), Some(9.0));
        assert_eq!(s.tail_mean(2), 8.5);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.downsample(5).len(), 5);
    }

    #[test]
    fn profiler_accumulates() {
        let mut p = PhaseProfiler::default();
        {
            let _g = p.span("phase_a");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        p.add("phase_a", 0.1);
        let rep = p.report();
        assert_eq!(rep.len(), 1);
        assert_eq!(rep[0].1, 2);
        assert!(p.total("phase_a") > 0.1);
    }

    #[test]
    fn nan_serializes_as_null() {
        let r = Record::new(0).field("bad", f64::NAN);
        assert!(r.to_json().dump().contains("null"));
    }
}
