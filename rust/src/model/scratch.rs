//! Per-thread scratch arenas for the block-dequant attention kernel
//! (DESIGN.md §10).
//!
//! [`InferModel::forward_block`] runs one attention job per sequence —
//! serially on the caller, or scattered across `OSP_THREADS` workers.
//! Each job needs the same transient buffers every call: the dense K/V
//! tiles the packed cache block-dequantizes into, a softmax score row,
//! and RoPE staging. Allocating them per (layer, sequence, block) call
//! put several `Vec` allocations on the hottest loop in the serving
//! stack; instead every thread owns one lazily-created [`AttnScratch`]
//! that grows to its high-water mark and is reused across layers,
//! blocks, sequences, and engine steps.
//!
//! Lifetime: the arena lives for the thread (workers of the shared pool
//! live for the process), holds `2 * positions * d_model` f32 for the
//! K/V tiles of the longest sequence it has served, and is only ever
//! touched between [`with_attn`]'s borrow — attention jobs never nest,
//! so the `RefCell` borrow cannot conflict. Contents are *not* zeroed
//! between uses; every kernel fully overwrites the ranges it reads.
//!
//! [`InferModel::forward_block`]: super::InferModel::forward_block

use std::cell::RefCell;

/// Reusable attention scratch (one per thread; see module docs).
pub struct AttnScratch {
    /// Head-major dequantized K tile: `[n_heads, positions, head_dim]`.
    pub k: Vec<f32>,
    /// Head-major dequantized V tile, same layout as `k`.
    pub v: Vec<f32>,
    /// Softmax score row (one query's weights over all positions).
    pub w: Vec<f32>,
    /// RoPE'd query staging for one head.
    pub qh: Vec<f32>,
    /// RoPE'd key staging for one token (all heads).
    pub kbuf: Vec<f32>,
    /// Page-run staging for the paged-cache dequant scatter
    /// (DESIGN.md §13): one physical page's rows, position-major.
    pub pg: Vec<f32>,
}

fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

impl AttnScratch {
    fn new() -> AttnScratch {
        AttnScratch { k: Vec::new(), v: Vec::new(), w: Vec::new(),
                      qh: Vec::new(), kbuf: Vec::new(),
                      pg: Vec::new() }
    }

    /// Ensure capacity for a block over `p` cache positions of an
    /// `nh`-head, `hd`-wide model (grow-only; buffers may stay larger
    /// than the current block needs).
    pub fn reserve(&mut self, nh: usize, hd: usize, p: usize) {
        grow(&mut self.k, nh * p * hd);
        grow(&mut self.v, nh * p * hd);
        grow(&mut self.w, p);
        grow(&mut self.qh, hd);
        grow(&mut self.kbuf, nh * hd);
    }

    /// Ensure the page-run staging buffer holds `len` f32s (one page
    /// of decoded rows at most).
    pub fn reserve_run(&mut self, len: usize) {
        grow(&mut self.pg, len);
    }
}

thread_local! {
    static SCRATCH: RefCell<AttnScratch> = RefCell::new(AttnScratch::new());
}

/// Run `f` with the calling thread's arena (created on first use). The
/// closure must not re-enter `with_attn` — attention jobs don't nest.
pub fn with_attn<R>(f: impl FnOnce(&mut AttnScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_grows_and_is_reused() {
        let first = with_attn(|s| {
            s.reserve(2, 8, 5);
            assert!(s.k.len() >= 2 * 8 * 5 && s.v.len() >= 2 * 8 * 5);
            assert!(s.w.len() >= 5 && s.qh.len() >= 8);
            s.k.as_ptr() as usize
        });
        // Same thread, smaller request: no shrink, same allocation.
        let second = with_attn(|s| {
            s.reserve(2, 8, 3);
            assert!(s.k.len() >= 2 * 8 * 5, "grow-only");
            s.k.as_ptr() as usize
        });
        assert_eq!(first, second, "arena reused across calls");
    }
}
