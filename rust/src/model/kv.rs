//! Paged quantized KV cache for the host model layer (DESIGN.md
//! §8-§9, paging §13).
//!
//! Each sequence owns one [`SeqKv`]: per layer, one append-only
//! [`QRows`] store for keys and one for values, one row per
//! (position, head) in position-major order. Rows are quantized with the
//! evalq graph's per-token RTN tap — `scale = absmax / levels + 1e-8`,
//! `code = clip(round(v / scale), -levels-1, levels)` — and stored as
//! packed two's-complement codes in the *same field layout as
//! [`QTensor`]* (`qtensor::encode`/`decode`) when the bit-width packs
//! (2..=8 bits), or as the fake-quantized f32 values otherwise
//! (bits >= 9, including the 16-bit "off" passthrough).
//!
//! Storage is paged (DESIGN.md §13): rows live in fixed-size
//! [`PageBuf`] slabs of [`PagePool::page_rows`] rows each — a packed
//! page is `rows * stride` code bytes plus `rows` f32 scales; a
//! passthrough page is `rows * dim` f32s. A [`QRows`] holds a page
//! *table* (`Vec<PageRef>`) instead of contiguous vectors; row `i`
//! lives in page `i / R` at slot `i % R`. Pages are refcounted
//! (`Arc`) and owned by a [`PagePool`] with a free list; every
//! retain/release goes through the pool so its gauges (live pages,
//! outstanding refs, peaks) are exact and a dropped cache provably
//! returns every page. Copy-on-write: writes land in the last
//! (private) page; if the tail page is shared — its refcount is > 1 —
//! the writer first copies it into a fresh page, so bytes of a shared
//! page are never mutated in place.
//!
//! Prefix sharing: the pool keeps a small registry of hashed
//! token-aligned prompt prefixes at page granularity
//! ([`PagePool::register_prefix_boundary`] /
//! [`PagePool::lookup_prefix`]); identical prefixes across sequences
//! adopt the same physical pages ([`SeqKv::adopt_prefix`]), so a
//! system prompt is stored once per pool rather than once per request.
//! Only *full* pages covering at most `prompt_len - 1` tokens are ever
//! shared — the last prompt token is always fed by the adopter (it
//! produces the first logits) and the tail partial page is always
//! private.
//!
//! Parity contract (pinned by `rust/tests/infer_properties.rs` and
//! `rust/tests/model_properties.rs`): `code as f32 * scale` is bitwise
//! the fake-quantized value the dense f32 store would hold, and
//! [`QRows::dot`] / [`QRows::axpy_into`] accumulate in the same element
//! order either way — so attention over a packed KV4 cache is
//! bit-identical to attention over a dense cache holding the
//! fake-quantized rows. Paging adds a second contract: because a page
//! holds a whole number of rows and every per-row kernel reads exactly
//! one page, the paged store is bit-identical to the old contiguous
//! layout for *any* page size, and an adopted prefix is bit-identical
//! to re-prefilling it (prefill is deterministic).
//!
//! [`QTensor`]: crate::tensor::qtensor::QTensor

use std::sync::{Arc, Mutex};

use crate::coordinator::levels_for_bits;
use crate::quant::rtn::rtn_code;
use crate::tensor::lut;
use crate::tensor::qtensor::{codes_per_byte, decode, encode, storage_bits};

/// The eps the evalq fake-quant kernel adds to every row scale
/// (`python/compile/kernels/fake_quant.py`). One constant shared with
/// the activation tap and the integer quantizer — see
/// [`crate::quant::rtn::ACT_EPS`].
pub const KV_EPS: f32 = crate::quant::rtn::ACT_EPS;

/// Default rows per page (one layer-side page = 64 roped (pos, head)
/// rows). Divides every supported head count, so page boundaries are
/// token-aligned and prefix sharing engages out of the box.
pub const DEFAULT_PAGE_ROWS: usize = 64;

/// Max prefix-registry entries per pool (one entry per token-aligned
/// page boundary); oldest entries are evicted FIFO and their page
/// refs returned to the pool.
const PREFIX_CAP: usize = 64;

const FNV_SEED: u64 = 0xcbf2_9ce4_8422_2325;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// One fixed-size slab of quantized rows. Packed pools fill `codes` +
/// `scales`; passthrough pools fill `dense`. Buffers are allocated at
/// full page size up front and zeroed on (re)allocation, so a slot is
/// deterministic before its row is written.
pub struct PageBuf {
    codes: Vec<u8>,
    scales: Vec<f32>,
    dense: Vec<f32>,
}

/// Shared handle to one physical page. The `Arc` strong count *is*
/// the refcount; all clones and drops must go through
/// [`PagePool::retain`] / [`PagePool::release`] so the pool gauges
/// stay exact.
pub type PageRef = Arc<PageBuf>;

struct PrefixEntry {
    hash: u64,
    tokens: Vec<i32>,
    /// The physical pages holding this boundary's page index, ordered
    /// `[layer0.k, layer0.v, layer1.k, layer1.v, ...]`.
    group: Vec<PageRef>,
}

struct PoolInner {
    free: Vec<PageBuf>,
    pages_live: usize,
    refs_live: usize,
    pages_peak: usize,
    shared_peak: usize,
    /// Soft budget in pages (0 = unbounded). Never enforced at alloc
    /// time — admission control in the decode engine consults it, so
    /// `push` stays infallible.
    cap_pages: usize,
    prefix: Vec<PrefixEntry>,
}

fn release_locked(g: &mut PoolInner, page: PageRef) {
    debug_assert!(g.refs_live > 0, "PagePool::release with 0 refs");
    g.refs_live -= 1;
    if let Ok(buf) = Arc::try_unwrap(page) {
        debug_assert!(g.pages_live > 0, "page freed with 0 live");
        g.pages_live -= 1;
        g.free.push(buf);
    }
}

fn note_shared(g: &mut PoolInner) {
    debug_assert!(g.refs_live >= g.pages_live,
                  "every live page holds >= 1 ref");
    g.shared_peak = g.shared_peak.max(g.refs_live - g.pages_live);
}

/// Instantaneous pool gauges plus high-water marks — the `/metrics`
/// and `DecodeStats` KV-memory columns.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolGauges {
    /// Distinct physical pages currently allocated.
    pub pages_live: usize,
    /// Outstanding page references (cache tables + prefix registry).
    pub refs_live: usize,
    /// `refs_live - pages_live`: references saved by sharing, now.
    pub pages_shared: usize,
    /// High-water mark of `pages_live`.
    pub pages_peak: usize,
    /// High-water mark of `pages_shared`.
    pub shared_peak: usize,
    /// `pages_live * page_bytes`.
    pub bytes_live: usize,
    /// `pages_peak * page_bytes`.
    pub bytes_peak: usize,
    /// Recycled pages parked on the free list.
    pub free_pages: usize,
    /// Soft page budget (0 = unbounded).
    pub cap_pages: usize,
}

/// Global page allocator for one KV geometry (`dim`, `bits`): free
/// list, refcount gauges, soft budget, and the prefix-sharing
/// registry. One pool serves every `QRows` of every sequence admitted
/// to a decode engine; standalone `QRows::new` / `SeqKv::new` create
/// a private uncapped pool so library callers and tests see exactly
/// the old contiguous-cache behavior.
pub struct PagePool {
    dim: usize,
    bits: u32,
    sbits: Option<u32>,
    stride: usize,
    page_rows: usize,
    page_bytes: usize,
    inner: Mutex<PoolInner>,
}

impl PagePool {
    /// `cap_pages` is a *soft* budget consulted by admission control
    /// (0 = unbounded); allocation itself never fails.
    pub fn new(dim: usize, bits: u32, page_rows: usize,
               cap_pages: usize) -> Arc<PagePool> {
        assert!(page_rows > 0, "page_rows must be positive");
        assert!(dim > 0, "dim must be positive");
        let sbits = if bits < 16 { storage_bits(bits) } else { None };
        let stride = match sbits {
            Some(_) => dim.div_ceil(codes_per_byte(bits)),
            None => 0,
        };
        let page_bytes = match sbits {
            Some(_) => page_rows * stride + 4 * page_rows,
            None => 4 * page_rows * dim,
        };
        Arc::new(PagePool {
            dim, bits, sbits, stride, page_rows, page_bytes,
            inner: Mutex::new(PoolInner {
                free: Vec::new(), pages_live: 0, refs_live: 0,
                pages_peak: 0, shared_peak: 0, cap_pages,
                prefix: Vec::new() }),
        })
    }

    /// Pool with a soft byte budget: `mb` MiB translated to whole
    /// pages (`mb` 0 = unbounded). The decode engine's constructor —
    /// the `--kv-pool-mb` knob lands here.
    pub fn with_budget_mb(dim: usize, bits: u32, page_rows: usize,
                          mb: usize) -> Arc<PagePool> {
        let pool = PagePool::new(dim, bits, page_rows, 0);
        if mb > 0 {
            let cap = ((mb << 20) / pool.page_bytes).max(1);
            pool.inner.lock().unwrap().cap_pages = cap;
        }
        pool
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Rows per page (the `--kv-page-rows` knob).
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// Physical bytes of one page (codes + scales, or dense f32).
    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    /// Pages needed to hold `rows` rows.
    pub fn pages_for_rows(&self, rows: usize) -> usize {
        rows.div_ceil(self.page_rows)
    }

    /// Tokens covered by one page of an `n_heads`-head cache — `None`
    /// when page boundaries are not token-aligned (sharing disabled).
    pub fn tokens_per_page(&self, n_heads: usize) -> Option<usize> {
        if n_heads > 0 && self.page_rows % n_heads == 0 {
            Some(self.page_rows / n_heads)
        } else {
            None
        }
    }

    /// Longest registerable prefix of a `prompt_len`-token prompt:
    /// whole token-aligned pages covering at most `prompt_len - 1`
    /// tokens (the adopter always feeds the last prompt token itself).
    pub fn shareable_prefix_len(&self, prompt_len: usize,
                                n_heads: usize) -> usize {
        match self.tokens_per_page(n_heads) {
            Some(tpp) if prompt_len > 1 => {
                ((prompt_len - 1) / tpp) * tpp
            }
            _ => 0,
        }
    }

    /// Allocate one zeroed page (recycled from the free list when
    /// possible). Infallible by design: the soft cap is enforced by
    /// admission control, not here.
    pub fn alloc(&self) -> PageRef {
        let mut g = self.inner.lock().unwrap();
        let buf = match g.free.pop() {
            Some(mut b) => {
                b.codes.fill(0);
                b.scales.fill(0.0);
                b.dense.fill(0.0);
                b
            }
            None => PageBuf {
                codes: vec![0u8; self.page_rows * self.stride],
                scales: vec![0.0f32; if self.sbits.is_some() {
                    self.page_rows
                } else {
                    0
                }],
                dense: vec![0.0f32; if self.sbits.is_some() {
                    0
                } else {
                    self.page_rows * self.dim
                }],
            },
        };
        g.pages_live += 1;
        g.refs_live += 1;
        g.pages_peak = g.pages_peak.max(g.pages_live);
        note_shared(&mut g);
        Arc::new(buf)
    }

    /// Add one reference to a live page (copy-on-write sharing).
    pub fn retain(&self, page: &PageRef) -> PageRef {
        let mut g = self.inner.lock().unwrap();
        g.refs_live += 1;
        note_shared(&mut g);
        Arc::clone(page)
    }

    /// Drop one reference; the last release recycles the page onto
    /// the free list.
    pub fn release(&self, page: PageRef) {
        let mut g = self.inner.lock().unwrap();
        release_locked(&mut g, page);
    }

    pub fn gauges(&self) -> PoolGauges {
        let g = self.inner.lock().unwrap();
        PoolGauges {
            pages_live: g.pages_live,
            refs_live: g.refs_live,
            pages_shared: g.refs_live - g.pages_live,
            pages_peak: g.pages_peak,
            shared_peak: g.shared_peak,
            bytes_live: g.pages_live * self.page_bytes,
            bytes_peak: g.pages_peak * self.page_bytes,
            free_pages: g.free.len(),
            cap_pages: g.cap_pages,
        }
    }

    /// Register the physical pages backing one token-aligned prefix
    /// boundary. `group` must hold refs already retained through this
    /// pool (ownership transfers here); if the boundary is already
    /// registered the refs are released back.
    pub fn register_prefix_boundary(&self, tokens: &[i32],
                                    group: Vec<PageRef>) {
        let mut hash = FNV_SEED;
        for &t in tokens {
            hash = fnv1a(hash, &t.to_le_bytes());
        }
        let mut g = self.inner.lock().unwrap();
        if g.prefix.iter().any(|e| e.hash == hash
                               && e.tokens[..] == tokens[..]) {
            for p in group {
                release_locked(&mut g, p);
            }
            return;
        }
        g.prefix.push(PrefixEntry { hash, tokens: tokens.to_vec(),
                                    group });
        while g.prefix.len() > PREFIX_CAP {
            let e = g.prefix.remove(0);
            for p in e.group {
                release_locked(&mut g, p);
            }
        }
    }

    /// Longest registered prefix of `prompt` at page granularity:
    /// returns `(tokens_covered, page groups)` with one retained ref
    /// per page for the caller (feed to [`SeqKv::adopt_prefix`]).
    /// Hash-chained per boundary and verified against the stored
    /// tokens, so collisions cannot alias prefixes. Never covers the
    /// whole prompt — the adopter must feed >= 1 token for logits.
    pub fn lookup_prefix(&self, prompt: &[i32], n_heads: usize)
                         -> Option<(usize, Vec<Vec<PageRef>>)> {
        let tpp = self.tokens_per_page(n_heads)?;
        let mut g = self.inner.lock().unwrap();
        let mut hash = FNV_SEED;
        let mut groups: Vec<Vec<PageRef>> = Vec::new();
        let mut covered = 0usize;
        while covered + tpp < prompt.len() {
            for &t in &prompt[covered..covered + tpp] {
                hash = fnv1a(hash, &t.to_le_bytes());
            }
            let want = &prompt[..covered + tpp];
            let Some(pi) = g.prefix.iter().position(
                |e| e.hash == hash && e.tokens[..] == want[..])
            else {
                break;
            };
            let group: Vec<PageRef> =
                g.prefix[pi].group.iter().map(Arc::clone).collect();
            g.refs_live += group.len();
            note_shared(&mut g);
            groups.push(group);
            covered += tpp;
        }
        if groups.is_empty() {
            None
        } else {
            Some((covered, groups))
        }
    }

    /// Number of prefix boundaries currently registered.
    pub fn n_prefixes(&self) -> usize {
        self.inner.lock().unwrap().prefix.len()
    }

    /// Drop the prefix registry, returning its page refs to the pool
    /// (engine teardown, or to reclaim budget when admission stalls).
    pub fn clear_prefixes(&self) {
        let mut g = self.inner.lock().unwrap();
        let entries = std::mem::take(&mut g.prefix);
        for e in entries {
            for p in e.group {
                release_locked(&mut g, p);
            }
        }
    }
}

/// Append-only store of quantized `dim`-sized rows, backed by a page
/// table over a [`PagePool`].
pub struct QRows {
    bits: u32,
    dim: usize,
    levels: f32,
    /// Some(storage field width) when rows pack; None = f32 passthrough.
    sbits: Option<u32>,
    /// Bytes per packed row.
    stride: usize,
    /// Rows per page (cached from the pool for the hot paths).
    prows: usize,
    pool: Arc<PagePool>,
    pages: Vec<PageRef>,
    n_rows: usize,
}

impl QRows {
    /// Standalone store with a private uncapped pool at the default
    /// page size — behaviorally identical to the old contiguous store.
    pub fn new(dim: usize, bits: u32) -> QRows {
        QRows::with_pool(PagePool::new(dim, bits, DEFAULT_PAGE_ROWS, 0))
    }

    /// Store whose pages come from (and return to) `pool`.
    pub fn with_pool(pool: Arc<PagePool>) -> QRows {
        QRows { bits: pool.bits, dim: pool.dim,
                levels: levels_for_bits(pool.bits), sbits: pool.sbits,
                stride: pool.stride, prows: pool.page_rows,
                pool, pages: Vec::new(), n_rows: 0 }
    }

    pub fn len(&self) -> usize {
        self.n_rows
    }

    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Row width (one K/V head row).
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn is_packed(&self) -> bool {
        self.sbits.is_some()
    }

    /// Rows per page of the backing pool (page-run walks in the
    /// attention kernel).
    pub fn page_rows(&self) -> usize {
        self.prows
    }

    /// Pages currently referenced by this store's table.
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Physical bytes this store's page table references (whole
    /// pages; shared pages count once per referencing table) — the
    /// serve-bench KV-memory column. Pool gauges carry the
    /// deduplicated physical truth.
    pub fn bytes(&self) -> usize {
        self.pages.len() * self.pool.page_bytes
    }

    /// One retained ref to page `p` of this store's table (prefix
    /// registration).
    pub fn page_ref(&self, p: usize) -> PageRef {
        self.pool.retain(&self.pages[p])
    }

    /// Append one already-populated *full* page to the table (prefix
    /// adoption). Ownership of the (retained) ref transfers here.
    pub fn adopt_page(&mut self, page: PageRef) {
        debug_assert_eq!(self.n_rows % self.prows, 0,
                         "adopt_page after a partial page");
        self.pages.push(page);
        self.n_rows += self.prows;
    }

    /// Tail page ready for writing slot `n_rows % prows`. Allocates on
    /// a page boundary; copies-on-write when the tail page is shared,
    /// so shared page bytes are never mutated in place.
    fn tail_for_write(&mut self) -> (&mut PageBuf, usize) {
        let slot = self.n_rows % self.prows;
        if slot == 0 {
            let p = self.pool.alloc();
            self.pages.push(p);
        }
        let idx = self.pages.len() - 1;
        if Arc::get_mut(&mut self.pages[idx]).is_none() {
            let mut fresh = self.pool.alloc();
            {
                let dst = Arc::get_mut(&mut fresh)
                    .expect("fresh page is private");
                let src = &self.pages[idx];
                dst.codes.copy_from_slice(&src.codes);
                dst.scales.copy_from_slice(&src.scales);
                dst.dense.copy_from_slice(&src.dense);
            }
            let old = std::mem::replace(&mut self.pages[idx], fresh);
            self.pool.release(old);
        }
        let page = Arc::get_mut(&mut self.pages[idx])
            .expect("tail page is private after CoW");
        (page, slot)
    }

    /// Quantize-and-append one row (the per-(position, head) KV tap).
    /// Codes come from the one shared [`rtn_code`] snap helper, so the
    /// packed/dense parity contract has a single source of truth.
    pub fn push(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.dim);
        let scale = crate::quant::rtn::act_scale(row, self.levels);
        let lv = self.levels;
        let (dim, stride, sbits) = (self.dim, self.stride, self.sbits);
        let (page, slot) = self.tail_for_write();
        match sbits {
            Some(sbits) => {
                let out =
                    &mut page.codes[slot * stride..(slot + 1) * stride];
                for (j, &v) in row.iter().enumerate() {
                    encode(out, sbits, j, rtn_code(v, scale, lv));
                }
                page.scales[slot] = scale;
            }
            None => {
                let out =
                    &mut page.dense[slot * dim..(slot + 1) * dim];
                for (o, &v) in out.iter_mut().zip(row) {
                    *o = rtn_code(v, scale, lv) as f32 * scale;
                }
            }
        }
        self.n_rows += 1;
    }

    /// Quantize-and-append a contiguous group of rows (`data.len()` a
    /// multiple of `dim`) — the block-forward path appends one token's
    /// head rows (or a whole chunk) in one call. Each row is quantized
    /// independently with its own scale, exactly like repeated
    /// [`QRows::push`] calls.
    pub fn append_block(&mut self, data: &[f32]) {
        debug_assert_eq!(data.len() % self.dim, 0,
                         "append_block wants whole rows");
        for row in data.chunks_exact(self.dim) {
            self.push(row);
        }
    }

    /// Dequantize rows `[i0, i1)` into `out` (`[i1 - i0, dim]`
    /// row-major) through the byte LUTs — the block-dequant attention
    /// kernel's cache read: each packed KV row decodes exactly once per
    /// query block into a scratch tile, instead of once per query
    /// token. Walks the page table one page run at a time; because
    /// every row lives entirely in one page, `out[r][j]` is bitwise
    /// `self.at(i0 + r, j)` for any page size, so dense tile ops over
    /// the output are bit-identical to the element-wise [`QRows::dot`]
    /// / [`QRows::axpy_into`] reference kernels.
    pub fn dequant_block_into(&self, i0: usize, i1: usize,
                              out: &mut [f32]) {
        debug_assert!(i0 <= i1 && i1 <= self.n_rows,
                      "dequant_block_into rows {i0}..{i1} of a {}-row \
                       cache", self.n_rows);
        debug_assert_eq!(out.len(), (i1 - i0) * self.dim,
                         "dequant_block_into wants {} f32s", (i1 - i0)
                         * self.dim);
        let dim = self.dim;
        let mut i = i0;
        while i < i1 {
            let p = i / self.prows;
            let end = ((p + 1) * self.prows).min(i1);
            let page = &self.pages[p];
            let o0 = (i - i0) * dim;
            match self.sbits {
                Some(sbits) => {
                    let orun = &mut out[o0..o0 + (end - i) * dim];
                    for (r, orow) in (i..end)
                        .zip(orun.chunks_exact_mut(dim))
                    {
                        let slot = r % self.prows;
                        let row = &page.codes
                            [slot * self.stride..(slot + 1) * self.stride];
                        lut::dequant_uniform(row, sbits,
                                             page.scales[slot], 0, dim,
                                             orow);
                    }
                }
                None => {
                    let s0 = (i % self.prows) * dim;
                    out[o0..o0 + (end - i) * dim].copy_from_slice(
                        &page.dense[s0..s0 + (end - i) * dim]);
                }
            }
            i = end;
        }
    }

    /// Dequantized element `j` of row `i` (test/diagnostic helper).
    pub fn at(&self, i: usize, j: usize) -> f32 {
        let page = &self.pages[i / self.prows];
        let slot = i % self.prows;
        match self.sbits {
            Some(sbits) => {
                let row = &page.codes
                    [slot * self.stride..(slot + 1) * self.stride];
                decode(row, sbits, j) as f32 * page.scales[slot]
            }
            None => page.dense[slot * self.dim + j],
        }
    }

    /// deq(row i) · x, accumulated in ascending element order — the
    /// element-wise attention-logit reference kernel (the hot path now
    /// reads [`QRows::dequant_block_into`] tiles). Bit-identical
    /// between packed and dense storage of the same fake-quantized row.
    pub fn dot(&self, i: usize, x: &[f32]) -> f32 {
        debug_assert!(i < self.n_rows, "QRows::dot row {i} of a {}-row \
                                        cache", self.n_rows);
        debug_assert_eq!(x.len(), self.dim);
        let page = &self.pages[i / self.prows];
        let slot = i % self.prows;
        match self.sbits {
            Some(sbits) => {
                let row = &page.codes
                    [slot * self.stride..(slot + 1) * self.stride];
                let s = page.scales[slot];
                let mut acc = 0.0f32;
                for (j, &xv) in x.iter().enumerate() {
                    acc += decode(row, sbits, j) as f32 * s * xv;
                }
                acc
            }
            None => {
                let row = &page.dense
                    [slot * self.dim..(slot + 1) * self.dim];
                let mut acc = 0.0f32;
                for (kv, &xv) in row.iter().zip(x) {
                    acc += kv * xv;
                }
                acc
            }
        }
    }

    /// out += w * deq(row i) — the element-wise attention value-mix
    /// reference kernel, same element order and parity as
    /// [`QRows::dot`].
    pub fn axpy_into(&self, i: usize, w: f32, out: &mut [f32]) {
        debug_assert!(i < self.n_rows, "QRows::axpy_into row {i} of a \
                                        {}-row cache", self.n_rows);
        debug_assert_eq!(out.len(), self.dim);
        let page = &self.pages[i / self.prows];
        let slot = i % self.prows;
        match self.sbits {
            Some(sbits) => {
                let row = &page.codes
                    [slot * self.stride..(slot + 1) * self.stride];
                let s = page.scales[slot];
                for (j, o) in out.iter_mut().enumerate() {
                    *o += w * (decode(row, sbits, j) as f32 * s);
                }
            }
            None => {
                let row = &page.dense
                    [slot * self.dim..(slot + 1) * self.dim];
                for (o, &v) in out.iter_mut().zip(row) {
                    *o += w * v;
                }
            }
        }
    }
}

impl Drop for QRows {
    /// Return every page ref to the pool — the one teardown path for
    /// finished, cancelled, and deadline-evicted sequences alike, so
    /// pool balance (`refs_live`, `pages_live`) is provable from any
    /// drop site.
    fn drop(&mut self) {
        for p in self.pages.drain(..) {
            self.pool.release(p);
        }
    }
}

/// One layer's key and value stores.
pub struct LayerKv {
    pub k: QRows,
    pub v: QRows,
}

/// Per-sequence KV cache: `n_layers` layer stores of (position, head)
/// rows, position-major (`row = pos * n_heads + head`), all paged out
/// of one shared [`PagePool`].
pub struct SeqKv {
    layers: Vec<LayerKv>,
    pool: Arc<PagePool>,
    n_heads: usize,
    n_tokens: usize,
}

impl SeqKv {
    /// Cache with a private uncapped pool at the default page size —
    /// behaviorally identical to the old contiguous cache.
    pub fn new(n_layers: usize, n_heads: usize, head_dim: usize,
               kv_bits: u32) -> SeqKv {
        let pool = PagePool::new(head_dim, kv_bits, DEFAULT_PAGE_ROWS,
                                 0);
        SeqKv::new_in(n_layers, n_heads, pool)
    }

    /// Cache whose pages come from (and return to) `pool` — the
    /// decode engine's path, one pool across all admitted sequences.
    pub fn new_in(n_layers: usize, n_heads: usize,
                  pool: Arc<PagePool>) -> SeqKv {
        let layers = (0..n_layers)
            .map(|_| LayerKv {
                k: QRows::with_pool(Arc::clone(&pool)),
                v: QRows::with_pool(Arc::clone(&pool)),
            })
            .collect();
        SeqKv { layers, pool, n_heads, n_tokens: 0 }
    }

    pub fn pool(&self) -> &Arc<PagePool> {
        &self.pool
    }

    /// Positions cached so far (the next token decodes at this position).
    pub fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layer(&self, l: usize) -> &LayerKv {
        &self.layers[l]
    }

    pub fn layer_mut(&mut self, l: usize) -> &mut LayerKv {
        &mut self.layers[l]
    }

    /// Called once per decoded token, after every layer pushed its
    /// `n_heads` K and V rows.
    pub fn advance(&mut self) {
        self.advance_by(1);
    }

    /// Advance the position counter past a whole block of `n` tokens
    /// (every layer must already hold their K/V rows). The block-forward
    /// path calls this once per chunk instead of once per token.
    pub fn advance_by(&mut self, n: usize) {
        self.n_tokens += n;
        for lay in &self.layers {
            debug_assert_eq!(lay.k.len(), self.n_tokens * self.n_heads);
            debug_assert_eq!(lay.v.len(), self.n_tokens * self.n_heads);
        }
    }

    /// Total cache bytes across layers (K + V), counting whole pages;
    /// adopted shared pages count once per referencing cache (the
    /// pool's gauges carry the deduplicated physical bytes).
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.bytes() + l.v.bytes()).sum()
    }

    /// Map an already-registered prefix's physical pages into this
    /// *fresh* cache: `groups[p]` holds page index `p`'s pages in
    /// `[l0.k, l0.v, l1.k, l1.v, ...]` order with refs retained by
    /// [`PagePool::lookup_prefix`]; ownership transfers here. After
    /// adoption the cache reads exactly as if it had prefilled
    /// `n_tokens` tokens itself (prefill is deterministic), and its
    /// next write opens a fresh private page.
    pub fn adopt_prefix(&mut self, n_tokens: usize,
                        groups: Vec<Vec<PageRef>>) {
        debug_assert_eq!(self.n_tokens, 0,
                         "adopt_prefix into a used cache");
        for group in groups {
            debug_assert_eq!(group.len(), 2 * self.layers.len(),
                             "page group is one K + one V per layer");
            let mut it = group.into_iter();
            for lay in &mut self.layers {
                lay.k.adopt_page(it.next().unwrap());
                lay.v.adopt_page(it.next().unwrap());
            }
        }
        self.n_tokens = n_tokens;
        for lay in &self.layers {
            debug_assert_eq!(lay.k.len(), self.n_tokens * self.n_heads,
                             "adopted prefix is token-aligned");
        }
    }

    /// Register this cache's full token-aligned prefix pages with the
    /// pool so later identical prompts can adopt them. `prefix` must
    /// be a whole number of pages this cache has already prefilled
    /// (see [`PagePool::shareable_prefix_len`]).
    pub fn register_prefix(&self, prefix: &[i32]) {
        let Some(tpp) = self.pool.tokens_per_page(self.n_heads) else {
            return;
        };
        debug_assert_eq!(prefix.len() % tpp, 0,
                         "register_prefix wants whole pages");
        debug_assert!(prefix.len() <= self.n_tokens,
                      "register_prefix beyond the cached tokens");
        let n_pages = prefix.len() / tpp;
        for p in 0..n_pages {
            let mut group = Vec::with_capacity(2 * self.layers.len());
            for lay in &self.layers {
                group.push(lay.k.page_ref(p));
                group.push(lay.v.page_ref(p));
            }
            self.pool.register_prefix_boundary(
                &prefix[..(p + 1) * tpp], group);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn fake_quant_ref(row: &[f32], bits: u32) -> Vec<f32> {
        let lv = levels_for_bits(bits);
        let absmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = absmax / lv + KV_EPS;
        row.iter()
            .map(|&v| (v / scale).round().clamp(-lv - 1.0, lv) * scale)
            .collect()
    }

    #[test]
    fn packed_rows_hold_fake_quant_values_bitwise() {
        let mut rng = Pcg::new(1, 0);
        for bits in [2u32, 4, 8] {
            let mut rows = QRows::new(16, bits);
            assert!(rows.is_packed());
            for r in 0..5 {
                let row: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
                rows.push(&row);
                let want = fake_quant_ref(&row, bits);
                for (j, w) in want.iter().enumerate() {
                    assert_eq!(rows.at(r, j), *w, "{bits}b r{r} j{j}");
                }
            }
        }
    }

    #[test]
    fn passthrough_rows_apply_the_off_tap() {
        let mut rows = QRows::new(8, 16);
        assert!(!rows.is_packed());
        let row: Vec<f32> = (0..8).map(|i| i as f32 * 0.3 - 1.0).collect();
        rows.push(&row);
        let want = fake_quant_ref(&row, 16);
        for (j, w) in want.iter().enumerate() {
            assert_eq!(rows.at(0, j), *w, "j{j}");
        }
    }

    #[test]
    fn append_block_equals_repeated_push() {
        let mut rng = Pcg::new(9, 0);
        let dim = 10;
        for bits in [4u32, 16] {
            let flat: Vec<f32> = (0..3 * dim).map(|_| rng.normal()).collect();
            let mut blk = QRows::new(dim, bits);
            blk.append_block(&flat);
            let mut one = QRows::new(dim, bits);
            for row in flat.chunks_exact(dim) {
                one.push(row);
            }
            assert_eq!(blk.len(), 3);
            for i in 0..3 {
                for j in 0..dim {
                    assert_eq!(blk.at(i, j), one.at(i, j),
                               "{bits}b r{i} j{j}");
                }
            }
        }
    }

    #[test]
    fn dot_and_axpy_match_dense_reference_bitwise() {
        let mut rng = Pcg::new(2, 0);
        let dim = 12;
        let mut packed = QRows::new(dim, 4);
        let mut dense: Vec<Vec<f32>> = Vec::new();
        for _ in 0..7 {
            let row: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
            packed.push(&row);
            dense.push(fake_quant_ref(&row, 4));
        }
        let x: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        for (i, drow) in dense.iter().enumerate() {
            let mut want = 0.0f32;
            for (kv, &xv) in drow.iter().zip(&x) {
                want += kv * xv;
            }
            assert_eq!(packed.dot(i, &x), want, "dot row {i}");
            let mut a = vec![0.5f32; dim];
            let mut b = a.clone();
            packed.axpy_into(i, 0.25, &mut a);
            for (o, &v) in b.iter_mut().zip(drow) {
                *o += 0.25 * v;
            }
            assert_eq!(a, b, "axpy row {i}");
        }
    }

    #[test]
    fn dequant_block_matches_element_accessor() {
        // Packed widths (2..8, including the 3/5-bit field-sharing
        // cases) and the f32 passthrough, over interior [i0, i1) spans
        // — with a 3-row page size so every span crosses a page
        // boundary, plus the default page size.
        let mut rng = Pcg::new(21, 0);
        let dim = 9;
        for bits in [2u32, 3, 4, 5, 8, 16] {
            for prows in [3usize, DEFAULT_PAGE_ROWS] {
                let pool = PagePool::new(dim, bits, prows, 0);
                let mut rows = QRows::with_pool(pool);
                for _ in 0..7 {
                    let row: Vec<f32> =
                        (0..dim).map(|_| rng.normal()).collect();
                    rows.push(&row);
                }
                for (i0, i1) in [(0usize, 7usize), (2, 5), (3, 3),
                                 (6, 7)] {
                    let mut out = vec![0.0f32; (i1 - i0) * dim];
                    rows.dequant_block_into(i0, i1, &mut out);
                    for (r, i) in (i0..i1).enumerate() {
                        for j in 0..dim {
                            assert_eq!(out[r * dim + j], rows.at(i, j),
                                       "{bits}b/{prows}r [{i0},{i1}) \
                                        row {i} j{j}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "row")]
    #[cfg(debug_assertions)]
    fn dot_out_of_range_fails_loudly() {
        let mut rows = QRows::new(4, 4);
        rows.push(&[1.0, 2.0, 3.0, 4.0]);
        rows.dot(3, &[0.0; 4]);
    }

    #[test]
    #[should_panic(expected = "rows")]
    #[cfg(debug_assertions)]
    fn dequant_block_out_of_range_fails_loudly() {
        let mut rows = QRows::new(4, 4);
        rows.push(&[1.0, 2.0, 3.0, 4.0]);
        let mut out = vec![0.0f32; 8];
        rows.dequant_block_into(0, 2, &mut out);
    }

    #[test]
    fn kv4_cache_is_much_smaller_than_f32() {
        let mut q4 = QRows::new(64, 4);
        let mut q16 = QRows::new(64, 16);
        let row = vec![0.5f32; 64];
        for _ in 0..32 {
            q4.push(&row);
            q16.push(&row);
        }
        // One 4-bit page: 64*32 code bytes + 64 scales vs one f32
        // page: 64*64 f32s.
        assert!(q4.bytes() * 4 < q16.bytes(),
                "{} vs {}", q4.bytes(), q16.bytes());
    }

    #[test]
    fn seq_kv_row_accounting() {
        let mut kv = SeqKv::new(2, 4, 8, 4);
        assert_eq!(kv.n_tokens(), 0);
        let row = vec![0.1f32; 8];
        for l in 0..2 {
            for _h in 0..4 {
                kv.layer_mut(l).k.push(&row);
                kv.layer_mut(l).v.push(&row);
            }
        }
        kv.advance();
        assert_eq!(kv.n_tokens(), 1);
        assert!(kv.bytes() > 0);
        // A 3-token block advances in one call.
        let block = vec![0.2f32; 3 * 4 * 8];
        for l in 0..2 {
            kv.layer_mut(l).k.append_block(&block);
            kv.layer_mut(l).v.append_block(&block);
        }
        kv.advance_by(3);
        assert_eq!(kv.n_tokens(), 4);
    }

    #[test]
    fn page_size_does_not_change_stored_values() {
        // The paged store is bit-identical across page sizes — the
        // "paged == contiguous" contract, with page_rows = 1 as the
        // degenerate one-row-per-page case and a page larger than the
        // store as the old contiguous layout.
        let mut rng = Pcg::new(31, 0);
        let dim = 11;
        let n = 13;
        let data: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect();
        for bits in [4u32, 16] {
            let stores: Vec<QRows> = [1usize, 4, 64, 1024]
                .iter()
                .map(|&pr| {
                    let mut q = QRows::with_pool(
                        PagePool::new(dim, bits, pr, 0));
                    for row in &data {
                        q.push(row);
                    }
                    q
                })
                .collect();
            for i in 0..n {
                for j in 0..dim {
                    let want = stores[0].at(i, j);
                    for s in &stores[1..] {
                        assert_eq!(s.at(i, j), want,
                                   "{bits}b row {i} j {j}");
                    }
                }
            }
        }
    }

    #[test]
    fn pool_recycles_pages_and_tracks_gauges() {
        let pool = PagePool::new(8, 4, 4, 0);
        let mut q = QRows::with_pool(Arc::clone(&pool));
        let row = vec![1.0f32; 8];
        for _ in 0..9 {
            q.push(&row); // 9 rows -> 3 pages
        }
        let g = pool.gauges();
        assert_eq!(g.pages_live, 3);
        assert_eq!(g.refs_live, 3);
        assert_eq!(g.pages_shared, 0);
        assert_eq!(q.n_pages(), 3);
        drop(q);
        let g = pool.gauges();
        assert_eq!(g.pages_live, 0);
        assert_eq!(g.refs_live, 0);
        assert_eq!(g.free_pages, 3);
        assert_eq!(g.pages_peak, 3);
        // A new store reuses the freed pages: no new allocations.
        let mut q2 = QRows::with_pool(Arc::clone(&pool));
        for _ in 0..8 {
            q2.push(&row);
        }
        let g = pool.gauges();
        assert_eq!(g.pages_live, 2);
        assert_eq!(g.free_pages, 1);
        assert_eq!(g.pages_peak, 3, "peak is a high-water mark");
    }

    #[test]
    fn cow_never_mutates_a_shared_page() {
        // Two stores share a full page; the second keeps appending.
        // Its writes must land in private pages and the shared page's
        // decoded values must stay bitwise intact.
        let pool = PagePool::new(6, 4, 4, 0);
        let mut rng = Pcg::new(77, 0);
        let mut a = QRows::with_pool(Arc::clone(&pool));
        for _ in 0..4 {
            let row: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            a.push(&row);
        }
        let before: Vec<f32> =
            (0..4).flat_map(|i| (0..6).map(move |j| (i, j)))
                  .map(|(i, j)| a.at(i, j))
                  .collect();
        let mut b = QRows::with_pool(Arc::clone(&pool));
        b.adopt_page(a.page_ref(0));
        assert_eq!(pool.gauges().pages_shared, 1);
        // Appends by the adopter open fresh pages past the shared one.
        for _ in 0..5 {
            let row: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            b.push(&row);
        }
        for i in 0..4 {
            for j in 0..6 {
                assert_eq!(a.at(i, j), before[i * 6 + j],
                           "shared page mutated at {i},{j}");
                assert_eq!(b.at(i, j), before[i * 6 + j],
                           "adopted view diverged at {i},{j}");
            }
        }
        drop(b);
        drop(a);
        let g = pool.gauges();
        assert_eq!((g.refs_live, g.pages_live), (0, 0), "leak");
    }

    #[test]
    fn cow_copies_a_shared_partial_tail() {
        // A *partial* shared tail page (possible through the raw page
        // API, not the engine path) is copied before the write: the
        // holder of the original ref sees unchanged bytes.
        let pool = PagePool::new(5, 4, 4, 0);
        let mut a = QRows::with_pool(Arc::clone(&pool));
        a.push(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        a.push(&[5.0, 4.0, 3.0, 2.0, 1.0]);
        let held = a.page_ref(0); // tail page now shared
        let a01 = [a.at(0, 0), a.at(1, 4)];
        a.push(&[9.0, 9.0, 9.0, 9.0, 9.0]); // triggers CoW
        assert_eq!([a.at(0, 0), a.at(1, 4)], a01,
                   "copied rows survive the CoW");
        assert!(a.at(2, 0) != 0.0, "new row landed");
        let g = pool.gauges();
        assert_eq!(g.pages_live, 2, "original + private copy");
        pool.release(held);
        drop(a);
        let g = pool.gauges();
        assert_eq!((g.refs_live, g.pages_live), (0, 0), "leak");
    }

    #[test]
    fn prefix_registry_round_trips_and_releases() {
        // Register a 2-layer cache's first page boundary, adopt it
        // into a fresh cache, decode both bitwise-equal, then clear
        // and verify the pool balances to zero.
        let (nl, nh, hd) = (2usize, 2usize, 4usize);
        let pool = PagePool::new(hd, 4, 4, 0); // tpp = 2 tokens
        let mut rng = Pcg::new(5, 0);
        let mut src = SeqKv::new_in(nl, nh, Arc::clone(&pool));
        let prompt: Vec<i32> = (0..5).map(|t| t as i32).collect();
        for _pos in 0..4 {
            for l in 0..nl {
                for _h in 0..nh {
                    let row: Vec<f32> =
                        (0..hd).map(|_| rng.normal()).collect();
                    src.layer_mut(l).k.push(&row);
                    let row: Vec<f32> =
                        (0..hd).map(|_| rng.normal()).collect();
                    src.layer_mut(l).v.push(&row);
                }
            }
            src.advance();
        }
        let share = pool.shareable_prefix_len(prompt.len(), nh);
        assert_eq!(share, 4, "5-token prompt shares 2 full 2-token \
                              pages");
        src.register_prefix(&prompt[..share]);
        assert_eq!(pool.n_prefixes(), 2, "one entry per boundary");
        // Unknown prompt: no match. Matching prompt: both boundaries.
        assert!(pool.lookup_prefix(&[9, 9, 9, 9, 9], nh).is_none());
        let (tok, groups) = pool.lookup_prefix(&prompt, nh).unwrap();
        assert_eq!((tok, groups.len()), (4, 2));
        let mut dst = SeqKv::new_in(nl, nh, Arc::clone(&pool));
        dst.adopt_prefix(tok, groups);
        assert_eq!(dst.n_tokens(), 4);
        for l in 0..nl {
            for i in 0..4 * nh {
                for j in 0..hd {
                    assert_eq!(dst.layer(l).k.at(i, j),
                               src.layer(l).k.at(i, j),
                               "L{l} K[{i}][{j}]");
                    assert_eq!(dst.layer(l).v.at(i, j),
                               src.layer(l).v.at(i, j),
                               "L{l} V[{i}][{j}]");
                }
            }
        }
        // A shorter prompt only matches the first boundary (the last
        // token is never covered by a shared page).
        let (tok, groups) = pool.lookup_prefix(&prompt[..3], nh)
            .unwrap();
        assert_eq!((tok, groups.len()), (2, 1));
        for grp in groups {
            for p in grp {
                pool.release(p);
            }
        }
        assert!(pool.gauges().pages_shared > 0);
        drop(dst);
        drop(src);
        pool.clear_prefixes();
        let g = pool.gauges();
        assert_eq!((g.refs_live, g.pages_live), (0, 0), "leak");
        assert_eq!(pool.n_prefixes(), 0);
    }
}
