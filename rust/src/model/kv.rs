//! Quantized KV cache for the host model layer (DESIGN.md §8-§9).
//!
//! Each sequence owns one [`SeqKv`]: per layer, one append-only
//! [`QRows`] store for keys and one for values, one row per
//! (position, head) in position-major order. Rows are quantized with the
//! evalq graph's per-token RTN tap — `scale = absmax / levels + 1e-8`,
//! `code = clip(round(v / scale), -levels-1, levels)` — and stored as
//! packed two's-complement codes in the *same field layout as
//! [`QTensor`]* (`qtensor::encode`/`decode`) when the bit-width packs
//! (2..=8 bits), or as the fake-quantized f32 values otherwise
//! (bits >= 9, including the 16-bit "off" passthrough).
//!
//! The multi-token block forward ([`super::InferModel::forward_block`])
//! appends whole groups of rows at once ([`QRows::append_block`]) and
//! advances the position counter by the block length
//! ([`SeqKv::advance_by`]); single-token decode is the block-size-1
//! special case. On the read side, block-dequant attention
//! ([`QRows::dequant_block_into`], DESIGN.md §10) decodes every cached
//! row exactly once per query block into a per-worker scratch tile via
//! the byte LUTs; [`QRows::dot`] / [`QRows::axpy_into`] remain as the
//! element-wise reference kernels the tiles are pinned against.
//!
//! Parity contract (pinned by `rust/tests/infer_properties.rs` and
//! `rust/tests/model_properties.rs`): `code as f32 * scale` is bitwise
//! the fake-quantized value the dense f32 store would hold, and
//! [`QRows::dot`] / [`QRows::axpy_into`] accumulate in the same element
//! order either way — so attention over a packed KV4 cache is
//! bit-identical to attention over a dense cache holding the
//! fake-quantized rows.
//!
//! [`QTensor`]: crate::tensor::qtensor::QTensor

use crate::coordinator::levels_for_bits;
use crate::quant::rtn::rtn_code;
use crate::tensor::lut;
use crate::tensor::qtensor::{codes_per_byte, decode, encode, storage_bits};

/// The eps the evalq fake-quant kernel adds to every row scale
/// (`python/compile/kernels/fake_quant.py`). One constant shared with
/// the activation tap and the integer quantizer — see
/// [`crate::quant::rtn::ACT_EPS`].
pub const KV_EPS: f32 = crate::quant::rtn::ACT_EPS;

/// Append-only store of quantized `dim`-sized rows.
pub struct QRows {
    bits: u32,
    dim: usize,
    levels: f32,
    /// Some(storage field width) when rows pack; None = f32 passthrough.
    sbits: Option<u32>,
    /// Bytes per packed row.
    stride: usize,
    codes: Vec<u8>,
    scales: Vec<f32>,
    dense: Vec<f32>,
    n_rows: usize,
}

impl QRows {
    pub fn new(dim: usize, bits: u32) -> QRows {
        let sbits = if bits < 16 { storage_bits(bits) } else { None };
        let stride = match sbits {
            Some(_) => dim.div_ceil(codes_per_byte(bits)),
            None => 0,
        };
        QRows { bits, dim, levels: levels_for_bits(bits), sbits, stride,
                codes: Vec::new(), scales: Vec::new(), dense: Vec::new(),
                n_rows: 0 }
    }

    pub fn len(&self) -> usize {
        self.n_rows
    }

    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Row width (one K/V head row).
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn is_packed(&self) -> bool {
        self.sbits.is_some()
    }

    /// Bytes this store currently occupies (codes + scales, or dense
    /// f32) — the serve-bench KV-memory column.
    pub fn bytes(&self) -> usize {
        self.codes.len() + 4 * self.scales.len() + 4 * self.dense.len()
    }

    /// Quantize-and-append one row (the per-(position, head) KV tap).
    /// Codes come from the one shared [`rtn_code`] snap helper, so the
    /// packed/dense parity contract has a single source of truth.
    pub fn push(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.dim);
        let scale = crate::quant::rtn::act_scale(row, self.levels);
        let lv = self.levels;
        match self.sbits {
            Some(sbits) => {
                let base = self.codes.len();
                self.codes.resize(base + self.stride, 0);
                let out = &mut self.codes[base..];
                for (j, &v) in row.iter().enumerate() {
                    encode(out, sbits, j, rtn_code(v, scale, lv));
                }
                self.scales.push(scale);
            }
            None => {
                for &v in row {
                    self.dense.push(rtn_code(v, scale, lv) as f32 * scale);
                }
            }
        }
        self.n_rows += 1;
    }

    /// Quantize-and-append a contiguous group of rows (`data.len()` a
    /// multiple of `dim`) — the block-forward path appends one token's
    /// head rows (or a whole chunk) in one call. Each row is quantized
    /// independently with its own scale, exactly like repeated
    /// [`QRows::push`] calls.
    pub fn append_block(&mut self, data: &[f32]) {
        debug_assert_eq!(data.len() % self.dim, 0,
                         "append_block wants whole rows");
        for row in data.chunks_exact(self.dim) {
            self.push(row);
        }
    }

    /// Dequantize rows `[i0, i1)` into `out` (`[i1 - i0, dim]`
    /// row-major) through the byte LUTs — the block-dequant attention
    /// kernel's cache read: each packed KV row decodes exactly once per
    /// query block into a scratch tile, instead of once per query
    /// token. `out[r][j]` is bitwise `self.at(i0 + r, j)`, so dense
    /// tile ops over the output are bit-identical to the element-wise
    /// [`QRows::dot`] / [`QRows::axpy_into`] reference kernels.
    pub fn dequant_block_into(&self, i0: usize, i1: usize,
                              out: &mut [f32]) {
        debug_assert!(i0 <= i1 && i1 <= self.n_rows,
                      "dequant_block_into rows {i0}..{i1} of a {}-row \
                       cache", self.n_rows);
        debug_assert_eq!(out.len(), (i1 - i0) * self.dim,
                         "dequant_block_into wants {} f32s", (i1 - i0)
                         * self.dim);
        match self.sbits {
            Some(sbits) => {
                for (i, orow) in (i0..i1)
                    .zip(out.chunks_exact_mut(self.dim))
                {
                    let row = &self.codes
                        [i * self.stride..(i + 1) * self.stride];
                    lut::dequant_uniform(row, sbits, self.scales[i], 0,
                                         self.dim, orow);
                }
            }
            None => {
                out.copy_from_slice(
                    &self.dense[i0 * self.dim..i1 * self.dim]);
            }
        }
    }

    /// Dequantized element `j` of row `i` (test/diagnostic helper).
    pub fn at(&self, i: usize, j: usize) -> f32 {
        match self.sbits {
            Some(sbits) => {
                let row = &self.codes[i * self.stride..(i + 1) * self.stride];
                decode(row, sbits, j) as f32 * self.scales[i]
            }
            None => self.dense[i * self.dim + j],
        }
    }

    /// deq(row i) · x, accumulated in ascending element order — the
    /// element-wise attention-logit reference kernel (the hot path now
    /// reads [`QRows::dequant_block_into`] tiles). Bit-identical
    /// between packed and dense storage of the same fake-quantized row.
    pub fn dot(&self, i: usize, x: &[f32]) -> f32 {
        debug_assert!(i < self.n_rows, "QRows::dot row {i} of a {}-row \
                                        cache", self.n_rows);
        debug_assert_eq!(x.len(), self.dim);
        match self.sbits {
            Some(sbits) => {
                let row = &self.codes[i * self.stride..(i + 1) * self.stride];
                let s = self.scales[i];
                let mut acc = 0.0f32;
                for (j, &xv) in x.iter().enumerate() {
                    acc += decode(row, sbits, j) as f32 * s * xv;
                }
                acc
            }
            None => {
                let row = &self.dense[i * self.dim..(i + 1) * self.dim];
                let mut acc = 0.0f32;
                for (kv, &xv) in row.iter().zip(x) {
                    acc += kv * xv;
                }
                acc
            }
        }
    }

    /// out += w * deq(row i) — the element-wise attention value-mix
    /// reference kernel, same element order and parity as
    /// [`QRows::dot`].
    pub fn axpy_into(&self, i: usize, w: f32, out: &mut [f32]) {
        debug_assert!(i < self.n_rows, "QRows::axpy_into row {i} of a \
                                        {}-row cache", self.n_rows);
        debug_assert_eq!(out.len(), self.dim);
        match self.sbits {
            Some(sbits) => {
                let row = &self.codes[i * self.stride..(i + 1) * self.stride];
                let s = self.scales[i];
                for (j, o) in out.iter_mut().enumerate() {
                    *o += w * (decode(row, sbits, j) as f32 * s);
                }
            }
            None => {
                let row = &self.dense[i * self.dim..(i + 1) * self.dim];
                for (o, &v) in out.iter_mut().zip(row) {
                    *o += w * v;
                }
            }
        }
    }
}

/// One layer's key and value stores.
pub struct LayerKv {
    pub k: QRows,
    pub v: QRows,
}

/// Per-sequence KV cache: `n_layers` layer stores of (position, head)
/// rows, position-major (`row = pos * n_heads + head`).
pub struct SeqKv {
    layers: Vec<LayerKv>,
    n_heads: usize,
    n_tokens: usize,
}

impl SeqKv {
    pub fn new(n_layers: usize, n_heads: usize, head_dim: usize,
               kv_bits: u32) -> SeqKv {
        let layers = (0..n_layers)
            .map(|_| LayerKv { k: QRows::new(head_dim, kv_bits),
                               v: QRows::new(head_dim, kv_bits) })
            .collect();
        SeqKv { layers, n_heads, n_tokens: 0 }
    }

    /// Positions cached so far (the next token decodes at this position).
    pub fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layer(&self, l: usize) -> &LayerKv {
        &self.layers[l]
    }

    pub fn layer_mut(&mut self, l: usize) -> &mut LayerKv {
        &mut self.layers[l]
    }

    /// Called once per decoded token, after every layer pushed its
    /// `n_heads` K and V rows.
    pub fn advance(&mut self) {
        self.advance_by(1);
    }

    /// Advance the position counter past a whole block of `n` tokens
    /// (every layer must already hold their K/V rows). The block-forward
    /// path calls this once per chunk instead of once per token.
    pub fn advance_by(&mut self, n: usize) {
        self.n_tokens += n;
        for lay in &self.layers {
            debug_assert_eq!(lay.k.len(), self.n_tokens * self.n_heads);
            debug_assert_eq!(lay.v.len(), self.n_tokens * self.n_heads);
        }
    }

    /// Total cache bytes across layers (K + V).
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.bytes() + l.v.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn fake_quant_ref(row: &[f32], bits: u32) -> Vec<f32> {
        let lv = levels_for_bits(bits);
        let absmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = absmax / lv + KV_EPS;
        row.iter()
            .map(|&v| (v / scale).round().clamp(-lv - 1.0, lv) * scale)
            .collect()
    }

    #[test]
    fn packed_rows_hold_fake_quant_values_bitwise() {
        let mut rng = Pcg::new(1, 0);
        for bits in [2u32, 4, 8] {
            let mut rows = QRows::new(16, bits);
            assert!(rows.is_packed());
            for r in 0..5 {
                let row: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
                rows.push(&row);
                let want = fake_quant_ref(&row, bits);
                for (j, w) in want.iter().enumerate() {
                    assert_eq!(rows.at(r, j), *w, "{bits}b r{r} j{j}");
                }
            }
        }
    }

    #[test]
    fn passthrough_rows_apply_the_off_tap() {
        let mut rows = QRows::new(8, 16);
        assert!(!rows.is_packed());
        let row: Vec<f32> = (0..8).map(|i| i as f32 * 0.3 - 1.0).collect();
        rows.push(&row);
        let want = fake_quant_ref(&row, 16);
        for (j, w) in want.iter().enumerate() {
            assert_eq!(rows.at(0, j), *w, "j{j}");
        }
    }

    #[test]
    fn append_block_equals_repeated_push() {
        let mut rng = Pcg::new(9, 0);
        let dim = 10;
        for bits in [4u32, 16] {
            let flat: Vec<f32> = (0..3 * dim).map(|_| rng.normal()).collect();
            let mut blk = QRows::new(dim, bits);
            blk.append_block(&flat);
            let mut one = QRows::new(dim, bits);
            for row in flat.chunks_exact(dim) {
                one.push(row);
            }
            assert_eq!(blk.len(), 3);
            for i in 0..3 {
                for j in 0..dim {
                    assert_eq!(blk.at(i, j), one.at(i, j),
                               "{bits}b r{i} j{j}");
                }
            }
        }
    }

    #[test]
    fn dot_and_axpy_match_dense_reference_bitwise() {
        let mut rng = Pcg::new(2, 0);
        let dim = 12;
        let mut packed = QRows::new(dim, 4);
        let mut dense: Vec<Vec<f32>> = Vec::new();
        for _ in 0..7 {
            let row: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
            packed.push(&row);
            dense.push(fake_quant_ref(&row, 4));
        }
        let x: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        for (i, drow) in dense.iter().enumerate() {
            let mut want = 0.0f32;
            for (kv, &xv) in drow.iter().zip(&x) {
                want += kv * xv;
            }
            assert_eq!(packed.dot(i, &x), want, "dot row {i}");
            let mut a = vec![0.5f32; dim];
            let mut b = a.clone();
            packed.axpy_into(i, 0.25, &mut a);
            for (o, &v) in b.iter_mut().zip(drow) {
                *o += 0.25 * v;
            }
            assert_eq!(a, b, "axpy row {i}");
        }
    }

    #[test]
    fn dequant_block_matches_element_accessor() {
        // Packed widths (2..8, including the 3/5-bit field-sharing
        // cases) and the f32 passthrough, over interior [i0, i1) spans.
        let mut rng = Pcg::new(21, 0);
        let dim = 9;
        for bits in [2u32, 3, 4, 5, 8, 16] {
            let mut rows = QRows::new(dim, bits);
            for _ in 0..7 {
                let row: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
                rows.push(&row);
            }
            for (i0, i1) in [(0usize, 7usize), (2, 5), (3, 3), (6, 7)] {
                let mut out = vec![0.0f32; (i1 - i0) * dim];
                rows.dequant_block_into(i0, i1, &mut out);
                for (r, i) in (i0..i1).enumerate() {
                    for j in 0..dim {
                        assert_eq!(out[r * dim + j], rows.at(i, j),
                                   "{bits}b [{i0},{i1}) row {i} j{j}");
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "row")]
    #[cfg(debug_assertions)]
    fn dot_out_of_range_fails_loudly() {
        let mut rows = QRows::new(4, 4);
        rows.push(&[1.0, 2.0, 3.0, 4.0]);
        rows.dot(3, &[0.0; 4]);
    }

    #[test]
    #[should_panic(expected = "rows")]
    #[cfg(debug_assertions)]
    fn dequant_block_out_of_range_fails_loudly() {
        let mut rows = QRows::new(4, 4);
        rows.push(&[1.0, 2.0, 3.0, 4.0]);
        let mut out = vec![0.0f32; 8];
        rows.dequant_block_into(0, 2, &mut out);
    }

    #[test]
    fn kv4_cache_is_much_smaller_than_f32() {
        let mut q4 = QRows::new(64, 4);
        let mut q16 = QRows::new(64, 16);
        let row = vec![0.5f32; 64];
        for _ in 0..32 {
            q4.push(&row);
            q16.push(&row);
        }
        // 4-bit rows: 32 bytes codes + 4 bytes scale vs 256 bytes f32.
        assert!(q4.bytes() * 4 < q16.bytes(),
                "{} vs {}", q4.bytes(), q16.bytes());
    }

    #[test]
    fn seq_kv_row_accounting() {
        let mut kv = SeqKv::new(2, 4, 8, 4);
        assert_eq!(kv.n_tokens(), 0);
        let row = vec![0.1f32; 8];
        for l in 0..2 {
            for _h in 0..4 {
                kv.layer_mut(l).k.push(&row);
                kv.layer_mut(l).v.push(&row);
            }
        }
        kv.advance();
        assert_eq!(kv.n_tokens(), 1);
        assert!(kv.bytes() > 0);
        // A 3-token block advances in one call.
        let block = vec![0.2f32; 3 * 4 * 8];
        for l in 0..2 {
            kv.layer_mut(l).k.append_block(&block);
            kv.layer_mut(l).v.append_block(&block);
        }
        kv.advance_by(3);
        assert_eq!(kv.n_tokens(), 4);
    }
}
