//! Token selection: greedy argmax and temperature sampling with
//! optional top-k / nucleus (top-p) truncation.
//!
//! Determinism contract: every path is a pure function of
//! (logits, parameters, RNG state). `top_k = 0` and `top_p >= 1.0` mean
//! "off"; with both off, [`sample_token_filtered`] is *bitwise* the
//! untruncated [`sample_token`] (same index-order accumulation against
//! the same single RNG draw), `top_k = 1` is exactly [`argmax`], and
//! `temperature <= 0` is greedy regardless of truncation.

use crate::util::rng::Pcg;

use super::ops::softmax_in_place;

/// Greedy argmax over a logits row (lowest index wins ties —
/// deterministic).
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate().skip(1) {
        if v > row[best] {
            best = i;
        }
    }
    best as i32
}

/// Sample from softmax(logits / temperature); `temperature <= 0` is
/// greedy.
pub fn sample_token(row: &[f32], temperature: f32, rng: &mut Pcg) -> i32 {
    if temperature <= 0.0 {
        return argmax(row);
    }
    let mut probs: Vec<f32> = row.iter().map(|v| v / temperature).collect();
    softmax_in_place(&mut probs);
    let u = rng.uniform() as f32;
    let mut acc = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i as i32;
        }
    }
    (probs.len() - 1) as i32
}

/// [`sample_token`] with top-k / top-p truncation: keep the `top_k`
/// highest-probability tokens (0 = all), then shrink to the smallest
/// prefix whose cumulative probability reaches `top_p` (>= 1.0 = all),
/// renormalize over the kept set, and sample. Candidates are ordered by
/// descending probability with index as the deterministic tie-break, so
/// fixed (seed, logits) always yields the same token.
pub fn sample_token_filtered(row: &[f32], temperature: f32, top_k: usize,
                             top_p: f32, rng: &mut Pcg) -> i32 {
    if temperature <= 0.0 {
        return argmax(row);
    }
    if top_k == 0 && top_p >= 1.0 {
        // No truncation: take the exact untruncated path (bitwise the
        // pre-top-k/p behavior, pinned by the p=1.0 unit test).
        return sample_token(row, temperature, rng);
    }
    let mut probs: Vec<f32> = row.iter().map(|v| v / temperature).collect();
    softmax_in_place(&mut probs);
    // Total order: descending probability, index as tie-break — makes
    // both the selected set and its ordering deterministic.
    let by_prob_desc = |a: &usize, b: &usize| {
        probs[*b].total_cmp(&probs[*a]).then(a.cmp(b))
    };
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    let k = if top_k == 0 { idx.len() } else { top_k.min(idx.len()) };
    if k < idx.len() {
        // Partial selection isolates the top k in O(V); only those are
        // sorted (a full-vocab sort per sampled token dominated at
        // serving vocab sizes).
        idx.select_nth_unstable_by(k - 1, by_prob_desc);
        idx.truncate(k);
    }
    idx.sort_unstable_by(by_prob_desc);
    // `keep` stays the full top-k set if the mass never reaches p.
    let mut keep = k;
    if top_p < 1.0 {
        let mut acc = 0.0f32;
        for (n, &i) in idx.iter().enumerate() {
            acc += probs[i];
            if acc >= top_p {
                keep = n + 1;
                break;
            }
        }
    }
    let kept = &idx[..keep.max(1)];
    let z: f32 = kept.iter().map(|&i| probs[i]).sum();
    let u = rng.uniform() as f32 * z;
    let mut acc = 0.0f32;
    for &i in kept {
        acc += probs[i];
        if u < acc {
            return i as i32;
        }
    }
    kept[kept.len() - 1] as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_breaks_ties_low() {
        assert_eq!(argmax(&[0.5, 1.0, 1.0, 0.1]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn sample_greedy_at_zero_temperature() {
        let mut rng = Pcg::new(1, 0);
        let row = [0.1f32, 3.0, -1.0];
        assert_eq!(sample_token(&row, 0.0, &mut rng), 1);
        assert_eq!(sample_token_filtered(&row, 0.0, 2, 0.5, &mut rng), 1);
        // Positive temperature samples valid indices.
        for _ in 0..50 {
            let t = sample_token(&row, 1.0, &mut rng);
            assert!((0..3).contains(&t));
        }
    }

    #[test]
    fn top_k_one_is_argmax() {
        let mut rng = Pcg::new(3, 0);
        let row = [0.2f32, 1.7, -0.5, 1.7, 0.9];
        for _ in 0..40 {
            assert_eq!(sample_token_filtered(&row, 0.9, 1, 1.0, &mut rng),
                       argmax(&row));
        }
    }

    #[test]
    fn top_p_one_and_k_zero_match_full_softmax_bitwise() {
        let row = [0.3f32, -1.0, 2.0, 0.7, -0.2];
        let mut a = Pcg::new(11, 5);
        let mut b = Pcg::new(11, 5);
        for _ in 0..60 {
            assert_eq!(sample_token_filtered(&row, 0.8, 0, 1.0, &mut a),
                       sample_token(&row, 0.8, &mut b));
        }
    }

    #[test]
    fn truncation_restricts_support() {
        let row = [5.0f32, 4.5, -10.0, -10.0, -10.0];
        let mut rng = Pcg::new(7, 0);
        for _ in 0..80 {
            // top_k = 2 can only ever yield the two high-logit tokens.
            let t = sample_token_filtered(&row, 1.0, 2, 1.0, &mut rng);
            assert!(t == 0 || t == 1, "top-k leaked {t}");
            // A tight nucleus keeps only the head of the distribution.
            let t = sample_token_filtered(&row, 1.0, 0, 0.5, &mut rng);
            assert_eq!(t, 0, "top-p leaked {t}");
        }
    }

    #[test]
    fn filtered_sampling_is_seed_deterministic() {
        let row = [0.4f32, 0.2, 1.1, -0.3, 0.8, 0.0];
        let run = |seed: u64| -> Vec<i32> {
            let mut rng = Pcg::new(seed, 9);
            (0..16)
                .map(|_| sample_token_filtered(&row, 0.7, 3, 0.9, &mut rng))
                .collect()
        };
        assert_eq!(run(42), run(42));
        assert!(run(42).iter().all(|&t| (0..6).contains(&t)));
    }
}
