//! Per-row math shared by every host forward path (DESIGN.md §9):
//! norms, the per-token activation fake-quant tap, RoPE, softmax, and
//! SiLU. Extracted from the decode engine so the block forward, the
//! single-token decode path, and the engine-free evaluator all snap
//! through the exact same kernels — the bit-parity contracts depend on
//! every call site agreeing.

use crate::tensor::Tensor;

use super::kv::KV_EPS;

/// RMSNorm (per-channel scale) or SSNorm (scalar gamma), matching the
/// graph kernels' formulas (`ref.rmsnorm_ref` / `ref.ssnorm_ref`).
pub fn norm_row(row: &mut [f32], scale: &Tensor, ss: bool) {
    if ss {
        let norm = (row.iter().map(|v| v * v).sum::<f32>() + 1e-6).sqrt();
        let g = scale.data()[0];
        for v in row.iter_mut() {
            *v = g * *v / norm;
        }
    } else {
        let ms = row.iter().map(|v| v * v).sum::<f32>()
            / row.len() as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for (v, s) in row.iter_mut().zip(scale.data()) {
            *v *= s * inv;
        }
    }
}

/// Per-token RTN fake-quantization (the evalq activation tap):
/// `scale = absmax / levels + 1e-8`, values snapped to the symmetric
/// grid through the one shared [`crate::quant::rtn::rtn_code`] helper
/// (the parity contract depends on every snap site agreeing). With the
/// "off" levels (2^20) this is numerically the identity, exactly like
/// the graph.
pub fn fake_quant_row(row: &mut [f32], levels: f32) {
    let absmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let scale = absmax / levels + KV_EPS;
    for v in row.iter_mut() {
        *v = crate::quant::rtn::rtn_code(*v, scale, levels) as f32 * scale;
    }
}

/// Rotary embedding of one head row at absolute position `pos`
/// (half-split layout, matching `model._rope`; frequencies come from
/// the model's precomputed `theta^(-j/half)` table).
pub fn rope_in_place(head: &mut [f32], pos: usize, inv_freq: &[f32]) {
    let half = head.len() / 2;
    debug_assert_eq!(inv_freq.len(), half);
    for j in 0..half {
        let angle = pos as f32 * inv_freq[j];
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (head[j], head[half + j]);
        head[j] = a * cos - b * sin;
        head[half + j] = a * sin + b * cos;
    }
}

/// Numerically-stable in-place softmax over one weight row. An empty
/// row is a no-op (the normalizer would otherwise be 0 and the old
/// 0/0 path minted NaNs for every later read of the buffer).
pub fn softmax_in_place(w: &mut [f32]) {
    if w.is_empty() {
        return;
    }
    let m = w.iter().cloned().fold(f32::MIN, f32::max);
    let mut z = 0.0f32;
    for v in w.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    for v in w.iter_mut() {
        *v /= z;
    }
}

pub fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_empty_slice_is_a_noop() {
        let mut w: Vec<f32> = Vec::new();
        softmax_in_place(&mut w); // must not panic or divide 0/0
        assert!(w.is_empty());
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut w = vec![0.5f32, 1.5, -2.0, 0.0];
        softmax_in_place(&mut w);
        let z: f32 = w.iter().sum();
        assert!((z - 1.0).abs() < 1e-6, "sum {z}");
        assert!(w.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn fake_quant_off_levels_is_identity() {
        // 2^20 levels (the "off" tap) leaves typical activations intact.
        let mut row = vec![0.125f32, -1.0, 0.75, 2.5];
        let want = row.clone();
        fake_quant_row(&mut row, (1u32 << 20) as f32);
        assert_eq!(row, want);
    }

    #[test]
    fn norm_row_rms_and_ss() {
        let scale = Tensor::full(&[4], 1.0);
        let mut row = vec![1.0f32, -1.0, 1.0, -1.0];
        norm_row(&mut row, &scale, false);
        for v in &row {
            assert!((v.abs() - 1.0).abs() < 1e-3, "{row:?}");
        }
        let g = Tensor::full(&[1], 2.0);
        let mut row = vec![3.0f32, 4.0];
        norm_row(&mut row, &g, true);
        // |x| = 5, so x -> 2 * x / 5.
        assert!((row[0] - 1.2).abs() < 1e-5 && (row[1] - 1.6).abs() < 1e-5,
                "{row:?}");
    }

    #[test]
    fn rope_preserves_norm() {
        let inv_freq = [1.0f32, 0.1];
        let mut head = vec![1.0f32, 2.0, 3.0, 4.0];
        let norm0: f32 = head.iter().map(|v| v * v).sum();
        rope_in_place(&mut head, 7, &inv_freq);
        let norm1: f32 = head.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() < 1e-4, "{norm0} vs {norm1}");
        // Position 0 is the identity rotation.
        let mut h0 = vec![1.0f32, 2.0, 3.0, 4.0];
        rope_in_place(&mut h0, 0, &inv_freq);
        assert_eq!(h0, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
