//! Per-row math shared by every host forward path (DESIGN.md §9):
//! norms, the per-token activation fake-quant tap, RoPE, softmax, and
//! SiLU. Extracted from the decode engine so the block forward, the
//! single-token decode path, and the engine-free evaluator all snap
//! through the exact same kernels — the bit-parity contracts depend on
//! every call site agreeing.

use crate::quant::rtn;
use crate::tensor::intkern::{Backend, QuantActs};
use crate::tensor::Tensor;

/// RMSNorm (per-channel scale) or SSNorm (scalar gamma), matching the
/// graph kernels' formulas (`ref.rmsnorm_ref` / `ref.ssnorm_ref`).
pub fn norm_row(row: &mut [f32], scale: &Tensor, ss: bool) {
    if ss {
        let norm = (row.iter().map(|v| v * v).sum::<f32>() + 1e-6).sqrt();
        let g = scale.data()[0];
        for v in row.iter_mut() {
            *v = g * *v / norm;
        }
    } else {
        let ms = row.iter().map(|v| v * v).sum::<f32>()
            / row.len() as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for (v, s) in row.iter_mut().zip(scale.data()) {
            *v *= s * inv;
        }
    }
}

/// Per-token RTN fake-quantization (the evalq activation tap):
/// `scale = absmax / levels + 1e-8` ([`rtn::act_scale`]), values
/// snapped to the symmetric grid through the one shared
/// [`rtn::rtn_code`] helper (the parity contract depends on every snap
/// site agreeing). For i8-representable grids (A≤8) this is literally
/// codes-times-scale through the i8 type — the integer tap
/// ([`quant_rows_i8`]) emits the very same codes. With the "off" levels
/// (2^20) this is numerically the identity, exactly like the graph.
pub fn fake_quant_row(row: &mut [f32], levels: f32) {
    let scale = rtn::act_scale(row, levels);
    if rtn::i8_representable(levels) {
        for v in row.iter_mut() {
            *v = rtn::rtn_code(*v, scale, levels) as i8 as f32 * scale;
        }
    } else {
        for v in row.iter_mut() {
            *v = rtn::rtn_code(*v, scale, levels) as f32 * scale;
        }
    }
}

/// Integer form of the activation tap: quantize every row of `data`
/// (row width `k`) to i8 codes + one scale via
/// [`rtn::quantize_row_i8`], writing the fake-quant values back in
/// place. The write-back is bitwise [`fake_quant_row`]'s output, so the
/// f32 fallback kernels, probes, and residual reads see exactly what
/// they always saw — the codes are a lossless side channel for the
/// integer kernels.
pub fn quant_rows_i8(data: &mut [f32], k: usize, levels: f32) -> QuantActs {
    let m = if k == 0 { 0 } else { data.len() / k };
    debug_assert_eq!(m * k, data.len());
    let mut codes = vec![0i8; data.len()];
    let mut scales = vec![0.0f32; m];
    for (r, row) in data.chunks_exact_mut(k.max(1)).enumerate() {
        let crow = &mut codes[r * k..(r + 1) * k];
        let scale = rtn::quantize_row_i8(row, levels, crow);
        scales[r] = scale;
        for (v, &c) in row.iter_mut().zip(crow.iter()) {
            *v = c as f32 * scale;
        }
    }
    QuantActs::from_parts(codes, scales, m, k)
}

/// One activation tap site: fake-quantize every row of `data` in
/// place, and when an integer backend is active also emit the i8
/// codes/scales for the downstream packed linears. `None` (integer
/// path off, or the grid is not i8-representable) leaves behavior
/// exactly as before — plain [`fake_quant_row`] per row.
pub fn quant_tap(data: &mut [f32], k: usize, levels: f32,
                 int_be: Option<Backend>) -> Option<(QuantActs, Backend)> {
    match int_be {
        Some(be) if rtn::i8_representable(levels) => {
            Some((quant_rows_i8(data, k, levels), be))
        }
        _ => {
            for row in data.chunks_exact_mut(k.max(1)) {
                fake_quant_row(row, levels);
            }
            None
        }
    }
}

/// Rotary embedding of one head row at absolute position `pos`
/// (half-split layout, matching `model._rope`; frequencies come from
/// the model's precomputed `theta^(-j/half)` table).
pub fn rope_in_place(head: &mut [f32], pos: usize, inv_freq: &[f32]) {
    let half = head.len() / 2;
    debug_assert_eq!(inv_freq.len(), half);
    for j in 0..half {
        let angle = pos as f32 * inv_freq[j];
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (head[j], head[half + j]);
        head[j] = a * cos - b * sin;
        head[half + j] = a * sin + b * cos;
    }
}

/// Numerically-stable in-place softmax over one weight row. An empty
/// row is a no-op (the normalizer would otherwise be 0 and the old
/// 0/0 path minted NaNs for every later read of the buffer).
pub fn softmax_in_place(w: &mut [f32]) {
    if w.is_empty() {
        return;
    }
    let m = w.iter().cloned().fold(f32::MIN, f32::max);
    let mut z = 0.0f32;
    for v in w.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    for v in w.iter_mut() {
        *v /= z;
    }
}

pub fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_empty_slice_is_a_noop() {
        let mut w: Vec<f32> = Vec::new();
        softmax_in_place(&mut w); // must not panic or divide 0/0
        assert!(w.is_empty());
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut w = vec![0.5f32, 1.5, -2.0, 0.0];
        softmax_in_place(&mut w);
        let z: f32 = w.iter().sum();
        assert!((z - 1.0).abs() < 1e-6, "sum {z}");
        assert!(w.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn fake_quant_off_levels_is_identity() {
        // 2^20 levels (the "off" tap) leaves typical activations intact.
        let mut row = vec![0.125f32, -1.0, 0.75, 2.5];
        let want = row.clone();
        fake_quant_row(&mut row, (1u32 << 20) as f32);
        assert_eq!(row, want);
    }

    /// The pre-refactor tap, verbatim: inline absmax/scale plus the
    /// rtn_code snap. The rewrite through `rtn::act_scale` /
    /// `rtn::quantize_row_i8` must reproduce it bit for bit.
    fn fake_quant_row_old(row: &mut [f32], levels: f32) {
        let absmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = absmax / levels + super::super::kv::KV_EPS;
        for v in row.iter_mut() {
            *v = crate::quant::rtn::rtn_code(*v, scale, levels) as f32
                * scale;
        }
    }

    #[test]
    fn fake_quant_rewrite_is_bitwise_the_old_impl() {
        let mut rng = crate::util::rng::Pcg::new(31, 2);
        for levels in [1.0f32, 3.0, 7.0, 127.0, 16383.0,
                       (1u32 << 20) as f32] {
            for len in [1usize, 5, 64] {
                let mut row = vec![0.0f32; len];
                rng.fill_normal(&mut row, 2.0);
                let mut old = row.clone();
                fake_quant_row_old(&mut old, levels);
                fake_quant_row(&mut row, levels);
                assert_eq!(row.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                           old.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                           "levels {levels} len {len}");
            }
        }
    }

    #[test]
    fn quant_rows_i8_writes_back_fake_quant_bitwise() {
        let mut rng = crate::util::rng::Pcg::new(57, 2);
        for (m, k) in [(1usize, 17usize), (4, 8), (3, 33)] {
            for levels in [7.0f32, 127.0] {
                let mut data = vec![0.0f32; m * k];
                rng.fill_normal(&mut data, 1.0);
                let mut want = data.clone();
                for row in want.chunks_exact_mut(k) {
                    fake_quant_row(row, levels);
                }
                let acts = quant_rows_i8(&mut data, k, levels);
                assert_eq!(data, want, "write-back m {m} k {k}");
                for r in 0..m {
                    for (t, &c) in acts.row_codes(r).iter().enumerate() {
                        let deq = c as f32 * acts.scale(r);
                        assert_eq!(deq.to_bits(),
                                   want[r * k + t].to_bits(),
                                   "codes×scale r {r} t {t}");
                    }
                }
            }
        }
    }

    #[test]
    fn quant_tap_without_backend_equals_plain_rows() {
        let mut rng = crate::util::rng::Pcg::new(91, 2);
        let mut data = vec![0.0f32; 3 * 16];
        rng.fill_normal(&mut data, 1.0);
        let mut want = data.clone();
        for row in want.chunks_exact_mut(16) {
            fake_quant_row(row, 7.0);
        }
        assert!(quant_tap(&mut data, 16, 7.0, None).is_none());
        assert_eq!(data, want);
        // A non-i8 grid must refuse the integer side even when asked.
        let mut wide = want.clone();
        assert!(quant_tap(&mut wide, 16, 16383.0,
                          Some(Backend::Scalar)).is_none());
        // And an i8 grid with a backend returns codes matching the
        // written-back values.
        let mut data2 = want.clone();
        let (acts, be) = quant_tap(&mut data2, 16, 7.0,
                                   Some(Backend::Scalar)).unwrap();
        assert_eq!(be, Backend::Scalar);
        assert_eq!(acts.m(), 3);
        assert_eq!(acts.k(), 16);
        for r in 0..3 {
            for (t, &c) in acts.row_codes(r).iter().enumerate() {
                assert_eq!((c as f32 * acts.scale(r)).to_bits(),
                           data2[r * 16 + t].to_bits());
            }
        }
    }

    #[test]
    fn norm_row_rms_and_ss() {
        let scale = Tensor::full(&[4], 1.0);
        let mut row = vec![1.0f32, -1.0, 1.0, -1.0];
        norm_row(&mut row, &scale, false);
        for v in &row {
            assert!((v.abs() - 1.0).abs() < 1e-3, "{row:?}");
        }
        let g = Tensor::full(&[1], 2.0);
        let mut row = vec![3.0f32, 4.0];
        norm_row(&mut row, &g, true);
        // |x| = 5, so x -> 2 * x / 5.
        assert!((row[0] - 1.2).abs() < 1e-5 && (row[1] - 1.6).abs() < 1e-5,
                "{row:?}");
    }

    #[test]
    fn rope_preserves_norm() {
        let inv_freq = [1.0f32, 0.1];
        let mut head = vec![1.0f32, 2.0, 3.0, 4.0];
        let norm0: f32 = head.iter().map(|v| v * v).sum();
        rope_in_place(&mut head, 7, &inv_freq);
        let norm1: f32 = head.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() < 1e-4, "{norm0} vs {norm1}");
        // Position 0 is the identity rotation.
        let mut h0 = vec![1.0f32, 2.0, 3.0, 4.0];
        rope_in_place(&mut h0, 0, &inv_freq);
        assert_eq!(h0, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
