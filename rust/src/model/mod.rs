//! Host model layer (DESIGN.md §9): the one forward pass every
//! engine-free path shares — batched decode, chunked multi-token
//! prefill, and teacher-forced evaluation all run through
//! [`InferModel::forward_block`] straight off packed [`QTensor`] weights
//! with a quantized KV cache ([`kv`]).
//!
//! The block forward takes `[n_tokens, d_model]` activations per
//! sequence and runs the trunk as `qmatmul` matrix-matrix calls: every
//! linear layer batches across *all tokens of all sequences*, so each
//! packed weight row is dequantized in-register once per block instead
//! of once per token — the same amortization `qmatmul_rhs` applies
//! across the batch. Attention is causally masked per sequence over the
//! packed KV cache, which grows whole blocks at a time
//! ([`kv::QRows::append_block`] / [`kv::SeqKv::advance_by`]) and is
//! read by block-dequant (DESIGN.md §10): each cached K/V row decodes
//! exactly once per query block into a per-thread [`scratch`] tile
//! through the byte LUTs, with scores and value mixes then running as
//! dense tile ops — killing the old per-(query, row) re-decode.
//!
//! The forward mirrors the evalq graph semantics
//! (`python/compile/model.py`): RMSNorm/SSNorm, RoPE on q/k, per-token
//! RTN fake-quantization of every linear input activation (`a_bits`),
//! KV-cache quantization after RoPE (`kv_bits`), and the optional online
//! Hadamard on the FFN hidden state (`had_flag`, paired with the
//! pre-rotated `w_down` the PTQ pipeline emits). Bit-widths follow the
//! same `levels = 2^(bits-1) - 1` mapping as the executables.
//!
//! Parity contract (pinned by `rust/tests/infer_properties.rs` and
//! `rust/tests/model_properties.rs`):
//!
//! * Forwarding on packed weights is bit-identical to forwarding on
//!   their [`QTensor::dequantize`]d f32 twins — the fused kernels share
//!   the dense kernels' accumulation order, and the packed KV cache
//!   stores exactly the fake-quantized values the dense cache holds.
//! * Block size never changes results: feeding a prompt in chunks of 1
//!   or 64 yields bit-identical logits and KV contents, because every
//!   per-token operation is row-local and attention reads the same
//!   cached rows in the same order either way.
//! * Serial and pool-parallel forwards are bit-identical for any worker
//!   count: batch rows, column stripes, and per-sequence attention jobs
//!   each compute with the same per-element arithmetic.

pub mod kv;
pub mod ops;
pub mod remote;
pub mod sample;
pub mod scratch;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::levels_for_bits;
use crate::quant::QParam;
use crate::tensor::intkern::{Backend, IntMode, QuantActs};
use crate::tensor::linalg;
use crate::tensor::qtensor::QTensor;
use crate::tensor::{par, Tensor};
use crate::util::rng::Pcg;
use crate::util::threadpool::ThreadPool;

use std::sync::Arc;

use kv::{PagePool, SeqKv};

pub use sample::{argmax, sample_token, sample_token_filtered};

/// The decoder shape the host layer runs (subset of the lowering-time
/// model config, plus the norm/embproj knobs the arch name encodes).
#[derive(Clone, Debug)]
pub struct InferConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub rope_theta: f32,
    /// Single-Scale RMSNorm (scalar gamma) vs per-channel RMSNorm.
    pub norm_ss: bool,
    pub embproj: bool,
}

impl InferConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Decode the norm/embproj knobs from an arch tag
    /// (`{rms|ss}norm_{plain|embproj}`).
    pub fn arch_knobs(arch: &str) -> Result<(bool, bool)> {
        let norm_ss = match arch.split("norm_").next() {
            Some("rms") => false,
            Some("ss") => true,
            _ => bail!("unknown arch '{arch}' (want {{rms|ss}}norm_...)"),
        };
        let embproj = match arch.split("norm_").nth(1) {
            Some("plain") => false,
            Some("embproj") => true,
            _ => bail!("unknown arch '{arch}' (want ..._{{plain|embproj}})"),
        };
        Ok((norm_ss, embproj))
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_heads == 0 || self.d_model % self.n_heads != 0 {
            bail!("n_heads {} must divide d_model {}", self.n_heads,
                  self.d_model);
        }
        if self.head_dim() % 2 != 0 {
            bail!("head_dim {} must be even (RoPE pairs channels)",
                  self.head_dim());
        }
        Ok(())
    }
}

/// One weight matrix of the host model: packed codes (the deployment
/// path), a dense f32 fallback, or a remote handle whose codes live on
/// sharded workers (DESIGN.md §14). All kernels are bit-identical
/// across representations of the same dequantized values; the remote
/// arm only supports the integer-tap path (validated at serve spawn).
pub enum Linear {
    Dense(Tensor),
    Packed(QTensor),
    Remote(remote::RemoteLinear),
}

impl Linear {
    fn shape(&self) -> &[usize] {
        match self {
            Linear::Dense(t) => t.shape(),
            Linear::Packed(q) => q.shape(),
            Linear::Remote(r) => r.shape(),
        }
    }

    /// C = A @ deq(self); `self` is `[in, out]`, A is `[batch, in]`.
    fn matmul(&self, pool: Option<&ThreadPool>, a: &Tensor) -> Tensor {
        match self {
            Linear::Dense(t) => par::matmul_with(pool, a, t),
            Linear::Packed(q) => q.qmatmul_rhs_with(pool, a),
            Linear::Remote(r) => panic!(
                "remote linear '{}' has no f32 path — sharded serving \
                 requires the integer tap (a_bits <= 8, int mode on)",
                r.op()),
        }
    }

    /// [`Self::matmul`] with an optional integer-tap side channel: when
    /// the tap carries i8 activation codes and this leaf is packed, the
    /// product runs on the integer kernels
    /// ([`QTensor::qmatmul_rhs_int_with`]); every other combination
    /// falls back to the f32 path on the *same* `a` (the tap's
    /// write-back), so routing never changes which values are consumed.
    /// Remote leaves accept only the tap path — their codes live on
    /// workers that speak i8, and the spawn-time validation guarantees
    /// every trunk tap is live before a remote model serves. Remote
    /// transport failure is the only `Err` — local paths are
    /// infallible — and it propagates through `forward_block` to the
    /// serve loop's step-error boundary (DESIGN.md §15).
    fn matmul_tap(&self, pool: Option<&ThreadPool>, a: &Tensor,
                  tap: Option<&(QuantActs, Backend)>) -> Result<Tensor> {
        match (self, tap) {
            (Linear::Remote(r), Some((acts, _be))) => {
                return r.matmul_int(acts);
            }
            (Linear::Packed(q), Some((acts, be))) => {
                if q.is_packed() {
                    return Ok(q.qmatmul_rhs_int_with(pool, acts, *be));
                }
            }
            _ => {}
        }
        Ok(self.matmul(pool, a))
    }

    /// Row `i` dequantized into `out` (the embedding lookup).
    fn row_into(&self, i: usize, out: &mut [f32]) {
        match self {
            Linear::Dense(t) => out.copy_from_slice(t.row(i)),
            Linear::Packed(q) => q.dequant_row_into(i, out),
            Linear::Remote(r) => panic!(
                "row_into on remote linear '{}' (embedding leaves stay \
                 on the coordinator)", r.op()),
        }
    }

    /// Serialized weight bytes this process holds in the current
    /// representation (a remote leaf keeps only its rescale vector —
    /// the codes are worker-side).
    pub fn packed_bytes(&self) -> usize {
        match self {
            Linear::Dense(t) => 4 * t.len(),
            Linear::Packed(q) => q.packed_bytes(),
            Linear::Remote(r) => r.local_bytes(),
        }
    }

    fn dequantized(&self) -> Linear {
        match self {
            Linear::Dense(t) => Linear::Dense(t.clone()),
            Linear::Packed(q) => Linear::Dense(q.dequantize()),
            Linear::Remote(r) => panic!(
                "cannot dequantize remote linear '{}'", r.op()),
        }
    }

    fn quantized(&self, bits: u32) -> Linear {
        match self {
            Linear::Dense(t) if bits < 16 => {
                Linear::Packed(crate::quant::rtn::quantize_per_channel_q(
                    t, bits))
            }
            Linear::Dense(t) => Linear::Dense(t.clone()),
            Linear::Packed(q) => Linear::Packed(q.clone()),
            Linear::Remote(r) => panic!(
                "cannot requantize remote linear '{}'", r.op()),
        }
    }
}

struct LayerWeights {
    attn_norm: Tensor,
    wq: Linear,
    wk: Linear,
    wv: Linear,
    wo: Linear,
    ffn_norm: Tensor,
    w_gate: Linear,
    w_up: Linear,
    w_down: Linear,
}

/// What [`InferModel::forward_block`] should run the logits head on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LogitsMode {
    /// Skip the final-norm/EmbProj/unembed head (the model's largest
    /// matmul) entirely — pure prefill steps.
    None,
    /// Logits for the *last* token of each sequence only
    /// (`[n_seqs, vocab]`) — the decode/sampling path. Head ops are
    /// row-local, so this is bitwise the matching rows of `All`.
    Last,
    /// Logits for every fed token (`[total_tokens, vocab]`, sequences
    /// concatenated in order) — the teacher-forced eval path.
    All,
}

/// One sequence's slice of a block forward: the tokens to feed this call
/// and the KV cache they extend (positions `cache.n_tokens()..+len`).
pub struct SeqBlock<'a> {
    pub tokens: &'a [i32],
    pub cache: &'a mut SeqKv,
}

/// Residual-stream kurtosis accumulator, mirroring the evalq graph's
/// `kurt` output: tap `2*li` samples the MHSA input of layer `li`,
/// tap `2*li + 1` the FFN input. Samples concatenate across every
/// [`InferModel::forward_block`] call that carries the probe; callers
/// scope one probe per evaluation batch (and average the per-batch
/// kurtosis, like the engine path's `mean_vecs`) so probe memory stays
/// bounded by a single batch's activations.
pub struct KurtProbe {
    taps: Vec<Vec<f32>>,
}

impl KurtProbe {
    pub fn new(n_layers: usize) -> KurtProbe {
        KurtProbe { taps: vec![Vec::new(); 2 * n_layers] }
    }

    fn tap(&mut self, idx: usize, data: &[f32]) {
        self.taps[idx].extend_from_slice(data);
    }

    /// Excess kurtosis per tap (`[2 * n_layers]`, MHSA-in then FFN-in
    /// per layer — the paper's Fig-2/3 measurement points).
    pub fn kurt(&self) -> Vec<f64> {
        self.taps
            .iter()
            .map(|t| crate::tensor::stats::excess_kurtosis(t))
            .collect()
    }
}

/// A decode-ready model: the packed leaves of a
/// [`crate::quant::QuantizedModel`] (or dense f32 weights) arranged for
/// the block forward pass.
pub struct InferModel {
    pub cfg: InferConfig,
    /// Online FFN Hadamard (must match the weight preparation).
    pub had_flag: bool,
    embed: Linear,
    embproj_in: Option<Linear>,
    embproj_out: Option<Linear>,
    layers: Vec<LayerWeights>,
    final_norm: Tensor,
    unembed: Linear,
    /// Precomputed RoPE frequencies `theta^(-j/half)`, one per
    /// channel pair — keeps `powf` out of the per-token hot loop.
    rope_inv_freq: Vec<f32>,
    /// Integer-kernel dispatch for A≤8 packed linears (DESIGN.md §11).
    /// Defaults to [`IntMode::Off`] so library callers keep the exact
    /// packed-vs-dense f32 parity; the CLI opts into `Auto`.
    int_mode: IntMode,
}

fn rope_inv_freq(cfg: &InferConfig) -> Vec<f32> {
    let half = cfg.head_dim() / 2;
    (0..half)
        .map(|j| cfg.rope_theta.powf(-(j as f32) / half as f32))
        .collect()
}

/// Replace one validated packed trunk leaf with its remote handle
/// (helper of [`InferModel::shard_remote`]).
fn swap_remote(name: String, l: &mut Linear, kind: remote::ShardKind,
               pool: &Arc<dyn remote::ShardCompute>) {
    let Linear::Packed(q) = &*l else {
        unreachable!("shard_remote validated '{name}' as packed");
    };
    let shape = [q.rows(), q.cols()];
    let bits = q.bits();
    let scales = if kind == remote::ShardKind::Row {
        q.scales().to_vec()
    } else {
        Vec::new()
    };
    *l = Linear::Remote(remote::RemoteLinear::new(
        name, shape, bits, kind, scales, Arc::clone(pool)));
}

fn norm_leaf(p: &QParam) -> Tensor {
    match p {
        QParam::Dense(t) => t.clone(),
        QParam::Packed(q) => q.dequantize(),
    }
}

fn linear_leaf(p: &QParam) -> Linear {
    match p {
        QParam::Dense(t) => Linear::Dense(t.clone()),
        QParam::Packed(q) => Linear::Packed(q.clone()),
    }
}

impl InferModel {
    /// Build from quantized-model leaves in manifest parameter order
    /// (embed, [embproj_in, embproj_out], per layer {attn_norm, wq, wk,
    /// wv, wo, ffn_norm, w_gate, w_up, w_down}, final_norm, unembed).
    /// `n_heads` and `rope_theta` come from the lowering-time config —
    /// they are not recoverable from the leaf shapes.
    pub fn from_qparams(arch: &str, params: &[QParam], n_heads: usize,
                        rope_theta: f32, had_flag: bool)
                        -> Result<InferModel> {
        let (norm_ss, embproj) = InferConfig::arch_knobs(arch)?;
        let head = 1 + if embproj { 2 } else { 0 };
        let tail = 2; // final_norm, unembed
        let body = params
            .len()
            .checked_sub(head + tail)
            .ok_or_else(|| anyhow!("{} leaves is too few for '{arch}'",
                                   params.len()))?;
        if body % 9 != 0 {
            bail!("{} leaves does not match '{arch}' (9 per layer)",
                  params.len());
        }
        let n_layers = body / 9;
        if n_layers == 0 {
            bail!("'{arch}' model with zero layers");
        }
        let embed = linear_leaf(&params[0]);
        if embed.shape().len() != 2 {
            bail!("embed leaf is not 2-D");
        }
        let (vocab_size, d_model) = (embed.shape()[0], embed.shape()[1]);
        let (embproj_in, embproj_out) = if embproj {
            (Some(linear_leaf(&params[1])), Some(linear_leaf(&params[2])))
        } else {
            (None, None)
        };
        let mut layers = Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            let b = head + li * 9;
            layers.push(LayerWeights {
                attn_norm: norm_leaf(&params[b]),
                wq: linear_leaf(&params[b + 1]),
                wk: linear_leaf(&params[b + 2]),
                wv: linear_leaf(&params[b + 3]),
                wo: linear_leaf(&params[b + 4]),
                ffn_norm: norm_leaf(&params[b + 5]),
                w_gate: linear_leaf(&params[b + 6]),
                w_up: linear_leaf(&params[b + 7]),
                w_down: linear_leaf(&params[b + 8]),
            });
        }
        let d_ff = layers[0].w_gate.shape()[1];
        let final_norm = norm_leaf(&params[head + body]);
        let unembed = linear_leaf(&params[head + body + 1]);
        if unembed.shape() != &[d_model, vocab_size] {
            bail!("unembed shape {:?} != [{d_model}, {vocab_size}]",
                  unembed.shape());
        }
        let want_norm = if norm_ss { 1 } else { d_model };
        for (what, len) in [("attn_norm", layers[0].attn_norm.len()),
                            ("ffn_norm", layers[0].ffn_norm.len()),
                            ("final_norm", final_norm.len())] {
            if len != want_norm {
                bail!("{what} has {len} scales, '{arch}' wants \
                       {want_norm}");
            }
        }
        let cfg = InferConfig { vocab_size, d_model, n_layers, n_heads,
                                d_ff, rope_theta, norm_ss, embproj };
        cfg.validate()?;
        let rope_inv_freq = rope_inv_freq(&cfg);
        Ok(InferModel { cfg, had_flag, embed, embproj_in, embproj_out,
                        layers, final_norm, unembed, rope_inv_freq,
                        int_mode: IntMode::default() })
    }

    /// Wrap dense f32 checkpoint leaves (same ordering) — the unquantized
    /// baseline the consistency checks decode against, and the FP rows of
    /// the host-eval tables.
    pub fn from_dense_params(arch: &str, params: &[Tensor], n_heads: usize,
                             rope_theta: f32) -> Result<InferModel> {
        let qp: Vec<QParam> =
            params.iter().cloned().map(QParam::Dense).collect();
        InferModel::from_qparams(arch, &qp, n_heads, rope_theta, false)
    }

    /// The dense-f32 twin: every packed leaf dequantized, everything
    /// else cloned. Same token streams bit-for-bit (the parity
    /// contract); used by `osp generate --check` and the property tests.
    pub fn dequantized(&self) -> InferModel {
        InferModel {
            cfg: self.cfg.clone(),
            had_flag: self.had_flag,
            embed: self.embed.dequantized(),
            embproj_in: self.embproj_in.as_ref().map(|l| l.dequantized()),
            embproj_out: self.embproj_out.as_ref().map(|l| l.dequantized()),
            layers: self
                .layers
                .iter()
                .map(|l| LayerWeights {
                    attn_norm: l.attn_norm.clone(),
                    wq: l.wq.dequantized(),
                    wk: l.wk.dequantized(),
                    wv: l.wv.dequantized(),
                    wo: l.wo.dequantized(),
                    ffn_norm: l.ffn_norm.clone(),
                    w_gate: l.w_gate.dequantized(),
                    w_up: l.w_up.dequantized(),
                    w_down: l.w_down.dequantized(),
                })
                .collect(),
            final_norm: self.final_norm.clone(),
            unembed: self.unembed.dequantized(),
            rope_inv_freq: self.rope_inv_freq.clone(),
            int_mode: self.int_mode,
        }
    }

    /// RTN-quantize every matrix leaf to `w_bits` packed codes (norm
    /// leaves stay dense) — the synthetic-model path serve-bench and the
    /// property tests use; real checkpoints go through `quant::prepare`.
    pub fn quantized(&self, w_bits: u32) -> InferModel {
        InferModel {
            cfg: self.cfg.clone(),
            had_flag: self.had_flag,
            embed: self.embed.quantized(w_bits),
            embproj_in: self.embproj_in.as_ref()
                .map(|l| l.quantized(w_bits)),
            embproj_out: self.embproj_out.as_ref()
                .map(|l| l.quantized(w_bits)),
            layers: self
                .layers
                .iter()
                .map(|l| LayerWeights {
                    attn_norm: l.attn_norm.clone(),
                    wq: l.wq.quantized(w_bits),
                    wk: l.wk.quantized(w_bits),
                    wv: l.wv.quantized(w_bits),
                    wo: l.wo.quantized(w_bits),
                    ffn_norm: l.ffn_norm.clone(),
                    w_gate: l.w_gate.quantized(w_bits),
                    w_up: l.w_up.quantized(w_bits),
                    w_down: l.w_down.quantized(w_bits),
                })
                .collect(),
            final_norm: self.final_norm.clone(),
            unembed: self.unembed.quantized(w_bits),
            rope_inv_freq: self.rope_inv_freq.clone(),
            int_mode: self.int_mode,
        }
    }

    /// A random dense model at `cfg` (normal init, residual-branch
    /// scaling like the init artifact) — the no-artifacts path for
    /// serve-bench, the examples, and the property tests.
    pub fn synthetic(cfg: &InferConfig, seed: u64) -> InferModel {
        cfg.validate().expect("synthetic: invalid InferConfig");
        let mut rng = Pcg::new(seed, 23);
        let std = 0.05f32;
        let res = std / (2.0 * cfg.n_layers as f32).sqrt();
        let mut randn = |shape: &[usize], s: f32| -> Linear {
            let mut t = Tensor::zeros(shape);
            rng.fill_normal(t.data_mut(), s);
            Linear::Dense(t)
        };
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
        let norm = |ss: bool| -> Tensor {
            if ss {
                Tensor::full(&[1], (d as f32).sqrt())
            } else {
                Tensor::full(&[d], 1.0)
            }
        };
        let embed = randn(&[v, d], std);
        let (embproj_in, embproj_out) = if cfg.embproj {
            (Some(randn(&[d, d], 1.0 / (d as f32).sqrt())),
             Some(randn(&[d, d], 1.0 / (d as f32).sqrt())))
        } else {
            (None, None)
        };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                attn_norm: norm(cfg.norm_ss),
                wq: randn(&[d, d], std),
                wk: randn(&[d, d], std),
                wv: randn(&[d, d], std),
                wo: randn(&[d, d], res),
                ffn_norm: norm(cfg.norm_ss),
                w_gate: randn(&[d, f], std),
                w_up: randn(&[d, f], std),
                w_down: randn(&[f, d], res),
            })
            .collect();
        let final_norm = norm(cfg.norm_ss);
        let unembed = randn(&[d, v], std);
        InferModel { cfg: cfg.clone(), had_flag: false, embed, embproj_in,
                     embproj_out, layers, final_norm, unembed,
                     rope_inv_freq: rope_inv_freq(cfg),
                     int_mode: IntMode::default() }
    }

    /// Select the integer-kernel dispatch mode (see [`IntMode`]).
    pub fn set_int_mode(&mut self, mode: IntMode) {
        self.int_mode = mode;
    }

    /// Builder form of [`Self::set_int_mode`].
    pub fn with_int_mode(mut self, mode: IntMode) -> InferModel {
        self.int_mode = mode;
        self
    }

    pub fn int_mode(&self) -> IntMode {
        self.int_mode
    }

    /// The kernel backend A`a_bits` linears will actually run on:
    /// `Some` only when the mode opts in *and* the activation grid is
    /// i8-representable (A≤8).
    pub fn int_kernel(&self, a_bits: u32) -> Option<Backend> {
        match self.int_mode.backend() {
            Some(be) if crate::quant::rtn::int_levels(a_bits).is_some() => {
                Some(be)
            }
            _ => None,
        }
    }

    /// Label for stats/bench rows: the resolved backend, or None when
    /// the integer path is off for this activation width.
    pub fn int_kernel_label(&self, a_bits: u32) -> Option<&'static str> {
        self.int_kernel(a_bits).map(Backend::label)
    }

    /// Serialized weight bytes in the current representation.
    pub fn weight_bytes(&self) -> usize {
        let mut b = self.embed.packed_bytes() + self.unembed.packed_bytes();
        for l in [&self.embproj_in, &self.embproj_out].into_iter().flatten() {
            b += l.packed_bytes();
        }
        for l in &self.layers {
            b += 4 * (l.attn_norm.len() + l.ffn_norm.len())
                + l.wq.packed_bytes() + l.wk.packed_bytes()
                + l.wv.packed_bytes() + l.wo.packed_bytes()
                + l.w_gate.packed_bytes() + l.w_up.packed_bytes()
                + l.w_down.packed_bytes();
        }
        b + 4 * self.final_norm.len()
    }

    /// Storage bit-width of the packed weight leaves (the serve-layer
    /// "W" in a `W4A4KV4` label): the widest packed leaf, or 16 when
    /// every leaf is dense f32. Stats plumbing for `/metrics` and
    /// `BENCH_serve.json` rows — not used by any kernel.
    pub fn weight_bits(&self) -> u32 {
        let leaf = |l: &Linear| match l {
            Linear::Packed(q) if q.is_packed() => q.bits(),
            Linear::Remote(r) => r.bits(),
            _ => 16,
        };
        let mut bits = 0u32;
        for l in &self.layers {
            for w in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up,
                      &l.w_down] {
                bits = bits.max(leaf(w));
            }
        }
        if bits == 0 { 16 } else { bits }
    }

    /// `(name, leaf, split kind)` for every shardable trunk linear, in
    /// one fixed order (DESIGN.md §14): QKV and the FFN expansions
    /// split by output column (their per-channel scales travel with
    /// the columns), the reduction weights (wo/w_down) by contraction
    /// row (exact i32 partials), and the unembed — the widest matmul —
    /// by column like the projections. The names are the routing keys
    /// workers look shards up by.
    fn trunk_linears(&self) -> Vec<(String, &Linear, remote::ShardKind)> {
        use remote::ShardKind::{Col, Row};
        let mut v = Vec::with_capacity(7 * self.layers.len() + 1);
        for (li, lw) in self.layers.iter().enumerate() {
            v.push((format!("L{li}.wq"), &lw.wq, Col));
            v.push((format!("L{li}.wk"), &lw.wk, Col));
            v.push((format!("L{li}.wv"), &lw.wv, Col));
            v.push((format!("L{li}.wo"), &lw.wo, Row));
            v.push((format!("L{li}.w_gate"), &lw.w_gate, Col));
            v.push((format!("L{li}.w_up"), &lw.w_up, Col));
            v.push((format!("L{li}.w_down"), &lw.w_down, Row));
        }
        v.push(("unembed".to_string(), &self.unembed, Col));
        v
    }

    /// Slice every trunk linear into `shards` self-contained worker
    /// sets (DESIGN.md §14). Embedding, norm, and EmbProj leaves stay
    /// with the coordinator — they are small and row-local. Requires
    /// every trunk leaf packed (shard a quantized model) and every
    /// split dimension >= `shards`.
    pub fn extract_shard_sets(&self, shards: usize)
                              -> Result<Vec<remote::ShardSet>> {
        if shards == 0 {
            bail!("extract_shard_sets: need at least one shard");
        }
        let mut sets: Vec<remote::ShardSet> =
            (0..shards).map(|_| Vec::new()).collect();
        for (name, l, kind) in self.trunk_linears() {
            let q = match l {
                Linear::Packed(q) if q.is_packed() => q,
                Linear::Remote(_) => bail!("'{name}' is already remote"),
                _ => bail!("'{name}' is not packed — shard a quantized \
                            model (w_bits <= 8)"),
            };
            let (k, n) = (q.rows(), q.cols());
            let dim = match kind {
                remote::ShardKind::Col => n,
                remote::ShardKind::Row => k,
            };
            if dim < shards {
                bail!("'{name}' {} dimension {dim} < {shards} shards",
                      kind.label());
            }
            for (w, set) in sets.iter_mut().enumerate() {
                let (a, b) = remote::shard_range(dim, shards, w);
                let piece = match kind {
                    remote::ShardKind::Col => q.shard_cols(a, b),
                    remote::ShardKind::Row => q.shard_rows(a, b),
                };
                set.push(remote::ShardEntry {
                    name: name.clone(), kind, full_k: k, full_n: n,
                    off: a, q: piece,
                });
            }
        }
        Ok(sets)
    }

    /// Swap every shardable trunk linear for a remote handle driving
    /// `pool` (the coordinator side of sharded serving). Validates the
    /// whole trunk before mutating anything, so a failed call leaves
    /// the model untouched. After the swap, only the integer-tap
    /// forward works (the serve layer enforces `a_bits <= 8` + int
    /// mode at spawn), and [`Self::weight_bytes`] reports just the
    /// coordinator-resident bytes — the sharded codes are accounted by
    /// the workers holding them.
    pub fn shard_remote(&mut self, pool: Arc<dyn remote::ShardCompute>)
                        -> Result<()> {
        let shards = pool.n_workers();
        if shards == 0 {
            bail!("shard_remote: pool has no workers");
        }
        for (name, l, kind) in self.trunk_linears() {
            let q = match l {
                Linear::Packed(q) if q.is_packed() => q,
                Linear::Remote(_) => bail!("'{name}' is already remote"),
                _ => bail!("'{name}' is not packed — shard a quantized \
                            model (w_bits <= 8)"),
            };
            let dim = match kind {
                remote::ShardKind::Col => q.cols(),
                remote::ShardKind::Row => q.rows(),
            };
            if dim < shards {
                bail!("'{name}' {} dimension {dim} < {shards} workers",
                      kind.label());
            }
        }
        for li in 0..self.layers.len() {
            use remote::ShardKind::{Col, Row};
            let lw = &mut self.layers[li];
            swap_remote(format!("L{li}.wq"), &mut lw.wq, Col, &pool);
            swap_remote(format!("L{li}.wk"), &mut lw.wk, Col, &pool);
            swap_remote(format!("L{li}.wv"), &mut lw.wv, Col, &pool);
            swap_remote(format!("L{li}.wo"), &mut lw.wo, Row, &pool);
            swap_remote(format!("L{li}.w_gate"), &mut lw.w_gate, Col,
                        &pool);
            swap_remote(format!("L{li}.w_up"), &mut lw.w_up, Col, &pool);
            swap_remote(format!("L{li}.w_down"), &mut lw.w_down, Row,
                        &pool);
        }
        swap_remote("unembed".to_string(), &mut self.unembed,
                    remote::ShardKind::Col, &pool);
        Ok(())
    }

    /// Worker count behind the trunk after [`Self::shard_remote`];
    /// 0 for a fully local model.
    pub fn remote_workers(&self) -> usize {
        match self.layers.first().map(|l| &l.wq) {
            Some(Linear::Remote(r)) => r.workers(),
            _ => 0,
        }
    }

    /// Fresh per-sequence KV cache for this model (private page pool
    /// — the standalone/eval path).
    pub fn new_cache(&self, kv_bits: u32) -> SeqKv {
        SeqKv::new(self.cfg.n_layers, self.cfg.n_heads,
                   self.cfg.head_dim(), kv_bits)
    }

    /// Fresh per-sequence KV cache drawing its pages from a shared
    /// [`PagePool`] (the decode-engine path, DESIGN.md §13). The
    /// pool's geometry must match this model's head width and the
    /// requested KV bit-width.
    pub fn new_cache_in(&self, kv_bits: u32, pool: &Arc<PagePool>)
                        -> SeqKv {
        assert_eq!(pool.dim(), self.cfg.head_dim(),
                   "pool page geometry != model head_dim");
        assert_eq!(pool.bits(), kv_bits,
                   "pool bit-width != requested kv_bits");
        SeqKv::new_in(self.cfg.n_layers, self.cfg.n_heads,
                      Arc::clone(pool))
    }

    /// The core op of the host layer: feed each sequence's token block
    /// (any per-sequence length >= 1) at its cache position and run the
    /// trunk once over the concatenated `[total_tokens, d_model]`
    /// activations. Linear layers batch across every token of every
    /// sequence (the prefill-amortization win); attention runs per
    /// sequence, causally, over its quantized cache — one pool job each.
    ///
    /// Rejects empty batches, empty per-sequence blocks, and
    /// out-of-vocab tokens with `Err` (never panics), so one bad request
    /// cannot kill a serve loop. On success every cache has advanced by
    /// its block length and the logits selected by `mode` are returned.
    pub fn forward_block(&self, pool: Option<&ThreadPool>,
                         seqs: &mut [SeqBlock<'_>], a_bits: u32,
                         mode: LogitsMode,
                         mut probe: Option<&mut KurtProbe>)
                         -> Result<Option<Tensor>> {
        if seqs.is_empty() {
            bail!("forward_block: empty batch");
        }
        for (si, sb) in seqs.iter().enumerate() {
            if sb.tokens.is_empty() {
                bail!("forward_block: sequence {si} feeds no tokens");
            }
            for &t in sb.tokens {
                if t < 0 || t as usize >= self.cfg.vocab_size {
                    bail!("forward_block: sequence {si} token {t} outside \
                           vocab 0..{}", self.cfg.vocab_size);
                }
            }
        }
        let d = self.cfg.d_model;
        let a_levels = levels_for_bits(a_bits);
        // Resolved once per block: Some(backend) routes every packed
        // linear whose input passes an activation tap through the
        // integer kernels; None is the legacy f32 path everywhere.
        let int_be = self.int_kernel(a_bits);
        let total: usize = seqs.iter().map(|s| s.tokens.len()).sum();

        // Embedding lookup (+ EmbProj input projection), sequences
        // concatenated in order.
        let mut x = Tensor::zeros(&[total, d]);
        {
            let xd = x.data_mut();
            let mut r = 0usize;
            for sb in seqs.iter() {
                for &t in sb.tokens {
                    self.embed.row_into(t as usize,
                                        &mut xd[r * d..(r + 1) * d]);
                    r += 1;
                }
            }
        }
        if let Some(p_in) = &self.embproj_in {
            x = p_in.matmul(pool, &x);
        }

        // Layer-loop scratch, allocated once per block instead of once
        // per layer: the norm/fake-quant staging and the attention
        // accumulator (re-zeroed per layer — its writers accumulate).
        let mut h = Tensor::zeros(&[total, d]);
        let mut attn_out = Tensor::zeros(&[total, d]);
        for (li, lw) in self.layers.iter().enumerate() {
            // ---- MHSA ----
            if let Some(p) = probe.as_deref_mut() {
                p.tap(2 * li, x.data());
            }
            h.data_mut().copy_from_slice(x.data());
            for row in h.data_mut().chunks_mut(d) {
                ops::norm_row(row, &lw.attn_norm, self.cfg.norm_ss);
            }
            // One tap feeds all three projections: the rows are
            // quantized exactly once and the codes shared.
            let tap = ops::quant_tap(h.data_mut(), d, a_levels, int_be);
            let q = lw.wq.matmul_tap(pool, &h, tap.as_ref())?;
            let k = lw.wk.matmul_tap(pool, &h, tap.as_ref())?;
            let v = lw.wv.matmul_tap(pool, &h, tap.as_ref())?;
            attn_out.data_mut().fill(0.0);
            {
                let (qd, kd, vd) = (q.data(), k.data(), v.data());
                let mut jobs: Vec<(usize, &mut SeqKv, &mut [f32])> =
                    Vec::with_capacity(seqs.len());
                let mut rest = attn_out.data_mut();
                let mut row0 = 0usize;
                for sb in seqs.iter_mut() {
                    let n = sb.tokens.len();
                    // `take` moves the remainder out so the split halves
                    // keep the full borrow lifetime across iterations.
                    let (chunk, tail) =
                        std::mem::take(&mut rest).split_at_mut(n * d);
                    rest = tail;
                    jobs.push((row0, &mut *sb.cache, chunk));
                    row0 += n;
                }
                par::par_map_mut(pool, &mut jobs, |_ji, (row0, cache, out)| {
                    self.attend_block(li, *row0, qd, kd, vd, cache, out);
                });
            }
            let tap = ops::quant_tap(attn_out.data_mut(), d, a_levels,
                                     int_be);
            x = x.add(&lw.wo.matmul_tap(pool, &attn_out,
                                        tap.as_ref())?);

            // ---- FFN (SwiGLU) ----
            if let Some(p) = probe.as_deref_mut() {
                p.tap(2 * li + 1, x.data());
            }
            h.data_mut().copy_from_slice(x.data());
            for row in h.data_mut().chunks_mut(d) {
                ops::norm_row(row, &lw.ffn_norm, self.cfg.norm_ss);
            }
            let tap = ops::quant_tap(h.data_mut(), d, a_levels, int_be);
            let gate = lw.w_gate.matmul_tap(pool, &h, tap.as_ref())?;
            let mut g = lw.w_up.matmul_tap(pool, &h, tap.as_ref())?;
            for (gv, xv) in g.data_mut().iter_mut().zip(gate.data()) {
                *gv *= ops::silu(*xv);
            }
            let f = self.cfg.d_ff;
            let (blk, hscale) = (linalg::pow2_block(f),
                                 1.0 / (linalg::pow2_block(f) as f32).sqrt());
            if self.had_flag {
                for row in g.data_mut().chunks_mut(f) {
                    linalg::hadamard_row(row, blk, hscale);
                }
            }
            let tap = ops::quant_tap(g.data_mut(), f, a_levels, int_be);
            x = x.add(&lw.w_down.matmul_tap(pool, &g, tap.as_ref())?);
        }

        // Advance every cache past its whole block.
        for sb in seqs.iter_mut() {
            sb.cache.advance_by(sb.tokens.len());
        }

        let mut h = match mode {
            LogitsMode::None => return Ok(None),
            LogitsMode::All => x,
            LogitsMode::Last => {
                // Head ops are row-local, so gathering last rows first is
                // bitwise the matching rows of the All head.
                let mut last = Tensor::zeros(&[seqs.len(), d]);
                let mut r = 0usize;
                for (si, sb) in seqs.iter().enumerate() {
                    r += sb.tokens.len();
                    last.row_mut(si)
                        .copy_from_slice(&x.data()[(r - 1) * d..r * d]);
                }
                last
            }
        };
        for row in h.data_mut().chunks_mut(d) {
            ops::norm_row(row, &self.final_norm, self.cfg.norm_ss);
        }
        if let Some(p_out) = &self.embproj_out {
            h = p_out.matmul(pool, &h);
        }
        let tap = ops::quant_tap(h.data_mut(), d, a_levels, int_be);
        Ok(Some(self.unembed.matmul_tap(pool, &h, tap.as_ref())?))
    }

    /// One decode step for a batch of sequences: feed `tokens[r]` at
    /// position `caches[r].n_tokens()` and return next-token logits
    /// `[batch, vocab]` — the block forward with every block of length
    /// one. Returns `Err` (instead of the old panic) on empty batches
    /// and out-of-vocab tokens.
    pub fn forward_step(&self, pool: Option<&ThreadPool>, tokens: &[i32],
                        caches: &mut [SeqKv], a_bits: u32)
                        -> Result<Tensor> {
        let mut refs: Vec<&mut SeqKv> = caches.iter_mut().collect();
        self.forward_step_refs(pool, tokens, &mut refs, a_bits)
    }

    /// [`InferModel::forward_step`] over a scattered view of caches (the
    /// scheduler's sequences own theirs individually).
    pub fn forward_step_refs(&self, pool: Option<&ThreadPool>,
                             tokens: &[i32], caches: &mut [&mut SeqKv],
                             a_bits: u32) -> Result<Tensor> {
        Ok(self
            .decode_step(pool, tokens, caches, a_bits, true)?
            .expect("want_logits"))
    }

    /// The single-token compat entry point: like
    /// [`InferModel::forward_step_refs`] but with `want_logits = false`
    /// the final-norm/EmbProj/unembed head is skipped and `None`
    /// returned. Only valid for steps where no sequence samples (pure
    /// prefill); the trunk and every cache update are identical either
    /// way.
    pub fn decode_step(&self, pool: Option<&ThreadPool>, tokens: &[i32],
                       caches: &mut [&mut SeqKv], a_bits: u32,
                       want_logits: bool) -> Result<Option<Tensor>> {
        if tokens.len() != caches.len() {
            bail!("decode_step: {} tokens for {} caches", tokens.len(),
                  caches.len());
        }
        let mut blocks: Vec<SeqBlock> = tokens
            .iter()
            .zip(caches.iter_mut())
            .map(|(t, c)| SeqBlock { tokens: std::slice::from_ref(t),
                                     cache: &mut **c })
            .collect();
        let mode = if want_logits { LogitsMode::All } else {
            LogitsMode::None
        };
        self.forward_block(pool, &mut blocks, a_bits, mode, None)
    }

    /// Per-sequence causal attention at layer `li` over one block, in
    /// three passes (DESIGN.md §10): (1) RoPE + quantize-append the
    /// whole block's K/V head rows ([`kv::QRows::append_block`]) — same
    /// values and append order as the old per-token path; (2)
    /// block-dequant every cached row exactly once into the calling
    /// thread's head-major scratch tiles
    /// ([`kv::QRows::dequant_block_into`]); (3) softmax-attend each
    /// (token, head) causally over the dense tiles into `out`
    /// (`[n_tokens, d_model]`, heads merged). Scores and value mixes
    /// accumulate in the same ascending element/position order as the
    /// element-wise [`kv::QRows::dot`] / [`kv::QRows::axpy_into`]
    /// kernels over the packed rows, so the rewrite is bit-identical to
    /// the per-(query, row) re-decoding path it replaced.
    fn attend_block(&self, li: usize, row0: usize, qd: &[f32], kd: &[f32],
                    vd: &[f32], cache: &mut SeqKv, out: &mut [f32]) {
        let (nh, hd) = (self.cfg.n_heads, self.cfg.head_dim());
        let d = self.cfg.d_model;
        let n = out.len() / d;
        let base = cache.n_tokens();
        let p = base + n;
        let shd = (hd as f32).sqrt();
        scratch::with_attn(|s| {
            s.reserve(nh, hd, p);
            // (1) RoPE + append this block's K/V rows. Attention for
            // token i only reads positions 0..=base+i, so appending the
            // whole block up front is causally equivalent to the old
            // interleaved append/attend.
            {
                let lay = cache.layer_mut(li);
                let kbuf = &mut s.kbuf[..d];
                for i in 0..n {
                    let r = row0 + i;
                    kbuf.copy_from_slice(&kd[r * d..(r + 1) * d]);
                    for h in 0..nh {
                        ops::rope_in_place(&mut kbuf[h * hd..(h + 1) * hd],
                                           base + i, &self.rope_inv_freq);
                    }
                    lay.k.append_block(kbuf);
                    lay.v.append_block(&vd[r * d..(r + 1) * d]);
                }
            }
            // (2) Block-dequant the whole visible cache into head-major
            // tiles, one page run at a time (DESIGN.md §13): each run
            // of position-major rows living in one physical page
            // decodes in a single sweep into the page staging buffer,
            // then scatters row-by-row so (pos, h) lands at tile
            // offset (h * p + pos) and each head's score/mix loops
            // stream contiguously. The scatter copies whole decoded
            // rows, so the tiles are bitwise what the per-row
            // dequant_block_into calls produced for any page size.
            let lay = cache.layer(li);
            let rows = p * nh;
            let prun = lay.k.page_rows();
            s.reserve_run(prun.min(rows) * hd);
            let mut r0 = 0usize;
            while r0 < rows {
                let r1 = ((r0 / prun + 1) * prun).min(rows);
                {
                    let stage = &mut s.pg[..(r1 - r0) * hd];
                    lay.k.dequant_block_into(r0, r1, stage);
                    for (ri, srow) in (r0..r1)
                        .zip(stage.chunks_exact(hd))
                    {
                        let dst = ((ri % nh) * p + ri / nh) * hd;
                        s.k[dst..dst + hd].copy_from_slice(srow);
                    }
                }
                {
                    let stage = &mut s.pg[..(r1 - r0) * hd];
                    lay.v.dequant_block_into(r0, r1, stage);
                    for (ri, srow) in (r0..r1)
                        .zip(stage.chunks_exact(hd))
                    {
                        let dst = ((ri % nh) * p + ri / nh) * hd;
                        s.v[dst..dst + hd].copy_from_slice(srow);
                    }
                }
                r0 = r1;
            }
            // (3) Scores + softmax + value mix on the dense tiles.
            let qh = &mut s.qh[..hd];
            for i in 0..n {
                let pos = base + i;
                let r = row0 + i;
                let qrow = &qd[r * d..(r + 1) * d];
                for h in 0..nh {
                    qh.copy_from_slice(&qrow[h * hd..(h + 1) * hd]);
                    ops::rope_in_place(qh, pos, &self.rope_inv_freq);
                    let ktile = &s.k[h * p * hd..(h + 1) * p * hd];
                    let w = &mut s.w[..pos + 1];
                    for (t, wv) in w.iter_mut().enumerate() {
                        let krow = &ktile[t * hd..(t + 1) * hd];
                        let mut acc = 0.0f32;
                        for (kv, qv) in krow.iter().zip(qh.iter()) {
                            acc += kv * qv;
                        }
                        *wv = acc / shd;
                    }
                    ops::softmax_in_place(w);
                    let vtile = &s.v[h * p * hd..(h + 1) * p * hd];
                    let out_h =
                        &mut out[i * d + h * hd..i * d + (h + 1) * hd];
                    for (t, &wv) in w.iter().enumerate() {
                        let vrow = &vtile[t * hd..(t + 1) * hd];
                        for (o, &vv) in out_h.iter_mut().zip(vrow) {
                            *o += wv * vv;
                        }
                    }
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> InferConfig {
        InferConfig { vocab_size: 96, d_model: 32, n_layers: 2, n_heads: 2,
                      d_ff: 48, rope_theta: 10000.0, norm_ss: true,
                      embproj: false }
    }

    #[test]
    fn arch_knobs_parse() {
        assert_eq!(InferConfig::arch_knobs("rmsnorm_plain").unwrap(),
                   (false, false));
        assert_eq!(InferConfig::arch_knobs("ssnorm_embproj").unwrap(),
                   (true, true));
        assert!(InferConfig::arch_knobs("bogus").is_err());
    }

    #[test]
    fn synthetic_roundtrip_through_qparams() {
        let m = InferModel::synthetic(&tiny_cfg(), 3);
        assert_eq!(m.cfg.vocab_size, 96);
        let q = m.quantized(4);
        assert!(q.weight_bytes() * 3 < m.weight_bytes(),
                "{} vs {}", q.weight_bytes(), m.weight_bytes());
    }

    #[test]
    fn forward_step_shapes_and_cache_growth() {
        let m = InferModel::synthetic(&tiny_cfg(), 5);
        let mut caches = vec![m.new_cache(4), m.new_cache(4)];
        let logits = m.forward_step(None, &[1, 2], &mut caches, 4).unwrap();
        assert_eq!(logits.shape(), &[2, 96]);
        assert_eq!(caches[0].n_tokens(), 1);
        let logits = m.forward_step(None, &[3, 4], &mut caches, 4).unwrap();
        assert_eq!(logits.shape(), &[2, 96]);
        assert_eq!(caches[1].n_tokens(), 2);
    }

    #[test]
    fn forward_block_multi_token_shapes() {
        let m = InferModel::synthetic(&tiny_cfg(), 5);
        let mut c0 = m.new_cache(4);
        let mut c1 = m.new_cache(4);
        let t0 = [1i32, 2, 3];
        let t1 = [4i32, 5];
        let mut blocks = vec![SeqBlock { tokens: &t0, cache: &mut c0 },
                              SeqBlock { tokens: &t1, cache: &mut c1 }];
        let all = m
            .forward_block(None, &mut blocks, 4, LogitsMode::All, None)
            .unwrap()
            .unwrap();
        assert_eq!(all.shape(), &[5, 96]);
        assert_eq!(c0.n_tokens(), 3);
        assert_eq!(c1.n_tokens(), 2);
    }

    #[test]
    fn last_mode_matches_all_mode_rows_bitwise() {
        let m = InferModel::synthetic(&tiny_cfg(), 7).quantized(4);
        let t0 = [1i32, 2, 3];
        let t1 = [4i32, 5];
        let run = |mode: LogitsMode| -> Tensor {
            let mut c0 = m.new_cache(4);
            let mut c1 = m.new_cache(4);
            let mut blocks =
                vec![SeqBlock { tokens: &t0, cache: &mut c0 },
                     SeqBlock { tokens: &t1, cache: &mut c1 }];
            m.forward_block(None, &mut blocks, 4, mode, None)
                .unwrap()
                .unwrap()
        };
        let all = run(LogitsMode::All);
        let last = run(LogitsMode::Last);
        assert_eq!(last.shape(), &[2, 96]);
        assert_eq!(last.row(0), all.row(2), "seq 0 last-token logits");
        assert_eq!(last.row(1), all.row(4), "seq 1 last-token logits");
    }

    #[test]
    fn forward_block_rejects_bad_inputs() {
        let m = InferModel::synthetic(&tiny_cfg(), 5);
        // Empty batch.
        let mut none: Vec<SeqBlock> = Vec::new();
        assert!(m.forward_block(None, &mut none, 4, LogitsMode::All, None)
                .is_err());
        // Empty per-sequence block.
        let mut c = m.new_cache(4);
        let empty: [i32; 0] = [];
        let mut blocks = vec![SeqBlock { tokens: &empty, cache: &mut c }];
        assert!(m.forward_block(None, &mut blocks, 4, LogitsMode::All, None)
                .is_err());
        // Out-of-vocab token (vocab is 96) and negative token.
        for bad in [96i32, 1000, -1] {
            let toks = [bad];
            let mut c = m.new_cache(4);
            let mut blocks = vec![SeqBlock { tokens: &toks, cache: &mut c }];
            let err = m
                .forward_block(None, &mut blocks, 4, LogitsMode::All, None)
                .unwrap_err();
            assert!(format!("{err}").contains("vocab"), "{err}");
            // The rejected block never touched the cache.
            assert_eq!(c.n_tokens(), 0);
        }
    }

    #[test]
    fn decode_step_errs_instead_of_panicking() {
        let m = InferModel::synthetic(&tiny_cfg(), 5);
        // Empty batch.
        let mut no_caches: Vec<&mut SeqKv> = Vec::new();
        assert!(m.decode_step(None, &[], &mut no_caches, 4, true).is_err());
        // Out-of-vocab token through the step API.
        let mut c = m.new_cache(4);
        let mut refs = vec![&mut c];
        assert!(m.decode_step(None, &[1234], &mut refs, 4, true).is_err());
        // Length mismatch.
        let mut c2 = m.new_cache(4);
        let mut refs = vec![&mut c2];
        assert!(m.decode_step(None, &[1, 2], &mut refs, 4, true).is_err());
    }

    #[test]
    fn kurt_probe_collects_both_taps_per_layer() {
        let m = InferModel::synthetic(&tiny_cfg(), 5);
        let mut probe = KurtProbe::new(m.cfg.n_layers);
        let mut c = m.new_cache(16);
        let toks = [1i32, 2, 3, 4];
        let mut blocks = vec![SeqBlock { tokens: &toks, cache: &mut c }];
        m.forward_block(None, &mut blocks, 16, LogitsMode::None,
                        Some(&mut probe))
            .unwrap();
        let kurt = probe.kurt();
        assert_eq!(kurt.len(), 2 * m.cfg.n_layers);
        assert!(kurt.iter().all(|v| v.is_finite()), "{kurt:?}");
    }

    /// The §14 model-layer invariant: swapping the trunk for remote
    /// handles over an in-process shard pool changes no logits bit,
    /// for any worker count.
    #[test]
    fn sharded_trunk_matches_local_forward_bitwise() {
        for shards in [1usize, 2, 4] {
            let mut m = InferModel::synthetic(&tiny_cfg(), 9)
                .quantized(4)
                .with_int_mode(IntMode::Scalar);
            let run = |m: &InferModel| -> Vec<f32> {
                let mut c = m.new_cache(4);
                let mut out = Vec::new();
                for t in [1i32, 5, 9, 2] {
                    let mut refs = vec![&mut c];
                    let logits = m
                        .forward_step_refs(None, &[t], &mut refs, 4)
                        .unwrap();
                    out.extend_from_slice(logits.data());
                }
                out
            };
            let want = run(&m);
            let sets = m.extract_shard_sets(shards).unwrap();
            assert_eq!(sets.len(), shards);
            assert_eq!(sets[0].len(), 7 * m.cfg.n_layers + 1);
            let pool = Arc::new(remote::LocalShards::new(
                sets, Backend::Scalar));
            m.shard_remote(pool).unwrap();
            assert_eq!(m.remote_workers(), shards);
            assert_eq!(want, run(&m), "x{shards} shards");
        }
    }

    #[test]
    fn shard_extraction_rejects_dense_and_oversplit() {
        let dense = InferModel::synthetic(&tiny_cfg(), 9);
        assert!(dense.extract_shard_sets(2).is_err());
        let q = InferModel::synthetic(&tiny_cfg(), 9).quantized(4);
        assert!(q.extract_shard_sets(0).is_err());
        // d_model is 32: 64 shards cannot split the wo contraction.
        assert!(q.extract_shard_sets(64).is_err());
        assert!(q.extract_shard_sets(2).is_ok());
    }

    #[test]
    fn sharded_weight_bytes_shrink_on_the_coordinator() {
        let mut m = InferModel::synthetic(&tiny_cfg(), 9).quantized(4);
        let full = m.weight_bytes();
        let bits = m.weight_bits();
        let sets = m.extract_shard_sets(2).unwrap();
        let pool = Arc::new(remote::LocalShards::new(
            sets, Backend::Scalar));
        m.shard_remote(pool).unwrap();
        // Trunk codes moved to the workers; the coordinator keeps the
        // embed/norm leaves and the Row-op rescale vectors.
        assert!(m.weight_bytes() < full, "{} !< {full}",
                m.weight_bytes());
        // The W label survives the swap (stats plumbing).
        assert_eq!(m.weight_bits(), bits);
    }

    #[test]
    fn from_qparams_rejects_bad_counts() {
        // 5 leaves cannot be 1 embed + 9k layer leaves + 2 tail.
        let dense: Vec<Tensor> = vec![Tensor::zeros(&[4, 4]); 5];
        assert!(InferModel::from_dense_params("rmsnorm_plain", &dense, 2,
                                              1e4)
                .is_err());
    }
}
