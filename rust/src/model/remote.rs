//! Row-parallel remote linears (DESIGN.md §14): the model-side half of
//! multi-process sharded serving.
//!
//! A sharded deployment splits every *trunk* linear of an
//! [`super::InferModel`] across N workers along the dimension that
//! keeps the integer kernels exact:
//!
//! * **Column shards** ([`ShardKind::Col`]) — wq/wk/wv/w_gate/w_up and
//!   the unembed split along *output* channels. Every worker sees the
//!   full activation row, runs the same ascending-k i8×i8→i32 dot
//!   products as the unsharded kernel, and rescales its own columns
//!   with the per-channel scales that traveled with them. The
//!   coordinator just concatenates the f32 stripes — bit-identical
//!   because each output element is computed by exactly one worker,
//!   with the unsharded arithmetic.
//! * **Row shards** ([`ShardKind::Row`]) — the reduction weights
//!   (wo/w_down) split along the *contraction* dimension. Here an
//!   output element needs contributions from every worker, and f32
//!   partial sums would not be associative. So workers return their
//!   *exact i32* partials (no scales applied), the coordinator sums
//!   them in i32 — integer addition is exactly associative — and then
//!   applies the single `act_scale * weight_scale` rescale of
//!   [`crate::tensor::qtensor::QTensor::qmatmul_rhs_int_with`]. One
//!   float rounding happens per element, same as single-process.
//!
//! This is why sharded serving *requires* the §11 integer path
//! (`a_bits <= 8`, int mode on): the f32 kernels have no exact
//! cross-process partial. The serve layer validates that at spawn.
//!
//! Transport stays out of this module: [`ShardCompute`] is the small
//! sync interface the coordinator drives, [`LocalShards`] is the
//! in-process implementation the property tests pin recombination
//! with, and `serve::worker::HttpShardPool` implements the same trait
//! over the std-only HTTP layer.

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::tensor::intkern::{Backend, QuantActs};
use crate::tensor::qtensor::QTensor;
use crate::tensor::Tensor;

/// Which dimension of a `[in, out]` weight a shard slices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardKind {
    /// Output-column slice: self-contained (scales travel along),
    /// recombined by stripe concatenation.
    Col,
    /// Contraction-row slice: recombined by exact i32 partial-sum
    /// reduction, rescaled once by the coordinator.
    Row,
}

impl ShardKind {
    /// Stable wire/disk tag (shard artifacts, worker protocol).
    pub fn tag(self) -> u8 {
        match self {
            ShardKind::Col => 0,
            ShardKind::Row => 1,
        }
    }

    pub fn from_tag(tag: u8) -> Result<ShardKind, String> {
        match tag {
            0 => Ok(ShardKind::Col),
            1 => Ok(ShardKind::Row),
            other => Err(format!("unknown shard kind tag {other}")),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ShardKind::Col => "col",
            ShardKind::Row => "row",
        }
    }
}

/// One sharded weight as a worker holds it: the op name the
/// coordinator routes by, the slice geometry, and the packed piece.
pub struct ShardEntry {
    /// Routing key, e.g. `"L0.wq"` / `"L3.w_down"` / `"unembed"` —
    /// identical in `InferModel::extract_shard_sets` and the worker's
    /// lookup, so there is no separate schema to keep in sync.
    pub name: String,
    pub kind: ShardKind,
    /// Contraction depth of the *full* weight (shape\[0\]).
    pub full_k: usize,
    /// Output width of the full weight (shape\[1\]).
    pub full_n: usize,
    /// This shard's offset along the split dimension (`j0` for Col,
    /// `k0` for Row).
    pub off: usize,
    pub q: QTensor,
}

/// Everything one worker serves: its slice of every trunk linear.
pub type ShardSet = Vec<ShardEntry>;

/// Balanced split `[start, end)` of dimension `n` for worker `w` of
/// `shards`: the one partition function shared by shard extraction,
/// the coordinator's stripe/slice routing, and the workers — all
/// three must agree or recombination scrambles.
pub fn shard_range(n: usize, shards: usize, w: usize) -> (usize, usize) {
    ((n * w) / shards, (n * (w + 1)) / shards)
}

/// The k-window `[k0, k1)` of every activation row: row-parallel ops
/// feed each worker only the contraction slice its shard covers, so
/// the wire carries `m * (k1 - k0)` codes instead of `m * k`.
pub fn slice_acts(acts: &QuantActs, k0: usize, k1: usize) -> QuantActs {
    let (m, kw) = (acts.m(), k1 - k0);
    let mut codes = Vec::with_capacity(m * kw);
    for r in 0..m {
        codes.extend_from_slice(&acts.row_codes(r)[k0..k1]);
    }
    let scales: Vec<f32> = (0..m).map(|r| acts.scale(r)).collect();
    QuantActs::from_parts(codes, scales, m, kw)
}

/// What the coordinator needs from a worker fleet. Implementations
/// own fan-out, transport, and retries; the contract is only that the
/// returned numbers are the exact int-kernel results (any backend —
/// Scalar/AVX2/NEON are pinned bit-identical, so a heterogeneous
/// fleet is fine).
pub trait ShardCompute: Send + Sync {
    /// Number of *partitions* the weights were cut into — the stripe /
    /// slice count of every call below. With replication (DESIGN.md
    /// §15) the physical fleet may be larger; replicas are an
    /// implementation detail behind this trait, invisible here because
    /// any replica of a shard returns bit-identical integer results.
    fn n_workers(&self) -> usize;

    /// Column-parallel `op`: worker `w` runs the full-width `acts`
    /// against its column slice and returns its `[m, jw(w)]` row-major
    /// f32 stripe (already rescaled). Stripes ascend by worker index.
    fn col_stripes(&self, op: &str, acts: &QuantActs)
                   -> Result<Vec<Vec<f32>>>;

    /// Row-parallel `op`: worker `w` consumes `slices[w]` (its
    /// k-window of the activations) and returns its exact `[m, n]` i32
    /// partial accumulator — no scales applied. Partials ascend by
    /// worker index.
    fn row_partials(&self, op: &str, slices: &[QuantActs])
                    -> Result<Vec<Vec<i32>>>;
}

/// A trunk linear whose weights live on remote workers. Holds only
/// what the coordinator-side recombination needs: the full logical
/// shape, the split kind, and (for Row ops) the full per-output-column
/// scale vector for the post-sum rescale.
pub struct RemoteLinear {
    op: String,
    shape: [usize; 2],
    bits: u32,
    kind: ShardKind,
    /// Full `[n]` scales for Row ops (the single rescale after the i32
    /// reduction); empty for Col ops, whose scales live on the workers.
    scales: Vec<f32>,
    pool: Arc<dyn ShardCompute>,
}

impl RemoteLinear {
    pub fn new(op: String, shape: [usize; 2], bits: u32, kind: ShardKind,
               scales: Vec<f32>, pool: Arc<dyn ShardCompute>)
               -> RemoteLinear {
        if kind == ShardKind::Row {
            assert_eq!(scales.len(), shape[1],
                       "row-parallel '{op}' needs one scale per output \
                        column for the post-sum rescale");
        }
        RemoteLinear { op, shape, bits, kind, scales, pool }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn op(&self) -> &str {
        &self.op
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    pub fn workers(&self) -> usize {
        self.pool.n_workers()
    }

    /// Coordinator-side bytes this leaf still holds (the Row-op scale
    /// vector); the codes live on the workers.
    pub fn local_bytes(&self) -> usize {
        4 * self.scales.len()
    }

    /// C = A @ deq(W) across the worker fleet, bit-identical to
    /// [`QTensor::qmatmul_rhs_int_with`] on the unsharded weight (see
    /// module docs for why). Transport failures and mis-sized worker
    /// replies return `Err` — never wrong tokens — and propagate
    /// through the model's `Result` forward to the serve loop's
    /// step-error boundary, which fails the affected requests and
    /// keeps serving (DESIGN.md §15).
    pub fn matmul_int(&self, acts: &QuantActs) -> Result<Tensor> {
        let (m, k) = (acts.m(), acts.k());
        let [kk, n] = self.shape;
        ensure!(k == kk, "remote {} [{m}, {k}] @ {:?}", self.op,
                self.shape);
        let nw = self.pool.n_workers();
        let mut c = Tensor::zeros(&[m, n]);
        match self.kind {
            ShardKind::Col => {
                let stripes = self.pool.col_stripes(&self.op, acts)
                    .with_context(|| format!(
                        "remote {} col stripes", self.op))?;
                ensure!(stripes.len() == nw,
                        "remote {}: {} stripes for {nw} shards",
                        self.op, stripes.len());
                let cd = c.data_mut();
                for (w, stripe) in stripes.iter().enumerate() {
                    let (j0, j1) = shard_range(n, nw, w);
                    let jw = j1 - j0;
                    ensure!(stripe.len() == m * jw,
                            "remote {} shard {w}: stripe has {} \
                             elements, want {}", self.op,
                            stripe.len(), m * jw);
                    for r in 0..m {
                        cd[r * n + j0..r * n + j1].copy_from_slice(
                            &stripe[r * jw..(r + 1) * jw]);
                    }
                }
            }
            ShardKind::Row => {
                let slices: Vec<QuantActs> = (0..nw)
                    .map(|w| {
                        let (k0, k1) = shard_range(k, nw, w);
                        slice_acts(acts, k0, k1)
                    })
                    .collect();
                let partials = self.pool.row_partials(&self.op, &slices)
                    .with_context(|| format!(
                        "remote {} row partials", self.op))?;
                ensure!(partials.len() == nw,
                        "remote {}: {} partials for {nw} shards",
                        self.op, partials.len());
                // Exact integer reduction (ascending worker index for
                // definiteness, though i32 sums are order-free), then
                // the one rescale the unsharded kernel applies.
                let mut acc = vec![0i32; m * n];
                for (w, part) in partials.iter().enumerate() {
                    ensure!(part.len() == m * n,
                            "remote {} shard {w}: partial has {} \
                             elements, want {}", self.op, part.len(),
                            m * n);
                    for (a, p) in acc.iter_mut().zip(part) {
                        *a += p;
                    }
                }
                let cd = c.data_mut();
                for r in 0..m {
                    let sa = acts.scale(r);
                    let arow = &acc[r * n..(r + 1) * n];
                    let crow = &mut cd[r * n..(r + 1) * n];
                    for ((cv, &av), &sw) in
                        crow.iter_mut().zip(arow).zip(&self.scales)
                    {
                        *cv = av as f32 * (sa * sw);
                    }
                }
            }
        }
        Ok(c)
    }
}

/// In-process [`ShardCompute`] over extracted shard sets: the pure
/// recombination path — no HTTP, no storage — that the property tests
/// pin sharded-vs-single-process bit-parity with, and a useful
/// harness for anything that wants "sharded math, one process".
pub struct LocalShards {
    sets: Vec<ShardSet>,
    backend: Backend,
}

impl LocalShards {
    pub fn new(sets: Vec<ShardSet>, backend: Backend) -> LocalShards {
        LocalShards { sets, backend }
    }

    fn entry(&self, w: usize, op: &str) -> &ShardEntry {
        self.sets[w]
            .iter()
            .find(|e| e.name == op)
            .unwrap_or_else(|| panic!("worker {w} has no shard for '{op}'"))
    }
}

impl ShardCompute for LocalShards {
    fn n_workers(&self) -> usize {
        self.sets.len()
    }

    fn col_stripes(&self, op: &str, acts: &QuantActs)
                   -> Result<Vec<Vec<f32>>> {
        Ok((0..self.sets.len())
            .map(|w| {
                let e = self.entry(w, op);
                e.q.qmatmul_rhs_int_with(None, acts, self.backend)
                    .data()
                    .to_vec()
            })
            .collect())
    }

    fn row_partials(&self, op: &str, slices: &[QuantActs])
                    -> Result<Vec<Vec<i32>>> {
        Ok(slices
            .iter()
            .enumerate()
            .map(|(w, sacts)| {
                let e = self.entry(w, op);
                let mut acc = vec![0i32; sacts.m() * e.q.cols()];
                e.q.accumulate_int(sacts, self.backend, &mut acc);
                acc
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::quantize_per_channel_q;
    use crate::util::rng::Pcg;

    fn random_acts(rng: &mut Pcg, m: usize, k: usize) -> QuantActs {
        let codes: Vec<i8> = (0..m * k)
            .map(|_| (rng.below(16) as i64 - 8) as i8)
            .collect();
        let scales: Vec<f32> =
            (0..m).map(|r| 0.05 + 0.01 * r as f32).collect();
        QuantActs::from_parts(codes, scales, m, k)
    }

    fn random_q(rng: &mut Pcg, k: usize, n: usize) -> QTensor {
        let mut t = Tensor::zeros(&[k, n]);
        rng.fill_normal(t.data_mut(), 0.1);
        quantize_per_channel_q(&t, 4)
    }

    fn shard_q(q: &QTensor, name: &str, kind: ShardKind, shards: usize)
               -> Vec<ShardSet> {
        let (k, n) = (q.rows(), q.cols());
        let dim = match kind {
            ShardKind::Col => n,
            ShardKind::Row => k,
        };
        (0..shards)
            .map(|w| {
                let (a, b) = shard_range(dim, shards, w);
                let piece = match kind {
                    ShardKind::Col => q.shard_cols(a, b),
                    ShardKind::Row => q.shard_rows(a, b),
                };
                vec![ShardEntry { name: name.into(), kind, full_k: k,
                                  full_n: n, off: a, q: piece }]
            })
            .collect()
    }

    #[test]
    fn shard_range_is_a_partition() {
        for n in [1usize, 7, 64, 353] {
            for shards in [1usize, 2, 3, 4, 7] {
                let mut covered = 0usize;
                for w in 0..shards {
                    let (a, b) = shard_range(n, shards, w);
                    assert_eq!(a, covered, "gap at worker {w}");
                    assert!(b >= a);
                    covered = b;
                }
                assert_eq!(covered, n, "{n} over {shards}");
            }
        }
    }

    #[test]
    fn remote_col_linear_matches_unsharded_kernel_bitwise() {
        let mut rng = Pcg::new(31, 0);
        let (m, k, n) = (3, 20, 17);
        let q = random_q(&mut rng, k, n);
        let acts = random_acts(&mut rng, m, k);
        let be = Backend::Scalar;
        let want = q.qmatmul_rhs_int_with(None, &acts, be);
        for shards in [1usize, 2, 4] {
            let pool: Arc<dyn ShardCompute> = Arc::new(LocalShards::new(
                shard_q(&q, "op", ShardKind::Col, shards), be));
            let r = RemoteLinear::new("op".into(), [k, n], 4,
                                      ShardKind::Col, Vec::new(), pool);
            assert_eq!(want.data(),
                       r.matmul_int(&acts).unwrap().data(),
                       "x{shards}");
        }
    }

    #[test]
    fn remote_row_linear_matches_unsharded_kernel_bitwise() {
        let mut rng = Pcg::new(32, 0);
        let (m, k, n) = (2, 21, 10);
        let q = random_q(&mut rng, k, n);
        let acts = random_acts(&mut rng, m, k);
        let be = Backend::Scalar;
        let want = q.qmatmul_rhs_int_with(None, &acts, be);
        for shards in [1usize, 2, 3] {
            let pool: Arc<dyn ShardCompute> = Arc::new(LocalShards::new(
                shard_q(&q, "op", ShardKind::Row, shards), be));
            let r = RemoteLinear::new("op".into(), [k, n], 4,
                                      ShardKind::Row,
                                      q.scales().to_vec(), pool);
            assert_eq!(want.data(),
                       r.matmul_int(&acts).unwrap().data(),
                       "x{shards}");
        }
    }

    #[test]
    fn slice_acts_windows_codes_and_keeps_scales() {
        let mut rng = Pcg::new(33, 0);
        let acts = random_acts(&mut rng, 3, 12);
        let s = slice_acts(&acts, 4, 9);
        assert_eq!((s.m(), s.k()), (3, 5));
        for r in 0..3 {
            assert_eq!(s.row_codes(r), &acts.row_codes(r)[4..9]);
            assert_eq!(s.scale(r), acts.scale(r));
        }
    }

    #[test]
    fn shard_kind_tags_roundtrip() {
        for kind in [ShardKind::Col, ShardKind::Row] {
            assert_eq!(ShardKind::from_tag(kind.tag()).unwrap(), kind);
        }
        assert!(ShardKind::from_tag(7).is_err());
    }
}
