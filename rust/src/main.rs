//! `osp` — the Outlier-Safe Pre-Training coordinator CLI.
//!
//! Subcommands:
//!   train      train one configuration (fused / DP / disaggregated)
//!   ablation   train the full Table-2 ablation grid
//!   repro      regenerate a paper table or figure from recorded runs
//!   suite      run the 10-task benchmark suite on a checkpoint
//!   quantize   apply a PTQ recipe to a checkpoint and report perplexity
//!   analyze    attention-sink / massive-activation analysis (§5.2)
//!
//! Everything is manifest-driven; run `make artifacts` first.

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use osp::checkpoint;
use osp::config::{TrainConfig, ABLATION_GRID};
use osp::coordinator::Trainer;
use osp::eval::{perplexity, perplexity_packed, tasks};
use osp::quant::{self, PtqConfig, Rotation, WeightMethod};
use osp::repro::{self, Effort};
use osp::runtime::Engine;
use osp::util::cli::Args;

const HELP: &str = "\
osp — Outlier-Safe Pre-Training coordinator (Park et al., ACL 2025 repro)

USAGE: osp <subcommand> [flags]

  train      --optimizer adam|muon|muon_noadam|shampoo|soap
             --arch rmsnorm_plain|ssnorm_plain|rmsnorm_embproj|ssnorm_embproj
             --steps N --lr F --seed N --run-dir DIR
             --dp-ranks N --grad-accum N --disaggregated true
             --ckpt-every N --eval-every N
  ablation   --steps N --runs-dir DIR          train all 6 Table-2 configs
  repro      table2|table3|table4|table5|fig1|fig2|fig3|fig4|
             fig5_6|fig7|fig8_11  [--runs-dir DIR] [--full]
  suite      --ckpt DIR [--a-bits N --kv-bits N]
  quantize   --ckpt DIR [--w-bits N] [--method rtn|gptq]
             [--rotation none|random|learned] [--ffn-had true]
             [--save-packed FILE]   persist the packed-code model (~8x
                                    smaller at W4), or
             --packed FILE          evaluate a previously saved one
  analyze    [--runs-dir DIR] [--tags adam,osp]

  common     --artifacts DIR (default: artifacts)
";

fn engine_from(args: &Args) -> Result<Engine> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    Engine::open(&dir)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args);
    let engine = engine_from(args)?;
    let mut trainer = Trainer::new(engine, cfg)?;
    let summary = trainer.run()?;
    println!(
        "done: steps={} final_loss={:.4} final_ppl={:.2} kurt_max={:.2} \
         tok/s={:.0} wall={:.1}s",
        summary.steps, summary.final_loss, summary.final_ppl,
        summary.final_kurt_max, summary.tokens_per_sec, summary.wall_secs);
    for (phase, n, secs) in trainer.profiler.report() {
        println!("  [profile] {phase:12} x{n:<6} {secs:8.2}s");
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    let steps = args.u64_or("steps", 300);
    let runs_dir = PathBuf::from(args.str_or("runs-dir", "runs"));
    for (tag, optimizer, arch) in ABLATION_GRID {
        let run_dir = runs_dir.join(tag);
        if !checkpoint::list_steps(&run_dir).is_empty()
            && !args.bool_or("force", false)
        {
            println!("[{tag}] already trained (use --force to redo)");
            continue;
        }
        println!("=== training {tag} ({optimizer} @ {arch}) ===");
        let mut targs = vec![
            "--optimizer".to_string(), optimizer.to_string(),
            "--arch".to_string(), arch.to_string(),
            "--steps".to_string(), steps.to_string(),
            "--run-dir".to_string(), run_dir.to_string_lossy().into_owned(),
            "--ckpt-every".to_string(),
            (steps / 3).max(1).to_string(),
        ];
        if let Some(lr) = args.get("lr") {
            targs.push("--lr".into());
            targs.push(lr.to_string());
        }
        let cfg = TrainConfig::from_args(&Args::parse(&targs, false));
        let mut trainer = Trainer::new(engine.clone(), cfg)?;
        let s = trainer.run()?;
        println!(
            "[{tag}] loss={:.4} ppl={:.2} kurt_max={:.2} tok/s={:.0}",
            s.final_loss, s.final_ppl, s.final_kurt_max, s.tokens_per_sec);
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("repro needs a table/figure id"))?
        .clone();
    let engine = engine_from(args)?;
    let runs_dir = PathBuf::from(args.str_or("runs-dir", "runs"));
    let effort = if args.bool_or("full", false) {
        Effort::FULL
    } else {
        Effort::QUICK
    };
    let all = repro::ablation_tags();
    match what.as_str() {
        "table2" => repro::table2(&engine, &runs_dir, effort)?.print(),
        "table3" => repro::table3(&engine, &runs_dir, effort)?.print(),
        "table4" => repro::table4(&engine, &runs_dir, effort)?.print(),
        "table5" => repro::table5(&engine, &runs_dir, effort)?.print(),
        "fig1" => repro::fig1(&engine, &runs_dir, effort)?.print(),
        "fig2" | "fig8_11" => {
            println!("{}", repro::fig2(&engine, &runs_dir, &all)?);
            println!("{}", repro::fig1011(&engine, &runs_dir,
                                          &["adam", "osp"])?);
        }
        "fig3" => println!("{}", repro::fig3(&runs_dir, &all)?),
        "fig7" => println!("{}", repro::fig3(&runs_dir, &["adam", "osp"])?),
        "fig4" => repro::fig4(&engine, &runs_dir,
                              &["adam", "muon", "osp"], effort)?.print(),
        "fig5_6" => println!("{}", repro::fig56(&engine, &runs_dir,
                                                &["adam", "osp"])?),
        "table1" => bail!("table1 is a bench: \
                           cargo bench --bench table1_optimizers"),
        other => bail!("unknown repro target '{other}'"),
    }
    Ok(())
}

fn cmd_suite(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    let ckpt = PathBuf::from(
        args.get("ckpt").ok_or_else(|| anyhow!("--ckpt required"))?);
    let ck = checkpoint::load(&ckpt)?;
    let a = args.usize_or("a-bits", 16) as u32;
    let kv = args.usize_or("kv-bits", 16) as u32;
    let (rows, avg) = tasks::run_suite(&engine, &ck.arch, &ck.params, 24,
                                       a, kv, 0.0, 99)?;
    for (task, acc) in rows {
        println!("{task:16} {:.1}", 100.0 * acc);
    }
    println!("{:16} {:.1}", "AVERAGE", 100.0 * avg);
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    if let Some(packed) = args.get("packed") {
        // Evaluate a packed-code model straight from disk: no f32
        // checkpoint, no re-quantization.
        let qm = checkpoint::load_packed(&PathBuf::from(packed))?;
        let a = args.usize_or("a-bits", 4) as u32;
        let kv = args.usize_or("kv-bits", 4) as u32;
        let q = perplexity_packed(&engine, &qm, a, kv, 2)?;
        println!(
            "packed model {packed} ({} KiB packed, {:.2}x of dense): \
             ppl {:.2} @ A{a}-KV{kv}",
            qm.packed_bytes() / 1024,
            qm.packed_bytes() as f64 / qm.dense_bytes().max(1) as f64,
            q.ppl);
        return Ok(());
    }
    let ckpt = PathBuf::from(
        args.get("ckpt").ok_or_else(|| anyhow!("--ckpt required"))?);
    let ck = checkpoint::load(&ckpt)?;
    let cfg = PtqConfig {
        w_bits: args.usize_or("w-bits", 4) as u32,
        method: match args.str_or("method", "rtn").as_str() {
            "gptq" => WeightMethod::Gptq,
            _ => WeightMethod::Rtn,
        },
        rotation: match args.str_or("rotation", "none").as_str() {
            "random" => Rotation::Random,
            "learned" => Rotation::Learned,
            _ => Rotation::None,
        },
        ffn_had: args.bool_or("ffn-had", false),
        seed: args.u64_or("seed", 7),
        calib_batches: args.usize_or("calib-batches", 2),
    };
    let qm = quant::prepare(&engine, &ck.arch, &ck.params, &cfg)?;
    if let Some(out) = args.get("save-packed") {
        checkpoint::save_packed(&PathBuf::from(out), &qm)?;
        println!(
            "saved packed model to {out}: {} KiB vs {} KiB dense ({:.2}x)",
            qm.packed_bytes() / 1024, qm.dense_bytes() / 1024,
            qm.packed_bytes() as f64 / qm.dense_bytes().max(1) as f64);
    }
    let a = args.usize_or("a-bits", 4) as u32;
    let kv = args.usize_or("kv-bits", 4) as u32;
    let fp = perplexity(&engine, &ck.arch, &ck.params, 16, 16, 0.0, 2)?;
    let q = perplexity(&engine, &qm.arch, qm.dense_params(), a, kv,
                       qm.had_flag, 2)?;
    println!("{}: fp16 ppl {:.2} -> quantized ppl {:.2} (kurt_max {:.2})",
             cfg.label(), fp.ppl, q.ppl, fp.kurt_max);
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    let runs_dir = PathBuf::from(args.str_or("runs-dir", "runs"));
    let tags = args.list_or("tags", &["adam", "osp"]);
    let tag_refs: Vec<&str> = tags.iter().map(|s| s.as_str()).collect();
    println!("{}", repro::fig56(&engine, &runs_dir, &tag_refs)?);
    Ok(())
}

fn main() {
    let args = Args::from_env(true);
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("repro") => cmd_repro(&args),
        Some("suite") => cmd_suite(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}'\n{HELP}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
