//! `osp` — the Outlier-Safe Pre-Training coordinator CLI.
//!
//! Subcommands:
//!   train      train one configuration (fused / DP / disaggregated)
//!   ablation   train the full Table-2 ablation grid
//!   repro      regenerate a paper table or figure from recorded runs
//!   suite      run the 10-task benchmark suite on a checkpoint
//!   quantize   apply a PTQ recipe to a checkpoint and report perplexity
//!   eval       engine-free host evaluation straight off packed weights
//!   generate   autoregressive decode on the host model layer
//!   serve      streaming HTTP front-end on the decode engine
//!   shard      partition a packed model into per-worker artifacts
//!   worker     row-parallel shard worker for a sharded serve
//!   serve-load chaos-capable load generator against a running serve
//!   chaos-proxy  fleet-fault TCP proxy in front of one shard worker
//!   serve-bench  decode + chunked-prefill throughput sweeps
//!   bench-diff  per-row speedup diff of two bench JSON artifacts
//!   simd-info  detected CPU features + integer-kernel backend
//!   analyze    attention-sink / massive-activation analysis (§5.2)
//!
//! Training/repro paths are manifest-driven (`make artifacts` first);
//! `eval`, `generate`, and `serve-bench` also run fully offline
//! (`--synthetic`, or `--packed` with explicit `--n-heads`).

use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use osp::bench::{diff as bench_diff, Table};
use osp::checkpoint;
use osp::config::{TrainConfig, ABLATION_GRID};
use osp::coordinator::Trainer;
use osp::data::grammar::{Grammar, LANGUAGE_SEED};
use osp::eval::{host, perplexity, perplexity_packed, tasks, BitConfig,
                HostEvalOpts};
use osp::infer::{engine as decode, DecodeEngine, DecodeParams, GenRequest,
                 InferConfig, InferModel};
use osp::quant::{self, PtqConfig, Rotation, WeightMethod};
use osp::repro::{self, Effort};
use osp::runtime::{Engine, Manifest};
use osp::serve::chaos::ChaosSpec;
use osp::serve::load::{self as serve_load, LoadOpts};
use osp::serve::worker::{ShardSource, WorkerOpts, WorkerServer};
use osp::serve::{ServeOpts, Server};
use osp::tensor::{intkern, par};
use osp::util::cli::Args;
use osp::util::json::Json;

const HELP: &str = "\
osp — Outlier-Safe Pre-Training coordinator (Park et al., ACL 2025 repro)

USAGE: osp <subcommand> [flags]

  train      --optimizer adam|muon|muon_noadam|shampoo|soap
             --arch rmsnorm_plain|ssnorm_plain|rmsnorm_embproj|ssnorm_embproj
             --steps N --lr F --seed N --run-dir DIR
             --dp-ranks N --grad-accum N --disaggregated true
             --ckpt-every N --eval-every N
  ablation   --steps N --runs-dir DIR          train all 6 Table-2 configs
  repro      table2|table3|table4|table5|fig1|fig2|fig3|fig4|
             fig5_6|fig7|fig8_11  [--runs-dir DIR] [--full]
  suite      --ckpt DIR [--a-bits N --kv-bits N]
  quantize   --ckpt DIR [--w-bits N] [--method rtn|gptq]
             [--rotation none|random|learned] [--ffn-had true]
             [--save-packed FILE]   persist the packed-code model (~8x
                                    smaller at W4), or
             --packed FILE          evaluate a previously saved one
  eval       engine-free held-out perplexity + task suite, teacher-forced
             on the host model layer straight off packed weights (works
             offline — no compiled artifacts needed)
             --packed FILE [--n-heads N --rope-theta F] |
             --ckpt DIR [--w-bits N] | --synthetic [--arch A]
             [--a-bits N] [--kv-bits N] [--batches N] [--batch N]
             [--seq-len N] [--eval-chunk N] [--suite false]
             [--n-per-task N]
  generate   autoregressive decode straight off packed weights
             --packed FILE [--n-heads N --rope-theta F] |
             --ckpt DIR [--w-bits N] | --synthetic [--arch A]
             [--prompt \"1 2 3\"] [--prompts N --prompt-len N]
             [--max-new N] [--a-bits N] [--kv-bits N] [--max-batch N]
             [--prefill-chunk N]    prompt tokens per sequence per step
                                    (default 64; 1 = token-at-a-time)
             [--temperature F] [--top-k N] [--top-p F] [--seed N]
             [--kv-page-rows N]     rows per KV page (default 64; any
                                    value is bit-identical)
             [--kv-pool-mb N]       soft KV pool budget (0 = unbounded)
             [--share-prefix on|off]  copy-on-write prompt-prefix
                                    sharing across requests (default
                                    off here, on for serve)
             [--int off|scalar|auto]  integer i8xi8 kernels for the
                                    packed linears when A-bits <= 8
                                    (default $OSP_INT else auto; auto
                                    picks AVX2/NEON when the CPU has
                                    it, OSP_SIMD=off forces scalar)
             [--check true]         verify bit-parity: SIMD vs scalar
                                    integer streams (when --int is
                                    active), then packed f32 vs the
                                    dense-f32 twin
  serve      streaming HTTP/1.1 front-end on the decode engine:
             POST /generate (chunked NDJSON token stream), GET /metrics,
             GET /healthz, POST /admin/drain (graceful shutdown)
             --packed FILE | --ckpt DIR | --synthetic  (as generate)
             [--addr HOST:PORT]      default 127.0.0.1:8080 (port 0 =
                                     ephemeral, printed at startup)
             [--max-batch N] [--queue-cap N]  admission bound; overflow
                                     is rejected 503 + Retry-After
             [--a-bits N] [--kv-bits N] [--prefill-chunk N] [--seed N]
             [--temperature F] [--top-k N] [--top-p F]
             [--max-new-cap N] [--timeout-ms N] [--timeout-cap-ms N]
             [--header-timeout-ms N] [--int off|scalar|auto]
             [--kv-page-rows N] [--kv-pool-mb N]  paged KV pool; pool
                                     exhaustion is a retryable 503
             [--share-prefix on|off] store identical prompt prefixes
                                     once across requests (default on)
             [--workers A:P1,A:P2]   row-parallel sharded mode: route
                                     trunk matmuls to these osp worker
                                     processes (token streams stay
                                     bit-identical to single-process;
                                     worker w serves shard
                                     w % n_shards)
             [--shard-dir DIR]       osp shard output served to the
                                     workers over GET /shards/...
             [--replicas N]          shard replication factor: with
                                     N >= 2 live replicas per shard
                                     the fleet survives any single
                                     worker failure mid-decode
             [--probe-interval-ms N] health prober cadence
                                     (default 150)
             [--down-after N]        consecutive failures before a
                                     worker's breaker trips
                                     (default 3)
  shard      partition a packed model into per-worker row/col shard
             artifacts + manifest.json for sharded serving
             --packed FILE | --ckpt DIR | --synthetic  (as generate)
             [--shards N]            fleet size (default 2)
             [--out DIR]             output directory (default shards)
  worker     serve one shard of the trunk for a sharded osp serve:
             POST /matmul, GET /metrics, GET /healthz,
             POST /admin/drain (graceful shutdown)
             --artifact FILE         load a local osp shard artifact, or
             --coordinator HOST:PORT checksummed resumable fetch from
                                     the coordinator's /shards endpoints
             [--shard N] [--n-shards N] [--addr HOST:PORT]
             [--spool FILE]          fetch spool path (resume point,
                                     default shard_N.part)
             [--fetch-budget BYTES]  abort the fetch after this many
                                     wire bytes (testing; rerun resumes)
             [--int scalar|auto]     integer kernels are required here
  serve-load built-in load generator + chaos harness for osp serve
             [--addr HOST:PORT] [--clients N] [--requests N per client]
             [--prompt-len N] [--max-new N] [--timeout-ms N] [--seed N]
             [--prefix-len N]        shared system-prompt tokens
                                     prepended to every request
                                     (exercises --share-prefix)
             [--chaos SPEC]          off|default|[preset,]k=v,... with
                                     keys abort/delay/oversize/malformed/
                                     slowloris/tiny_deadline (probs),
                                     seed/delay_ms/hold_ms, plus fleet
                                     faults worker-kill=k (drop the
                                     proxied worker after k completed
                                     requests, revive hold_ms later)
                                     and worker-stall-ms=t
             [--proxy HOST:PORT]     chaos-proxy control address the
                                     fleet faults are driven through
             [--json [FILE]]         write BENCH_serve.json (diffable
                                     with osp bench-diff)
             [--drain true]          POST /admin/drain afterwards
  chaos-proxy  byte-transparent fault-injection proxy for one worker
             --target HOST:PORT [--listen HOST:PORT (default
             127.0.0.1:0)]; control via POST /chaos/kill,
             /chaos/revive, /chaos/stall?ms=N, GET /chaos/ping
  serve-bench  sustained decode + chunked-prefill throughput on a
             synthetic model across the Table-2 bit configs
             [--batches 1,8,32] [--prompt-len N] [--max-new N]
             [--prefill-chunks 1,16,64] [--prefill-len N]
             [--prefill-batch N] [--int off|scalar|auto]
             [--d-model N --n-layers N --n-heads N --d-ff N --vocab N]
             [--json [FILE]]        write BENCH_infer.json for CI
  bench-diff OLD.json NEW.json     diff two BENCH_quant.json /
             [--threshold F]        BENCH_infer.json artifacts: print
                                    per-row speedups, exit 1 on any
                                    metric more than F slower
                                    (default 0.10 = 10%)
  simd-info  print the detected CPU features and which integer
             microkernel backend (scalar / AVX2 / NEON) will run
  analyze    [--runs-dir DIR] [--tags adam,osp]

  common     --artifacts DIR (default: artifacts)
";

fn engine_from(args: &Args) -> Result<Engine> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    Engine::open(&dir)
}

/// Parse a `--*-bits` flag, rejecting widths without a symmetric
/// integer grid (0/1 bits used to panic or divide-by-zero downstream).
fn bits_arg(args: &Args, key: &str, default: u32) -> Result<u32> {
    let bits = args.usize_or(key, default as usize) as u32;
    osp::coordinator::checked_levels_for_bits(bits)
        .with_context(|| format!("--{key}"))?;
    Ok(bits)
}

/// Parse `--share-prefix on|off` (copy-on-write prompt-prefix sharing,
/// DESIGN.md §13). The library default is off; `osp serve` flips its
/// own default to on, so each caller passes its default in.
fn share_prefix_arg(args: &Args, default: bool) -> Result<bool> {
    let raw = args.str_or("share-prefix",
                          if default { "on" } else { "off" });
    match raw.as_str() {
        "on" | "true" => Ok(true),
        "off" | "false" => Ok(false),
        other => bail!("--share-prefix wants on|off, got {other}"),
    }
}

/// Parse `--int off|scalar|auto` (integer-kernel dispatch for the
/// packed linears). The flag defaults to `$OSP_INT`, else `auto`: the
/// library-level default is `off` so tests keep the exact f32 parity
/// contract, but the CLI opts into the fast path unless told otherwise.
fn int_mode_arg(args: &Args) -> Result<intkern::IntMode> {
    let default = std::env::var("OSP_INT").unwrap_or_else(|_| "auto".into());
    let s = args.str_or("int", &default);
    intkern::IntMode::parse(&s)
        .ok_or_else(|| anyhow!("--int wants off|scalar|auto, got '{s}'"))
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args);
    let engine = engine_from(args)?;
    let mut trainer = Trainer::new(engine, cfg)?;
    let summary = trainer.run()?;
    println!(
        "done: steps={} final_loss={:.4} final_ppl={:.2} kurt_max={:.2} \
         tok/s={:.0} wall={:.1}s",
        summary.steps, summary.final_loss, summary.final_ppl,
        summary.final_kurt_max, summary.tokens_per_sec, summary.wall_secs);
    for (phase, n, secs) in trainer.profiler.report() {
        println!("  [profile] {phase:12} x{n:<6} {secs:8.2}s");
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    let steps = args.u64_or("steps", 300);
    let runs_dir = PathBuf::from(args.str_or("runs-dir", "runs"));
    for (tag, optimizer, arch) in ABLATION_GRID {
        let run_dir = runs_dir.join(tag);
        if !checkpoint::list_steps(&run_dir).is_empty()
            && !args.bool_or("force", false)
        {
            println!("[{tag}] already trained (use --force to redo)");
            continue;
        }
        println!("=== training {tag} ({optimizer} @ {arch}) ===");
        let mut targs = vec![
            "--optimizer".to_string(), optimizer.to_string(),
            "--arch".to_string(), arch.to_string(),
            "--steps".to_string(), steps.to_string(),
            "--run-dir".to_string(), run_dir.to_string_lossy().into_owned(),
            "--ckpt-every".to_string(),
            (steps / 3).max(1).to_string(),
        ];
        if let Some(lr) = args.get("lr") {
            targs.push("--lr".into());
            targs.push(lr.to_string());
        }
        let cfg = TrainConfig::from_args(&Args::parse(&targs, false));
        let mut trainer = Trainer::new(engine.clone(), cfg)?;
        let s = trainer.run()?;
        println!(
            "[{tag}] loss={:.4} ppl={:.2} kurt_max={:.2} tok/s={:.0}",
            s.final_loss, s.final_ppl, s.final_kurt_max, s.tokens_per_sec);
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("repro needs a table/figure id"))?
        .clone();
    let engine = engine_from(args)?;
    let runs_dir = PathBuf::from(args.str_or("runs-dir", "runs"));
    let effort = if args.bool_or("full", false) {
        Effort::FULL
    } else {
        Effort::QUICK
    };
    let all = repro::ablation_tags();
    match what.as_str() {
        "table2" => repro::table2(&engine, &runs_dir, effort)?.print(),
        "table3" => repro::table3(&engine, &runs_dir, effort)?.print(),
        "table4" => repro::table4(&engine, &runs_dir, effort)?.print(),
        "table5" => repro::table5(&engine, &runs_dir, effort)?.print(),
        "fig1" => repro::fig1(&engine, &runs_dir, effort)?.print(),
        "fig2" | "fig8_11" => {
            println!("{}", repro::fig2(&engine, &runs_dir, &all)?);
            println!("{}", repro::fig1011(&engine, &runs_dir,
                                          &["adam", "osp"])?);
        }
        "fig3" => println!("{}", repro::fig3(&runs_dir, &all)?),
        "fig7" => println!("{}", repro::fig3(&runs_dir, &["adam", "osp"])?),
        "fig4" => repro::fig4(&engine, &runs_dir,
                              &["adam", "muon", "osp"], effort)?.print(),
        "fig5_6" => println!("{}", repro::fig56(&engine, &runs_dir,
                                                &["adam", "osp"])?),
        "table1" => bail!("table1 is a bench: \
                           cargo bench --bench table1_optimizers"),
        other => bail!("unknown repro target '{other}'"),
    }
    Ok(())
}

fn cmd_suite(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    let ckpt = PathBuf::from(
        args.get("ckpt").ok_or_else(|| anyhow!("--ckpt required"))?);
    let ck = checkpoint::load(&ckpt)?;
    let a = bits_arg(args, "a-bits", 16)?;
    let kv = bits_arg(args, "kv-bits", 16)?;
    let (rows, avg) = tasks::run_suite(&engine, &ck.arch, &ck.params, 24,
                                       a, kv, 0.0, 99)?;
    for (task, acc) in rows {
        println!("{task:16} {:.1}", 100.0 * acc);
    }
    println!("{:16} {:.1}", "AVERAGE", 100.0 * avg);
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    if let Some(packed) = args.get("packed") {
        // Evaluate a packed-code model straight from disk: no f32
        // checkpoint, no re-quantization.
        let qm = checkpoint::load_packed(&PathBuf::from(packed))?;
        let a = bits_arg(args, "a-bits", 4)?;
        let kv = bits_arg(args, "kv-bits", 4)?;
        let q = perplexity_packed(&engine, &qm, a, kv, 2)?;
        println!(
            "packed model {packed} ({} KiB packed, {:.2}x of dense): \
             ppl {:.2} @ A{a}-KV{kv}",
            qm.packed_bytes() / 1024,
            qm.packed_bytes() as f64 / qm.dense_bytes().max(1) as f64,
            q.ppl);
        return Ok(());
    }
    let ckpt = PathBuf::from(
        args.get("ckpt").ok_or_else(|| anyhow!("--ckpt required"))?);
    let ck = checkpoint::load(&ckpt)?;
    let cfg = PtqConfig {
        w_bits: bits_arg(args, "w-bits", 4)?,
        method: match args.str_or("method", "rtn").as_str() {
            "gptq" => WeightMethod::Gptq,
            _ => WeightMethod::Rtn,
        },
        rotation: match args.str_or("rotation", "none").as_str() {
            "random" => Rotation::Random,
            "learned" => Rotation::Learned,
            _ => Rotation::None,
        },
        ffn_had: args.bool_or("ffn-had", false),
        seed: args.u64_or("seed", 7),
        calib_batches: args.usize_or("calib-batches", 2),
    };
    let qm = quant::prepare(&engine, &ck.arch, &ck.params, &cfg)?;
    if let Some(out) = args.get("save-packed") {
        checkpoint::save_packed(&PathBuf::from(out), &qm)?;
        println!(
            "saved packed model to {out}: {} KiB vs {} KiB dense ({:.2}x)",
            qm.packed_bytes() / 1024, qm.dense_bytes() / 1024,
            qm.packed_bytes() as f64 / qm.dense_bytes().max(1) as f64);
    }
    let a = bits_arg(args, "a-bits", 4)?;
    let kv = bits_arg(args, "kv-bits", 4)?;
    let fp = perplexity(&engine, &ck.arch, &ck.params, 16, 16, 0.0, 2)?;
    let q = perplexity(&engine, &qm.arch, qm.dense_params(), a, kv,
                       qm.had_flag, 2)?;
    println!("{}: fp16 ppl {:.2} -> quantized ppl {:.2} (kurt_max {:.2})",
             cfg.label(), fp.ppl, q.ppl, fp.kurt_max);
    Ok(())
}

/// Explicit token-id prompt ("1 2 3" or "1,2,3"), vocab-checked.
fn parse_prompt(s: &str, vocab: usize) -> Result<Vec<i32>> {
    s.split(|c: char| c == ',' || c.is_whitespace())
        .filter(|t| !t.is_empty())
        .map(|t| {
            let v: i64 = t
                .parse()
                .map_err(|_| anyhow!("--prompt token '{t}' is not an \
                                      integer"))?;
            if v < 0 || v as usize >= vocab {
                bail!("--prompt token {v} outside vocab 0..{vocab}");
            }
            Ok(v as i32)
        })
        .collect()
}

/// Resolve the model `osp generate` decodes: a packed artifact, a dense
/// checkpoint quantized on the fly, or a synthetic demo model (no
/// artifacts needed).
fn generate_model(args: &Args) -> Result<InferModel> {
    let w_bits = bits_arg(args, "w-bits", 4)?;
    if let Some(packed) = args.get("packed") {
        let qm = checkpoint::load_packed(&PathBuf::from(packed))?;
        // The OSPQ file does not record n_heads/rope_theta: take them
        // from an explicit --n-heads (artifact-free use), else from the
        // manifest — cross-checking the scale so a packed model is not
        // silently decoded against the wrong artifact dir's head count.
        if args.has("n-heads") {
            return qm.decoder(args.usize_or("n-heads", 0),
                              args.f64_or("rope-theta", 10000.0) as f32);
        }
        let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
        let m = Manifest::load(&dir).context(
            "--packed needs artifacts/manifest.json for \
             n_heads/rope_theta (or pass --n-heads [--rope-theta])")?;
        let model = qm.decoder(m.model.n_heads,
                               m.model.rope_theta as f32)?;
        if model.cfg.d_model != m.model.d_model
            || model.cfg.vocab_size != m.model.vocab_size
        {
            bail!("packed model is d_model={} vocab={}, but {:?} \
                   describes d_model={} vocab={} — wrong artifact dir \
                   for this model",
                  model.cfg.d_model, model.cfg.vocab_size, dir,
                  m.model.d_model, m.model.vocab_size);
        }
        return Ok(model);
    }
    if let Some(ckpt) = args.get("ckpt") {
        let engine = engine_from(args)?;
        let ck = checkpoint::load(&PathBuf::from(ckpt))?;
        let cfg = PtqConfig {
            w_bits,
            method: match args.str_or("method", "rtn").as_str() {
                "gptq" => WeightMethod::Gptq,
                _ => WeightMethod::Rtn,
            },
            rotation: Rotation::None,
            ffn_had: false,
            seed: args.u64_or("seed", 7),
            calib_batches: args.usize_or("calib-batches", 2),
        };
        let qm = quant::prepare(&engine, &ck.arch, &ck.params, &cfg)?;
        let m = engine.manifest();
        return qm.decoder(m.model.n_heads, m.model.rope_theta as f32);
    }
    if args.bool_or("synthetic", false) {
        let (norm_ss, embproj) =
            InferConfig::arch_knobs(&args.str_or("arch", "ssnorm_plain"))?;
        let cfg = InferConfig {
            vocab_size: args.usize_or("vocab", 512),
            d_model: args.usize_or("d-model", 128),
            n_layers: args.usize_or("n-layers", 4),
            n_heads: args.usize_or("n-heads", 4),
            d_ff: args.usize_or("d-ff", 352),
            rope_theta: 10000.0,
            norm_ss,
            embproj,
        };
        cfg.validate()?;
        let dense = InferModel::synthetic(&cfg, args.u64_or("seed", 7));
        return Ok(dense.quantized(w_bits));
    }
    bail!("generate needs --packed FILE, --ckpt DIR, or --synthetic")
}

fn cmd_generate(args: &Args) -> Result<()> {
    let mut model = generate_model(args)?;
    model.set_int_mode(int_mode_arg(args)?);
    let vocab = model.cfg.vocab_size;
    let max_new = args.usize_or("max-new", 32);
    let params = DecodeParams {
        a_bits: bits_arg(args, "a-bits", 16)?,
        kv_bits: bits_arg(args, "kv-bits", 16)?,
        max_batch: args.usize_or("max-batch", 8).max(1),
        temperature: args.f64_or("temperature", 0.0) as f32,
        top_k: args.usize_or("top-k", 0),
        top_p: args.f64_or("top-p", 1.0) as f32,
        prefill_chunk: args
            .usize_or("prefill-chunk", decode::DEFAULT_PREFILL_CHUNK)
            .max(1),
        seed: args.u64_or("seed", 7),
        kv_page_rows: args
            .usize_or("kv-page-rows", osp::infer::kv::DEFAULT_PAGE_ROWS)
            .max(1),
        kv_pool_mb: args.usize_or("kv-pool-mb", 0),
        share_prefix: share_prefix_arg(args, false)?,
    };
    let prompts: Vec<Vec<i32>> = match args.get("prompt") {
        Some(s) => vec![parse_prompt(s, vocab)?],
        None => {
            let g = Grammar::new(vocab, LANGUAGE_SEED);
            tasks::grammar_prompts(&g, args.usize_or("prompts", 4).max(1),
                                   args.usize_or("prompt-len", 8).max(1),
                                   params.seed)
        }
    };
    let pool = par::shared_pool();
    let mut eng = DecodeEngine::new(&model, params, pool);
    for (i, p) in prompts.iter().enumerate() {
        eng.submit(GenRequest { id: i, prompt: p.clone(), max_new })?;
    }
    // Stream results as they finish instead of eng.run(): writing
    // through the io::Write path (println! panics on EPIPE) lets a
    // closed stdout — `osp generate | head` — stop the decode early
    // and exit 0 instead of dying with a broken-pipe panic.
    let mut results = Vec::new();
    {
        use std::io::Write;
        let stdout = std::io::stdout();
        let mut out = stdout.lock();
        let mut broken = false;
        'decode: while eng.n_pending() > 0 {
            eng.step()?;
            for r in eng.take_finished() {
                let wrote = writeln!(out, "[{}] prompt {:?} -> {:?}",
                                     r.id, prompts[r.id], r.generated);
                results.push(r);
                if let Err(e) = wrote {
                    if e.kind() == std::io::ErrorKind::BrokenPipe {
                        broken = true;
                        break 'decode;
                    }
                    return Err(e.into());
                }
            }
        }
        if broken || out.flush().is_err() {
            // Reader went away: stop decoding, exit cleanly. (The
            // stats println below would EPIPE-panic on a dead pipe.)
            return Ok(());
        }
    }
    results.sort_by_key(|r| r.id);
    let st = eng.stats;
    println!(
        "{} sequences, {} tokens ({} prefill) in {:.2}s: {:.0} tok/s \
         ({:.0} generated/s, {:.0} prefill/s), peak KV {} KiB, weights \
         {} KiB",
        results.len(), st.tokens_processed, st.tokens_prefilled,
        st.wall_secs, st.tokens_per_sec(), st.generated_per_sec(),
        st.prefill_per_sec(), st.peak_kv_bytes / 1024,
        model.weight_bytes() / 1024);
    if let Some(kernel) = st.int_kernel {
        println!("int kernel: {kernel} ({})", intkern::describe());
    }
    if args.bool_or("check", false) {
        drop(eng);
        let int_active = st.int_kernel.is_some();
        // 1) With the integer path active, re-decode through the scalar
        //    integer oracle: SIMD and scalar int kernels share one
        //    parity contract, so the streams must match bit for bit.
        if int_active {
            model.set_int_mode(intkern::IntMode::Scalar);
            let scalar = decode::generate(&model, &prompts, max_new,
                                          params, pool)?;
            let mut diverged = 0usize;
            for (r, s) in results.iter().zip(&scalar) {
                if &r.generated != s {
                    diverged += 1;
                    eprintln!("[{}] {} {:?} != scalar-int {:?}", r.id,
                              st.int_kernel.unwrap_or("int"),
                              r.generated, s);
                }
            }
            if diverged > 0 {
                bail!("{diverged}/{} streams diverged between the SIMD \
                       and scalar integer kernels", results.len());
            }
            println!("check: SIMD and scalar integer kernels produced \
                      identical streams ({} sequences)", results.len());
        }
        // 2) The original exact contract, unchanged: with the integer
        //    path off, packed f32 decode matches the dense-f32 twin.
        //    (Int and f32 streams are NOT compared — the integer path
        //    rounds each dot product once instead of per fused step,
        //    a deliberate last-ulp difference; see DESIGN.md §11.)
        model.set_int_mode(intkern::IntMode::Off);
        let packed_f32: Vec<Vec<i32>> = if int_active {
            decode::generate(&model, &prompts, max_new, params, pool)?
        } else {
            results.iter().map(|r| r.generated.clone()).collect()
        };
        let dense = model.dequantized();
        let want = decode::generate(&dense, &prompts, max_new, params,
                                    pool)?;
        let mut mismatches = 0usize;
        for (i, (p, w)) in packed_f32.iter().zip(&want).enumerate() {
            if p != w {
                mismatches += 1;
                eprintln!("[{i}] packed {p:?} != dense {w:?}");
            }
        }
        if mismatches > 0 {
            bail!("{mismatches}/{} streams diverged from the dense-f32 \
                   twin", results.len());
        }
        println!("check: packed and dense-f32 token streams identical \
                  ({} sequences)", results.len());
    }
    Ok(())
}

/// Engine-free evaluation on the host model layer: teacher-forced
/// perplexity over the held-out stream plus (optionally) the 10-task
/// suite — straight off packed weights, no compiled artifacts.
fn cmd_eval(args: &Args) -> Result<()> {
    let model = generate_model(args)?;
    let a = bits_arg(args, "a-bits", 4)?;
    let kv = bits_arg(args, "kv-bits", 4)?;
    let opts = HostEvalOpts {
        a_bits: a,
        kv_bits: kv,
        batch: args.usize_or("batch", 4).max(1),
        seq_len: args.usize_or("seq-len", 64).max(2),
        n_batches: args.usize_or("batches", 2).max(1),
        chunk: args.usize_or("eval-chunk", host::DEFAULT_EVAL_CHUNK).max(1),
    };
    let pool = par::shared_pool();
    let p = host::perplexity_host(&model, &opts, pool)?;
    println!(
        "host eval (engine-free, chunk {}): ppl {:.2} @ A{a}-KV{kv} \
         (nll/tok {:.4}, kurt_max {:.2}, kurt_mean {:.2}, weights {} KiB)",
        opts.chunk, p.ppl, p.nll_per_token, p.kurt_max, p.kurt_mean,
        model.weight_bytes() / 1024);
    if args.bool_or("suite", true) {
        let (rows, avg) = host::run_suite_host(
            &model, args.usize_or("n-per-task", 8).max(1), a, kv,
            args.u64_or("task-seed", 99), pool)?;
        for (task, acc) in rows {
            println!("{task:16} {:.1}", 100.0 * acc);
        }
        println!("{:16} {:.1}", "AVERAGE", 100.0 * avg);
    }
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    let cfg = InferConfig {
        vocab_size: args.usize_or("vocab", 512),
        d_model: args.usize_or("d-model", 256),
        n_layers: args.usize_or("n-layers", 4),
        n_heads: args.usize_or("n-heads", 8),
        d_ff: args.usize_or("d-ff", 688),
        rope_theta: 10000.0,
        norm_ss: true,
        embproj: false,
    };
    cfg.validate()?;
    let prompt_len = args.usize_or("prompt-len", 8).max(1);
    let max_new = args.usize_or("max-new", 32);
    let batches: Vec<usize> = args
        .list_or("batches", &["1", "8", "32"])
        .iter()
        .map(|s| s.parse().map_err(|_| anyhow!("--batches wants ints")))
        .collect::<Result<_>>()?;
    let dense = InferModel::synthetic(&cfg, args.u64_or("seed", 11));
    let int_mode = int_mode_arg(args)?;
    let g = Grammar::new(cfg.vocab_size, LANGUAGE_SEED);
    let pool = par::shared_pool();
    let nw = par::configured_threads();
    let mut table = Table::new(
        &format!("decode serve-bench (OSP_THREADS={nw}, d={} L={} \
                  prompt={prompt_len} new={max_new})",
                 cfg.d_model, cfg.n_layers),
        &["config", "batch", "kernel", "tok/s", "gen tok/s",
          "peak KV KiB", "weights KiB"]);
    let mut records = Vec::new();
    for bc in BitConfig::table2_columns() {
        bc.validate()?;
        let model = dense.quantized(bc.w).with_int_mode(int_mode);
        let kernel = model.int_kernel_label(bc.a).unwrap_or("f32");
        for &batch in &batches {
            let prompts = tasks::grammar_prompts(&g, batch, prompt_len, 1);
            let params = DecodeParams::greedy(bc.a, bc.kv, batch.max(1));
            let mut eng = DecodeEngine::new(&model, params, pool);
            for (i, p) in prompts.iter().enumerate() {
                eng.submit(GenRequest { id: i, prompt: p.clone(),
                                        max_new })?;
            }
            eng.run()?;
            let st = eng.stats;
            table.row(vec![
                bc.label(), format!("{batch}"), kernel.to_string(),
                format!("{:.0}", st.tokens_per_sec()),
                format!("{:.0}", st.generated_per_sec()),
                format!("{}", st.peak_kv_bytes / 1024),
                format!("{}", model.weight_bytes() / 1024),
            ]);
            records.push(Json::obj(vec![
                ("phase", Json::str("decode")),
                ("config", Json::str(bc.label())),
                ("w_bits", Json::num(bc.w as f64)),
                ("a_bits", Json::num(bc.a as f64)),
                ("kv_bits", Json::num(bc.kv as f64)),
                ("batch", Json::num(batch as f64)),
                ("kernel", Json::str(kernel)),
                ("tokens_per_sec", Json::num(st.tokens_per_sec())),
                ("generated_per_sec", Json::num(st.generated_per_sec())),
                ("kv_page_rows",
                 Json::num(params.kv_page_rows as f64)),
                ("share_prefix",
                 Json::str(if params.share_prefix { "on" } else {
                     "off"
                 })),
                ("kv_bytes_peak", Json::num(st.peak_kv_bytes as f64)),
                ("kv_pages_peak", Json::num(st.kv_pages_peak as f64)),
                ("kv_pages_shared",
                 Json::num(st.kv_pages_shared as f64)),
                ("weight_bytes", Json::num(model.weight_bytes() as f64)),
            ]));
        }
    }
    table.print();

    // Prefill sweep: prompt-ingestion throughput at chunk 1/16/64 per
    // bit config (max_new 1, so the run is prefill-dominated). Chunk 1
    // is the old token-at-a-time prefill; larger chunks amortize each
    // weight row's in-register dequant across the whole block.
    let prefill_len = args.usize_or("prefill-len", 64).max(2);
    let prefill_batch = args.usize_or("prefill-batch", 8).max(1);
    let prefill_chunks: Vec<usize> = args
        .list_or("prefill-chunks", &["1", "16", "64"])
        .iter()
        .map(|s| s.parse().map_err(|_| anyhow!("--prefill-chunks wants \
                                                ints")))
        .collect::<Result<_>>()?;
    let mut ptable = Table::new(
        &format!("prefill serve-bench (prompt={prefill_len} \
                  batch={prefill_batch}, OSP_THREADS={nw})"),
        &["config", "chunk", "prompt tok/s", "tok/s", "steps"]);
    // One prompt set for the whole sweep: every config and chunk is
    // measured on identical inputs.
    let prefill_prompts =
        tasks::grammar_prompts(&g, prefill_batch, prefill_len, 2);
    for bc in BitConfig::table2_columns() {
        let model = dense.quantized(bc.w).with_int_mode(int_mode);
        let kernel = model.int_kernel_label(bc.a).unwrap_or("f32");
        for &chunk in &prefill_chunks {
            let mut params =
                DecodeParams::greedy(bc.a, bc.kv, prefill_batch);
            params.prefill_chunk = chunk.max(1);
            let mut eng = DecodeEngine::new(&model, params, pool);
            for (i, p) in prefill_prompts.iter().enumerate() {
                eng.submit(GenRequest { id: i, prompt: p.clone(),
                                        max_new: 1 })?;
            }
            eng.run()?;
            let st = eng.stats;
            ptable.row(vec![
                bc.label(), format!("{chunk}"),
                format!("{:.0}", st.prefill_per_sec()),
                format!("{:.0}", st.tokens_per_sec()),
                format!("{}", st.steps),
            ]);
            records.push(Json::obj(vec![
                ("phase", Json::str("prefill")),
                ("config", Json::str(bc.label())),
                ("w_bits", Json::num(bc.w as f64)),
                ("a_bits", Json::num(bc.a as f64)),
                ("kv_bits", Json::num(bc.kv as f64)),
                ("batch", Json::num(prefill_batch as f64)),
                ("kernel", Json::str(kernel)),
                ("chunk", Json::num(chunk as f64)),
                ("prompt_len", Json::num(prefill_len as f64)),
                ("prompt_tokens_per_sec", Json::num(st.prefill_per_sec())),
                ("tokens_per_sec", Json::num(st.tokens_per_sec())),
                ("steps", Json::num(st.steps as f64)),
            ]));
        }
    }
    ptable.print();
    if let Some(j) = args.get("json") {
        let path = if j == "true" { "BENCH_infer.json" } else { j };
        let doc = Json::obj(vec![
            ("bench", Json::str("infer")),
            ("threads", Json::num(nw as f64)),
            ("d_model", Json::num(cfg.d_model as f64)),
            ("n_layers", Json::num(cfg.n_layers as f64)),
            ("prompt_len", Json::num(prompt_len as f64)),
            ("max_new", Json::num(max_new as f64)),
            ("rows", Json::Arr(records)),
        ]);
        std::fs::write(path, doc.dump())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `osp bench-diff OLD.json NEW.json`: per-row speedup table between
/// two bench artifacts, nonzero exit on a metric regressing more than
/// `--threshold` (default 10%). CI runs it advisory against the
/// previous run's uploaded artifact.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    let [old_path, new_path] = match args.positional.as_slice() {
        [a, b] => [a.clone(), b.clone()],
        _ => bail!("bench-diff wants exactly two positional arguments: \
                    OLD.json NEW.json"),
    };
    let threshold = args.f64_or("threshold", 0.10);
    if !(0.0..1.0).contains(&threshold) {
        bail!("--threshold wants a fraction in [0, 1), got {threshold}");
    }
    let load = |path: &str| -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        Json::parse(&text).with_context(|| format!("parsing {path}"))
    };
    let report = bench_diff::diff_reports(&load(&old_path)?,
                                          &load(&new_path)?)?;
    let mut table = Table::new(
        &format!("bench diff: {old_path} -> {new_path}"),
        &["row", "metric", "old", "new", "speedup"]);
    for m in &report.metrics {
        table.row(vec![m.row.clone(), m.metric.clone(),
                       bench_diff::fmt_metric(m.old),
                       bench_diff::fmt_metric(m.new),
                       format!("{:.2}x", m.speedup)]);
    }
    table.print();
    if let Some(note) = &report.thread_note {
        println!("note: {note}");
    }
    if !report.only_old.is_empty() || !report.only_new.is_empty() {
        println!("unmatched rows (informational, never fatal): \
                  {} only in OLD, {} only in NEW",
                 report.only_old.len(), report.only_new.len());
        for key in &report.only_old {
            println!("  - removed (only in OLD): {key}");
        }
        for key in &report.only_new {
            println!("  + added   (only in NEW): {key}");
        }
    }
    let regs = report.regressions(threshold);
    if !regs.is_empty() {
        for m in &regs {
            eprintln!("REGRESSION {:.1}%: {} {} ({} -> {})",
                      100.0 * (1.0 - m.speedup), m.row, m.metric,
                      bench_diff::fmt_metric(m.old),
                      bench_diff::fmt_metric(m.new));
        }
        bail!("{} metric(s) regressed more than {:.0}%", regs.len(),
              100.0 * threshold);
    }
    println!("no regressions beyond {:.0}% ({} metrics compared)",
             100.0 * threshold, report.metrics.len());
    Ok(())
}

/// `osp serve`: spawn the streaming HTTP front-end on the resolved
/// model and block until a drain (`POST /admin/drain`) completes.
/// Exits 0 after in-flight sequences finish — the graceful path.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut model = generate_model(args)?;
    model.set_int_mode(int_mode_arg(args)?);
    let defaults = ServeOpts::default();
    let opts = ServeOpts {
        addr: args.str_or("addr", &defaults.addr),
        max_batch: args.usize_or("max-batch", defaults.max_batch)
            .max(1),
        queue_cap: args.usize_or("queue-cap", defaults.queue_cap)
            .max(1),
        a_bits: bits_arg(args, "a-bits", 4)?,
        kv_bits: bits_arg(args, "kv-bits", 4)?,
        prefill_chunk: args
            .usize_or("prefill-chunk", decode::DEFAULT_PREFILL_CHUNK)
            .max(1),
        temperature: args.f64_or("temperature", 0.0) as f32,
        top_k: args.usize_or("top-k", 0),
        top_p: args.f64_or("top-p", 1.0) as f32,
        seed: args.u64_or("seed", 7),
        max_new_default: args
            .usize_or("max-new", defaults.max_new_default)
            .max(1),
        max_new_cap: args.usize_or("max-new-cap", defaults.max_new_cap)
            .max(1),
        max_prompt: args.usize_or("max-prompt", defaults.max_prompt)
            .max(1),
        default_timeout_ms: args
            .u64_or("timeout-ms", defaults.default_timeout_ms)
            .max(1),
        timeout_cap_ms: args
            .u64_or("timeout-cap-ms", defaults.timeout_cap_ms)
            .max(1),
        header_timeout_ms: args
            .u64_or("header-timeout-ms", defaults.header_timeout_ms)
            .max(1),
        write_timeout_ms: args
            .u64_or("write-timeout-ms", defaults.write_timeout_ms)
            .max(1),
        max_body_bytes: defaults.max_body_bytes,
        max_conns: args.usize_or("max-conns", defaults.max_conns)
            .max(1),
        kv_page_rows: args
            .usize_or("kv-page-rows", defaults.kv_page_rows)
            .max(1),
        kv_pool_mb: args.usize_or("kv-pool-mb", defaults.kv_pool_mb),
        share_prefix: share_prefix_arg(args, defaults.share_prefix)?,
        workers: args
            .list_or("workers", &[])
            .into_iter()
            .filter(|w| !w.is_empty())
            .collect(),
        shard_dir: args.str_or("shard-dir", &defaults.shard_dir),
        replicas: args.usize_or("replicas", defaults.replicas).max(1),
        probe_interval_ms: args
            .u64_or("probe-interval-ms", defaults.probe_interval_ms)
            .max(10),
        down_after: args
            .u64_or("down-after", defaults.down_after as u64)
            .max(1) as u32,
    };
    let n_workers = opts.workers.len();
    let replicas = opts.replicas;
    let server = Server::spawn(model, opts)?;
    if n_workers > 0 {
        println!("sharded: trunk matmuls routed to {n_workers} \
                  worker(s) at --replicas {replicas}; GET /shards \
                  serves their artifacts");
    }
    println!(
        "osp serve listening on {} (max_batch {}, queue_cap {}; \
         POST /generate, GET /metrics, GET /status, GET /healthz, \
         POST /admin/drain to stop)",
        server.addr(),
        args.usize_or("max-batch", defaults.max_batch).max(1),
        args.usize_or("queue-cap", defaults.queue_cap).max(1));
    server.join();
    println!("drained; all batch slots returned, exiting");
    Ok(())
}

/// `osp shard`: partition the resolved packed model into per-worker
/// row/col shard artifacts plus a manifest (DESIGN.md §14). The output
/// directory is what a sharded `osp serve --shard-dir` streams to its
/// workers, and what `osp worker --artifact` loads directly.
fn cmd_shard(args: &Args) -> Result<()> {
    let model = generate_model(args)?;
    let shards = args.usize_or("shards", 2).max(1);
    let arch = args.str_or("arch", "ssnorm_plain");
    let dir = PathBuf::from(args.str_or("out", "shards"));
    let report =
        osp::coordinator::shard::write_shards(&model, shards, &arch,
                                              &dir)?;
    let total: usize = report.bytes.iter().sum();
    for (w, b) in report.bytes.iter().enumerate() {
        println!("  shard_{w}.bin  {:>8} KiB", b / 1024);
    }
    println!(
        "wrote {} shard(s) + manifest.json to {:?}: {} KiB total \
         (full model {} KiB; dense embed/norms stay coordinator-side)",
        report.shards, dir, total / 1024,
        model.weight_bytes() / 1024);
    Ok(())
}

/// `osp worker`: serve one row/col shard of the trunk over HTTP for a
/// sharded `osp serve` coordinator. The artifact comes from a local
/// file (`--artifact`) or a checksummed resumable fetch against the
/// coordinator's `/shards` endpoints (`--coordinator`). Blocks until
/// drained (`POST /admin/drain`); a failed shard load exits nonzero.
fn cmd_worker(args: &Args) -> Result<()> {
    let shard = args.usize_or("shard", 0);
    let source = if let Some(file) = args.get("artifact") {
        ShardSource::File(PathBuf::from(file))
    } else if let Some(coord) = args.get("coordinator") {
        ShardSource::Fetch {
            coordinator: coord.to_string(),
            spool: PathBuf::from(
                args.str_or("spool", &format!("shard_{shard}.part"))),
            byte_budget: match args.get("fetch-budget") {
                Some(s) => Some(s.parse().map_err(|_| {
                    anyhow!("--fetch-budget wants a byte count, got \
                             '{s}'")
                })?),
                None => None,
            },
        }
    } else {
        bail!("worker needs --artifact FILE or --coordinator HOST:PORT")
    };
    let opts = WorkerOpts {
        addr: args.str_or("addr", "127.0.0.1:0"),
        n_shards: args.usize_or("n-shards", 0),
        int_mode: int_mode_arg(args)?,
        ..WorkerOpts::new("", shard, source)
    };
    let server = WorkerServer::spawn(opts)?;
    println!(
        "osp worker (shard {shard}) listening on {} (POST /matmul, \
         GET /metrics, GET /healthz, POST /admin/drain to stop)",
        server.addr());
    // Block until drained. `is_done` flips on POST /admin/drain or on
    // a failed shard load; read the failure before join() consumes the
    // handle so a bad artifact exits 1, not "drained" + 0.
    while !server.is_done() {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    let load_err = server.load_error();
    server.join();
    if let Some(e) = load_err {
        bail!("shard load failed: {e}");
    }
    Ok(())
}

/// `osp serve-load`: drive a running `osp serve` with N chaos-seeded
/// client threads and write the diffable `BENCH_serve.json` report.
fn cmd_serve_load(args: &Args) -> Result<()> {
    let chaos_label = args.str_or("chaos", "off");
    let defaults = LoadOpts::default();
    let opts = LoadOpts {
        addr: args.str_or("addr", &defaults.addr),
        clients: args.usize_or("clients", defaults.clients).max(1),
        requests: args.usize_or("requests", defaults.requests).max(1),
        prompt_len: args.usize_or("prompt-len", defaults.prompt_len)
            .max(1),
        prefix_len: args.usize_or("prefix-len", defaults.prefix_len),
        max_new: args.usize_or("max-new", defaults.max_new).max(1),
        timeout_ms: args.u64_or("timeout-ms", defaults.timeout_ms)
            .max(1),
        chaos: ChaosSpec::parse(&chaos_label)?,
        chaos_label: chaos_label.clone(),
        proxy: args.str_or("proxy", ""),
        seed: args.u64_or("seed", 7),
    };
    if opts.chaos.has_fleet_faults() && opts.proxy.is_empty() {
        bail!("chaos spec '{chaos_label}' has fleet faults \
               (worker-kill / worker-stall-ms) but no --proxy \
               HOST:PORT to drive them through");
    }
    let doc = serve_load::run_load(&opts)?;
    let row = doc
        .get("rows")
        .and_then(|r| r.as_arr())
        .and_then(|r| r.first())
        .ok_or_else(|| anyhow!("load run produced no rows"))?;
    let f = |key: &str| {
        row.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
    };
    println!(
        "serve-load vs {} (chaos {}): {} clients x {} requests -> \
         {:.0} completed, {:.0} rejected, {:.0} deadline, {:.0} \
         aborted, {:.0} errors; {:.0} tokens, p50 {:.2} ms/token, \
         p99 {:.2} ms/token, first-token p50 {:.2} ms",
        opts.addr, chaos_label, opts.clients, opts.requests,
        f("completed"), f("rejected"), f("deadline"), f("aborted"),
        f("errors"), f("tokens"), f("p50_token_ms"), f("p99_token_ms"),
        f("first_token_p50_ms"));
    println!(
        "server counters: admitted {:.0}, completed {:.0}, timed_out \
         {:.0}, cancelled {:.0}, failed {:.0}, in_flight {:.0}",
        f("server_admitted"), f("server_completed"),
        f("server_timed_out"), f("server_cancelled"),
        f("server_failed"), f("server_in_flight"));
    println!(
        "kv pool: peak {:.0} bytes over {:.0} pages, {:.0} page(s) \
         saved by prefix sharing, {:.0} live at scrape",
        f("kv_bytes_peak"), f("kv_pages_peak"), f("kv_pages_shared"),
        f("kv_pages_live"));
    if f("replicas") >= 2.0 || f("failovers") > 0.0 {
        println!(
            "fleet: {:.0} failover(s), {:.0} breaker trip(s), {:.0} \
             rejoin(s), {:.0} uncovered 503(s)",
            f("failovers"), f("breaker_trips"), f("rejoins"),
            f("server_uncovered_503s"));
    }
    if let Some(j) = args.get("json") {
        let path = if j == "true" { "BENCH_serve.json" } else { j };
        std::fs::write(path, doc.dump())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    if args.bool_or("drain", false) {
        let (status, _) =
            serve_load::http_post(&opts.addr, "/admin/drain", "")?;
        println!("drain requested ({status})");
    }
    Ok(())
}

/// `osp chaos-proxy`: stand-alone fleet-fault TCP proxy for one shard
/// worker (DESIGN.md §15). Put it between the coordinator and a worker
/// (`--workers` lists the proxy's address), then drive faults over its
/// control surface — by hand with curl, or from `osp serve-load
/// --proxy` via the `worker-kill`/`worker-stall-ms` chaos keys. Runs
/// until killed.
fn cmd_chaos_proxy(args: &Args) -> Result<()> {
    let listen = args.str_or("listen", "127.0.0.1:0");
    let target = args.get("target").ok_or_else(|| {
        anyhow!("chaos-proxy needs --target HOST:PORT")
    })?;
    let proxy = osp::serve::chaos::ChaosProxy::spawn(&listen, target)?;
    println!(
        "osp chaos-proxy forwarding {} -> {target} (POST \
         /chaos/kill, /chaos/revive, /chaos/stall?ms=N; GET \
         /chaos/ping)",
        proxy.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `osp simd-info`: one line naming the host arch, the CPU features the
/// integer microkernels probe for, and the backend `--int auto` would
/// dispatch to (honoring `OSP_SIMD=off`). CI logs this before the test
/// runs so every green build records which kernels it actually covered.
fn cmd_simd_info(args: &Args) -> Result<()> {
    println!("{}", intkern::describe());
    println!("--int default: {}", int_mode_arg(args)?.label());
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    let runs_dir = PathBuf::from(args.str_or("runs-dir", "runs"));
    let tags = args.list_or("tags", &["adam", "osp"]);
    let tag_refs: Vec<&str> = tags.iter().map(|s| s.as_str()).collect();
    println!("{}", repro::fig56(&engine, &runs_dir, &tag_refs)?);
    Ok(())
}

fn main() {
    let args = Args::from_env(true);
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("repro") => cmd_repro(&args),
        Some("suite") => cmd_suite(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("eval") => cmd_eval(&args),
        Some("generate") => cmd_generate(&args),
        Some("serve") => cmd_serve(&args),
        Some("shard") => cmd_shard(&args),
        Some("worker") => cmd_worker(&args),
        Some("serve-load") => cmd_serve_load(&args),
        Some("chaos-proxy") => cmd_chaos_proxy(&args),
        Some("serve-bench") => cmd_serve_bench(&args),
        Some("bench-diff") => cmd_bench_diff(&args),
        Some("simd-info") => cmd_simd_info(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}'\n{HELP}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
