//! Health-checked worker registry for the sharded serving plane
//! (DESIGN.md §15).
//!
//! The coordinator keeps one [`HealthRegistry`] per fleet. A background
//! prober (spawned by `Server::spawn`) GETs every worker's `/healthz`
//! on a fixed cadence and feeds the observations in here; the rpc path
//! ([`super::worker::HttpShardPool`]) feeds transport outcomes in as
//! they happen. Both drive the same per-worker state machine:
//!
//! ```text
//!   Rejoining --ready--> Up --fail--> Suspect --fail*--> Down
//!       ^                 ^------------ok-----------------|
//!       |                                                 |
//!       +---------------probe reachable, not ready--------+
//! ```
//!
//! `Up` and `Suspect` are routable. `Rejoining` (reachable but the
//! shard is still loading — boot and rejoin look identical) is a
//! last-resort route: `/matmul` answers a retryable 503 until ready.
//! `Down` is breaker-open: the pool skips the worker entirely and only
//! the prober can half-open it back (a reachable probe moves it to
//! `Rejoining`, a ready one to `Up`). Shard coverage — every shard has
//! at least one non-`Down` replica — is the serve front-end's
//! readiness/degradation gate.
//!
//! Retry pacing is [`retry_delay`]: capped exponential backoff with
//! deterministic Pcg jitter, overridden upward by a peer's
//! `Retry-After` hint (still capped).

use std::sync::atomic::Ordering::{Relaxed, SeqCst};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8};
use std::time::Duration;

use crate::util::json::Json;
use crate::util::rng::Pcg;

/// Per-worker health as seen from the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// Probed ready; first-choice route for its shard.
    Up,
    /// Recent failure(s), breaker not yet tripped; still routable.
    Suspect,
    /// Breaker open: consecutive failures reached the threshold. Not
    /// routed; only a successful probe can move it out.
    Down,
    /// Reachable but not ready (shard loading — initial join or a
    /// restarted worker re-fetching). Routed only when nothing better
    /// is live; `/matmul` answers 503 until ready.
    Rejoining,
}

impl HealthState {
    pub fn label(self) -> &'static str {
        match self {
            HealthState::Up => "up",
            HealthState::Suspect => "suspect",
            HealthState::Down => "down",
            HealthState::Rejoining => "rejoining",
        }
    }

    fn from_u8(v: u8) -> HealthState {
        match v {
            0 => HealthState::Up,
            1 => HealthState::Suspect,
            2 => HealthState::Down,
            _ => HealthState::Rejoining,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            HealthState::Up => 0,
            HealthState::Suspect => 1,
            HealthState::Down => 2,
            HealthState::Rejoining => 3,
        }
    }
}

/// Knobs for the registry + retry schedule. Defaults match the serve
/// CLI defaults documented in DESIGN.md §15.
#[derive(Clone, Debug)]
pub struct HealthOpts {
    /// Prober cadence.
    pub probe_interval_ms: u64,
    /// Consecutive failures before the breaker trips (`Down`).
    pub down_after: u32,
    /// First retry backoff step.
    pub backoff_base_ms: u64,
    /// Backoff cap (also caps honored `Retry-After` hints).
    pub backoff_cap_ms: u64,
    /// Rpc attempt rounds per call (each round tries every live
    /// replica of the shard).
    pub retries: u32,
    /// Seed for the deterministic backoff jitter.
    pub seed: u64,
}

impl Default for HealthOpts {
    fn default() -> HealthOpts {
        HealthOpts {
            probe_interval_ms: 150,
            down_after: 3,
            backoff_base_ms: 10,
            backoff_cap_ms: 500,
            retries: 4,
            seed: 0,
        }
    }
}

struct WorkerHealth {
    state: AtomicU8,
    fails: AtomicU32,
    /// Whether this worker has ever been observed ready — separates a
    /// genuine rejoin from the initial join, so booting a fleet of N
    /// does not count N rejoins.
    ever_up: AtomicBool,
}

/// Fleet health: per-worker state machines plus the failover counters
/// `/status` publishes. Shared between the rpc pool and the prober.
pub struct HealthRegistry {
    workers: Vec<WorkerHealth>,
    /// `shard_of[w]` = the shard worker `w` serves (round-robin
    /// placement, see [`crate::coordinator::shard::replica_assignment`]).
    shard_of: Vec<usize>,
    n_shards: usize,
    pub opts: HealthOpts,
    pub failovers: AtomicU64,
    pub breaker_trips: AtomicU64,
    pub rejoins: AtomicU64,
}

impl HealthRegistry {
    pub fn new(n_workers: usize, n_shards: usize, opts: HealthOpts)
               -> HealthRegistry {
        assert!(n_shards > 0 && n_workers >= n_shards,
                "{n_workers} workers cannot cover {n_shards} shards");
        HealthRegistry {
            workers: (0..n_workers)
                .map(|_| WorkerHealth {
                    state: AtomicU8::new(
                        HealthState::Rejoining.as_u8()),
                    fails: AtomicU32::new(0),
                    ever_up: AtomicBool::new(false),
                })
                .collect(),
            shard_of: crate::coordinator::shard::replica_assignment(
                n_workers, n_shards),
            n_shards,
            opts,
            failovers: AtomicU64::new(0),
            breaker_trips: AtomicU64::new(0),
            rejoins: AtomicU64::new(0),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub fn shard_of(&self, w: usize) -> usize {
        self.shard_of[w]
    }

    pub fn state(&self, w: usize) -> HealthState {
        HealthState::from_u8(self.workers[w].state.load(SeqCst))
    }

    fn set_state(&self, w: usize, s: HealthState) -> HealthState {
        let prev = self.workers[w].state.swap(s.as_u8(), SeqCst);
        HealthState::from_u8(prev)
    }

    /// A probe saw `ready: true`, or an rpc succeeded: the worker is
    /// fully live. Counts a rejoin when it returns from `Down` /
    /// `Rejoining` after having been up before.
    pub fn record_ready(&self, w: usize) {
        self.workers[w].fails.store(0, SeqCst);
        let prev = self.set_state(w, HealthState::Up);
        let rejoined = matches!(prev, HealthState::Down
                                | HealthState::Rejoining)
            && self.workers[w].ever_up.load(SeqCst);
        if rejoined {
            self.rejoins.fetch_add(1, Relaxed);
        }
        self.workers[w].ever_up.store(true, SeqCst);
    }

    /// A probe reached the worker but it reported `ready: false` (the
    /// shard is still loading, or it is draining). Half-opens a
    /// breaker-tripped worker into `Rejoining`.
    pub fn record_unready(&self, w: usize) {
        self.workers[w].fails.store(0, SeqCst);
        self.set_state(w, HealthState::Rejoining);
    }

    /// A probe or rpc could not reach the worker (transport error).
    pub fn record_failure(&self, w: usize) {
        let fails = self.workers[w].fails.fetch_add(1, SeqCst) + 1;
        if fails >= self.opts.down_after {
            let prev = self.set_state(w, HealthState::Down);
            if prev != HealthState::Down {
                self.breaker_trips.fetch_add(1, Relaxed);
            }
        } else {
            self.set_state(w, HealthState::Suspect);
        }
    }

    /// Worker indices serving `shard`, in routing preference order:
    /// `Up` first, then `Suspect`, then `Rejoining`; `Down` (breaker
    /// open) workers are excluded entirely. Empty ⇒ the shard is
    /// uncovered.
    pub fn route_order(&self, shard: usize) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.workers.len())
            .filter(|&w| self.shard_of[w] == shard
                    && self.state(w) != HealthState::Down)
            .collect();
        let rank = |s: HealthState| match s {
            HealthState::Up => 0u8,
            HealthState::Suspect => 1,
            _ => 2,
        };
        order.sort_by_key(|&w| (rank(self.state(w)), w));
        order
    }

    /// Is `shard` servable right now — does it have a ready (`Up` or
    /// `Suspect`) replica?
    pub fn covered(&self, shard: usize) -> bool {
        (0..self.workers.len()).any(|w| {
            self.shard_of[w] == shard
                && matches!(self.state(w), HealthState::Up
                            | HealthState::Suspect)
        })
    }

    /// Lowest shard with no live replica, if any. `None` ⇒ the fleet
    /// can serve; this is the coordinator's readiness gate (at boot
    /// every shard is uncovered until its first replica goes `Up`).
    pub fn first_uncovered(&self) -> Option<usize> {
        (0..self.n_shards).find(|&s| !self.covered(s))
    }

    pub fn all_covered(&self) -> bool {
        self.first_uncovered().is_none()
    }

    /// Fleet counters + per-worker states for `/status`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("states",
             Json::Arr((0..self.workers.len())
                       .map(|w| Json::str(self.state(w).label()))
                       .collect())),
            ("failovers",
             Json::num(self.failovers.load(Relaxed) as f64)),
            ("breaker_trips",
             Json::num(self.breaker_trips.load(Relaxed) as f64)),
            ("rejoins", Json::num(self.rejoins.load(Relaxed) as f64)),
        ])
    }
}

/// Retry pacing for attempt `attempt` (1-based: the sleep taken
/// *before* that attempt): capped exponential backoff with
/// deterministic Pcg "equal jitter" — half the step is fixed, half is
/// drawn from `Pcg::new(seed, salt)` advanced per attempt, so a given
/// (seed, salt) always yields the same schedule. A peer's
/// `Retry-After` hint (milliseconds) raises the floor but never
/// exceeds `cap_ms`.
pub fn retry_delay(base_ms: u64, cap_ms: u64, attempt: u32, seed: u64,
                   salt: u64, retry_after_ms: Option<u64>) -> Duration {
    let exp = attempt.saturating_sub(1).min(16);
    let step = base_ms.saturating_mul(1u64 << exp).min(cap_ms).max(1);
    let mut rng = Pcg::new(seed, salt);
    let mut jitter = 0;
    for _ in 0..attempt {
        jitter = rng.below(step.div_ceil(2).max(1));
    }
    let mut ms = step / 2 + jitter;
    if let Some(hint) = retry_after_ms {
        ms = ms.max(hint);
    }
    Duration::from_millis(ms.min(cap_ms))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg(workers: usize, shards: usize) -> HealthRegistry {
        HealthRegistry::new(workers, shards, HealthOpts::default())
    }

    #[test]
    fn boot_fleet_is_rejoining_and_uncovered_until_ready() {
        let r = reg(3, 2);
        assert_eq!(r.state(0), HealthState::Rejoining);
        assert_eq!(r.first_uncovered(), Some(0));
        r.record_ready(0); // shard 0
        assert_eq!(r.first_uncovered(), Some(1));
        r.record_ready(1); // shard 1
        assert!(r.all_covered());
        // Initial joins are not rejoins.
        assert_eq!(r.rejoins.load(Relaxed), 0);
    }

    #[test]
    fn breaker_trips_after_threshold_and_half_opens_via_probe() {
        let r = reg(2, 1);
        r.record_ready(0);
        r.record_ready(1);
        r.record_failure(0);
        assert_eq!(r.state(0), HealthState::Suspect);
        assert!(r.covered(0), "suspect still covers");
        r.record_failure(0);
        r.record_failure(0);
        assert_eq!(r.state(0), HealthState::Down);
        assert_eq!(r.breaker_trips.load(Relaxed), 1);
        // Down workers drop out of routing; the replica remains.
        assert_eq!(r.route_order(0), vec![1]);
        // Probe reaches it mid-reload: half-open, last-resort route.
        r.record_unready(0);
        assert_eq!(r.state(0), HealthState::Rejoining);
        assert_eq!(r.route_order(0), vec![1, 0]);
        // Ready again: that is one rejoin, not two.
        r.record_ready(0);
        assert_eq!(r.state(0), HealthState::Up);
        assert_eq!(r.rejoins.load(Relaxed), 1);
        r.record_ready(0);
        assert_eq!(r.rejoins.load(Relaxed), 1);
    }

    #[test]
    fn route_order_prefers_up_over_suspect_over_rejoining() {
        let r = reg(4, 2); // shard 0: workers {0, 2}; shard 1: {1, 3}
        r.record_ready(0);
        r.record_ready(2);
        r.record_failure(0);
        assert_eq!(r.route_order(0), vec![2, 0]);
        assert_eq!(r.shard_of(2), 0);
        // All replicas down -> uncovered, empty route.
        for _ in 0..3 {
            r.record_failure(0);
            r.record_failure(2);
        }
        assert!(r.route_order(0).is_empty());
        assert_eq!(r.first_uncovered(), Some(0));
    }

    #[test]
    fn retry_schedule_is_deterministic_capped_and_grows() {
        let d = |attempt, hint| {
            retry_delay(10, 500, attempt, 42, 7, hint).as_millis()
                as u64
        };
        // Deterministic: same inputs, same schedule.
        assert_eq!(d(1, None), d(1, None));
        assert_eq!(d(3, None), d(3, None));
        // Each attempt stays within its exponential envelope
        // [step/2, step] for step = min(cap, base << (attempt-1)).
        for attempt in 1..10u32 {
            let step =
                (10u64 << (attempt - 1).min(16)).min(500).max(1);
            let ms = d(attempt, None);
            assert!(ms >= step / 2 && ms <= step,
                    "attempt {attempt}: {ms}ms outside envelope \
                     [{}, {step}]", step / 2);
        }
        // Capped: late attempts never exceed the cap.
        assert!(d(30, None) <= 500);
        // Retry-After raises the floor but respects the cap.
        assert!(d(1, Some(200)) >= 200);
        assert_eq!(d(1, Some(30_000)), 500);
        // Different seeds give different jitter somewhere in the
        // schedule (not a fixed sleep).
        let a: Vec<u64> = (1..8)
            .map(|i| retry_delay(10, 500, i, 1, 0, None).as_millis()
                 as u64)
            .collect();
        let b: Vec<u64> = (1..8)
            .map(|i| retry_delay(10, 500, i, 2, 0, None).as_millis()
                 as u64)
            .collect();
        assert_ne!(a, b);
    }
}
