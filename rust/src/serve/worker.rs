//! `osp worker` — the worker half of row-parallel sharded serving
//! (DESIGN.md §14), plus [`HttpShardPool`], the coordinator-side
//! [`ShardCompute`] implementation that drives a worker fleet over the
//! std-only HTTP layer.
//!
//! A worker is a stateless sharded-matmul server: it acquires one OSPS
//! shard artifact (from a local file, or by checksummed resumable
//! fetch from the coordinator's `/shards/{i}/...` endpoints), then
//! answers `POST /matmul` with either an f32 column stripe (Col ops)
//! or an exact i32 partial accumulator (Row ops) — see
//! `model::remote` for why that split keeps sharded streams
//! bit-identical to single-process ones.
//!
//! Worker endpoints: `POST /matmul`, `GET /healthz` (carries `ready`),
//! `GET /metrics` (shard fetch progress, rpc counters, stripe
//! latency), `POST /admin/drain`. The worker serves health/metrics
//! while the shard is still loading; `/matmul` answers 503 until
//! ready.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering::{Relaxed, SeqCst};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64};
use std::sync::{Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::{self, ShardArtifact};
use crate::model::remote::{ShardCompute, ShardEntry, ShardKind};
use crate::tensor::intkern::{Backend, IntMode, QuantActs, MAX_INT_K};
use crate::util::json::Json;

use super::health::{self, HealthOpts, HealthRegistry};
use super::http::{self, header, ClientConn};
use super::metrics::LatHist;
use super::storage::{fnv64, ShardMeta, StorageBackend, CHUNK_BYTES};

/// Largest `len` a single `/shards/{i}/data` range request may ask
/// for; clients fetch chunk-by-chunk anyway.
pub const MAX_RANGE_BYTES: usize = 8 << 20;

// ---- small blocking HTTP client helpers --------------------------------

/// Connect with `timeout` applied to the connect itself as well as
/// both I/O directions. A plain `TcpStream::connect` would block for
/// the OS default (minutes) on a black-holed address — far past every
/// read timeout in this file — so a dead worker would stall fetches
/// and rpcs instead of failing fast into the §15 failover path.
fn connect(addr: &str, timeout: Duration) -> Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let sa = addr.to_socket_addrs()
        .with_context(|| format!("resolve {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("no socket address for {addr}"))?;
    let stream = TcpStream::connect_timeout(&sa, timeout)
        .with_context(|| format!("connect {addr}"))?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    Ok(stream)
}

/// GET returning the raw body bytes — the shard-data fetch path, which
/// must never pass through a lossy UTF-8 conversion.
fn get_bytes(addr: &str, path: &str, timeout: Duration)
             -> Result<(u16, Vec<u8>)> {
    let mut conn = ClientConn::new(connect(addr, timeout)?);
    conn.send_request("GET", path, "")?;
    let (status, headers) = conn.read_head()?;
    let n: usize = header(&headers, "content-length")
        .ok_or_else(|| anyhow!("response without Content-Length"))?
        .parse()?;
    Ok((status, conn.read_body_bytes(n)?))
}

fn post_json(addr: &str, path: &str, body: &str, timeout: Duration)
             -> Result<(u16, Json)> {
    let (status, doc, _headers) =
        post_json_hdrs(addr, path, body, timeout)?;
    Ok((status, doc))
}

/// [`post_json`] that also surfaces the response headers — the rpc
/// path reads `Retry-After` off 503s to pace its backoff.
fn post_json_hdrs(addr: &str, path: &str, body: &str,
                  timeout: Duration)
                  -> Result<(u16, Json, Vec<(String, String)>)> {
    let mut conn = ClientConn::new(connect(addr, timeout)?);
    conn.send_request("POST", path, body)?;
    let (status, headers) = conn.read_head()?;
    let n: usize = header(&headers, "content-length")
        .ok_or_else(|| anyhow!("response without Content-Length"))?
        .parse()?;
    let text = conn.read_body(n)?;
    let doc = Json::parse(&text)
        .map_err(|e| anyhow!("bad response JSON: {e}"))?;
    Ok((status, doc, headers))
}

fn json_err(doc: &Json) -> String {
    doc.get("error")
        .and_then(|e| e.as_str())
        .unwrap_or("?")
        .to_string()
}

// ---- the /shards/{i}/... endpoint body (served by the coordinator) ----

/// Build the response for a `GET /shards/...` path against a storage
/// backend: `(status, content_type, body)`. Factored out of the serve
/// front-end so the fetch tests can run it behind a bare listener
/// without booting a model.
pub(crate) fn shards_http_response(path: &str,
                                   store: &dyn StorageBackend)
                                   -> (u16, &'static str, Vec<u8>) {
    fn err(status: u16, msg: &str) -> (u16, &'static str, Vec<u8>) {
        let body = Json::obj(vec![("error", Json::str(msg))]).dump();
        (status, "application/json", body.into_bytes())
    }
    let Some(rest) = path.strip_prefix("/shards/") else {
        return err(404, "no such endpoint");
    };
    let Some((idx, tail)) = rest.split_once('/') else {
        return err(404, "want /shards/{i}/meta or /shards/{i}/data");
    };
    let Ok(shard) = idx.parse::<usize>() else {
        return err(404, "bad shard index");
    };
    if tail == "meta" {
        return match store.meta(shard) {
            Ok(m) => (200, "application/json",
                      m.to_json().dump().into_bytes()),
            Err(e) => err(404, &format!("{e:#}")),
        };
    }
    let Some(query) = tail.strip_prefix("data?") else {
        return err(404, "want /shards/{i}/meta or /shards/{i}/data");
    };
    let (mut off, mut len) = (None, None);
    for kv in query.split('&') {
        match kv.split_once('=') {
            Some(("off", v)) => off = v.parse::<usize>().ok(),
            Some(("len", v)) => len = v.parse::<usize>().ok(),
            _ => {}
        }
    }
    let (Some(off), Some(len)) = (off, len) else {
        return err(400, "data wants ?off=N&len=N");
    };
    if len == 0 || len > MAX_RANGE_BYTES {
        return err(400, "bad range length");
    }
    match store.read(shard, off, len) {
        Ok(bytes) => (200, "application/octet-stream", bytes),
        Err(e) => err(400, &format!("{e:#}")),
    }
}

// ---- worker metrics ----------------------------------------------------

/// Worker-side counters and gauges, all lock-free. `chunks_*` move
/// during the fetch so `/metrics` shows live progress.
#[derive(Default)]
pub struct WorkerMetrics {
    pub rpcs_served: AtomicU64,
    /// Matmuls currently executing (the worker's queue-depth gauge;
    /// single-threaded worker ⇒ 0 or 1, and 0 after drain).
    pub rpc_in_flight: AtomicI64,
    pub stripe_lat: LatHist,
    pub fetch_ms: AtomicU64,
    pub bytes_fetched: AtomicU64,
    pub chunks_done: AtomicU64,
    pub chunks_total: AtomicU64,
    /// Chunks recovered from the spool instead of the wire.
    pub resumed_chunks: AtomicU64,
}

// ---- resumable checksummed shard fetch ---------------------------------

pub struct FetchStats {
    pub fetch_ms: u64,
    /// Bytes that crossed the wire *this call* (resumed chunks do not
    /// count — that is the point of resuming).
    pub bytes_fetched: u64,
    pub resumed_chunks: u64,
}

/// Fetch shard `shard` from the coordinator's `/shards` endpoints,
/// verifying every [`CHUNK_BYTES`] chunk against the meta digests as
/// it lands and spooling verified bytes to `spool`. A rerun after an
/// interruption re-verifies the spool and resumes at the first
/// unverified chunk. `byte_budget` caps wire bytes for this call (the
/// interruption-injection knob used by tests and `osp worker
/// --fetch-budget`); exceeding it errors *after* persisting progress.
pub fn fetch_shard(coordinator: &str, shard: usize, spool: &Path,
                   byte_budget: Option<usize>, wm: &WorkerMetrics)
                   -> Result<(ShardArtifact, FetchStats)> {
    let t0 = Instant::now();
    let timeout = Duration::from_secs(10);
    let (status, meta_doc) = post_meta(coordinator, shard, timeout)?;
    if status != 200 {
        bail!("coordinator {coordinator} /shards/{shard}/meta -> \
               {status}: {}", json_err(&meta_doc));
    }
    let meta = ShardMeta::from_json(&meta_doc)
        .context("parsing shard meta")?;
    if meta.shard != shard {
        bail!("asked for shard {shard}, meta describes {}", meta.shard);
    }
    let want_chunks = meta.bytes.div_ceil(CHUNK_BYTES);
    if meta.n_chunks() != want_chunks {
        bail!("meta lists {} chunk digests for {} bytes (want {})",
              meta.n_chunks(), meta.bytes, want_chunks);
    }
    wm.chunks_total.store(want_chunks as u64, Relaxed);

    // Re-verify whatever a previous attempt spooled; keep the verified
    // prefix, drop the rest.
    let mut buf = std::fs::read(spool).unwrap_or_default();
    let mut verified = 0usize;
    for i in 0..want_chunks {
        let a = i * CHUNK_BYTES;
        let b = ((i + 1) * CHUNK_BYTES).min(meta.bytes);
        if buf.len() >= b && fnv64(&buf[a..b]) == meta.chunk_fnv[i] {
            verified += 1;
        } else {
            break;
        }
    }
    buf.truncate((verified * CHUNK_BYTES).min(meta.bytes));
    wm.resumed_chunks.store(verified as u64, Relaxed);
    wm.chunks_done.store(verified as u64, Relaxed);

    if let Some(parent) = spool.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {parent:?}"))?;
        }
    }
    let mut wire_bytes = 0usize;
    for i in verified..want_chunks {
        let off = i * CHUNK_BYTES;
        let len = CHUNK_BYTES.min(meta.bytes - off);
        if let Some(cap) = byte_budget {
            if wire_bytes + len > cap {
                bail!("fetch interrupted after {wire_bytes} wire bytes \
                       ({i} of {want_chunks} chunks verified and \
                       spooled; rerun to resume)");
            }
        }
        let path = format!("/shards/{shard}/data?off={off}&len={len}");
        let (status, chunk) = get_bytes(coordinator, &path, timeout)?;
        if status != 200 {
            bail!("coordinator {coordinator} {path} -> {status}: {}",
                  String::from_utf8_lossy(&chunk));
        }
        if chunk.len() != len {
            bail!("{path}: got {} bytes, asked for {len}", chunk.len());
        }
        if fnv64(&chunk) != meta.chunk_fnv[i] {
            bail!("shard {shard} chunk {i} failed its checksum in \
                   transit");
        }
        buf.extend_from_slice(&chunk);
        std::fs::write(spool, &buf)
            .with_context(|| format!("spooling to {spool:?}"))?;
        wire_bytes += len;
        wm.bytes_fetched.fetch_add(len as u64, Relaxed);
        wm.chunks_done.fetch_add(1, Relaxed);
    }
    if buf.len() != meta.bytes || fnv64(&buf) != meta.fnv {
        bail!("shard {shard} artifact failed its whole-file checksum");
    }
    let art = checkpoint::parse_shard(
        &buf, &format!("shard {shard} fetched from {coordinator}"))?;
    if art.shard != shard {
        bail!("fetched artifact says it is shard {} of {}, expected \
               shard {shard}", art.shard, art.n_shards);
    }
    let ms = t0.elapsed().as_millis() as u64;
    wm.fetch_ms.store(ms, Relaxed);
    Ok((art, FetchStats { fetch_ms: ms,
                          bytes_fetched: wire_bytes as u64,
                          resumed_chunks: verified as u64 }))
}

fn post_meta(coordinator: &str, shard: usize, timeout: Duration)
             -> Result<(u16, Json)> {
    let mut conn = ClientConn::new(connect(coordinator, timeout)?);
    conn.send_request("GET", &format!("/shards/{shard}/meta"), "")?;
    let (status, headers) = conn.read_head()?;
    let n: usize = header(&headers, "content-length")
        .ok_or_else(|| anyhow!("meta response without Content-Length"))?
        .parse()?;
    let text = conn.read_body(n)?;
    let doc = Json::parse(&text)
        .map_err(|e| anyhow!("bad meta JSON: {e}"))?;
    Ok((status, doc))
}

// ---- the worker server -------------------------------------------------

/// Where a worker's shard artifact comes from.
pub enum ShardSource {
    /// Load an `osp shard` output file directly (same machine).
    File(PathBuf),
    /// Checksummed resumable fetch from the coordinator's `/shards`
    /// endpoints. `byte_budget` caps wire bytes (None = unlimited).
    Fetch {
        coordinator: String,
        spool: PathBuf,
        byte_budget: Option<usize>,
    },
}

pub struct WorkerOpts {
    /// Bind address; port 0 picks an ephemeral port.
    pub addr: String,
    /// Which shard this worker serves.
    pub shard: usize,
    /// Expected fleet size; 0 = accept whatever the artifact says.
    pub n_shards: usize,
    pub source: ShardSource,
    pub int_mode: IntMode,
    pub max_body_bytes: usize,
}

impl WorkerOpts {
    pub fn new(addr: &str, shard: usize, source: ShardSource)
               -> WorkerOpts {
        WorkerOpts { addr: addr.into(), shard, n_shards: 0, source,
                     int_mode: IntMode::Auto,
                     max_body_bytes: 4 << 20 }
    }
}

struct WorkerCtl {
    shard: usize,
    backend: Backend,
    ready: AtomicBool,
    draining: AtomicBool,
    n_shards: AtomicU64,
    weight_bytes: AtomicU64,
    failed: Mutex<Option<String>>,
    metrics: WorkerMetrics,
    entries: RwLock<Vec<ShardEntry>>,
}

/// A running worker. Binds immediately (health/metrics respond while
/// the shard loads); `drain()` + `join()` is the clean shutdown path.
pub struct WorkerServer {
    addr: SocketAddr,
    ctl: Arc<WorkerCtl>,
    handle: Option<thread::JoinHandle<()>>,
}

impl WorkerServer {
    pub fn spawn(opts: WorkerOpts) -> Result<WorkerServer> {
        let backend = opts.int_mode.backend().ok_or_else(|| anyhow!(
            "worker requires the integer kernel path (int mode \
             scalar|auto)"))?;
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("bind {}", opts.addr))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let ctl = Arc::new(WorkerCtl {
            shard: opts.shard,
            backend,
            ready: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            n_shards: AtomicU64::new(opts.n_shards as u64),
            weight_bytes: AtomicU64::new(0),
            failed: Mutex::new(None),
            metrics: WorkerMetrics::default(),
            entries: RwLock::new(Vec::new()),
        });
        let ctl2 = Arc::clone(&ctl);
        let handle = thread::Builder::new()
            .name(format!("osp-worker-{}", opts.shard))
            .spawn(move || worker_loop(opts, listener, &ctl2))?;
        Ok(WorkerServer { addr, ctl, handle: Some(handle) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn is_ready(&self) -> bool {
        self.ctl.ready.load(SeqCst)
    }

    /// The load error, if acquiring the shard failed (the worker then
    /// drains itself).
    pub fn load_error(&self) -> Option<String> {
        self.ctl.failed.lock().unwrap().clone()
    }

    pub fn drain(&self) {
        self.ctl.draining.store(true, SeqCst);
    }

    pub fn is_done(&self) -> bool {
        self.ctl.draining.load(SeqCst)
    }

    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn load_shard_set(opts: &WorkerOpts, ctl: &WorkerCtl) -> Result<()> {
    let art = match &opts.source {
        ShardSource::File(path) => checkpoint::load_shard(path)?,
        ShardSource::Fetch { coordinator, spool, byte_budget } => {
            fetch_shard(coordinator, opts.shard, spool, *byte_budget,
                        &ctl.metrics)?.0
        }
    };
    if art.shard != opts.shard {
        bail!("artifact is shard {} of {}, this worker serves shard {}",
              art.shard, art.n_shards, opts.shard);
    }
    if opts.n_shards != 0 && art.n_shards != opts.n_shards {
        bail!("artifact was cut for {} workers, fleet has {}",
              art.n_shards, opts.n_shards);
    }
    let bytes: usize = art.entries.iter()
        .map(|e| e.q.packed_bytes())
        .sum();
    ctl.weight_bytes.store(bytes as u64, SeqCst);
    ctl.n_shards.store(art.n_shards as u64, SeqCst);
    *ctl.entries.write().unwrap() = art.entries;
    ctl.ready.store(true, SeqCst);
    Ok(())
}

fn worker_loop(opts: WorkerOpts, listener: TcpListener,
               ctl: &Arc<WorkerCtl>) {
    // Acquire the shard on a helper thread so health/metrics probes
    // (and the coordinator's readiness poller) get answers during a
    // long fetch.
    let ctl2 = Arc::clone(ctl);
    let opts = Arc::new(opts);
    let opts2 = Arc::clone(&opts);
    let loader = thread::Builder::new()
        .name("osp-worker-load".into())
        .spawn(move || {
            if let Err(e) = load_shard_set(&opts2, &ctl2) {
                eprintln!("worker {}: shard load failed: {e:#}",
                          opts2.shard);
                *ctl2.failed.lock().unwrap() = Some(format!("{e:#}"));
                ctl2.draining.store(true, SeqCst);
            }
        })
        .expect("spawn worker loader");
    loop {
        if ctl.draining.load(SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                handle_worker_conn(stream, ctl, opts.max_body_bytes);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(2));
            }
            Err(_) => thread::sleep(Duration::from_millis(2)),
        }
    }
    let _ = loader.join();
    // The zero-leak drain line CI greps on every worker process.
    println!("worker {} drained; {} rpcs served, {} stripes in flight",
             ctl.shard, ctl.metrics.rpcs_served.load(Relaxed),
             ctl.metrics.rpc_in_flight.load(Relaxed));
}

fn worker_status_json(ctl: &WorkerCtl) -> Json {
    let m = &ctl.metrics;
    let q = |p: f64| match m.stripe_lat.quantile(p) {
        Some(ms) => Json::num(ms),
        None => Json::Null,
    };
    Json::obj(vec![
        ("shard", Json::num(ctl.shard as f64)),
        ("n_shards", Json::num(ctl.n_shards.load(SeqCst) as f64)),
        ("ready", Json::Bool(ctl.ready.load(SeqCst))),
        ("draining", Json::Bool(ctl.draining.load(SeqCst))),
        ("backend", Json::str(ctl.backend.label())),
        ("weight_bytes",
         Json::num(ctl.weight_bytes.load(SeqCst) as f64)),
        ("rpcs_served", Json::num(m.rpcs_served.load(Relaxed) as f64)),
        ("rpc_in_flight",
         Json::num(m.rpc_in_flight.load(Relaxed) as f64)),
        ("fetch_ms", Json::num(m.fetch_ms.load(Relaxed) as f64)),
        ("bytes_fetched",
         Json::num(m.bytes_fetched.load(Relaxed) as f64)),
        ("chunks_done", Json::num(m.chunks_done.load(Relaxed) as f64)),
        ("chunks_total",
         Json::num(m.chunks_total.load(Relaxed) as f64)),
        ("resumed_chunks",
         Json::num(m.resumed_chunks.load(Relaxed) as f64)),
        ("stripe_p50_ms", q(0.50)),
        ("stripe_p95_ms", q(0.95)),
        ("error", match &*ctl.failed.lock().unwrap() {
            Some(msg) => Json::str(msg.clone()),
            None => Json::Null,
        }),
    ])
}

fn handle_worker_conn(mut stream: TcpStream, ctl: &WorkerCtl,
                      max_body: usize) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let req = match http::read_request(&mut stream, max_body) {
        Ok(r) => r,
        Err(e) => {
            if let Some((status, msg)) = e.status() {
                let body = Json::obj(vec![("error", Json::str(msg))])
                    .dump();
                let _ = http::write_response(&mut stream, status, &[],
                                             &body);
            }
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let body = Json::obj(vec![
                ("ok", Json::Bool(ctl.failed.lock().unwrap().is_none())),
                ("ready", Json::Bool(ctl.ready.load(SeqCst))),
                ("shard", Json::num(ctl.shard as f64)),
                ("draining",
                 Json::Bool(ctl.draining.load(SeqCst))),
            ]).dump();
            let _ = http::write_response(&mut stream, 200, &[], &body);
        }
        ("GET", "/metrics") => {
            let _ = http::write_response(
                &mut stream, 200, &[], &worker_status_json(ctl).dump());
        }
        ("POST", "/admin/drain") => {
            let body = Json::obj(vec![("draining", Json::Bool(true))])
                .dump();
            let _ = http::write_response(&mut stream, 200, &[], &body);
            ctl.draining.store(true, SeqCst);
        }
        ("POST", "/matmul") => {
            let (status, body) = handle_matmul(ctl, &req.body);
            // Not-ready 503s carry a pacing hint for the pool's
            // Retry-After-aware backoff (§15).
            let extra: &[(&str, &str)] = if status == 503 {
                &[("Retry-After", "1")]
            } else {
                &[]
            };
            let _ = http::write_response(&mut stream, status, extra,
                                         &body);
        }
        _ => {
            let body = Json::obj(vec![
                ("error", Json::str("no such endpoint")),
            ]).dump();
            let _ = http::write_response(&mut stream, 404, &[], &body);
        }
    }
}

struct MatmulReq {
    op: String,
    row: bool,
    acts: QuantActs,
}

/// Validate a `/matmul` body. Everything the kernels would `assert!`
/// on is rejected here with a 400 instead — worker threads must not
/// die on a malformed peer.
fn parse_matmul(body: &[u8]) -> Result<MatmulReq, String> {
    let text = std::str::from_utf8(body)
        .map_err(|_| "body is not UTF-8".to_string())?;
    let doc = Json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let op = doc.get("op").and_then(|v| v.as_str())
        .ok_or("missing 'op'")?.to_string();
    let row = match doc.get("kind").and_then(|v| v.as_str()) {
        Some("col") => false,
        Some("row") => true,
        _ => return Err("'kind' must be \"col\" or \"row\"".into()),
    };
    let m = doc.get("m").and_then(|v| v.as_usize()).filter(|&m| m > 0)
        .ok_or("'m' must be a positive integer")?;
    let k = doc.get("k").and_then(|v| v.as_usize()).filter(|&k| k > 0)
        .ok_or("'k' must be a positive integer")?;
    if k >= MAX_INT_K {
        return Err(format!("k {k} >= int-kernel cap {MAX_INT_K}"));
    }
    let codes_arr = doc.get("codes").and_then(|v| v.as_arr())
        .ok_or("missing 'codes' array")?;
    if codes_arr.len() != m * k {
        return Err(format!("{} codes for m*k = {}", codes_arr.len(),
                           m * k));
    }
    let mut codes = Vec::with_capacity(m * k);
    for v in codes_arr {
        let c = v.as_f64().filter(|x| x.fract() == 0.0)
            .map(|x| x as i64)
            .filter(|&x| (-128..=127).contains(&x))
            .ok_or("codes must be integers in [-128, 127]")?;
        codes.push(c as i8);
    }
    let scales_arr = doc.get("scales").and_then(|v| v.as_arr())
        .ok_or("missing 'scales' array")?;
    if scales_arr.len() != m {
        return Err(format!("{} scales for m = {m}", scales_arr.len()));
    }
    let mut scales = Vec::with_capacity(m);
    for v in scales_arr {
        let s = v.as_f64().filter(|x| x.is_finite())
            .ok_or("scales must be finite numbers")?;
        scales.push(s as f32);
    }
    Ok(MatmulReq { op, row,
                   acts: QuantActs::from_parts(codes, scales, m, k) })
}

fn handle_matmul(ctl: &WorkerCtl, body: &[u8]) -> (u16, String) {
    let err = |status: u16, msg: &str| {
        (status,
         Json::obj(vec![("error", Json::str(msg))]).dump())
    };
    if !ctl.ready.load(SeqCst) {
        return err(503, "shard not loaded yet");
    }
    let req = match parse_matmul(body) {
        Ok(r) => r,
        Err(msg) => return err(400, &msg),
    };
    ctl.metrics.rpc_in_flight.fetch_add(1, SeqCst);
    let out = run_matmul(ctl, &req);
    ctl.metrics.rpc_in_flight.fetch_sub(1, SeqCst);
    match out {
        Ok(doc) => {
            ctl.metrics.rpcs_served.fetch_add(1, Relaxed);
            (200, doc.dump())
        }
        Err(msg) => err(400, &msg),
    }
}

fn run_matmul(ctl: &WorkerCtl, req: &MatmulReq)
              -> Result<Json, String> {
    let entries = ctl.entries.read().unwrap();
    let e = entries.iter().find(|e| e.name == req.op)
        .ok_or_else(|| format!("no shard entry for op '{}'", req.op))?;
    let want_row = e.kind == ShardKind::Row;
    if want_row != req.row {
        return Err(format!("op '{}' is {}-parallel, request says {}",
                           req.op, e.kind.label(),
                           if req.row { "row" } else { "col" }));
    }
    if req.acts.k() != e.q.rows() {
        return Err(format!("op '{}' wants k = {}, request has k = {}",
                           req.op, e.q.rows(), req.acts.k()));
    }
    let t0 = Instant::now();
    let doc = if req.row {
        let n = e.q.cols();
        let mut acc = vec![0i32; req.acts.m() * n];
        e.q.accumulate_int(&req.acts, ctl.backend, &mut acc);
        Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("partial",
             Json::Arr(acc.iter().map(|&v| Json::num(v as f64))
                       .collect())),
        ])
    } else {
        let stripe =
            e.q.qmatmul_rhs_int_with(None, &req.acts, ctl.backend);
        Json::obj(vec![
            ("j0", Json::num(e.off as f64)),
            ("j1", Json::num((e.off + e.q.cols()) as f64)),
            ("stripe",
             Json::Arr(stripe.data().iter()
                       .map(|&v| Json::num(v as f64)).collect())),
        ])
    };
    ctl.metrics.stripe_lat.record(t0.elapsed());
    Ok(doc)
}

// ---- the coordinator-side HTTP shard pool ------------------------------

/// [`ShardCompute`] over a worker fleet reached through the std HTTP
/// layer. Owns fan-out (one thread per *shard* per call — the fleet
/// is small), replica failover, and the rpc counters the
/// coordinator's `/metrics`/`/status` publish.
///
/// With `--replicas R` (DESIGN.md §15) the fleet is larger than the
/// shard count: worker `w` serves shard `w % n_shards`
/// ([`crate::coordinator::shard::replica_assignment`]). Each stripe
/// rpc walks its shard's replicas in health order (Up, Suspect, then
/// Rejoining; breaker-open workers skipped), failing over mid-call on
/// transport errors — output-preserving because any replica returns
/// bit-identical integer results. Attempt rounds are paced by
/// [`health::retry_delay`] (capped exponential backoff, deterministic
/// seeded jitter, `Retry-After`-aware). When every replica of a shard
/// is down the rpc returns the `shard N uncovered` error that the
/// serve layer turns into retryable 503s — never wrong tokens.
pub struct HttpShardPool {
    workers: Vec<String>,
    n_shards: usize,
    health: Arc<HealthRegistry>,
    timeout: Duration,
    pub rpcs_ok: AtomicU64,
    pub rpcs_retried: AtomicU64,
    pub per_worker_ok: Vec<AtomicU64>,
    /// Round-trip latency of successful partial-stripe rpcs.
    pub stripe_lat: LatHist,
}

impl HttpShardPool {
    /// One worker per shard — the unreplicated PR-9 layout, with a
    /// default-knob private health registry.
    pub fn new(workers: Vec<String>) -> HttpShardPool {
        let n = workers.len();
        let health = Arc::new(HealthRegistry::new(
            n, n, HealthOpts::default()));
        HttpShardPool::with_health(workers, n, health)
    }

    /// Replicated fleet: `workers[w]` serves shard `w % n_shards`.
    /// `health` is shared with the serve front-end's prober thread.
    pub fn with_health(workers: Vec<String>, n_shards: usize,
                       health: Arc<HealthRegistry>) -> HttpShardPool {
        assert_eq!(health.n_workers(), workers.len(),
                   "health registry sized for a different fleet");
        assert_eq!(health.n_shards(), n_shards,
                   "health registry cut for a different shard count");
        let n = workers.len();
        HttpShardPool {
            workers,
            n_shards,
            health,
            timeout: Duration::from_secs(30),
            rpcs_ok: AtomicU64::new(0),
            rpcs_retried: AtomicU64::new(0),
            per_worker_ok: (0..n).map(|_| AtomicU64::new(0)).collect(),
            stripe_lat: LatHist::default(),
        }
    }

    pub fn worker_addrs(&self) -> &[String] {
        &self.workers
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub fn health(&self) -> &Arc<HealthRegistry> {
        &self.health
    }

    /// Pool counters for the coordinator's metrics endpoints. The
    /// cross-process conservation invariant: `rpcs_ok` here never
    /// exceeds the sum of the workers' `rpcs_served`.
    pub fn to_json(&self) -> Json {
        let q = |p: f64| match self.stripe_lat.quantile(p) {
            Some(ms) => Json::num(ms),
            None => Json::Null,
        };
        Json::obj(vec![
            ("workers", Json::num(self.workers.len() as f64)),
            ("shards", Json::num(self.n_shards as f64)),
            ("rpcs_ok", Json::num(self.rpcs_ok.load(Relaxed) as f64)),
            ("rpcs_retried",
             Json::num(self.rpcs_retried.load(Relaxed) as f64)),
            ("per_worker_rpcs_ok",
             Json::Arr(self.per_worker_ok.iter()
                       .map(|c| Json::num(c.load(Relaxed) as f64))
                       .collect())),
            ("stripe_p50_ms", q(0.50)),
            ("stripe_p95_ms", q(0.95)),
        ])
    }

    /// One stripe rpc for `shard`, with replica failover inside the
    /// call: every attempt round walks the shard's live replicas in
    /// health order before sleeping. A reply from any replica is
    /// bit-identical, so failover never perturbs the stream.
    fn rpc_shard(&self, shard: usize, body: &str) -> Result<Json> {
        let h = &self.health;
        let o = h.opts.clone();
        let mut last: Option<anyhow::Error> = None;
        let mut hint: Option<u64> = None;
        for attempt in 0..o.retries {
            if attempt > 0 {
                self.rpcs_retried.fetch_add(1, Relaxed);
                thread::sleep(health::retry_delay(
                    o.backoff_base_ms, o.backoff_cap_ms, attempt,
                    o.seed, shard as u64, hint.take()));
            }
            let order = h.route_order(shard);
            if order.is_empty() {
                // Breaker open on every replica: shed fast; only the
                // prober can bring a worker back into rotation.
                break;
            }
            for (choice, &w) in order.iter().enumerate() {
                let addr = &self.workers[w];
                let t0 = Instant::now();
                match post_json_hdrs(addr, "/matmul", body,
                                     self.timeout) {
                    Ok((200, doc, _)) => {
                        h.record_ready(w);
                        if choice > 0 {
                            h.failovers.fetch_add(1, Relaxed);
                        }
                        self.stripe_lat.record(t0.elapsed());
                        self.rpcs_ok.fetch_add(1, Relaxed);
                        self.per_worker_ok[w].fetch_add(1, Relaxed);
                        return Ok(doc);
                    }
                    Ok((503, doc, headers)) => {
                        // Alive but not ready (loading/draining): not
                        // a transport failure — honor its pacing hint.
                        hint = header(&headers, "retry-after")
                            .and_then(|v| v.trim().parse::<u64>().ok())
                            .map(|s| s.saturating_mul(1000))
                            .or(hint);
                        last = Some(anyhow!(
                            "worker {addr} not ready (503): {}",
                            json_err(&doc)));
                    }
                    Ok((status, doc, _)) => {
                        // A semantic rejection is the same on every
                        // replica; neither retry nor failover helps.
                        bail!("worker {addr} /matmul -> {status}: {}",
                              json_err(&doc));
                    }
                    Err(e) => {
                        h.record_failure(w);
                        last = Some(e);
                    }
                }
            }
        }
        let detail = match last {
            Some(e) => format!("; last error: {e:#}"),
            None => "; breaker open on every replica".to_string(),
        };
        bail!("shard {shard} uncovered after {} attempts{detail}",
              o.retries)
    }
}

fn matmul_body(op: &str, kind: &str, acts: &QuantActs) -> String {
    let (m, k) = (acts.m(), acts.k());
    let mut codes = Vec::with_capacity(m * k);
    let mut scales = Vec::with_capacity(m);
    for r in 0..m {
        codes.extend(acts.row_codes(r).iter()
                     .map(|&c| Json::num(c as f64)));
        scales.push(Json::num(acts.scale(r) as f64));
    }
    Json::obj(vec![
        ("op", Json::str(op)),
        ("kind", Json::str(kind)),
        ("m", Json::num(m as f64)),
        ("k", Json::num(k as f64)),
        ("codes", Json::Arr(codes)),
        ("scales", Json::Arr(scales)),
    ]).dump()
}

fn parse_f32_arr(doc: &Json, key: &str) -> Result<Vec<f32>> {
    doc.get(key).and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("reply missing '{key}'"))?
        .iter()
        .map(|v| v.as_f64().map(|x| x as f32)
             .ok_or_else(|| anyhow!("non-numeric '{key}' element")))
        .collect()
}

fn parse_i32_arr(doc: &Json, key: &str) -> Result<Vec<i32>> {
    doc.get(key).and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("reply missing '{key}'"))?
        .iter()
        .map(|v| v.as_f64().filter(|x| x.fract() == 0.0)
             .map(|x| x as i32)
             .ok_or_else(|| anyhow!("non-integer '{key}' element")))
        .collect()
}

impl ShardCompute for HttpShardPool {
    /// Partition count — stripes/slices per call. The physical fleet
    /// (`worker_addrs`) may be `replicas`× larger.
    fn n_workers(&self) -> usize {
        self.n_shards
    }

    fn col_stripes(&self, op: &str, acts: &QuantActs)
                   -> Result<Vec<Vec<f32>>> {
        let body = matmul_body(op, "col", acts);
        let ns = self.n_shards;
        let mut out: Vec<Result<Vec<f32>>> = Vec::with_capacity(ns);
        thread::scope(|s| {
            let handles: Vec<_> = (0..ns)
                .map(|shard| {
                    let body = &body;
                    s.spawn(move || {
                        parse_f32_arr(&self.rpc_shard(shard, body)?,
                                      "stripe")
                    })
                })
                .collect();
            for h in handles {
                out.push(h.join().unwrap_or_else(|_| {
                    Err(anyhow!("rpc thread panicked"))
                }));
            }
        });
        out.into_iter().collect()
    }

    fn row_partials(&self, op: &str, slices: &[QuantActs])
                    -> Result<Vec<Vec<i32>>> {
        let ns = self.n_shards;
        anyhow::ensure!(slices.len() == ns,
                        "{} slices for {ns} shards", slices.len());
        let bodies: Vec<String> = slices.iter()
            .map(|sl| matmul_body(op, "row", sl))
            .collect();
        let mut out: Vec<Result<Vec<i32>>> = Vec::with_capacity(ns);
        thread::scope(|s| {
            let handles: Vec<_> = (0..ns)
                .map(|shard| {
                    let body = &bodies[shard];
                    s.spawn(move || {
                        parse_i32_arr(&self.rpc_shard(shard, body)?,
                                      "partial")
                    })
                })
                .collect();
            for h in handles {
                out.push(h.join().unwrap_or_else(|_| {
                    Err(anyhow!("rpc thread panicked"))
                }));
            }
        });
        out.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::remote::{shard_range, LocalShards, ShardSet};
    use crate::quant::rtn::quantize_per_channel_q;
    use crate::serve::health::HealthState;
    use crate::serve::storage::{self, LocalDir, Manifest,
                                ManifestEntry};
    use crate::tensor::qtensor::QTensor;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg;

    fn random_q(rng: &mut Pcg, k: usize, n: usize, bits: u32)
                -> QTensor {
        let mut t = Tensor::zeros(&[k, n]);
        rng.fill_normal(t.data_mut(), 0.1);
        quantize_per_channel_q(&t, bits)
    }

    fn random_acts(rng: &mut Pcg, m: usize, k: usize) -> QuantActs {
        let codes: Vec<i8> = (0..m * k)
            .map(|_| (rng.below(16) as i64 - 8) as i8)
            .collect();
        let scales: Vec<f32> =
            (0..m).map(|r| 0.04 + 0.01 * r as f32).collect();
        QuantActs::from_parts(codes, scales, m, k)
    }

    /// Two-op shard sets (one Col, one Row) over `shards` workers.
    fn two_op_sets(qc: &QTensor, qr: &QTensor, shards: usize)
                   -> Vec<ShardSet> {
        (0..shards)
            .map(|w| {
                let (j0, j1) = shard_range(qc.cols(), shards, w);
                let (k0, k1) = shard_range(qr.rows(), shards, w);
                vec![
                    ShardEntry { name: "L0.wq".into(),
                                 kind: ShardKind::Col,
                                 full_k: qc.rows(), full_n: qc.cols(),
                                 off: j0, q: qc.shard_cols(j0, j1) },
                    ShardEntry { name: "L0.wo".into(),
                                 kind: ShardKind::Row,
                                 full_k: qr.rows(), full_n: qr.cols(),
                                 off: k0, q: qr.shard_rows(k0, k1) },
                ]
            })
            .collect()
    }

    fn temp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("osp_worker_test_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn wait_ready(ws: &[&WorkerServer]) {
        let t0 = Instant::now();
        while !ws.iter().all(|w| w.is_ready()) {
            assert!(t0.elapsed() < Duration::from_secs(20),
                    "workers never became ready: {:?}",
                    ws.iter().map(|w| w.load_error()).collect::<Vec<_>>());
            thread::sleep(Duration::from_millis(5));
        }
    }

    /// The §14 transport invariant: HTTP recombination is bitwise the
    /// in-process [`LocalShards`] recombination for both shard kinds.
    #[test]
    fn http_pool_matches_local_shards_bitwise() {
        let dir = temp("pool");
        let mut rng = Pcg::new(41, 0);
        let qc = random_q(&mut rng, 20, 14, 4);
        let qr = random_q(&mut rng, 24, 9, 4);
        let acts = random_acts(&mut rng, 2, 20);
        let shards = 2;
        for (w, set) in two_op_sets(&qc, &qr, shards).into_iter()
            .enumerate()
        {
            checkpoint::save_shard(&dir.join(format!("shard_{w}.bin")),
                                   w, shards, "ssnorm_plain", &set)
                .unwrap();
        }
        let workers: Vec<WorkerServer> = (0..shards)
            .map(|w| {
                let mut o = WorkerOpts::new(
                    "127.0.0.1:0", w,
                    ShardSource::File(
                        dir.join(format!("shard_{w}.bin"))));
                o.int_mode = IntMode::Scalar;
                o.n_shards = shards;
                WorkerServer::spawn(o).unwrap()
            })
            .collect();
        wait_ready(&workers.iter().collect::<Vec<_>>());
        let pool = HttpShardPool::new(
            workers.iter().map(|w| w.addr().to_string()).collect());
        let local = LocalShards::new(two_op_sets(&qc, &qr, shards),
                                     Backend::Scalar);
        assert_eq!(pool.col_stripes("L0.wq", &acts).unwrap(),
                   local.col_stripes("L0.wq", &acts).unwrap());
        let slices: Vec<QuantActs> = (0..shards)
            .map(|w| {
                let (k0, k1) = shard_range(24, shards, w);
                crate::model::remote::slice_acts(
                    &random_acts(&mut Pcg::new(42, 0), 2, 24), k0, k1)
            })
            .collect();
        assert_eq!(pool.row_partials("L0.wo", &slices).unwrap(),
                   local.row_partials("L0.wo", &slices).unwrap());
        // Conservation: every pool success was served by a worker.
        let served: u64 = workers.iter()
            .map(|w| w.ctl.metrics.rpcs_served.load(Relaxed))
            .sum();
        assert_eq!(pool.rpcs_ok.load(Relaxed), served);
        assert_eq!(pool.rpcs_ok.load(Relaxed),
                   pool.per_worker_ok.iter()
                       .map(|c| c.load(Relaxed)).sum::<u64>());
        for w in workers {
            w.drain();
            w.join();
        }
    }

    /// §15 failover at the pool level: two replicas of one shard,
    /// kill the one serving traffic, and the rpc reroutes mid-call —
    /// bit-identically. With both replicas dead the pool reports the
    /// shard uncovered (after tripping both breakers) instead of
    /// hanging or panicking.
    #[test]
    fn pool_fails_over_to_replica_then_reports_uncovered() {
        let dir = temp("failover");
        let mut rng = Pcg::new(45, 0);
        let qc = random_q(&mut rng, 18, 12, 4);
        let qr = random_q(&mut rng, 20, 7, 4);
        let acts = random_acts(&mut rng, 2, 18);
        // A 1-shard cut served by two replica workers.
        let set = two_op_sets(&qc, &qr, 1).remove(0);
        let path = dir.join("shard_0.bin");
        checkpoint::save_shard(&path, 0, 1, "ssnorm_plain", &set)
            .unwrap();
        let spawn_one = || {
            let mut o = WorkerOpts::new("127.0.0.1:0", 0,
                                        ShardSource::File(path.clone()));
            o.int_mode = IntMode::Scalar;
            o.n_shards = 1;
            WorkerServer::spawn(o).unwrap()
        };
        let (w0, w1) = (spawn_one(), spawn_one());
        wait_ready(&[&w0, &w1]);
        let health = Arc::new(HealthRegistry::new(
            2, 1, HealthOpts::default()));
        let pool = HttpShardPool::with_health(
            vec![w0.addr().to_string(), w1.addr().to_string()],
            1, Arc::clone(&health));
        let local = LocalShards::new(two_op_sets(&qc, &qr, 1),
                                     Backend::Scalar);
        let want = local.col_stripes("L0.wq", &acts).unwrap();
        // Healthy call routes to the primary and matches local math.
        assert_eq!(pool.col_stripes("L0.wq", &acts).unwrap(), want);
        assert_eq!(health.state(0), HealthState::Up);
        // Kill the primary: the same call fails over to the replica,
        // still bitwise-identical, and counts the reroute.
        w0.drain();
        w0.join();
        assert_eq!(pool.col_stripes("L0.wq", &acts).unwrap(), want);
        assert!(health.failovers.load(Relaxed) >= 1,
                "failover not counted");
        // Kill the replica too: uncovered, with both breakers tripped
        // and a typed error instead of a panic.
        w1.drain();
        w1.join();
        let err = pool.col_stripes("L0.wq", &acts)
            .unwrap_err().to_string();
        assert!(err.contains("shard 0 uncovered"), "{err}");
        assert_eq!(health.breaker_trips.load(Relaxed), 2);
        assert_eq!(health.route_order(0), Vec::<usize>::new());
        // Conservation still holds: every pool success has a serving
        // worker behind it.
        assert_eq!(pool.rpcs_ok.load(Relaxed),
                   pool.per_worker_ok.iter()
                       .map(|c| c.load(Relaxed)).sum::<u64>());
    }

    #[test]
    fn matmul_rejects_malformed_unknown_and_mismatched() {
        let dir = temp("rej");
        let mut rng = Pcg::new(43, 0);
        let qc = random_q(&mut rng, 16, 10, 4);
        let qr = random_q(&mut rng, 16, 6, 4);
        let set = two_op_sets(&qc, &qr, 1).remove(0);
        let path = dir.join("shard_0.bin");
        checkpoint::save_shard(&path, 0, 1, "ssnorm_plain", &set)
            .unwrap();
        let mut o = WorkerOpts::new("127.0.0.1:0", 0,
                                    ShardSource::File(path));
        o.int_mode = IntMode::Scalar;
        let w = WorkerServer::spawn(o).unwrap();
        wait_ready(&[&w]);
        let addr = w.addr().to_string();
        let post = |body: &str| {
            post_json(&addr, "/matmul", body,
                      Duration::from_secs(5)).unwrap()
        };
        assert_eq!(post("{not json").0, 400);
        let acts = random_acts(&mut rng, 1, 16);
        let bad_op = matmul_body("L9.nope", "col", &acts);
        assert_eq!(post(&bad_op).0, 400);
        // Kind mismatch: L0.wo is row-parallel.
        let bad_kind = matmul_body("L0.wo", "col", &acts);
        assert_eq!(post(&bad_kind).0, 400);
        // Wrong contraction depth.
        let bad_k = matmul_body("L0.wq", "col",
                                &random_acts(&mut rng, 1, 12));
        assert_eq!(post(&bad_k).0, 400);
        // And a well-formed request still works afterwards.
        assert_eq!(post(&matmul_body("L0.wq", "col", &acts)).0, 200);
        w.drain();
        w.join();
    }

    // ---- fetch protocol tests ------------------------------------------

    /// Bare listener serving `/shards/...` from a storage backend —
    /// the coordinator's fetch surface without booting a model.
    struct MiniShardServer {
        addr: SocketAddr,
        stop: Arc<AtomicBool>,
        handle: Option<thread::JoinHandle<()>>,
    }

    impl MiniShardServer {
        fn spawn(store: Arc<dyn StorageBackend>) -> MiniShardServer {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            listener.set_nonblocking(true).unwrap();
            let addr = listener.local_addr().unwrap();
            let stop = Arc::new(AtomicBool::new(false));
            let stop2 = Arc::clone(&stop);
            let handle = thread::spawn(move || loop {
                if stop2.load(SeqCst) {
                    break;
                }
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        let _ = stream.set_read_timeout(
                            Some(Duration::from_secs(5)));
                        if let Ok(req) =
                            http::read_request(&mut stream, 1024)
                        {
                            let (st, ct, body) = shards_http_response(
                                &req.path, &*store);
                            let _ = http::write_response_bytes(
                                &mut stream, st, &[], ct, &body);
                        }
                    }
                    Err(_) => {
                        thread::sleep(Duration::from_millis(2))
                    }
                }
            });
            MiniShardServer { addr, stop, handle: Some(handle) }
        }

        fn addr(&self) -> String {
            self.addr.to_string()
        }
    }

    impl Drop for MiniShardServer {
        fn drop(&mut self) {
            self.stop.store(true, SeqCst);
            if let Some(h) = self.handle.take() {
                let _ = h.join();
            }
        }
    }

    /// A storage backend that corrupts one byte in transit — the
    /// artifact on disk (and thus `meta`) stays honest, so only the
    /// chunk checksum can catch it.
    struct FlippingStore {
        inner: LocalDir,
        flip_at: usize,
    }

    impl StorageBackend for FlippingStore {
        fn n_shards(&self) -> usize {
            self.inner.n_shards()
        }
        fn meta(&self, shard: usize) -> Result<ShardMeta> {
            self.inner.meta(shard)
        }
        fn read(&self, shard: usize, offset: usize, len: usize)
                -> Result<Vec<u8>> {
            let mut b = self.inner.read(shard, offset, len)?;
            if (offset..offset + len).contains(&self.flip_at) {
                b[self.flip_at - offset] ^= 1;
            }
            Ok(b)
        }
    }

    /// Publish one multi-chunk artifact; returns (dir, total bytes).
    fn publish_big(tag: &str) -> (PathBuf, usize) {
        let dir = temp(tag);
        let mut rng = Pcg::new(44, 0);
        // ~148 KiB packed -> 3 chunks at 64 KiB.
        let q = random_q(&mut rng, 768, 384, 4);
        let qr = random_q(&mut rng, 16, 6, 4);
        let set = two_op_sets(&q, &qr, 1).remove(0);
        let path = dir.join("shard_0.bin");
        checkpoint::save_shard(&path, 0, 1, "ssnorm_plain", &set)
            .unwrap();
        let blob = std::fs::read(&path).unwrap();
        assert!(blob.len() > 2 * CHUNK_BYTES,
                "artifact too small to exercise chunking: {}",
                blob.len());
        let total = blob.len();
        storage::write_manifest(&dir, &Manifest {
            shards: 1,
            arch: "ssnorm_plain".into(),
            files: vec![ManifestEntry { file: "shard_0.bin".into(),
                                        bytes: total,
                                        fnv: fnv64(&blob) }],
        }).unwrap();
        (dir, total)
    }

    /// Interrupted fetch resumes from the last verified chunk instead
    /// of restarting (the satellite robustness contract).
    #[test]
    fn fetch_resumes_from_verified_chunks() {
        let (dir, total) = publish_big("resume");
        let store: Arc<dyn StorageBackend> =
            Arc::new(LocalDir::open(&dir).unwrap());
        let srv = MiniShardServer::spawn(store);
        let spool = dir.join("spool.part");
        let wm = WorkerMetrics::default();
        // Budget for exactly one chunk: the fetch dies mid-way...
        let err = fetch_shard(&srv.addr(), 0, &spool,
                              Some(CHUNK_BYTES + 10), &wm)
            .unwrap_err().to_string();
        assert!(err.contains("interrupted"), "{err}");
        let spooled = std::fs::read(&spool).unwrap().len();
        assert_eq!(spooled, CHUNK_BYTES, "one verified chunk spooled");
        // ...and the rerun picks up where it left off.
        let wm2 = WorkerMetrics::default();
        let (art, stats) =
            fetch_shard(&srv.addr(), 0, &spool, None, &wm2).unwrap();
        assert_eq!(stats.resumed_chunks, 1);
        assert_eq!(stats.bytes_fetched as usize, total - CHUNK_BYTES);
        assert_eq!(art.shard, 0);
        assert_eq!(art.entries.len(), 2);
    }

    #[test]
    fn fetch_rejects_corrupted_chunk_with_clean_error() {
        let (dir, _total) = publish_big("corrupt");
        let store: Arc<dyn StorageBackend> = Arc::new(FlippingStore {
            inner: LocalDir::open(&dir).unwrap(),
            flip_at: CHUNK_BYTES + 5, // inside chunk 1
        });
        let srv = MiniShardServer::spawn(store);
        let spool = dir.join("spool.part");
        let wm = WorkerMetrics::default();
        let err = fetch_shard(&srv.addr(), 0, &spool, None, &wm)
            .unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // Chunk 0 (clean) was still spooled for a future resume.
        assert_eq!(std::fs::read(&spool).unwrap().len(), CHUNK_BYTES);
    }

    /// A version-bumped artifact passes every checksum (the manifest
    /// is rebuilt to match) but is rejected by the OSPS parser — the
    /// version gate and the integrity gate are independent.
    #[test]
    fn fetch_rejects_version_mismatch_after_valid_transfer() {
        let (dir, _total) = publish_big("version");
        let path = dir.join("shard_0.bin");
        let mut blob = std::fs::read(&path).unwrap();
        blob[4] = 99; // version u32 LE lives right after the magic
        std::fs::write(&path, &blob).unwrap();
        storage::write_manifest(&dir, &Manifest {
            shards: 1,
            arch: "ssnorm_plain".into(),
            files: vec![ManifestEntry { file: "shard_0.bin".into(),
                                        bytes: blob.len(),
                                        fnv: fnv64(&blob) }],
        }).unwrap();
        let store: Arc<dyn StorageBackend> =
            Arc::new(LocalDir::open(&dir).unwrap());
        let srv = MiniShardServer::spawn(store);
        let wm = WorkerMetrics::default();
        let err = fetch_shard(&srv.addr(), 0, &dir.join("spool.part"),
                              None, &wm)
            .unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn shards_endpoint_rejects_bad_paths_and_ranges() {
        let (dir, total) = publish_big("paths");
        let store = LocalDir::open(&dir).unwrap();
        let code = |p: &str| shards_http_response(p, &store).0;
        assert_eq!(code("/shards/0/meta"), 200);
        assert_eq!(code("/shards/1/meta"), 404);
        assert_eq!(code("/shards/x/meta"), 404);
        assert_eq!(code("/shards/0/nope"), 404);
        assert_eq!(code("/shards/0/data?off=0&len=16"), 200);
        assert_eq!(code("/shards/0/data?off=0"), 400);
        assert_eq!(code("/shards/0/data?off=0&len=0"), 400);
        assert_eq!(
            code(&format!("/shards/0/data?off={total}&len=1")), 400);
    }
}
