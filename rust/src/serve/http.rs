//! Minimal hand-rolled HTTP/1.1 (DESIGN.md §12): just enough protocol
//! for the streaming serve front-end — request-head + Content-Length
//! body parsing on the server side, chunked transfer encoding for the
//! per-token response stream, and a small blocking client used by the
//! load generator and the integration tests.
//!
//! The offline toolchain has no async runtime and no HTTP crates, so
//! everything here is `std` over blocking sockets. Robustness rules:
//! every malformed input maps to a typed [`HttpError`] (never a panic),
//! head and body sizes are hard-capped, and read timeouts installed on
//! the socket surface as [`HttpError::Timeout`] so slow-loris clients
//! are shed instead of pinning a handler thread.

use std::io::{self, Read, Write};

/// Upper bound on the request head (request line + headers).
pub const MAX_HEAD_BYTES: usize = 8192;

/// A parsed request. Header names are lowercased; values are trimmed.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Why a request could not be read. Each variant maps to one well-formed
/// rejection response (see [`HttpError::status`]).
#[derive(Debug)]
pub enum HttpError {
    /// Clean EOF before any request byte — client connected and left.
    Closed,
    /// Socket read timed out mid-head or mid-body (slow-loris).
    Timeout,
    /// Request head exceeded [`MAX_HEAD_BYTES`].
    HeadTooLarge,
    /// Declared Content-Length beyond the server's body cap.
    BodyTooLarge(usize),
    /// Body-carrying request without a Content-Length.
    LengthRequired,
    /// Anything else unparseable.
    BadRequest(String),
    Io(io::Error),
}

impl HttpError {
    /// The response this error earns, or None when the peer is simply
    /// gone and no response can be delivered.
    pub fn status(&self) -> Option<(u16, &'static str)> {
        match self {
            HttpError::Closed | HttpError::Io(_) => None,
            HttpError::Timeout => Some((408, "request timed out")),
            HttpError::HeadTooLarge => {
                Some((431, "request head too large"))
            }
            HttpError::BodyTooLarge(_) => Some((413, "body too large")),
            HttpError::LengthRequired => {
                Some((411, "Content-Length required"))
            }
            HttpError::BadRequest(_) => Some((400, "malformed request")),
        }
    }
}

fn timeout_kind(k: io::ErrorKind) -> bool {
    matches!(k, io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Read and parse one request from `r`. The transport is expected to
/// carry a read timeout (set on the socket by the caller); timeouts
/// surface as [`HttpError::Timeout`]. Bodies are only read for
/// Content-Length-framed requests and only up to `max_body` bytes —
/// an oversized declaration is rejected *before* the body is consumed.
pub fn read_request<R: Read>(r: &mut R, max_body: usize)
                             -> Result<Request, HttpError> {
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let mut tmp = [0u8; 1024];
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() >= MAX_HEAD_BYTES {
            return Err(HttpError::HeadTooLarge);
        }
        match r.read(&mut tmp) {
            Ok(0) if buf.is_empty() => return Err(HttpError::Closed),
            Ok(0) => {
                return Err(HttpError::BadRequest(
                    "eof inside request head".into()))
            }
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if timeout_kind(e.kind()) => {
                return Err(HttpError::Timeout)
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(HttpError::Io(e)),
        }
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| HttpError::BadRequest("non-utf8 head".into()))?;
    let mut lines = head.split("\r\n");
    let reqline = lines.next().unwrap_or("");
    let mut parts = reqline.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| HttpError::BadRequest("empty request line".into()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| HttpError::BadRequest("missing path".into()))?
        .to_string();
    match parts.next() {
        Some(v) if v.starts_with("HTTP/1.") => {}
        _ => {
            return Err(HttpError::BadRequest(
                "missing HTTP/1.x version".into()))
        }
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(HttpError::BadRequest(
                format!("malformed header line '{line}'")));
        };
        headers.push((k.trim().to_ascii_lowercase(),
                      v.trim().to_string()));
    }
    let mut req =
        Request { method, path, headers, body: Vec::new() };
    let content_len = match req.header("content-length") {
        Some(v) => Some(v.parse::<usize>().map_err(|_| {
            HttpError::BadRequest("bad Content-Length".into())
        })?),
        None => None,
    };
    // Only POSTs carry bodies here; a POST without framing is 411.
    if req.method == "POST" {
        let len = content_len.ok_or(HttpError::LengthRequired)?;
        if len > max_body {
            return Err(HttpError::BodyTooLarge(len));
        }
        let mut body = buf[head_end + 4..].to_vec();
        while body.len() < len {
            match r.read(&mut tmp) {
                Ok(0) => {
                    return Err(HttpError::BadRequest(
                        "eof inside body".into()))
                }
                Ok(n) => body.extend_from_slice(&tmp[..n]),
                Err(e) if timeout_kind(e.kind()) => {
                    return Err(HttpError::Timeout)
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(HttpError::Io(e)),
            }
        }
        body.truncate(len);
        req.body = body;
    }
    Ok(req)
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    }
}

/// Write a complete (non-streamed) JSON response. Every connection is
/// single-request (`Connection: close`) — the serve front-end trades
/// keep-alive for a radically simpler lifecycle.
pub fn write_response(w: &mut impl Write, status: u16,
                      extra: &[(&str, &str)], body: &str)
                      -> io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        reason(status), body.len());
    for (k, v) in extra {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

/// Write a complete response with an arbitrary content type and a raw
/// byte body — the shard-fetch data path (DESIGN.md §14) ships OSPS
/// artifact ranges as `application/octet-stream`, which must never
/// pass through a UTF-8 conversion.
pub fn write_response_bytes(w: &mut impl Write, status: u16,
                            extra: &[(&str, &str)], content_type: &str,
                            body: &[u8]) -> io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        reason(status), body.len());
    for (k, v) in extra {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Start a chunked (streaming) response; each subsequent
/// [`write_chunk`] delivers one newline-terminated JSON event.
pub fn start_chunked(w: &mut impl Write, status: u16) -> io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/x-ndjson\r\n\
         Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
        reason(status));
    w.write_all(head.as_bytes())?;
    w.flush()
}

/// One chunk of a streamed response (a single token/event line).
pub fn write_chunk(w: &mut impl Write, line: &str) -> io::Result<()> {
    let framed = format!("{:x}\r\n{line}\r\n", line.len());
    w.write_all(framed.as_bytes())?;
    w.flush()
}

/// Terminate a chunked response.
pub fn end_chunked(w: &mut impl Write) -> io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

/// Blocking single-request HTTP client (load generator + tests): sends
/// one request, then reads the status line, headers, and — for chunked
/// responses — one chunk at a time so per-token arrival times are
/// observable.
pub struct ClientConn<S: Read + Write> {
    stream: S,
    buf: Vec<u8>,
    pos: usize,
}

impl<S: Read + Write> ClientConn<S> {
    pub fn new(stream: S) -> ClientConn<S> {
        ClientConn { stream, buf: Vec::new(), pos: 0 }
    }

    pub fn stream_mut(&mut self) -> &mut S {
        &mut self.stream
    }

    /// Write a request with an optional body (Content-Length framed).
    pub fn send_request(&mut self, method: &str, path: &str, body: &str)
                        -> io::Result<()> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: osp\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len());
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body.as_bytes())?;
        self.stream.flush()
    }

    fn fill(&mut self) -> io::Result<usize> {
        let mut tmp = [0u8; 1024];
        let n = self.stream.read(&mut tmp)?;
        self.buf.extend_from_slice(&tmp[..n]);
        Ok(n)
    }

    fn take_until(&mut self, pat: &[u8]) -> io::Result<Vec<u8>> {
        loop {
            if self.buf.len() > self.pos {
                let window = &self.buf[self.pos..];
                if let Some(i) =
                    window.windows(pat.len()).position(|w| w == pat)
                {
                    let out = window[..i].to_vec();
                    self.pos += i + pat.len();
                    return Ok(out);
                }
            }
            if self.fill()? == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof before delimiter"));
            }
        }
    }

    fn take_exact(&mut self, n: usize) -> io::Result<Vec<u8>> {
        while self.buf.len() - self.pos < n {
            if self.fill()? == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof, "eof inside payload"));
            }
        }
        let out = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(out)
    }

    /// Read the status line + headers. Returns (status, headers).
    pub fn read_head(&mut self)
                     -> io::Result<(u16, Vec<(String, String)>)> {
        let head = self.take_until(b"\r\n\r\n")?;
        let text = String::from_utf8_lossy(&head).into_owned();
        let mut lines = text.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bad status line '{status_line}'")))?;
        let mut headers = Vec::new();
        for line in lines {
            if let Some((k, v)) = line.split_once(':') {
                headers.push((k.trim().to_ascii_lowercase(),
                              v.trim().to_string()));
            }
        }
        Ok((status, headers))
    }

    /// Next chunk of a chunked response; `None` after the final chunk.
    pub fn next_chunk(&mut self) -> io::Result<Option<String>> {
        let size_line = self.take_until(b"\r\n")?;
        let text = String::from_utf8_lossy(&size_line).into_owned();
        let n = usize::from_str_radix(text.trim(), 16).map_err(|_| {
            io::Error::new(io::ErrorKind::InvalidData,
                           format!("bad chunk size '{text}'"))
        })?;
        if n == 0 {
            let _ = self.take_until(b"\r\n");
            return Ok(None);
        }
        let data = self.take_exact(n)?;
        let _ = self.take_until(b"\r\n")?;
        Ok(Some(String::from_utf8_lossy(&data).into_owned()))
    }

    /// Read a Content-Length-framed body of `n` bytes.
    pub fn read_body(&mut self, n: usize) -> io::Result<String> {
        Ok(String::from_utf8_lossy(&self.take_exact(n)?).into_owned())
    }

    /// Byte-exact body read for binary payloads (shard artifacts).
    /// [`ClientConn::read_body`] is UTF-8-lossy and would corrupt
    /// packed code bytes; fetches must come through here.
    pub fn read_body_bytes(&mut self, n: usize) -> io::Result<Vec<u8>> {
        self.take_exact(n)
    }
}

/// Header lookup on a client-side header list.
pub fn header<'h>(headers: &'h [(String, String)], name: &str)
                  -> Option<&'h str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /generate HTTP/1.1\r\nHost: x\r\n\
                    Content-Length: 5\r\n\r\nhello";
        let req = read_request(&mut Cursor::new(&raw[..]), 64).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/generate");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn get_needs_no_length() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..]), 64).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_malformed_heads() {
        for raw in ["\r\n\r\n", "POST\r\n\r\n", "POST /x FTP/9\r\n\r\n",
                    "POST /x HTTP/1.1\r\nnocolon\r\n\r\n"] {
            let got =
                read_request(&mut Cursor::new(raw.as_bytes()), 64);
            assert!(matches!(got, Err(HttpError::BadRequest(_))),
                    "{raw:?} -> {got:?}");
        }
    }

    #[test]
    fn empty_connection_is_closed_not_bad() {
        let got = read_request(&mut Cursor::new(&b""[..]), 64);
        assert!(matches!(got, Err(HttpError::Closed)));
    }

    #[test]
    fn oversized_declaration_rejected_before_body_read() {
        let raw = b"POST /g HTTP/1.1\r\nContent-Length: 999\r\n\r\n";
        let got = read_request(&mut Cursor::new(&raw[..]), 64);
        assert!(matches!(got, Err(HttpError::BodyTooLarge(999))));
    }

    #[test]
    fn post_without_length_is_411() {
        let raw = b"POST /g HTTP/1.1\r\n\r\n";
        let got = read_request(&mut Cursor::new(&raw[..]), 64);
        assert!(matches!(got, Err(HttpError::LengthRequired)));
        assert_eq!(HttpError::LengthRequired.status().unwrap().0, 411);
    }

    #[test]
    fn head_cap_is_enforced() {
        let mut raw = b"GET /x HTTP/1.1\r\n".to_vec();
        raw.extend(std::iter::repeat(b'a').take(MAX_HEAD_BYTES + 10));
        let got = read_request(&mut Cursor::new(&raw[..]), 64);
        assert!(matches!(got, Err(HttpError::HeadTooLarge)));
    }

    /// A duplex-in-memory round trip: chunked writer framing is readable
    /// by the client chunk reader.
    #[test]
    fn chunked_round_trip() {
        let mut wire = Vec::new();
        start_chunked(&mut wire, 200).unwrap();
        write_chunk(&mut wire, "{\"token\":1}\n").unwrap();
        write_chunk(&mut wire, "{\"done\":true}\n").unwrap();
        end_chunked(&mut wire).unwrap();
        let mut client = ClientConn::new(Cursor::new(wire));
        let (status, headers) = client.read_head().unwrap();
        assert_eq!(status, 200);
        assert_eq!(header(&headers, "transfer-encoding"),
                   Some("chunked"));
        assert_eq!(client.next_chunk().unwrap().as_deref(),
                   Some("{\"token\":1}\n"));
        assert_eq!(client.next_chunk().unwrap().as_deref(),
                   Some("{\"done\":true}\n"));
        assert_eq!(client.next_chunk().unwrap(), None);
    }

    /// Binary bodies survive the wire bit-for-bit — including byte
    /// sequences that are invalid UTF-8, which the lossy string path
    /// would silently replace.
    #[test]
    fn byte_response_round_trip_is_exact() {
        let payload: Vec<u8> = (0..=255u8).cycle().take(700).collect();
        assert!(String::from_utf8(payload.clone()).is_err());
        let mut wire = Vec::new();
        write_response_bytes(&mut wire, 200, &[("X-Shard", "1")],
                             "application/octet-stream", &payload)
            .unwrap();
        let mut client = ClientConn::new(Cursor::new(wire));
        let (status, headers) = client.read_head().unwrap();
        assert_eq!(status, 200);
        assert_eq!(header(&headers, "content-type"),
                   Some("application/octet-stream"));
        assert_eq!(header(&headers, "x-shard"), Some("1"));
        let n: usize = header(&headers, "content-length")
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(client.read_body_bytes(n).unwrap(), payload);
    }

    #[test]
    fn simple_response_round_trip() {
        let mut wire = Vec::new();
        write_response(&mut wire, 503, &[("Retry-After", "1")],
                       "{\"error\":\"queue full\"}")
            .unwrap();
        let mut client = ClientConn::new(Cursor::new(wire));
        let (status, headers) = client.read_head().unwrap();
        assert_eq!(status, 503);
        assert_eq!(header(&headers, "retry-after"), Some("1"));
        let n: usize = header(&headers, "content-length")
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(client.read_body(n).unwrap(),
                   "{\"error\":\"queue full\"}");
    }
}
