//! `osp serve` — a fault-tolerant streaming HTTP front-end for the
//! continuous-batching decode engine (DESIGN.md §12, ROADMAP Open
//! item 1 adapted to the offline std-only toolchain: threads +
//! `std::net`, no async runtime, no HTTP crates).
//!
//! Thread ownership:
//!
//! ```text
//! acceptor (serve_loop thread)
//!   ├── service thread: owns the DecodeEngine, drains the bounded
//!   │   admission queue between steps, fans tokens out per request
//!   └── handler thread per connection: parses HTTP, validates,
//!       try_sends an Admission, relays Events to the socket
//! ```
//!
//! Robustness contract (pinned by `tests/serve_properties.rs`):
//! malformed requests → 400, queue full → 503 + `Retry-After`,
//! oversized bodies → 413, slow-loris heads → 408, deadline expiry →
//! eviction mid-decode, client disconnect → cancellation next step,
//! per-request engine errors → 500 while the loop keeps serving, and
//! `/admin/drain` stops admissions, finishes in-flight work, and shuts
//! the server down cleanly with zero occupied batch slots.
//!
//! Endpoints: `POST /generate` (chunked NDJSON token stream),
//! `GET /metrics`, `GET /healthz`, `GET /status`,
//! `POST /admin/drain` — plus, when spawned with `--workers`, the
//! shard-distribution surface `GET /shards/{i}/meta` and
//! `GET /shards/{i}/data?off=N&len=N` (DESIGN.md §14).
//!
//! Row-parallel sharded mode: with `opts.workers` non-empty the
//! coordinator swaps every trunk linear for a remote stub driven by a
//! [`worker::HttpShardPool`], serves the `osp shard` artifacts to
//! fetching workers, and gates `/generate` on fleet readiness. The
//! sharded token stream is pinned bit-identical to the single-process
//! one (`tests/shard_properties.rs`).

pub mod chaos;
pub mod health;
pub mod http;
pub mod load;
pub mod metrics;
mod service;
pub mod storage;
pub mod worker;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::Ordering::{Relaxed, SeqCst};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicUsize};
use std::sync::mpsc::{self, RecvTimeoutError, SyncSender,
                      TrySendError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::infer::DecodeParams;
use crate::model::InferModel;
use crate::tensor::par;
use crate::util::json::Json;

use http::HttpError;
use metrics::ServeMetrics;
use service::{Admission, Event};

/// Everything tunable about the server. CLI flags in `main.rs` map
/// onto this 1:1; tests shrink the timeouts.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub addr: String,
    /// Engine batching knob (active-sequence cap).
    pub max_batch: usize,
    /// Bounded admission queue depth; overflow → 503.
    pub queue_cap: usize,
    pub a_bits: u32,
    pub kv_bits: u32,
    pub prefill_chunk: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub top_p: f32,
    pub seed: u64,
    /// `max_new` when the request omits it.
    pub max_new_default: usize,
    /// Server-side ceiling on requested `max_new`.
    pub max_new_cap: usize,
    /// Prompt-length ceiling (tokens).
    pub max_prompt: usize,
    /// Deadline when the request omits `timeout_ms`.
    pub default_timeout_ms: u64,
    /// Server-side ceiling on requested `timeout_ms`.
    pub timeout_cap_ms: u64,
    /// Socket read timeout while parsing the request (slow-loris cap).
    pub header_timeout_ms: u64,
    /// Socket write timeout (slow-consumer cap).
    pub write_timeout_ms: u64,
    /// Request body cap; larger declared lengths → 413.
    pub max_body_bytes: usize,
    /// Concurrent-connection cap; overflow → immediate 503.
    pub max_conns: usize,
    /// Rows per KV page (`--kv-page-rows`; DESIGN.md §13).
    pub kv_page_rows: usize,
    /// Soft KV pool budget in MiB (`--kv-pool-mb`; 0 = unbounded).
    /// Exhaustion → 503 + `Retry-After` while sequences are running.
    pub kv_pool_mb: usize,
    /// Copy-on-write prefix sharing (`--share-prefix on|off`). On by
    /// default in serve: shared streams are pinned bit-identical to
    /// unshared, and repeated system prompts are the serving norm.
    pub share_prefix: bool,
    /// Worker addresses for row-parallel sharded serving (DESIGN.md
    /// §14); empty = classic single-process serving. Order matters:
    /// `workers[w]` must serve shard `w % n_shards` (round-robin
    /// replica placement, DESIGN.md §15).
    pub workers: Vec<String>,
    /// Directory written by `osp shard` that the coordinator serves
    /// worker fetches from. Required when `workers` is non-empty.
    pub shard_dir: String,
    /// Replication factor (`--replicas`): each shard may be served by
    /// up to this many workers, and the fleet survives any single
    /// worker failure when every shard has ≥ 2 live replicas.
    pub replicas: usize,
    /// Health prober cadence (`--probe-interval-ms`, DESIGN.md §15).
    pub probe_interval_ms: u64,
    /// Consecutive probe/rpc failures before a worker's breaker trips
    /// (`--down-after`).
    pub down_after: u32,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            addr: "127.0.0.1:8080".into(),
            max_batch: 8,
            queue_cap: 32,
            a_bits: 4,
            kv_bits: 4,
            prefill_chunk: 64,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 7,
            max_new_default: 16,
            max_new_cap: 256,
            max_prompt: 4096,
            default_timeout_ms: 10_000,
            timeout_cap_ms: 60_000,
            header_timeout_ms: 2_000,
            write_timeout_ms: 10_000,
            max_body_bytes: 1 << 16,
            max_conns: 256,
            kv_page_rows: crate::model::kv::DEFAULT_PAGE_ROWS,
            kv_pool_mb: 0,
            share_prefix: true,
            workers: Vec::new(),
            shard_dir: String::new(),
            replicas: 1,
            probe_interval_ms: 150,
            down_after: 3,
        }
    }
}

/// Immutable model facts snapshotted at spawn for `/metrics` (the load
/// generator keys its bench rows off these).
pub struct ServeInfo {
    pub w_bits: u32,
    pub a_bits: u32,
    pub kv_bits: u32,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub int_kernel: Option<&'static str>,
    /// Packed weight footprint of the full (unsharded) model — the
    /// denominator of the sharded-memory win (DESIGN.md §14).
    pub weight_bytes_full: usize,
    /// Weights actually resident in this process after any remote
    /// swap (== `weight_bytes_full` when serving single-process).
    pub weight_bytes_coord: usize,
}

impl ServeInfo {
    /// `"w-a-kv"`, the bit-config row label shared with the bench
    /// harness (e.g. `"4-4-4"`).
    pub fn config_label(&self) -> String {
        format!("{}-{}-{}", self.w_bits, self.a_bits, self.kv_bits)
    }
}

/// Sharded-mode coordinator state: the storage backend workers fetch
/// their artifacts from, and the rpc pool the remote linears ride.
/// The `/generate` gate is the pool's health registry — shard
/// coverage, not a one-way ready latch, so the gate reopens after an
/// outage once a worker rejoins (DESIGN.md §15).
pub(crate) struct ShardCtl {
    pub store: Box<dyn storage::StorageBackend>,
    pub pool: Arc<worker::HttpShardPool>,
}

/// Shared control block: handlers, the service thread, and the
/// acceptor all hold `&Ctl` (via `Arc` at the top).
pub(crate) struct Ctl {
    pub draining: AtomicBool,
    pub service_done: AtomicBool,
    pub conns: AtomicI64,
    pub metrics: ServeMetrics,
    pub opts: ServeOpts,
    pub info: ServeInfo,
    /// `Some` iff serving in row-parallel sharded mode.
    pub shard: Option<ShardCtl>,
}

impl Ctl {
    /// Lowest shard with no live replica (`None` = fleet can serve;
    /// always `None` single-process). Uncovered at boot until every
    /// shard's first replica turns ready, and again mid-outage.
    fn uncovered_shard(&self) -> Option<usize> {
        self.shard.as_ref()
            .and_then(|sh| sh.pool.health().first_uncovered())
    }

    fn workers_ready(&self) -> bool {
        self.uncovered_shard().is_none()
    }

    fn status_json(&self) -> Json {
        let n_workers = self.opts.workers.len();
        let n_shards = self.shard.as_ref()
            .map(|sh| sh.pool.n_shards())
            .unwrap_or(n_workers);
        Json::obj(vec![
            ("config", Json::str(self.info.config_label())),
            ("w_bits", Json::num(self.info.w_bits as f64)),
            ("a_bits", Json::num(self.info.a_bits as f64)),
            ("kv_bits", Json::num(self.info.kv_bits as f64)),
            ("vocab", Json::num(self.info.vocab as f64)),
            ("d_model", Json::num(self.info.d_model as f64)),
            ("n_layers", Json::num(self.info.n_layers as f64)),
            ("int_kernel",
             match self.info.int_kernel {
                 Some(k) => Json::str(k),
                 None => Json::Null,
             }),
            ("max_batch", Json::num(self.opts.max_batch as f64)),
            ("queue_cap", Json::num(self.opts.queue_cap as f64)),
            ("kv_page_rows",
             Json::num(self.opts.kv_page_rows as f64)),
            ("share_prefix",
             Json::str(if self.opts.share_prefix { "on" } else {
                 "off"
             })),
            ("threads", Json::num(par::configured_threads() as f64)),
            ("draining", Json::Bool(self.draining.load(SeqCst))),
            ("weight_bytes_full",
             Json::num(self.info.weight_bytes_full as f64)),
            ("weight_bytes_coord",
             Json::num(self.info.weight_bytes_coord as f64)),
            ("workers", Json::num(n_workers as f64)),
            ("shards", Json::num(n_shards as f64)),
            ("replicas", Json::num(self.opts.replicas.max(1) as f64)),
            ("workers_ready", Json::Bool(self.workers_ready())),
            ("shard_pool", match &self.shard {
                Some(sh) => sh.pool.to_json(),
                None => Json::Null,
            }),
            ("fleet_health", match &self.shard {
                Some(sh) => sh.pool.health().to_json(),
                None => Json::Null,
            }),
            ("metrics", self.metrics.to_json()),
        ])
    }

    /// `/status`: the `/metrics` document plus a live scrape of every
    /// worker's own `/metrics` — per-worker liveness, fetch progress,
    /// queue depth, and stripe latency in one place. An unreachable
    /// worker becomes `{"error": ...}` instead of failing the scrape;
    /// the conservation invariant (pool `rpcs_ok` ≤ Σ worker
    /// `rpcs_served`) is checkable straight off this document.
    fn full_status_json(&self) -> Json {
        let mut doc = self.status_json();
        if let Json::Obj(map) = &mut doc {
            let scraped: Vec<Json> = match &self.shard {
                None => Vec::new(),
                Some(sh) => sh.pool.worker_addrs().iter().enumerate()
                    .map(|(w, a)| {
                        let mut m = match load::http_get(a, "/metrics")
                        {
                            Ok((200, m)) => m,
                            Ok((status, _)) => Json::obj(vec![(
                                "error",
                                Json::str(format!(
                                    "/metrics -> {status}")),
                            )]),
                            Err(e) => Json::obj(vec![(
                                "error", Json::str(format!("{e:#}")),
                            )]),
                        };
                        if let Json::Obj(map) = &mut m {
                            map.insert("addr".into(),
                                       Json::str(a.clone()));
                            map.insert(
                                "health".into(),
                                Json::str(sh.pool.health().state(w)
                                          .label()));
                        }
                        m
                    })
                    .collect(),
            };
            map.insert("worker_status".into(), Json::Arr(scraped));
        }
        doc
    }
}

/// A running server. Owns the model (moved into the serve thread);
/// `drain()` + `join()` is the clean shutdown path.
pub struct Server {
    addr: SocketAddr,
    ctl: Arc<Ctl>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `opts.addr` (port 0 picks an ephemeral port — the bound
    /// address is available via [`Server::addr`]) and start the
    /// acceptor + service threads.
    pub fn spawn(mut model: InferModel, opts: ServeOpts)
                 -> Result<Server> {
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("bind {}", opts.addr))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let weight_bytes_full = model.weight_bytes();
        // Sharded mode: validate up front (a misconfigured fleet must
        // fail at spawn, not mid-decode), then swap the trunk linears
        // for remote stubs over the worker pool.
        let shard = if opts.workers.is_empty() {
            None
        } else {
            let dir = Path::new(&opts.shard_dir);
            let store = storage::LocalDir::open(dir)
                .context("opening --shard-dir")?;
            let n_shards = store.n_shards();
            let replicas = opts.replicas.max(1);
            let nw = opts.workers.len();
            if nw < n_shards || nw > n_shards * replicas {
                bail!("shard dir {dir:?} was cut for {n_shards} \
                       shards; --workers lists {nw} addresses (want \
                       {n_shards} to {} with --replicas {replicas}, \
                       worker w serving shard w % {n_shards})",
                      n_shards * replicas);
            }
            if model.int_kernel(opts.a_bits).is_none() {
                bail!("sharded serving requires the integer kernel \
                       path: a_bits <= 8 (got {}) and int mode \
                       scalar|auto — f32 partial sums would break \
                       stream bit-parity (DESIGN.md §14)", opts.a_bits);
            }
            let hopts = health::HealthOpts {
                probe_interval_ms: opts.probe_interval_ms.max(10),
                down_after: opts.down_after.max(1),
                seed: opts.seed,
                ..health::HealthOpts::default()
            };
            let registry = Arc::new(health::HealthRegistry::new(
                nw, n_shards, hopts));
            let pool = Arc::new(worker::HttpShardPool::with_health(
                opts.workers.clone(), n_shards, registry));
            model.shard_remote(Arc::clone(&pool))?;
            Some(ShardCtl { store: Box::new(store), pool })
        };
        let info = ServeInfo {
            w_bits: model.weight_bits(),
            a_bits: opts.a_bits,
            kv_bits: opts.kv_bits,
            vocab: model.cfg.vocab_size,
            d_model: model.cfg.d_model,
            n_layers: model.cfg.n_layers,
            int_kernel: model.int_kernel_label(opts.a_bits),
            weight_bytes_full,
            weight_bytes_coord: model.weight_bytes(),
        };
        let ctl = Arc::new(Ctl {
            draining: AtomicBool::new(false),
            service_done: AtomicBool::new(false),
            conns: AtomicI64::new(0),
            metrics: ServeMetrics::default(),
            opts,
            info,
            shard,
        });
        if ctl.shard.is_some() {
            // Persistent health prober (DESIGN.md §15): feeds every
            // worker's /healthz into the registry's state machines —
            // the /generate coverage gate, the breaker half-open
            // path, and the rejoin counter all ride these probes.
            // Unlike the PR-9 one-shot readiness poller this never
            // latches: the gate closes during an outage and reopens
            // when a restarted worker passes readiness again.
            let ctl3 = Arc::clone(&ctl);
            thread::Builder::new()
                .name("osp-health".into())
                .spawn(move || {
                    let sh = ctl3.shard.as_ref().unwrap();
                    let reg = sh.pool.health();
                    let interval = Duration::from_millis(
                        ctl3.opts.probe_interval_ms.max(10));
                    let per_probe = Duration::from_millis(1_000);
                    while !ctl3.draining.load(SeqCst)
                        && !ctl3.service_done.load(SeqCst)
                    {
                        for (w, a) in sh.pool.worker_addrs().iter()
                            .enumerate()
                        {
                            match load::http_get_timeout(
                                a, "/healthz", per_probe)
                            {
                                Ok((200, doc))
                                    if doc.get("ready")
                                        .and_then(|v| v.as_bool())
                                        == Some(true) =>
                                    reg.record_ready(w),
                                Ok((200, _)) => reg.record_unready(w),
                                Ok(_) | Err(_) => {
                                    reg.record_failure(w)
                                }
                            }
                        }
                        thread::sleep(interval);
                    }
                })?;
        }
        let ctl2 = Arc::clone(&ctl);
        let handle = thread::Builder::new()
            .name("osp-serve".into())
            .spawn(move || serve_loop(model, listener, &ctl2))?;
        Ok(Server { addr, ctl, handle: Some(handle) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop admissions; in-flight sequences finish, then the server
    /// exits (same effect as `POST /admin/drain`).
    pub fn drain(&self) {
        self.ctl.draining.store(true, SeqCst);
    }

    pub fn is_done(&self) -> bool {
        self.ctl.service_done.load(SeqCst)
    }

    /// Wait for the serve thread to exit (requires a prior drain).
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Acceptor + thread nursery. Runs on the dedicated serve thread; the
/// scope guarantees the service thread and every handler exit before
/// the model (borrowed by all of them) is dropped.
fn serve_loop(model: InferModel, listener: TcpListener, ctl: &Ctl) {
    let params = DecodeParams {
        a_bits: ctl.opts.a_bits,
        kv_bits: ctl.opts.kv_bits,
        max_batch: ctl.opts.max_batch,
        temperature: ctl.opts.temperature,
        top_k: ctl.opts.top_k,
        top_p: ctl.opts.top_p,
        prefill_chunk: ctl.opts.prefill_chunk.max(1),
        seed: ctl.opts.seed,
        kv_page_rows: ctl.opts.kv_page_rows.max(1),
        kv_pool_mb: ctl.opts.kv_pool_mb,
        share_prefix: ctl.opts.share_prefix,
    };
    // Declared before the scope so scoped threads may borrow them.
    let (adm_tx, adm_rx) = mpsc::sync_channel::<Admission>(
        ctl.opts.queue_cap);
    let next_id = AtomicUsize::new(0);
    let model_ref = &model;
    let next_id_ref = &next_id;
    thread::scope(|s| {
        thread::Builder::new()
            .name("osp-service".into())
            .spawn_scoped(s, move || {
                service::service_loop(model_ref, params, adm_rx, ctl);
            })
            .expect("spawn service thread");
        loop {
            if ctl.service_done.load(SeqCst) {
                break;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    ctl.metrics.connections.fetch_add(1, Relaxed);
                    if ctl.conns.fetch_add(1, SeqCst)
                        >= ctl.opts.max_conns as i64
                    {
                        ctl.conns.fetch_sub(1, SeqCst);
                        ctl.metrics.rejected_full.fetch_add(1, Relaxed);
                        let mut stream = stream;
                        let _ = http::write_response(
                            &mut stream, 503,
                            &[("Retry-After", "1")],
                            "{\"error\":\"connection limit\"}");
                        continue;
                    }
                    let tx = adm_tx.clone();
                    let spawned = thread::Builder::new()
                        .name("osp-handler".into())
                        .spawn_scoped(s, move || {
                            handle_conn(stream, tx, ctl, next_id_ref);
                            ctl.conns.fetch_sub(1, SeqCst);
                        });
                    if spawned.is_err() {
                        ctl.conns.fetch_sub(1, SeqCst);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(2));
                }
                Err(_) => thread::sleep(Duration::from_millis(2)),
            }
        }
    });
    // Sharded mode: propagate the drain so workers print their own
    // zero-leak line and exit (best-effort — a dead worker is already
    // drained for our purposes).
    if let Some(sh) = &ctl.shard {
        for a in sh.pool.worker_addrs() {
            let _ = load::http_post(a, "/admin/drain", "{}");
        }
    }
}

fn err_body(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).dump()
}

/// One connection, one request (Connection: close). Never panics on
/// client input; every early return maps to a well-formed response or
/// a deliberate hangup.
fn handle_conn(mut stream: TcpStream, adm_tx: SyncSender<Admission>,
               ctl: &Ctl, next_id: &AtomicUsize) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(
        ctl.opts.header_timeout_ms.max(1))));
    let _ = stream.set_write_timeout(Some(Duration::from_millis(
        ctl.opts.write_timeout_ms.max(1))));
    let req = match http::read_request(&mut stream,
                                       ctl.opts.max_body_bytes) {
        Ok(r) => r,
        Err(e) => {
            match &e {
                HttpError::Timeout => {
                    ctl.metrics.rejected_slow.fetch_add(1, Relaxed);
                }
                HttpError::BodyTooLarge(_) => {
                    ctl.metrics.rejected_oversize.fetch_add(1, Relaxed);
                }
                HttpError::Closed | HttpError::Io(_) => {}
                _ => {
                    ctl.metrics.rejected_bad.fetch_add(1, Relaxed);
                }
            }
            if let Some((status, msg)) = e.status() {
                let _ = http::write_response(&mut stream, status, &[],
                                             &err_body(msg));
            }
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let body = Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("ready", Json::Bool(ctl.workers_ready())),
                ("draining",
                 Json::Bool(ctl.draining.load(SeqCst))),
            ])
            .dump();
            let _ = http::write_response(&mut stream, 200, &[], &body);
        }
        ("GET", "/metrics") => {
            let _ = http::write_response(&mut stream, 200, &[],
                                         &ctl.status_json().dump());
        }
        ("GET", "/status") => {
            let _ = http::write_response(
                &mut stream, 200, &[], &ctl.full_status_json().dump());
        }
        ("GET", p) if p.starts_with("/shards/") => {
            match &ctl.shard {
                Some(sh) => {
                    let (status, ct, body) =
                        worker::shards_http_response(p, &*sh.store);
                    let _ = http::write_response_bytes(
                        &mut stream, status, &[], ct, &body);
                }
                None => {
                    let _ = http::write_response(
                        &mut stream, 404, &[],
                        &err_body("not a sharded server"));
                }
            }
        }
        ("POST", "/admin/drain") => {
            ctl.draining.store(true, SeqCst);
            let body = Json::obj(vec![("draining", Json::Bool(true))])
                .dump();
            let _ = http::write_response(&mut stream, 200, &[], &body);
        }
        ("POST", "/generate") => {
            handle_generate(stream, &req, adm_tx, ctl, next_id);
        }
        _ => {
            ctl.metrics.rejected_bad.fetch_add(1, Relaxed);
            let _ = http::write_response(&mut stream, 404, &[],
                                         &err_body("no such endpoint"));
        }
    }
}

struct GenParams {
    prompt: Vec<i32>,
    max_new: usize,
    timeout: Duration,
}

/// Validate a `/generate` body against the server caps. Every failure
/// is a handler-side 400 — nothing invalid reaches the engine.
fn parse_generate(body: &[u8], ctl: &Ctl)
                  -> std::result::Result<GenParams, String> {
    let text = std::str::from_utf8(body)
        .map_err(|_| "body is not UTF-8".to_string())?;
    let doc = Json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let arr = doc
        .get("prompt")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| "missing 'prompt' array".to_string())?;
    if arr.len() > ctl.opts.max_prompt {
        return Err(format!("prompt len {} > cap {}", arr.len(),
                           ctl.opts.max_prompt));
    }
    let vocab = ctl.info.vocab as i64;
    let mut prompt = Vec::with_capacity(arr.len());
    for v in arr {
        let t = v
            .as_f64()
            .filter(|x| x.fract() == 0.0)
            .map(|x| x as i64)
            .ok_or_else(|| "prompt tokens must be integers"
                .to_string())?;
        if t < 0 || t >= vocab {
            return Err(format!(
                "prompt token {t} outside vocab 0..{vocab}"));
        }
        prompt.push(t as i32);
    }
    let max_new = match doc.get("max_new") {
        None => ctl.opts.max_new_default,
        Some(v) => v
            .as_usize()
            .filter(|&n| n > 0)
            .ok_or_else(|| "'max_new' must be a positive integer"
                .to_string())?,
    }
    .min(ctl.opts.max_new_cap);
    let timeout_ms = match doc.get("timeout_ms") {
        None => ctl.opts.default_timeout_ms,
        Some(v) => v
            .as_f64()
            .filter(|&x| x >= 0.0 && x.fract() == 0.0)
            .map(|x| x as u64)
            .ok_or_else(|| "'timeout_ms' must be a non-negative \
                            integer"
                .to_string())?,
    }
    .min(ctl.opts.timeout_cap_ms);
    Ok(GenParams { prompt, max_new,
                   timeout: Duration::from_millis(timeout_ms.max(1)) })
}

/// The streaming request path: admit, then relay events until a
/// terminal one. The HTTP status line is deferred until the first
/// event so rejections and pre-stream deadlines get real status codes;
/// once streaming starts, failures become error chunks.
fn handle_generate(mut stream: TcpStream, req: &http::Request,
                   adm_tx: SyncSender<Admission>, ctl: &Ctl,
                   next_id: &AtomicUsize) {
    let gp = match parse_generate(&req.body, ctl) {
        Ok(gp) => gp,
        Err(msg) => {
            ctl.metrics.rejected_bad.fetch_add(1, Relaxed);
            let _ = http::write_response(&mut stream, 400, &[],
                                         &err_body(&msg));
            return;
        }
    };
    if ctl.draining.load(SeqCst) {
        ctl.metrics.rejected_draining.fetch_add(1, Relaxed);
        let _ = http::write_response(&mut stream, 503,
                                     &[("Retry-After", "1")],
                                     &err_body("draining"));
        return;
    }
    // Sharded mode: while any shard has no live replica — boot,
    // outage, every-replica-down — a decode step would fail inside a
    // remote linear, so defer new requests with a retryable 503
    // instead (DESIGN.md §15). The fleet recovers without a restart:
    // the prober reopens this gate as soon as a worker rejoins.
    if let Some(shard) = ctl.uncovered_shard() {
        ctl.metrics.rejected_full.fetch_add(1, Relaxed);
        ctl.metrics.uncovered_503s.fetch_add(1, Relaxed);
        let _ = http::write_response(
            &mut stream, 503, &[("Retry-After", "1")],
            &err_body(&format!("shard {shard} uncovered")));
        return;
    }
    // Event capacity max_new + 4: every token plus the terminal event
    // fit without the service thread ever blocking on this client.
    let (ev_tx, ev_rx) = mpsc::sync_channel::<Event>(gp.max_new + 4);
    let id = next_id.fetch_add(1, SeqCst);
    let deadline = Instant::now() + gp.timeout;
    let adm = Admission { id, prompt: gp.prompt, max_new: gp.max_new,
                          deadline, events: ev_tx };
    match adm_tx.try_send(adm) {
        Ok(()) => {
            ctl.metrics.queue_depth.fetch_add(1, Relaxed);
        }
        Err(TrySendError::Full(_)) => {
            ctl.metrics.rejected_full.fetch_add(1, Relaxed);
            let _ = http::write_response(&mut stream, 503,
                                         &[("Retry-After", "1")],
                                         &err_body("queue full"));
            return;
        }
        Err(TrySendError::Disconnected(_)) => {
            ctl.metrics.rejected_draining.fetch_add(1, Relaxed);
            let _ = http::write_response(&mut stream, 503, &[],
                                         &err_body("shutting down"));
            return;
        }
    }
    // Relay loop. Dropping ev_rx (any early return) is the
    // cancellation signal: the service thread's next try_send fails
    // and it evicts the sequence.
    let grace = Duration::from_millis(2_000);
    let mut streaming = false;
    let mut sent = 0usize;
    loop {
        let wait = deadline
            .saturating_duration_since(Instant::now())
            + grace;
        let ev = match ev_rx.recv_timeout(wait) {
            Ok(ev) => ev,
            Err(RecvTimeoutError::Timeout)
            | Err(RecvTimeoutError::Disconnected) => {
                // Service silent past deadline + grace (or gone):
                // answer something well-formed and let the drop of
                // ev_rx cancel the sequence.
                if streaming {
                    let _ = http::write_chunk(
                        &mut stream,
                        &format!("{{\"error\":\"deadline\",\
                                  \"tokens\":{sent}}}\n"));
                    let _ = http::end_chunked(&mut stream);
                } else {
                    let _ = http::write_response(
                        &mut stream, 504, &[],
                        &err_body("deadline exceeded"));
                }
                return;
            }
        };
        match ev {
            Event::Token(t) => {
                if !streaming {
                    if http::start_chunked(&mut stream, 200).is_err() {
                        return;
                    }
                    streaming = true;
                }
                sent += 1;
                let line = format!("{{\"token\":{t}}}\n");
                if http::write_chunk(&mut stream, &line).is_err() {
                    return;
                }
            }
            Event::Done { tokens } => {
                if !streaming
                    && http::start_chunked(&mut stream, 200).is_err()
                {
                    return;
                }
                let _ = http::write_chunk(
                    &mut stream,
                    &format!("{{\"done\":true,\"tokens\":{tokens}}}\n"));
                let _ = http::end_chunked(&mut stream);
                return;
            }
            Event::Deadline { tokens } => {
                if streaming {
                    let _ = http::write_chunk(
                        &mut stream,
                        &format!("{{\"error\":\"deadline\",\
                                  \"tokens\":{tokens}}}\n"));
                    let _ = http::end_chunked(&mut stream);
                } else {
                    let _ = http::write_response(
                        &mut stream, 504, &[],
                        &err_body("deadline exceeded"));
                }
                return;
            }
            Event::Rejected { status, msg } => {
                let retry = [("Retry-After", "1")];
                let extra: &[(&str, &str)] =
                    if status == 503 { &retry } else { &[] };
                let _ = http::write_response(&mut stream, status, extra,
                                             &err_body(&msg));
                return;
            }
            Event::Failed { msg } => {
                if streaming {
                    let _ = http::write_chunk(
                        &mut stream,
                        &format!("{{\"error\":{}}}\n",
                                 Json::str(msg).dump()));
                    let _ = http::end_chunked(&mut stream);
                } else {
                    let _ = http::write_response(&mut stream, 500, &[],
                                                 &err_body(&msg));
                }
                return;
            }
        }
    }
}
