//! The engine-owner service thread (DESIGN.md §12).
//!
//! Exactly one thread touches the [`DecodeEngine`]; handler threads
//! talk to it through two kinds of channels:
//!
//! * a single bounded **admission** channel (`sync_channel(queue_cap)`)
//!   carrying [`Admission`]s in — `try_send` failure is the 503
//!   backpressure signal, so the queue can never grow without bound;
//! * one bounded **event** channel per request carrying [`Event`]s out.
//!   Its capacity is `max_new + 4`, enough for every token plus the
//!   terminal event, so the service thread can *never* block on a slow
//!   client: `try_send` either succeeds immediately or fails with
//!   `Disconnected`, and a disconnect (the handler dropped its receiver
//!   because the socket write failed) cancels the sequence via
//!   [`DecodeEngine::cancel`] without disturbing batchmates.
//!
//! Loop order per iteration: admit → deadline sweep → step → fan out
//! emitted tokens → retire finished sequences. Any `Err` from
//! [`DecodeEngine::step`] fails the in-flight requests with a 500-class
//! event and keeps serving — the loop itself must never panic or exit
//! on request-induced errors. The only clean exit is drain: admissions
//! stop, in-flight sequences finish, and the thread sets
//! `Ctl::service_done`.

use std::collections::HashMap;
use std::sync::atomic::Ordering::{Relaxed, SeqCst};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender,
                      TryRecvError};
use std::time::{Duration, Instant};

use crate::infer::{DecodeEngine, DecodeParams, GenRequest};
use crate::model::InferModel;
use crate::tensor::par;

use super::metrics::ServeMetrics;
use super::Ctl;

/// A validated request handed from a handler thread to the service
/// thread. The handler keeps the receiving end of `events`.
pub(crate) struct Admission {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub deadline: Instant,
    pub events: SyncSender<Event>,
}

/// Service → handler stream. At most one terminal event
/// (`Done`/`Deadline`/`Rejected`/`Failed`) is sent per request.
pub(crate) enum Event {
    Token(i32),
    Done { tokens: usize },
    Deadline { tokens: usize },
    Rejected { status: u16, msg: String },
    Failed { msg: String },
}

struct InFlight {
    events: SyncSender<Event>,
    deadline: Instant,
    tokens: usize,
}

/// How long the service thread parks on the admission channel when the
/// engine is idle.
const IDLE_WAIT: Duration = Duration::from_millis(5);

/// Mirror the engine's KV page-pool gauges into the `/metrics`
/// atomics (DESIGN.md §13). Called once per service-loop step and at
/// drain, so scrapes see at-most-one-step-old values.
fn mirror_pool(eng: &DecodeEngine, m: &ServeMetrics) {
    let g = eng.pool_gauges();
    m.kv_pages_live.store(g.pages_live as i64, Relaxed);
    m.kv_pages_shared.store(g.shared_peak as u64, Relaxed);
    m.kv_pages_peak.store(g.pages_peak as u64, Relaxed);
    m.kv_bytes_peak.store(g.bytes_peak as u64, Relaxed);
}

fn admit_one(eng: &mut DecodeEngine,
             inflight: &mut HashMap<usize, InFlight>, adm: Admission,
             ctl: &Ctl) {
    let m = &ctl.metrics;
    m.queue_depth.fetch_sub(1, Relaxed);
    if ctl.draining.load(SeqCst) {
        let _ = adm.events.try_send(Event::Rejected {
            status: 503,
            msg: "draining".into(),
        });
        m.rejected_draining.fetch_add(1, Relaxed);
        return;
    }
    let req = GenRequest { id: adm.id, prompt: adm.prompt,
                           max_new: adm.max_new };
    // Pool backpressure (DESIGN.md §13): with a `--kv-pool-mb` budget
    // and other sequences holding pages, a request that cannot fit
    // right now is shed with a retryable 503 instead of queueing
    // behind memory we don't have. An idle engine admits regardless —
    // it reclaims the prefix registry, so progress is guaranteed.
    if eng.n_active() > 0
        && !eng.pool_has_room(req.prompt.len(), req.max_new)
    {
        let _ = adm.events.try_send(Event::Rejected {
            status: 503,
            msg: "kv pool exhausted".into(),
        });
        m.rejected_full.fetch_add(1, Relaxed);
        return;
    }
    match eng.submit(req) {
        Ok(()) => {
            m.admitted.fetch_add(1, Relaxed);
            inflight.insert(adm.id, InFlight {
                events: adm.events,
                deadline: adm.deadline,
                tokens: 0,
            });
        }
        // Handlers validate prompts, so this is belt-and-braces: an
        // unsubmittable request is a handler-side rejection, never an
        // admitted one (keeps the conservation invariant).
        Err(e) => {
            let _ = adm.events.try_send(Event::Rejected {
                status: 400,
                msg: e.to_string(),
            });
            m.rejected_bad.fetch_add(1, Relaxed);
        }
    }
}

pub(crate) fn service_loop(model: &InferModel, params: DecodeParams,
                           adm_rx: Receiver<Admission>, ctl: &Ctl) {
    let pool = par::shared_pool();
    let mut eng = DecodeEngine::new(model, params, pool);
    let mut inflight: HashMap<usize, InFlight> = HashMap::new();
    let m = &ctl.metrics;

    'serve: loop {
        // 1. Admit while slots are free; never block here.
        while eng.n_pending() < params.max_batch {
            match adm_rx.try_recv() {
                Ok(adm) => {
                    admit_one(&mut eng, &mut inflight, adm, ctl)
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if eng.n_pending() == 0 {
                        break 'serve;
                    }
                    break;
                }
            }
        }

        // 2. Deadline sweep: evict expired sequences (queued or
        // active) before spending a step on them.
        let now = Instant::now();
        let expired: Vec<usize> = inflight
            .iter()
            .filter(|(_, st)| now >= st.deadline)
            .map(|(&id, _)| id)
            .collect();
        for id in expired {
            let st = inflight.remove(&id).unwrap();
            eng.cancel(id);
            let _ = st.events.try_send(Event::Deadline {
                tokens: st.tokens,
            });
            m.timed_out.fetch_add(1, Relaxed);
        }

        // 3. Idle: park briefly on the admission channel instead of
        // spinning; drain exits here once the engine is empty.
        if eng.n_pending() == 0 {
            if ctl.draining.load(SeqCst) {
                break 'serve;
            }
            match adm_rx.recv_timeout(IDLE_WAIT) {
                Ok(adm) => {
                    admit_one(&mut eng, &mut inflight, adm, ctl)
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break 'serve,
            }
            m.active_seqs.store(eng.n_pending() as i64, Relaxed);
            continue;
        }

        // 4. Step. A request-induced error must not kill the loop:
        // fail everything in flight, reset, keep serving. The
        // alternate `{:#}` rendering flattens the whole anyhow
        // context chain — `to_string()` shows only the outermost
        // layer, which would hide the "uncovered" marker that
        // `HttpShardPool::rpc_shard` buries under per-op context.
        let t0 = Instant::now();
        if let Err(e) = eng.step() {
            let msg = format!("{e:#}");
            // A fleet outage (every replica of some shard down,
            // DESIGN.md §15) is retryable: tell clients 503 +
            // Retry-After when they have seen zero tokens, so they
            // can resubmit elsewhere. Requests mid-stream still fail
            // — the terminal accounting (`failed`) is identical
            // either way, preserving conservation.
            let uncovered = msg.contains("uncovered");
            for (id, st) in inflight.drain() {
                eng.cancel(id);
                let ev = if uncovered && st.tokens == 0 {
                    m.uncovered_503s.fetch_add(1, Relaxed);
                    Event::Rejected { status: 503, msg: msg.clone() }
                } else {
                    Event::Failed { msg: msg.clone() }
                };
                let _ = st.events.try_send(ev);
                m.failed.fetch_add(1, Relaxed);
            }
            m.active_seqs.store(eng.n_pending() as i64, Relaxed);
            continue;
        }
        let step_dt = t0.elapsed();

        // 5. Fan out this step's tokens. A dead receiver means the
        // handler saw a socket failure and dropped it: cancel that
        // sequence, batchmates keep streaming.
        let mut dropped: Vec<usize> = Vec::new();
        for (id, tok) in eng.take_emitted() {
            let Some(st) = inflight.get_mut(&id) else { continue };
            st.tokens += 1;
            m.tokens_streamed.fetch_add(1, Relaxed);
            m.token_lat.record(step_dt);
            if st.events.try_send(Event::Token(tok)).is_err() {
                dropped.push(id);
            }
        }
        for id in dropped {
            inflight.remove(&id);
            eng.cancel(id);
            m.cancelled.fetch_add(1, Relaxed);
        }

        // 6. Retire finished sequences.
        for r in eng.take_finished() {
            if let Some(st) = inflight.remove(&r.id) {
                let _ = st.events.try_send(Event::Done {
                    tokens: r.generated.len(),
                });
                m.completed.fetch_add(1, Relaxed);
            }
        }
        mirror_pool(&eng, m);
        m.active_seqs.store(eng.n_pending() as i64, Relaxed);
    }

    // Final sweep: reject admissions that raced in while we were
    // deciding to exit, so no handler is left waiting on its channel.
    while let Ok(adm) = adm_rx.try_recv() {
        m.queue_depth.fetch_sub(1, Relaxed);
        let _ = adm.events.try_send(Event::Rejected {
            status: 503,
            msg: "draining".into(),
        });
        m.rejected_draining.fetch_add(1, Relaxed);
    }
    m.active_seqs.store(0, Relaxed);
    debug_assert_eq!(eng.n_pending(), 0, "drain leaked batch slots");
    // Return prefix-registry refs and prove pool balance before
    // exiting: a drained engine must hold zero pages. CI greps the
    // printed line.
    eng.clear_prefix_cache();
    mirror_pool(&eng, m);
    let g = eng.pool_gauges();
    println!("kv pool balance after drain: {} pages live, {} refs live",
             g.pages_live, g.refs_live);
    debug_assert_eq!((g.refs_live, g.pages_live), (0, 0),
                     "drain leaked KV pages");
    ctl.service_done.store(true, SeqCst);
}
