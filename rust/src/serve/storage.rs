//! Pluggable shard storage (DESIGN.md §14): where the coordinator
//! reads per-worker shard artifacts from, and the checksum scheme the
//! chunked fetch protocol verifies against.
//!
//! [`StorageBackend`] is deliberately tiny — `meta` + ranged `read` —
//! so a remote object store can slot in later; [`LocalDir`] is the
//! implementation over an `osp shard` output directory. Artifacts are
//! content-addressed with FNV-1a 64 at two granularities: one digest
//! over the whole file (the manifest / end-of-fetch check) and one per
//! [`CHUNK_BYTES`] chunk, which is what makes interrupted fetches
//! *resumable*: a worker re-verifies the chunks it already spooled and
//! continues from the first unverified one instead of starting over.
//!
//! Checksums cross JSON as fixed-width hex strings, never numbers:
//! the JSON layer carries f64, which silently loses u64 precision past
//! 2^53.

use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Fetch-protocol chunk size. Small enough that a resumed fetch loses
/// at most 64 KiB of progress, large enough that per-chunk overhead
/// (one digest, one HTTP range request) stays negligible.
pub const CHUNK_BYTES: usize = 64 * 1024;

/// FNV-1a 64-bit digest — tiny, dependency-free, and plenty for
/// transport/bit-rot detection (this is an integrity check, not an
/// adversarial MAC).
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// `u64` digest as the fixed-width hex string it travels as in JSON.
pub fn fnv_hex(v: u64) -> String {
    format!("{v:016x}")
}

pub fn parse_fnv(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16)
        .with_context(|| format!("bad fnv digest '{s}'"))
}

/// Per-[`CHUNK_BYTES`] digests of an artifact (last chunk short).
pub fn chunk_sums(bytes: &[u8]) -> Vec<u64> {
    bytes.chunks(CHUNK_BYTES).map(fnv64).collect()
}

/// What a worker needs to fetch-and-verify one shard artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMeta {
    pub shard: usize,
    pub bytes: usize,
    /// Whole-artifact digest (checked after the last chunk).
    pub fnv: u64,
    /// Per-chunk digests (checked as each chunk lands; the resume
    /// anchor).
    pub chunk_fnv: Vec<u64>,
}

impl ShardMeta {
    pub fn n_chunks(&self) -> usize {
        self.chunk_fnv.len()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::num(self.shard as f64)),
            ("bytes", Json::num(self.bytes as f64)),
            ("chunk_bytes", Json::num(CHUNK_BYTES as f64)),
            ("fnv", Json::str(fnv_hex(self.fnv))),
            ("chunks",
             Json::Arr(self.chunk_fnv.iter().map(|&c| {
                 Json::str(fnv_hex(c))
             }).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ShardMeta> {
        let cb = j.req("chunk_bytes")?.as_usize()
            .context("chunk_bytes")?;
        if cb != CHUNK_BYTES {
            bail!("peer chunk size {cb} != ours {CHUNK_BYTES}");
        }
        let chunk_fnv = j
            .req("chunks")?
            .as_arr()
            .context("chunks")?
            .iter()
            .map(|c| parse_fnv(c.as_str().context("chunk digest")?))
            .collect::<Result<Vec<u64>>>()?;
        Ok(ShardMeta {
            shard: j.req("shard")?.as_usize().context("shard")?,
            bytes: j.req("bytes")?.as_usize().context("bytes")?,
            fnv: parse_fnv(j.req("fnv")?.as_str().context("fnv")?)?,
            chunk_fnv,
        })
    }
}

/// Where shard artifacts live. Implementations must be safe to call
/// from concurrent handler threads.
pub trait StorageBackend: Send + Sync {
    fn n_shards(&self) -> usize;

    /// Size + digests of one shard's artifact.
    fn meta(&self, shard: usize) -> Result<ShardMeta>;

    /// `len` bytes at `offset` of the shard's artifact; errors rather
    /// than short-reads past the end.
    fn read(&self, shard: usize, offset: usize, len: usize)
            -> Result<Vec<u8>>;
}

/// One artifact line of `manifest.json`.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub file: String,
    pub bytes: usize,
    pub fnv: u64,
}

/// The `osp shard` output directory's index: shard count, model arch,
/// and the per-shard artifact digests a [`LocalDir`] serves against.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub shards: usize,
    pub arch: String,
    pub files: Vec<ManifestEntry>,
}

pub fn write_manifest(dir: &Path, m: &Manifest) -> Result<()> {
    assert_eq!(m.files.len(), m.shards, "one artifact per shard");
    let doc = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("shards", Json::num(m.shards as f64)),
        ("arch", Json::str(m.arch.clone())),
        ("files",
         Json::Arr(m.files.iter().map(|f| {
             Json::obj(vec![
                 ("file", Json::str(f.file.clone())),
                 ("bytes", Json::num(f.bytes as f64)),
                 ("fnv", Json::str(fnv_hex(f.fnv))),
             ])
         }).collect())),
    ]);
    let path = dir.join("manifest.json");
    std::fs::write(&path, doc.dump())
        .with_context(|| format!("writing {path:?}"))
}

pub fn read_manifest(dir: &Path) -> Result<Manifest> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("no shard manifest at {path:?}"))?;
    let doc = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    let version = doc.req("version")?.as_usize().context("version")?;
    if version != 1 {
        bail!("{path:?}: manifest version {version}, this build reads 1");
    }
    let shards = doc.req("shards")?.as_usize().context("shards")?;
    let files = doc
        .req("files")?
        .as_arr()
        .context("files")?
        .iter()
        .map(|f| {
            Ok(ManifestEntry {
                file: f.req("file")?.as_str().context("file")?.into(),
                bytes: f.req("bytes")?.as_usize().context("bytes")?,
                fnv: parse_fnv(f.req("fnv")?.as_str().context("fnv")?)?,
            })
        })
        .collect::<Result<Vec<ManifestEntry>>>()?;
    if files.len() != shards {
        bail!("{path:?}: {} files for {shards} shards", files.len());
    }
    Ok(Manifest {
        shards,
        arch: doc.req("arch")?.as_str().context("arch")?.into(),
        files,
    })
}

/// [`StorageBackend`] over an `osp shard` output directory. Ranged
/// reads go straight to the file (no resident copy of the artifacts);
/// `meta` re-reads and re-digests the file so tampering after `osp
/// shard` is caught at serve time, not worker-crash time.
pub struct LocalDir {
    dir: PathBuf,
    manifest: Manifest,
}

impl LocalDir {
    pub fn open(dir: &Path) -> Result<LocalDir> {
        let manifest = read_manifest(dir)?;
        Ok(LocalDir { dir: dir.to_path_buf(), manifest })
    }

    pub fn arch(&self) -> &str {
        &self.manifest.arch
    }

    fn entry(&self, shard: usize) -> Result<&ManifestEntry> {
        self.manifest
            .files
            .get(shard)
            .ok_or_else(|| anyhow::anyhow!(
                "shard {shard} of {}", self.manifest.shards))
    }
}

impl StorageBackend for LocalDir {
    fn n_shards(&self) -> usize {
        self.manifest.shards
    }

    fn meta(&self, shard: usize) -> Result<ShardMeta> {
        let e = self.entry(shard)?;
        let path = self.dir.join(&e.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != e.bytes {
            bail!("{path:?}: {} bytes, manifest says {}", bytes.len(),
                  e.bytes);
        }
        let fnv = fnv64(&bytes);
        if fnv != e.fnv {
            bail!("{path:?}: checksum mismatch (artifact modified after \
                   `osp shard`?)");
        }
        Ok(ShardMeta { shard, bytes: bytes.len(), fnv,
                       chunk_fnv: chunk_sums(&bytes) })
    }

    fn read(&self, shard: usize, offset: usize, len: usize)
            -> Result<Vec<u8>> {
        let e = self.entry(shard)?;
        let end = offset.checked_add(len).unwrap_or(usize::MAX);
        if end > e.bytes {
            bail!("range [{offset}, {end}) past {} artifact bytes",
                  e.bytes);
        }
        let path = self.dir.join(&e.file);
        let mut f = std::fs::File::open(&path)
            .with_context(|| format!("opening {path:?}"))?;
        f.seek(SeekFrom::Start(offset as u64))?;
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf)
            .with_context(|| format!("short read in {path:?}"))?;
        Ok(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_store(tag: &str, payloads: &[Vec<u8>]) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("osp_store_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let files = payloads
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let file = format!("shard_{i}.bin");
                std::fs::write(dir.join(&file), p).unwrap();
                ManifestEntry { file, bytes: p.len(), fnv: fnv64(p) }
            })
            .collect();
        write_manifest(&dir, &Manifest {
            shards: payloads.len(),
            arch: "ssnorm_plain".into(),
            files,
        }).unwrap();
        dir
    }

    #[test]
    fn fnv64_known_vectors() {
        // FNV-1a 64 reference values (offset basis and "a").
        assert_eq!(fnv64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv64(b"a"), 0xaf63dc4c8601ec8c);
        assert_ne!(fnv64(b"ab"), fnv64(b"ba"));
    }

    #[test]
    fn fnv_hex_roundtrip_preserves_high_bits() {
        // The reason digests travel as hex strings: 2^53-adjacent u64s
        // collapse in f64, but survive the string path exactly.
        for v in [0u64, 1, (1 << 53) + 1, u64::MAX, 0xcbf29ce484222325] {
            assert_eq!(parse_fnv(&fnv_hex(v)).unwrap(), v);
        }
        assert!(parse_fnv("not-hex").is_err());
    }

    #[test]
    fn chunk_sums_cover_exact_and_ragged_sizes() {
        assert_eq!(chunk_sums(&[]).len(), 0);
        assert_eq!(chunk_sums(&vec![7u8; CHUNK_BYTES]).len(), 1);
        assert_eq!(chunk_sums(&vec![7u8; CHUNK_BYTES + 1]).len(), 2);
        assert_eq!(chunk_sums(&vec![7u8; 3 * CHUNK_BYTES]).len(), 3);
    }

    #[test]
    fn shard_meta_json_roundtrip() {
        let m = ShardMeta {
            shard: 1,
            bytes: CHUNK_BYTES + 17,
            fnv: u64::MAX - 3,
            chunk_fnv: vec![5, (1 << 60) + 9],
        };
        let back =
            ShardMeta::from_json(&Json::parse(&m.to_json().dump())
                                 .unwrap()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn local_dir_serves_meta_and_ranges() {
        let payload: Vec<u8> =
            (0..(CHUNK_BYTES + 100)).map(|i| (i % 251) as u8).collect();
        let dir = temp_store("a", &[vec![1, 2, 3], payload.clone()]);
        let s = LocalDir::open(&dir).unwrap();
        assert_eq!(s.n_shards(), 2);
        assert_eq!(s.arch(), "ssnorm_plain");
        let m = s.meta(1).unwrap();
        assert_eq!(m.bytes, payload.len());
        assert_eq!(m.n_chunks(), 2);
        assert_eq!(m.fnv, fnv64(&payload));
        assert_eq!(s.read(1, 0, 5).unwrap(), &payload[..5]);
        assert_eq!(s.read(1, CHUNK_BYTES, 100).unwrap(),
                   &payload[CHUNK_BYTES..]);
        // Past-the-end and unknown-shard reads fail cleanly.
        assert!(s.read(1, payload.len() - 1, 2).is_err());
        assert!(s.read(2, 0, 1).is_err());
        assert!(s.meta(2).is_err());
    }

    #[test]
    fn local_dir_catches_post_shard_tampering() {
        let dir = temp_store("b", &[vec![9u8; 500]]);
        let s = LocalDir::open(&dir).unwrap();
        assert!(s.meta(0).is_ok());
        // Flip one artifact byte after the manifest was written.
        let path = dir.join("shard_0.bin");
        let mut b = std::fs::read(&path).unwrap();
        b[250] ^= 0xff;
        std::fs::write(&path, &b).unwrap();
        let err = s.meta(0).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // Truncation trips the size check first.
        std::fs::write(&path, &b[..100]).unwrap();
        assert!(s.meta(0).is_err());
    }

    #[test]
    fn manifest_rejects_bad_version_and_missing_dir() {
        let dir = temp_store("c", &[vec![1u8]]);
        let text =
            std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        std::fs::write(dir.join("manifest.json"),
                       text.replace("\"version\":1", "\"version\":9"))
            .unwrap();
        assert!(LocalDir::open(&dir).is_err());
        let empty = std::env::temp_dir().join("osp_store_nope");
        let _ = std::fs::remove_dir_all(&empty);
        assert!(LocalDir::open(&empty).is_err());
    }
}
