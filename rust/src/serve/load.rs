//! `osp serve-load` — the built-in load generator (DESIGN.md §12).
//!
//! Drives a running `osp serve` with N client threads, each issuing a
//! deterministic request schedule whose misbehavior is drawn from a
//! seeded [`ChaosSpec`]. Records exact client-side latency percentiles
//! (per-token gaps and time-to-first-token) plus outcome counts, pulls
//! the server's own counters from `/metrics`, and emits a bench-style
//! `BENCH_serve.json` document diffable with `osp bench-diff`.
//!
//! The client is also the test harness: `tests/serve_properties.rs`
//! reuses [`http_get`]/[`http_post`] and the per-fault request logic
//! through [`run_load`].

use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Pcg;

use super::chaos::{ChaosSpec, Fault};
use super::http::{header, ClientConn};

#[derive(Clone, Debug)]
pub struct LoadOpts {
    /// Server address, e.g. `127.0.0.1:8080`.
    pub addr: String,
    pub clients: usize,
    /// Requests per client.
    pub requests: usize,
    pub prompt_len: usize,
    /// Tokens of a common "system prompt" prepended to every request
    /// (`--prefix-len`; 0 = off). Drawn from the seed alone, so all
    /// clients share it bit-identically — the prefix-sharing
    /// (`--share-prefix`) exercise path.
    pub prefix_len: usize,
    pub max_new: usize,
    pub timeout_ms: u64,
    pub chaos: ChaosSpec,
    /// The raw `--chaos` spec string (bench-row identity).
    pub chaos_label: String,
    /// Chaos-proxy control address (`--proxy`) the fleet faults
    /// (`worker-kill`, `worker-stall-ms`) are driven through; empty =
    /// no proxy, fleet faults are ignored (DESIGN.md §15).
    pub proxy: String,
    pub seed: u64,
}

impl Default for LoadOpts {
    fn default() -> LoadOpts {
        LoadOpts { addr: "127.0.0.1:8080".into(), clients: 4,
                   requests: 8, prompt_len: 12, prefix_len: 0,
                   max_new: 16, timeout_ms: 10_000,
                   chaos: ChaosSpec::off(),
                   chaos_label: "off".into(), proxy: String::new(),
                   seed: 7 }
    }
}

/// Per-client tallies, merged after the run.
#[derive(Clone, Debug, Default)]
pub struct ClientStats {
    pub requests: u64,
    pub completed: u64,
    /// 4xx/5xx before any token (queue full, malformed, oversize,
    /// slow-loris shed, draining).
    pub rejected: u64,
    /// Deadline evictions (504 or a mid-stream deadline chunk).
    pub deadline: u64,
    /// Connections we dropped on purpose (chaos aborts).
    pub aborted: u64,
    /// Anything else: transport errors, truncated streams.
    pub errors: u64,
    pub tokens: u64,
    pub token_gaps_us: Vec<u64>,
    pub first_token_us: Vec<u64>,
}

impl ClientStats {
    fn merge(&mut self, other: ClientStats) {
        self.requests += other.requests;
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.deadline += other.deadline;
        self.aborted += other.aborted;
        self.errors += other.errors;
        self.tokens += other.tokens;
        self.token_gaps_us.extend(other.token_gaps_us);
        self.first_token_us.extend(other.first_token_us);
    }
}

fn connect(addr: &str, read_timeout: Duration) -> Result<TcpStream> {
    // `connect_timeout` rather than `connect`: a black-holed server
    // (SYN dropped, not refused) would otherwise park the client for
    // the kernel's connect timeout — minutes, not the bounded wait
    // the health prober and chaos driver need.
    let sa = addr
        .to_socket_addrs()
        .with_context(|| format!("resolve {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("no address for {addr}"))?;
    let stream =
        TcpStream::connect_timeout(&sa, read_timeout.min(
            Duration::from_secs(5)))
        .with_context(|| format!("connect {addr}"))?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(read_timeout))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    Ok(stream)
}

/// Blocking GET returning (status, parsed body). Used for `/metrics`
/// and `/healthz`.
pub fn http_get(addr: &str, path: &str) -> Result<(u16, Json)> {
    http_get_timeout(addr, path, Duration::from_secs(10))
}

/// [`http_get`] with an explicit per-call budget covering connect and
/// read — the health prober's probe must fail fast, not inherit the
/// 10 s client default.
pub fn http_get_timeout(addr: &str, path: &str, timeout: Duration)
                        -> Result<(u16, Json)> {
    let stream = connect(addr, timeout)?;
    let mut conn = ClientConn::new(stream);
    conn.send_request("GET", path, "")?;
    read_framed_json(&mut conn)
}

/// Blocking POST returning (status, parsed body). Used for
/// `/admin/drain` and non-streaming error paths.
pub fn http_post(addr: &str, path: &str, body: &str)
                 -> Result<(u16, Json)> {
    let stream = connect(addr, Duration::from_secs(10))?;
    let mut conn = ClientConn::new(stream);
    conn.send_request("POST", path, body)?;
    read_framed_json(&mut conn)
}

fn read_framed_json(conn: &mut ClientConn<TcpStream>)
                    -> Result<(u16, Json)> {
    let (status, headers) = conn.read_head()?;
    let n: usize = header(&headers, "content-length")
        .ok_or_else(|| anyhow!("response without Content-Length"))?
        .parse()?;
    let body = conn.read_body(n)?;
    let doc = Json::parse(&body)
        .map_err(|e| anyhow!("bad response JSON: {e}"))?;
    Ok((status, doc))
}

/// Outcome of one streamed `/generate` exchange.
enum Outcome {
    Completed,
    Rejected,
    Deadline,
    Aborted,
    Error,
}

fn deterministic_prompt(opts: &LoadOpts, vocab: usize, client: u64,
                        req: u64) -> Vec<i32> {
    let mut out =
        Vec::with_capacity(opts.prefix_len + opts.prompt_len.max(1));
    // Shared prefix first: seeded from the run seed alone, so every
    // client and request agrees on it token for token.
    let mut pre = Pcg::new(opts.seed, 501);
    for _ in 0..opts.prefix_len {
        out.push(pre.below_usize(vocab.max(1)) as i32);
    }
    let mut rng = Pcg::new(opts.seed ^ (client * 100_000 + req), 500);
    for _ in 0..opts.prompt_len.max(1) {
        out.push(rng.below_usize(vocab.max(1)) as i32);
    }
    out
}

fn one_request(opts: &LoadOpts, vocab: usize, client: u64, req: u64,
               fault: Fault, st: &mut ClientStats) -> Outcome {
    let read_timeout =
        Duration::from_millis(opts.timeout_ms + 15_000);
    match fault {
        Fault::Malformed => {
            let Ok(stream) = connect(&opts.addr, read_timeout) else {
                return Outcome::Error;
            };
            let mut conn = ClientConn::new(stream);
            if conn
                .send_request("POST", "/generate", "{not json")
                .is_err()
            {
                return Outcome::Error;
            }
            match conn.read_head() {
                Ok((400, _)) => Outcome::Rejected,
                Ok(_) => Outcome::Error,
                Err(_) => Outcome::Error,
            }
        }
        Fault::Oversize => {
            let Ok(stream) = connect(&opts.addr, read_timeout) else {
                return Outcome::Error;
            };
            let mut conn = ClientConn::new(stream);
            // Declare an absurd length; send only a sliver. The server
            // must reject on the declaration alone.
            let head = format!(
                "POST /generate HTTP/1.1\r\nHost: osp\r\n\
                 Content-Length: {}\r\nConnection: close\r\n\r\nxx",
                1usize << 30);
            use std::io::Write;
            if conn.stream_mut().write_all(head.as_bytes()).is_err() {
                return Outcome::Error;
            }
            match conn.read_head() {
                Ok((413, _)) => Outcome::Rejected,
                Ok(_) => Outcome::Error,
                Err(_) => Outcome::Error,
            }
        }
        Fault::Slowloris => {
            let Ok(stream) = connect(&opts.addr, read_timeout) else {
                return Outcome::Error;
            };
            let mut conn = ClientConn::new(stream);
            use std::io::Write;
            let partial = "POST /generate HTTP/1.1\r\nHost: osp\r\n";
            if conn
                .stream_mut()
                .write_all(partial.as_bytes())
                .is_err()
            {
                return Outcome::Error;
            }
            thread::sleep(Duration::from_millis(opts.chaos.hold_ms));
            // Either a 408 or a hangup counts as the server correctly
            // shedding us; a wedge would surface as a read timeout.
            match conn.read_head() {
                Ok((408, _)) => Outcome::Rejected,
                Ok(_) => Outcome::Error,
                Err(_) => Outcome::Rejected,
            }
        }
        Fault::None
        | Fault::DelayedRead
        | Fault::TinyDeadline
        | Fault::Abort { .. } => {
            let prompt = deterministic_prompt(opts, vocab, client, req);
            let timeout_ms = if fault == Fault::TinyDeadline {
                1
            } else {
                opts.timeout_ms
            };
            let body = format!(
                "{{\"prompt\":{},\"max_new\":{},\"timeout_ms\":{}}}",
                Json::Arr(prompt
                    .iter()
                    .map(|&t| Json::num(t as f64))
                    .collect())
                .dump(),
                opts.max_new, timeout_ms);
            let Ok(stream) = connect(&opts.addr, read_timeout) else {
                return Outcome::Error;
            };
            let mut conn = ClientConn::new(stream);
            let t_send = Instant::now();
            if conn.send_request("POST", "/generate", &body).is_err() {
                return Outcome::Error;
            }
            if let Fault::Abort { after_tokens: 0 } = fault {
                return Outcome::Aborted;
            }
            if fault == Fault::DelayedRead {
                thread::sleep(Duration::from_millis(
                    opts.chaos.delay_ms));
            }
            let (status, _headers) = match conn.read_head() {
                Ok(h) => h,
                Err(_) => return Outcome::Error,
            };
            match status {
                200 => {}
                503 | 400 | 413 | 408 => return Outcome::Rejected,
                504 => return Outcome::Deadline,
                _ => return Outcome::Error,
            }
            let abort_after = match fault {
                Fault::Abort { after_tokens } => Some(after_tokens),
                _ => None,
            };
            let mut got = 0u64;
            let mut prev: Option<Instant> = None;
            loop {
                let line = match conn.next_chunk() {
                    Ok(Some(line)) => line,
                    Ok(None) => {
                        // Stream ended without a terminal event.
                        return Outcome::Error;
                    }
                    Err(_) => return Outcome::Error,
                };
                let now = Instant::now();
                let Ok(ev) = Json::parse(line.trim()) else {
                    return Outcome::Error;
                };
                if ev.get("token").is_some() {
                    got += 1;
                    st.tokens += 1;
                    match prev {
                        None => st.first_token_us.push(
                            now.duration_since(t_send).as_micros()
                                as u64),
                        Some(p) => st.token_gaps_us.push(
                            now.duration_since(p).as_micros() as u64),
                    }
                    prev = Some(now);
                    if let Some(k) = abort_after {
                        if got as usize >= k.max(1) {
                            return Outcome::Aborted;
                        }
                    }
                    continue;
                }
                if ev
                    .get("done")
                    .and_then(|d| d.as_bool())
                    .unwrap_or(false)
                {
                    return Outcome::Completed;
                }
                match ev.get("error").and_then(|e| e.as_str()) {
                    Some("deadline") => return Outcome::Deadline,
                    _ => return Outcome::Error,
                }
            }
        }
    }
}

fn run_client(opts: &LoadOpts, vocab: usize, client: u64)
              -> ClientStats {
    let mut st = ClientStats::default();
    for r in 0..opts.requests as u64 {
        let fault = opts.chaos.draw(client, r);
        st.requests += 1;
        match one_request(opts, vocab, client, r, fault, &mut st) {
            Outcome::Completed => st.completed += 1,
            Outcome::Rejected => st.rejected += 1,
            Outcome::Deadline => st.deadline += 1,
            Outcome::Aborted => st.aborted += 1,
            Outcome::Error => st.errors += 1,
        }
    }
    st
}

/// Exact percentile over raw samples (client side keeps every sample,
/// unlike the server's bucketed histogram).
fn percentile_ms(samples: &mut [u64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_unstable();
    let idx = ((q * (samples.len() - 1) as f64).round() as usize)
        .min(samples.len() - 1);
    samples[idx] as f64 / 1000.0
}

/// Drive the fleet faults (DESIGN.md §15) against the chaos proxy at
/// `opts.proxy` while the client threads run:
///
/// * `worker-stall-ms=t` — applied immediately; every forwarded
///   connection stalls `t` ms, exercising Suspect/backoff without a
///   breaker trip.
/// * `worker-kill=k` — waits until the coordinator reports `k`
///   completed requests (so the kill lands mid-run, not before the
///   fleet warms up), drops the proxied worker, holds `hold_ms`, then
///   revives it — the failover→breaker→rejoin arc in one run.
///
/// `stop` is the client-threads-finished signal: a threshold never
/// reached skips the kill rather than firing it after the run.
fn drive_fleet_faults(opts: &LoadOpts, stop: &AtomicBool) {
    if opts.proxy.is_empty() || !opts.chaos.has_fleet_faults() {
        return;
    }
    let c = &opts.chaos;
    if c.worker_stall_ms > 0 {
        let _ = http_post(
            &opts.proxy,
            &format!("/chaos/stall?ms={}", c.worker_stall_ms), "{}");
    }
    if c.worker_kill == 0 {
        return;
    }
    while !stop.load(Ordering::SeqCst) {
        let completed = http_get(&opts.addr, "/metrics")
            .ok()
            .and_then(|(_, d)| {
                d.get("metrics")?.get("completed")?.as_f64()
            })
            .unwrap_or(0.0);
        if completed >= c.worker_kill as f64 {
            let _ = http_post(&opts.proxy, "/chaos/kill", "{}");
            thread::sleep(Duration::from_millis(c.hold_ms));
            let _ = http_post(&opts.proxy, "/chaos/revive", "{}");
            return;
        }
        thread::sleep(Duration::from_millis(20));
    }
}

/// Drive the server at `opts.addr` and return a `BENCH_serve.json`
/// document (bench-style: `{"bench":"serve","threads":N,"rows":[...]}`
/// — one row keyed by config/clients/chaos, diffable with
/// `osp bench-diff`).
pub fn run_load(opts: &LoadOpts) -> Result<Json> {
    let (status, info) = http_get(&opts.addr, "/metrics")
        .with_context(|| format!("fetch {}/metrics", opts.addr))?;
    if status != 200 {
        bail!("/metrics returned {status}");
    }
    let vocab = info
        .get("vocab")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow!("/metrics missing 'vocab'"))?;
    let t0 = Instant::now();
    let mut total = ClientStats::default();
    let clients_done = AtomicBool::new(false);
    let done_ref = &clients_done;
    thread::scope(|s| {
        let driver =
            s.spawn(move || drive_fleet_faults(opts, done_ref));
        let handles: Vec<_> = (0..opts.clients as u64)
            .map(|c| s.spawn(move || run_client(opts, vocab, c)))
            .collect();
        for h in handles {
            if let Ok(st) = h.join() {
                total.merge(st);
            }
        }
        clients_done.store(true, Ordering::SeqCst);
        let _ = driver.join();
    });
    let wall = t0.elapsed().as_secs_f64();
    let (_, after) = http_get(&opts.addr, "/metrics")
        .context("fetch final /metrics")?;
    let server = |key: &str| {
        after
            .get("metrics")
            .and_then(|m| m.get(key))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
    };
    // Sharded-mode extras off `/status` (live per-worker scrape). On a
    // single-process server `worker_status` is empty and these fold to
    // zero, keeping the row schema stable across modes.
    let status_doc = http_get(&opts.addr, "/status")
        .map(|(_, d)| d)
        .unwrap_or(Json::Null);
    let worker_stat = |key: &str, fold: fn(f64, f64) -> f64| {
        status_doc
            .get("worker_status")
            .and_then(|w| w.as_arr())
            .map(|ws| {
                ws.iter()
                    .filter_map(|w| {
                        w.get(key).and_then(|v| v.as_f64())
                    })
                    .fold(0.0, fold)
            })
            .unwrap_or(0.0)
    };
    // Fleet-robustness counters off the coordinator's health registry
    // (DESIGN.md §15); Null-shaped absence folds to 0 single-process.
    let fleet = |key: &str| {
        status_doc
            .get("fleet_health")
            .and_then(|f| f.get(key))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
    };
    let mut gaps = total.token_gaps_us.clone();
    let mut firsts = total.first_token_us.clone();
    let row = Json::obj(vec![
        ("phase", Json::str("serve")),
        ("config",
         Json::str(info
             .get("config")
             .and_then(|c| c.as_str())
             .unwrap_or("?"))),
        ("w_bits", info.get("w_bits").cloned().unwrap_or(Json::Null)),
        ("a_bits", info.get("a_bits").cloned().unwrap_or(Json::Null)),
        ("kv_bits",
         info.get("kv_bits").cloned().unwrap_or(Json::Null)),
        ("clients", Json::num(opts.clients as f64)),
        ("chaos", Json::str(opts.chaos_label.clone())),
        ("prompt_len", Json::num(opts.prompt_len as f64)),
        ("prefix_len", Json::num(opts.prefix_len as f64)),
        ("kv_page_rows",
         info.get("kv_page_rows").cloned().unwrap_or(Json::Null)),
        ("share_prefix",
         info.get("share_prefix").cloned().unwrap_or(Json::Null)),
        ("workers", info.get("workers").cloned().unwrap_or(Json::Null)),
        ("shards", info.get("shards").cloned().unwrap_or(Json::Null)),
        ("replicas",
         info.get("replicas").cloned().unwrap_or(Json::Null)),
        ("requests", Json::num(total.requests as f64)),
        ("completed", Json::num(total.completed as f64)),
        ("rejected", Json::num(total.rejected as f64)),
        ("deadline", Json::num(total.deadline as f64)),
        ("aborted", Json::num(total.aborted as f64)),
        ("errors", Json::num(total.errors as f64)),
        ("tokens", Json::num(total.tokens as f64)),
        ("p50_token_ms", Json::num(percentile_ms(&mut gaps, 0.50))),
        ("p99_token_ms", Json::num(percentile_ms(&mut gaps, 0.99))),
        ("first_token_p50_ms",
         Json::num(percentile_ms(&mut firsts, 0.50))),
        ("gen_tokens_per_sec",
         Json::num(total.tokens as f64 / wall.max(1e-9))),
        ("wall_secs", Json::num(wall)),
        ("server_admitted", Json::num(server("admitted"))),
        ("server_completed", Json::num(server("completed"))),
        ("server_timed_out", Json::num(server("timed_out"))),
        ("server_cancelled", Json::num(server("cancelled"))),
        ("server_failed", Json::num(server("failed"))),
        ("server_rejected_full", Json::num(server("rejected_full"))),
        ("server_rejected_bad", Json::num(server("rejected_bad"))),
        ("server_uncovered_503s",
         Json::num(server("uncovered_503s"))),
        ("failovers", Json::num(fleet("failovers"))),
        ("breaker_trips", Json::num(fleet("breaker_trips"))),
        ("rejoins", Json::num(fleet("rejoins"))),
        ("server_queue_depth", Json::num(server("queue_depth"))),
        ("server_in_flight", Json::num(server("in_flight"))),
        ("kv_bytes_peak", Json::num(server("kv_bytes_peak"))),
        ("kv_pages_peak", Json::num(server("kv_pages_peak"))),
        ("kv_pages_shared", Json::num(server("kv_pages_shared"))),
        ("kv_pages_live", Json::num(server("kv_pages_live"))),
        // Shard-distribution metrics (DESIGN.md §14): slowest worker
        // fetch, total artifact bytes over the wire, and the per-worker
        // vs full-model weight footprint the memory win is judged on.
        ("fetch_ms", Json::num(worker_stat("fetch_ms", f64::max))),
        ("bytes_streamed",
         Json::num(worker_stat("bytes_fetched", |a, b| a + b))),
        ("worker_weight_bytes_max",
         Json::num(worker_stat("weight_bytes", f64::max))),
        ("weight_bytes_full",
         info.get("weight_bytes_full").cloned()
             .unwrap_or(Json::Null)),
        ("weight_bytes_coord",
         info.get("weight_bytes_coord").cloned()
             .unwrap_or(Json::Null)),
    ]);
    Ok(Json::obj(vec![
        ("bench", Json::str("serve")),
        ("threads", Json::num(opts.clients as f64)),
        ("rows", Json::Arr(vec![row])),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_exact_on_sorted_samples() {
        let mut xs: Vec<u64> = (1..=100).map(|i| i * 1000).collect();
        let p50 = percentile_ms(&mut xs.clone(), 0.50);
        let p99 = percentile_ms(&mut xs, 0.99);
        assert!((p50 - 50.0).abs() <= 1.0, "p50={p50}");
        assert!((p99 - 99.0).abs() <= 1.0, "p99={p99}");
        assert_eq!(percentile_ms(&mut [], 0.5), 0.0);
    }

    #[test]
    fn shared_prefix_is_identical_across_clients() {
        let mut opts = LoadOpts::default();
        opts.prefix_len = 16;
        let a = deterministic_prompt(&opts, 128, 0, 0);
        let b = deterministic_prompt(&opts, 128, 5, 3);
        assert_eq!(a.len(), 16 + opts.prompt_len);
        assert_eq!(&a[..16], &b[..16],
                   "prefix is shared across clients and requests");
        assert_ne!(&a[16..], &b[16..], "suffixes stay per-request");
    }

    #[test]
    fn prompts_are_deterministic_per_client_request() {
        let opts = LoadOpts::default();
        let a = deterministic_prompt(&opts, 128, 3, 7);
        let b = deterministic_prompt(&opts, 128, 3, 7);
        let c = deterministic_prompt(&opts, 128, 3, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.iter().all(|&t| (0..128).contains(&t)));
    }
}
