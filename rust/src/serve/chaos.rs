//! Deterministic fault injection for the serve front-end
//! (DESIGN.md §12). Chaos lives in the *client* (the load generator and
//! the integration tests): the server under test is always the real
//! server, and the spec decides how each request misbehaves — so every
//! failure path the handler/service threads must survive is exercised
//! reproducibly from a seed.
//!
//! Spec grammar (the `--chaos` flag):
//!
//! ```text
//! spec    := "off" | "default" | [preset ","] pair ("," pair)*
//! preset  := "off" | "default"
//! pair    := key "=" value
//! key     := seed | abort | delay | oversize | malformed
//!          | slowloris | tiny_deadline | delay_ms | hold_ms
//!          | worker-kill | worker-stall-ms
//! ```
//!
//! Probability keys take values in `[0,1]` and their sum must be <= 1
//! (the remainder is the well-behaved-request probability). The draw
//! for (client c, request r) depends only on `(seed, c, r)` — chaos
//! schedules replay exactly across runs, which is what lets the
//! bit-parity acceptance test compare a chaos run against an
//! unperturbed run.
//!
//! The two `worker-*` keys are **fleet faults** (DESIGN.md §15): they
//! perturb one worker of a sharded fleet, not a client request, and
//! require a [`ChaosProxy`] sitting in front of that worker
//! (`--proxy` on `osp serve-load`). `worker-kill=k` drops the worker
//! after the coordinator completes `k` requests and revives it
//! `hold_ms` later; `worker-stall-ms=t` delays every forwarded
//! connection by `t` ms.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::rng::Pcg;

/// What one request does to the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Well-behaved request.
    None,
    /// Disconnect after reading `after_tokens` streamed tokens
    /// (0 = right after sending the request).
    Abort { after_tokens: usize },
    /// Sleep `delay_ms` before reading the response (slow consumer).
    DelayedRead,
    /// Declare an absurd Content-Length; expect 413.
    Oversize,
    /// Send a syntactically broken request; expect 400.
    Malformed,
    /// Send a partial header then stall `hold_ms`; expect the server
    /// to shed the connection (408 or a hangup), never to wedge.
    Slowloris,
    /// Ask for `timeout_ms=1`; expect a deadline eviction (504 or a
    /// truncated stream), batchmates unaffected.
    TinyDeadline,
}

#[derive(Clone, Copy, Debug)]
pub struct ChaosSpec {
    pub seed: u64,
    pub abort: f64,
    pub delay: f64,
    pub oversize: f64,
    pub malformed: f64,
    pub slowloris: f64,
    pub tiny_deadline: f64,
    /// Slow-consumer pause before reads.
    pub delay_ms: u64,
    /// Slow-loris stall length (must exceed the server header timeout
    /// for the fault to actually trigger a 408). Doubles as the
    /// kill→revive hold for `worker-kill`.
    pub hold_ms: u64,
    /// Fleet fault: SIGKILL-equivalent drop of the proxied worker
    /// after this many completed requests (0 = off).
    pub worker_kill: u64,
    /// Fleet fault: per-connection forward stall in ms (0 = off).
    pub worker_stall_ms: u64,
}

impl ChaosSpec {
    pub fn off() -> ChaosSpec {
        ChaosSpec { seed: 0, abort: 0.0, delay: 0.0, oversize: 0.0,
                    malformed: 0.0, slowloris: 0.0, tiny_deadline: 0.0,
                    delay_ms: 40, hold_ms: 3000, worker_kill: 0,
                    worker_stall_ms: 0 }
    }

    /// The CI preset: every failure class is present, a majority of
    /// requests are still well-behaved.
    pub fn default_preset() -> ChaosSpec {
        ChaosSpec { abort: 0.20, delay: 0.10, oversize: 0.05,
                    malformed: 0.10, slowloris: 0.05,
                    tiny_deadline: 0.10, ..ChaosSpec::off() }
    }

    pub fn is_off(&self) -> bool {
        self.abort + self.delay + self.oversize + self.malformed
            + self.slowloris + self.tiny_deadline
            == 0.0
            && !self.has_fleet_faults()
    }

    /// Any fleet (worker-level) fault requested?
    pub fn has_fleet_faults(&self) -> bool {
        self.worker_kill > 0 || self.worker_stall_ms > 0
    }

    /// Parse a `--chaos` spec string (grammar above).
    pub fn parse(spec: &str) -> Result<ChaosSpec> {
        let mut out = ChaosSpec::off();
        for (i, part) in spec.split(',').enumerate() {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part {
                "off" | "default" if i == 0 => {
                    if part == "default" {
                        out = ChaosSpec::default_preset();
                    }
                    continue;
                }
                _ => {}
            }
            let Some((k, v)) = part.split_once('=') else {
                bail!("chaos: expected key=value, got '{part}' \
                       (presets 'off'/'default' must come first)");
            };
            let (k, v) = (k.trim(), v.trim());
            let prob = |v: &str| -> Result<f64> {
                let p: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!(
                        "chaos: bad probability '{v}' for '{k}'"))?;
                if !(0.0..=1.0).contains(&p) {
                    bail!("chaos: probability '{k}={p}' outside [0,1]");
                }
                Ok(p)
            };
            match k {
                "seed" => out.seed = v.parse()?,
                "abort" => out.abort = prob(v)?,
                "delay" => out.delay = prob(v)?,
                "oversize" => out.oversize = prob(v)?,
                "malformed" => out.malformed = prob(v)?,
                "slowloris" => out.slowloris = prob(v)?,
                "tiny_deadline" => out.tiny_deadline = prob(v)?,
                "delay_ms" => out.delay_ms = v.parse()?,
                "hold_ms" => out.hold_ms = v.parse()?,
                "worker-kill" => out.worker_kill = v.parse()?,
                "worker-stall-ms" => {
                    out.worker_stall_ms = v.parse()?
                }
                _ => bail!("chaos: unknown key '{k}'"),
            }
        }
        let sum = out.abort + out.delay + out.oversize + out.malformed
            + out.slowloris
            + out.tiny_deadline;
        if sum > 1.0 + 1e-9 {
            bail!("chaos: fault probabilities sum to {sum:.3} > 1");
        }
        Ok(out)
    }

    /// Deterministic fault for `(client, request)` under this spec.
    pub fn draw(&self, client: u64, request: u64) -> Fault {
        let mut rng = Pcg::new(
            self.seed ^ client.wrapping_mul(0x9E3779B97F4A7C15),
            1000 + request);
        let x = rng.uniform();
        let mut acc = 0.0;
        let classes = [
            (self.abort, 0usize),
            (self.delay, 1),
            (self.oversize, 2),
            (self.malformed, 3),
            (self.slowloris, 4),
            (self.tiny_deadline, 5),
        ];
        for (p, tag) in classes {
            acc += p;
            if x < acc {
                return match tag {
                    0 => Fault::Abort {
                        after_tokens: rng.below_usize(4),
                    },
                    1 => Fault::DelayedRead,
                    2 => Fault::Oversize,
                    3 => Fault::Malformed,
                    4 => Fault::Slowloris,
                    _ => Fault::TinyDeadline,
                };
            }
        }
        Fault::None
    }
}

/// A TCP chaos proxy fronting one worker (DESIGN.md §15). Forwards
/// byte streams verbatim — worker RPC semantics are preserved
/// bit-for-bit — while exposing an HTTP control surface on the same
/// port for the fleet faults:
///
/// * `POST /chaos/kill` — drop every subsequent connection before a
///   byte reaches the worker, so from the coordinator the worker
///   looks SIGKILLed;
/// * `POST /chaos/revive` — resume forwarding;
/// * `POST /chaos/stall?ms=N` — delay each forward by `N` ms
///   (`worker-stall-ms`), exercising Suspect/backoff;
/// * `GET /chaos/ping` — current fault state.
///
/// Control paths are recognised by peeking the head of each inbound
/// connection; anything else is replayed to the target untouched.
/// Run standalone as `osp chaos-proxy --listen A --target B`, or in
/// process from the integration tests.
pub struct ChaosProxy {
    addr: String,
    killed: Arc<AtomicBool>,
    stall_ms: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
}

impl ChaosProxy {
    /// Bind `listen` (port 0 picks an ephemeral port; see
    /// [`ChaosProxy::addr`]) and start forwarding to `target`.
    pub fn spawn(listen: &str, target: &str) -> Result<ChaosProxy> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("chaos-proxy bind {listen}"))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?.to_string();
        let killed = Arc::new(AtomicBool::new(false));
        let stall_ms = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let (k2, s2, st2) = (Arc::clone(&killed), Arc::clone(&stall_ms),
                             Arc::clone(&stop));
        let target = target.to_string();
        thread::Builder::new()
            .name("osp-chaos-proxy".into())
            .spawn(move || loop {
                if st2.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let t = target.clone();
                        let k = Arc::clone(&k2);
                        let s = Arc::clone(&s2);
                        let _ = thread::Builder::new()
                            .name("osp-chaos-conn".into())
                            .spawn(move || {
                                proxy_conn(stream, &t, &k, &s)
                            });
                    }
                    Err(_) => {
                        thread::sleep(Duration::from_millis(2))
                    }
                }
            })?;
        Ok(ChaosProxy { addr, killed, stall_ms, stop })
    }

    /// The bound listen address (`host:port`).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn kill(&self) {
        self.killed.store(true, Ordering::SeqCst);
    }

    pub fn revive(&self) {
        self.killed.store(false, Ordering::SeqCst);
    }

    pub fn set_stall_ms(&self, ms: u64) {
        self.stall_ms.store(ms, Ordering::SeqCst);
    }

    /// Stop accepting; existing forwards finish on their own.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn head_complete(buf: &[u8]) -> bool {
    buf.windows(4).any(|w| w == b"\r\n\r\n")
}

/// Raw bytes up to (and past) the end of the request head, capped at
/// 8 KiB — enough to classify the path, and whatever body bytes ride
/// along are replayed to the target with it.
fn read_head_raw(stream: &mut TcpStream) -> Option<Vec<u8>> {
    let mut buf = Vec::with_capacity(1024);
    let mut tmp = [0u8; 1024];
    while !head_complete(&buf) && buf.len() < 8192 {
        match stream.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(_) => return None,
        }
    }
    if buf.is_empty() { None } else { Some(buf) }
}

fn proxy_conn(mut client: TcpStream, target: &str,
              killed: &AtomicBool, stall: &AtomicU64) {
    let _ = client.set_nodelay(true);
    let _ = client.set_read_timeout(Some(Duration::from_secs(5)));
    let _ = client.set_write_timeout(Some(Duration::from_secs(5)));
    let Some(head) = read_head_raw(&mut client) else { return };
    let line_end = head.iter().position(|&b| b == b'\n')
        .unwrap_or(head.len());
    let line = String::from_utf8_lossy(&head[..line_end]);
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if path.starts_with("/chaos/") {
        control(&mut client, &method, &path, killed, stall);
        return;
    }
    if killed.load(Ordering::SeqCst) {
        // Dead worker: hang up without a byte. The coordinator sees a
        // transport error, exactly like a SIGKILLed process.
        return;
    }
    forward(client, head, target, stall.load(Ordering::SeqCst));
}

fn control(stream: &mut TcpStream, method: &str, path: &str,
           killed: &AtomicBool, stall: &AtomicU64) {
    let (bare, query) = path.split_once('?').unwrap_or((path, ""));
    let status = match (method, bare) {
        ("POST", "/chaos/kill") => {
            killed.store(true, Ordering::SeqCst);
            200
        }
        ("POST", "/chaos/revive") => {
            killed.store(false, Ordering::SeqCst);
            200
        }
        ("POST", "/chaos/stall") => {
            match query.strip_prefix("ms=")
                .and_then(|v| v.parse::<u64>().ok())
            {
                Some(ms) => {
                    stall.store(ms, Ordering::SeqCst);
                    200
                }
                None => 400,
            }
        }
        ("GET", "/chaos/ping") => 200,
        _ => 404,
    };
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        _ => "Not Found",
    };
    let body = format!("{{\"killed\":{},\"stall_ms\":{}}}",
                       killed.load(Ordering::SeqCst),
                       stall.load(Ordering::SeqCst));
    let _ = write!(stream,
                   "HTTP/1.1 {status} {reason}\r\n\
                    Content-Length: {}\r\n\
                    Content-Type: application/json\r\n\
                    Connection: close\r\n\r\n{body}",
                   body.len());
    let _ = stream.flush();
}

fn forward(mut client: TcpStream, head: Vec<u8>, target: &str,
           stall_ms: u64) {
    if stall_ms > 0 {
        thread::sleep(Duration::from_millis(stall_ms));
    }
    let Some(sa) = target.to_socket_addrs().ok()
        .and_then(|mut i| i.next())
    else {
        return;
    };
    let Ok(mut upstream) =
        TcpStream::connect_timeout(&sa, Duration::from_secs(5))
    else {
        return;
    };
    let _ = upstream.set_nodelay(true);
    let _ = upstream.set_read_timeout(Some(Duration::from_secs(30)));
    if upstream.write_all(&head).is_err() {
        return;
    }
    let (Ok(mut up_w), Ok(mut cl_r)) =
        (upstream.try_clone(), client.try_clone())
    else {
        return;
    };
    // Pump any remaining request bytes client→target while the main
    // thread relays the response target→client; the upstream's
    // Connection-close EOF ends the relay and the shutdowns unblock
    // the pump.
    let pump = thread::spawn(move || {
        let _ = std::io::copy(&mut cl_r, &mut up_w);
        let _ = up_w.shutdown(Shutdown::Write);
    });
    let _ = std::io::copy(&mut upstream, &mut client);
    let _ = client.shutdown(Shutdown::Both);
    let _ = upstream.shutdown(Shutdown::Both);
    let _ = pump.join();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_and_overrides() {
        assert!(ChaosSpec::parse("off").unwrap().is_off());
        let d = ChaosSpec::parse("default").unwrap();
        assert!(!d.is_off());
        assert_eq!(d.abort, 0.20);
        let c =
            ChaosSpec::parse("default,seed=42,abort=0.5,delay=0")
                .unwrap();
        assert_eq!(c.seed, 42);
        assert_eq!(c.abort, 0.5);
        assert_eq!(c.delay, 0.0);
        assert_eq!(c.malformed, 0.10);
        let bare = ChaosSpec::parse("abort=1").unwrap();
        assert_eq!(bare.abort, 1.0);
        assert_eq!(bare.malformed, 0.0);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(ChaosSpec::parse("abort=1.5").is_err());
        assert!(ChaosSpec::parse("abort=-0.1").is_err());
        assert!(ChaosSpec::parse("abort=0.7,delay=0.7").is_err());
        assert!(ChaosSpec::parse("wibble=0.5").is_err());
        assert!(ChaosSpec::parse("abort").is_err());
        assert!(ChaosSpec::parse("abort=0.1,default").is_err());
    }

    #[test]
    fn draws_are_deterministic_and_seed_sensitive() {
        let spec = ChaosSpec::parse("default,seed=7").unwrap();
        for client in 0..4u64 {
            for req in 0..16u64 {
                assert_eq!(spec.draw(client, req),
                           spec.draw(client, req));
            }
        }
        let other = ChaosSpec::parse("default,seed=8").unwrap();
        let differs = (0..64u64)
            .any(|r| spec.draw(0, r) != other.draw(0, r));
        assert!(differs, "seed change never altered the schedule");
    }

    #[test]
    fn draw_frequencies_roughly_match_probabilities() {
        let spec = ChaosSpec::parse("abort=0.5,seed=3").unwrap();
        let n = 2000u64;
        let aborts = (0..n)
            .filter(|&r| matches!(spec.draw(1, r), Fault::Abort { .. }))
            .count();
        let frac = aborts as f64 / n as f64;
        assert!((0.42..0.58).contains(&frac), "abort frac {frac}");
    }

    #[test]
    fn certain_fault_always_fires() {
        let spec = ChaosSpec::parse("malformed=1").unwrap();
        for r in 0..32u64 {
            assert_eq!(spec.draw(0, r), Fault::Malformed);
        }
    }

    #[test]
    fn fleet_fault_keys_parse() {
        let c = ChaosSpec::parse(
            "worker-kill=3,worker-stall-ms=250,hold_ms=900")
            .unwrap();
        assert_eq!(c.worker_kill, 3);
        assert_eq!(c.worker_stall_ms, 250);
        assert_eq!(c.hold_ms, 900);
        assert!(c.has_fleet_faults());
        assert!(!c.is_off(), "fleet faults are not 'off'");
        assert!(!ChaosSpec::parse("off").unwrap().has_fleet_faults());
        assert!(ChaosSpec::parse("worker-kill=x").is_err());
        assert!(ChaosSpec::parse("worker_kill=1").is_err(),
                "grammar uses hyphens");
    }

    /// Minimal single-response HTTP target: enough for the proxy's
    /// pass-through, kill, and stall paths to be observed end to end.
    fn spawn_target() -> (String, Arc<AtomicBool>) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        thread::spawn(move || {
            for conn in l.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    return;
                }
                let Ok(mut s) = conn else { continue };
                let _ = s.set_read_timeout(
                    Some(Duration::from_secs(2)));
                let mut buf = Vec::new();
                let mut tmp = [0u8; 1024];
                while !head_complete(&buf) && buf.len() < 8192 {
                    match s.read(&mut tmp) {
                        Ok(0) => break,
                        Ok(n) => buf.extend_from_slice(&tmp[..n]),
                        Err(_) => break,
                    }
                }
                let body = "{\"target\":true}";
                let _ = write!(
                    s,
                    "HTTP/1.1 200 OK\r\nContent-Length: {}\r\n\
                     Connection: close\r\n\r\n{body}",
                    body.len());
            }
        });
        (addr, stop)
    }

    #[test]
    fn proxy_forwards_kills_revives_and_stalls() {
        use crate::serve::load;
        let (target, stop) = spawn_target();
        let proxy =
            ChaosProxy::spawn("127.0.0.1:0", &target).unwrap();
        // Pass-through: the target's bytes come back verbatim.
        let (status, doc) =
            load::http_get(proxy.addr(), "/metrics").unwrap();
        assert_eq!(status, 200);
        assert_eq!(doc.get("target").and_then(|v| v.as_bool()),
                   Some(true));
        // Kill over the HTTP control surface: forwards now drop
        // before a byte reaches the target.
        let (status, doc) =
            load::http_post(proxy.addr(), "/chaos/kill", "{}")
                .unwrap();
        assert_eq!(status, 200);
        assert_eq!(doc.get("killed").and_then(|v| v.as_bool()),
                   Some(true));
        assert!(load::http_get(proxy.addr(), "/metrics").is_err(),
                "killed proxy must look like a dead worker");
        // Control surface stays alive while "dead".
        let (status, _) =
            load::http_get(proxy.addr(), "/chaos/ping").unwrap();
        assert_eq!(status, 200);
        // Revive + stall: forwards resume, delayed by the stall.
        let (status, _) =
            load::http_post(proxy.addr(), "/chaos/revive", "{}")
                .unwrap();
        assert_eq!(status, 200);
        let (status, doc) = load::http_post(
            proxy.addr(), "/chaos/stall?ms=150", "{}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(doc.get("stall_ms").and_then(|v| v.as_f64()),
                   Some(150.0));
        let t0 = std::time::Instant::now();
        let (status, doc) =
            load::http_get(proxy.addr(), "/metrics").unwrap();
        assert_eq!(status, 200);
        assert_eq!(doc.get("target").and_then(|v| v.as_bool()),
                   Some(true));
        assert!(t0.elapsed() >= Duration::from_millis(120),
                "stall was not applied");
        // Bad control requests answer without touching the target.
        let (status, _) = load::http_post(
            proxy.addr(), "/chaos/stall?ms=oops", "{}").unwrap();
        assert_eq!(status, 400);
        let (status, _) =
            load::http_post(proxy.addr(), "/chaos/nope", "{}")
                .unwrap();
        assert_eq!(status, 404);
        proxy.shutdown();
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(&target); // wake the target loop
    }
}
