//! Deterministic fault injection for the serve front-end
//! (DESIGN.md §12). Chaos lives in the *client* (the load generator and
//! the integration tests): the server under test is always the real
//! server, and the spec decides how each request misbehaves — so every
//! failure path the handler/service threads must survive is exercised
//! reproducibly from a seed.
//!
//! Spec grammar (the `--chaos` flag):
//!
//! ```text
//! spec    := "off" | "default" | [preset ","] pair ("," pair)*
//! preset  := "off" | "default"
//! pair    := key "=" value
//! key     := seed | abort | delay | oversize | malformed
//!          | slowloris | tiny_deadline | delay_ms | hold_ms
//! ```
//!
//! Probability keys take values in `[0,1]` and their sum must be <= 1
//! (the remainder is the well-behaved-request probability). The draw
//! for (client c, request r) depends only on `(seed, c, r)` — chaos
//! schedules replay exactly across runs, which is what lets the
//! bit-parity acceptance test compare a chaos run against an
//! unperturbed run.

use anyhow::{bail, Result};

use crate::util::rng::Pcg;

/// What one request does to the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Well-behaved request.
    None,
    /// Disconnect after reading `after_tokens` streamed tokens
    /// (0 = right after sending the request).
    Abort { after_tokens: usize },
    /// Sleep `delay_ms` before reading the response (slow consumer).
    DelayedRead,
    /// Declare an absurd Content-Length; expect 413.
    Oversize,
    /// Send a syntactically broken request; expect 400.
    Malformed,
    /// Send a partial header then stall `hold_ms`; expect the server
    /// to shed the connection (408 or a hangup), never to wedge.
    Slowloris,
    /// Ask for `timeout_ms=1`; expect a deadline eviction (504 or a
    /// truncated stream), batchmates unaffected.
    TinyDeadline,
}

#[derive(Clone, Copy, Debug)]
pub struct ChaosSpec {
    pub seed: u64,
    pub abort: f64,
    pub delay: f64,
    pub oversize: f64,
    pub malformed: f64,
    pub slowloris: f64,
    pub tiny_deadline: f64,
    /// Slow-consumer pause before reads.
    pub delay_ms: u64,
    /// Slow-loris stall length (must exceed the server header timeout
    /// for the fault to actually trigger a 408).
    pub hold_ms: u64,
}

impl ChaosSpec {
    pub fn off() -> ChaosSpec {
        ChaosSpec { seed: 0, abort: 0.0, delay: 0.0, oversize: 0.0,
                    malformed: 0.0, slowloris: 0.0, tiny_deadline: 0.0,
                    delay_ms: 40, hold_ms: 3000 }
    }

    /// The CI preset: every failure class is present, a majority of
    /// requests are still well-behaved.
    pub fn default_preset() -> ChaosSpec {
        ChaosSpec { abort: 0.20, delay: 0.10, oversize: 0.05,
                    malformed: 0.10, slowloris: 0.05,
                    tiny_deadline: 0.10, ..ChaosSpec::off() }
    }

    pub fn is_off(&self) -> bool {
        self.abort + self.delay + self.oversize + self.malformed
            + self.slowloris + self.tiny_deadline
            == 0.0
    }

    /// Parse a `--chaos` spec string (grammar above).
    pub fn parse(spec: &str) -> Result<ChaosSpec> {
        let mut out = ChaosSpec::off();
        for (i, part) in spec.split(',').enumerate() {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part {
                "off" | "default" if i == 0 => {
                    if part == "default" {
                        out = ChaosSpec::default_preset();
                    }
                    continue;
                }
                _ => {}
            }
            let Some((k, v)) = part.split_once('=') else {
                bail!("chaos: expected key=value, got '{part}' \
                       (presets 'off'/'default' must come first)");
            };
            let (k, v) = (k.trim(), v.trim());
            let prob = |v: &str| -> Result<f64> {
                let p: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!(
                        "chaos: bad probability '{v}' for '{k}'"))?;
                if !(0.0..=1.0).contains(&p) {
                    bail!("chaos: probability '{k}={p}' outside [0,1]");
                }
                Ok(p)
            };
            match k {
                "seed" => out.seed = v.parse()?,
                "abort" => out.abort = prob(v)?,
                "delay" => out.delay = prob(v)?,
                "oversize" => out.oversize = prob(v)?,
                "malformed" => out.malformed = prob(v)?,
                "slowloris" => out.slowloris = prob(v)?,
                "tiny_deadline" => out.tiny_deadline = prob(v)?,
                "delay_ms" => out.delay_ms = v.parse()?,
                "hold_ms" => out.hold_ms = v.parse()?,
                _ => bail!("chaos: unknown key '{k}'"),
            }
        }
        let sum = out.abort + out.delay + out.oversize + out.malformed
            + out.slowloris
            + out.tiny_deadline;
        if sum > 1.0 + 1e-9 {
            bail!("chaos: fault probabilities sum to {sum:.3} > 1");
        }
        Ok(out)
    }

    /// Deterministic fault for `(client, request)` under this spec.
    pub fn draw(&self, client: u64, request: u64) -> Fault {
        let mut rng = Pcg::new(
            self.seed ^ client.wrapping_mul(0x9E3779B97F4A7C15),
            1000 + request);
        let x = rng.uniform();
        let mut acc = 0.0;
        let classes = [
            (self.abort, 0usize),
            (self.delay, 1),
            (self.oversize, 2),
            (self.malformed, 3),
            (self.slowloris, 4),
            (self.tiny_deadline, 5),
        ];
        for (p, tag) in classes {
            acc += p;
            if x < acc {
                return match tag {
                    0 => Fault::Abort {
                        after_tokens: rng.below_usize(4),
                    },
                    1 => Fault::DelayedRead,
                    2 => Fault::Oversize,
                    3 => Fault::Malformed,
                    4 => Fault::Slowloris,
                    _ => Fault::TinyDeadline,
                };
            }
        }
        Fault::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_and_overrides() {
        assert!(ChaosSpec::parse("off").unwrap().is_off());
        let d = ChaosSpec::parse("default").unwrap();
        assert!(!d.is_off());
        assert_eq!(d.abort, 0.20);
        let c =
            ChaosSpec::parse("default,seed=42,abort=0.5,delay=0")
                .unwrap();
        assert_eq!(c.seed, 42);
        assert_eq!(c.abort, 0.5);
        assert_eq!(c.delay, 0.0);
        assert_eq!(c.malformed, 0.10);
        let bare = ChaosSpec::parse("abort=1").unwrap();
        assert_eq!(bare.abort, 1.0);
        assert_eq!(bare.malformed, 0.0);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(ChaosSpec::parse("abort=1.5").is_err());
        assert!(ChaosSpec::parse("abort=-0.1").is_err());
        assert!(ChaosSpec::parse("abort=0.7,delay=0.7").is_err());
        assert!(ChaosSpec::parse("wibble=0.5").is_err());
        assert!(ChaosSpec::parse("abort").is_err());
        assert!(ChaosSpec::parse("abort=0.1,default").is_err());
    }

    #[test]
    fn draws_are_deterministic_and_seed_sensitive() {
        let spec = ChaosSpec::parse("default,seed=7").unwrap();
        for client in 0..4u64 {
            for req in 0..16u64 {
                assert_eq!(spec.draw(client, req),
                           spec.draw(client, req));
            }
        }
        let other = ChaosSpec::parse("default,seed=8").unwrap();
        let differs = (0..64u64)
            .any(|r| spec.draw(0, r) != other.draw(0, r));
        assert!(differs, "seed change never altered the schedule");
    }

    #[test]
    fn draw_frequencies_roughly_match_probabilities() {
        let spec = ChaosSpec::parse("abort=0.5,seed=3").unwrap();
        let n = 2000u64;
        let aborts = (0..n)
            .filter(|&r| matches!(spec.draw(1, r), Fault::Abort { .. }))
            .count();
        let frac = aborts as f64 / n as f64;
        assert!((0.42..0.58).contains(&frac), "abort frac {frac}");
    }

    #[test]
    fn certain_fault_always_fires() {
        let spec = ChaosSpec::parse("malformed=1").unwrap();
        for r in 0..32u64 {
            assert_eq!(spec.draw(0, r), Fault::Malformed);
        }
    }
}
