//! Serve-side counters and latency tracking (DESIGN.md §12).
//!
//! Everything here is lock-free: handler threads and the service thread
//! bump `AtomicU64`s, and `GET /metrics` snapshots them without
//! coordination. The one structural invariant — checked by
//! `tests/serve_properties.rs` — is conservation over terminal states:
//!
//! ```text
//! admitted == completed + timed_out + cancelled + failed  (at drain)
//! ```
//!
//! i.e. every request that enters the engine leaves it through exactly
//! one of the four doors, so batch slots cannot leak.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

const REL: Ordering = Ordering::Relaxed;

/// Monotonic counters + gauges for the serve front-end.
#[derive(Default)]
pub struct ServeMetrics {
    // Handler-side rejections (request never reached the engine).
    pub rejected_full: AtomicU64,
    pub rejected_bad: AtomicU64,
    pub rejected_oversize: AtomicU64,
    pub rejected_slow: AtomicU64,
    pub rejected_draining: AtomicU64,
    /// Retryable 503s shed because a shard had no live replica
    /// (DESIGN.md §15) — both handler-side deferrals and in-flight
    /// requests failed by an uncovered step error. Subset of
    /// `rejected_full` + `failed`, broken out so operators can tell
    /// fleet outages from ordinary backpressure.
    pub uncovered_503s: AtomicU64,
    // Service-side terminal states (request was admitted).
    pub admitted: AtomicU64,
    pub completed: AtomicU64,
    pub timed_out: AtomicU64,
    pub cancelled: AtomicU64,
    pub failed: AtomicU64,
    // Volume + gauges.
    pub tokens_streamed: AtomicU64,
    pub connections: AtomicU64,
    pub queue_depth: AtomicI64,
    pub active_seqs: AtomicI64,
    // KV page-pool gauges mirrored from the engine each service-loop
    // iteration (DESIGN.md §13). Shared pages are counted once in
    // live/peak; `kv_pages_shared` is the aliasing high-water mark
    // (`refs_live - pages_live`), 0 with `--share-prefix off`.
    pub kv_pages_live: AtomicI64,
    pub kv_pages_shared: AtomicU64,
    pub kv_pages_peak: AtomicU64,
    pub kv_bytes_peak: AtomicU64,
    /// Inter-token latency as observed by the service thread.
    pub token_lat: LatHist,
}

impl ServeMetrics {
    pub fn rejected_total(&self) -> u64 {
        self.rejected_full.load(REL)
            + self.rejected_bad.load(REL)
            + self.rejected_oversize.load(REL)
            + self.rejected_slow.load(REL)
            + self.rejected_draining.load(REL)
    }

    /// `admitted - (completed + timed_out + cancelled + failed)`;
    /// zero once the engine is idle, positive while requests are in
    /// flight, and never negative.
    pub fn in_flight(&self) -> i64 {
        self.admitted.load(REL) as i64
            - self.completed.load(REL) as i64
            - self.timed_out.load(REL) as i64
            - self.cancelled.load(REL) as i64
            - self.failed.load(REL) as i64
    }

    pub fn to_json(&self) -> Json {
        let n = |v: &AtomicU64| Json::num(v.load(REL) as f64);
        let g = |v: &AtomicI64| Json::num(v.load(REL) as f64);
        Json::obj(vec![
            ("admitted", n(&self.admitted)),
            ("completed", n(&self.completed)),
            ("timed_out", n(&self.timed_out)),
            ("cancelled", n(&self.cancelled)),
            ("failed", n(&self.failed)),
            ("rejected_full", n(&self.rejected_full)),
            ("rejected_bad", n(&self.rejected_bad)),
            ("rejected_oversize", n(&self.rejected_oversize)),
            ("rejected_slow", n(&self.rejected_slow)),
            ("rejected_draining", n(&self.rejected_draining)),
            ("uncovered_503s", n(&self.uncovered_503s)),
            ("tokens_streamed", n(&self.tokens_streamed)),
            ("connections", n(&self.connections)),
            ("queue_depth", g(&self.queue_depth)),
            ("active_seqs", g(&self.active_seqs)),
            ("kv_pages_live", g(&self.kv_pages_live)),
            ("kv_pages_shared", n(&self.kv_pages_shared)),
            ("kv_pages_peak", n(&self.kv_pages_peak)),
            ("kv_bytes_peak", n(&self.kv_bytes_peak)),
            ("in_flight", Json::num(self.in_flight() as f64)),
            ("token_p50_ms",
             Json::num(self.token_lat.quantile(0.50).unwrap_or(0.0))),
            ("token_p99_ms",
             Json::num(self.token_lat.quantile(0.99).unwrap_or(0.0))),
            ("token_lat_count",
             Json::num(self.token_lat.count() as f64)),
        ])
    }
}

/// Log2-microsecond-bucket histogram: bucket `i` holds samples in
/// `[2^i, 2^(i+1))` µs. 48 buckets cover ~1 µs to ~8.9 years, which is
/// enough dynamic range that clamping never matters in practice.
/// Quantiles are approximate (geometric bucket midpoint) but
/// allocation-free and safe to hammer from any thread.
pub struct LatHist {
    buckets: [AtomicU64; 48],
}

impl Default for LatHist {
    fn default() -> LatHist {
        LatHist { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl LatHist {
    pub fn record(&self, d: Duration) {
        let us = (d.as_micros() as u64).max(1);
        let idx = (63 - us.leading_zeros() as usize).min(47);
        self.buckets[idx].fetch_add(1, REL);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(REL)).sum()
    }

    /// Approximate quantile in milliseconds, `None` when empty.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let total = self.count();
        if total == 0 {
            return None;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64)
            .max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(REL);
            if seen >= target {
                // Geometric midpoint of [2^i, 2^(i+1)) µs, in ms.
                return Some((1u64 << i) as f64 * 1.5 / 1000.0);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatHist::default();
        assert_eq!(h.quantile(0.5), None);
        // 90 samples near 1ms, 10 near 16ms: p50 in the 1ms bucket,
        // p99 in the 16ms bucket.
        for _ in 0..90 {
            h.record(Duration::from_micros(1100));
        }
        for _ in 0..10 {
            h.record(Duration::from_micros(17_000));
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50).unwrap();
        let p99 = h.quantile(0.99).unwrap();
        assert!((1.0..3.1).contains(&p50), "p50={p50}");
        assert!((16.0..50.0).contains(&p99), "p99={p99}");
        assert!(p50 < p99);
    }

    #[test]
    fn zero_duration_lands_in_first_bucket() {
        let h = LatHist::default();
        h.record(Duration::from_nanos(0));
        assert_eq!(h.count(), 1);
        assert!(h.quantile(1.0).unwrap() > 0.0);
    }

    #[test]
    fn conservation_and_json_snapshot() {
        let m = ServeMetrics::default();
        m.admitted.store(10, REL);
        m.completed.store(6, REL);
        m.timed_out.store(2, REL);
        m.cancelled.store(1, REL);
        m.failed.store(1, REL);
        m.rejected_full.store(3, REL);
        m.rejected_bad.store(2, REL);
        assert_eq!(m.in_flight(), 0);
        assert_eq!(m.rejected_total(), 5);
        let j = m.to_json();
        assert_eq!(j.get("admitted").and_then(|v| v.as_f64()),
                   Some(10.0));
        assert_eq!(j.get("in_flight").and_then(|v| v.as_f64()),
                   Some(0.0));
        // Round-trips through the serializer.
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back.get("completed").and_then(|v| v.as_f64()),
                   Some(6.0));
    }
}
