//! Bench-report differ behind `osp bench-diff OLD.json NEW.json`:
//! row-by-row comparison of two `BENCH_quant.json` / `BENCH_infer.json`
//! artifacts so CI (and humans) can see per-kernel speedups and catch
//! throughput regressions between pushes.
//!
//! Rows are matched on their *identity fields* (the sweep coordinates:
//! op/phase/config/size/bit-widths/batch/chunk/page geometry); every
//! shared numeric field that looks like a metric — `*_ns_op` timings
//! (lower is better), `*per_sec*` rates (higher is better), or
//! `*_bytes*`/`*_pages*` memory footprints (lower is better) — is
//! compared and normalized into a speedup where `> 1.0` means NEW is
//! faster (or smaller). Other context fields (step counts, outcome
//! tallies) are ignored, and rows present in only one file are
//! reported but never fail the diff, so adding or removing bench rows
//! does not break the gate.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// Fields that locate a row in the sweep grid. Only the subset present
/// on a row participates in its key. `clients`/`chaos` key the
/// `BENCH_serve.json` rows: the same serve sweep under a different
/// client count or fault mix is a different experiment, not a
/// regression candidate. Likewise `kv_page_rows`/`share_prefix`
/// (DESIGN.md §13): page geometry and prefix sharing change the
/// memory-footprint metrics by design, so runs under different KV
/// layouts must not be diffed against each other. `workers`/`shards`
/// key the row-parallel sharded serve rows (DESIGN.md §14): a 2-worker
/// run pays rpc latency a single-process run does not, so the two are
/// different experiments, never regression candidates. `replicas`
/// joins them (DESIGN.md §15): a replicated fleet buys failover with
/// extra rpc fan-in, so its latencies are not comparable to an
/// unreplicated run's.
const IDENTITY_FIELDS: [&str; 18] = [
    "op", "phase", "config", "size", "w_bits", "a_bits", "kv_bits", "bits",
    "batch", "chunk", "prompt_len", "clients", "chaos", "kv_page_rows",
    "share_prefix", "workers", "shards", "replicas",
];

/// Lower-is-better metrics: `*_ns_op` kernel timings and the serve
/// bench's `*_ms` latency percentiles.
fn is_time_metric(key: &str) -> bool {
    key.ends_with("_ns_op") || key.ends_with("_ms")
}

fn is_rate_metric(key: &str) -> bool {
    key.contains("per_sec")
}

/// Lower-is-better memory metrics: byte and page footprints
/// (`weight_bytes`, `kv_bytes_peak`, `kv_pages_shared`, ...). Counted
/// like timings: `speedup > 1.0` means NEW uses less memory.
/// `bytes_streamed` (shard distribution volume, DESIGN.md §14) is
/// named prefix-first so the substring rules miss it — listed
/// explicitly.
fn is_mem_metric(key: &str) -> bool {
    key.contains("_bytes") || key.contains("_pages")
        || key == "bytes_streamed"
}

/// One compared metric of one matched row.
#[derive(Clone, Debug)]
pub struct MetricDiff {
    /// Human-readable identity key, e.g. `op=matvec size=512 w_bits=4`.
    pub row: String,
    pub metric: String,
    pub old: f64,
    pub new: f64,
    /// Normalized across metric polarity: `> 1.0` = NEW is faster.
    pub speedup: f64,
}

/// Full diff of two bench reports.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    pub metrics: Vec<MetricDiff>,
    /// Row keys present in only one of the files (not compared).
    pub only_old: Vec<String>,
    pub only_new: Vec<String>,
    /// Set when the two runs used different worker counts — speedups
    /// then mix kernel changes with thread-count changes.
    pub thread_note: Option<String>,
}

impl DiffReport {
    /// Metrics slower than `1 - threshold` (e.g. threshold 0.10 flags
    /// anything more than 10% slower in NEW).
    pub fn regressions(&self, threshold: f64) -> Vec<&MetricDiff> {
        self.metrics
            .iter()
            .filter(|m| m.speedup < 1.0 - threshold)
            .collect()
    }
}

fn row_key(row: &Json) -> String {
    let mut parts = Vec::new();
    for f in IDENTITY_FIELDS {
        match row.get(f) {
            Some(Json::Str(s)) => parts.push(format!("{f}={s}")),
            Some(Json::Num(n)) => parts.push(format!("{f}={n}")),
            _ => {}
        }
    }
    parts.join(" ")
}

fn rows_by_key(doc: &Json, which: &str)
               -> Result<BTreeMap<String, Json>> {
    let rows = doc
        .get("rows")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| anyhow!(
            "{which}: no 'rows' array — not a BENCH_*.json artifact"))?;
    let mut map = BTreeMap::new();
    for r in rows {
        map.insert(row_key(r), r.clone());
    }
    Ok(map)
}

/// Diff two parsed bench artifacts (see module docs for matching and
/// metric polarity rules).
pub fn diff_reports(old: &Json, new: &Json) -> Result<DiffReport> {
    let old_rows = rows_by_key(old, "OLD")?;
    let new_rows = rows_by_key(new, "NEW")?;
    let mut report = DiffReport::default();
    let (ot, nt) = (old.get("threads").and_then(|t| t.as_f64()),
                    new.get("threads").and_then(|t| t.as_f64()));
    if let (Some(ot), Some(nt)) = (ot, nt) {
        if ot != nt {
            report.thread_note = Some(format!(
                "thread counts differ (OLD {ot} vs NEW {nt}); speedups \
                 mix kernel and parallelism changes"));
        }
    }
    for (key, orow) in &old_rows {
        let Some(nrow) = new_rows.get(key) else {
            report.only_old.push(key.clone());
            continue;
        };
        let Some(fields) = orow.as_obj() else { continue };
        for (metric, oval) in fields {
            // Time and memory share polarity: lower is better.
            let lower = is_time_metric(metric) || is_mem_metric(metric);
            if !lower && !is_rate_metric(metric) {
                continue;
            }
            let (Some(ov), Some(nv)) = (
                oval.as_f64(),
                nrow.get(metric).and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            if !(ov > 0.0 && nv > 0.0) {
                continue; // degenerate or non-finite sample
            }
            let speedup = if lower { ov / nv } else { nv / ov };
            report.metrics.push(MetricDiff {
                row: key.clone(),
                metric: metric.clone(),
                old: ov,
                new: nv,
                speedup,
            });
        }
    }
    for key in new_rows.keys() {
        if !old_rows.contains_key(key) {
            report.only_new.push(key.clone());
        }
    }
    Ok(report)
}

/// Compact metric formatting for the diff table (ns and tok/s both span
/// several orders of magnitude).
pub fn fmt_metric(v: f64) -> String {
    if v >= 1000.0 {
        format!("{v:.0}")
    } else if v >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(threads: f64, rows: Vec<Json>) -> Json {
        Json::obj(vec![
            ("bench", Json::str("quant")),
            ("threads", Json::num(threads)),
            ("rows", Json::Arr(rows)),
        ])
    }

    fn matvec_row(size: f64, bits: f64, ns: f64, tps: f64) -> Json {
        Json::obj(vec![
            ("op", Json::str("matvec")),
            ("size", Json::num(size)),
            ("w_bits", Json::num(bits)),
            ("packed_ns_op", Json::num(ns)),
            ("tokens_per_sec", Json::num(tps)),
            ("weight_bytes", Json::num(1234.0)), // memory: lower wins
        ])
    }

    #[test]
    fn speedups_normalize_metric_polarity() {
        let old = report(4.0, vec![matvec_row(512.0, 4.0, 2000.0, 100.0)]);
        let new = report(4.0, vec![matvec_row(512.0, 4.0, 1000.0, 150.0)]);
        let d = diff_reports(&old, &new).unwrap();
        assert_eq!(d.metrics.len(), 3, "{:?}", d.metrics);
        for m in &d.metrics {
            match m.metric.as_str() {
                "packed_ns_op" => assert!((m.speedup - 2.0).abs() < 1e-12),
                "tokens_per_sec" => {
                    assert!((m.speedup - 1.5).abs() < 1e-12)
                }
                "weight_bytes" => {
                    assert!((m.speedup - 1.0).abs() < 1e-12)
                }
                other => panic!("unexpected metric {other}"),
            }
        }
        assert!(d.regressions(0.10).is_empty());
        assert!(d.thread_note.is_none());
    }

    #[test]
    fn regressions_flag_beyond_threshold_only() {
        let old = report(1.0, vec![matvec_row(512.0, 4.0, 1000.0, 100.0)]);
        let new = report(1.0, vec![matvec_row(512.0, 4.0, 1080.0, 85.0)]);
        let d = diff_reports(&old, &new).unwrap();
        // ns: 1.08x slower (within 10%); tok/s: 15% slower (beyond).
        let regs = d.regressions(0.10);
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert_eq!(regs[0].metric, "tokens_per_sec");
        assert_eq!(d.regressions(0.20).len(), 0);
    }

    #[test]
    fn unmatched_rows_and_thread_skew_are_reported_not_fatal() {
        let old = report(1.0, vec![matvec_row(512.0, 4.0, 1000.0, 100.0),
                                   matvec_row(256.0, 4.0, 500.0, 50.0)]);
        let new = report(4.0, vec![matvec_row(512.0, 4.0, 900.0, 120.0),
                                   matvec_row(512.0, 8.0, 800.0, 90.0)]);
        let d = diff_reports(&old, &new).unwrap();
        assert_eq!(d.only_old.len(), 1);
        assert_eq!(d.only_new.len(), 1);
        assert!(d.thread_note.is_some());
        assert_eq!(d.metrics.len(), 3); // only the matched row compares
    }

    /// The §11 integer-kernel rows: `int_ns_op` / `int_scalar_ns_op`
    /// must diff as timings (lower = faster) and the `kernel` field is
    /// context, not identity — a run on an AVX2 box still matches a
    /// scalar-only run's rows.
    #[test]
    fn int_kernel_rows_diff_as_time_metrics() {
        assert!(is_time_metric("int_ns_op"));
        assert!(is_time_metric("int_scalar_ns_op"));
        let int_row = |kernel: &str, ns: f64, sns: f64| {
            Json::obj(vec![
                ("op", Json::str("matvec_rhs")),
                ("size", Json::num(512.0)),
                ("w_bits", Json::num(4.0)),
                ("a_bits", Json::num(4.0)),
                ("batch", Json::num(1.0)),
                ("kernel", Json::str(kernel)),
                ("int_ns_op", Json::num(ns)),
                ("int_scalar_ns_op", Json::num(sns)),
            ])
        };
        let old = report(1.0, vec![int_row("scalar", 4000.0, 4000.0)]);
        let new = report(1.0, vec![int_row("avx2", 1000.0, 4000.0)]);
        let d = diff_reports(&old, &new).unwrap();
        assert!(d.only_old.is_empty() && d.only_new.is_empty(),
                "kernel must not split row identity: {:?}", d.only_old);
        let int = d.metrics.iter().find(|m| m.metric == "int_ns_op")
            .expect("int_ns_op compared");
        assert!((int.speedup - 4.0).abs() < 1e-12, "{:?}", int);
        let sc = d.metrics.iter()
            .find(|m| m.metric == "int_scalar_ns_op")
            .expect("int_scalar_ns_op compared");
        assert!((sc.speedup - 1.0).abs() < 1e-12, "{:?}", sc);
    }

    /// The §12 serve rows: `clients`/`chaos` are identity (a 4-client
    /// chaos run must not be compared against an 8-client clean run),
    /// `*_ms` latency percentiles diff as timings (lower = faster),
    /// and counters like `completed` stay context-only.
    #[test]
    fn serve_rows_key_on_clients_and_chaos_and_diff_ms() {
        assert!(is_time_metric("p99_token_ms"));
        assert!(is_time_metric("first_token_p50_ms"));
        assert!(!is_time_metric("wall_secs"));
        let serve_row = |clients: f64, chaos: &str, p99: f64,
                         tps: f64| {
            Json::obj(vec![
                ("phase", Json::str("serve")),
                ("config", Json::str("4-4-4")),
                ("clients", Json::num(clients)),
                ("chaos", Json::str(chaos)),
                ("p99_token_ms", Json::num(p99)),
                ("gen_tokens_per_sec", Json::num(tps)),
                ("completed", Json::num(30.0)), // context: not compared
            ])
        };
        let old = report(4.0, vec![serve_row(8.0, "default", 20.0,
                                             500.0)]);
        let new = report(4.0, vec![serve_row(8.0, "default", 10.0,
                                             600.0),
                                   serve_row(16.0, "off", 8.0, 900.0)]);
        let d = diff_reports(&old, &new).unwrap();
        assert_eq!(d.only_new.len(), 1, "{:?}", d.only_new);
        assert!(d.only_new[0].contains("clients=16"), "{:?}",
                d.only_new);
        assert!(d.only_new[0].contains("chaos=off"), "{:?}", d.only_new);
        assert_eq!(d.metrics.len(), 2, "{:?}", d.metrics);
        for m in &d.metrics {
            match m.metric.as_str() {
                "p99_token_ms" => {
                    assert!((m.speedup - 2.0).abs() < 1e-12, "{m:?}")
                }
                "gen_tokens_per_sec" => {
                    assert!((m.speedup - 1.2).abs() < 1e-12, "{m:?}")
                }
                other => panic!("unexpected metric {other}"),
            }
        }
    }

    /// The §13 paged-KV fields: `*_bytes*`/`*_pages*` footprints diff
    /// as lower-is-better memory metrics, and `kv_page_rows` /
    /// `share_prefix` are identity — a run under a different page
    /// geometry or sharing mode is a different experiment.
    #[test]
    fn memory_metrics_are_lower_is_better_and_kv_layout_is_identity() {
        assert!(is_mem_metric("kv_bytes_peak"));
        assert!(is_mem_metric("kv_pages_peak"));
        assert!(is_mem_metric("kv_pages_shared"));
        assert!(is_mem_metric("weight_bytes"));
        assert!(!is_mem_metric("tokens"));
        assert!(!is_mem_metric("completed"));
        assert!(IDENTITY_FIELDS.contains(&"kv_page_rows"));
        assert!(IDENTITY_FIELDS.contains(&"share_prefix"));
        let kv_row = |page_rows: f64, share: &str, bytes: f64| {
            Json::obj(vec![
                ("phase", Json::str("serve")),
                ("config", Json::str("4-4-4")),
                ("clients", Json::num(8.0)),
                ("kv_page_rows", Json::num(page_rows)),
                ("share_prefix", Json::str(share)),
                ("kv_bytes_peak", Json::num(bytes)),
            ])
        };
        // Same layout, halved footprint: speedup 2.0 on the memory
        // metric. A different page size must split row identity.
        let old = report(4.0, vec![kv_row(64.0, "on", 4096.0)]);
        let new = report(4.0, vec![kv_row(64.0, "on", 2048.0),
                                   kv_row(16.0, "on", 2048.0)]);
        let d = diff_reports(&old, &new).unwrap();
        assert_eq!(d.metrics.len(), 1, "{:?}", d.metrics);
        assert_eq!(d.metrics[0].metric, "kv_bytes_peak");
        assert!((d.metrics[0].speedup - 2.0).abs() < 1e-12);
        assert_eq!(d.only_new.len(), 1, "{:?}", d.only_new);
        assert!(d.only_new[0].contains("kv_page_rows=16"),
                "{:?}", d.only_new);
        // Sharing mode splits identity the same way.
        let off = report(4.0, vec![kv_row(64.0, "off", 4096.0)]);
        let d2 = diff_reports(&old, &off).unwrap();
        assert!(d2.metrics.is_empty(), "{:?}", d2.metrics);
        assert_eq!(d2.only_old.len(), 1);
        assert_eq!(d2.only_new.len(), 1);
    }

    /// The §14 sharded-serve rows: `workers`/`shards` are identity (a
    /// 2-worker run never diffs against single-process), `fetch_ms`
    /// diffs as a timing, and `bytes_streamed` /
    /// `worker_weight_bytes_max` as lower-is-better memory metrics.
    #[test]
    fn sharded_rows_key_on_workers_and_diff_fetch_metrics() {
        assert!(IDENTITY_FIELDS.contains(&"workers"));
        assert!(IDENTITY_FIELDS.contains(&"shards"));
        // §15: replication factor splits identity too, while the
        // failover counters stay context-only (never "regressions").
        assert!(IDENTITY_FIELDS.contains(&"replicas"));
        for counter in ["failovers", "breaker_trips", "rejoins"] {
            assert!(!is_time_metric(counter)
                    && !is_rate_metric(counter)
                    && !is_mem_metric(counter),
                    "{counter} must not diff as a metric");
        }
        assert!(is_time_metric("fetch_ms"));
        assert!(is_mem_metric("bytes_streamed"));
        assert!(is_mem_metric("worker_weight_bytes_max"));
        assert!(!is_mem_metric("tokens"));
        let sharded_row = |workers: f64, fetch: f64, streamed: f64| {
            Json::obj(vec![
                ("phase", Json::str("serve")),
                ("config", Json::str("4-4-4")),
                ("clients", Json::num(8.0)),
                ("workers", Json::num(workers)),
                ("shards", Json::num(workers)),
                ("fetch_ms", Json::num(fetch)),
                ("bytes_streamed", Json::num(streamed)),
            ])
        };
        let old = report(4.0, vec![sharded_row(2.0, 300.0, 8192.0)]);
        let new = report(4.0, vec![sharded_row(2.0, 150.0, 4096.0),
                                   sharded_row(4.0, 200.0, 8192.0)]);
        let d = diff_reports(&old, &new).unwrap();
        assert_eq!(d.only_new.len(), 1, "{:?}", d.only_new);
        assert!(d.only_new[0].contains("workers=4"), "{:?}",
                d.only_new);
        assert_eq!(d.metrics.len(), 2, "{:?}", d.metrics);
        for m in &d.metrics {
            assert!((m.speedup - 2.0).abs() < 1e-12, "{m:?}");
        }
    }

    /// Added/removed rows are informational: a NEW-only artifact (e.g.
    /// the first `BENCH_serve.json`) produces no comparisons and no
    /// regressions — the gate must not fail on it.
    #[test]
    fn new_only_rows_never_regress() {
        let old = report(1.0, vec![]);
        let new = report(1.0, vec![matvec_row(512.0, 4.0, 1000.0,
                                              100.0)]);
        let d = diff_reports(&old, &new).unwrap();
        assert!(d.metrics.is_empty());
        assert_eq!(d.only_new.len(), 1);
        assert!(d.only_old.is_empty());
        assert!(d.regressions(0.0).is_empty());
    }

    #[test]
    fn rejects_non_bench_documents() {
        let bogus = Json::obj(vec![("hello", Json::str("world"))]);
        assert!(diff_reports(&bogus, &bogus).is_err());
    }

    #[test]
    fn fmt_metric_scales() {
        assert_eq!(fmt_metric(123456.0), "123456");
        assert_eq!(fmt_metric(42.5), "42.5");
        assert_eq!(fmt_metric(1.25), "1.250");
    }
}
