//! Bench harness (criterion is not in the offline vendor set): warmup +
//! timed iterations with mean/min/max, and paper-style table rendering
//! shared by `rust/benches/*` and the `osp repro` subcommands. [`diff`]
//! compares two recorded bench artifacts (`osp bench-diff`).

pub mod diff;

use std::time::Instant;

/// Timing summary over the measured iterations.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

impl Timing {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_secs.max(1e-12)
    }
}

/// Run `f` `warmup` times untimed, then `iters` times timed.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    Timing {
        iters,
        mean_secs: times.iter().sum::<f64>() / iters as f64,
        min_secs: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_secs: times.iter().cloned().fold(0.0, f64::max),
    }
}

/// Markdown-ish table rendering (the paper-row printers).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(),
                   "row width != header width");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.chars().count());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s
        };
        let mut out = format!("\n## {}\n\n", self.title);
        out.push_str(&line(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format helpers used by the bench binaries.
pub fn fmt_ppl(ppl: f64) -> String {
    if ppl >= 1e4 {
        format!("{ppl:.1e}")
    } else {
        format!("{ppl:.2}")
    }
}

pub fn fmt_pct(frac: f64) -> String {
    format!("{:.1}", 100.0 * frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0;
        let t = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(t.iters, 5);
        assert!(t.min_secs <= t.mean_secs && t.mean_secs <= t.max_secs);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Test", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["wide_cell".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("## Test"));
        assert!(s.contains("| wide_cell | x           |"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only_one".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ppl(12.345), "12.35");
        assert_eq!(fmt_ppl(123456.0), "1.2e5");
        assert_eq!(fmt_pct(0.357), "35.7");
    }
}
