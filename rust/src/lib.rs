//! Outlier-Safe Pre-Training (OSP) — Rust coordinator library.
//!
//! Reproduction of *Outlier-Safe Pre-Training for Robust 4-Bit
//! Quantization of Large Language Models* (Park et al., ACL 2025) as a
//! three-layer Rust + JAX + Pallas system. This crate is Layer 3: the
//! training coordinator, data pipeline, quantization library, and
//! evaluation harness that drive AOT-compiled XLA executables (built once
//! by `make artifacts` from `python/compile/`).
//!
//! Module map (see rust/DESIGN.md §3):
//! * [`util`] — hand-built substrates (JSON, RNG, CLI, threadpool,
//!   property testing); this offline build has no external crates beyond
//!   `anyhow` — the PJRT surface is the fail-fast stub in
//!   `runtime/xla_stub.rs`.
//! * [`tensor`] — dense f32 tensor/linalg library (matmul, QR, Cholesky,
//!   Hadamard, moment statistics) plus the shared parallel kernel layer
//!   ([`tensor::par`], `OSP_THREADS` workers — DESIGN.md §6).
//! * [`runtime`] — PJRT client wrapper; manifest-driven artifact loading.
//! * [`data`] — synthetic grammar corpus, sharding, batching.
//! * [`coordinator`] — the training control plane (fused + disaggregated
//!   optimizer-parallel modes, simulated data parallelism).
//! * [`quant`] — RTN / GPTQ / QuaRot-lite / SpinQuant-lite and EmbProj
//!   absorption.
//! * [`model`] — the shared host model layer: multi-token block forward
//!   on packed weights, quantized KV cache, row kernels, and sampling
//!   (DESIGN.md §9).
//! * [`infer`] — the continuous-batching decode scheduler with chunked
//!   prefill on top of [`model`] (DESIGN.md §8).
//! * [`serve`] — the fault-tolerant streaming HTTP front-end around
//!   [`infer`]: std-only threads + `std::net`, bounded admission,
//!   deadlines, cancellation, chaos testing (DESIGN.md §12).
//! * [`eval`] — perplexity and the 10-task synthetic benchmark suite on
//!   both the engine and engine-free host paths, plus attention-sink
//!   analysis.
//! * [`metrics`] — telemetry registry, histograms, kurtosis tracking.
//! * [`checkpoint`] — binary parameter store.
//! * [`bench`] — the bench harness used by `rust/benches/*` (no criterion
//!   offline).

pub mod bench;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod infer;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod repro;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
