//! Checkpoint store: flat binary tensors + a JSON manifest per step.
//!
//! Layout: `<run>/ckpt/step_<N>/{meta.json, params.bin}` where params.bin
//! is the little-endian f32 concatenation of the parameter leaves in
//! manifest order. Optimizer state is stored the same way when requested
//! (resumable training).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{OptLeafSpec, ParamSpec};
use crate::tensor::Tensor;
use crate::util::json::Json;

fn write_tensors(path: &Path, tensors: &[Tensor]) -> Result<()> {
    let mut bytes: Vec<u8> = Vec::new();
    for t in tensors {
        for v in t.data() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    std::fs::write(path, &bytes).with_context(|| format!("writing {path:?}"))
}

fn read_tensors(path: &Path, shapes: &[Vec<usize>]) -> Result<Vec<Tensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    if bytes.len() != total * 4 {
        bail!("{path:?}: {} bytes, expected {} ({} f32)", bytes.len(),
              total * 4, total);
    }
    let mut off = 0usize;
    let mut out = Vec::with_capacity(shapes.len());
    for shape in shapes {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = bytes[off..off + 4 * n]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        off += 4 * n;
        out.push(Tensor::new(shape.clone(), data));
    }
    Ok(out)
}

/// A saved training state.
pub struct Checkpoint {
    pub step: u64,
    pub arch: String,
    pub optimizer: String,
    pub params: Vec<Tensor>,
    pub opt_state: Option<Vec<Tensor>>,
}

pub fn ckpt_dir(run_dir: &Path, step: u64) -> PathBuf {
    run_dir.join("ckpt").join(format!("step_{step:07}"))
}

/// Save a checkpoint. `param_specs` fixes ordering; opt_state optional.
pub fn save(run_dir: &Path, step: u64, arch: &str, optimizer: &str,
            param_specs: &[ParamSpec], params: &[Tensor],
            opt_leaves: Option<(&[OptLeafSpec], &[Tensor])>) -> Result<PathBuf> {
    assert_eq!(param_specs.len(), params.len());
    let dir = ckpt_dir(run_dir, step);
    std::fs::create_dir_all(&dir)?;
    write_tensors(&dir.join("params.bin"), params)?;
    let mut meta = vec![
        ("step", Json::num(step as f64)),
        ("arch", Json::str(arch)),
        ("optimizer", Json::str(optimizer)),
        ("has_opt_state", Json::Bool(opt_leaves.is_some())),
        ("param_names",
         Json::Arr(param_specs.iter().map(|p| Json::str(p.name.clone()))
                   .collect())),
        ("param_shapes",
         Json::Arr(param_specs
                   .iter()
                   .map(|p| Json::Arr(p.shape.iter()
                                      .map(|&d| Json::num(d as f64))
                                      .collect()))
                   .collect())),
    ];
    if let Some((leaves, state)) = opt_leaves {
        assert_eq!(leaves.len(), state.len());
        write_tensors(&dir.join("opt_state.bin"), state)?;
        meta.push((
            "opt_shapes",
            Json::Arr(leaves
                      .iter()
                      .map(|l| Json::Arr(l.shape.iter()
                                         .map(|&d| Json::num(d as f64))
                                         .collect()))
                      .collect()),
        ));
    }
    std::fs::write(dir.join("meta.json"), Json::obj(meta).dump())?;
    Ok(dir)
}

/// Load a checkpoint saved by [`save`].
pub fn load(dir: &Path) -> Result<Checkpoint> {
    let meta_text = std::fs::read_to_string(dir.join("meta.json"))
        .with_context(|| format!("no checkpoint at {dir:?}"))?;
    let meta = Json::parse(&meta_text)
        .map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
    let shapes: Vec<Vec<usize>> = meta
        .req("param_shapes")?
        .as_arr()
        .context("param_shapes")?
        .iter()
        .map(|s| s.usize_arr().context("shape"))
        .collect::<Result<_>>()?;
    let params = read_tensors(&dir.join("params.bin"), &shapes)?;
    let opt_state = if meta.req("has_opt_state")?.as_bool() == Some(true) {
        let oshapes: Vec<Vec<usize>> = meta
            .req("opt_shapes")?
            .as_arr()
            .context("opt_shapes")?
            .iter()
            .map(|s| s.usize_arr().context("shape"))
            .collect::<Result<_>>()?;
        Some(read_tensors(&dir.join("opt_state.bin"), &oshapes)?)
    } else {
        None
    };
    Ok(Checkpoint {
        step: meta.req("step")?.as_usize().context("step")? as u64,
        arch: meta.req("arch")?.as_str().context("arch")?.to_string(),
        optimizer: meta.req("optimizer")?.as_str().context("opt")?.to_string(),
        params,
        opt_state,
    })
}

/// List checkpoint step dirs under a run, ascending.
pub fn list_steps(run_dir: &Path) -> Vec<(u64, PathBuf)> {
    let mut out = Vec::new();
    let Ok(rd) = std::fs::read_dir(run_dir.join("ckpt")) else {
        return out;
    };
    for entry in rd.flatten() {
        let name = entry.file_name().to_string_lossy().to_string();
        if let Some(num) = name.strip_prefix("step_") {
            if let Ok(step) = num.parse::<u64>() {
                out.push((step, entry.path()));
            }
        }
    }
    out.sort_by_key(|&(s, _)| s);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "a".into(), shape: vec![2, 3],
                        init: "normal".into(), kind: "matrix".into() },
            ParamSpec { name: "b".into(), shape: vec![4],
                        init: "ones".into(), kind: "norm".into() },
        ]
    }

    #[test]
    fn save_load_roundtrip() {
        let run = std::env::temp_dir().join("osp_ckpt_test_a");
        let _ = std::fs::remove_dir_all(&run);
        let params = vec![
            Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
            Tensor::new(vec![4], vec![0.5; 4]),
        ];
        let dir = save(&run, 42, "ssnorm_embproj", "muon", &specs(), &params,
                       None).unwrap();
        let ck = load(&dir).unwrap();
        assert_eq!(ck.step, 42);
        assert_eq!(ck.arch, "ssnorm_embproj");
        assert_eq!(ck.params[0].data(), params[0].data());
        assert_eq!(ck.params[1].shape(), &[4]);
        assert!(ck.opt_state.is_none());
    }

    #[test]
    fn save_load_with_opt_state() {
        let run = std::env::temp_dir().join("osp_ckpt_test_b");
        let _ = std::fs::remove_dir_all(&run);
        let params = vec![
            Tensor::zeros(&[2, 3]),
            Tensor::zeros(&[4]),
        ];
        let leaves = vec![OptLeafSpec { name: "step".into(), shape: vec![1],
                                        init: "zeros".into() }];
        let state = vec![Tensor::new(vec![1], vec![7.0])];
        let dir = save(&run, 7, "rmsnorm_plain", "adam", &specs(), &params,
                       Some((&leaves, &state))).unwrap();
        let ck = load(&dir).unwrap();
        assert_eq!(ck.opt_state.unwrap()[0].data(), &[7.0]);
    }

    #[test]
    fn list_steps_sorted() {
        let run = std::env::temp_dir().join("osp_ckpt_test_c");
        let _ = std::fs::remove_dir_all(&run);
        let params = vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[4])];
        for step in [30u64, 10, 20] {
            save(&run, step, "a", "adam", &specs(), &params, None).unwrap();
        }
        let steps: Vec<u64> =
            list_steps(&run).into_iter().map(|(s, _)| s).collect();
        assert_eq!(steps, vec![10, 20, 30]);
    }

    #[test]
    fn corrupted_size_rejected() {
        let run = std::env::temp_dir().join("osp_ckpt_test_d");
        let _ = std::fs::remove_dir_all(&run);
        let params = vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[4])];
        let dir = save(&run, 1, "a", "adam", &specs(), &params, None).unwrap();
        std::fs::write(dir.join("params.bin"), [0u8; 12]).unwrap();
        assert!(load(&dir).is_err());
    }
}
