//! Checkpoint store: flat binary tensors + a JSON manifest per step.
//!
//! Layout: `<run>/ckpt/step_<N>/{meta.json, params.bin}` where params.bin
//! is the little-endian f32 concatenation of the parameter leaves in
//! manifest order. Optimizer state is stored the same way when requested
//! (resumable training).
//!
//! [`save_packed`]/[`load_packed`] additionally persist weight-quantized
//! models in their packed-code form (versioned `OSPQ` header, DESIGN.md
//! §7): a W4 artifact costs ~1/8th of the dense f32 checkpoint.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::remote::{ShardEntry, ShardKind, ShardSet};
use crate::quant::{QParam, QuantizedModel};
use crate::runtime::manifest::{OptLeafSpec, ParamSpec};
use crate::tensor::qtensor::{QStorage, QTensor};
use crate::tensor::Tensor;
use crate::util::json::Json;

fn write_tensors(path: &Path, tensors: &[Tensor]) -> Result<()> {
    let mut bytes: Vec<u8> = Vec::new();
    for t in tensors {
        for v in t.data() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    std::fs::write(path, &bytes).with_context(|| format!("writing {path:?}"))
}

fn read_tensors(path: &Path, shapes: &[Vec<usize>]) -> Result<Vec<Tensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    if bytes.len() != total * 4 {
        bail!("{path:?}: {} bytes, expected {} ({} f32)", bytes.len(),
              total * 4, total);
    }
    let mut off = 0usize;
    let mut out = Vec::with_capacity(shapes.len());
    for shape in shapes {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = bytes[off..off + 4 * n]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        off += 4 * n;
        out.push(Tensor::new(shape.clone(), data));
    }
    Ok(out)
}

/// A saved training state.
pub struct Checkpoint {
    pub step: u64,
    pub arch: String,
    pub optimizer: String,
    pub params: Vec<Tensor>,
    pub opt_state: Option<Vec<Tensor>>,
}

pub fn ckpt_dir(run_dir: &Path, step: u64) -> PathBuf {
    run_dir.join("ckpt").join(format!("step_{step:07}"))
}

/// Save a checkpoint. `param_specs` fixes ordering; opt_state optional.
pub fn save(run_dir: &Path, step: u64, arch: &str, optimizer: &str,
            param_specs: &[ParamSpec], params: &[Tensor],
            opt_leaves: Option<(&[OptLeafSpec], &[Tensor])>) -> Result<PathBuf> {
    assert_eq!(param_specs.len(), params.len());
    let dir = ckpt_dir(run_dir, step);
    std::fs::create_dir_all(&dir)?;
    write_tensors(&dir.join("params.bin"), params)?;
    let mut meta = vec![
        ("step", Json::num(step as f64)),
        ("arch", Json::str(arch)),
        ("optimizer", Json::str(optimizer)),
        ("has_opt_state", Json::Bool(opt_leaves.is_some())),
        ("param_names",
         Json::Arr(param_specs.iter().map(|p| Json::str(p.name.clone()))
                   .collect())),
        ("param_shapes",
         Json::Arr(param_specs
                   .iter()
                   .map(|p| Json::Arr(p.shape.iter()
                                      .map(|&d| Json::num(d as f64))
                                      .collect()))
                   .collect())),
    ];
    if let Some((leaves, state)) = opt_leaves {
        assert_eq!(leaves.len(), state.len());
        write_tensors(&dir.join("opt_state.bin"), state)?;
        meta.push((
            "opt_shapes",
            Json::Arr(leaves
                      .iter()
                      .map(|l| Json::Arr(l.shape.iter()
                                         .map(|&d| Json::num(d as f64))
                                         .collect()))
                      .collect()),
        ));
    }
    std::fs::write(dir.join("meta.json"), Json::obj(meta).dump())?;
    Ok(dir)
}

/// Load a checkpoint saved by [`save`].
pub fn load(dir: &Path) -> Result<Checkpoint> {
    let meta_text = std::fs::read_to_string(dir.join("meta.json"))
        .with_context(|| format!("no checkpoint at {dir:?}"))?;
    let meta = Json::parse(&meta_text)
        .map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
    let shapes: Vec<Vec<usize>> = meta
        .req("param_shapes")?
        .as_arr()
        .context("param_shapes")?
        .iter()
        .map(|s| s.usize_arr().context("shape"))
        .collect::<Result<_>>()?;
    let params = read_tensors(&dir.join("params.bin"), &shapes)?;
    let opt_state = if meta.req("has_opt_state")?.as_bool() == Some(true) {
        let oshapes: Vec<Vec<usize>> = meta
            .req("opt_shapes")?
            .as_arr()
            .context("opt_shapes")?
            .iter()
            .map(|s| s.usize_arr().context("shape"))
            .collect::<Result<_>>()?;
        Some(read_tensors(&dir.join("opt_state.bin"), &oshapes)?)
    } else {
        None
    };
    Ok(Checkpoint {
        step: meta.req("step")?.as_usize().context("step")? as u64,
        arch: meta.req("arch")?.as_str().context("arch")?.to_string(),
        optimizer: meta.req("optimizer")?.as_str().context("opt")?.to_string(),
        params,
        opt_state,
    })
}

// ---- packed quantized models ----------------------------------------------

/// Magic + format version of the packed-model artifact. Bump the version
/// on any layout change; `load_packed` rejects unknown versions instead
/// of misreading bytes.
const QCKPT_MAGIC: [u8; 4] = *b"OSPQ";
const QCKPT_VERSION: u32 = 1;

/// Per-param record tags in the packed artifact.
const QTAG_DENSE: u8 = 0; // untouched param: raw f32
const QTAG_PACKED: u8 = 1; // packed codes + per-column scales
const QTAG_DENSE_Q: u8 = 2; // quantized but unpackable bits: raw f32

struct ByteWriter(Vec<u8>);

impl ByteWriter {
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn f32s(&mut self, vs: &[f32]) {
        for v in vs {
            self.0.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }

    fn shape(&mut self, shape: &[usize]) {
        self.u32(shape.len() as u32);
        for &d in shape {
            self.u32(d as u32);
        }
    }
}

struct ByteReader<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> ByteReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.off.checked_add(n).filter(|&e| e <= self.b.len());
        let Some(end) = end else {
            bail!("packed model truncated at byte {}", self.off);
        };
        let s = &self.b[self.off..end];
        self.off = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        Ok(self.take(4 * n)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())
            .context("packed model: non-utf8 string")?)
    }

    fn shape(&mut self) -> Result<Vec<usize>> {
        let nd = self.u32()? as usize;
        if nd > 8 {
            bail!("packed model: implausible rank {nd}");
        }
        (0..nd).map(|_| Ok(self.u32()? as usize)).collect()
    }
}

/// Serialize a quantized model in packed-code form (single file).
pub fn save_packed(path: &Path, qm: &QuantizedModel) -> Result<()> {
    let mut w = ByteWriter(Vec::with_capacity(qm.packed_bytes() + 256));
    w.0.extend_from_slice(&QCKPT_MAGIC);
    w.u32(QCKPT_VERSION);
    w.str(&qm.arch);
    w.f32s(&[qm.had_flag]);
    w.u32(qm.params().len() as u32);
    for p in qm.params() {
        match p {
            QParam::Dense(t) => {
                w.0.push(QTAG_DENSE);
                w.shape(t.shape());
                w.f32s(t.data());
            }
            QParam::Packed(q) => match q.storage() {
                QStorage::Packed(codes) => {
                    w.0.push(QTAG_PACKED);
                    w.shape(q.shape());
                    w.u32(q.bits());
                    w.f32s(q.scales());
                    w.u32(codes.len() as u32);
                    w.0.extend_from_slice(codes);
                }
                QStorage::Dense(_) => {
                    w.0.push(QTAG_DENSE_Q);
                    w.shape(q.shape());
                    w.u32(q.bits());
                    w.u32(q.scales().len() as u32);
                    w.f32s(q.scales());
                    w.f32s(q.dequantize().data());
                }
            },
        }
    }
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, &w.0).with_context(|| format!("writing {path:?}"))
}

/// Load a packed model saved by [`save_packed`].
pub fn load_packed(path: &Path) -> Result<QuantizedModel> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("no packed model at {path:?}"))?;
    let mut r = ByteReader { b: &bytes, off: 0 };
    if r.take(4)? != QCKPT_MAGIC {
        bail!("{path:?}: not a packed model (bad magic)");
    }
    let version = r.u32()?;
    if version != QCKPT_VERSION {
        bail!("{path:?}: packed model version {version}, \
               this build reads {QCKPT_VERSION}");
    }
    let arch = r.str()?;
    let had_flag = r.f32s(1)?[0];
    let n_params = r.u32()? as usize;
    if n_params > 1 << 20 {
        bail!("{path:?}: implausible param count {n_params}");
    }
    let mut params = Vec::with_capacity(n_params);
    for pi in 0..n_params {
        let tag = r.take(1)?[0];
        let shape = r.shape()?;
        let numel = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .ok_or_else(|| {
                anyhow::anyhow!("{path:?}: param {pi}: shape {shape:?} \
                                 overflows")
            })?;
        let p = match tag {
            QTAG_DENSE => {
                QParam::Dense(Tensor::new(shape, r.f32s(numel)?))
            }
            QTAG_PACKED => {
                let bits = r.u32()?;
                let cols = *shape.last().unwrap_or(&0);
                let scales = r.f32s(cols)?;
                let n_codes = r.u32()? as usize;
                let codes = r.take(n_codes)?.to_vec();
                let q = QTensor::from_parts(shape, bits, scales,
                                            QStorage::Packed(codes))
                    .map_err(|e| {
                        anyhow::anyhow!("{path:?}: param {pi}: {e}")
                    })?;
                QParam::Packed(q)
            }
            QTAG_DENSE_Q => {
                let bits = r.u32()?;
                let n_scales = r.u32()? as usize;
                let scales = r.f32s(n_scales)?;
                let data = r.f32s(numel)?;
                let q = QTensor::from_parts(shape, bits, scales,
                                            QStorage::Dense(data))
                    .map_err(|e| {
                        anyhow::anyhow!("{path:?}: param {pi}: {e}")
                    })?;
                QParam::Packed(q)
            }
            other => bail!("{path:?}: param {pi}: unknown tag {other}"),
        };
        params.push(p);
    }
    if r.off != bytes.len() {
        bail!("{path:?}: {} trailing bytes", bytes.len() - r.off);
    }
    Ok(QuantizedModel::new(arch, params, had_flag))
}

// ---- per-worker shard artifacts (DESIGN.md §14) ---------------------------

/// Magic + format version of a per-worker shard artifact (`osp shard`
/// output, fetched by workers over the storage backend). Versioned the
/// same way as `OSPQ`: any layout change bumps the version, and
/// [`load_shard`] rejects unknown versions instead of misreading.
const SHARD_MAGIC: [u8; 4] = *b"OSPS";
const SHARD_VERSION: u32 = 1;

/// A loaded shard artifact: worker `shard` of `n_shards`, carrying its
/// slice of every trunk linear.
pub struct ShardArtifact {
    pub shard: usize,
    pub n_shards: usize,
    pub arch: String,
    pub entries: ShardSet,
}

/// Serialize one worker's shard set (single file). Every entry must be
/// packed — shard extraction only emits packed pieces.
pub fn save_shard(path: &Path, shard: usize, n_shards: usize, arch: &str,
                  set: &ShardSet) -> Result<()> {
    let mut w = ByteWriter(Vec::new());
    w.0.extend_from_slice(&SHARD_MAGIC);
    w.u32(SHARD_VERSION);
    w.u32(shard as u32);
    w.u32(n_shards as u32);
    w.str(arch);
    w.u32(set.len() as u32);
    for e in set {
        let QStorage::Packed(codes) = e.q.storage() else {
            bail!("shard entry '{}' is not packed", e.name);
        };
        w.str(&e.name);
        w.0.push(e.kind.tag());
        w.u32(e.full_k as u32);
        w.u32(e.full_n as u32);
        w.u32(e.off as u32);
        w.u32(e.q.bits());
        w.shape(e.q.shape());
        w.f32s(e.q.scales());
        w.u32(codes.len() as u32);
        w.0.extend_from_slice(codes);
    }
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, &w.0).with_context(|| format!("writing {path:?}"))
}

/// Parse a shard artifact from raw bytes (the worker's fetch path —
/// bytes may arrive over HTTP rather than from a file). Validates the
/// magic, version, entry geometry (via [`QTensor::from_parts`]), and
/// that no bytes trail the last entry.
pub fn parse_shard(bytes: &[u8], what: &str) -> Result<ShardArtifact> {
    let mut r = ByteReader { b: bytes, off: 0 };
    if r.take(4)? != SHARD_MAGIC {
        bail!("{what}: not a shard artifact (bad magic)");
    }
    let version = r.u32()?;
    if version != SHARD_VERSION {
        bail!("{what}: shard artifact version {version}, this build \
               reads {SHARD_VERSION}");
    }
    let shard = r.u32()? as usize;
    let n_shards = r.u32()? as usize;
    if n_shards == 0 || shard >= n_shards {
        bail!("{what}: shard {shard} of {n_shards} is inconsistent");
    }
    let arch = r.str()?;
    let n_entries = r.u32()? as usize;
    if n_entries > 1 << 20 {
        bail!("{what}: implausible entry count {n_entries}");
    }
    let mut entries = Vec::with_capacity(n_entries);
    for ei in 0..n_entries {
        let name = r.str()?;
        let kind = ShardKind::from_tag(r.take(1)?[0])
            .map_err(|e| anyhow::anyhow!("{what}: entry {ei}: {e}"))?;
        let full_k = r.u32()? as usize;
        let full_n = r.u32()? as usize;
        let off = r.u32()? as usize;
        let bits = r.u32()?;
        let shape = r.shape()?;
        if shape.len() != 2 {
            bail!("{what}: entry '{name}' has rank {}", shape.len());
        }
        let scales = r.f32s(shape[1])?;
        let n_codes = r.u32()? as usize;
        let codes = r.take(n_codes)?.to_vec();
        let q = QTensor::from_parts(shape, bits, scales,
                                    QStorage::Packed(codes))
            .map_err(|e| anyhow::anyhow!("{what}: entry '{name}': {e}"))?;
        entries.push(ShardEntry { name, kind, full_k, full_n, off, q });
    }
    if r.off != bytes.len() {
        bail!("{what}: {} trailing bytes", bytes.len() - r.off);
    }
    Ok(ShardArtifact { shard, n_shards, arch, entries })
}

/// Load a shard artifact saved by [`save_shard`].
pub fn load_shard(path: &Path) -> Result<ShardArtifact> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("no shard artifact at {path:?}"))?;
    parse_shard(&bytes, &format!("{path:?}"))
}

/// List checkpoint step dirs under a run, ascending.
pub fn list_steps(run_dir: &Path) -> Vec<(u64, PathBuf)> {
    let mut out = Vec::new();
    let Ok(rd) = std::fs::read_dir(run_dir.join("ckpt")) else {
        return out;
    };
    for entry in rd.flatten() {
        let name = entry.file_name().to_string_lossy().to_string();
        if let Some(num) = name.strip_prefix("step_") {
            if let Ok(step) = num.parse::<u64>() {
                out.push((step, entry.path()));
            }
        }
    }
    out.sort_by_key(|&(s, _)| s);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "a".into(), shape: vec![2, 3],
                        init: "normal".into(), kind: "matrix".into() },
            ParamSpec { name: "b".into(), shape: vec![4],
                        init: "ones".into(), kind: "norm".into() },
        ]
    }

    #[test]
    fn save_load_roundtrip() {
        let run = std::env::temp_dir().join("osp_ckpt_test_a");
        let _ = std::fs::remove_dir_all(&run);
        let params = vec![
            Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
            Tensor::new(vec![4], vec![0.5; 4]),
        ];
        let dir = save(&run, 42, "ssnorm_embproj", "muon", &specs(), &params,
                       None).unwrap();
        let ck = load(&dir).unwrap();
        assert_eq!(ck.step, 42);
        assert_eq!(ck.arch, "ssnorm_embproj");
        assert_eq!(ck.params[0].data(), params[0].data());
        assert_eq!(ck.params[1].shape(), &[4]);
        assert!(ck.opt_state.is_none());
    }

    #[test]
    fn save_load_with_opt_state() {
        let run = std::env::temp_dir().join("osp_ckpt_test_b");
        let _ = std::fs::remove_dir_all(&run);
        let params = vec![
            Tensor::zeros(&[2, 3]),
            Tensor::zeros(&[4]),
        ];
        let leaves = vec![OptLeafSpec { name: "step".into(), shape: vec![1],
                                        init: "zeros".into() }];
        let state = vec![Tensor::new(vec![1], vec![7.0])];
        let dir = save(&run, 7, "rmsnorm_plain", "adam", &specs(), &params,
                       Some((&leaves, &state))).unwrap();
        let ck = load(&dir).unwrap();
        assert_eq!(ck.opt_state.unwrap()[0].data(), &[7.0]);
    }

    #[test]
    fn list_steps_sorted() {
        let run = std::env::temp_dir().join("osp_ckpt_test_c");
        let _ = std::fs::remove_dir_all(&run);
        let params = vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[4])];
        for step in [30u64, 10, 20] {
            save(&run, step, "a", "adam", &specs(), &params, None).unwrap();
        }
        let steps: Vec<u64> =
            list_steps(&run).into_iter().map(|(s, _)| s).collect();
        assert_eq!(steps, vec![10, 20, 30]);
    }

    fn toy_quantized_model() -> QuantizedModel {
        use crate::quant::rtn;
        use crate::util::rng::Pcg;
        let mut rng = Pcg::new(42, 1);
        let mut w = Tensor::zeros(&[64, 48]);
        rng.fill_normal(w.data_mut(), 1.0);
        let params = vec![
            QParam::Packed(rtn::quantize_per_channel_q(&w, 4)),
            QParam::Dense(Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0])),
            QParam::Packed(QTensor::from_dense(&Tensor::full(&[2, 2], 0.5))),
        ];
        QuantizedModel::new("ssnorm_plain".into(), params, 1.0)
    }

    #[test]
    fn packed_model_roundtrip() {
        let dir = std::env::temp_dir().join("osp_qckpt_test_a");
        let _ = std::fs::remove_dir_all(&dir);
        let qm = toy_quantized_model();
        let path = dir.join("qmodel.bin");
        save_packed(&path, &qm).unwrap();
        let back = load_packed(&path).unwrap();
        assert_eq!(back.arch, "ssnorm_plain");
        assert_eq!(back.had_flag, 1.0);
        assert_eq!(back.params().len(), qm.params().len());
        for (a, b) in qm.params().iter().zip(back.params()) {
            assert_eq!(a.dequantize(), b.dequantize());
        }
    }

    #[test]
    fn packed_w4_artifact_is_small() {
        // The point of the format: a W4 model on disk costs well under
        // 0.3x the dense f32 bytes of its quantized weights.
        let dir = std::env::temp_dir().join("osp_qckpt_test_b");
        let _ = std::fs::remove_dir_all(&dir);
        use crate::quant::rtn;
        use crate::util::rng::Pcg;
        let mut rng = Pcg::new(7, 2);
        let mut w = Tensor::zeros(&[128, 96]);
        rng.fill_normal(w.data_mut(), 1.0);
        let qm = QuantizedModel::new(
            "a".into(),
            vec![QParam::Packed(rtn::quantize_per_channel_q(&w, 4))],
            0.0);
        let path = dir.join("qmodel.bin");
        save_packed(&path, &qm).unwrap();
        let file_bytes = std::fs::metadata(&path).unwrap().len() as f64;
        let dense_bytes = (4 * 128 * 96) as f64;
        assert!(file_bytes <= 0.3 * dense_bytes,
                "{file_bytes} vs dense {dense_bytes}");
    }

    #[test]
    fn packed_model_rejects_corruption() {
        let dir = std::env::temp_dir().join("osp_qckpt_test_c");
        let _ = std::fs::remove_dir_all(&dir);
        let qm = toy_quantized_model();
        let path = dir.join("qmodel.bin");
        save_packed(&path, &qm).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // bad magic
        let mut evil = bytes.clone();
        evil[0] = b'X';
        std::fs::write(&path, &evil).unwrap();
        assert!(load_packed(&path).is_err());
        // unknown version
        let mut evil = bytes.clone();
        evil[4] = 99;
        std::fs::write(&path, &evil).unwrap();
        assert!(load_packed(&path).is_err());
        // truncation
        bytes.truncate(bytes.len() - 3);
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_packed(&path).is_err());
    }

    fn toy_shard_set() -> ShardSet {
        use crate::quant::rtn;
        use crate::util::rng::Pcg;
        let mut rng = Pcg::new(9, 3);
        let mut w = Tensor::zeros(&[24, 20]);
        rng.fill_normal(w.data_mut(), 1.0);
        let q = rtn::quantize_per_channel_q(&w, 4);
        vec![
            ShardEntry { name: "L0.wq".into(), kind: ShardKind::Col,
                         full_k: 24, full_n: 40, off: 20,
                         q: q.shard_cols(0, 20) },
            ShardEntry { name: "L0.wo".into(), kind: ShardKind::Row,
                         full_k: 48, full_n: 20, off: 24,
                         q: q.shard_rows(0, 24) },
        ]
    }

    #[test]
    fn shard_artifact_roundtrip() {
        let dir = std::env::temp_dir().join("osp_shard_test_a");
        let _ = std::fs::remove_dir_all(&dir);
        let set = toy_shard_set();
        let path = dir.join("shard_1.bin");
        save_shard(&path, 1, 2, "ssnorm_plain", &set).unwrap();
        let back = load_shard(&path).unwrap();
        assert_eq!((back.shard, back.n_shards), (1, 2));
        assert_eq!(back.arch, "ssnorm_plain");
        assert_eq!(back.entries.len(), 2);
        for (a, b) in set.iter().zip(&back.entries) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
            assert_eq!((a.full_k, a.full_n, a.off),
                       (b.full_k, b.full_n, b.off));
            assert_eq!(a.q, b.q, "'{}' payload", a.name);
        }
    }

    /// The satellite robustness matrix: bad magic, unknown version,
    /// inconsistent shard index, and truncation all fail cleanly (an
    /// `Err`, never a panic or a silently-wrong tensor).
    #[test]
    fn shard_artifact_rejects_corruption() {
        let dir = std::env::temp_dir().join("osp_shard_test_b");
        let _ = std::fs::remove_dir_all(&dir);
        let set = toy_shard_set();
        let path = dir.join("shard_0.bin");
        save_shard(&path, 0, 2, "a", &set).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // bad magic
        let mut evil = bytes.clone();
        evil[0] = b'X';
        assert!(parse_shard(&evil, "t").is_err());
        // unknown version
        let mut evil = bytes.clone();
        evil[4] = 99;
        let err = parse_shard(&evil, "t").unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        // shard index out of range (byte 8 is the shard u32)
        let mut evil = bytes.clone();
        evil[8] = 7;
        assert!(parse_shard(&evil, "t").is_err());
        // truncation at any tail point
        for cut in [bytes.len() - 1, bytes.len() / 2, 10] {
            assert!(parse_shard(&bytes[..cut], "t").is_err(),
                    "cut at {cut}");
        }
        // flipped payload bit that breaks pad-bit canonicalization is
        // caught by from_parts; a mid-scale flip still parses (scales
        // are opaque f32s) — integrity beyond structure is the storage
        // layer's checksum job (serve::storage).
        assert!(load_shard(&path).is_ok());
    }

    #[test]
    fn corrupted_size_rejected() {
        let run = std::env::temp_dir().join("osp_ckpt_test_d");
        let _ = std::fs::remove_dir_all(&run);
        let params = vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[4])];
        let dir = save(&run, 1, "a", "adam", &specs(), &params, None).unwrap();
        std::fs::write(dir.join("params.bin"), [0u8; 12]).unwrap();
        assert!(load(&dir).is_err());
    }
}
