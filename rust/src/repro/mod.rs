//! Paper-row regeneration: one function per table/figure (rust/DESIGN.md
//! §5 "Quantization pipeline and paper-row regeneration"). Used by the
//! `osp repro` CLI, the examples, and the bench binaries (quick
//! variants). Activation-kurtosis scans run on the shared parallel
//! reduction (`tensor::stats` over `tensor::par`, DESIGN.md §6).
//!
//! Table 2 — the paper's headline W4A4KV4 claim — evaluates on the
//! engine-free host path (DESIGN.md §9): packed leaves go straight into
//! [`crate::model::InferModel::forward_block`] with no `dense_params()`
//! materialization and no compiled executables, so `osp repro table2`
//! works offline on the stub runtime. The remaining tables/figures keep
//! the PJRT engine path (GPTQ calibration and the probe artifacts have
//! no host equivalent yet).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::bench::{fmt_pct, fmt_ppl, Table};
use crate::checkpoint;
use crate::config::ABLATION_GRID;
use crate::data::{Split, TokenStream};
use crate::eval::{host, perplexity, sinks, tasks, BitConfig,
                  HostEvalOpts};
use crate::metrics::read_telemetry;
use crate::model::InferModel;
use crate::quant::{self, PtqConfig, Rotation, WeightMethod};
use crate::runtime::{Engine, HostValue};
use crate::tensor::stats::Histogram;
use crate::tensor::{par, Tensor};

/// Evaluation effort knob (benches use Quick).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Effort {
    pub ppl_batches: usize,
    pub n_per_task: usize,
}

impl Effort {
    pub const QUICK: Effort = Effort { ppl_batches: 1, n_per_task: 8 };
    pub const FULL: Effort = Effort { ppl_batches: 4, n_per_task: 24 };
}

/// A trained run on disk (tag -> final checkpoint).
pub struct Run {
    pub tag: String,
    pub arch: String,
    pub optimizer: String,
    pub dir: PathBuf,
    pub params: Vec<Tensor>,
}

/// Load the latest checkpoint of each ablation tag present in runs_dir.
pub fn load_runs(runs_dir: &Path, tags: &[&str]) -> Result<Vec<Run>> {
    let mut out = Vec::new();
    for &tag in tags {
        let dir = runs_dir.join(tag);
        let steps = checkpoint::list_steps(&dir);
        let Some((_step, ckpt_dir)) = steps.last() else {
            continue;
        };
        let ck = checkpoint::load(ckpt_dir)
            .with_context(|| format!("loading {ckpt_dir:?}"))?;
        out.push(Run { tag: tag.to_string(), arch: ck.arch.clone(),
                       optimizer: ck.optimizer.clone(), dir,
                       params: ck.params });
    }
    if out.is_empty() {
        return Err(anyhow!(
            "no trained runs under {runs_dir:?}; run \
             `cargo run --release --example train_osp -- --ablation` first"));
    }
    Ok(out)
}

pub fn ablation_tags() -> Vec<&'static str> {
    ABLATION_GRID.iter().map(|&(tag, _, _)| tag).collect()
}

/// Host-eval shape for one engine manifest (batch/seq from the eval
/// executables' lowering, quantization bits from the caller).
fn host_opts(engine: &Engine, bits_a: u32, bits_kv: u32,
             effort: Effort) -> HostEvalOpts {
    let m = engine.manifest();
    HostEvalOpts { a_bits: bits_a, kv_bits: bits_kv, batch: m.batch_eval,
                   seq_len: m.model.seq_len,
                   n_batches: effort.ppl_batches,
                   chunk: host::DEFAULT_EVAL_CHUNK }
}

/// Evaluate one run under one bit configuration (weights quantized here;
/// activations/KV at runtime) on the engine-free host path: the packed
/// leaves are served by the block forward directly — no `dense_params()`
/// round-trip, no compiled executables. Returns (avg_score, ppl,
/// kurt_max).
pub fn eval_bitconfig(engine: &Engine, run: &Run, bits: BitConfig,
                      ffn_had: bool, effort: Effort)
                      -> Result<(f64, f64, f64)> {
    let cfg = PtqConfig {
        w_bits: bits.w,
        method: WeightMethod::Rtn,
        rotation: Rotation::None,
        ffn_had,
        seed: 7,
        calib_batches: 1,
    };
    let qm = quant::prepare(engine, &run.arch, &run.params, &cfg)?;
    let m = engine.manifest();
    let model = qm.decoder(m.model.n_heads, m.model.rope_theta as f32)?;
    let opts = host_opts(engine, bits.a, bits.kv, effort);
    let ppl = host::perplexity_host(&model, &opts, par::shared_pool())?;
    let (_rows, avg) = host::run_suite_host(&model, effort.n_per_task,
                                            bits.a, bits.kv, 99,
                                            par::shared_pool())?;
    Ok((avg, ppl.ppl, ppl.kurt_max))
}

/// Table 2: the ablation grid across bit configurations, RTN and +Had.
pub fn table2(engine: &Engine, runs_dir: &Path, effort: Effort)
              -> Result<Table> {
    table2_tags(engine, runs_dir, effort, &ablation_tags())
}

/// Table 2 restricted to a subset of configs (the bench's quick variant).
pub fn table2_tags(engine: &Engine, runs_dir: &Path, effort: Effort,
                   tags: &[&str]) -> Result<Table> {
    let runs = load_runs(runs_dir, tags)?;
    let cols = BitConfig::table2_columns();
    let mut headers = vec!["Config".to_string(), "Had.".to_string(),
                           "Ex.Kurt".to_string()];
    for c in &cols {
        headers.push(format!("{} Avg", c.label()));
        headers.push(format!("{} PPL", c.label()));
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Table 2 — ablation x quantization (RTN / +FFN-Had)", &hdr_refs);
    let m = engine.manifest();
    for run in &runs {
        // FP reference on the host path too: dense leaves wrapped as a
        // host model, kurtosis from the block forward's residual taps.
        let fp_model = InferModel::from_dense_params(
            &run.arch, &run.params, m.model.n_heads,
            m.model.rope_theta as f32)?;
        let fp = host::perplexity_host(
            &fp_model, &host_opts(engine, 16, 16, effort),
            par::shared_pool())?;
        for &had in &[false, true] {
            let mut row = vec![run.tag.clone(),
                               if had { "yes" } else { "no" }.to_string(),
                               format!("{:.2}", fp.kurt_max)];
            for c in &cols {
                let (avg, ppl, _k) =
                    eval_bitconfig(engine, run, *c, had, effort)?;
                row.push(fmt_pct(avg));
                row.push(fmt_ppl(ppl));
            }
            table.row(row);
        }
    }
    Ok(table)
}

/// Table 3: per-task scores at 4-4-4 (our from-scratch rows; ablation
/// configs stand in for the open-source comparators — DESIGN.md §2).
pub fn table3(engine: &Engine, runs_dir: &Path, effort: Effort)
              -> Result<Table> {
    let runs = load_runs(runs_dir, &ablation_tags())?;
    let mut headers = vec!["Model".to_string()];
    headers.extend(tasks::TASK_NAMES.iter().map(|s| s.to_string()));
    headers.push("Avg".to_string());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("Table 3 — 4-bit (4-4-4) benchmark scores",
                               &hdr_refs);
    for run in &runs {
        let cfg = PtqConfig::rtn(4);
        let qm = quant::prepare(engine, &run.arch, &run.params, &cfg)?;
        let (rows, avg) = tasks::run_suite(engine, &qm.arch,
                                           qm.dense_params(),
                                           effort.n_per_task, 4, 4,
                                           qm.had_flag, 99)?;
        let mut cells = vec![run.tag.clone()];
        cells.extend(rows.iter().map(|(_t, a)| fmt_pct(*a)));
        cells.push(fmt_pct(avg));
        table.row(cells);
    }
    Ok(table)
}

/// Table 4: PTQ method composition at W4-A4-KV4, Adam vs OSP.
pub fn table4(engine: &Engine, runs_dir: &Path, effort: Effort)
              -> Result<Table> {
    let runs = load_runs(runs_dir, &["adam", "osp"])?;
    let recipes: Vec<(&str, PtqConfig)> = vec![
        ("RTN", PtqConfig::rtn(4)),
        ("+ FFN Had", PtqConfig { ffn_had: true, ..PtqConfig::rtn(4) }),
        ("+ GPTQ", PtqConfig { method: WeightMethod::Gptq,
                               ..PtqConfig::rtn(4) }),
        ("+ QuaRot-lite", PtqConfig { method: WeightMethod::Gptq,
                                      rotation: Rotation::Random,
                                      ffn_had: true, ..PtqConfig::rtn(4) }),
        ("+ SpinQuant-lite", PtqConfig { method: WeightMethod::Gptq,
                                         rotation: Rotation::Learned,
                                         ffn_had: true,
                                         ..PtqConfig::rtn(4) }),
    ];
    let mut headers = vec!["Quantization".to_string()];
    for r in &runs {
        headers.push(r.tag.clone());
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Table 4 — PTQ composition, W4-A4-KV4 perplexity", &hdr_refs);
    for (label, cfg) in recipes {
        let mut row = vec![label.to_string()];
        for run in &runs {
            let qm = quant::prepare(engine, &run.arch, &run.params, &cfg)?;
            let ppl = perplexity(engine, &qm.arch, qm.dense_params(), 4, 4,
                                 qm.had_flag, effort.ppl_batches)?;
            row.push(fmt_ppl(ppl.ppl));
        }
        table.row(row);
    }
    Ok(table)
}

/// Table 5: full-precision per-task scores.
pub fn table5(engine: &Engine, runs_dir: &Path, effort: Effort)
              -> Result<Table> {
    let runs = load_runs(runs_dir, &ablation_tags())?;
    let mut headers = vec!["Model".to_string()];
    headers.extend(tasks::TASK_NAMES.iter().map(|s| s.to_string()));
    headers.push("Avg".to_string());
    headers.push("PPL".to_string());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table =
        Table::new("Table 5 — full-precision benchmark scores", &hdr_refs);
    for run in &runs {
        let (rows, avg) = tasks::run_suite(engine, &run.arch, &run.params,
                                           effort.n_per_task, 16, 16, 0.0,
                                           99)?;
        let ppl = perplexity(engine, &run.arch, &run.params, 16, 16, 0.0,
                             effort.ppl_batches)?;
        let mut cells = vec![run.tag.clone()];
        cells.extend(rows.iter().map(|(_t, a)| fmt_pct(*a)));
        cells.push(fmt_pct(avg));
        cells.push(fmt_ppl(ppl.ppl));
        table.row(cells);
    }
    Ok(table)
}

/// Figure 1: fp16 vs 4-bit average score per saved checkpoint.
pub fn fig1(engine: &Engine, runs_dir: &Path, effort: Effort)
            -> Result<Table> {
    let mut table = Table::new(
        "Figure 1 — degradation under 4-bit (per checkpoint)",
        &["run", "step", "fp16 avg", "4-4-4 avg", "delta"]);
    for &tag in &ablation_tags() {
        let dir = runs_dir.join(tag);
        let steps = checkpoint::list_steps(&dir);
        // Quick effort: only the final two checkpoints per run.
        let skip = if effort.n_per_task <= Effort::QUICK.n_per_task {
            steps.len().saturating_sub(2)
        } else {
            0
        };
        for (step, ckpt_dir) in steps.into_iter().skip(skip) {
            let ck = checkpoint::load(&ckpt_dir)?;
            let run = Run { tag: tag.into(), arch: ck.arch.clone(),
                            optimizer: ck.optimizer.clone(),
                            dir: dir.clone(), params: ck.params };
            let (_r, fp_avg) = tasks::run_suite(
                engine, &run.arch, &run.params, effort.n_per_task, 16, 16,
                0.0, 99)?;
            let (q_avg, _ppl, _k) = eval_bitconfig(
                engine, &run, BitConfig::new(4, 4, 4), false, effort)?;
            table.row(vec![tag.to_string(), step.to_string(),
                           fmt_pct(fp_avg), fmt_pct(q_avg),
                           format!("{:+.1}", 100.0 * (q_avg - fp_avg))]);
        }
    }
    Ok(table)
}

/// Figure 2 / Figures 8-9: activation histograms at the probed layers.
pub fn fig2(engine: &Engine, runs_dir: &Path, tags: &[&str])
            -> Result<String> {
    let runs = load_runs(runs_dir, tags)?;
    let m = engine.manifest();
    let mut out = String::from(
        "\n## Figure 2 / 8-9 — activation histograms (log-scale sparklines)\n");
    for run in &runs {
        let probe = engine.load(&format!("probe_{}", run.arch))?;
        let mut valid = TokenStream::new(m.model.vocab_size, 0xF16,
                                         Split::Valid, 0, 1);
        let b = valid.next_batch(m.batch_probe, m.model.seq_len, 0);
        let mut inputs: Vec<HostValue> =
            run.params.iter().cloned().map(HostValue::F32).collect();
        inputs.push(HostValue::tokens(&[m.batch_probe, m.model.seq_len],
                                      b.tokens));
        let res = probe.run(&inputs)?;
        let mhsa = res[1].as_f32()?;
        let ffn = res[2].as_f32()?;
        out.push_str(&format!("\n### {}\n", run.tag));
        let stride = m.batch_probe * m.model.seq_len * m.model.d_model;
        for (pi, &layer) in m.probe_layers.iter().enumerate() {
            for (name, t) in [("MHSA-in", mhsa), ("FFN-in", ffn)] {
                let data = &t.data()[pi * stride..(pi + 1) * stride];
                let h = Histogram::auto(data, 64);
                let absmax =
                    data.iter().fold(0.0f32, |a, v| a.max(v.abs()));
                let kurt = crate::tensor::stats::excess_kurtosis(data);
                out.push_str(&format!(
                    "layer {layer:2} {name:8} absmax {absmax:8.2} \
                     kurt {kurt:9.2} |{}|\n",
                    h.sparkline()));
            }
        }
    }
    Ok(out)
}

/// Figure 3 / 7: loss + kurtosis curves from telemetry.
pub fn fig3(runs_dir: &Path, tags: &[&str]) -> Result<String> {
    let mut out = String::from(
        "\n## Figure 3/7 — training dynamics (loss | max excess kurtosis)\n");
    for &tag in tags {
        let path = runs_dir.join(tag).join("telemetry.jsonl");
        if !path.exists() {
            continue;
        }
        let recs = read_telemetry(&path)?;
        let mut loss = crate::metrics::Series::default();
        let mut kurt = crate::metrics::Series::default();
        for r in &recs {
            if let Some(l) = r.fields.get("loss") {
                loss.push(r.step, *l);
            }
            if let Some(k) = r.fields.get("kurt_max") {
                kurt.push(r.step, *k);
            }
        }
        out.push_str(&format!("\n### {tag}\n  step: "));
        for (s, _) in loss.downsample(12) {
            out.push_str(&format!("{s:>8}"));
        }
        out.push_str("\n  loss: ");
        for (_, v) in loss.downsample(12) {
            out.push_str(&format!("{v:>8.3}"));
        }
        out.push_str("\n  kurt: ");
        for (_, v) in kurt.downsample(12) {
            out.push_str(&format!("{v:>8.2}"));
        }
        out.push('\n');
    }
    Ok(out)
}

/// Figure 4: perplexity across weight x activation bit-widths.
pub fn fig4(engine: &Engine, runs_dir: &Path, tags: &[&str],
            effort: Effort) -> Result<Table> {
    let runs = load_runs(runs_dir, tags)?;
    let w_bits = [16u32, 8, 6, 4, 3, 2];
    let a_bits = [16u32, 8, 6, 4];
    let mut headers = vec!["run".to_string(), "W bits".to_string()];
    for a in a_bits {
        headers.push(format!("A{a}"));
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Figure 4 — PPL across weight/activation bit-widths (RTN)",
        &hdr_refs);
    for run in &runs {
        for w in w_bits {
            let cfg = PtqConfig::rtn(w);
            let qm = quant::prepare(engine, &run.arch, &run.params, &cfg)?;
            let mut row = vec![run.tag.clone(), w.to_string()];
            for a in a_bits {
                let ppl = perplexity(engine, &qm.arch, qm.dense_params(),
                                     a, 16, 0.0, effort.ppl_batches)?;
                row.push(fmt_ppl(ppl.ppl));
            }
            table.row(row);
        }
    }
    Ok(table)
}

/// Figures 5 & 6 + §5.2: attention sinks and massive activations.
pub fn fig56(engine: &Engine, runs_dir: &Path, tags: &[&str])
             -> Result<String> {
    let runs = load_runs(runs_dir, tags)?;
    let m = engine.manifest();
    let mut out = String::from(
        "\n## Figures 5-6 — attention sinks without outliers\n");
    for run in &runs {
        let mut valid = TokenStream::new(m.model.vocab_size, 0x517Bu64,
                                         Split::Valid, 0, 1);
        let b = valid.next_batch(m.batch_probe, m.model.seq_len, 0);
        let report = sinks::analyze(
            engine, &run.arch, &run.params,
            HostValue::tokens(&[m.batch_probe, m.model.seq_len], b.tokens))?;
        out.push_str(&format!(
            "\n### {}\n  massive(|x|>6sigma): mhsa {:.4}% ffn {:.4}%  \
             kurt_max {:.2}  qk-concentration {:.2}\n",
            run.tag,
            100.0 * report.massive_fraction_mhsa,
            100.0 * report.massive_fraction_ffn,
            report.kurt_max,
            report.qk_concentration));
        let sink_heads = report.sink_heads(0.3);
        out.push_str(&format!("  sink heads (mass>0.3): {}\n",
                              sink_heads.len()));
        for h in report.heads.iter().take(8) {
            out.push_str(&format!(
                "    L{} H{}: sink_mass {:.2}  sink_logit {:+.2}  \
                 other_logit {:+.2} (sd {:.2})\n",
                h.layer, h.head, h.sink_mass, h.sink_logit_mean,
                h.other_logit_mean, h.other_logit_std));
        }
    }
    Ok(out)
}

/// Figures 10-11: weight histograms at probed depths.
pub fn fig1011(engine: &Engine, runs_dir: &Path, tags: &[&str])
               -> Result<String> {
    let runs = load_runs(runs_dir, tags)?;
    let m = engine.manifest();
    let mut out =
        String::from("\n## Figures 10-11 — weight histograms\n");
    for run in &runs {
        out.push_str(&format!("\n### {}\n", run.tag));
        let specs = engine.manifest().params(&run.arch)?;
        for &layer in &m.probe_layers {
            for w in ["wq", "w_down"] {
                let name = format!("layers.{layer}.{w}");
                if let Some(idx) =
                    specs.iter().position(|s| s.name == name)
                {
                    let t = &run.params[idx];
                    let h = Histogram::auto(t.data(), 64);
                    let kurt =
                        crate::tensor::stats::excess_kurtosis(t.data());
                    out.push_str(&format!(
                        "{name:20} absmax {:8.3} kurt {kurt:8.2} |{}|\n",
                        t.abs_max(), h.sparkline()));
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efforts() {
        assert!(Effort::QUICK.n_per_task < Effort::FULL.n_per_task);
    }

    #[test]
    fn ablation_tags_match_grid() {
        let tags = ablation_tags();
        assert_eq!(tags.len(), 6);
        assert!(tags.contains(&"osp") && tags.contains(&"adam"));
    }
}
