//! The 10-task synthetic benchmark suite (the stand-in for ARC, CSQA,
//! GSM8K, HellaSwag, MMLU, OBQA, PIQA, SIQA, TriviaQA, WinoGrande —
//! DESIGN.md §2).
//!
//! Every task is 4-way multiple choice scored exactly like lm-eval-
//! harness MC tasks: the option with the highest next-token
//! log-likelihood wins; chance = 25%. Tasks are derived from the grammar
//! the model was trained on, so a trained model is far above chance at
//! fp16 and collapses toward chance when quantization destroys it —
//! reproducing the Table-3 signature.

use anyhow::Result;

use crate::coordinator::levels_for_bits;
use crate::data::grammar::{Class, Grammar, BOS, COLON, EQUALS, LPAREN,
                           N_DIGITS, PLUS, QUERY, RPAREN, SEP};
use crate::infer::{engine, DecodeParams, InferModel};
use crate::runtime::{Engine, HostValue};
use crate::tensor::Tensor;
use crate::util::rng::Pcg;
use crate::util::threadpool::ThreadPool;

pub const N_OPTIONS: usize = 4;

/// One MC instance: a context, 4 single-token options, the answer index.
#[derive(Clone, Debug)]
pub struct Instance {
    pub context: Vec<i32>,
    pub options: [i32; N_OPTIONS],
    pub answer: usize,
}

/// The task families, in the order reported by the benches.
pub const TASK_NAMES: [&str; 10] = [
    "bigram", "template", "induction", "copy", "math", "bracket", "zipf",
    "recall", "long_induction", "math_2hop",
];

fn pick_distractors(correct: i32, pool: &[i32], rng: &mut Pcg) -> [i32; N_OPTIONS] {
    let mut opts = [correct; N_OPTIONS];
    let mut used = vec![correct];
    for slot in opts.iter_mut().skip(1) {
        loop {
            let cand = pool[rng.below_usize(pool.len())];
            if !used.contains(&cand) {
                used.push(cand);
                *slot = cand;
                break;
            }
        }
    }
    opts
}

fn shuffle_answer(mut opts: [i32; N_OPTIONS], rng: &mut Pcg) -> ([i32; N_OPTIONS], usize) {
    let correct = opts[0];
    // Fisher-Yates over the fixed-size array.
    for i in (1..N_OPTIONS).rev() {
        let j = rng.below_usize(i + 1);
        opts.swap(i, j);
    }
    let answer = opts.iter().position(|&o| o == correct).unwrap();
    (opts, answer)
}

/// Generate `n` instances of the named task.
pub fn generate(g: &Grammar, task: &str, n: usize, seed: u64) -> Vec<Instance> {
    let mut rng = Pcg::new(seed ^ 0x7A5C, 55);
    (0..n).map(|_| generate_one(g, task, &mut rng)).collect()
}

fn filler(g: &Grammar, rng: &mut Pcg, k: usize, out: &mut Vec<i32>) {
    for _ in 0..k {
        out.push(g.sample_class(Class::Func, rng));
    }
}

fn generate_one(g: &Grammar, task: &str, rng: &mut Pcg) -> Instance {
    let mut ctx = vec![BOS];
    match task {
        // ARC stand-in: local bigram knowledge.
        "bigram" => {
            let t = g.sample_class(Class::Noun, rng);
            filler(g, rng, 3, &mut ctx);
            ctx.push(SEP);
            ctx.push(t);
            let succ = g.successors(t);
            let correct = succ[0];
            let pool: Vec<i32> = g
                .class_tokens(Class::Noun)
                .iter()
                .chain(g.class_tokens(Class::Verb))
                .copied()
                .filter(|c| !succ.contains(c))
                .collect();
            let (options, answer) =
                shuffle_answer(pick_distractors(correct, &pool, rng), rng);
            Instance { context: ctx, options, answer }
        }
        // WinoGrande stand-in: agreement between noun and verb form.
        "template" => {
            let adj = g.sample_class(Class::Adj, rng);
            let noun = g.sample_class(Class::Noun, rng);
            let correct = g.agreement[noun as usize];
            ctx.extend_from_slice(&[adj, noun]);
            let pool: Vec<i32> = g
                .class_tokens(Class::Verb)
                .iter()
                .copied()
                .filter(|&v| v != correct)
                .collect();
            let (options, answer) =
                shuffle_answer(pick_distractors(correct, &pool, rng), rng);
            Instance { context: ctx, options, answer }
        }
        // HellaSwag stand-in: continue the repeated pattern.
        "induction" | "long_induction" => {
            let a = g.sample_class(Class::Noun, rng);
            let b = g.sample_class(Class::Verb, rng);
            ctx.push(a);
            ctx.push(b);
            let gap = if task == "induction" { 3 } else { 12 };
            filler(g, rng, gap, &mut ctx);
            ctx.push(a);
            let pool: Vec<i32> = g
                .class_tokens(Class::Verb)
                .iter()
                .copied()
                .filter(|&v| v != b)
                .collect();
            let (options, answer) =
                shuffle_answer(pick_distractors(b, &pool, rng), rng);
            Instance { context: ctx, options, answer }
        }
        // PIQA stand-in: verbatim copy.
        "copy" => {
            let span: Vec<i32> = (0..3)
                .map(|_| g.sample_class(Class::Noun, rng))
                .collect();
            ctx.extend_from_slice(&span);
            ctx.push(SEP);
            ctx.extend_from_slice(&span[..2]);
            let correct = span[2];
            let pool: Vec<i32> = g
                .class_tokens(Class::Noun)
                .iter()
                .copied()
                .filter(|&v| !span.contains(&v))
                .collect();
            let (options, answer) =
                shuffle_answer(pick_distractors(correct, &pool, rng), rng);
            Instance { context: ctx, options, answer }
        }
        // MMLU stand-in: one-hop modular arithmetic.
        "math" => {
            let a = rng.below_usize(N_DIGITS);
            let b = rng.below_usize(N_DIGITS);
            ctx.extend_from_slice(&[g.digit(a), PLUS, g.digit(b), EQUALS]);
            let correct = g.digit(a + b);
            let pool: Vec<i32> = (0..N_DIGITS)
                .map(|v| g.digit(v))
                .filter(|&v| v != correct)
                .collect();
            let (options, answer) =
                shuffle_answer(pick_distractors(correct, &pool, rng), rng);
            Instance { context: ctx, options, answer }
        }
        // OBQA stand-in: close the bracket.
        "bracket" => {
            ctx.push(LPAREN);
            ctx.push(g.sample_class(Class::Noun, rng));
            ctx.push(g.sample_class(Class::Verb, rng));
            let correct = RPAREN;
            let distractors = [
                LPAREN,
                g.sample_class(Class::Noun, rng),
                g.sample_class(Class::Func, rng),
            ];
            let mut options = [correct; N_OPTIONS];
            options[1..].copy_from_slice(&distractors);
            let (options, answer) = shuffle_answer(options, rng);
            Instance { context: ctx, options, answer }
        }
        // SIQA stand-in: frequency prior (Zipf head vs tail).
        "zipf" => {
            ctx.push(SEP);
            let nouns = g.class_tokens(Class::Noun);
            let correct = nouns[0]; // Zipf rank 1 within the class
            let tail = &nouns[nouns.len() * 3 / 4..];
            let (options, answer) =
                shuffle_answer(pick_distractors(correct, tail, rng), rng);
            Instance { context: ctx, options, answer }
        }
        // TriviaQA stand-in: key-value recall.
        "recall" => {
            let k = g.sample_class(Class::Noun, rng);
            let v = g.sample_class(Class::Adj, rng);
            ctx.extend_from_slice(&[k, COLON, v]);
            filler(g, rng, 4, &mut ctx);
            ctx.extend_from_slice(&[QUERY, k, COLON]);
            let pool: Vec<i32> = g
                .class_tokens(Class::Adj)
                .iter()
                .copied()
                .filter(|&x| x != v)
                .collect();
            let (options, answer) =
                shuffle_answer(pick_distractors(v, &pool, rng), rng);
            Instance { context: ctx, options, answer }
        }
        // GSM8K stand-in: two-hop arithmetic (expected near chance at
        // this scale, like GSM8K's 0.0 rows in Table 3).
        "math_2hop" => {
            let a = rng.below_usize(N_DIGITS);
            let b = rng.below_usize(N_DIGITS);
            let c = (a + b) % N_DIGITS;
            let d = rng.below_usize(N_DIGITS);
            ctx.extend_from_slice(&[
                g.digit(a), PLUS, g.digit(b), EQUALS, g.digit(c), SEP,
                g.digit(c), PLUS, g.digit(d), EQUALS,
            ]);
            let correct = g.digit(c + d);
            let pool: Vec<i32> = (0..N_DIGITS)
                .map(|v| g.digit(v))
                .filter(|&v| v != correct)
                .collect();
            let (options, answer) =
                shuffle_answer(pick_distractors(correct, &pool, rng), rng);
            Instance { context: ctx, options, answer }
        }
        other => panic!("unknown task '{other}'"),
    }
}

/// Accuracy of the model on a task under the given runtime quantization.
/// Instances are packed into fixed [batch_eval, seq_len] rows; options
/// are scored by the logit at the context's final position.
pub fn accuracy(engine: &Engine, arch: &str, params: &[Tensor],
                instances: &[Instance], a_bits: u32, kv_bits: u32,
                had_flag: f32) -> Result<f64> {
    crate::coordinator::checked_levels_for_bits(a_bits)?;
    crate::coordinator::checked_levels_for_bits(kv_bits)?;
    let m = engine.manifest();
    let logitsq = engine.load(&format!("logitsq_{arch}"))?;
    let (b, s, v) = (m.batch_eval, m.model.seq_len, m.model.vocab_size);

    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in instances.chunks(b) {
        let mut tokens = vec![SEP; b * s];
        let mut read_pos = vec![0usize; b];
        for (r, inst) in chunk.iter().enumerate() {
            let ctx = &inst.context[..inst.context.len().min(s)];
            tokens[r * s..r * s + ctx.len()].copy_from_slice(ctx);
            read_pos[r] = ctx.len() - 1;
        }
        let mut inputs: Vec<HostValue> =
            params.iter().cloned().map(HostValue::F32).collect();
        inputs.push(HostValue::tokens(&[b, s], tokens));
        inputs.push(HostValue::scalar(levels_for_bits(a_bits)));
        inputs.push(HostValue::scalar(levels_for_bits(kv_bits)));
        inputs.push(HostValue::scalar(had_flag));
        let out = logitsq.run(&inputs)?;
        let logits = out[0].as_f32()?;
        for (r, inst) in chunk.iter().enumerate() {
            let base = (r * s + read_pos[r]) * v;
            let row = &logits.data()[base..base + v];
            let best = inst
                .options
                .iter()
                .enumerate()
                .max_by(|(_, &x), (_, &y)| {
                    row[x as usize].total_cmp(&row[y as usize])
                })
                .map(|(i, _)| i)
                .unwrap();
            if best == inst.answer {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Grammar-document prefixes for decode checks: `n` prompts of
/// `prompt_len` tokens drawn from the language the model was trained on.
pub fn grammar_prompts(g: &Grammar, n: usize, prompt_len: usize,
                       seed: u64) -> Vec<Vec<i32>> {
    assert!(prompt_len > 0);
    let mut out = Vec::with_capacity(n);
    let mut doc_idx = 0u64;
    while out.len() < n {
        let mut rng = Pcg::new(seed ^ 0xDEC0DE, doc_idx);
        doc_idx += 1;
        let mut doc = g.document(&mut rng);
        doc.truncate(prompt_len);
        while doc.len() < prompt_len {
            doc.push(SEP);
        }
        out.push(doc);
    }
    out
}

/// Outcome of [`generation_consistency`].
#[derive(Clone, Copy, Debug)]
pub struct ConsistencyReport {
    pub prompts: usize,
    pub tokens: usize,
    pub mismatches: usize,
}

impl ConsistencyReport {
    pub fn agreement(&self) -> f64 {
        if self.tokens == 0 {
            return 1.0;
        }
        (self.tokens - self.mismatches) as f64 / self.tokens as f64
    }
}

/// Generation-consistency check: greedy-decode the same grammar prompts
/// on the packed model and on its dense-f32 twin
/// ([`InferModel::dequantized`]) under identical runtime bits, and count
/// token mismatches. The packed kernels and quantized KV cache are
/// bit-identical to the dense path by construction, so any mismatch is
/// an engine bug — `osp generate --check` and the property tests gate on
/// zero.
pub fn generation_consistency(packed: &InferModel, g: &Grammar, n_prompts: usize,
                              prompt_len: usize, max_new: usize,
                              a_bits: u32, kv_bits: u32, seed: u64,
                              pool: Option<&ThreadPool>)
                              -> ConsistencyReport {
    let dense = packed.dequantized();
    let prompts = grammar_prompts(g, n_prompts, prompt_len, seed);
    let params = DecodeParams::greedy(a_bits, kv_bits,
                                      n_prompts.max(1));
    // Grammar prompts are vocab-valid by construction, so decode errors
    // here are engine bugs, not input errors.
    let a = engine::generate(packed, &prompts, max_new, params, pool)
        .expect("packed decode");
    let b = engine::generate(&dense, &prompts, max_new, params, pool)
        .expect("dense decode");
    let mut tokens = 0usize;
    let mut mismatches = 0usize;
    for (x, y) in a.iter().zip(&b) {
        tokens += x.len().max(y.len());
        mismatches += x
            .iter()
            .zip(y)
            .filter(|(p, q)| p != q)
            .count()
            + x.len().abs_diff(y.len());
    }
    ConsistencyReport { prompts: prompts.len(), tokens, mismatches }
}

/// Run the full 10-task suite; returns (task, accuracy) pairs + average.
pub fn run_suite(engine: &Engine, arch: &str, params: &[Tensor],
                 n_per_task: usize, a_bits: u32, kv_bits: u32,
                 had_flag: f32, seed: u64) -> Result<(Vec<(String, f64)>, f64)> {
    let m = engine.manifest();
    // Tasks must be posed in the language the model was trained on.
    let g = Grammar::new(m.model.vocab_size,
                         crate::data::grammar::LANGUAGE_SEED);
    let mut rows = Vec::new();
    let mut sum = 0.0;
    for task in TASK_NAMES {
        let instances = generate(&g, task, n_per_task, seed);
        let acc = accuracy(engine, arch, params, &instances, a_bits,
                           kv_bits, had_flag)?;
        sum += acc;
        rows.push((task.to_string(), acc));
    }
    Ok((rows, sum / TASK_NAMES.len() as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grammar() -> Grammar {
        Grammar::new(512, 42)
    }

    #[test]
    fn all_tasks_generate_valid_instances() {
        let g = grammar();
        for task in TASK_NAMES {
            let instances = generate(&g, task, 20, 7);
            assert_eq!(instances.len(), 20);
            for inst in &instances {
                assert!(inst.answer < N_OPTIONS);
                assert!(inst.context.len() >= 2);
                assert!(inst.context.len() < 64, "{task} context too long");
                // options distinct
                let mut o = inst.options.to_vec();
                o.sort_unstable();
                o.dedup();
                assert_eq!(o.len(), N_OPTIONS, "{task} duplicate options");
                // correct option present at answer index
                for &t in &inst.options {
                    assert!((0..512).contains(&t));
                }
            }
        }
    }

    #[test]
    fn answers_are_shuffled() {
        let g = grammar();
        let instances = generate(&g, "bigram", 100, 9);
        let mut hist = [0usize; N_OPTIONS];
        for i in &instances {
            hist[i.answer] += 1;
        }
        for &h in &hist {
            assert!(h > 5, "answer position biased: {hist:?}");
        }
    }

    #[test]
    fn math_task_is_consistent_with_grammar() {
        let g = grammar();
        for inst in generate(&g, "math", 50, 3) {
            // context: BOS d1 + d2 =
            let a = inst.context[1] - 8;
            let b = inst.context[3] - 8;
            let correct = inst.options[inst.answer] - 8;
            assert_eq!((a + b) % N_DIGITS as i32, correct);
        }
    }

    #[test]
    fn grammar_prompts_are_sized_and_in_vocab() {
        let g = grammar();
        let prompts = grammar_prompts(&g, 6, 9, 3);
        assert_eq!(prompts.len(), 6);
        for p in &prompts {
            assert_eq!(p.len(), 9);
            assert!(p.iter().all(|&t| (0..512).contains(&t)));
        }
        assert_eq!(prompts, grammar_prompts(&g, 6, 9, 3));
    }

    #[test]
    fn packed_kv4_decode_is_consistent_with_dense() {
        use crate::infer::InferConfig;
        let g = Grammar::new(128, 42);
        let cfg = InferConfig { vocab_size: 128, d_model: 32, n_layers: 2,
                                n_heads: 2, d_ff: 48, rope_theta: 10000.0,
                                norm_ss: true, embproj: false };
        let packed = InferModel::synthetic(&cfg, 9).quantized(4);
        let rep = generation_consistency(&packed, &g, 4, 6, 8, 4, 4, 1,
                                         None);
        assert_eq!(rep.mismatches, 0, "agreement {}", rep.agreement());
        assert_eq!(rep.tokens, 4 * 8);
    }

    #[test]
    fn deterministic_generation() {
        let g = grammar();
        let a = generate(&g, "recall", 10, 5);
        let b = generate(&g, "recall", 10, 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.options, y.options);
        }
    }
}
