//! Engine-free evaluation on the host model layer (DESIGN.md §9):
//! held-out perplexity and the 10-task suite computed by teacher-forced
//! [`InferModel::forward_block`] passes straight off packed weights — no
//! PJRT executables, no `dense_params()` materialization — so
//! `osp eval` and `osp repro table2` work offline on the stub runtime.
//! Teacher-forced chunks ride the §10 microkernels end to end: weight
//! matmuls decode through the byte LUTs and attention block-dequantizes
//! each cached KV row once per `--eval-chunk` block (instead of once
//! per evaluated position), so large-chunk eval is where the
//! block-dequant win is biggest.
//!
//! Semantics mirror the evalq/logitsq graphs (`python/compile/model.py`):
//! the same held-out [`TokenStream`] (seed [`VALID_STREAM_SEED`], Valid
//! split), next-token NLL over positions `0..seq_len-1` predicting
//! `tokens[1..]`, the per-token activation/KV fake-quant taps, and
//! residual-stream excess kurtosis at the MHSA/FFN inputs
//! ([`KurtProbe`], one probe per batch averaged across batches — the
//! engine path's `mean_vecs` combine). The logitsq-style task accuracy
//! scores each multiple-choice option by the logit at the context's
//! final position.
//!
//! Determinism: per-sequence NLL accumulates in ascending position order
//! regardless of `chunk`, so the result is invariant to the prefill
//! chunking (logits themselves are bit-identical across chunk sizes —
//! the block-forward parity contract).

use anyhow::{bail, Result};

use crate::coordinator::checked_levels_for_bits;
use crate::data::grammar::{Grammar, LANGUAGE_SEED};
use crate::data::{Split, TokenStream};
use crate::model::kv::SeqKv;
use crate::model::{InferModel, KurtProbe, LogitsMode, SeqBlock};
use crate::util::threadpool::ThreadPool;

use super::tasks::{self, Instance};
use super::PplResult;

/// Document-sampling seed of the engine path's held-out stream
/// (`eval::perplexity`); the host path reads the identical data.
pub const VALID_STREAM_SEED: u64 = 0xE7A1;

/// Default teacher-forcing block size (`--eval-chunk`).
pub const DEFAULT_EVAL_CHUNK: usize = 64;

/// Shape of one host evaluation pass.
#[derive(Clone, Copy, Debug)]
pub struct HostEvalOpts {
    /// Activation fake-quant bits (16 = off).
    pub a_bits: u32,
    /// KV-cache bits (16 = f32 passthrough).
    pub kv_bits: u32,
    /// Sequences per held-out batch.
    pub batch: usize,
    /// Tokens per sequence (must be >= 2 for next-token targets).
    pub seq_len: usize,
    /// Held-out batches to score.
    pub n_batches: usize,
    /// Teacher-forcing block size (results are chunk-invariant).
    pub chunk: usize,
}

impl HostEvalOpts {
    pub fn new(a_bits: u32, kv_bits: u32) -> HostEvalOpts {
        HostEvalOpts { a_bits, kv_bits, batch: 4, seq_len: 64,
                       n_batches: 2, chunk: DEFAULT_EVAL_CHUNK }
    }
}

/// -log softmax(row)[target], accumulated like the graph's
/// `log_softmax` + `take_along_axis` (f32 reduction, f64 result).
fn nll_pick(row: &[f32], target: usize) -> f64 {
    let m = row.iter().cloned().fold(f32::MIN, f32::max);
    let mut z = 0.0f32;
    for &v in row {
        z += (v - m).exp();
    }
    (z as f64).ln() - (row[target] - m) as f64
}

/// Held-out perplexity of a host model under runtime activation/KV
/// quantization — the engine-free counterpart of [`super::perplexity`].
/// Weights stay in whatever representation the model carries (packed
/// leaves are never dequantized).
pub fn perplexity_host(model: &InferModel, opts: &HostEvalOpts,
                       pool: Option<&ThreadPool>) -> Result<PplResult> {
    checked_levels_for_bits(opts.a_bits)?;
    checked_levels_for_bits(opts.kv_bits)?;
    if opts.batch == 0 || opts.n_batches == 0 {
        bail!("host eval needs batch >= 1 and n_batches >= 1");
    }
    if opts.seq_len < 2 {
        bail!("host eval needs seq_len >= 2 (next-token targets)");
    }
    let (b, s) = (opts.batch, opts.seq_len);
    let chunk = opts.chunk.max(1);
    let mut valid = TokenStream::new(model.cfg.vocab_size,
                                     VALID_STREAM_SEED, Split::Valid, 0, 1);
    // One probe per batch, averaged across batches — the engine path's
    // `mean_vecs` semantics (PR 3's telemetry fix), and it bounds probe
    // memory to a single batch's activations.
    let mut kurt_sum = vec![0.0f64; 2 * model.cfg.n_layers];
    let mut nll = 0.0f64;
    let mut count = 0.0f64;
    for bi in 0..opts.n_batches {
        let mut probe = KurtProbe::new(model.cfg.n_layers);
        let batch = valid.next_batch(b, s, bi as u64);
        let rows: Vec<&[i32]> = (0..b)
            .map(|r| &batch.tokens[r * s..(r + 1) * s])
            .collect();
        let mut caches: Vec<SeqKv> =
            (0..b).map(|_| model.new_cache(opts.kv_bits)).collect();
        // Per-sequence sums accumulate in ascending position order, so
        // the total is independent of the chunking.
        let mut seq_nll = vec![0.0f64; b];
        let mut c0 = 0usize;
        while c0 < s {
            let c1 = (c0 + chunk).min(s);
            let n = c1 - c0;
            let logits = {
                let mut blocks: Vec<SeqBlock> = rows
                    .iter()
                    .zip(caches.iter_mut())
                    .map(|(row, cache)| SeqBlock {
                        tokens: &row[c0..c1],
                        cache,
                    })
                    .collect();
                model
                    .forward_block(pool, &mut blocks, opts.a_bits,
                                   LogitsMode::All, Some(&mut probe))?
                    .expect("All mode returns logits")
            };
            for (r, snll) in seq_nll.iter_mut().enumerate() {
                for t in 0..n {
                    let pos = c0 + t;
                    if pos + 1 >= s {
                        continue; // the last position has no target
                    }
                    *snll += nll_pick(logits.row(r * n + t),
                                      rows[r][pos + 1] as usize);
                }
            }
            c0 = c1;
        }
        for v in seq_nll {
            nll += v;
        }
        count += (b * (s - 1)) as f64;
        for (acc, k) in kurt_sum.iter_mut().zip(probe.kurt()) {
            *acc += k;
        }
    }
    let kurt: Vec<f64> = kurt_sum
        .iter()
        .map(|v| v / opts.n_batches as f64)
        .collect();
    let per_tok = nll / count;
    let kmax = kurt.iter().cloned().fold(f64::MIN, f64::max);
    let kmean = kurt.iter().sum::<f64>() / kurt.len().max(1) as f64;
    // Perplexities explode under aggressive quantization (the paper's 1e5
    // cells); clamp the exponent to keep the number printable.
    let ppl = per_tok.min(60.0).exp();
    Ok(PplResult { ppl, nll_per_token: per_tok, kurt_max: kmax,
                   kurt_mean: kmean })
}

/// Accuracy of the host model on pre-generated MC instances: every
/// context runs as one sequence of a single block forward, and the
/// option with the highest last-position logit wins — exactly the
/// logitsq scoring rule (padding after the context cannot affect
/// causal positions, so feeding the bare context is equivalent).
pub fn accuracy_host(model: &InferModel, instances: &[Instance],
                     a_bits: u32, kv_bits: u32,
                     pool: Option<&ThreadPool>) -> Result<f64> {
    checked_levels_for_bits(a_bits)?;
    checked_levels_for_bits(kv_bits)?;
    if instances.is_empty() {
        return Ok(0.0);
    }
    let mut caches: Vec<SeqKv> = instances
        .iter()
        .map(|_| model.new_cache(kv_bits))
        .collect();
    let logits = {
        let mut blocks: Vec<SeqBlock> = instances
            .iter()
            .zip(caches.iter_mut())
            .map(|(inst, cache)| SeqBlock { tokens: &inst.context[..],
                                            cache })
            .collect();
        model
            .forward_block(pool, &mut blocks, a_bits, LogitsMode::Last,
                           None)?
            .expect("Last mode returns logits")
    };
    let mut correct = 0usize;
    for (r, inst) in instances.iter().enumerate() {
        let row = logits.row(r);
        let best = inst
            .options
            .iter()
            .enumerate()
            .max_by(|(_, &x), (_, &y)| {
                row[x as usize].total_cmp(&row[y as usize])
            })
            .map(|(i, _)| i)
            .unwrap();
        if best == inst.answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / instances.len() as f64)
}

/// The 10-task suite on the host model layer; returns (task, accuracy)
/// pairs + average — the engine-free counterpart of
/// [`tasks::run_suite`].
pub fn run_suite_host(model: &InferModel, n_per_task: usize, a_bits: u32,
                      kv_bits: u32, seed: u64,
                      pool: Option<&ThreadPool>)
                      -> Result<(Vec<(String, f64)>, f64)> {
    // Tasks must be posed in the language the model was trained on.
    let g = Grammar::new(model.cfg.vocab_size, LANGUAGE_SEED);
    let mut rows = Vec::new();
    let mut sum = 0.0;
    for task in tasks::TASK_NAMES {
        let instances = tasks::generate(&g, task, n_per_task, seed);
        let acc = accuracy_host(model, &instances, a_bits, kv_bits, pool)?;
        sum += acc;
        rows.push((task.to_string(), acc));
    }
    Ok((rows, sum / tasks::TASK_NAMES.len() as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::InferConfig;

    fn tiny_model() -> InferModel {
        let cfg = InferConfig { vocab_size: 128, d_model: 32, n_layers: 2,
                                n_heads: 2, d_ff: 48, rope_theta: 10000.0,
                                norm_ss: true, embproj: false };
        InferModel::synthetic(&cfg, 13)
    }

    #[test]
    fn perplexity_host_is_finite_and_validates() {
        let m = tiny_model();
        let mut opts = HostEvalOpts::new(16, 16);
        opts.batch = 2;
        opts.seq_len = 24;
        opts.n_batches = 1;
        let p = perplexity_host(&m, &opts, None).unwrap();
        assert!(p.ppl.is_finite() && p.ppl > 1.0, "ppl {}", p.ppl);
        assert!(p.kurt_max.is_finite());
        // Degenerate shapes are rejected, not paniced on.
        let bad = HostEvalOpts { seq_len: 1, ..opts };
        assert!(perplexity_host(&m, &bad, None).is_err());
        let bad = HostEvalOpts { a_bits: 1, ..opts };
        assert!(perplexity_host(&m, &bad, None).is_err());
    }

    #[test]
    fn perplexity_host_packed_matches_dense_twin() {
        let packed = tiny_model().quantized(4);
        let dense = packed.dequantized();
        let mut opts = HostEvalOpts::new(4, 4);
        opts.batch = 2;
        opts.seq_len = 20;
        opts.n_batches = 1;
        let a = perplexity_host(&packed, &opts, None).unwrap();
        let b = perplexity_host(&dense, &opts, None).unwrap();
        assert_eq!(a.nll_per_token, b.nll_per_token);
        assert_eq!(a.ppl, b.ppl);
    }

    #[test]
    fn run_suite_host_covers_all_tasks() {
        let m = tiny_model();
        let (rows, avg) = run_suite_host(&m, 4, 16, 16, 3, None).unwrap();
        assert_eq!(rows.len(), tasks::TASK_NAMES.len());
        for (task, acc) in &rows {
            assert!((0.0..=1.0).contains(acc), "{task}: {acc}");
        }
        assert!((0.0..=1.0).contains(&avg));
    }
}
