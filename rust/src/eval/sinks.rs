//! Attention-sink and massive-activation analysis (paper §5.2, Figures
//! 5 & 6): do sinks persist without outliers, and through which logit
//! strategy?
//!
//! Works on the probe executable's captures: residual streams (massive-
//! activation detection via the Bondarenko 6-sigma criterion), per-head
//! q/k channel magnitudes (Fig 5), and raw attention logits (Fig 6's
//! sink-vs-rest distributions).

use anyhow::Result;

use crate::runtime::{Engine, HostValue};
use crate::tensor::{par, stats};
use crate::tensor::Tensor;

/// Per-head sink diagnostics for one probed layer.
#[derive(Clone, Debug)]
pub struct HeadSink {
    pub layer: usize,
    pub head: usize,
    /// Mean attention probability mass on position 0 (BOS) over queries.
    pub sink_mass: f64,
    /// Mean raw logit toward position 0 vs mean logit elsewhere.
    pub sink_logit_mean: f64,
    pub other_logit_mean: f64,
    pub other_logit_std: f64,
}

/// Whole-model analysis output.
#[derive(Clone, Debug)]
pub struct SinkReport {
    pub heads: Vec<HeadSink>,
    /// Fraction of residual-stream activations beyond 6 sigma.
    pub massive_fraction_mhsa: f64,
    pub massive_fraction_ffn: f64,
    /// Residual-stream excess kurtosis (max over probed layers).
    pub kurt_max: f64,
    /// Channel concentration of q/k magnitudes: max/mean ratio per probed
    /// layer-head, averaged (Fig 5: Adam concentrated, OSP diffuse).
    pub qk_concentration: f64,
}

impl SinkReport {
    /// Heads with sink mass above `thresh` (Gu et al.-style filter).
    pub fn sink_heads(&self, thresh: f64) -> Vec<&HeadSink> {
        self.heads.iter().filter(|h| h.sink_mass > thresh).collect()
    }
}

/// Run the probe and analyze sinks / massive activations.
pub fn analyze(engine: &Engine, arch: &str, params: &[Tensor],
               tokens: HostValue) -> Result<SinkReport> {
    let m = engine.manifest();
    let probe = engine.load(&format!("probe_{arch}"))?;
    let (b, s) = (m.batch_probe, m.model.seq_len);
    let (nh, d) = (m.model.n_heads, m.model.d_model);
    let hd = d / nh;
    let probe_layers = m.probe_layers.clone();

    let mut inputs: Vec<HostValue> =
        params.iter().cloned().map(HostValue::F32).collect();
    inputs.push(tokens);
    let out = probe.run(&inputs)?;
    let kurt = out[0].as_f32()?;
    let mhsa_in = out[1].as_f32()?;
    let ffn_in = out[2].as_f32()?;
    let q_mag = out[3].as_f32()?;
    let k_mag = out[4].as_f32()?;
    let attn_logits = out[5].as_f32()?;

    // Layer x head cells are independent reads of the probe captures:
    // scatter one job per head over the shared pool, collecting in
    // (layer, head) order.
    let lstride = b * nh * s * s;
    let cells: Vec<(usize, usize, usize)> = probe_layers
        .iter()
        .enumerate()
        .flat_map(|(pi, &layer)| (0..nh).map(move |h| (pi, layer, h)))
        .collect();
    let heads = par::par_map(
        par::active_pool(), &cells, |_, &(pi, layer, h)| {
            let mut sink_mass = 0.0f64;
            let mut sink_logits = Vec::new();
            let mut other_logits = Vec::new();
            for bb in 0..b {
                let off = pi * lstride + (bb * nh + h) * s * s;
                let logits = &attn_logits.data()[off..off + s * s];
                for q in 1..s {
                    let row = &logits[q * s..q * s + q + 1]; // causal prefix
                    // softmax over the prefix
                    let mx = row.iter().cloned().fold(f32::MIN, f32::max);
                    let exps: Vec<f32> =
                        row.iter().map(|&v| (v - mx).exp()).collect();
                    let z: f32 = exps.iter().sum();
                    sink_mass += (exps[0] / z) as f64;
                    sink_logits.push(row[0]);
                    other_logits.extend_from_slice(&row[1..]);
                }
            }
            let n_q = (b * (s - 1)) as f64;
            let sm = stats::moments(&sink_logits);
            let om = stats::moments(&other_logits);
            HeadSink {
                layer,
                head: h,
                sink_mass: sink_mass / n_q,
                sink_logit_mean: sm.mean,
                other_logit_mean: om.mean,
                other_logit_std: om.var.sqrt(),
            }
        });

    // q/k channel concentration: max |channel| / mean |channel|.
    let mut conc = Vec::new();
    let hstride = b * nh * hd;
    for pi in 0..probe_layers.len() {
        for mag in [q_mag, k_mag] {
            let data = &mag.data()[pi * hstride..(pi + 1) * hstride];
            for bh in 0..b * nh {
                let ch = &data[bh * hd..(bh + 1) * hd];
                let mx = ch.iter().cloned().fold(0.0f32, f32::max) as f64;
                let mean =
                    ch.iter().map(|&v| v as f64).sum::<f64>() / hd as f64;
                if mean > 1e-9 {
                    conc.push(mx / mean);
                }
            }
        }
    }
    let qk_concentration =
        conc.iter().sum::<f64>() / conc.len().max(1) as f64;

    Ok(SinkReport {
        heads,
        massive_fraction_mhsa:
            stats::Histogram::outlier_fraction(mhsa_in.data(), 6.0),
        massive_fraction_ffn:
            stats::Histogram::outlier_fraction(ffn_in.data(), 6.0),
        kurt_max: kurt.data().iter().cloned().fold(f32::MIN, f32::max)
            as f64,
        qk_concentration,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_heads_filter() {
        let mk = |mass| HeadSink {
            layer: 0,
            head: 0,
            sink_mass: mass,
            sink_logit_mean: 0.0,
            other_logit_mean: 0.0,
            other_logit_std: 1.0,
        };
        let report = SinkReport {
            heads: vec![mk(0.1), mk(0.5), mk(0.9)],
            massive_fraction_mhsa: 0.0,
            massive_fraction_ffn: 0.0,
            kurt_max: 0.0,
            qk_concentration: 1.0,
        };
        assert_eq!(report.sink_heads(0.3).len(), 2);
    }
}
