//! Evaluation: held-out perplexity under any quantization configuration,
//! the 10-task synthetic benchmark suite, and attention-sink analysis.
//!
//! Two execution paths share the same semantics: the PJRT engine path
//! ([`perplexity`], [`tasks::run_suite`] — needs compiled evalq/logitsq
//! artifacts) and the engine-free host path ([`host`] — teacher-forced
//! [`crate::model::InferModel::forward_block`] passes straight off
//! packed weights). [`perplexity_packed`] routes packed models to the
//! host path, so `osp eval` / `osp repro table2` run offline; the engine
//! path stays available behind [`perplexity_packed_engine`] for parity
//! tests on builds with the real runtime.

pub mod host;
pub mod sinks;
pub mod tasks;

use anyhow::{bail, Result};

use crate::coordinator::{checked_levels_for_bits, levels_for_bits,
                         MIN_QUANT_BITS};
use crate::data::{Split, TokenStream};
use crate::quant::QuantizedModel;
use crate::runtime::{Engine, HostValue};
use crate::tensor::{par, Tensor};

pub use host::{accuracy_host, perplexity_host, run_suite_host,
               HostEvalOpts};

/// A `w-a-kv` bit configuration (paper notation; 16 = off). The weight
/// bits are applied by `quant::prepare` before calling these helpers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitConfig {
    pub w: u32,
    pub a: u32,
    pub kv: u32,
}

impl BitConfig {
    pub const FP: BitConfig = BitConfig { w: 16, a: 16, kv: 16 };

    pub fn new(w: u32, a: u32, kv: u32) -> BitConfig {
        BitConfig { w, a, kv }
    }

    pub fn label(&self) -> String {
        format!("{}-{}-{}", self.w, self.a, self.kv)
    }

    /// Reject bit-widths without a symmetric integer grid (0/1 bits used
    /// to panic or poison the evalq graph with 0 levels). Anything >= 2
    /// is accepted; 16+ means "off" on that axis.
    pub fn validate(&self) -> Result<()> {
        for (axis, bits) in [("w", self.w), ("a", self.a), ("kv", self.kv)]
        {
            if bits < MIN_QUANT_BITS {
                bail!("{axis}-bits {bits} unsupported: quantization needs \
                       at least {MIN_QUANT_BITS} bits (16+ = off)");
            }
        }
        Ok(())
    }

    /// The paper's Table-2 columns.
    pub fn table2_columns() -> Vec<BitConfig> {
        vec![
            BitConfig::new(16, 16, 16),
            BitConfig::new(4, 8, 16),
            BitConfig::new(4, 8, 8),
            BitConfig::new(4, 4, 16),
            BitConfig::new(4, 4, 4),
        ]
    }
}

/// Evaluation outcome.
#[derive(Clone, Debug)]
pub struct PplResult {
    pub ppl: f64,
    pub nll_per_token: f64,
    pub kurt_max: f64,
    pub kurt_mean: f64,
}

/// Held-out perplexity with runtime activation/KV quantization.
/// `had_flag` must match the weight preparation (quant::prepare).
pub fn perplexity(engine: &Engine, arch: &str, params: &[Tensor],
                  a_bits: u32, kv_bits: u32, had_flag: f32,
                  n_batches: usize) -> Result<PplResult> {
    // Reject grid-less bit-widths here, not just in the CLI — library
    // callers would otherwise get silently clamped levels.
    checked_levels_for_bits(a_bits)?;
    checked_levels_for_bits(kv_bits)?;
    let m = engine.manifest();
    let evalq = engine.load(&format!("evalq_{arch}"))?;
    let (b, s) = (m.batch_eval, m.model.seq_len);
    let mut valid = TokenStream::new(m.model.vocab_size,
                                     host::VALID_STREAM_SEED, Split::Valid,
                                     0, 1);
    let mut nll = 0.0f64;
    let mut count = 0.0f64;
    // Like the Host/DP trainer fix: kurt telemetry averages over every
    // batch instead of keeping whichever ran last.
    let mut kurt_batches: Vec<Vec<f32>> = Vec::new();
    for i in 0..n_batches {
        let batch = valid.next_batch(b, s, i as u64);
        let mut inputs: Vec<HostValue> =
            params.iter().cloned().map(HostValue::F32).collect();
        inputs.push(HostValue::tokens(&[b, s], batch.tokens));
        inputs.push(HostValue::scalar(levels_for_bits(a_bits)));
        inputs.push(HostValue::scalar(levels_for_bits(kv_bits)));
        inputs.push(HostValue::scalar(had_flag));
        let out = evalq.run(&inputs)?;
        nll += out[0].as_f32()?.data()[0] as f64;
        count += out[1].as_f32()?.data()[0] as f64;
        kurt_batches.push(out[2].as_f32()?.data().to_vec());
    }
    let kurt = crate::coordinator::mean_vecs(&kurt_batches);
    let per_tok = nll / count;
    let kmax = kurt.iter().cloned().fold(f32::MIN, f32::max) as f64;
    let kmean = kurt.iter().sum::<f32>() as f64 / kurt.len().max(1) as f64;
    // Perplexities explode under aggressive quantization (the paper's 1e5
    // cells); clamp the exponent to keep the number printable.
    let ppl = per_tok.min(60.0).exp();
    Ok(PplResult { ppl, nll_per_token: per_tok, kurt_max: kmax,
                   kurt_mean: kmean })
}

/// Held-out perplexity of a packed quantized model, evaluated on the
/// engine-free host path: the packed leaves are served directly by the
/// block forward (`dense_params()` is never called), so this works
/// offline on the stub runtime. The engine is only consulted for its
/// manifest (eval batch shape, `n_heads`, `rope_theta`).
pub fn perplexity_packed(engine: &Engine, qm: &QuantizedModel, a_bits: u32,
                         kv_bits: u32, n_batches: usize) -> Result<PplResult> {
    let m = engine.manifest();
    let model = qm.decoder(m.model.n_heads, m.model.rope_theta as f32)?;
    let opts = HostEvalOpts { a_bits, kv_bits, batch: m.batch_eval,
                              seq_len: m.model.seq_len, n_batches,
                              chunk: host::DEFAULT_EVAL_CHUNK };
    perplexity_host(&model, &opts, par::shared_pool())
}

/// The pre-host behavior of [`perplexity_packed`]: dequantize the packed
/// leaves once (`dense_params`) and run the compiled evalq executable.
/// Kept for engine-vs-host parity tests on builds with the real PJRT
/// runtime; fails fast on the offline stub.
pub fn perplexity_packed_engine(engine: &Engine, qm: &QuantizedModel,
                                a_bits: u32, kv_bits: u32,
                                n_batches: usize) -> Result<PplResult> {
    perplexity(engine, &qm.arch, qm.dense_params(), a_bits, kv_bits,
               qm.had_flag, n_batches)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitconfig_labels() {
        assert_eq!(BitConfig::new(4, 4, 4).label(), "4-4-4");
        assert_eq!(BitConfig::FP.label(), "16-16-16");
        assert_eq!(BitConfig::table2_columns().len(), 5);
    }

    #[test]
    fn bitconfig_validation() {
        assert!(BitConfig::new(4, 4, 4).validate().is_ok());
        assert!(BitConfig::FP.validate().is_ok());
        assert!(BitConfig::new(0, 4, 4).validate().is_err());
        assert!(BitConfig::new(4, 1, 4).validate().is_err());
        assert!(BitConfig::new(4, 4, 1).validate().is_err());
        for c in BitConfig::table2_columns() {
            assert!(c.validate().is_ok(), "{}", c.label());
        }
    }
}
