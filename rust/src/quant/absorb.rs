//! EmbProj absorption (paper Section 3.3): the learnable embedding
//! projections are linear maps adjacent to the embedding/unembedding, so
//! after training they fold into their neighbors with exact computational
//! invariance:
//!
//!   embed' = embed @ P_in        unembed' = P_out @ unembed
//!
//! turning an `*_embproj` checkpoint into the corresponding plain
//! architecture. The integration suite verifies invariance through the
//! real evalq executables.

use anyhow::{anyhow, Result};

use crate::runtime::manifest::ParamSpec;
use crate::tensor::linalg::matmul;
use crate::tensor::Tensor;

/// Fold embproj_in/out into embed/unembed. Inputs are the embproj arch's
/// (specs, params); returns params ordered for the matching plain arch
/// specs (same list minus the embproj leaves).
pub fn absorb_embproj(specs: &[ParamSpec], params: &[Tensor])
                      -> Result<Vec<Tensor>> {
    assert_eq!(specs.len(), params.len());
    let idx = |name: &str| -> Result<usize> {
        specs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("param '{name}' not found"))
    };
    let p_in = &params[idx("embproj_in")?];
    let p_out = &params[idx("embproj_out")?];
    let embed = &params[idx("embed")?];
    let unembed = &params[idx("unembed")?];

    // Each product row-blocks across the whole shared pool inside
    // matmul; running them back-to-back beats a 2-job scatter, which
    // would pin each product to a single worker (nested-dispatch guard).
    let new_embed = matmul(embed, p_in);
    let new_unembed = matmul(p_out, unembed);

    let mut out = Vec::with_capacity(specs.len() - 2);
    for (s, p) in specs.iter().zip(params) {
        match s.name.as_str() {
            "embproj_in" | "embproj_out" => {}
            "embed" => out.push(new_embed.clone()),
            "unembed" => out.push(new_unembed.clone()),
            _ => out.push(p.clone()),
        }
    }
    Ok(out)
}

/// The plain-arch name for an embproj arch ("ssnorm_embproj" ->
/// "ssnorm_plain").
pub fn plain_arch(arch: &str) -> Option<String> {
    arch.strip_suffix("_embproj").map(|base| format!("{base}_plain"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn spec(name: &str, shape: &[usize], kind: &str) -> ParamSpec {
        ParamSpec { name: name.into(), shape: shape.to_vec(),
                    init: "normal".into(), kind: kind.into() }
    }

    #[test]
    fn absorb_drops_projections_and_composes() {
        let mut rng = Pcg::new(1, 0);
        let mut randn = |shape: &[usize]| {
            let mut t = Tensor::zeros(shape);
            rng.fill_normal(t.data_mut(), 1.0);
            t
        };
        let specs = vec![
            spec("embed", &[10, 4], "embed"),
            spec("embproj_in", &[4, 4], "matrix"),
            spec("embproj_out", &[4, 4], "matrix"),
            spec("layers.0.wq", &[4, 4], "matrix"),
            spec("unembed", &[4, 10], "unembed"),
        ];
        let params: Vec<Tensor> =
            specs.iter().map(|s| randn(&s.shape)).collect();
        let absorbed = absorb_embproj(&specs, &params).unwrap();
        assert_eq!(absorbed.len(), 3);
        // embed' = embed @ p_in
        let want = matmul(&params[0], &params[1]);
        crate::util::prop::all_close(absorbed[0].data(), want.data(), 1e-6)
            .unwrap();
        // unembed' = p_out @ unembed
        let want_u = matmul(&params[2], &params[4]);
        crate::util::prop::all_close(absorbed[2].data(), want_u.data(), 1e-6)
            .unwrap();
        // middle weight untouched
        assert_eq!(absorbed[1].data(), params[3].data());
    }

    #[test]
    fn plain_arch_names() {
        assert_eq!(plain_arch("ssnorm_embproj").as_deref(),
                   Some("ssnorm_plain"));
        assert_eq!(plain_arch("rmsnorm_embproj").as_deref(),
                   Some("rmsnorm_plain"));
        assert_eq!(plain_arch("rmsnorm_plain"), None);
    }

    #[test]
    fn missing_projection_errors() {
        let specs = vec![spec("embed", &[4, 2], "embed")];
        let params = vec![Tensor::zeros(&[4, 2])];
        assert!(absorb_embproj(&specs, &params).is_err());
    }
}
