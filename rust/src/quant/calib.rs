//! GPTQ calibration: reconstruct per-linear input activations from the
//! probe executable's captures and accumulate Hessians H = X^T X.
//!
//! The probe artifact returns the raw residual-stream inputs (mhsa_in,
//! ffn_in) and attention logits at the probed layers; everything else a
//! linear layer consumes (post-norm h, the attention output, the FFN
//! hidden state) is recomputed host-side from the checkpoint weights.
//! Layers that are not probed borrow the Hessian of the nearest probed
//! layer (DESIGN.md §5 documents this substitution).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::data::{Split, TokenStream};
use crate::runtime::{Engine, HostValue};
use crate::tensor::linalg::{matmul, transpose};
use crate::tensor::Tensor;

/// Per-parameter-name Hessians over the input dimension.
pub type Hessians = BTreeMap<String, Tensor>;

fn rmsnorm_rows(x: &Tensor, scale: &Tensor) -> Tensor {
    let (rows, d) = (x.rows(), x.cols());
    let mut out = x.clone();
    for r in 0..rows {
        let row = out.row_mut(r);
        let ms: f32 =
            row.iter().map(|v| v * v).sum::<f32>() / d as f32 + 1e-6;
        let inv = 1.0 / ms.sqrt();
        if scale.len() == 1 {
            // SSNorm: gamma * x / ||x||_2 == gamma/sqrt(d) * x / rms.
            let g = scale.data()[0] / (d as f32).sqrt();
            for v in row.iter_mut() {
                *v *= inv * g;
            }
        } else {
            for (v, s) in row.iter_mut().zip(scale.data()) {
                *v *= inv * s;
            }
        }
    }
    out
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Accumulate X^T X into the map under `name`.
fn accumulate(hessians: &mut Hessians, name: &str, x: &Tensor) {
    let h = matmul(&transpose(&x.clone().reshape(&[x.rows(), x.cols()])), x);
    match hessians.get_mut(name) {
        Some(acc) => acc.axpy(1.0, &h),
        None => {
            hessians.insert(name.to_string(), h);
        }
    }
}

/// Softmax over the last axis with causal masking, applied to captured
/// attention logits [H, S, S] for one batch element.
fn causal_softmax_rows(logits: &mut [f32], s: usize) {
    for q in 0..s {
        let row = &mut logits[q * s..(q + 1) * s];
        let valid = q + 1;
        let m = row[..valid].iter().cloned().fold(f32::MIN, f32::max);
        let mut z = 0.0f32;
        for v in row[..valid].iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row[..valid].iter_mut() {
            *v /= z;
        }
        for v in row[valid..].iter_mut() {
            *v = 0.0;
        }
    }
}

/// Build calibration Hessians for `arch` at `params` using `n_batches`
/// probe batches of held-out data.
pub fn collect_hessians(engine: &Engine, arch: &str, params: &[Tensor],
                        n_batches: usize) -> Result<Hessians> {
    let m = engine.manifest();
    let specs = m.params(arch)?;
    let probe = engine.load(&format!("probe_{arch}"))?;
    let (b, s) = (m.batch_probe, m.model.seq_len);
    let (d, nh) = (m.model.d_model, m.model.n_heads);
    let hd = d / nh;
    let n_layers = m.model.n_layers;
    let probe_layers = m.probe_layers.clone();

    let by_name: BTreeMap<&str, &Tensor> = specs
        .iter()
        .zip(params)
        .map(|(sp, p)| (sp.name.as_str(), p))
        .collect();
    let get = |name: &str| -> Result<&Tensor> {
        by_name
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("calib: missing param {name}"))
    };

    let mut valid = TokenStream::new(m.model.vocab_size, 0xCA11B, Split::Valid,
                                     0, 1);
    let mut hessians: Hessians = BTreeMap::new();

    for bi in 0..n_batches {
        let batch = valid.next_batch(b, s, bi as u64);
        let mut inputs: Vec<HostValue> =
            params.iter().cloned().map(HostValue::F32).collect();
        inputs.push(HostValue::tokens(&[b, s], batch.tokens));
        let out = probe.run(&inputs)?;
        // outputs: kurt, mhsa_in, ffn_in, q_mag, k_mag, attn_logits
        let mhsa_in = out[1].as_f32()?;
        let ffn_in = out[2].as_f32()?;
        let attn_logits = out[5].as_f32()?;

        for (pi, &layer) in probe_layers.iter().enumerate() {
            let pfx = format!("layers.{layer}.");
            let n = b * s;
            let slice = |t: &Tensor| -> Tensor {
                let stride = b * s * d;
                Tensor::new(vec![n, d],
                            t.data()[pi * stride..(pi + 1) * stride].to_vec())
            };

            // h_attn = norm(mhsa_in): input to wq/wk/wv.
            let h_attn = rmsnorm_rows(&slice(mhsa_in),
                                      get(&format!("{pfx}attn_norm"))?);
            accumulate(&mut hessians, &format!("{pfx}wq"), &h_attn);
            accumulate(&mut hessians, &format!("{pfx}wk"), &h_attn);
            accumulate(&mut hessians, &format!("{pfx}wv"), &h_attn);

            // Attention output = softmax(masked logits) @ v, merged heads:
            // input to wo.
            let v_flat = matmul(&h_attn, get(&format!("{pfx}wv"))?);
            let mut attn_out = Tensor::zeros(&[n, d]);
            let lstride = b * nh * s * s;
            for bb in 0..b {
                for h in 0..nh {
                    let off = pi * lstride + (bb * nh + h) * s * s;
                    let mut probs =
                        attn_logits.data()[off..off + s * s].to_vec();
                    causal_softmax_rows(&mut probs, s);
                    // out[q, :] = sum_k probs[q,k] * v[k, head h]
                    for q in 0..s {
                        for k in 0..=q.min(s - 1) {
                            let p = probs[q * s + k];
                            if p == 0.0 {
                                continue;
                            }
                            for c in 0..hd {
                                let vv = v_flat
                                    .at2(bb * s + k, h * hd + c);
                                let cur =
                                    attn_out.at2(bb * s + q, h * hd + c);
                                attn_out.set2(bb * s + q, h * hd + c,
                                              cur + p * vv);
                            }
                        }
                    }
                }
            }
            accumulate(&mut hessians, &format!("{pfx}wo"), &attn_out);

            // h_ffn = norm(ffn_in): input to w_gate/w_up.
            let h_ffn = rmsnorm_rows(&slice(ffn_in),
                                     get(&format!("{pfx}ffn_norm"))?);
            accumulate(&mut hessians, &format!("{pfx}w_gate"), &h_ffn);
            accumulate(&mut hessians, &format!("{pfx}w_up"), &h_ffn);

            // FFN hidden g = silu(h@w_gate) * (h@w_up): input to w_down.
            let gate = matmul(&h_ffn, get(&format!("{pfx}w_gate"))?);
            let up = matmul(&h_ffn, get(&format!("{pfx}w_up"))?);
            let mut g = up;
            for (gv, xv) in g.data_mut().iter_mut().zip(gate.data()) {
                *gv *= silu(*xv);
            }
            accumulate(&mut hessians, &format!("{pfx}w_down"), &g);
        }
    }

    // Nearest-probe-layer fallback for unprobed layers.
    for layer in 0..n_layers {
        if probe_layers.contains(&layer) {
            continue;
        }
        let nearest = *probe_layers
            .iter()
            .min_by_key(|&&p| (p as i64 - layer as i64).abs())
            .unwrap();
        for w in ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"] {
            let src = format!("layers.{nearest}.{w}");
            if let Some(h) = hessians.get(&src) {
                hessians.insert(format!("layers.{layer}.{w}"), h.clone());
            }
        }
    }
    Ok(hessians)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_rows_unit_rms() {
        let x = Tensor::new(vec![2, 4], vec![2., 2., 2., 2., 1., 0., 0., 0.]);
        let scale = Tensor::full(&[4], 1.0);
        let y = rmsnorm_rows(&x, &scale);
        for r in 0..2 {
            let ms: f32 =
                y.row(r).iter().map(|v| v * v).sum::<f32>() / 4.0;
            assert!((ms - 1.0).abs() < 1e-3, "{ms}");
        }
    }

    #[test]
    fn ssnorm_scalar_path() {
        let x = Tensor::new(vec![1, 4], vec![3., 0., 4., 0.]);
        let gamma = Tensor::new(vec![1], vec![2.0]); // SSNorm gamma
        let y = rmsnorm_rows(&x, &gamma);
        // ||y|| should be gamma = 2
        let n: f32 = y.data().iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!((n - 2.0).abs() < 1e-3, "{n}");
    }

    #[test]
    fn causal_softmax_masks_future() {
        let s = 4;
        let mut logits = vec![0.0f32; s * s];
        causal_softmax_rows(&mut logits, s);
        // Row 0 attends only to position 0.
        assert_eq!(logits[0], 1.0);
        assert_eq!(logits[1], 0.0);
        // Rows sum to 1 over the causal prefix.
        for q in 0..s {
            let sum: f32 = logits[q * s..(q + 1) * s].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn accumulate_sums_gram_matrices() {
        let mut h = Hessians::new();
        let x = Tensor::new(vec![2, 2], vec![1., 0., 0., 1.]);
        accumulate(&mut h, "w", &x);
        accumulate(&mut h, "w", &x);
        assert_eq!(h["w"].at2(0, 0), 2.0);
        assert_eq!(h["w"].at2(0, 1), 0.0);
    }
}
