//! Round-to-nearest weight quantization (the paper's RTN baseline).
//!
//! Weights are stored [in, out] (used as x @ W), so the quantization
//! group is the *output channel* = column: one symmetric scale per
//! column, scale = absmax / (2^(b-1) - 1).

use crate::tensor::qtensor::QTensor;
use crate::tensor::Tensor;

/// levels = 2^(bits-1) - 1 (7 for 4-bit). bits >= 16 means "off".
/// Delegates to the guarded [`crate::coordinator::levels_for_bits`], so
/// degenerate widths (0/1 bits) clamp to the 2-bit grid instead of
/// panicking or yielding 0 levels (inf scales, all-NaN dequant).
pub fn levels(bits: u32) -> Option<f32> {
    if bits >= 16 {
        None
    } else {
        Some(crate::coordinator::levels_for_bits(bits))
    }
}

/// Quantize-dequantize one value against a scale.
#[inline]
fn rtn(v: f32, scale: f32, lv: f32) -> f32 {
    if scale <= 0.0 {
        return 0.0;
    }
    (v / scale).round().clamp(-lv - 1.0, lv) * scale
}

/// The integer code behind [`rtn`]: `rtn(v, s, lv) == code * s` exactly
/// (the rounded value is integral, so the i32 round-trip is lossless).
/// Caveat: a NaN weight maps to code 0 (`NaN as i32` saturates to 0)
/// where the f32 path propagated NaN — the parity contract assumes
/// finite weights, as every trained checkpoint has.
#[inline]
pub(crate) fn rtn_code(v: f32, scale: f32, lv: f32) -> i32 {
    if scale <= 0.0 {
        return 0;
    }
    (v / scale).round().clamp(-lv - 1.0, lv) as i32
}

/// Epsilon folded into every runtime activation/KV scale so an all-zero
/// row still gets a positive scale (`model::kv` re-exports this as
/// `KV_EPS`). One shared constant: the activation tap, the KV cache,
/// and the integer activation quantizer must agree bitwise.
pub const ACT_EPS: f32 = 1e-8;

/// The per-row runtime activation scale shared by every tap site:
/// `absmax / levels + ACT_EPS`. Extracting it here (rather than
/// repeating the fold at each site) is what lets the integer path prove
/// `codes × scale == fake_quant_row` bitwise.
#[inline]
pub fn act_scale(row: &[f32], levels: f32) -> f32 {
    let absmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    absmax / levels + ACT_EPS
}

/// True when every code on the `levels` grid fits an i8. The clamp in
/// [`rtn_code`] bounds codes to `[-levels-1, levels]`, so grids up to
/// 127 levels (A≤8 configs) are exactly i8-representable.
#[inline]
pub fn i8_representable(levels: f32) -> bool {
    levels <= 127.0
}

/// [`levels`] restricted to the i8-representable grids — `Some` exactly
/// when the integer kernel path may serve this activation width.
pub fn int_levels(bits: u32) -> Option<f32> {
    levels(bits).filter(|&lv| i8_representable(lv))
}

/// Quantize one activation row to i8 codes + its scale (the integer
/// half of the runtime tap). `codes[i] as f32 * scale` is bitwise what
/// [`crate::model::ops::fake_quant_row`] writes back — both snap
/// through [`act_scale`] and [`rtn_code`], and the i8 round-trip is
/// lossless for any [`i8_representable`] grid.
pub fn quantize_row_i8(row: &[f32], levels: f32, codes: &mut [i8]) -> f32 {
    assert!(i8_representable(levels),
            "levels {levels} does not fit i8 codes");
    debug_assert_eq!(row.len(), codes.len());
    let scale = act_scale(row, levels);
    for (c, &v) in codes.iter_mut().zip(row) {
        *c = rtn_code(v, scale, levels) as i8;
    }
    scale
}

/// Single-pass per-column absmax over contiguous row slices — the scale
/// pass shared by RTN, GPTQ, and the streaming quant MSE (replaces the
/// bounds-checked per-element `at2` walks each had).
pub fn column_absmax(w: &Tensor) -> Vec<f32> {
    let cols = w.shape()[1];
    let mut absmax = vec![0.0f32; cols];
    if cols == 0 {
        return absmax;
    }
    for row in w.data().chunks_exact(cols) {
        for (m, v) in absmax.iter_mut().zip(row) {
            *m = m.max(v.abs());
        }
    }
    absmax
}

/// Per-output-channel symmetric RTN emitting packed codes directly: the
/// deployment path. `result.dequantize()` is bit-identical to
/// [`quantize_per_channel`] (which is now this + dequantize).
pub fn quantize_per_channel_q(w: &Tensor, bits: u32) -> QTensor {
    let Some(lv) = levels(bits) else {
        return QTensor::from_dense(w);
    };
    let (rows, cols) = (w.shape()[0], w.shape()[1]);
    let scales: Vec<f32> = column_absmax(w).iter().map(|m| m / lv).collect();
    let mut codes = vec![0i32; rows * cols];
    for (wrow, crow) in w.data().chunks_exact(cols.max(1))
        .zip(codes.chunks_exact_mut(cols.max(1)))
    {
        for (j, (&v, c)) in wrow.iter().zip(crow.iter_mut()).enumerate() {
            *c = rtn_code(v, scales[j], lv);
        }
    }
    QTensor::from_codes(w.shape(), bits, &codes, scales)
}

/// Per-output-channel (column) symmetric RTN for a [in, out] matrix
/// (f32 round-trip view of [`quantize_per_channel_q`]).
pub fn quantize_per_channel(w: &Tensor, bits: u32) -> Tensor {
    if bits >= 16 {
        return w.clone();
    }
    quantize_per_channel_q(w, bits).dequantize()
}

/// Per-tensor symmetric RTN (any shape).
pub fn quantize_per_tensor(w: &Tensor, bits: u32) -> Tensor {
    let Some(lv) = levels(bits) else {
        return w.clone();
    };
    let scale = w.abs_max() / lv;
    let mut out = w.clone();
    for v in out.data_mut() {
        *v = rtn(*v, scale, lv);
    }
    out
}

/// Mean squared quantization error (diagnostics + SpinQuant objective).
/// Streams codes in-register — scale pass + error pass over contiguous
/// rows, never materializing the dequantized copy (the rotation search
/// calls this per candidate per param). Arithmetic is identical to
/// diffing against [`quantize_per_channel`], so results match bitwise.
pub fn quant_mse(w: &Tensor, bits: u32) -> f64 {
    let Some(lv) = levels(bits) else {
        return 0.0;
    };
    if w.is_empty() {
        return 0.0;
    }
    let cols = w.shape()[1];
    let scales: Vec<f32> = column_absmax(w).iter().map(|m| m / lv).collect();
    let mut s = 0.0f64;
    for row in w.data().chunks_exact(cols) {
        for (j, &v) in row.iter().enumerate() {
            let q = rtn_code(v, scales[j], lv) as f32 * scales[j];
            let d = (v - q) as f64;
            s += d * d;
        }
    }
    s / w.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed, 4);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn levels_table() {
        assert_eq!(levels(4), Some(7.0));
        assert_eq!(levels(8), Some(127.0));
        assert_eq!(levels(2), Some(1.0));
        assert_eq!(levels(16), None);
        // Degenerate widths clamp instead of panicking / returning 0.
        assert_eq!(levels(0), Some(1.0));
        assert_eq!(levels(1), Some(1.0));
    }

    #[test]
    fn sixteen_bit_is_identity() {
        let w = randn(&[8, 8], 1);
        assert_eq!(quantize_per_channel(&w, 16), w);
    }

    #[test]
    fn error_bounded_by_half_scale() {
        let w = randn(&[32, 16], 2);
        let q = quantize_per_channel(&w, 4);
        for j in 0..16 {
            let absmax = (0..32).map(|i| w.at2(i, j).abs())
                .fold(0.0f32, f32::max);
            let scale = absmax / 7.0;
            for i in 0..32 {
                assert!((w.at2(i, j) - q.at2(i, j)).abs()
                        <= scale / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn grid_size_at_4bit() {
        let w = randn(&[64, 4], 3);
        let q = quantize_per_channel(&w, 4);
        for j in 0..4 {
            let mut vals: Vec<i64> = (0..64)
                .map(|i| {
                    let absmax = (0..64).map(|r| w.at2(r, j).abs())
                        .fold(0.0f32, f32::max);
                    (q.at2(i, j) / (absmax / 7.0)).round() as i64
                })
                .collect();
            vals.sort_unstable();
            vals.dedup();
            assert!(vals.len() <= 16, "{}", vals.len());
        }
    }

    #[test]
    fn outlier_column_wrecks_only_itself() {
        // Per-channel scales isolate an outlier column — unlike per-tensor,
        // where it inflates everyone's scale (the paper's Eq. 1 problem).
        let mut w = randn(&[32, 8], 4);
        for i in 0..32 {
            let v = w.at2(i, 3) * 100.0;
            w.set2(i, 3, v);
        }
        let q_pc = quantize_per_channel(&w, 4);
        let q_pt = quantize_per_tensor(&w, 4);
        let mse_col = |q: &Tensor, j: usize| -> f64 {
            (0..32)
                .map(|i| ((w.at2(i, j) - q.at2(i, j)) as f64).powi(2))
                .sum::<f64>()
        };
        // Non-outlier column 0: per-channel much better than per-tensor.
        assert!(mse_col(&q_pc, 0) < mse_col(&q_pt, 0) / 10.0);
    }

    #[test]
    fn column_absmax_matches_at2_walk() {
        let w = randn(&[13, 7], 9);
        let got = column_absmax(&w);
        for j in 0..7 {
            let want = (0..13).map(|i| w.at2(i, j).abs())
                .fold(0.0f32, f32::max);
            assert_eq!(got[j], want);
        }
    }

    #[test]
    fn code_emitting_rtn_dequantizes_identically() {
        for bits in [2u32, 4, 8] {
            let w = randn(&[17, 9], 20 + bits as u64);
            let q = quantize_per_channel_q(&w, bits);
            assert!(q.is_packed());
            assert_eq!(q.dequantize().data(),
                       quantize_per_channel(&w, bits).data());
        }
        // bits >= 16: dense passthrough, identical to the f32 identity.
        let w = randn(&[5, 4], 30);
        let q = quantize_per_channel_q(&w, 16);
        assert!(!q.is_packed());
        assert_eq!(q.dequantize(), w);
    }

    #[test]
    fn packed_w4_is_at_most_0p3x_dense() {
        let w = randn(&[64, 48], 31);
        let q = quantize_per_channel_q(&w, 4);
        assert!(q.packed_bytes() as f64 <= 0.3 * q.dense_bytes() as f64,
                "{} packed vs {} dense", q.packed_bytes(), q.dense_bytes());
    }

    #[test]
    fn int_levels_gate() {
        assert_eq!(int_levels(4), Some(7.0));
        assert_eq!(int_levels(8), Some(127.0));
        // 9..15-bit grids need codes beyond i8; 16+ is "off".
        assert_eq!(int_levels(9), None);
        assert_eq!(int_levels(16), None);
    }

    #[test]
    fn quantize_row_i8_is_codes_times_scale() {
        let mut rng = Pcg::new(77, 4);
        for bits in [2u32, 4, 8] {
            let lv = levels(bits).unwrap();
            let mut row = vec![0.0f32; 33];
            rng.fill_normal(&mut row, 1.5);
            let mut codes = vec![0i8; row.len()];
            let scale = quantize_row_i8(&row, lv, &mut codes);
            assert_eq!(scale, act_scale(&row, lv));
            for (&v, &c) in row.iter().zip(&codes) {
                assert!((c as f32) >= -lv - 1.0 && (c as f32) <= lv);
                assert_eq!(c as f32 * scale,
                           rtn_code(v, scale, lv) as f32 * scale);
            }
        }
    }

    #[test]
    fn quantize_row_i8_zero_row_has_positive_scale() {
        let row = [0.0f32; 8];
        let mut codes = [0i8; 8];
        let scale = quantize_row_i8(&row, 7.0, &mut codes);
        assert!(scale > 0.0);
        assert!(codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn mse_decreases_with_bits() {
        let w = randn(&[64, 32], 5);
        let e4 = quant_mse(&w, 4);
        let e8 = quant_mse(&w, 8);
        assert!(e8 < e4 / 10.0);
    }
}
