//! Round-to-nearest weight quantization (the paper's RTN baseline).
//!
//! Weights are stored [in, out] (used as x @ W), so the quantization
//! group is the *output channel* = column: one symmetric scale per
//! column, scale = absmax / (2^(b-1) - 1).

use crate::tensor::Tensor;

/// levels = 2^(bits-1) - 1 (7 for 4-bit). bits >= 16 means "off".
pub fn levels(bits: u32) -> Option<f32> {
    if bits >= 16 {
        None
    } else {
        Some(((1u32 << (bits - 1)) - 1) as f32)
    }
}

/// Quantize-dequantize one value against a scale.
#[inline]
fn rtn(v: f32, scale: f32, lv: f32) -> f32 {
    if scale <= 0.0 {
        return 0.0;
    }
    (v / scale).round().clamp(-lv - 1.0, lv) * scale
}

/// Per-output-channel (column) symmetric RTN for a [in, out] matrix.
pub fn quantize_per_channel(w: &Tensor, bits: u32) -> Tensor {
    let Some(lv) = levels(bits) else {
        return w.clone();
    };
    let (rows, cols) = (w.shape()[0], w.shape()[1]);
    // Column absmax.
    let mut absmax = vec![0.0f32; cols];
    for i in 0..rows {
        for (j, m) in absmax.iter_mut().enumerate() {
            *m = m.max(w.at2(i, j).abs());
        }
    }
    let scales: Vec<f32> = absmax.iter().map(|m| m / lv).collect();
    let mut out = w.clone();
    for i in 0..rows {
        for j in 0..cols {
            let v = rtn(w.at2(i, j), scales[j], lv);
            out.set2(i, j, v);
        }
    }
    out
}

/// Per-tensor symmetric RTN (any shape).
pub fn quantize_per_tensor(w: &Tensor, bits: u32) -> Tensor {
    let Some(lv) = levels(bits) else {
        return w.clone();
    };
    let scale = w.abs_max() / lv;
    let mut out = w.clone();
    for v in out.data_mut() {
        *v = rtn(*v, scale, lv);
    }
    out
}

/// Mean squared quantization error (diagnostics + SpinQuant objective).
pub fn quant_mse(w: &Tensor, bits: u32) -> f64 {
    let q = quantize_per_channel(w, bits);
    let mut s = 0.0f64;
    for (a, b) in w.data().iter().zip(q.data()) {
        let d = (a - b) as f64;
        s += d * d;
    }
    s / w.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed, 4);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn levels_table() {
        assert_eq!(levels(4), Some(7.0));
        assert_eq!(levels(8), Some(127.0));
        assert_eq!(levels(2), Some(1.0));
        assert_eq!(levels(16), None);
    }

    #[test]
    fn sixteen_bit_is_identity() {
        let w = randn(&[8, 8], 1);
        assert_eq!(quantize_per_channel(&w, 16), w);
    }

    #[test]
    fn error_bounded_by_half_scale() {
        let w = randn(&[32, 16], 2);
        let q = quantize_per_channel(&w, 4);
        for j in 0..16 {
            let absmax = (0..32).map(|i| w.at2(i, j).abs())
                .fold(0.0f32, f32::max);
            let scale = absmax / 7.0;
            for i in 0..32 {
                assert!((w.at2(i, j) - q.at2(i, j)).abs()
                        <= scale / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn grid_size_at_4bit() {
        let w = randn(&[64, 4], 3);
        let q = quantize_per_channel(&w, 4);
        for j in 0..4 {
            let mut vals: Vec<i64> = (0..64)
                .map(|i| {
                    let absmax = (0..64).map(|r| w.at2(r, j).abs())
                        .fold(0.0f32, f32::max);
                    (q.at2(i, j) / (absmax / 7.0)).round() as i64
                })
                .collect();
            vals.sort_unstable();
            vals.dedup();
            assert!(vals.len() <= 16, "{}", vals.len());
        }
    }

    #[test]
    fn outlier_column_wrecks_only_itself() {
        // Per-channel scales isolate an outlier column — unlike per-tensor,
        // where it inflates everyone's scale (the paper's Eq. 1 problem).
        let mut w = randn(&[32, 8], 4);
        for i in 0..32 {
            let v = w.at2(i, 3) * 100.0;
            w.set2(i, 3, v);
        }
        let q_pc = quantize_per_channel(&w, 4);
        let q_pt = quantize_per_tensor(&w, 4);
        let mse_col = |q: &Tensor, j: usize| -> f64 {
            (0..32)
                .map(|i| ((w.at2(i, j) - q.at2(i, j)) as f64).powi(2))
                .sum::<f64>()
        };
        // Non-outlier column 0: per-channel much better than per-tensor.
        assert!(mse_col(&q_pc, 0) < mse_col(&q_pt, 0) / 10.0);
    }

    #[test]
    fn mse_decreases_with_bits() {
        let w = randn(&[64, 32], 5);
        let e4 = quant_mse(&w, 4);
        let e8 = quant_mse(&w, 8);
        assert!(e8 < e4 / 10.0);
    }
}
