//! Post-training quantization library (Tables 2-4, Figures 1 & 4).
//!
//! Pipeline (`prepare`): absorb EmbProj -> (optional) fold norm scales +
//! residual rotation (QuaRot-lite / SpinQuant-lite) -> (optional) FFN-Had
//! weight pre-rotation -> weight quantization (RTN per-channel or GPTQ).
//! Activation / KV-cache quantization happens *inside* the evalq/logitsq
//! executables at runtime (bit-widths are inputs), so one artifact serves
//! every configuration.

pub mod absorb;
pub mod calib;
pub mod gptq;
pub mod rotate;
pub mod rtn;

use std::sync::OnceLock;

use anyhow::{Context, Result};

use crate::runtime::Engine;
use crate::tensor::linalg;
use crate::tensor::qtensor::QTensor;
use crate::tensor::{par, Tensor};
use crate::util::rng::Pcg;

pub use rotate::Rotation;

/// Weight-quantization algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightMethod {
    Rtn,
    Gptq,
}

/// A full PTQ recipe (one row of Table 4 / one cell of Table 2).
#[derive(Clone, Copy, Debug)]
pub struct PtqConfig {
    pub w_bits: u32,
    pub method: WeightMethod,
    pub rotation: Rotation,
    /// Online Hadamard on the FFN hidden state ("FFN Had"): pre-rotates
    /// w_down here and sets had_flag=1 for the executables.
    pub ffn_had: bool,
    pub seed: u64,
    /// Calibration batches for GPTQ.
    pub calib_batches: usize,
}

impl PtqConfig {
    pub fn rtn(w_bits: u32) -> PtqConfig {
        PtqConfig { w_bits, method: WeightMethod::Rtn,
                    rotation: Rotation::None, ffn_had: false, seed: 0,
                    calib_batches: 2 }
    }

    pub fn label(&self) -> String {
        let mut parts = vec![match self.method {
            WeightMethod::Rtn => "RTN".to_string(),
            WeightMethod::Gptq => "GPTQ".to_string(),
        }];
        match self.rotation {
            Rotation::None => {}
            Rotation::Random => parts.push("QuaRot-lite".into()),
            Rotation::Learned => parts.push("SpinQuant-lite".into()),
        }
        if self.ffn_had {
            parts.push("FFN-Had".into());
        }
        format!("{} (W{})", parts.join("+"), self.w_bits)
    }
}

/// One parameter of a quantized model: packed codes for the quantized
/// 2-D weights, dense f32 for everything else (norm scalars, passthrough
/// leaves).
pub enum QParam {
    Dense(Tensor),
    Packed(QTensor),
}

impl QParam {
    /// Materialize the dense f32 view (bit-identical to the old f32
    /// quantize-dequantize output for packed params).
    pub fn dequantize(&self) -> Tensor {
        match self {
            QParam::Dense(t) => t.clone(),
            QParam::Packed(q) => q.dequantize(),
        }
    }

    /// Serialized weight bytes in this representation.
    pub fn packed_bytes(&self) -> usize {
        match self {
            QParam::Dense(t) => 4 * t.len(),
            QParam::Packed(q) => q.packed_bytes(),
        }
    }

    /// What the parameter costs dense (f32).
    pub fn dense_bytes(&self) -> usize {
        match self {
            QParam::Dense(t) => 4 * t.len(),
            QParam::Packed(q) => q.dense_bytes(),
        }
    }
}

/// A weight-quantized model ready for the evalq/logitsq executables and
/// for the engine-free host paths ([`QuantizedModel::decoder`]).
/// Weights stay packed; the dense f32 view the PJRT boundary needs is
/// dequantized lazily, exactly once, by [`QuantizedModel::dense_params`]
/// — the host decode/eval paths never call it.
pub struct QuantizedModel {
    /// Architecture whose executables must be used (embproj arches are
    /// absorbed into their plain counterparts).
    pub arch: String,
    /// Private: `dense_params` caches a snapshot, so post-hoc mutation
    /// of the leaves would silently serve stale dense weights.
    params: Vec<QParam>,
    /// had_flag input value (1.0 when ffn_had).
    pub had_flag: f32,
    dense: OnceLock<Vec<Tensor>>,
}

impl QuantizedModel {
    pub fn new(arch: String, params: Vec<QParam>, had_flag: f32)
               -> QuantizedModel {
        QuantizedModel { arch, params, had_flag, dense: OnceLock::new() }
    }

    pub fn params(&self) -> &[QParam] {
        &self.params
    }

    /// Dense f32 parameters for the PJRT boundary, dequantized on first
    /// call (one scatter over the shared pool) and cached.
    pub fn dense_params(&self) -> &[Tensor] {
        self.dense.get_or_init(|| {
            par::par_map(par::active_pool(), &self.params,
                         |_, p| p.dequantize())
        })
    }

    /// Total serialized weight bytes in packed form.
    pub fn packed_bytes(&self) -> usize {
        self.params.iter().map(|p| p.packed_bytes()).sum()
    }

    /// Total weight bytes a dense f32 model would cost.
    pub fn dense_bytes(&self) -> usize {
        self.params.iter().map(|p| p.dense_bytes()).sum()
    }

    /// Host-model view for decode *and* engine-free evaluation: reuses
    /// the packed leaves directly (no `dense_params` round-trip — tokens
    /// and teacher-forced eval logits are served straight off the codes
    /// by [`crate::model::InferModel::forward_block`]). `n_heads` and
    /// `rope_theta` come from the lowering-time model config
    /// (`engine.manifest().model`); they are not recoverable from the
    /// leaf shapes.
    pub fn decoder(&self, n_heads: usize, rope_theta: f32)
                   -> Result<crate::model::InferModel> {
        crate::model::InferModel::from_qparams(
            &self.arch, &self.params, n_heads, rope_theta,
            self.had_flag > 0.5)
    }
}

/// Apply the PTQ recipe to a checkpoint.
pub fn prepare(engine: &Engine, arch: &str, params: &[Tensor],
               cfg: &PtqConfig) -> Result<QuantizedModel> {
    let m = engine.manifest();

    // 1. Absorb EmbProj into the neighboring embeddings (Section 3.3).
    let (arch, mut params) = if let Some(plain) = absorb::plain_arch(arch) {
        let specs = m.params(arch)?;
        (plain.clone(), absorb::absorb_embproj(specs, params)?)
    } else {
        (arch.to_string(), params.to_vec())
    };
    let specs = m.params(&arch)?.to_vec();

    // 2. Residual rotation (rotation-invariant thanks to folded scales /
    //    SSNorm's scalar gamma).
    match cfg.rotation {
        Rotation::None => {}
        Rotation::Random => {
            rotate::fold_norm_scales(&specs, &mut params);
            let mut rng = Pcg::new(cfg.seed ^ 0x51A407, 31);
            let q = linalg::random_orthogonal(m.model.d_model, &mut rng);
            rotate::apply_residual_rotation(&specs, &mut params, &q)?;
        }
        Rotation::Learned => {
            rotate::fold_norm_scales(&specs, &mut params);
            let q = rotate::learn_rotation(&specs, &params,
                                           m.model.d_model, cfg.w_bits,
                                           cfg.seed);
            rotate::apply_residual_rotation(&specs, &mut params, &q)?;
        }
    }

    // 3. FFN-Had pre-rotation (pairs with the executables' online H).
    if cfg.ffn_had {
        rotate::prerotate_w_down_hadamard(&specs, &mut params);
    }

    // 4. Weight quantization of every 2D parameter.
    let hessians = if cfg.method == WeightMethod::Gptq && cfg.w_bits < 16 {
        Some(calib::collect_hessians(engine, &arch, &params,
                                     cfg.calib_batches)
             .context("GPTQ calibration")?)
    } else {
        None
    };
    // Each 2-D param quantizes independently into packed codes: scatter
    // one job per param over the shared pool (inner kernels fall back to
    // serial on the workers). The first error, in any param, wins
    // deterministically only in *whether* we fail — the message may name
    // any failing param; still-queued jobs then skip their (useless)
    // work.
    let failed = std::sync::atomic::AtomicBool::new(false);
    let first_err: std::sync::Mutex<Option<anyhow::Error>> =
        std::sync::Mutex::new(None);
    let packed: Vec<Option<QTensor>> =
        par::par_map(par::active_pool(), &params, |i, p| {
            use std::sync::atomic::Ordering;
            let s = &specs[i];
            if failed.load(Ordering::Relaxed)
                || p.shape().len() != 2
                || s.kind == "norm"
            {
                return None; // stays a dense leaf (moved below, no copy)
            }
            match hessians.as_ref().and_then(|h| h.get(&s.name)) {
                Some(h) => match gptq::gptq_quantize_q(p, h, cfg.w_bits) {
                    Ok(q) => Some(q),
                    Err(e) => {
                        failed.store(true, Ordering::Relaxed);
                        let mut slot = first_err.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(e.context(format!("GPTQ on {}",
                                                           s.name)));
                        }
                        None
                    }
                },
                None => Some(rtn::quantize_per_channel_q(p, cfg.w_bits)),
            }
        });
    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    // Zip back against the owned params so untouched leaves move into
    // the model instead of being cloned.
    let qparams: Vec<QParam> = params
        .into_iter()
        .zip(packed)
        .map(|(p, q)| match q {
            Some(q) => QParam::Packed(q),
            None => QParam::Dense(p),
        })
        .collect();

    Ok(QuantizedModel::new(arch, qparams,
                           if cfg.ffn_had { 1.0 } else { 0.0 }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(PtqConfig::rtn(4).label(), "RTN (W4)");
        let c = PtqConfig { w_bits: 4, method: WeightMethod::Gptq,
                            rotation: Rotation::Learned, ffn_had: true,
                            seed: 0, calib_batches: 1 };
        assert_eq!(c.label(), "GPTQ+SpinQuant-lite+FFN-Had (W4)");
    }
}
