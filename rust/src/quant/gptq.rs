//! GPTQ (Frantar et al., 2023): Hessian-aware weight quantization with
//! error feedback, from scratch on the in-tree linalg.
//!
//! Orientation: weights are [in, out] (x @ W) and the Hessian is
//! H = X^T X over the input dimension. Rows of W are quantized in order;
//! the residual of row i is propagated to the not-yet-quantized rows
//! through the upper Cholesky factor U of H^{-1} (U^T U = H^{-1}).

use anyhow::{anyhow, Result};

use crate::tensor::linalg::{cholesky, spd_inverse, transpose};
use crate::tensor::qtensor::QTensor;
use crate::tensor::{par, Tensor};

use super::rtn;

/// Damped Hessian -> upper Cholesky factor of its inverse.
fn inverse_cholesky(h: &Tensor, damp_frac: f64) -> Result<Tensor> {
    let n = h.shape()[0];
    let mut hd = h.clone();
    let mean_diag: f64 =
        (0..n).map(|i| hd.at2(i, i) as f64).sum::<f64>() / n as f64;
    let damp = (damp_frac * mean_diag.max(1e-8)) as f32;
    for i in 0..n {
        let d = hd.at2(i, i);
        // Dead inputs (never activated in calibration) get unit curvature.
        let v = if d <= 0.0 { 1.0 } else { d + damp };
        hd.set2(i, i, v);
    }
    let hinv = spd_inverse(&hd)
        .map_err(|e| anyhow!("GPTQ Hessian inverse: {e}"))?;
    let l = cholesky(&hinv).map_err(|e| anyhow!("GPTQ Cholesky: {e}"))?;
    Ok(transpose(&l)) // upper factor U with U^T U = H^{-1}
}

/// GPTQ-quantize a [in, out] weight against Hessian [in, in], emitting
/// packed codes directly (the deployment path). Scales are symmetric per
/// output channel, fixed from the original W (same grid RTN uses, so
/// improvements are purely from error feedback). The dequantized value
/// each row's error feedback uses is exactly `code * scale`, so
/// `result.dequantize()` is bit-identical to the f32 round-trip
/// [`gptq_quantize`] (which is now this + dequantize).
pub fn gptq_quantize_q(w: &Tensor, h: &Tensor, bits: u32)
                       -> Result<QTensor> {
    let Some(lv) = rtn::levels(bits) else {
        return Ok(QTensor::from_dense(w));
    };
    let (rows, cols) = (w.shape()[0], w.shape()[1]);
    assert_eq!(h.shape(), &[rows, rows], "hessian shape");

    let u = inverse_cholesky(h, 0.01)?;

    // Per-output-channel scales from the original weights (single-pass
    // column absmax over contiguous rows, shared with RTN).
    let mut scales = rtn::column_absmax(w);
    for s in scales.iter_mut() {
        *s /= lv;
    }

    let mut work = w.clone();
    let mut codes = vec![0i32; rows * cols];
    for i in 0..rows {
        let uii = u.at2(i, i).max(1e-12);
        // Quantize row i in code space; the dequantized value only ever
        // lives in a register, for the scaled residual.
        let mut err = vec![0.0f32; cols];
        let wrow = &work.data()[i * cols..(i + 1) * cols];
        let crow = &mut codes[i * cols..(i + 1) * cols];
        for (j, (&v, c)) in wrow.iter().zip(crow.iter_mut()).enumerate() {
            let s = scales[j];
            let (code, q) = if s <= 0.0 {
                (0, 0.0)
            } else {
                let r = (v / s).round().clamp(-lv - 1.0, lv);
                (r as i32, r * s)
            };
            *c = code;
            err[j] = (v - q) / uii;
        }
        // Propagate to later rows: w[r,:] -= U[i,r] * err. The rank-1
        // update is independent per row — chunk the trailing block over
        // the shared pool when large (row arithmetic is identical in
        // both paths, so results match the serial loop bitwise).
        let rows_left = rows - i - 1;
        if rows_left == 0 || cols == 0 {
            continue;
        }
        let u_row = u.row(i);
        let tail = &mut work.data_mut()[(i + 1) * cols..];
        // One body for both paths (bitwise parity by construction):
        // `r0` is the absolute index of the chunk's first row.
        let update = |r0: usize, chunk: &mut [f32]| {
            for (rr, row) in chunk.chunks_mut(cols).enumerate() {
                let uir = u_row[r0 + rr];
                if uir == 0.0 {
                    continue;
                }
                for (wv, e) in row.iter_mut().zip(&err) {
                    *wv -= uir * e;
                }
            }
        };
        match par::pool_for_ops(rows_left * cols) {
            Some(p) if rows_left > 1 => {
                let rpb = par::rows_per_block(rows_left, p.n_workers());
                p.scatter_chunks(tail, rpb * cols, |ci, chunk| {
                    update(i + 1 + ci * rpb, chunk)
                });
            }
            _ => update(i + 1, tail),
        }
    }
    Ok(QTensor::from_codes(w.shape(), bits, &codes, scales))
}

/// f32 round-trip view of [`gptq_quantize_q`] (bit-identical by the
/// code-times-scale parity contract).
pub fn gptq_quantize(w: &Tensor, h: &Tensor, bits: u32) -> Result<Tensor> {
    if bits >= 16 {
        return Ok(w.clone());
    }
    Ok(gptq_quantize_q(w, h, bits)?.dequantize())
}

/// Hessian-weighted reconstruction error tr((W-Q)^T H (W-Q)) — the
/// objective GPTQ minimizes greedily; used to verify GPTQ <= RTN.
pub fn hessian_error(w: &Tensor, q: &Tensor, h: &Tensor) -> f64 {
    let diff = w.sub(q);
    let hd = crate::tensor::linalg::matmul(h, &diff);
    let mut tr = 0.0f64;
    for (a, b) in diff.data().iter().zip(hd.data()) {
        tr += (*a as f64) * (*b as f64);
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::linalg::matmul;
    use crate::util::rng::Pcg;

    fn randn(shape: &[usize], seed: u64, std: f32) -> Tensor {
        let mut rng = Pcg::new(seed, 6);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), std);
        t
    }

    fn random_hessian(n: usize, samples: usize, seed: u64) -> Tensor {
        let x = randn(&[samples, n], seed, 1.0);
        matmul(&transpose(&x), &x)
    }

    #[test]
    fn identity_hessian_reduces_to_rtn() {
        let w = randn(&[16, 8], 1, 1.0);
        let h = Tensor::eye(16);
        let q = gptq_quantize(&w, &h, 4).unwrap();
        let r = rtn::quantize_per_channel(&w, 4);
        crate::util::prop::all_close(q.data(), r.data(), 1e-6).unwrap();
    }

    #[test]
    fn gptq_beats_rtn_in_hessian_norm() {
        for seed in 0..5 {
            let w = randn(&[24, 12], 100 + seed, 1.0);
            let h = random_hessian(24, 64, 200 + seed);
            let q = gptq_quantize(&w, &h, 4).unwrap();
            let r = rtn::quantize_per_channel(&w, 4);
            let eg = hessian_error(&w, &q, &h);
            let er = hessian_error(&w, &r, &h);
            assert!(eg <= er * 1.001,
                    "seed {seed}: gptq {eg} > rtn {er}");
        }
    }

    #[test]
    fn sixteen_bit_identity() {
        let w = randn(&[8, 4], 2, 1.0);
        let h = random_hessian(8, 32, 3);
        let q = gptq_quantize(&w, &h, 16).unwrap();
        assert_eq!(q, w);
    }

    #[test]
    fn handles_rank_deficient_hessian() {
        // Fewer calibration samples than dims -> singular H; damping must
        // keep the algorithm well-posed.
        let w = randn(&[32, 8], 4, 1.0);
        let h = random_hessian(32, 4, 5);
        let q = gptq_quantize(&w, &h, 4).unwrap();
        assert!(q.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_values_on_grid() {
        let w = randn(&[16, 4], 6, 2.0);
        let h = random_hessian(16, 64, 7);
        let q = gptq_quantize(&w, &h, 4).unwrap();
        // Each column's values live on a 16-point symmetric grid.
        for j in 0..4 {
            let absmax = (0..16).map(|i| w.at2(i, j).abs())
                .fold(0.0f32, f32::max);
            let s = absmax / 7.0;
            for i in 0..16 {
                let ratio = q.at2(i, j) / s;
                assert!((ratio - ratio.round()).abs() < 1e-3,
                        "off-grid value {}", q.at2(i, j));
                assert!(ratio.round().abs() <= 8.0);
            }
        }
    }
}
