//! Residual-stream rotations: QuaRot-lite (random orthogonal / Hadamard)
//! and SpinQuant-lite (a learned rotation), plus the "FFN Had" weight
//! pre-rotation that pairs with the evalq executables' online Hadamard.
//!
//! Invariance argument (DESIGN.md §5, Table 4): with the norm's
//! channel-wise scale folded away, RMSNorm (and SSNorm natively — a
//! single scalar gamma commutes with rotations, one more payoff of the
//! paper's §3.2) satisfies norm(Q^T x) = Q^T norm(x). Rotating
//!
//!   embed' = embed Q,   {wq,wk,wv,w_gate,w_up}' = Q^T W,
//!   {wo,w_down}' = W Q,  unembed' = Q^T unembed
//!
//! leaves every logit unchanged in fp32 while redistributing outlier
//! channels before quantization.

use anyhow::{anyhow, Result};

use crate::runtime::manifest::ParamSpec;
use crate::tensor::linalg::{self, matmul, transpose};
use crate::tensor::{par, Tensor};
use crate::util::rng::Pcg;

use super::rtn;

/// Fold channel-wise norm scales into the downstream weight matrices and
/// set the norm params to 1 (RMSNorm arches; SSNorm needs no folding —
/// its single scalar commutes with any rotation, so it is left alone).
///
/// rmsnorm(x; s) @ W == rmsnorm(x; 1) @ (diag(s) W).
pub fn fold_norm_scales(specs: &[ParamSpec], params: &mut [Tensor]) {
    let idx = |name: &str| specs.iter().position(|s| s.name == name);
    let layer_count = specs
        .iter()
        .filter(|s| s.name.ends_with(".attn_norm"))
        .count();

    let mut fold = |norm_name: String, targets: Vec<String>| {
        let Some(ni) = idx(&norm_name) else { return };
        if params[ni].len() <= 1 {
            return; // SSNorm scalar: rotation-equivariant as-is.
        }
        let scale = params[ni].clone();
        for t in targets {
            let Some(wi) = idx(&t) else { continue };
            let w = &mut params[wi];
            let cols = w.shape()[1];
            for (i, &s) in scale.data().iter().enumerate() {
                for j in 0..cols {
                    let v = w.at2(i, j) * s;
                    w.set2(i, j, v);
                }
            }
        }
        params[ni] = Tensor::full(&[scale.len()], 1.0);
    };

    for l in 0..layer_count {
        fold(format!("layers.{l}.attn_norm"),
             vec![format!("layers.{l}.wq"), format!("layers.{l}.wk"),
                  format!("layers.{l}.wv")]);
        fold(format!("layers.{l}.ffn_norm"),
             vec![format!("layers.{l}.w_gate"), format!("layers.{l}.w_up")]);
    }
    fold("final_norm".to_string(), vec!["unembed".to_string()]);
}

/// Apply the residual-stream rotation Q (d_model x d_model, orthogonal).
/// Caller must fold norm scales first (RMSNorm arches) for exactness.
/// The per-param rotations are independent 2-D matmuls, so they scatter
/// over the shared pool (one job per param; each job's matmul is the
/// serial kernel, giving results identical to the sequential loop).
pub fn apply_residual_rotation(specs: &[ParamSpec], params: &mut [Tensor],
                               q: &Tensor) -> Result<()> {
    let short_of = |s: &ParamSpec| -> String {
        s.name.rsplit('.').next().unwrap_or(&s.name).to_string()
    };
    if specs.iter().any(|s| {
        matches!(short_of(s).as_str(), "embproj_in" | "embproj_out")
    }) {
        return Err(anyhow!("rotate after absorbing embproj (quant::absorb)"));
    }
    let qt = transpose(q);
    par::par_map_mut(par::active_pool(), params, |i, p| {
        match short_of(&specs[i]).as_str() {
            // Consumers of the residual stream: W' = Q^T W.
            "wq" | "wk" | "wv" | "w_gate" | "w_up" | "unembed" => {
                *p = matmul(&qt, p);
            }
            // Producers into the residual stream: W' = W Q. The
            // embedding emits residual vectors, so its rows rotate the
            // same way.
            "wo" | "w_down" | "embed" => {
                *p = matmul(p, q);
            }
            _ => {} // norm scalars / folded scales
        }
    });
    Ok(())
}

/// Pre-rotate w_down for the online "FFN Had" path: the executable
/// applies H to the FFN hidden state when had_flag=1, so computational
/// invariance needs w_down' = H w_down (H symmetric involution).
pub fn prerotate_w_down_hadamard(specs: &[ParamSpec],
                                 params: &mut [Tensor]) {
    // One scatter job per w_down (layers are independent).
    par::par_map_mut(par::active_pool(), params, |i, p| {
        if specs[i].name.ends_with("w_down") {
            // H W: rows mix => apply the blocked FWHT to columns, i.e.
            // transpose, row-transform, transpose back.
            let t = transpose(p);
            let rotated = linalg::hadamard_rows(&t);
            *p = transpose(&rotated);
        }
    });
}

/// Rotation selection for Table 4.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rotation {
    None,
    /// Random orthogonal Q (QuaRot-lite).
    Random,
    /// Learned Q (SpinQuant-lite): best-of-K random starts refined by
    /// Givens sweeps against the weight quantization MSE objective.
    Learned,
}

/// Objective for SpinQuant-lite: total per-channel 4-bit quantization MSE
/// of the residual-facing matrices after rotation (a weight-space proxy
/// for SpinQuant's end-to-end objective; DESIGN.md §2 documents the
/// substitution). Scoring goes through the streaming [`rtn::quant_mse`],
/// which derives codes in-register — no dequantized copy is ever
/// materialized across the search's many candidate evaluations.
pub fn rotation_objective(specs: &[ParamSpec], params: &[Tensor],
                          q: &Tensor, bits: u32) -> f64 {
    let mut trial: Vec<Tensor> = params.to_vec();
    let mut specs_v = specs.to_vec();
    fold_norm_scales(&specs_v, &mut trial);
    apply_residual_rotation(&mut specs_v.clone(), &mut trial, q).unwrap();
    let _ = &mut specs_v;
    // Per-param MSEs are independent: scatter, then combine in param
    // order (deterministic — the sum order never depends on scheduling).
    let quantizable: Vec<&Tensor> = specs
        .iter()
        .zip(&trial)
        .filter(|(s, w)| w.shape().len() == 2 && s.kind != "norm")
        .map(|(_, w)| w)
        .collect();
    par::par_map(par::active_pool(), &quantizable,
                 |_, &w| rtn::quant_mse(w, bits) * w.len() as f64)
        .into_iter()
        .sum()
}

/// Learn a rotation by best-of-K random starts + greedy Givens refinement.
pub fn learn_rotation(specs: &[ParamSpec], params: &[Tensor], d: usize,
                      bits: u32, seed: u64) -> Tensor {
    let mut rng = Pcg::new(seed, 77);
    // Candidates: identity-free random orthogonals.
    let mut best_q = linalg::random_orthogonal(d, &mut rng);
    let mut best = rotation_objective(specs, params, &best_q, bits);
    for _ in 0..3 {
        let q = linalg::random_orthogonal(d, &mut rng);
        let obj = rotation_objective(specs, params, &q, bits);
        if obj < best {
            best = obj;
            best_q = q;
        }
    }
    // Givens refinement: try small-angle rotations in random planes.
    let angles = [0.15f32, -0.15, 0.05, -0.05];
    for _ in 0..24 {
        let i = rng.below_usize(d);
        let mut j = rng.below_usize(d);
        if i == j {
            j = (j + 1) % d;
        }
        let mut improved = false;
        for &a in &angles {
            let mut q = best_q.clone();
            givens_right(&mut q, i, j, a);
            let obj = rotation_objective(specs, params, &q, bits);
            if obj < best * 0.9999 {
                best = obj;
                best_q = q;
                improved = true;
                break;
            }
        }
        let _ = improved;
    }
    best_q
}

/// Right-multiply q by a Givens rotation in plane (i, j).
fn givens_right(q: &mut Tensor, i: usize, j: usize, angle: f32) {
    let (c, s) = (angle.cos(), angle.sin());
    let rows = q.shape()[0];
    for r in 0..rows {
        let a = q.at2(r, i);
        let b = q.at2(r, j);
        q.set2(r, i, c * a - s * b);
        q.set2(r, j, s * a + c * b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize], kind: &str) -> ParamSpec {
        ParamSpec { name: name.into(), shape: shape.to_vec(),
                    init: "normal".into(), kind: kind.into() }
    }

    fn toy_model(d: usize, seed: u64) -> (Vec<ParamSpec>, Vec<Tensor>) {
        let mut rng = Pcg::new(seed, 5);
        let mut randn = |shape: &[usize]| {
            let mut t = Tensor::zeros(shape);
            rng.fill_normal(t.data_mut(), 1.0);
            t
        };
        let specs = vec![
            spec("embed", &[12, d], "embed"),
            spec("layers.0.attn_norm", &[d], "norm"),
            spec("layers.0.wq", &[d, d], "matrix"),
            spec("layers.0.wk", &[d, d], "matrix"),
            spec("layers.0.wv", &[d, d], "matrix"),
            spec("layers.0.wo", &[d, d], "matrix"),
            spec("layers.0.ffn_norm", &[d], "norm"),
            spec("layers.0.w_gate", &[d, 2 * d], "matrix"),
            spec("layers.0.w_up", &[d, 2 * d], "matrix"),
            spec("layers.0.w_down", &[2 * d, d], "matrix"),
            spec("final_norm", &[d], "norm"),
            spec("unembed", &[d, 12], "unembed"),
        ];
        let params: Vec<Tensor> =
            specs.iter().map(|s| randn(&s.shape)).collect();
        (specs, params)
    }

    #[test]
    fn fold_makes_norms_unit() {
        let (specs, mut params) = toy_model(8, 1);
        let wq_before = params[2].clone();
        let scale_before = params[1].clone();
        fold_norm_scales(&specs, &mut params);
        for v in params[1].data() {
            assert_eq!(*v, 1.0);
        }
        // wq row i scaled by s_i
        for i in 0..8 {
            for j in 0..8 {
                let want = wq_before.at2(i, j) * scale_before.data()[i];
                assert!((params[2].at2(i, j) - want).abs() < 1e-6);
            }
        }
        // wo untouched by folding
    }

    #[test]
    fn rotation_preserves_functional_composition() {
        // Check a single linear algebra identity on the rotated weights:
        // (Q^T x) @ (Q^T W) is NOT invariant, but x @ W computed through
        // the rotated pipeline embed Q -> Q^T wq is:
        //   (e Q)(Q^T wq) = e wq.
        let (specs, mut params) = toy_model(8, 2);
        let e0 = params[0].clone();
        let wq0 = params[2].clone();
        let wo0 = params[5].clone();
        let mut rng = Pcg::new(3, 0);
        let q = linalg::random_orthogonal(8, &mut rng);
        apply_residual_rotation(&specs, &mut params, &q).unwrap();
        let recomposed = matmul(&params[0], &params[2]);
        let want = matmul(&e0, &wq0);
        crate::util::prop::all_close(recomposed.data(), want.data(), 1e-3)
            .unwrap();
        // Producer side: wo' = wo Q, so wo' Q^T == wo.
        let back = matmul(&params[5], &transpose(&q));
        crate::util::prop::all_close(back.data(), wo0.data(), 1e-3).unwrap();
    }

    #[test]
    fn hadamard_prerotation_involution() {
        let (specs, mut params) = toy_model(8, 4);
        let w0 = params[9].clone();
        prerotate_w_down_hadamard(&specs, &mut params);
        prerotate_w_down_hadamard(&specs, &mut params);
        crate::util::prop::all_close(params[9].data(), w0.data(), 1e-4)
            .unwrap();
    }

    #[test]
    fn rotation_flattens_outlier_channel_mse() {
        // Plant an outlier channel; a random rotation must reduce the
        // 4-bit quantization MSE (the QuaRot mechanism).
        let (specs, mut params) = toy_model(16, 5);
        // Outlier channel in wq's input dim.
        for i in 0..16 {
            let v = params[2].at2(i, 3) * 50.0;
            params[2].set2(i, 3, v);
        }
        let eye = Tensor::eye(16);
        let base = rotation_objective(&specs, &params, &eye, 4);
        let mut rng = Pcg::new(6, 0);
        let q = linalg::random_orthogonal(16, &mut rng);
        let rotated = rotation_objective(&specs, &params, &q, 4);
        assert!(rotated < base, "rotated {rotated} >= base {base}");
    }

    #[test]
    fn learned_rotation_not_worse_than_random() {
        let (specs, params) = toy_model(8, 7);
        let learned = learn_rotation(&specs, &params, 8, 4, 11);
        let obj_learned = rotation_objective(&specs, &params, &learned, 4);
        let mut rng = Pcg::new(12, 0);
        let random = linalg::random_orthogonal(8, &mut rng);
        let obj_random = rotation_objective(&specs, &params, &random, 4);
        assert!(obj_learned <= obj_random * 1.05,
                "learned {obj_learned} vs random {obj_random}");
        // and actually orthogonal
        let g = matmul(&transpose(&learned), &learned);
        crate::util::prop::all_close(g.data(), Tensor::eye(8).data(), 1e-3)
            .unwrap();
    }
}
