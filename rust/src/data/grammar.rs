//! Synthetic probabilistic grammar: the stand-in for the paper's
//! FineWeb-Edu/FineMath/Cosmopedia/StarCoder mixture (DESIGN.md §2).
//!
//! The language mixes five generative processes so the corpus has
//! (a) a Zipfian long-tail unigram distribution — realistic channel
//! statistics, (b) local bigram structure — learnable quickly, (c)
//! induction/copy patterns — exercises attention, (d) bracket nesting —
//! stack-like state, and (e) key-value "facts" + modular arithmetic —
//! the raw material for the 10 synthetic benchmark task families in
//! `eval::tasks`. Everything is deterministic in (vocab_size, seed).

use crate::util::rng::Pcg;

/// The language itself (class partition, bigram table, agreement map) is
/// a project-wide constant: every consumer — training stream, held-out
/// stream, benchmark tasks, calibration — must speak the *same* language,
/// while document sampling varies by split/seed.
pub const LANGUAGE_SEED: u64 = 1;

/// Reserved token ids (the "tokenizer" — the language is already tokens).
pub const BOS: i32 = 0;
pub const SEP: i32 = 1;
pub const LPAREN: i32 = 2;
pub const RPAREN: i32 = 3;
pub const EQUALS: i32 = 4;
pub const PLUS: i32 = 5;
pub const COLON: i32 = 6;
pub const QUERY: i32 = 7;
pub const N_SPECIAL: usize = 8;

/// Number of "digit" tokens for the modular-arithmetic clauses.
pub const N_DIGITS: usize = 20;

/// Content-token classes (template slots).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    Noun,
    Verb,
    Adj,
    Func,
}

pub struct Grammar {
    pub vocab_size: usize,
    /// Content tokens per class, each with Zipf weights.
    nouns: Vec<i32>,
    verbs: Vec<i32>,
    adjs: Vec<i32>,
    funcs: Vec<i32>,
    /// Zipf weights aligned with the class vectors.
    noun_w: Vec<f64>,
    verb_w: Vec<f64>,
    adj_w: Vec<f64>,
    func_w: Vec<f64>,
    /// Preferred successor table: bigram structure (4 per token).
    successors: Vec<[i32; 4]>,
    /// Agreement map: each noun deterministically selects a verb "form"
    /// (the long-range-agreement task keys on this).
    pub agreement: Vec<i32>,
}

fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    (1..=n).map(|k| 1.0 / (k as f64).powf(s)).collect()
}

impl Grammar {
    pub fn digit(&self, v: usize) -> i32 {
        (N_SPECIAL + (v % N_DIGITS)) as i32
    }

    pub fn new(vocab_size: usize, seed: u64) -> Grammar {
        assert!(vocab_size >= 64, "vocab too small for the grammar");
        let mut rng = Pcg::new(seed, 101);
        let first_content = N_SPECIAL + N_DIGITS;
        let content: Vec<i32> =
            (first_content..vocab_size).map(|t| t as i32).collect();
        // Partition content into classes 40/25/15/20 %.
        let n = content.len();
        let n_noun = n * 40 / 100;
        let n_verb = n * 25 / 100;
        let n_adj = n * 15 / 100;
        let mut shuffled = content;
        rng.shuffle(&mut shuffled);
        let nouns = shuffled[..n_noun].to_vec();
        let verbs = shuffled[n_noun..n_noun + n_verb].to_vec();
        let adjs = shuffled[n_noun + n_verb..n_noun + n_verb + n_adj].to_vec();
        let funcs = shuffled[n_noun + n_verb + n_adj..].to_vec();

        let successors = (0..vocab_size)
            .map(|_| {
                let mut s = [0i32; 4];
                for slot in s.iter_mut() {
                    *slot = shuffled[rng.below_usize(shuffled.len())];
                }
                s
            })
            .collect();

        let agreement = (0..vocab_size)
            .map(|_| verbs[rng.below_usize(verbs.len())])
            .collect();

        Grammar {
            vocab_size,
            noun_w: zipf_weights(nouns.len(), 1.1),
            verb_w: zipf_weights(verbs.len(), 1.1),
            adj_w: zipf_weights(adjs.len(), 1.2),
            func_w: zipf_weights(funcs.len(), 0.9),
            nouns,
            verbs,
            adjs,
            funcs,
            successors,
            agreement,
        }
    }

    pub fn sample_class(&self, c: Class, rng: &mut Pcg) -> i32 {
        let (toks, w) = match c {
            Class::Noun => (&self.nouns, &self.noun_w),
            Class::Verb => (&self.verbs, &self.verb_w),
            Class::Adj => (&self.adjs, &self.adj_w),
            Class::Func => (&self.funcs, &self.func_w),
        };
        toks[rng.weighted(w)]
    }

    pub fn class_tokens(&self, c: Class) -> &[i32] {
        match c {
            Class::Noun => &self.nouns,
            Class::Verb => &self.verbs,
            Class::Adj => &self.adjs,
            Class::Func => &self.funcs,
        }
    }

    /// The four preferred successors of a token (bigram structure).
    pub fn successors(&self, t: i32) -> &[i32; 4] {
        &self.successors[t as usize]
    }

    // ---- clause generators -------------------------------------------------

    /// Markov walk: each step follows a preferred successor w.p. 0.85,
    /// else a fresh class sample. This is the bulk of the corpus.
    fn clause_markov(&self, rng: &mut Pcg, out: &mut Vec<i32>) {
        let len = 4 + rng.below_usize(8);
        let mut t = self.sample_class(Class::Noun, rng);
        out.push(t);
        for _ in 0..len {
            t = if rng.uniform() < 0.85 {
                let s = self.successors(t);
                s[rng.below_usize(4)]
            } else {
                self.sample_class(Class::Func, rng)
            };
            out.push(t);
        }
    }

    /// Template: ADJ NOUN VERB(agreeing) FUNC NOUN — the long-range
    /// agreement: the verb is determined by the *first* noun.
    fn clause_template(&self, rng: &mut Pcg, out: &mut Vec<i32>) {
        let adj = self.sample_class(Class::Adj, rng);
        let noun = self.sample_class(Class::Noun, rng);
        let verb = self.agreement[noun as usize];
        let func = self.sample_class(Class::Func, rng);
        let obj = self.sample_class(Class::Noun, rng);
        out.extend_from_slice(&[adj, noun, verb, func, obj]);
    }

    /// Induction: A B ... filler ... A B (the induction-head pattern).
    fn clause_induction(&self, rng: &mut Pcg, out: &mut Vec<i32>) {
        let a = self.sample_class(Class::Noun, rng);
        let b = self.sample_class(Class::Verb, rng);
        out.push(a);
        out.push(b);
        for _ in 0..2 + rng.below_usize(4) {
            out.push(self.sample_class(Class::Func, rng));
        }
        out.push(a);
        out.push(b);
    }

    /// Copy: X1..Xk SEP X1..Xk.
    fn clause_copy(&self, rng: &mut Pcg, out: &mut Vec<i32>) {
        let k = 2 + rng.below_usize(3);
        let span: Vec<i32> =
            (0..k).map(|_| self.sample_class(Class::Noun, rng)).collect();
        out.extend_from_slice(&span);
        out.push(SEP);
        out.extend_from_slice(&span);
    }

    /// Bracketed span with nesting depth <= 2.
    fn clause_bracket(&self, rng: &mut Pcg, out: &mut Vec<i32>) {
        out.push(LPAREN);
        out.push(self.sample_class(Class::Noun, rng));
        if rng.uniform() < 0.4 {
            out.push(LPAREN);
            out.push(self.sample_class(Class::Adj, rng));
            out.push(RPAREN);
        }
        out.push(self.sample_class(Class::Verb, rng));
        out.push(RPAREN);
    }

    /// Modular arithmetic fact: d1 + d2 = (d1+d2) mod N_DIGITS.
    fn clause_math(&self, rng: &mut Pcg, out: &mut Vec<i32>) {
        let a = rng.below_usize(N_DIGITS);
        let b = rng.below_usize(N_DIGITS);
        out.extend_from_slice(&[
            self.digit(a),
            PLUS,
            self.digit(b),
            EQUALS,
            self.digit(a + b),
        ]);
    }

    /// Key-value fact + later recall: K COLON V ... QUERY K COLON V.
    fn clause_fact(&self, rng: &mut Pcg, out: &mut Vec<i32>) {
        let k = self.sample_class(Class::Noun, rng);
        let v = self.sample_class(Class::Adj, rng);
        out.extend_from_slice(&[k, COLON, v]);
        for _ in 0..1 + rng.below_usize(3) {
            out.push(self.sample_class(Class::Func, rng));
        }
        out.extend_from_slice(&[QUERY, k, COLON, v]);
    }

    /// Generate one document (BOS ... SEP-joined clauses).
    pub fn document(&self, rng: &mut Pcg) -> Vec<i32> {
        let mut out = vec![BOS];
        let n_clauses = 5 + rng.below_usize(8);
        for _ in 0..n_clauses {
            match rng.weighted(&[4.0, 2.0, 1.0, 1.0, 1.0, 1.0, 1.0]) {
                0 => self.clause_markov(rng, &mut out),
                1 => self.clause_template(rng, &mut out),
                2 => self.clause_induction(rng, &mut out),
                3 => self.clause_copy(rng, &mut out),
                4 => self.clause_bracket(rng, &mut out),
                5 => self.clause_math(rng, &mut out),
                _ => self.clause_fact(rng, &mut out),
            }
            out.push(SEP);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let g1 = Grammar::new(512, 9);
        let g2 = Grammar::new(512, 9);
        let mut r1 = Pcg::new(1, 0);
        let mut r2 = Pcg::new(1, 0);
        assert_eq!(g1.document(&mut r1), g2.document(&mut r2));
    }

    #[test]
    fn tokens_in_range() {
        let g = Grammar::new(256, 3);
        let mut rng = Pcg::new(2, 0);
        for _ in 0..50 {
            for &t in &g.document(&mut rng) {
                assert!((0..256).contains(&t), "token {t} out of range");
            }
        }
    }

    #[test]
    fn classes_are_disjoint_and_cover_content() {
        let g = Grammar::new(512, 1);
        let mut all: Vec<i32> = [
            g.class_tokens(Class::Noun),
            g.class_tokens(Class::Verb),
            g.class_tokens(Class::Adj),
            g.class_tokens(Class::Func),
        ]
        .concat();
        let total = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), total, "classes overlap");
        assert_eq!(total, 512 - N_SPECIAL - N_DIGITS);
    }

    #[test]
    fn unigram_distribution_is_long_tailed() {
        let g = Grammar::new(512, 7);
        let mut rng = Pcg::new(5, 0);
        let mut counts = vec![0usize; 512];
        for _ in 0..400 {
            for t in g.document(&mut rng) {
                counts[t as usize] += 1;
            }
        }
        let mut sorted: Vec<usize> =
            counts.iter().copied().filter(|&c| c > 0).collect();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        // Zipf-ish: the head token should dominate the median by a lot.
        let head = sorted[1]; // skip SEP at [0]
        let median = sorted[sorted.len() / 2];
        assert!(head > 10 * median, "head {head} median {median}");
    }

    #[test]
    fn math_clauses_are_consistent() {
        let g = Grammar::new(256, 11);
        let mut rng = Pcg::new(8, 0);
        let mut found = 0;
        for _ in 0..200 {
            let doc = g.document(&mut rng);
            for w in doc.windows(5) {
                if w[1] == PLUS && w[3] == EQUALS {
                    let a = w[0] as usize - N_SPECIAL;
                    let b = w[2] as usize - N_SPECIAL;
                    let c = w[4] as usize - N_SPECIAL;
                    assert_eq!((a + b) % N_DIGITS, c);
                    found += 1;
                }
            }
        }
        assert!(found > 10, "math clauses too rare: {found}");
    }

    #[test]
    fn fact_clauses_recall_their_value() {
        let g = Grammar::new(512, 13);
        let mut rng = Pcg::new(9, 0);
        let mut found = 0;
        for _ in 0..200 {
            let doc = g.document(&mut rng);
            for (i, &t) in doc.iter().enumerate() {
                if t == QUERY && i + 3 < doc.len() {
                    let k = doc[i + 1];
                    // The defining `k COLON v` is the *nearest* earlier
                    // occurrence (keys may repeat across clauses).
                    for j in (0..i).rev() {
                        if doc[j] == k && doc.get(j + 1) == Some(&COLON) {
                            assert_eq!(doc[j + 2], doc[i + 3],
                                       "fact recall mismatch");
                            found += 1;
                            break;
                        }
                    }
                }
            }
        }
        assert!(found > 10, "fact clauses too rare: {found}");
    }

    #[test]
    fn agreement_is_deterministic_per_noun() {
        let g = Grammar::new(512, 17);
        let noun = g.class_tokens(Class::Noun)[0];
        let v1 = g.agreement[noun as usize];
        let v2 = g.agreement[noun as usize];
        assert_eq!(v1, v2);
        assert!(g.class_tokens(Class::Verb).contains(&v1));
    }
}
