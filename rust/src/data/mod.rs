//! Data pipeline: synthetic grammar corpus -> sharded token stream ->
//! prefetched fixed-shape batches for the training loop.
//!
//! Mirrors a production ingestion path (shards, deterministic order,
//! held-out split, bounded prefetch with backpressure) at laptop scale.

pub mod grammar;

use crate::util::rng::Pcg;
use crate::util::threadpool::{BoundedChannel, Receiver};

pub use grammar::Grammar;

/// A fixed-shape token batch [batch, seq_len], row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    pub batch: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
    /// Global step index this batch was produced for (telemetry).
    pub index: u64,
}

impl Batch {
    pub fn shape(&self) -> [usize; 2] {
        [self.batch, self.seq_len]
    }
}

/// Which split a stream draws from. Train and Valid documents live in
/// disjoint RNG-stream id spaces, so the held-out set ("our WikiText-2")
/// can never leak into training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Valid,
}

/// Deterministic, shardable token stream: shard `s` of `n` produces the
/// documents at slots s, s+n, s+2n, ... so any shard partition covers the
/// corpus exactly once (property-tested below).
pub struct TokenStream {
    grammar: Grammar,
    seed: u64,
    split: Split,
    shard: usize,
    n_shards: usize,
    /// Carry-over tokens between batches (documents are packed, never
    /// dropped).
    buffer: Vec<i32>,
    next_doc: u64,
}

impl TokenStream {
    pub fn new(vocab_size: usize, seed: u64, split: Split, shard: usize,
               n_shards: usize) -> TokenStream {
        assert!(shard < n_shards);
        TokenStream {
            // The grammar (the language) is fixed by LANGUAGE_SEED;
            // `seed` only drives document sampling.
            grammar: Grammar::new(vocab_size, grammar::LANGUAGE_SEED),
            seed,
            split,
            shard,
            n_shards,
            buffer: Vec::new(),
            next_doc: 0,
        }
    }

    pub fn grammar(&self) -> &Grammar {
        &self.grammar
    }

    fn doc_rng(&self, doc_index: u64) -> Pcg {
        let split_tag = match self.split {
            Split::Train => 1u64 << 62,
            Split::Valid => 2u64 << 62,
        };
        let slot = doc_index * self.n_shards as u64 + self.shard as u64;
        Pcg::new(self.seed, split_tag | slot)
    }

    /// Produce the next [batch, seq_len] batch by packing documents.
    pub fn next_batch(&mut self, batch: usize, seq_len: usize,
                      index: u64) -> Batch {
        let need = batch * seq_len;
        while self.buffer.len() < need {
            let mut rng = self.doc_rng(self.next_doc);
            self.next_doc += 1;
            let doc = self.grammar.document(&mut rng);
            self.buffer.extend_from_slice(&doc);
        }
        let tokens: Vec<i32> = self.buffer.drain(..need).collect();
        Batch { batch, seq_len, tokens, index }
    }

    /// Documents consumed so far (telemetry / resumption).
    pub fn docs_consumed(&self) -> u64 {
        self.next_doc
    }
}

/// Prefetching loader: a producer thread generates batches ahead of the
/// training loop through a bounded channel (capacity = backpressure).
pub struct Loader {
    rx: Receiver<Batch>,
}

impl Loader {
    pub fn spawn(vocab_size: usize, seed: u64, split: Split, batch: usize,
                 seq_len: usize, capacity: usize, max_batches: u64) -> Loader {
        let (tx, rx) = BoundedChannel::new(capacity);
        std::thread::Builder::new()
            .name("osp-data-loader".into())
            .spawn(move || {
                let mut stream =
                    TokenStream::new(vocab_size, seed, split, 0, 1);
                for i in 0..max_batches {
                    let b = stream.next_batch(batch, seq_len, i);
                    if tx.send(b).is_err() {
                        return; // consumer gone
                    }
                }
            })
            .expect("spawn loader");
        Loader { rx }
    }

    pub fn next(&self) -> Option<Batch> {
        self.rx.recv()
    }

    pub fn depth(&self) -> usize {
        self.rx.depth()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_deterministic() {
        let mut a = TokenStream::new(256, 4, Split::Train, 0, 1);
        let mut b = TokenStream::new(256, 4, Split::Train, 0, 1);
        for i in 0..5 {
            assert_eq!(a.next_batch(4, 64, i), b.next_batch(4, 64, i));
        }
    }

    #[test]
    fn shards_partition_documents() {
        // Union of 3 shards' first documents == the first 9 documents of
        // the unsharded stream (as multisets).
        let single: Vec<Vec<i32>> = {
            let s = TokenStream::new(256, 4, Split::Train, 0, 1);
            (0..9u64)
                .map(|d| {
                    let mut rng = s.doc_rng(d);
                    s.grammar.document(&mut rng)
                })
                .collect()
        };
        let mut union: Vec<Vec<i32>> = Vec::new();
        for shard in 0..3 {
            let s = TokenStream::new(256, 4, Split::Train, shard, 3);
            for d in 0..3u64 {
                let mut rng = s.doc_rng(d);
                union.push(s.grammar.document(&mut rng));
            }
        }
        let mut a = single;
        let mut b = union;
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn train_and_valid_disjoint() {
        let t = TokenStream::new(256, 4, Split::Train, 0, 1);
        let v = TokenStream::new(256, 4, Split::Valid, 0, 1);
        let mut tr = t.doc_rng(0);
        let mut vr = v.doc_rng(0);
        assert_ne!(t.grammar.document(&mut tr), v.grammar.document(&mut vr));
    }

    #[test]
    fn packing_loses_no_tokens() {
        let mut s = TokenStream::new(256, 4, Split::Train, 0, 1);
        let b1 = s.next_batch(2, 32, 0);
        let b2 = s.next_batch(2, 32, 1);
        // Regenerate the same docs manually; concatenation must match.
        let mut manual = Vec::new();
        let fresh = TokenStream::new(256, 4, Split::Train, 0, 1);
        let mut d = 0u64;
        while manual.len() < 128 {
            let mut rng = fresh.doc_rng(d);
            manual.extend(fresh.grammar.document(&mut rng));
            d += 1;
        }
        let got: Vec<i32> =
            b1.tokens.iter().chain(&b2.tokens).copied().collect();
        assert_eq!(got, manual[..128].to_vec());
    }

    #[test]
    fn loader_prefetches_and_terminates() {
        let loader = Loader::spawn(256, 7, Split::Train, 2, 32, 3, 10);
        let mut n = 0;
        while let Some(b) = loader.next() {
            assert_eq!(b.tokens.len(), 64);
            assert_eq!(b.index, n);
            n += 1;
        }
        assert_eq!(n, 10);
    }

    #[test]
    fn loader_depth_bounded() {
        let loader = Loader::spawn(256, 7, Split::Train, 2, 32, 2, 100);
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(loader.depth() <= 2);
        for _ in 0..10 {
            loader.next().unwrap();
            assert!(loader.depth() <= 2);
        }
    }
}
