//! Run configuration: typed configs resolved from CLI flags (+ optional
//! JSON config file), serialized into each run directory for provenance.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::util::cli::Args;
use crate::util::json::Json;

/// Training-run configuration (one ablation cell of Table 2 / Fig 3, or
/// the long Fig-7 run).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Architecture tag: rmsnorm_plain | ssnorm_plain | rmsnorm_embproj |
    /// ssnorm_embproj.
    pub arch: String,
    /// Optimizer: adam | muon | muon_noadam | shampoo | soap.
    pub optimizer: String,
    pub steps: u64,
    /// Peak learning rate (the trapezoid's plateau).
    pub peak_lr: f64,
    /// Warmup fraction of total steps (paper: 5B/1T ~ 0.005; we default
    /// higher because runs are short).
    pub warmup_frac: f64,
    /// Decay fraction of total steps (paper: final 20%).
    pub decay_frac: f64,
    pub seed: u64,
    /// Microbatch accumulation factor (macro batch = accum * batch_train).
    pub grad_accum: usize,
    /// Checkpoint every N steps (0 = only final).
    pub ckpt_every: u64,
    /// Eval (held-out ppl + kurtosis) every N steps (0 = never).
    pub eval_every: u64,
    /// Simulated data-parallel ranks (1 = plain fused loop).
    pub dp_ranks: usize,
    /// Use the disaggregated optimizer-parallel Muon path.
    pub disaggregated: bool,
    /// Optimizer-parallel ranks for the disaggregated path (paper: 8).
    pub opt_ranks: usize,
    pub run_dir: PathBuf,
    pub artifacts: PathBuf,
}

/// The paper's per-optimizer peak learning rates (Appendix A.1), scaled
/// for short synthetic-corpus runs.
pub fn default_peak_lr(optimizer: &str) -> f64 {
    match optimizer {
        // Muon lr; embeddings inside get 10x via ADAM_LR_RATIO (L2 side).
        "muon" | "muon_noadam" => 2e-3,
        "shampoo" | "soap" => 2e-3,
        // Adam (paper used 5e-3 at 1.4B; high LR accelerates outlier
        // emergence, matching the paper's regime).
        _ => 3e-3,
    }
}

impl TrainConfig {
    pub fn from_args(args: &Args) -> TrainConfig {
        let optimizer = args.str_or("optimizer", "muon");
        let arch = args.str_or("arch", "ssnorm_embproj");
        let steps = args.u64_or("steps", 300);
        TrainConfig {
            peak_lr: args.f64_or("lr", default_peak_lr(&optimizer)),
            arch: arch.clone(),
            optimizer: optimizer.clone(),
            steps,
            warmup_frac: args.f64_or("warmup-frac", 0.1),
            decay_frac: args.f64_or("decay-frac", 0.2),
            seed: args.u64_or("seed", 1),
            grad_accum: args.usize_or("grad-accum", 1),
            ckpt_every: args.u64_or("ckpt-every", 0),
            eval_every: args.u64_or("eval-every", 25),
            dp_ranks: args.usize_or("dp-ranks", 1),
            disaggregated: args.bool_or("disaggregated", false),
            opt_ranks: args.usize_or("opt-ranks", 4),
            run_dir: PathBuf::from(args.str_or(
                "run-dir",
                &format!("runs/{optimizer}_{arch}"),
            )),
            artifacts: PathBuf::from(args.str_or("artifacts", "artifacts")),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arch", Json::str(self.arch.clone())),
            ("optimizer", Json::str(self.optimizer.clone())),
            ("steps", Json::num(self.steps as f64)),
            ("peak_lr", Json::num(self.peak_lr)),
            ("warmup_frac", Json::num(self.warmup_frac)),
            ("decay_frac", Json::num(self.decay_frac)),
            ("seed", Json::num(self.seed as f64)),
            ("grad_accum", Json::num(self.grad_accum as f64)),
            ("ckpt_every", Json::num(self.ckpt_every as f64)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("dp_ranks", Json::num(self.dp_ranks as f64)),
            ("disaggregated", Json::Bool(self.disaggregated)),
            ("opt_ranks", Json::num(self.opt_ranks as f64)),
        ])
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("config.json"), self.to_json().dump())?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        const ARCHS: [&str; 4] = ["rmsnorm_plain", "ssnorm_plain",
                                  "rmsnorm_embproj", "ssnorm_embproj"];
        const OPTS: [&str; 5] = ["adam", "muon", "muon_noadam", "shampoo",
                                 "soap"];
        if !ARCHS.contains(&self.arch.as_str()) {
            return Err(anyhow!("unknown arch '{}' (one of {ARCHS:?})",
                               self.arch));
        }
        if !OPTS.contains(&self.optimizer.as_str()) {
            return Err(anyhow!("unknown optimizer '{}' (one of {OPTS:?})",
                               self.optimizer));
        }
        if self.disaggregated && !self.optimizer.starts_with("muon") {
            return Err(anyhow!(
                "disaggregated mode implements the paper's optimizer-\
                 parallel *Muon*; got '{}'", self.optimizer));
        }
        if self.steps == 0 || self.grad_accum == 0 || self.dp_ranks == 0 {
            return Err(anyhow!("steps/grad_accum/dp_ranks must be > 0"));
        }
        Ok(())
    }
}

/// The named ablation grid of Table 2 (config tag -> (optimizer, arch)).
pub const ABLATION_GRID: [(&str, &str, &str); 6] = [
    ("adam", "adam", "rmsnorm_plain"),
    ("muon_noadam", "muon_noadam", "rmsnorm_plain"),
    ("muon", "muon", "rmsnorm_plain"),
    ("muon_ssnorm", "muon", "ssnorm_plain"),
    ("muon_embproj", "muon", "rmsnorm_embproj"),
    ("osp", "muon", "ssnorm_embproj"),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn defaults_are_valid() {
        let cfg = TrainConfig::from_args(&Args::parse(&argv(""), false));
        cfg.validate().unwrap();
        assert_eq!(cfg.arch, "ssnorm_embproj");
        assert_eq!(cfg.optimizer, "muon");
        assert!((cfg.peak_lr - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn adam_default_lr_differs() {
        let cfg = TrainConfig::from_args(&Args::parse(
            &argv("--optimizer adam --arch rmsnorm_plain"), false));
        assert!((cfg.peak_lr - 3e-3).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_arch_and_disagg_adam() {
        let mut cfg = TrainConfig::from_args(&Args::parse(&argv(""), false));
        cfg.arch = "nope".into();
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::from_args(&Args::parse(&argv(""), false));
        cfg.optimizer = "adam".into();
        cfg.arch = "rmsnorm_plain".into();
        cfg.disaggregated = true;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn json_has_all_fields() {
        let cfg = TrainConfig::from_args(&Args::parse(&argv(""), false));
        let j = cfg.to_json();
        for key in ["arch", "optimizer", "steps", "peak_lr", "dp_ranks"] {
            assert!(j.get(key).is_some(), "{key}");
        }
    }

    #[test]
    fn ablation_grid_archs_valid() {
        for (_tag, opt, arch) in ABLATION_GRID {
            let cfg = TrainConfig::from_args(&Args::parse(
                &argv(&format!("--optimizer {opt} --arch {arch}")), false));
            cfg.validate().unwrap();
        }
    }
}
