//! Simulated data parallelism: K ranks compute gradients on their own
//! microbatches (grad_* executable), a ring all-reduce averages them, the
//! host optimizer applies the update.
//!
//! The transport is in-process (threads + bounded channels) but the
//! algorithm is the real one: reduce-scatter then all-gather over K-1
//! hops each, chunked by rank. Invariants (exact average, independence
//! from interleaving, every microbatch consumed once) are tested here and
//! property-tested in rust/tests.

use std::sync::Arc;

use crate::tensor::par;
use crate::util::threadpool::BoundedChannel;

/// Ring all-reduce (average) over `parts`: each element is one rank's
/// flat gradient vector. Returns the per-rank results (all equal).
///
/// Chunking: the vector is split into K chunks; chunk c travels the ring
/// accumulating, then travels again broadcasting — the standard
/// bandwidth-optimal schedule.
pub fn ring_all_reduce(parts: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
    let k = parts.len();
    assert!(k > 0);
    let n = parts[0].len();
    assert!(parts.iter().all(|p| p.len() == n), "rank size mismatch");
    if k == 1 {
        return parts;
    }

    // Chunk boundaries (chunk i: [bounds[i], bounds[i+1])).
    let bounds: Vec<usize> = (0..=k).map(|i| i * n / k).collect();

    // Channels: rank r sends to rank (r+1) % k.
    let mut senders = Vec::with_capacity(k);
    let mut receivers = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = BoundedChannel::new(2);
        senders.push(Some(tx));
        receivers.push(Some(rx));
    }
    // rank r receives from rank (r-1+k)%k: re-index receivers.
    let mut rx_for_rank: Vec<_> = (0..k).map(|_| None).collect();
    for (r, rx) in receivers.into_iter().enumerate() {
        rx_for_rank[(r + 1) % k] = rx;
    }

    let bounds = Arc::new(bounds);
    let mut handles = Vec::with_capacity(k);
    for (r, (mut data, (tx, rx))) in parts
        .into_iter()
        .zip(senders.into_iter().map(Option::unwrap).zip(
            rx_for_rank.into_iter().map(Option::unwrap)))
        .enumerate()
    {
        let bounds = Arc::clone(&bounds);
        handles.push(std::thread::spawn(move || {
            // Reduce-scatter: K-1 hops; at hop h, rank r sends chunk
            // (r - h) mod K and accumulates the incoming chunk.
            for h in 0..k - 1 {
                let send_c = (r + k - h) % k;
                let (s0, s1) = (bounds[send_c], bounds[send_c + 1]);
                tx.send((send_c, data[s0..s1].to_vec()))
                    .map_err(|_| ()).expect("ring send");
                let (c, chunk) = rx.recv().expect("ring recv");
                let (b0, _b1) = (bounds[c], bounds[c + 1]);
                // Accumulate hop: element-wise, so the shared-pool path
                // is exact for any worker count (rank threads are plain
                // OS threads, never pool workers, so this may fan out).
                par::add_assign(&mut data[b0..b0 + chunk.len()], &chunk);
            }
            // All-gather: K-1 hops; rank r now owns the fully reduced
            // chunk (r+1) mod K.
            for h in 0..k - 1 {
                let send_c = (r + 1 + k - h) % k;
                let (s0, s1) = (bounds[send_c], bounds[send_c + 1]);
                tx.send((send_c, data[s0..s1].to_vec()))
                    .map_err(|_| ()).expect("ring send");
                let (c, chunk) = rx.recv().expect("ring recv");
                let (b0, _b1) = (bounds[c], bounds[c + 1]);
                data[b0..b0 + chunk.len()].copy_from_slice(&chunk);
            }
            // Average (parallel element-wise scale when large).
            par::scale_in_place(&mut data, 1.0 / k as f32);
            data
        }));
    }
    handles.into_iter().map(|h| h.join().expect("rank panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn make_parts(k: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg::new(seed, 2);
        (0..k)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect()
    }

    fn expected_avg(parts: &[Vec<f32>]) -> Vec<f32> {
        let k = parts.len() as f32;
        let n = parts[0].len();
        (0..n)
            .map(|i| parts.iter().map(|p| p[i]).sum::<f32>() / k)
            .collect()
    }

    #[test]
    fn averages_exactly() {
        for k in [1, 2, 3, 4, 7] {
            for n in [1, 5, 64, 257] {
                let parts = make_parts(k, n, (k * 1000 + n) as u64);
                let want = expected_avg(&parts);
                let got = ring_all_reduce(parts);
                for r in &got {
                    crate::util::prop::all_close(r, &want, 1e-5)
                        .unwrap_or_else(|e| panic!("k={k} n={n}: {e}"));
                }
            }
        }
    }

    #[test]
    fn all_ranks_agree() {
        let parts = make_parts(5, 100, 9);
        let got = ring_all_reduce(parts);
        for r in 1..got.len() {
            assert_eq!(got[0], got[r]);
        }
    }

    #[test]
    fn n_smaller_than_k() {
        // Degenerate chunking (some chunks empty) must still work.
        let parts = make_parts(8, 3, 11);
        let want = expected_avg(&parts);
        let got = ring_all_reduce(parts);
        crate::util::prop::all_close(&got[3], &want, 1e-5).unwrap();
    }
}
