//! Trapezoidal (warmup-stable-decay) learning-rate schedule, as used by
//! the paper (Appendix A.1: linear warmup over the first 5B tokens, flat
//! plateau, linear decay to zero over the final 20%).

/// Piecewise-linear trapezoid. All fractions of `total_steps`.
#[derive(Clone, Copy, Debug)]
pub struct Trapezoid {
    pub peak: f64,
    pub total_steps: u64,
    pub warmup_frac: f64,
    pub decay_frac: f64,
}

impl Trapezoid {
    pub fn new(peak: f64, total_steps: u64, warmup_frac: f64,
               decay_frac: f64) -> Trapezoid {
        assert!(warmup_frac >= 0.0 && decay_frac >= 0.0);
        assert!(warmup_frac + decay_frac <= 1.0,
                "warmup + decay fractions exceed 1");
        Trapezoid { peak, total_steps, warmup_frac, decay_frac }
    }

    /// LR for step `t` (0-based).
    pub fn at(&self, t: u64) -> f64 {
        let total = self.total_steps.max(1) as f64;
        let w = (self.warmup_frac * total).round();
        let d = (self.decay_frac * total).round();
        let decay_start = total - d;
        let t = t as f64;
        if t < w {
            self.peak * (t + 1.0) / w.max(1.0)
        } else if t >= decay_start {
            let remain = (total - t) / d.max(1.0);
            self.peak * remain.max(0.0)
        } else {
            self.peak
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_is_trapezoidal() {
        let s = Trapezoid::new(1.0, 100, 0.1, 0.2);
        assert!(s.at(0) <= 0.2); // warming up
        assert!(s.at(5) < 1.0);
        assert_eq!(s.at(10), 1.0); // plateau
        assert_eq!(s.at(79), 1.0);
        assert!(s.at(90) < 1.0); // decaying
        assert!(s.at(99) <= 0.06);
        // monotone warmup
        for t in 0..9 {
            assert!(s.at(t) <= s.at(t + 1) + 1e-12);
        }
        // monotone decay
        for t in 80..99 {
            assert!(s.at(t) >= s.at(t + 1) - 1e-12);
        }
    }

    #[test]
    fn no_warmup_no_decay_is_constant() {
        let s = Trapezoid::new(0.5, 50, 0.0, 0.0);
        for t in 0..50 {
            assert_eq!(s.at(t), 0.5);
        }
    }

    #[test]
    fn peak_reached_even_tiny_runs() {
        let s = Trapezoid::new(2.0, 3, 0.34, 0.33);
        let max = (0..3).map(|t| s.at(t)).fold(0.0f64, f64::max);
        assert!(max >= 1.9);
    }
}
