//! Shard publication (DESIGN.md §14): partition a packed model into
//! per-worker artifacts and write the manifest a serving coordinator's
//! [`crate::serve::storage::LocalDir`] backend serves fetches from.
//!
//! This is the `osp shard` entry point. The split itself lives in the
//! model layer ([`crate::model::InferModel::extract_shard_sets`]); this
//! module only owns the on-disk layout: `shard_{w}.bin` OSPS artifacts
//! (checkpoint layer) plus `manifest.json` with per-file byte counts
//! and FNV-1a digests, so a worker fetching over HTTP can verify what
//! it got against what `osp shard` wrote.

use std::path::Path;

use anyhow::{Context, Result};

use crate::checkpoint;
use crate::model::InferModel;
use crate::serve::storage::{self, Manifest, ManifestEntry};

/// Per-shard byte counts of a published directory, for reporting.
pub struct ShardReport {
    pub shards: usize,
    pub bytes: Vec<usize>,
}

/// Replica placement for a possibly-replicated fleet (DESIGN.md §15):
/// worker `w` serves shard `w % n_shards`, so `--workers a,b,c` with 2
/// shards covers shard 0 twice (workers 0 and 2) and shard 1 once.
/// Round-robin keeps the `--replicas 1` layout identical to the PR-9
/// one-worker-per-shard fleet and spreads extra replicas evenly.
pub fn replica_assignment(n_workers: usize, n_shards: usize)
                          -> Vec<usize> {
    assert!(n_shards > 0, "replica_assignment with zero shards");
    (0..n_workers).map(|w| w % n_shards).collect()
}

/// Worker indices per shard under [`replica_assignment`], in placement
/// order (the first entry is the shard's primary).
pub fn replicas_of(n_workers: usize, n_shards: usize)
                   -> Vec<Vec<usize>> {
    let mut groups = vec![Vec::new(); n_shards];
    for (w, &s) in replica_assignment(n_workers, n_shards).iter()
        .enumerate()
    {
        groups[s].push(w);
    }
    groups
}

/// Partition `model`'s trunk into `shards` row/col slices and publish
/// them under `dir` (created if absent) with a manifest. The model is
/// left untouched — publication is a pure read.
pub fn write_shards(model: &InferModel, shards: usize, arch: &str,
                    dir: &Path) -> Result<ShardReport> {
    let sets = model.extract_shard_sets(shards)?;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {dir:?}"))?;
    let mut files = Vec::with_capacity(shards);
    let mut bytes = Vec::with_capacity(shards);
    for (w, set) in sets.iter().enumerate() {
        let file = format!("shard_{w}.bin");
        let path = dir.join(&file);
        checkpoint::save_shard(&path, w, shards, arch, set)?;
        let blob = std::fs::read(&path)
            .with_context(|| format!("re-reading {path:?}"))?;
        bytes.push(blob.len());
        files.push(ManifestEntry {
            file,
            bytes: blob.len(),
            fnv: storage::fnv64(&blob),
        });
    }
    storage::write_manifest(dir, &Manifest {
        shards,
        arch: arch.to_string(),
        files,
    })?;
    Ok(ShardReport { shards, bytes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::remote::ShardKind;
    use crate::model::InferConfig;
    use crate::serve::storage::{LocalDir, StorageBackend};

    fn tiny_cfg() -> InferConfig {
        InferConfig { vocab_size: 96, d_model: 32, n_layers: 2,
                      n_heads: 2, d_ff: 48, rope_theta: 10000.0,
                      norm_ss: true, embproj: false }
    }

    #[test]
    fn published_dir_roundtrips_through_storage_and_checkpoint() {
        let dir = std::env::temp_dir().join("osp_shard_pub_test");
        let _ = std::fs::remove_dir_all(&dir);
        let m = InferModel::synthetic(&tiny_cfg(), 11).quantized(4);
        let rep = write_shards(&m, 2, "ssnorm_plain", &dir).unwrap();
        assert_eq!(rep.shards, 2);
        assert_eq!(rep.bytes.len(), 2);

        // The serving side opens the same directory...
        let store = LocalDir::open(&dir).unwrap();
        assert_eq!(store.n_shards(), 2);
        assert_eq!(store.arch(), "ssnorm_plain");
        for w in 0..2 {
            let meta = store.meta(w).unwrap();
            assert_eq!(meta.bytes, rep.bytes[w]);
            // ...and a whole-file ranged read parses back into the
            // exact shard set the model layer extracted.
            let blob = store.read(w, 0, meta.bytes).unwrap();
            let art = checkpoint::parse_shard(&blob, "pub test").unwrap();
            assert_eq!(art.shard, w);
            assert_eq!(art.n_shards, 2);
            assert_eq!(art.arch, "ssnorm_plain");
            // 7 trunk linears per layer + unembed.
            assert_eq!(art.entries.len(), 7 * 2 + 1);
            assert!(art.entries.iter().any(|e| {
                e.name == "L0.wo" && e.kind == ShardKind::Row
            }));
        }
    }

    #[test]
    fn replica_assignment_covers_every_shard_evenly() {
        // replicas = 1: the PR-9 layout, worker w <-> shard w.
        assert_eq!(replica_assignment(2, 2), vec![0, 1]);
        // The CI failover fleet: 3 workers over 2 shards.
        assert_eq!(replica_assignment(3, 2), vec![0, 1, 0]);
        assert_eq!(replicas_of(3, 2), vec![vec![0, 2], vec![1]]);
        // Full duplication.
        assert_eq!(replicas_of(4, 2), vec![vec![0, 2], vec![1, 3]]);
        // Every shard covered, group sizes within 1 of each other.
        for (nw, ns) in [(2, 2), (3, 2), (5, 3), (8, 3)] {
            let groups = replicas_of(nw, ns);
            assert_eq!(groups.len(), ns);
            let (mut lo, mut hi) = (usize::MAX, 0);
            for g in &groups {
                assert!(!g.is_empty(), "{nw}/{ns}: uncovered shard");
                lo = lo.min(g.len());
                hi = hi.max(g.len());
            }
            assert!(hi - lo <= 1, "{nw}/{ns}: uneven groups");
            let total: usize = groups.iter().map(Vec::len).sum();
            assert_eq!(total, nw);
        }
    }

    #[test]
    fn publication_refuses_dense_models() {
        let dir = std::env::temp_dir().join("osp_shard_pub_dense_test");
        let _ = std::fs::remove_dir_all(&dir);
        let dense = InferModel::synthetic(&tiny_cfg(), 11);
        assert!(write_shards(&dense, 2, "ssnorm_plain", &dir).is_err());
    }
}
